"""AOT lowering: JAX -> HLO text artifacts for the rust PJRT runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md and gen_hlo.py there.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (what
``make artifacts`` runs).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_scorer() -> str:
    args = (
        spec((model.M_PAD, model.T_BINS)),  # mu
        spec((model.M_PAD, model.T_BINS)),  # sigma
        spec((model.M_PAD, 4)),             # phi
        spec((model.M_PAD, 3)),             # psi
        spec((model.M_PAD,)),               # trust
        spec((model.M_PAD,)),               # hist
        spec((model.M_PAD,)),               # valid
        spec((model.N_PARAMS,)),            # params
    )
    return to_hlo_text(jax.jit(model.scorer).lower(*args))


def lower_calibrator() -> str:
    args = (
        spec((model.M_PAD, 4)),  # declared
        spec((model.M_PAD, 4)),  # observed
        spec((4,)),              # weights
        spec((model.M_PAD,)),    # prev_mean_err
        spec((model.M_PAD,)),    # prev_count
        spec(()),                # kappa
    )
    return to_hlo_text(jax.jit(model.calibrator).lower(*args))


def lower_safety() -> str:
    args = (
        spec((model.M_PAD, model.T_BINS)),  # mu
        spec((model.M_PAD, model.T_BINS)),  # sigma
        spec(()),                           # capacity
    )
    return to_hlo_text(jax.jit(model.safety).lower(*args))


ARTIFACTS = {
    "scorer.hlo.txt": lower_scorer,
    "calibrator.hlo.txt": lower_calibrator,
    "safety.hlo.txt": lower_safety,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, lower in ARTIFACTS.items():
        text = lower()
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars to {path}")


if __name__ == "__main__":
    main()
