"""L2: the JASDA scoring graph — the computation the rust coordinator
executes on its hot path via PJRT.

Three exported entry points (one HLO artifact each, see ``aot.py``):

* ``scorer``      — the full variant-scoring pipeline (calls the L1
  Pallas kernel); inputs are the padded ``[M_PAD, T]`` batch the rust
  ``PjrtScorer`` stages.
* ``calibrator``  — batched ex-post verification update (Eqs. (6)–(8)):
  per-variant convex error, running-mean fold, reliability
  ``rho = exp(-kappa * mean_err)``.
* ``safety``      — standalone FMP violation probabilities (the job-side
  eligibility check of §4.1(a)), usable by external agent
  implementations.

Python runs only at build time; ``make artifacts`` lowers these once.
"""

import jax.numpy as jnp

from .kernels import ref, scoring

# Artifact shapes — must match rust/src/runtime/mod.rs constants.
M_PAD = 256
T_BINS = 64
N_PARAMS = 11


def scorer(mu, sigma, phi, psi, trust, hist, valid, params):
    """Variant scoring: returns (score, violation, headroom), each [M_PAD].

    Thin wrapper over the L1 Pallas kernel so the whole pipeline lowers
    into a single HLO module.
    """
    return scoring.score_pallas(mu, sigma, phi, psi, trust, hist, valid, params)


def calibrator(declared, observed, weights, prev_mean_err, prev_count, kappa):
    """Batched ex-post verification (paper Eqs. (6)–(8)).

    Args:
      declared:      [M, 4] declared feature vectors of completed subjobs.
      observed:      [M, 4] observed feature vectors.
      weights:       [4]    convex error weights w_i (sum to 1).
      prev_mean_err: [M]    each job's running mean error before this fold.
      prev_count:    [M]    each job's verified-variant count before fold.
      kappa:         []     reliability sensitivity.

    Returns:
      (eps [M], new_mean_err [M], rho [M]).
    """
    eps = jnp.sum(jnp.abs(declared - observed) * weights, axis=-1)
    count = prev_count + 1.0
    new_mean = prev_mean_err + (eps - prev_mean_err) / count
    rho = jnp.exp(-kappa * new_mean)
    return eps, new_mean, rho


def safety(mu, sigma, capacity):
    """Standalone FMP violation probabilities over a [M, T] batch."""
    sig = jnp.maximum(sigma, ref.SIGMA_EPS)
    z = (capacity - mu) / sig
    log_surv = jnp.sum(jnp.log(ref.normal_cdf(z)), axis=-1)
    return jnp.clip(1.0 - jnp.exp(log_surv), 0.0, 1.0)
