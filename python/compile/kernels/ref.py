"""Pure-jnp oracle for the JASDA variant-scoring pipeline.

This is the correctness reference for the L1 Pallas kernel
(``scoring.py``) and — transitively — for the rust ``NativeScorer`` and
the PJRT-executed artifact, all of which implement the *same* math:

1. probabilistic safety  ``viol = 1 - prod_t Phi((c - mu_t)/sigma_t)``
   (paper §4.1(a), per-bin independence, log-space product);
2. memory headroom       ``psi_mem = mean_t clip((c - mu_t)/c, 0, 1)``;
3. calibrated utility    ``h_cal = trust*h_tilde + (1-trust)*hist``
   with ``h_tilde = sum_i alpha_i phi_i`` (Eqs. (2) and (5));
4. system utility        ``f = b0*psi_util + b1*psi_mem + b2*psi_frag
   + b3*age`` (Eq. (3) + §4.3);
5. composite score       ``lambda*h_cal + (1-lambda)*f`` (Eq. (4)),
   zeroed for ineligible (viol > theta) or invalid (padded) lanes.

The erf uses the Abramowitz–Stegun 7.1.26 polynomial — the same one the
rust side hardcodes — so all implementations agree to f32 precision.
"""

import jax.numpy as jnp

# Shared numerical floor for sigma (mirrors rust SIGMA_EPS).
SIGMA_EPS = 1e-6


def erf_as(x):
    """Abramowitz–Stegun 7.1.26 erf approximation (|err| < 1.5e-7)."""
    a1 = 0.254829592
    a2 = -0.284496736
    a3 = 1.421413741
    a4 = -1.453152027
    a5 = 1.061405429
    p = 0.3275911
    sign = jnp.sign(x)
    # sign(0) = 0 but erf(0) ~ 0 anyway.
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + p * ax)
    y = 1.0 - (((((a5 * t + a4) * t) + a3) * t + a2) * t + a1) * t * jnp.exp(-ax * ax)
    return jnp.where(x < 0, -y, y)


def normal_cdf(x):
    """Phi(x) clamped into (0, 1) for log safety (kernel-identical)."""
    c = 0.5 * (1.0 + erf_as(x / jnp.sqrt(2.0).astype(x.dtype)))
    return jnp.clip(c, 1e-12, 1.0)


def score_ref(mu, sigma, phi, psi, trust, hist, valid, params):
    """Reference scoring pipeline.

    Args:
      mu:     [M, T] f32 — FMP mean memory per bin (GiB).
      sigma:  [M, T] f32 — FMP memory std per bin (GiB).
      phi:    [M, 4] f32 — declared job features [jct, qos, energy, loc].
      psi:    [M, 3] f32 — system features [util, frag, age].
      trust:  [M]    f32 — calibration weight gamma*rho_J.
      hist:   [M]    f32 — HistAvg(J) anchors.
      valid:  [M]    f32 — 1 for real rows, 0 for padding.
      params: [11]   f32 — [capacity, theta, lambda, alpha(4), beta(4)].

    Returns:
      (score [M], violation [M], headroom [M]) — score is 0 for
      ineligible or padded lanes.
    """
    mu = mu.astype(jnp.float32)
    sigma = sigma.astype(jnp.float32)
    capacity = params[0]
    theta = params[1]
    lam = params[2]
    alpha = params[3:7]
    beta = params[7:11]

    sig = jnp.maximum(sigma, SIGMA_EPS)
    z = (capacity - mu) / sig
    log_surv = jnp.sum(jnp.log(normal_cdf(z)), axis=-1)
    viol = jnp.clip(1.0 - jnp.exp(log_surv), 0.0, 1.0)

    headroom = jnp.mean(jnp.clip((capacity - mu) / capacity, 0.0, 1.0), axis=-1)

    h_tilde = phi @ alpha
    h_cal = trust * h_tilde + (1.0 - trust) * hist

    f_sys = beta[0] * psi[:, 0] + beta[1] * headroom + beta[2] * psi[:, 1] + beta[3] * psi[:, 2]

    score = lam * h_cal + (1.0 - lam) * f_sys
    eligible = (viol <= theta) & (valid > 0.0)
    score = jnp.where(eligible, jnp.clip(score, 0.0, 1.0), 0.0)
    return score, viol, headroom
