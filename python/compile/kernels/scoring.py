"""L1 Pallas kernel: fused JASDA variant-scoring pipeline.

One kernel invocation scores a block of variants against one announced
window: FMP safety product, memory headroom, calibrated job utility, and
the normalized composite score (paper Eqs. (2)–(5), §4.1(a), §4.3) —
fused so the (M, T) FMP matrices are read exactly once.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's setting
is MIG GPUs, but the scoring hot-spot is reduction-shaped, so the TPU
mapping tiles the *variant batch* dimension: each grid step holds a
(BLOCK_M, T) f32 tile of mu/sigma in VMEM (128x64x4 B = 32 KiB per
operand — far under the ~16 MiB VMEM budget, leaving room for
double-buffered streaming of large pools), computes with VPU-friendly
elementwise + row-reduction ops, and writes three [BLOCK_M] vectors.
There is no matmul, so the MXU is idle by design; the roofline is memory
bandwidth on the mu/sigma streams.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is exactly what
the rust runtime loads (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Rows per grid step. 128 keeps the tile VPU-aligned (8x128 lanes) and
# small enough to double-buffer.
BLOCK_M = 128


def _scoring_kernel(params_ref, mu_ref, sigma_ref, phi_ref, psi_ref, trust_ref,
                    hist_ref, valid_ref, score_ref_, viol_ref, head_ref):
    """Fused per-block scoring (same math as ref.score_ref)."""
    params = params_ref[...]
    capacity = params[0]
    theta = params[1]
    lam = params[2]
    alpha = params[3:7]
    beta = params[7:11]

    mu = mu_ref[...]
    sigma = sigma_ref[...]

    # 1) Safety: log-space survival product over bins.
    sig = jnp.maximum(sigma, ref.SIGMA_EPS)
    z = (capacity - mu) / sig
    log_surv = jnp.sum(jnp.log(ref.normal_cdf(z)), axis=-1)
    viol = jnp.clip(1.0 - jnp.exp(log_surv), 0.0, 1.0)

    # 2) Headroom.
    headroom = jnp.mean(jnp.clip((capacity - mu) / capacity, 0.0, 1.0), axis=-1)

    # 3) Calibrated job utility (Eqs. (2) + (5)).
    phi = phi_ref[...]
    h_tilde = phi @ alpha
    trust = trust_ref[...]
    h_cal = trust * h_tilde + (1.0 - trust) * hist_ref[...]

    # 4) System utility (Eq. (3) + age term of §4.3).
    psi = psi_ref[...]
    f_sys = beta[0] * psi[:, 0] + beta[1] * headroom + beta[2] * psi[:, 1] + beta[3] * psi[:, 2]

    # 5) Composite + eligibility/validity gating (Eq. (4)).
    score = lam * h_cal + (1.0 - lam) * f_sys
    eligible = (viol <= theta) & (valid_ref[...] > 0.0)
    score_ref_[...] = jnp.where(eligible, jnp.clip(score, 0.0, 1.0), 0.0)
    viol_ref[...] = viol
    head_ref[...] = headroom


@functools.partial(jax.jit, static_argnames=())
def score_pallas(mu, sigma, phi, psi, trust, hist, valid, params):
    """Score a padded variant batch with the Pallas kernel.

    Shapes: mu/sigma [M, T]; phi [M, 4]; psi [M, 3]; trust/hist/valid [M];
    params [11]. M must be a multiple of BLOCK_M.
    Returns (score [M], violation [M], headroom [M]).
    """
    m, t = mu.shape
    assert m % BLOCK_M == 0, f"M={m} must be a multiple of {BLOCK_M}"
    grid = (m // BLOCK_M,)
    vec = lambda: pl.BlockSpec((BLOCK_M,), lambda i: (i,))
    out_shape = [jax.ShapeDtypeStruct((m,), jnp.float32)] * 3
    return tuple(
        pl.pallas_call(
            _scoring_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((11,), lambda i: (0,)),      # params (replicated)
                pl.BlockSpec((BLOCK_M, t), lambda i: (i, 0)),  # mu
                pl.BlockSpec((BLOCK_M, t), lambda i: (i, 0)),  # sigma
                pl.BlockSpec((BLOCK_M, 4), lambda i: (i, 0)),  # phi
                pl.BlockSpec((BLOCK_M, 3), lambda i: (i, 0)),  # psi
                vec(),                                     # trust
                vec(),                                     # hist
                vec(),                                     # valid
            ],
            out_specs=[vec(), vec(), vec()],
            out_shape=out_shape,
            interpret=True,
        )(params, mu, sigma, phi, psi, trust, hist, valid)
    )
