"""AOT lowering tests: every artifact must lower to parseable HLO text
with the exact parameter/result shapes the rust runtime expects."""

import re

from compile import aot, model


class TestLowering:
    def test_all_artifacts_lower(self):
        for name, lower in aot.ARTIFACTS.items():
            text = lower()
            assert text.startswith("HloModule"), f"{name} is not HLO text"
            assert "ENTRY" in text, f"{name} lacks an entry computation"

    def test_scorer_signature(self):
        text = aot.lower_scorer()
        # 8 parameters with the staged shapes.
        m, t = model.M_PAD, model.T_BINS
        for shape in (
            f"f32[{m},{t}]",
            f"f32[{m},4]",
            f"f32[{m},3]",
            f"f32[{m}]",
            f"f32[{model.N_PARAMS}]",
        ):
            assert shape in text, f"missing {shape} in scorer HLO"
        # Tuple of three [M] outputs.
        assert re.search(rf"tuple\(.*f32\[{m}\].*f32\[{m}\].*f32\[{m}\]", text.replace("\n", " ")) or \
            f"(f32[{m}]" in text

    def test_calibrator_signature(self):
        text = aot.lower_calibrator()
        assert f"f32[{model.M_PAD},4]" in text
        assert "f32[4]" in text

    def test_safety_signature(self):
        text = aot.lower_safety()
        assert f"f32[{model.M_PAD},{model.T_BINS}]" in text

    def test_scorer_contains_no_custom_call(self):
        """interpret=True must lower to plain HLO the CPU PJRT can run —
        a Mosaic custom-call here would break the rust runtime."""
        text = aot.lower_scorer()
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower()
