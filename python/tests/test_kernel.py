"""Kernel-vs-oracle correctness: the CORE L1 signal.

The Pallas kernel (interpret mode) must match the pure-jnp reference on
every input in its domain; hypothesis sweeps shapes and value ranges.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref, scoring

PARAMS = np.array(
    [20.0, 0.05, 0.6, 0.45, 0.25, 0.15, 0.15, 0.45, 0.2, 0.15, 0.2], np.float32
)


def make_batch(rng, m, t, cap=20.0, theta=0.05, lam=0.6):
    mu = rng.uniform(0.5, cap * 0.95, (m, t)).astype(np.float32)
    sigma = rng.uniform(0.01, 3.0, (m, t)).astype(np.float32)
    phi = rng.uniform(0, 1, (m, 4)).astype(np.float32)
    psi = rng.uniform(0, 1, (m, 3)).astype(np.float32)
    trust = rng.uniform(0, 1, m).astype(np.float32)
    hist = rng.uniform(0, 1, m).astype(np.float32)
    valid = (rng.uniform(0, 1, m) > 0.15).astype(np.float32)
    params = PARAMS.copy()
    params[0], params[1], params[2] = cap, theta, lam
    return mu, sigma, phi, psi, trust, hist, valid, params


def assert_match(args, atol=2e-6):
    got = scoring.score_pallas(*args)
    want = ref.score_ref(*args)
    for g, w, name in zip(got, want, ["score", "violation", "headroom"]):
        np.testing.assert_allclose(
            np.array(g), np.array(w), atol=atol, err_msg=f"{name} mismatch"
        )


class TestKernelVsRef:
    def test_basic_block(self):
        rng = np.random.default_rng(0)
        assert_match(make_batch(rng, scoring.BLOCK_M, 64))

    def test_multi_block(self):
        rng = np.random.default_rng(1)
        assert_match(make_batch(rng, 4 * scoring.BLOCK_M, 64))

    @pytest.mark.parametrize("t", [1, 4, 16, 64, 128])
    def test_bin_counts(self, t):
        rng = np.random.default_rng(t)
        assert_match(make_batch(rng, scoring.BLOCK_M, t))

    @pytest.mark.parametrize("cap", [5.0, 10.0, 20.0, 40.0])
    def test_capacities(self, cap):
        rng = np.random.default_rng(int(cap))
        assert_match(make_batch(rng, scoring.BLOCK_M, 32, cap=cap))

    @pytest.mark.parametrize("lam", [0.0, 0.3, 0.5, 0.7, 1.0])
    def test_lambda_range(self, lam):
        rng = np.random.default_rng(int(lam * 10))
        assert_match(make_batch(rng, scoring.BLOCK_M, 32, lam=lam))

    @settings(max_examples=40, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        blocks=st.integers(1, 3),
        t=st.integers(1, 96),
        cap=st.floats(4.0, 40.0),
        theta=st.floats(0.001, 0.5),
        lam=st.floats(0.0, 1.0),
    )
    def test_hypothesis_sweep(self, seed, blocks, t, cap, theta, lam):
        rng = np.random.default_rng(seed)
        assert_match(
            make_batch(rng, blocks * scoring.BLOCK_M, t, cap=cap, theta=theta, lam=lam)
        )

    def test_degenerate_sigma_floor(self):
        """sigma == 0 hits the shared SIGMA_EPS floor in both paths."""
        m, t = scoring.BLOCK_M, 8
        mu = np.full((m, t), 4.0, np.float32)
        sigma = np.zeros((m, t), np.float32)
        ones = np.ones(m, np.float32)
        feat = np.full((m, 4), 0.5, np.float32)
        psi = np.full((m, 3), 0.5, np.float32)
        assert_match((mu, sigma, feat, psi, ones, ones * 0.5, ones, PARAMS))

    def test_padding_lanes_zeroed(self):
        rng = np.random.default_rng(3)
        args = list(make_batch(rng, scoring.BLOCK_M, 16))
        args[6] = np.zeros(scoring.BLOCK_M, np.float32)  # all invalid
        score, _, _ = scoring.score_pallas(*args)
        assert np.all(np.array(score) == 0.0)


class TestScoreSemantics:
    """Semantic invariants of the reference (and thus the kernel)."""

    def test_scores_in_unit_interval(self):
        rng = np.random.default_rng(7)
        score, viol, head = ref.score_ref(*make_batch(rng, 512, 64))
        assert np.all((np.array(score) >= 0) & (np.array(score) <= 1))
        assert np.all((np.array(viol) >= 0) & (np.array(viol) <= 1))
        assert np.all((np.array(head) >= 0) & (np.array(head) <= 1))

    def test_violation_monotone_in_capacity(self):
        rng = np.random.default_rng(8)
        args = list(make_batch(rng, 256, 32))
        p_small = args[7].copy()
        p_small[0] = 10.0
        p_big = args[7].copy()
        p_big[0] = 30.0
        _, v_small, _ = ref.score_ref(*args[:7], p_small)
        _, v_big, _ = ref.score_ref(*args[:7], p_big)
        assert np.all(np.array(v_big) <= np.array(v_small) + 1e-6)

    def test_unsafe_rows_get_zero_score(self):
        m, t = 128, 16
        mu = np.full((m, t), 19.9, np.float32)  # at capacity
        sigma = np.full((m, t), 2.0, np.float32)
        ones = np.ones(m, np.float32)
        feat = np.full((m, 4), 1.0, np.float32)
        psi = np.full((m, 3), 1.0, np.float32)
        score, viol, _ = ref.score_ref(mu, sigma, feat, psi, ones, ones, ones, PARAMS)
        assert np.all(np.array(viol) > 0.05)
        assert np.all(np.array(score) == 0.0)

    def test_calibration_pull(self):
        """Lower trust pulls the score toward the historical anchor."""
        m, t = 128, 8
        mu = np.full((m, t), 2.0, np.float32)
        sigma = np.full((m, t), 0.1, np.float32)
        feat = np.full((m, 4), 1.0, np.float32)  # declared perfect
        psi = np.zeros((m, 3), np.float32)
        hist = np.zeros(m, np.float32)  # history says otherwise
        valid = np.ones(m, np.float32)
        full = np.ones(m, np.float32)
        half = np.full(m, 0.5, np.float32)
        s_full, _, _ = ref.score_ref(mu, sigma, feat, psi, full, hist, valid, PARAMS)
        s_half, _, _ = ref.score_ref(mu, sigma, feat, psi, half, hist, valid, PARAMS)
        assert np.all(np.array(s_half) < np.array(s_full))

    def test_erf_against_numpy(self):
        from math import erf as math_erf

        xs = np.linspace(-5, 5, 201).astype(np.float32)
        got = np.array(ref.erf_as(xs))
        want = np.array([math_erf(float(x)) for x in xs])
        np.testing.assert_allclose(got, want, atol=5e-6)  # A&S error + f32 rounding


class TestModelHelpers:
    def test_calibrator_math(self):
        m = 16
        rng = np.random.default_rng(5)
        declared = rng.uniform(0, 1, (m, 4)).astype(np.float32)
        observed = rng.uniform(0, 1, (m, 4)).astype(np.float32)
        w = np.array([0.45, 0.25, 0.15, 0.15], np.float32) / 1.0
        prev_err = np.zeros(m, np.float32)
        prev_n = np.zeros(m, np.float32)
        eps, mean_err, rho = model.calibrator(declared, observed, w, prev_err, prev_n, 4.0)
        want_eps = np.sum(np.abs(declared - observed) * w, axis=-1)
        np.testing.assert_allclose(np.array(eps), want_eps, atol=1e-6)
        np.testing.assert_allclose(np.array(mean_err), want_eps, atol=1e-6)
        np.testing.assert_allclose(np.array(rho), np.exp(-4.0 * want_eps), rtol=1e-5)

    def test_calibrator_running_mean(self):
        declared = np.zeros((1, 4), np.float32)
        observed = np.ones((1, 4), np.float32)  # eps = 1
        w = np.full(4, 0.25, np.float32)
        # After 3 previous perfect verifications, mean goes 0 -> 1/4.
        eps, mean_err, _ = model.calibrator(
            declared, observed, w, np.zeros(1, np.float32), np.full(1, 3.0, np.float32), 1.0
        )
        assert abs(float(eps[0]) - 1.0) < 1e-6
        assert abs(float(mean_err[0]) - 0.25) < 1e-6

    def test_safety_standalone(self):
        m, t = 32, 16
        mu = np.full((m, t), 5.0, np.float32)
        sigma = np.full((m, t), 0.5, np.float32)
        safe = np.array(model.safety(mu, sigma, np.float32(10.0)))
        unsafe = np.array(model.safety(mu, sigma, np.float32(5.5)))
        assert np.all(safe < 1e-4)
        assert np.all(unsafe > 0.5)
