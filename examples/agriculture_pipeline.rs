//! Agriculture 4.0 scenario (the paper's motivating domain, §1).
//!
//! A farm-analytics tenant shares one MIG GPU with an AI-training tenant:
//! periodic, deadline-bound sensing/inference pipelines (`agri_pipeline`,
//! `inference_burst`) contend with long `train_small`/`train_large` jobs.
//! The question the paper's motivation poses: can the deadline-bound
//! pipelines meet their QoS while the trainers keep the GPU busy?
//!
//! We run JASDA in QoS-first mode (λ = 0.7, per Table 2) and compare
//! against FCFS on the identical workload.
//!
//! Run with: `cargo run --release --example agriculture_pipeline`

use jasda::baselines::{Discipline, MonolithicScheduler};
use jasda::config::SimConfig;
use jasda::jasda::JasdaScheduler;
use jasda::metrics::RunMetrics;
use jasda::sim::SimEngine;
use jasda::workload::WorkloadGenerator;

fn class_stats(m: &RunMetrics, class: &str) -> (usize, f64, f64) {
    let js: Vec<_> = m.jobs.iter().filter(|j| j.class == class).collect();
    let met = js.iter().filter(|j| j.deadline_met == Some(true)).count();
    let with_deadline = js.iter().filter(|j| j.deadline_met.is_some()).count();
    let jcts: Vec<f64> = js.iter().filter_map(|j| j.jct()).map(|x| x as f64).collect();
    let mean_jct = if jcts.is_empty() { f64::NAN } else { jcts.iter().sum::<f64>() / jcts.len() as f64 };
    let rate = if with_deadline == 0 { f64::NAN } else { met as f64 / with_deadline as f64 };
    (js.len(), rate, mean_jct)
}

fn report(label: &str, m: &RunMetrics) {
    println!("\n-- {label} --");
    println!("{}", m.summary());
    for class in ["agri_pipeline", "inference_burst", "train_small", "train_large"] {
        let (n, rate, jct) = class_stats(m, class);
        if n > 0 {
            println!(
                "  {class:<16} n={n:<3} deadline_rate={:<6} mean_jct={:.0}",
                if rate.is_nan() { "-".to_string() } else { format!("{rate:.2}") },
                jct
            );
        }
    }
}

fn main() {
    let mut cfg = SimConfig::default();
    cfg.seed = 2026;
    cfg.cluster.layout = "heterogeneous".into();
    cfg.workload.num_jobs = 50;
    cfg.workload.arrival_rate_per_sec = 0.35; // contended farm gateway
    cfg.workload.mix = vec![
        ("agri_pipeline".into(), 0.35),
        ("inference_burst".into(), 0.25),
        ("train_small".into(), 0.25),
        ("train_large".into(), 0.15),
    ];
    // QoS-first policy (paper Table 2, λ = 0.7).
    cfg.jasda.lambda = 0.7;

    let jobs = WorkloadGenerator::new(cfg.workload.clone()).generate(cfg.seed);
    println!(
        "Agriculture 4.0 scenario: {} jobs ({} with deadlines) on 1 MIG GPU",
        jobs.len(),
        jobs.iter().filter(|j| j.deadline.is_some()).count()
    );

    let jasda_out = SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(cfg.jasda.clone())))
        .run(jobs.clone());
    let fcfs_out = SimEngine::new(cfg, Box::new(MonolithicScheduler::new(Discipline::Fcfs)))
        .run(jobs);

    report("JASDA (QoS-first, λ=0.7)", &jasda_out.metrics);
    report("FCFS (monolithic)", &fcfs_out.metrics);

    let (_, jasda_rate, _) = class_stats(&jasda_out.metrics, "agri_pipeline");
    let (_, fcfs_rate, _) = class_stats(&fcfs_out.metrics, "agri_pipeline");
    println!(
        "\nagri_pipeline deadline adherence: JASDA {jasda_rate:.2} vs FCFS {fcfs_rate:.2} \
         (starvation: {} vs {})",
        jasda_out.metrics.max_starvation(),
        fcfs_out.metrics.max_starvation()
    );
}
