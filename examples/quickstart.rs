//! Quickstart: the smallest end-to-end JASDA run.
//!
//! Builds a one-GPU MIG cluster, generates a small mixed workload, runs
//! the JASDA scheduler, and prints headline metrics plus the scheduler's
//! internal interaction statistics.
//!
//! Run with: `cargo run --release --example quickstart`

use jasda::config::SimConfig;
use jasda::jasda::JasdaScheduler;
use jasda::sim::SimEngine;
use jasda::workload::WorkloadGenerator;

fn main() {
    // 1. Configure: one A100-class GPU in the 4g+2g+1g layout, 20 jobs.
    let mut cfg = SimConfig::default();
    cfg.seed = 42;
    cfg.cluster.num_gpus = 1;
    cfg.cluster.layout = "heterogeneous".into();
    cfg.workload.num_jobs = 20;

    // 2. Generate the workload (deterministic in the seed).
    let jobs = WorkloadGenerator::new(cfg.workload.clone()).generate(cfg.seed);
    println!("generated {} jobs:", jobs.len());
    for j in jobs.iter().take(5) {
        println!(
            "  job {:>2} [{}] arrival={} work={:.0} peak_mem={:.1} GiB atoms≈{:.0}",
            j.id,
            j.class,
            j.arrival,
            j.total_work(),
            j.trp.peak_mem_gb(),
            (j.total_work() / j.atom_work).ceil(),
        );
    }
    println!("  ... ({} more)\n", jobs.len().saturating_sub(5));

    // 3. Run the JASDA interaction cycle to completion.
    let scheduler = JasdaScheduler::new(cfg.jasda.clone());
    let out = SimEngine::new(cfg, Box::new(scheduler)).run(jobs);

    // 4. Report.
    let m = &out.metrics;
    println!("== result ==");
    println!("{}", m.summary());
    println!(
        "makespan {:.1}s  throughput {:.2} jobs/s  mean slowdown {:.2}  frag {:.3}",
        m.makespan as f64 / 1000.0,
        m.throughput_per_sec(),
        m.mean_slowdown().unwrap_or(f64::NAN),
        m.mean_fragmentation,
    );
    println!("scheduler stats: {}", out.scheduler_stats.to_string_pretty());
    assert_eq!(m.unfinished, 0, "quickstart must complete all jobs");
}
