//! End-to-end driver: the full system on a realistic workload.
//!
//! This is the headline experiment (EXPERIMENTS.md §E2E): a 4-GPU MIG
//! cluster serves a 200-job mixed trace under sustained contention; every
//! scheduler — JASDA plus all baselines — runs on the *identical* trace,
//! and the paper's headline metrics (utilization, JCT, fairness,
//! starvation, deadline adherence) are reported side by side. When the
//! AOT artifact is present, JASDA is additionally run with the
//! PJRT-executed L1/L2 scoring pipeline to prove all three layers compose
//! on the real decision path.
//!
//! Run with: `make artifacts && cargo run --release --example e2e_cluster_sim`

use jasda::baselines::{by_name, ALL_SCHEDULERS};
use jasda::config::SimConfig;
use jasda::jasda::JasdaScheduler;
use jasda::report::{comparison_headers, comparison_row, Table};
use jasda::runtime::PjrtScorer;
use jasda::sim::SimEngine;
use jasda::workload::WorkloadGenerator;

fn main() {
    let mut cfg = SimConfig::default();
    cfg.seed = 1;
    cfg.cluster.num_gpus = 4;
    cfg.cluster.layout = "heterogeneous".into();
    cfg.workload.num_jobs = 200;
    cfg.workload.arrival_rate_per_sec = 1.2; // ~1.5x offered load on 4 GPUs
    cfg.workload.misreport_fraction = 0.1;

    let jobs = WorkloadGenerator::new(cfg.workload.clone()).generate(cfg.seed);
    let total_work: f64 = jobs.iter().map(|j| j.total_work()).sum();
    println!(
        "e2e: {} jobs, {:.0}s of full-GPU work, {} GPUs ({} slices), seed {}",
        jobs.len(),
        total_work / 1000.0,
        cfg.cluster.num_gpus,
        cfg.cluster.num_gpus * 3,
        cfg.seed
    );

    let mut table = Table::new("End-to-end scheduler comparison", &comparison_headers());

    let t0 = std::time::Instant::now();
    for name in ALL_SCHEDULERS {
        let sched = by_name(name, &cfg.jasda).expect("known scheduler");
        let out = SimEngine::new(cfg.clone(), sched).run(jobs.clone());
        println!(
            "  ran {name:<12} makespan={:.0}s wall={:?}",
            out.metrics.makespan as f64 / 1000.0,
            t0.elapsed()
        );
        table.push_row(comparison_row(&out.metrics));
    }

    // Multi-window JASDA: one announced window per free slice each
    // iteration (ISSUE 1), the configuration a wide cluster wants.
    {
        let mut jcfg = cfg.jasda.clone();
        jcfg.announce_per_slice = true;
        let sched = JasdaScheduler::new(jcfg);
        let out = SimEngine::new(cfg.clone(), Box::new(sched)).run(jobs.clone());
        let mut row = comparison_row(&out.metrics);
        row[0] = "jasda(K=slices)".into();
        table.push_row(row);
        println!(
            "  ran jasda(K=slices) {:.2} commits/iter wall={:?}",
            out.metrics.commits_per_iteration(),
            t0.elapsed()
        );
    }

    // PJRT-backed JASDA (all three layers on the decision path). Skipped
    // cleanly when the artifact or the `pjrt` feature is absent.
    let artifact = jasda::runtime::artifacts_dir().join("scorer.hlo.txt");
    match PjrtScorer::load(&artifact) {
        Ok(scorer) => {
            let sched = JasdaScheduler::with_scorer(cfg.jasda.clone(), Box::new(scorer));
            let out = SimEngine::new(cfg.clone(), Box::new(sched)).run(jobs.clone());
            let mut row = comparison_row(&out.metrics);
            row[0] = "jasda(pjrt)".into();
            table.push_row(row);
            println!("  ran jasda(pjrt)  wall={:?}", t0.elapsed());
        }
        Err(e) => println!("  (skipping jasda(pjrt): {e})"),
    }

    println!("\n{}", table.to_markdown());
}
