//! End-to-end driver: the full system on a realistic workload.
//!
//! This is the headline experiment (EXPERIMENTS.md §E2E): a 4-GPU MIG
//! cluster serves a 200-job mixed trace under sustained contention; every
//! scheduler — JASDA plus all baselines — runs on the *identical* trace,
//! and the paper's headline metrics (utilization, JCT, fairness,
//! starvation, deadline adherence) are reported side by side. When the
//! AOT artifact is present, JASDA is additionally run with the
//! PJRT-executed L1/L2 scoring pipeline to prove all three layers compose
//! on the real decision path.
//!
//! Run with: `make artifacts && cargo run --release --example e2e_cluster_sim`

use jasda::baselines::{by_name, ALL_SCHEDULERS};
use jasda::config::SimConfig;
use jasda::jasda::JasdaScheduler;
use jasda::report::{comparison_headers, comparison_row, Table};
use jasda::runtime::PjrtScorer;
use jasda::sim::SimEngine;
use jasda::workload::WorkloadGenerator;

fn main() {
    let mut cfg = SimConfig::default();
    cfg.seed = 1;
    cfg.cluster.num_gpus = 4;
    cfg.cluster.layout = "heterogeneous".into();
    cfg.workload.num_jobs = 200;
    cfg.workload.arrival_rate_per_sec = 1.2; // ~1.5x offered load on 4 GPUs
    cfg.workload.misreport_fraction = 0.1;

    let jobs = WorkloadGenerator::new(cfg.workload.clone()).generate(cfg.seed);
    let total_work: f64 = jobs.iter().map(|j| j.total_work()).sum();
    println!(
        "e2e: {} jobs, {:.0}s of full-GPU work, {} GPUs ({} slices), seed {}",
        jobs.len(),
        total_work / 1000.0,
        cfg.cluster.num_gpus,
        cfg.cluster.num_gpus * 3,
        cfg.seed
    );

    let mut table = Table::new("End-to-end scheduler comparison", &comparison_headers());

    let t0 = std::time::Instant::now();
    for name in ALL_SCHEDULERS {
        let sched = by_name(name, &cfg.jasda).expect("known scheduler");
        let out = SimEngine::new(cfg.clone(), sched).run(jobs.clone());
        println!(
            "  ran {name:<12} makespan={:.0}s wall={:?}",
            out.metrics.makespan as f64 / 1000.0,
            t0.elapsed()
        );
        table.push_row(comparison_row(&out.metrics));
    }

    // PJRT-backed JASDA (all three layers on the decision path).
    let artifact = jasda::runtime::artifacts_dir().join("scorer.hlo.txt");
    if artifact.exists() {
        let scorer = PjrtScorer::load(&artifact).expect("artifact compiles");
        let sched = JasdaScheduler::with_scorer(cfg.jasda.clone(), Box::new(scorer));
        let out = SimEngine::new(cfg.clone(), Box::new(sched)).run(jobs.clone());
        let mut row = comparison_row(&out.metrics);
        row[0] = "jasda(pjrt)".into();
        table.push_row(row);
        println!("  ran jasda(pjrt)  wall={:?}", t0.elapsed());
    } else {
        println!("  (skipping jasda(pjrt): run `make artifacts` first)");
    }

    println!("\n{}", table.to_markdown());
}
