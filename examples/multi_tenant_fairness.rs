//! Multi-tenant fairness and trust calibration (paper §4.2.1, §4.3).
//!
//! Two demonstrations on one shared cluster:
//!
//! 1. **Misreporting** — 30% of jobs inflate their declared utilities by
//!    80%. With calibration ON, ex-post verification should drive their
//!    reliability ρ_J down and erase most of their stolen advantage;
//!    with calibration OFF, liars win more.
//! 2. **Age fairness** — with β_age = 0 (ablation) long-waiting jobs
//!    starve measurably longer than with the age term enabled.
//!
//! Run with: `cargo run --release --example multi_tenant_fairness`

use jasda::config::SimConfig;
use jasda::jasda::JasdaScheduler;
use jasda::metrics::RunMetrics;
use jasda::sim::SimEngine;
use jasda::workload::WorkloadGenerator;

/// Mean JCT of liars vs honest jobs (lower = advantaged).
fn liar_advantage(m: &RunMetrics, liars: &[bool]) -> (f64, f64) {
    let mut liar = (0.0, 0);
    let mut honest = (0.0, 0);
    for j in &m.jobs {
        if let Some(s) = j.slowdown() {
            if liars[j.job as usize] {
                liar = (liar.0 + s, liar.1 + 1);
            } else {
                honest = (honest.0 + s, honest.1 + 1);
            }
        }
    }
    (liar.0 / liar.1.max(1) as f64, honest.0 / honest.1.max(1) as f64)
}

fn main() {
    let mut cfg = SimConfig::default();
    cfg.seed = 7;
    cfg.cluster.layout = "balanced".into();
    cfg.workload.num_jobs = 60;
    cfg.workload.arrival_rate_per_sec = 0.4;
    cfg.workload.misreport_fraction = 0.3;
    cfg.workload.misreport_bias = 0.8;

    let jobs = WorkloadGenerator::new(cfg.workload.clone()).generate(cfg.seed);
    let liars: Vec<bool> = jobs.iter().map(|j| j.misreport_bias > 0.0).collect();
    println!(
        "{} jobs, {} misreporting (+80% declared utility)\n",
        jobs.len(),
        liars.iter().filter(|&&l| l).count()
    );

    // --- Part 1: calibration on vs off -----------------------------------
    let mut on = cfg.jasda.clone();
    on.calibration = true;
    let mut off = cfg.jasda.clone();
    off.calibration = false;

    let m_on = SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(on))).run(jobs.clone());
    let m_off = SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(off))).run(jobs.clone());

    let (liar_on, honest_on) = liar_advantage(&m_on.metrics, &liars);
    let (liar_off, honest_off) = liar_advantage(&m_off.metrics, &liars);
    println!("== trust calibration (§4.2.1) ==");
    println!(
        "calibration OFF: liar slowdown {liar_off:.2} vs honest {honest_off:.2} (ratio {:.2})",
        liar_off / honest_off
    );
    println!(
        "calibration ON : liar slowdown {liar_on:.2} vs honest {honest_on:.2} (ratio {:.2})",
        liar_on / honest_on
    );
    println!(
        "mean reliability rho after run: {:.3} (1.0 = fully trusted)",
        m_on.scheduler_stats.get("mean_rho").and_then(|j| j.as_f64()).unwrap_or(f64::NAN)
    );

    // --- Part 2: age-aware prioritization on vs off (§4.3) ----------------
    let mut aged = cfg.jasda.clone();
    aged.age_priority = true;
    let mut no_age = cfg.jasda.clone();
    no_age.age_priority = false;

    let m_aged =
        SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(aged))).run(jobs.clone());
    let m_no_age = SimEngine::new(cfg, Box::new(JasdaScheduler::new(no_age))).run(jobs);

    println!("\n== age-aware fairness (§4.3) ==");
    println!(
        "age term ON : max starvation {:>8}  p95 wait {:>8.0}  jain {:.3}",
        m_aged.metrics.max_starvation(),
        m_aged.metrics.p95_wait().unwrap_or(f64::NAN),
        m_aged.metrics.jain_fairness().unwrap_or(f64::NAN),
    );
    println!(
        "age term OFF: max starvation {:>8}  p95 wait {:>8.0}  jain {:.3}",
        m_no_age.metrics.max_starvation(),
        m_no_age.metrics.p95_wait().unwrap_or(f64::NAN),
        m_no_age.metrics.jain_fairness().unwrap_or(f64::NAN),
    );
}
