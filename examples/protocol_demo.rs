//! Bid–response protocol demo (paper §5.1(f): the "runtime
//! implementation pathway"), in its K-window form.
//!
//! Runs JASDA as an actual distributed negotiation: one leader thread
//! (announce → collect bids → clear ≤ K windows → award) and one agent
//! thread per job, exchanging only the protocol messages of
//! `coordinator::messages`. With `announce_k = 2` every round broadcasts
//! the cluster's candidate windows in a single `Announce`, each agent
//! answers with one `Bid` carrying a per-window variant portfolio
//! (planned once per window *shape*, stamped per window), and the leader
//! clears up to two windows with the same batched-scoring + per-window
//! WIS + cross-window-reconciliation engine the in-process scheduler
//! embeds — so one round can commit work on two slices at once while
//! still guaranteeing no job holds two overlapping reservations.
//!
//! The demo prints message-level statistics; the interesting ones for
//! K = 2 are `windows cleared > announcements` (multi-window rounds
//! actually happened) and `reconciliation conflicts` (cases where the
//! second window's best bids were filtered because their job already won
//! in the first window).
//!
//! Run with: `cargo run --release --example protocol_demo`

use jasda::config::SimConfig;
use jasda::coordinator::run_protocol;
use jasda::workload::WorkloadGenerator;

fn main() {
    let mut cfg = SimConfig::default();
    cfg.seed = 99;
    cfg.cluster.layout = "balanced".into();
    cfg.workload.num_jobs = 24;
    cfg.workload.arrival_rate_per_sec = 0.3;
    // K-window rounds: clear up to two windows per announcement cycle.
    cfg.jasda.announce_k = 2;

    let jobs = WorkloadGenerator::new(cfg.workload.clone()).generate(cfg.seed);
    println!(
        "protocol demo: {} job agents negotiating over {} slices, K = {}\n",
        jobs.len(),
        3 * cfg.cluster.num_gpus,
        cfg.jasda.announce_k,
    );

    let out = run_protocol(cfg, jobs, 2_000_000);

    println!("rounds                   {:>10}", out.rounds);
    println!("announce broadcasts      {:>10}", out.announcements);
    println!("windows cleared          {:>10}", out.windows_announced);
    println!("windows silent           {:>10}", out.windows_silent);
    println!("bid messages             {:>10}", out.bids);
    println!("variants proposed        {:>10}", out.variants);
    println!("awards granted           {:>10}", out.awards);
    println!("reconciliation conflicts {:>10}", out.cross_window_conflicts);
    println!("jobs completed           {:>7}/{}", out.completed_jobs, out.total_jobs);
    println!("virtual time             {:>9.1}s", out.final_time as f64 / 1000.0);
    println!("wall time                {:>10.2?}", out.wall);
    println!(
        "leader decision latency  {:>7.1}us/round (max {:.1}us)",
        out.decision_ns_per_round() / 1e3,
        out.max_round_decision_ns as f64 / 1e3,
    );
    println!(
        "\nmean variants/bid {:.2}, windows/announcement {:.2}, awards/window {:.2}",
        out.variants as f64 / out.bids.max(1) as f64,
        out.windows_announced as f64 / out.announcements.max(1) as f64,
        out.awards as f64 / out.windows_announced.max(1) as f64,
    );
    assert_eq!(out.completed_jobs, out.total_jobs, "protocol must complete all jobs");
}
