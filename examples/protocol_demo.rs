//! Bid–response protocol demo (paper §5.1(f): the "runtime
//! implementation pathway").
//!
//! Runs JASDA as an actual distributed negotiation: one leader thread
//! (announce → collect bids → clear → award) and one agent thread per
//! job, exchanging only the protocol messages of `coordinator::messages`.
//! Verifies the decentralized runtime reaches completion and reports
//! message-level statistics.
//!
//! Run with: `cargo run --release --example protocol_demo`

use jasda::config::SimConfig;
use jasda::coordinator::run_protocol;
use jasda::workload::WorkloadGenerator;

fn main() {
    let mut cfg = SimConfig::default();
    cfg.seed = 99;
    cfg.cluster.layout = "balanced".into();
    cfg.workload.num_jobs = 24;
    cfg.workload.arrival_rate_per_sec = 0.3;

    let jobs = WorkloadGenerator::new(cfg.workload.clone()).generate(cfg.seed);
    println!(
        "protocol demo: {} job agents negotiating over {} slices\n",
        jobs.len(),
        3 * cfg.cluster.num_gpus
    );

    let out = run_protocol(cfg, jobs, 2_000_000);

    println!("rounds            {:>10}", out.rounds);
    println!("announcements     {:>10}", out.announcements);
    println!("bid messages      {:>10}", out.bids);
    println!("variants proposed {:>10}", out.variants);
    println!("awards granted    {:>10}", out.awards);
    println!("jobs completed    {:>7}/{}", out.completed_jobs, out.total_jobs);
    println!("virtual time      {:>9.1}s", out.final_time as f64 / 1000.0);
    println!("wall time         {:>10.2?}", out.wall);
    println!(
        "\nmean variants/bid {:.2}, awards/announcement {:.2}",
        out.variants as f64 / out.bids.max(1) as f64,
        out.awards as f64 / out.announcements.max(1) as f64
    );
    assert_eq!(out.completed_jobs, out.total_jobs, "protocol must complete all jobs");
}
