//! Property-based tests over the DESIGN.md §6 invariants.
//!
//! No proptest in the offline environment, so these are hand-rolled
//! property loops driven by the deterministic `sim::Rng`: each test
//! generates hundreds of random cases and asserts the invariant; failing
//! seeds are printed so cases can be replayed.

use jasda::config::JasdaConfig;
use jasda::jasda::calibration::Calibration;
use jasda::jasda::clearing::{select_best_compatible, WisItem};
use jasda::jasda::scoring::{NativeScorer, ScoreBatch, ScorerBackend};
use jasda::jasda::{JasdaScheduler, WindowSelector};
use jasda::job::variants::generate_variants;
use jasda::job::{Job, JobSet, JobState};
use jasda::mig::{Cluster, PartitionLayout, Reservation, Timeline, Window};
use jasda::sim::{Rng, Scheduler};
use jasda::trp::{Phase, Trp};
use jasda::types::{Interval, Time};

/// Exhaustive WIS reference (exponential, n <= 14).
fn brute_force(items: &[WisItem]) -> f64 {
    let m = items.len();
    let mut best = 0.0f64;
    'subset: for mask in 0u32..(1 << m) {
        let mut total = 0.0;
        for i in 0..m {
            if mask & (1 << i) != 0 {
                for j in 0..i {
                    if mask & (1 << j) != 0
                        && items[i].interval.overlaps(&items[j].interval)
                    {
                        continue 'subset;
                    }
                }
                total += items[i].score;
            }
        }
        best = best.max(total);
    }
    best
}

#[test]
fn prop_wis_optimal_and_feasible() {
    let mut rng = Rng::new(0xA11CE);
    for case in 0..400 {
        let n = 1 + rng.index(14);
        let items: Vec<WisItem> = (0..n)
            .map(|_| {
                let s = rng.below(200);
                WisItem {
                    interval: Interval::new(s, s + 1 + rng.below(60)),
                    score: rng.uniform(),
                }
            })
            .collect();
        let sol = select_best_compatible(&items);
        // Optimality.
        let best = brute_force(&items);
        assert!(
            (sol.total_score - best).abs() < 1e-9,
            "case {case}: dp {} vs brute {best}: {items:?}",
            sol.total_score
        );
        // Feasibility + consistency.
        for i in 0..sol.selected.len() {
            for j in 0..i {
                assert!(!items[sol.selected[i]]
                    .interval
                    .overlaps(&items[sol.selected[j]].interval));
            }
        }
        let sum: f64 = sol.selected.iter().map(|&i| items[i].score).sum();
        assert!((sum - sol.total_score).abs() < 1e-9);
    }
}

#[test]
fn prop_timeline_never_overlaps_and_coalesces() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..300 {
        let mut tl = Timeline::new();
        let mut accepted: Vec<Interval> = Vec::new();
        for k in 0..40 {
            let s = rng.below(2_000);
            let iv = Interval::new(s, s + 1 + rng.below(100));
            let free = tl.is_free(&iv);
            let expect_free = accepted.iter().all(|a| !a.overlaps(&iv));
            assert_eq!(free, expect_free, "case {case}.{k}: is_free disagrees with model");
            let r = tl.reserve(Reservation { job: k, subjob_seq: 0, interval: iv });
            assert_eq!(r.is_ok(), expect_free);
            if r.is_ok() {
                accepted.push(iv);
            }
        }
        // Sorted, pairwise disjoint.
        let entries = tl.entries();
        for w in entries.windows(2) {
            assert!(w[0].interval.start <= w[1].interval.start);
            assert!(!w[0].interval.overlaps(&w[1].interval));
        }
        // Idle gaps + busy ticks partition the horizon.
        let busy = tl.busy_ticks(0, 3_000);
        let idle: u64 =
            tl.idle_gaps(0, 3_000, 1).iter().map(|g| g.interval.len()).sum();
        assert_eq!(busy + idle, 3_000, "case {case}: busy+idle must cover horizon");
    }
}

#[test]
fn prop_scores_normalized_when_weights_are() {
    let mut rng = Rng::new(0xCAFE);
    let mut scorer = NativeScorer;
    for case in 0..200 {
        // Random normalized weights.
        let mut alpha = [rng.uniform() as f32; 4];
        for a in alpha.iter_mut() {
            *a = rng.uniform() as f32;
        }
        let asum: f32 = alpha.iter().sum();
        for a in alpha.iter_mut() {
            *a /= asum.max(1.0); // Σα ≤ 1
        }
        let mut beta = [0.0f32; 4];
        for b in beta.iter_mut() {
            *b = rng.uniform() as f32;
        }
        let bsum: f32 = beta.iter().sum();
        for b in beta.iter_mut() {
            *b /= bsum.max(1.0);
        }

        let mut batch = ScoreBatch::with_bins(8);
        batch.capacity = rng.uniform_range(5.0, 40.0) as f32;
        batch.theta = rng.uniform_range(0.01, 0.3) as f32;
        batch.lambda = rng.uniform() as f32;
        batch.alpha = alpha;
        batch.beta = beta;
        for _ in 0..16 {
            let mu: Vec<f64> = (0..8).map(|_| rng.uniform_range(0.5, 45.0)).collect();
            let sigma: Vec<f64> = (0..8).map(|_| rng.uniform_range(0.0, 3.0)).collect();
            batch.push(
                &mu,
                &sigma,
                [rng.uniform(), rng.uniform(), rng.uniform(), rng.uniform()],
                [rng.uniform(), rng.uniform(), rng.uniform()],
                rng.uniform(),
                rng.uniform(),
            );
        }
        let out = scorer.score(&batch).unwrap();
        for i in 0..batch.m {
            assert!(
                (0.0..=1.0).contains(&out.score[i]),
                "case {case}: score {} out of [0,1]",
                out.score[i]
            );
            assert!((0.0..=1.0).contains(&out.violation[i]));
            assert!((0.0..=1.0).contains(&out.headroom[i]));
            if !out.eligible[i] {
                assert_eq!(out.score[i], 0.0);
            }
        }
    }
}

#[test]
fn prop_reliability_bounds_and_monotonicity() {
    let mut rng = Rng::new(0xD00D);
    for _case in 0..200 {
        let kappa = rng.uniform_range(0.5, 10.0);
        let mut cal = Calibration::new(1, kappa, 0.7, [0.45, 0.25, 0.15, 0.15]);
        let mut last_rho = 1.0;
        let constant_err = rng.uniform();
        for _ in 0..30 {
            // Feed a constant per-feature error: mean error converges to
            // it, so rho must be non-increasing.
            let declared = [constant_err, 0.5, constant_err, 0.5];
            let observed = [0.0, 0.5, 0.0, 0.5];
            cal.verify(0, &declared, &observed, 0.4);
            let t = cal.trust(0);
            assert!(t.rho > 0.0 && t.rho <= 1.0, "rho out of (0,1]: {}", t.rho);
            assert!(t.rho <= last_rho + 1e-12, "rho increased under constant error");
            assert!((0.0..=1.0).contains(&t.mean_error));
            assert!((0.0..=1.0).contains(&t.hist_avg));
            last_rho = t.rho;
        }
    }
}

#[test]
fn prop_generated_variants_always_eligible() {
    let mut rng = Rng::new(0xF00D);
    let cfg = JasdaConfig { fmp_bins: 16, tau_min: 50, ..JasdaConfig::default() };
    for case in 0..300 {
        let work = rng.uniform_range(200.0, 20_000.0);
        let mem = rng.uniform_range(0.5, 18.0);
        let noise = mem * rng.uniform_range(0.02, 0.2);
        let trp = Trp {
            phases: vec![
                Phase::new(work * 0.3, mem * 0.8, noise, rng.uniform()),
                Phase::new(work * 0.7, mem, noise, rng.uniform() * 0.3),
            ],
            duration_cv: rng.uniform_range(0.0, 0.2),
        };
        let mut job = Job::new(0, "p", 0, trp, None, 1.0, work * rng.uniform_range(0.1, 0.6), 0.0);
        job.state = JobState::Active;
        job.done_work = work * rng.uniform() * 0.8;

        let cap = [5.0, 10.0, 20.0, 40.0][rng.index(4)];
        let speed = [1.0 / 7.0, 2.0 / 7.0, 3.0 / 7.0, 4.0 / 7.0, 1.0][rng.index(5)];
        let start = rng.below(10_000);
        let len = 1 + rng.below(30_000);
        let window = Window {
            slice: 3,
            capacity_gb: cap,
            speed,
            interval: Interval::new(start, start + len),
        };

        let vs = generate_variants(&job, &window, &cfg);
        let mut prev_end = window.t_min();
        for (k, v) in vs.iter().enumerate() {
            assert!(
                window.interval.contains(&v.interval),
                "case {case}.{k}: variant escapes window"
            );
            assert!(v.duration() >= cfg.tau_min, "case {case}.{k}: below tau_min");
            assert!(
                v.violation_prob <= cfg.theta + 1e-12,
                "case {case}.{k}: unsafe variant emitted"
            );
            assert!(v.work <= job.pending_work() + 1e-6);
            assert!(v.declared.h_tilde >= 0.0 && v.declared.h_tilde <= 1.0);
            assert!(v.sys.util > 0.0 && v.sys.util <= 1.0);
            assert!(v.sys.frag >= 0.0 && v.sys.frag <= 1.0);
            // Chain variants are ordered and non-overlapping.
            if v.work_offset > 0.0 {
                assert!(v.interval.start >= prev_end, "case {case}.{k}: chain overlap");
            }
            prev_end = prev_end.max(v.interval.end);
        }
        assert!(vs.len() <= cfg.max_variants_per_job + 1);
    }
}

#[test]
fn prop_fmp_violation_monotone_in_capacity_and_sigma() {
    let mut rng = Rng::new(0x5EED);
    for _ in 0..300 {
        let mem = rng.uniform_range(1.0, 30.0);
        let trp = Trp {
            phases: vec![Phase::new(
                1000.0,
                mem,
                mem * rng.uniform_range(0.01, 0.3),
                rng.uniform(),
            )],
            duration_cv: 0.1,
        };
        let fmp = trp.fmp_bins(0.0, 1000.0, 16);
        let caps = [mem * 0.8, mem * 1.05, mem * 1.3, mem * 2.0];
        let viols: Vec<f64> = caps.iter().map(|&c| fmp.violation_prob(c)).collect();
        for w in viols.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "violation not monotone in capacity: {viols:?}");
        }
        for &v in &viols {
            assert!((0.0..=1.0).contains(&v));
        }
    }
}

#[test]
fn prop_age_factor_bounds_and_reset() {
    let mut rng = Rng::new(0xA6E);
    for _ in 0..200 {
        let arrival = rng.below(10_000);
        let trp = Trp { phases: vec![Phase::new(100.0, 1.0, 0.1, 0.0)], duration_cv: 0.0 };
        let mut job = Job::new(0, "a", arrival, trp, None, 1.0, 50.0, 0.0);
        let scale = 1 + rng.below(100_000);
        let mut last = 0.0;
        let mut t = arrival;
        for _ in 0..20 {
            t += rng.below(20_000);
            let a = job.age_factor(t, scale);
            assert!((0.0..=1.0).contains(&a));
            assert!(a + 1e-12 >= last, "age must be non-decreasing while unselected");
            last = a;
        }
        // Selection resets the clock.
        job.last_selected = t;
        assert_eq!(job.age_factor(t, scale), 0.0);
    }
}

// ---------------------------------------------------------------------
// K-window announcement/clearing invariants (DESIGN.md §6 + ISSUE 1).
// ---------------------------------------------------------------------

/// Random mid-run cluster state: a stock layout with a handful of
/// non-overlapping reservations sprinkled over the slices, plus an
/// active job population with varied memory footprints and progress.
fn random_state(rng: &mut Rng) -> (Cluster, JobSet, Time) {
    let layout = match rng.index(3) {
        0 => PartitionLayout::balanced(),
        1 => PartitionLayout::seven_small(),
        _ => PartitionLayout::heterogeneous(),
    };
    let mut cluster = Cluster::new(1 + rng.below(2) as u32, &layout);
    let now: Time = rng.below(5_000);
    for i in 0..cluster.num_slices() {
        for k in 0..rng.index(4) {
            let s = now + rng.below(8_000);
            let iv = Interval::new(s, s + 100 + rng.below(2_000));
            // Overlapping draws are simply skipped; the timeline stays valid.
            let _ = cluster.slice_mut(i as u32).timeline.reserve(Reservation {
                job: 90_000 + k as u32,
                subjob_seq: 0,
                interval: iv,
            });
        }
    }
    let n = 2 + rng.index(6);
    let jobs: Vec<Job> = (0..n as u32)
        .map(|id| {
            let work = rng.uniform_range(500.0, 8_000.0);
            let mem = rng.uniform_range(1.0, 16.0);
            let trp = Trp {
                phases: vec![
                    Phase::new(work * 0.4, mem * 0.8, mem * 0.05, 0.3),
                    Phase::new(work * 0.6, mem, mem * 0.05, 0.1),
                ],
                duration_cv: 0.08,
            };
            let mut j = Job::new(id, "p", 0, trp, None, 1.0, work / 4.0, 0.0);
            j.state = JobState::Active;
            j.done_work = work * rng.uniform() * 0.5;
            j
        })
        .collect();
    (cluster, JobSet::new(jobs), now)
}

/// Faithful replica of the seed's single-window `iterate` (announce one
/// window, retry silent windows, scalar-capacity scoring, one WIS pass),
/// returning the decision tuple per commitment.
fn reference_single_window_iterate(
    cfg: &JasdaConfig,
    cluster: &Cluster,
    jobs: &JobSet,
    now: Time,
) -> Vec<(u32, u32, Interval, f64, f64)> {
    let mut selector = WindowSelector::new();
    let cal = Calibration::new(jobs.len(), cfg.kappa, cfg.gamma, cfg.alpha.as_array());
    let from = now + cfg.announce_lead;
    let mut candidates =
        cluster.candidate_windows(from, cfg.announce_horizon, cfg.tau_min);
    let (window, pool) = loop {
        let idx = match selector.select(
            cfg.window_policy,
            &candidates,
            cluster,
            now,
            cfg.announce_horizon,
        ) {
            Some(i) => i,
            None => return vec![],
        };
        let window = candidates.swap_remove(idx);
        let mut pool = Vec::new();
        for job in jobs.bidders() {
            pool.extend(generate_variants(job, &window, cfg));
        }
        if !pool.is_empty() {
            break (window, pool);
        }
    };

    let mut batch = ScoreBatch::with_bins(cfg.fmp_bins);
    batch.capacity = window.capacity_gb as f32;
    batch.theta = cfg.theta as f32;
    batch.lambda = cfg.lambda as f32;
    let alpha = cfg.alpha.as_array();
    let beta = cfg.beta.as_array();
    batch.alpha = [alpha[0] as f32, alpha[1] as f32, alpha[2] as f32, alpha[3] as f32];
    batch.beta = [beta[0] as f32, beta[1] as f32, beta[2] as f32, beta[3] as f32];
    for v in &pool {
        let job = jobs.get(v.job);
        let age = if cfg.age_priority { job.age_factor(now, cfg.age_scale) } else { 0.0 };
        let (trust, hist) = if cfg.calibration {
            (cal.trust_weight(v.job), cal.hist_avg(v.job))
        } else {
            (1.0, 0.0)
        };
        batch.push(
            &v.fmp.mu,
            &v.fmp.sigma,
            [v.declared.phi[0], v.declared.phi[1], v.declared.phi[2], v.declared.phi[3]],
            [v.sys.util, v.sys.frag, age],
            trust,
            hist,
        );
    }
    let out = NativeScorer.score(&batch).expect("native scorer");

    let wlen = window.delta_t().max(1) as f64;
    let mut items = Vec::new();
    let mut item_to_pool = Vec::new();
    for (i, v) in pool.iter().enumerate() {
        if out.eligible[i] && out.score[i] > 0.0 {
            let w = if cfg.duration_weighted_clearing {
                v.duration() as f64 / wlen
            } else {
                1.0
            };
            items.push(WisItem { interval: v.interval, score: out.score[i] as f64 * w });
            item_to_pool.push(i);
        }
    }
    let sol = select_best_compatible(&items);
    sol.selected
        .iter()
        .map(|&k| {
            let v = &pool[item_to_pool[k]];
            (v.job, v.slice, v.interval, v.work, out.score[item_to_pool[k]] as f64)
        })
        .collect()
}

#[test]
fn prop_k1_bit_identical_to_single_window_reference() {
    // ISSUE 1 invariant (c): with announce_k = 1 the K-window scheduler
    // makes exactly the decisions of the seed's single-window loop —
    // same variants, same scores (bit-identical f32 pipeline), same WIS
    // selection, in the same order.
    let mut rng = Rng::new(0x51C1);
    for case in 0..60 {
        let (cluster, mut jobs, now) = random_state(&mut rng);
        let cfg = JasdaConfig { fmp_bins: 16, ..JasdaConfig::default() };
        assert_eq!(cfg.announce_k, 1, "default must preserve the paper loop");
        let expect = reference_single_window_iterate(&cfg, &cluster, &jobs, now);

        let mut sched = JasdaScheduler::new(cfg);
        let mut srng = Rng::new(1);
        let got = sched.iterate(now, &cluster, &mut jobs, &mut srng);

        assert_eq!(got.len(), expect.len(), "case {case}: commitment count");
        for (c, e) in got.iter().zip(&expect) {
            assert_eq!(c.job, e.0, "case {case}: job");
            assert_eq!(c.slice, e.1, "case {case}: slice");
            assert_eq!(c.interval, e.2, "case {case}: interval");
            assert_eq!(c.work, e.3, "case {case}: work must be bit-identical");
            assert_eq!(c.score, e.4, "case {case}: score must be bit-identical");
        }
    }
}

#[test]
fn prop_multi_window_commitments_are_conflict_free() {
    // ISSUE 1 invariants (a) + (b): across every announced window of one
    // iteration, (a) no two commitments on the same slice overlap (and
    // none overlaps an existing reservation), and (b) no job receives
    // two temporally overlapping reservations on different slices.
    let mut rng = Rng::new(0x4B17);
    for case in 0..80 {
        let (cluster, mut jobs, now) = random_state(&mut rng);
        let mut cfg = JasdaConfig { fmp_bins: 16, ..JasdaConfig::default() };
        match rng.index(3) {
            0 => cfg.announce_k = 2,
            1 => cfg.announce_k = 4,
            _ => cfg.announce_per_slice = true,
        }
        let mut sched = JasdaScheduler::new(cfg);
        let mut srng = Rng::new(2);
        let commits = sched.iterate(now, &cluster, &mut jobs, &mut srng);

        for (i, a) in commits.iter().enumerate() {
            assert!(a.interval.start >= now, "case {case}: commitment in the past");
            assert!(
                cluster.slice(a.slice).timeline.is_free(&a.interval),
                "case {case}: commitment overlaps an existing reservation"
            );
            for b in commits.iter().skip(i + 1) {
                if a.slice == b.slice {
                    assert!(
                        !a.interval.overlaps(&b.interval),
                        "case {case}: slice {} double-booked: {} vs {}",
                        a.slice,
                        a.interval,
                        b.interval
                    );
                }
                if a.job == b.job {
                    assert!(
                        !a.interval.overlaps(&b.interval),
                        "case {case}: job {} holds concurrent subjobs: {} vs {}",
                        a.job,
                        a.interval,
                        b.interval
                    );
                }
            }
        }
    }
}

#[test]
fn multi_window_clears_more_than_single_window_on_burst() {
    // Deterministic decision-round throughput: an idle 3-slice cluster,
    // 8 contending jobs, and windows short enough that one window can
    // only hold one chunk. K=1 can commit work on a single slice; the
    // per-slice mode must commit on several slices in the same round.
    let mk_jobs = || -> JobSet {
        JobSet::new(
            (0..8u32)
                .map(|id| {
                    let trp = Trp {
                        phases: vec![Phase::new(5_000.0, 4.0, 0.2, 0.1)],
                        duration_cv: 0.05,
                    };
                    let mut j =
                        Job::new(id, "b", 0, trp, None, 1.0, 250.0 + id as f64, 0.0);
                    j.state = JobState::Active;
                    j
                })
                .collect(),
        )
    };
    let cluster = Cluster::new(1, &PartitionLayout::balanced());
    let cfg = |per_slice: bool| JasdaConfig {
        fmp_bins: 16,
        announce_horizon: 1_000,
        announce_per_slice: per_slice,
        ..JasdaConfig::default()
    };

    let mut rng = Rng::new(3);
    let mut jobs1 = mk_jobs();
    let mut s1 = JasdaScheduler::new(cfg(false));
    let c1 = s1.iterate(0, &cluster, &mut jobs1, &mut rng);
    assert!(!c1.is_empty(), "single-window round must commit something");

    let mut jobs_k = mk_jobs();
    let mut sk = JasdaScheduler::new(cfg(true));
    let ck = sk.iterate(0, &cluster, &mut jobs_k, &mut rng);
    assert!(
        ck.len() > c1.len(),
        "per-slice round must out-commit K=1: {} vs {}",
        ck.len(),
        c1.len()
    );
    let mut slices: Vec<u32> = ck.iter().map(|c| c.slice).collect();
    slices.sort_unstable();
    slices.dedup();
    assert!(slices.len() >= 2, "per-slice round must touch several slices");
    // And the round stays conflict-free per job.
    for (i, a) in ck.iter().enumerate() {
        for b in ck.iter().skip(i + 1) {
            if a.job == b.job {
                assert!(!a.interval.overlaps(&b.interval));
            }
        }
    }
}

// ---------------------------------------------------------------------
// Incremental gap index + parallel clearing invariants (ISSUE 2).
// ---------------------------------------------------------------------

#[test]
fn prop_gap_index_matches_recompute_under_mutation() {
    // Arbitrary interleavings of reserve / release / truncate / compact
    // must leave the incremental gap index answering every query exactly
    // like a fresh full-timeline recompute (`idle_gaps_scan`).
    let mut rng = Rng::new(0x6A71);
    for case in 0..120 {
        let mut tl = Timeline::new();
        let mut live: Vec<(u32, u32)> = Vec::new();
        let mut next_seq = 0u32;
        for step in 0..50 {
            match rng.index(10) {
                0..=4 => {
                    // Reserve a random interval (overlaps simply fail).
                    let s = rng.below(5_000);
                    let iv = Interval::new(s, s + 1 + rng.below(400));
                    let r = Reservation { job: 7, subjob_seq: next_seq, interval: iv };
                    if tl.reserve(r).is_ok() {
                        live.push((7, next_seq));
                        next_seq += 1;
                    }
                }
                5 | 6 => {
                    // Release (completion / repack) a random reservation.
                    if !live.is_empty() {
                        let k = rng.index(live.len());
                        let (j, s) = live.swap_remove(k);
                        assert!(tl.release(j, s).is_some());
                    }
                }
                7 | 8 => {
                    // Truncate (early finish) a random reservation.
                    if !live.is_empty() {
                        let k = rng.index(live.len());
                        let (j, s) = live[k];
                        let iv = tl
                            .entries()
                            .iter()
                            .find(|r| r.job == j && r.subjob_seq == s)
                            .map(|r| r.interval)
                            .unwrap();
                        if iv.len() > 1 {
                            let new_end = iv.start + 1 + rng.below(iv.len() - 1);
                            assert!(tl.truncate(j, s, new_end));
                        }
                    }
                }
                _ => {
                    // History compaction.
                    let t = rng.below(6_000);
                    tl.compact_before(t);
                    live.retain(|&(j, s)| {
                        tl.entries().iter().any(|r| r.job == j && r.subjob_seq == s)
                    });
                }
            }
            // Index-backed queries vs full recompute on random spans.
            for _ in 0..3 {
                let from = rng.below(6_000);
                let to = from + rng.below(6_000);
                let min_len = 1 + rng.below(300);
                assert_eq!(
                    tl.idle_gaps(from, to, min_len),
                    tl.idle_gaps_scan(from, to, min_len),
                    "case {case} step {step}: index != recompute for [{from},{to}) min {min_len}"
                );
                let tau = 1 + rng.below(400);
                let expect = tl
                    .idle_gaps_scan(from, to, 1)
                    .iter()
                    .filter(|g| g.interval.len() < tau)
                    .count();
                assert_eq!(
                    tl.count_unusable_residues(from, to, tau),
                    expect,
                    "case {case} step {step}: residue count for [{from},{to}) tau {tau}"
                );
            }
        }
    }
}

/// A contended wide state: enough bidders and windows that every
/// fan-out stage of the parallel pipeline (plan generation, scoring row
/// chunks, speculative per-window WIS with reconciliation replays)
/// actually crosses its thread-gate thresholds.
fn wide_state() -> (Cluster, JobSet) {
    let mut cluster = Cluster::new(1, &PartitionLayout::seven_small());
    let mut seq = 0u32;
    for slice in 0..7u32 {
        if slice % 2 == 0 {
            let s = 500 + 97 * slice as u64;
            cluster
                .slice_mut(slice)
                .timeline
                .reserve(Reservation {
                    job: 90_000,
                    subjob_seq: seq,
                    interval: Interval::new(s, s + 400),
                })
                .unwrap();
            seq += 1;
        }
    }
    let jobs: Vec<Job> = (0..40u32)
        .map(|id| {
            let work = 2_000.0 + 50.0 * id as f64;
            let mem = 1.0 + (id % 4) as f64;
            let trp = Trp {
                phases: vec![Phase::new(work, mem, 0.1, 0.1)],
                duration_cv: 0.05,
            };
            let mut j = Job::new(id, "p", 0, trp, None, 1.0, work / 6.0, 0.0);
            j.state = JobState::Active;
            j
        })
        .collect();
    (cluster, JobSet::new(jobs))
}

#[test]
fn prop_parallel_clearing_bit_identical_to_serial() {
    // ISSUE 2 invariant: the parallel K-window clearing pipeline makes
    // exactly the serial path's decisions — same commitments, same
    // work/score floats — for K in {1, 2, per-slice}.
    for (k, per_slice) in [(1usize, false), (2, false), (1, true)] {
        let cfg_for = |threads: usize| JasdaConfig {
            fmp_bins: 16,
            announce_k: k,
            announce_per_slice: per_slice,
            parallel: threads,
            ..JasdaConfig::default()
        };

        let (cluster_a, mut jobs_a) = wide_state();
        let mut serial = JasdaScheduler::new(cfg_for(1));
        let mut rng_a = Rng::new(5);
        let ca = serial.iterate(0, &cluster_a, &mut jobs_a, &mut rng_a);

        let (cluster_b, mut jobs_b) = wide_state();
        let mut parallel = JasdaScheduler::new(cfg_for(8));
        let mut rng_b = Rng::new(5);
        let cb = parallel.iterate(0, &cluster_b, &mut jobs_b, &mut rng_b);

        assert!(!ca.is_empty(), "K={k} per_slice={per_slice}: scenario must commit work");
        assert_eq!(ca.len(), cb.len(), "K={k} per_slice={per_slice}: commitment count");
        for (a, b) in ca.iter().zip(&cb) {
            assert_eq!(a.job, b.job, "K={k} per_slice={per_slice}");
            assert_eq!(a.slice, b.slice, "K={k} per_slice={per_slice}");
            assert_eq!(a.interval, b.interval, "K={k} per_slice={per_slice}");
            assert_eq!(a.work, b.work, "K={k} per_slice={per_slice}: work bits");
            assert_eq!(a.score, b.score, "K={k} per_slice={per_slice}: score bits");
            assert_eq!(a.window_len, b.window_len, "K={k} per_slice={per_slice}");
        }
        // Job-side bookkeeping advanced identically too.
        for (ja, jb) in jobs_a.iter().zip(jobs_b.iter()) {
            assert_eq!(ja.bids_submitted, jb.bids_submitted, "bids_submitted diverged");
        }
    }
}

#[test]
fn prop_parallel_full_runs_bit_identical() {
    // End-to-end: whole simulations under serial vs parallel clearing
    // (random mid-sized states, every announcement mode) agree on the
    // decision-visible metrics.
    let mut rng = Rng::new(0x9A12);
    for case in 0..6 {
        let per_slice = case % 2 == 0;
        let k = 1 + rng.index(3);
        let run = |threads: usize| {
            let mut c = jasda::config::SimConfig::default();
            c.seed = 1000 + case as u64;
            c.cluster.layout = "balanced".into();
            c.engine.iteration_period = 25;
            c.jasda.fmp_bins = 16;
            c.jasda.announce_k = k;
            c.jasda.announce_per_slice = per_slice;
            c.jasda.parallel = threads;
            let jobs: Vec<Job> = (0..10u32)
                .map(|i| {
                    let work = 1_500.0 + 100.0 * i as f64;
                    let trp = Trp {
                        phases: vec![
                            Phase::new(work * 0.3, 4.0, 0.2, 0.4),
                            Phase::new(work * 0.7, 6.0, 0.3, 0.1),
                        ],
                        duration_cv: 0.08,
                    };
                    Job::new(i, "t", (i as u64) * 150, trp, None, 1.0, work / 4.0, 0.0)
                })
                .collect();
            let sched = JasdaScheduler::new(c.jasda.clone());
            jasda::sim::SimEngine::new(c, Box::new(sched)).run(jobs).metrics
        };
        let serial = run(1);
        let parallel = run(6);
        assert_eq!(serial.makespan, parallel.makespan, "case {case} K={k} ps={per_slice}");
        assert_eq!(
            serial.total_commits, parallel.total_commits,
            "case {case} K={k} ps={per_slice}"
        );
        assert_eq!(serial.mean_jct(), parallel.mean_jct(), "case {case}");
        assert_eq!(serial.unfinished, 0, "case {case}: runs must complete");
    }
}

#[test]
fn prop_rng_fork_streams_do_not_collide() {
    let root = Rng::new(123);
    let mut seen = std::collections::HashSet::new();
    for stream in 0..500u64 {
        let mut r = root.fork(stream);
        let v = (r.next_u64(), r.next_u64());
        assert!(seen.insert(v), "fork({stream}) collided");
    }
}

// ---------------------------------------------------------------------
// Coordinator K-window decision parity + worker-pool bit-identity
// (ISSUE 3).
// ---------------------------------------------------------------------

/// Random job population that fits the `balanced` layout (≤ 16 GiB), so
/// protocol runs always terminate.
fn random_trace(rng: &mut Rng, n: usize) -> Vec<Job> {
    (0..n as u32)
        .map(|id| {
            let work = rng.uniform_range(600.0, 4_000.0);
            let mem = rng.uniform_range(1.0, 14.0);
            let trp = Trp {
                phases: vec![
                    Phase::new(work * 0.4, mem * 0.8, mem * 0.05, 0.3),
                    Phase::new(work * 0.6, mem, mem * 0.05, 0.1),
                ],
                duration_cv: 0.08,
            };
            let arrival = rng.below(3_000);
            let deadline = if rng.uniform() < 0.3 { Some(arrival + 60_000) } else { None };
            let mut j =
                Job::new(id, "p", arrival, trp, deadline, 1.0, work / 4.0, 0.0);
            if rng.uniform() < 0.2 {
                j.misreport_bias = 0.6; // exercise calibration parity
            }
            j
        })
        .collect()
}

#[test]
fn prop_coordinator_decisions_match_scheduler() {
    // ISSUE 3 invariant: the message-passing coordinator runtime makes
    // exactly the in-process `JasdaScheduler::iterate` decisions — same
    // windows announced, same awards (job/slice/interval/work bits), in
    // the same rounds — for K in {1, 2, per-slice} on random traces.
    // `run_reference` is the oracle: the identical leader environment
    // with an embedded JasdaScheduler making the decisions.
    let mut rng = Rng::new(0xC0DE);
    for case in 0..6 {
        let (k, per_slice) = [(1usize, false), (2, false), (1, true)][case % 3];
        let mut c = jasda::config::SimConfig::default();
        c.seed = 7_000 + case as u64;
        c.cluster.layout = "balanced".into();
        c.engine.iteration_period = 25;
        c.jasda.fmp_bins = 16;
        c.jasda.announce_k = k;
        c.jasda.announce_per_slice = per_slice;
        // Alternate the parallel budget so the pool path is exercised on
        // both sides of the comparison.
        c.jasda.parallel = if case % 2 == 0 { 1 } else { 4 };
        let jobs = random_trace(&mut rng, 3 + case % 4);

        let mut proto_trace = Vec::new();
        let proto = jasda::coordinator::run_protocol_traced(
            c.clone(),
            jobs.clone(),
            400_000,
            Some(&mut proto_trace),
        );
        // The framed transport must be decision-invisible: same case,
        // every message crossing as wire bytes, same oracle.
        let mut framed = c.clone();
        framed.jasda.transport = jasda::config::TransportKind::Framed;
        let mut framed_trace = Vec::new();
        jasda::coordinator::run_protocol_traced(
            framed,
            jobs.clone(),
            400_000,
            Some(&mut framed_trace),
        );
        let mut ref_trace = Vec::new();
        let reference = jasda::coordinator::run_reference_traced(
            c,
            jobs,
            400_000,
            Some(&mut ref_trace),
        );

        assert_eq!(
            proto.completed_jobs, proto.total_jobs,
            "case {case}: protocol must finish: {proto:?}"
        );
        assert_eq!(
            reference.completed_jobs, reference.total_jobs,
            "case {case}: reference must finish: {reference:?}"
        );
        assert_eq!(
            proto_trace.len(),
            ref_trace.len(),
            "case {case} K={k} ps={per_slice}: decision-round count"
        );
        for (p, r) in proto_trace.iter().zip(&ref_trace) {
            assert_eq!(
                p, r,
                "case {case} K={k} ps={per_slice}: round {} decisions diverged",
                p.round
            );
        }
        assert_eq!(
            framed_trace.len(),
            ref_trace.len(),
            "case {case} K={k} ps={per_slice}: framed decision-round count"
        );
        for (p, r) in framed_trace.iter().zip(&ref_trace) {
            assert_eq!(
                p, r,
                "case {case} K={k} ps={per_slice}: framed round {} diverged",
                p.round
            );
        }
        assert_eq!(proto.rounds, reference.rounds, "case {case}");
        assert_eq!(proto.awards, reference.awards, "case {case}");
        assert_eq!(proto.windows_announced, reference.windows_announced, "case {case}");
        assert_eq!(proto.final_time, reference.final_time, "case {case}");
    }
}

#[test]
#[cfg(unix)]
fn prop_socket_transports_decision_identical_to_loopback() {
    // ISSUE 9 acceptance: moving the frames onto real sockets changes
    // no decision. For tcp and unix x shards in {1, 2, 4}, the traced
    // per-round windows and awards are bit-identical to the loopback
    // run of the same case — and at shards=1 to `run_reference`, the
    // in-process oracle. This holds because the spawn barrier delivers
    // round 0 to every agent, collection without a deadline blocks for
    // every reply, bids are stored by slot (arrival order free), and
    // the bounded write buffers never fill in a healthy run.
    let mut rng = Rng::new(0x50CC37);
    for (case, &shards) in [1usize, 2, 4].iter().enumerate() {
        let mut c = jasda::config::SimConfig::default();
        c.seed = 31_000 + case as u64;
        c.cluster.layout = "balanced".into();
        c.engine.iteration_period = 25;
        c.jasda.fmp_bins = 16;
        c.jasda.announce_per_slice = true;
        c.jasda.shards = shards;
        c.jasda.parallel = if case % 2 == 0 { 1 } else { 4 };
        let jobs = random_trace(&mut rng, 4);

        let mut base_trace = Vec::new();
        let base = jasda::coordinator::run_protocol_traced(
            c.clone(),
            jobs.clone(),
            400_000,
            Some(&mut base_trace),
        );
        assert_eq!(
            base.completed_jobs, base.total_jobs,
            "case {case}: loopback baseline must finish: {base:?}"
        );
        for kind in [jasda::config::TransportKind::Tcp, jasda::config::TransportKind::Unix] {
            let mut sc = c.clone();
            sc.jasda.transport = kind;
            let mut strace = Vec::new();
            let sout = jasda::coordinator::run_protocol_traced(
                sc,
                jobs.clone(),
                400_000,
                Some(&mut strace),
            );
            assert_eq!(
                sout.completed_jobs, sout.total_jobs,
                "case {case} {}: socket run must finish: {sout:?}",
                kind.name()
            );
            assert_eq!(
                sout.sends_dropped, 0,
                "case {case} {}: a healthy socket run must drop nothing",
                kind.name()
            );
            assert_eq!(
                strace.len(),
                base_trace.len(),
                "case {case} {} shards={shards}: decision-round count",
                kind.name()
            );
            for (s, b) in strace.iter().zip(&base_trace) {
                assert_eq!(
                    s, b,
                    "case {case} {} shards={shards}: round {} decisions diverged \
                     over sockets",
                    kind.name(),
                    s.round
                );
            }
            assert_eq!(sout.final_time, base.final_time, "case {case} {}", kind.name());
        }
        if shards == 1 {
            let mut ref_trace = Vec::new();
            jasda::coordinator::run_reference_traced(c, jobs, 400_000, Some(&mut ref_trace));
            assert_eq!(base_trace.len(), ref_trace.len(), "case {case}: vs reference");
            for (b, r) in base_trace.iter().zip(&ref_trace) {
                assert_eq!(b, r, "case {case}: round {} diverged from the oracle", b.round);
            }
        }
    }
}

#[test]
#[cfg(unix)]
fn prop_socket_smoke_1k_agents_1k_slices() {
    // ISSUE 9 acceptance: a 1000-agent x 1001-slice round completes
    // over unix sockets — only possible because the leader serves every
    // connection from one poll-driven I/O thread; a thread-per-agent
    // blocking-read leader at this scale is exactly what the socket
    // transport exists to avoid. Two rounds, then shut down (completing
    // 1000 jobs at announce_k=2 would take thousands of rounds).
    let mut c = jasda::config::SimConfig::default();
    c.cluster.layout = "7x1g".into();
    c.cluster.num_gpus = 143; // 143 GPUs x 7 slices = 1001 slices
    c.engine.iteration_period = 25;
    c.jasda.fmp_bins = 16;
    c.jasda.announce_k = 2;
    c.jasda.transport = jasda::config::TransportKind::Unix;
    let jobs: Vec<Job> = (0..1000u32)
        .map(|id| {
            let trp = Trp {
                phases: vec![Phase::new(800.0, 4.0, 0.2, 0.1)],
                duration_cv: 0.05,
            };
            Job::new(id, "p", 0, trp, None, 1.0, 300.0, 0.0)
        })
        .collect();
    let out = jasda::coordinator::run_protocol(c, jobs, 2);
    assert_eq!(out.rounds, 2, "{out:?}");
    assert!(out.announcements >= 2, "{out:?}");
    assert!(out.bids > 0, "1000 live agents must bid in a smoke round: {out:?}");
    assert_eq!(out.sends_dropped, 0, "healthy smoke run must drop nothing: {out:?}");
    assert_eq!(out.frames_rejected, 0, "{out:?}");
}

#[test]
fn prop_worker_pool_bit_identical_to_scoped_threads() {
    // ISSUE 3 invariant: the persistent WorkerPool fan-out computes the
    // same bits as the per-iteration `std::thread::scope` fan-out it
    // replaced. `ScorerBackend::score_into` still uses scoped threads;
    // `score_into_pooled` rides the pool with the identical chunking —
    // every output lane must agree exactly, across batch sizes that
    // straddle the fan-out threshold and budgets that do not divide the
    // row count.
    use jasda::jasda::pool::WorkerPool;
    use jasda::jasda::scoring::ScoreOutput;

    let mut rng = Rng::new(0x500C);
    for &m in &[1usize, 37, 255, 256, 1000, 3000] {
        let mut b = ScoreBatch::with_bins(8);
        b.capacity = 14.0;
        b.theta = 0.05;
        b.lambda = 0.6;
        b.alpha = [0.45, 0.25, 0.15, 0.15];
        b.beta = [0.45, 0.2, 0.15, 0.2];
        for _ in 0..m {
            let base = rng.uniform_range(2.0, 15.0);
            let mu: Vec<f64> = (0..8).map(|_| base + rng.uniform_range(-0.5, 0.5)).collect();
            let sigma: Vec<f64> = (0..8).map(|_| rng.uniform_range(0.05, 1.0)).collect();
            b.push(
                &mu,
                &sigma,
                [rng.uniform(), rng.uniform(), rng.uniform(), rng.uniform()],
                [rng.uniform(), rng.uniform(), rng.uniform()],
                rng.uniform(),
                rng.uniform(),
            );
        }
        // Mixed-capacity rows (the K-window union-batch shape).
        if m >= 256 {
            b.row_capacity = (0..m).map(|i| if i % 3 == 0 { 7.0 } else { 14.0 }).collect();
        }
        for &budget in &[1usize, 2, 3, 8] {
            let mut scoped = ScoreOutput::default();
            NativeScorer.score_into(&b, &mut scoped, budget).unwrap();
            let pool = WorkerPool::new(budget);
            let mut pooled = ScoreOutput::default();
            NativeScorer.score_into_pooled(&b, &mut pooled, &pool).unwrap();
            assert_eq!(
                scoped, pooled,
                "m={m} budget={budget}: pool diverged from scoped threads"
            );
        }
    }
}

#[test]
fn prop_sharded_coordinator_is_conflict_free_and_completes() {
    // ISSUE 6 invariant: N leader shards plus the cross-shard
    // reconciler never commit a conflict the single leader would have
    // caught — on random traces, for shards in {2, 4} over both
    // transports, every round's award set is free of same-job interval
    // overlaps and same-slice double bookings, and every job still
    // completes. (Slice-level overlaps would also panic the leader's
    // timeline `reserve`, so finishing at all is itself evidence.)
    let mut rng = Rng::new(0x54A2D);
    let mut total_cross_shard = 0u64;
    for case in 0..8 {
        let shards = [2usize, 4][case % 2];
        let mut c = jasda::config::SimConfig::default();
        c.seed = 11_000 + case as u64;
        c.cluster.layout = "balanced".into();
        c.engine.iteration_period = 25;
        c.jasda.fmp_bins = 16;
        c.jasda.shards = shards;
        c.jasda.announce_per_slice = case % 3 != 0;
        c.jasda.parallel = if case % 2 == 0 { 1 } else { 4 };
        if case % 4 >= 2 {
            c.jasda.transport = jasda::config::TransportKind::Framed;
        }
        let jobs = random_trace(&mut rng, 4 + case % 4);
        let n = jobs.len();

        let mut trace = Vec::new();
        let out =
            jasda::coordinator::run_protocol_traced(c, jobs, 400_000, Some(&mut trace));
        assert_eq!(
            out.completed_jobs, n,
            "case {case} shards={shards}: sharded leader must finish: {out:?}"
        );
        total_cross_shard += out.cross_shard_conflicts;
        for rd in &trace {
            for (i, a) in rd.awards.iter().enumerate() {
                for b in rd.awards.iter().skip(i + 1) {
                    if a.job == b.job {
                        assert!(
                            !a.interval.overlaps(&b.interval),
                            "case {case} shards={shards} round {}: job {} holds \
                             overlapping awards {:?} / {:?}",
                            rd.round,
                            a.job,
                            a.interval,
                            b.interval
                        );
                    }
                    if a.slice == b.slice {
                        assert!(
                            !a.interval.overlaps(&b.interval),
                            "case {case} shards={shards} round {}: slice {} double-booked",
                            rd.round,
                            a.slice
                        );
                    }
                }
            }
        }
    }
    // The reconciler must actually have work to do on contended traces;
    // a sweep where it never fires would mean the filter is dead code.
    assert!(
        total_cross_shard > 0,
        "expected at least one cross-shard conflict across the sweep"
    );
}

#[test]
fn prop_wire_codec_round_trips_random_messages() {
    // ISSUE 6 invariant: the hand-rolled wire codec is lossless —
    // encode → decode is the identity (f64s compared by bits) on
    // randomized messages, and `Arc`-shared FMPs come back shared.
    use jasda::coordinator::messages::{AgentReply, Award, CompletionReport, ToAgent};
    use jasda::coordinator::wire;
    use jasda::job::variants::{DeclaredFeatures, SysFeatures};
    use jasda::job::Variant;
    use jasda::trp::Fmp;
    use std::sync::Arc;

    let mut rng = Rng::new(0x31BE);
    let mut buf = Vec::new();
    for case in 0..200 {
        buf.clear();
        match case % 4 {
            0 => {
                let windows: Vec<Window> = (0..rng.index(6))
                    .map(|_| {
                        let start = rng.below(1 << 40);
                        Window {
                            slice: rng.below(8) as u32,
                            capacity_gb: rng.uniform_range(5.0, 40.0),
                            speed: rng.uniform_range(0.1, 1.0),
                            interval: Interval::new(start, start + rng.below(1 << 20)),
                        }
                    })
                    .collect();
                let msg = ToAgent::Announce {
                    round: rng.next_u64(),
                    now: rng.below(1 << 40),
                    windows: Arc::new(windows.clone()),
                };
                encode_decode_to_agent(&msg, &mut buf, |got| match got {
                    ToAgent::Announce { round, now, windows: w } => {
                        assert_eq!(round, match msg {
                            ToAgent::Announce { round, .. } => round,
                            _ => unreachable!(),
                        });
                        let _ = now;
                        assert_eq!(*w, windows, "case {case}");
                    }
                    other => panic!("case {case}: wrong decode {other:?}"),
                });
            }
            1 => {
                let ids: Vec<u32> = (0..rng.index(10)).map(|_| rng.below(1 << 32) as u32).collect();
                let msg = ToAgent::Awarded(Award {
                    round: rng.next_u64(),
                    variant_ids: ids.clone(),
                    now: rng.below(1 << 40),
                });
                encode_decode_to_agent(&msg, &mut buf, |got| match got {
                    ToAgent::Awarded(a) => assert_eq!(a.variant_ids, ids, "case {case}"),
                    other => panic!("case {case}: wrong decode {other:?}"),
                });
            }
            2 => {
                let planned = rng.uniform_range(0.0, 5_000.0);
                let msg = ToAgent::Completed(CompletionReport {
                    planned_work: planned,
                    realized_work: planned * rng.uniform(),
                    at: rng.below(1 << 40),
                });
                encode_decode_to_agent(&msg, &mut buf, |got| match (got, &msg) {
                    (ToAgent::Completed(g), ToAgent::Completed(w)) => {
                        assert_eq!(g.planned_work.to_bits(), w.planned_work.to_bits());
                        assert_eq!(g.realized_work.to_bits(), w.realized_work.to_bits());
                        assert_eq!(g.at, w.at);
                    }
                    (other, _) => panic!("case {case}: wrong decode {other:?}"),
                });
            }
            _ => {
                // A bid whose variants share FMPs in a random pattern.
                let fmps: Vec<Arc<Fmp>> = (0..1 + rng.index(3))
                    .map(|_| {
                        let bins = 1 + rng.index(24);
                        Arc::new(Fmp {
                            mu: (0..bins).map(|_| rng.uniform_range(0.0, 20.0)).collect(),
                            sigma: (0..bins).map(|_| rng.uniform_range(0.0, 2.0)).collect(),
                        })
                    })
                    .collect();
                let job = rng.below(1 << 32) as u32;
                let mut next_id = 0u32;
                let bids: Vec<Vec<Variant>> = (0..rng.index(4))
                    .map(|_| {
                        (0..rng.index(5))
                            .map(|_| {
                                let start = rng.below(1 << 40);
                                let id = next_id;
                                next_id += 1;
                                Variant {
                                    id,
                                    job,
                                    slice: rng.below(8) as u32,
                                    interval: Interval::new(start, start + rng.below(1 << 16)),
                                    work: rng.uniform_range(0.0, 4_000.0),
                                    work_offset: rng.uniform_range(0.0, 4_000.0),
                                    fmp: Arc::clone(&fmps[rng.index(fmps.len())]),
                                    violation_prob: rng.uniform(),
                                    declared: DeclaredFeatures {
                                        phi_honest: [rng.uniform(); 4],
                                        phi: [rng.uniform(); 4],
                                        h_tilde: rng.uniform(),
                                    },
                                    sys: SysFeatures {
                                        util: rng.uniform(),
                                        frag: rng.uniform(),
                                    },
                                }
                            })
                            .collect()
                    })
                    .collect();
                let done = rng.chance(0.5);
                let msg = AgentReply::Bid { job, round: rng.next_u64(), bids: bids.clone(), done };
                wire::encode_agent_reply(&msg, &mut buf).expect("in-cap reply encodes");
                let AgentReply::Bid { job: gj, bids: got, done: gd, .. } =
                    wire::decode_agent_reply(&buf).unwrap_or_else(|e| {
                        panic!("case {case}: decode failed: {e}")
                    });
                assert_eq!(gj, job);
                assert_eq!(gd, done);
                assert_eq!(got.len(), bids.len());
                for (gw, bw) in got.iter().zip(&bids) {
                    assert_eq!(gw.len(), bw.len(), "case {case}");
                    for (g, b) in gw.iter().zip(bw) {
                        assert_eq!(g.id, b.id);
                        assert_eq!(g.slice, b.slice);
                        assert_eq!(g.interval, b.interval);
                        assert_eq!(g.work.to_bits(), b.work.to_bits());
                        assert_eq!(g.work_offset.to_bits(), b.work_offset.to_bits());
                        assert_eq!(g.fmp.mu, b.fmp.mu);
                        assert_eq!(g.fmp.sigma, b.fmp.sigma);
                        assert_eq!(g.violation_prob.to_bits(), b.violation_prob.to_bits());
                        assert_eq!(g.declared.h_tilde.to_bits(), b.declared.h_tilde.to_bits());
                    }
                }
                // Sharing pattern is preserved: equal Arc identity on the
                // encode side implies equal Arc identity after decode.
                let flat_in: Vec<&Variant> = bids.iter().flatten().collect();
                let flat_out: Vec<&Variant> = got.iter().flatten().collect();
                for i in 0..flat_in.len() {
                    for j in (i + 1)..flat_in.len() {
                        assert_eq!(
                            Arc::ptr_eq(&flat_in[i].fmp, &flat_in[j].fmp),
                            Arc::ptr_eq(&flat_out[i].fmp, &flat_out[j].fmp),
                            "case {case}: FMP sharing pattern changed at ({i},{j})"
                        );
                    }
                }
            }
        }
    }

    fn encode_decode_to_agent(
        msg: &ToAgent,
        buf: &mut Vec<u8>,
        check: impl FnOnce(ToAgent),
    ) {
        wire::encode_to_agent(msg, buf).expect("in-cap message encodes");
        check(wire::decode_to_agent(buf).expect("round trip"));
    }
}

// ---------------------------------------------------------------------
// Fault-tolerant negotiation rounds (ISSUE 7): the round deadline is
// decision-invisible without faults, and under randomized fault plans
// every round still terminates and stays conflict-free.
// ---------------------------------------------------------------------

/// Seed grid for the fault-injection sweep. CI's fault-matrix step sets
/// `JASDA_FAULT_SEEDS` (a whitespace-separated list of u64s) to widen
/// the grid; the built-in default keeps local runs fast.
fn fault_seeds() -> Vec<u64> {
    match std::env::var("JASDA_FAULT_SEEDS") {
        Ok(s) => {
            let seeds: Vec<u64> = s
                .split_whitespace()
                .map(|t| {
                    t.parse().unwrap_or_else(|_| panic!("bad JASDA_FAULT_SEEDS token '{t}'"))
                })
                .collect();
            assert!(!seeds.is_empty(), "JASDA_FAULT_SEEDS is set but holds no seeds");
            seeds
        }
        Err(_) => vec![1, 2, 3],
    }
}

#[test]
fn prop_round_deadline_without_faults_is_decision_invisible() {
    // ISSUE 7 acceptance: configuring `jasda.round_timeout_ms` without
    // fault injection changes *nothing* — in a healthy run every reply
    // arrives long before any sane deadline, so the deadline arm is
    // never taken. For K in {1, 2, per-slice}, shards in {1, 2, 4},
    // over both transports: the deadline-on trace is bit-identical to
    // the deadline-off trace, no round times out, no straggler is
    // discarded — and at shards=1 both match `run_reference` (the
    // sharded decision paths diverge from the unsharded oracle by
    // design, so reference parity is a shards=1 claim, exactly as in
    // `prop_coordinator_decisions_match_scheduler`).
    let mut rng = Rng::new(0xDEAD71);
    for case in 0..6 {
        let (k, per_slice) = [(1usize, false), (2, false), (1, true)][case % 3];
        let shards = [1usize, 2, 4][case % 3];
        let mut c = jasda::config::SimConfig::default();
        c.seed = 17_000 + case as u64;
        c.cluster.layout = "balanced".into();
        c.engine.iteration_period = 25;
        c.jasda.fmp_bins = 16;
        c.jasda.announce_k = k;
        c.jasda.announce_per_slice = per_slice;
        c.jasda.shards = shards;
        c.jasda.parallel = if case % 2 == 0 { 1 } else { 4 };
        if case % 2 == 1 {
            c.jasda.transport = jasda::config::TransportKind::Framed;
        }
        let jobs = random_trace(&mut rng, 3 + case % 3);

        let mut base_trace = Vec::new();
        let base = jasda::coordinator::run_protocol_traced(
            c.clone(),
            jobs.clone(),
            400_000,
            Some(&mut base_trace),
        );
        let mut timed_cfg = c.clone();
        timed_cfg.jasda.round_timeout_ms = 5_000;
        timed_cfg.validate().expect("deadline-only config is valid");
        let mut timed_trace = Vec::new();
        let timed = jasda::coordinator::run_protocol_traced(
            timed_cfg,
            jobs.clone(),
            400_000,
            Some(&mut timed_trace),
        );

        assert_eq!(timed.rounds_timed_out, 0, "case {case}: healthy rounds never time out");
        assert_eq!(timed.stragglers, 0, "case {case}: no straggler without faults");
        assert_eq!(timed.agents_quarantined, 0, "case {case}");
        assert_eq!(timed_trace.len(), base_trace.len(), "case {case}: round count");
        for (t, b) in timed_trace.iter().zip(&base_trace) {
            assert_eq!(
                t, b,
                "case {case} K={k} ps={per_slice} shards={shards}: round {} decisions \
                 diverged under a generous deadline",
                t.round
            );
        }
        assert_eq!(timed.rounds, base.rounds, "case {case}");
        assert_eq!(timed.awards, base.awards, "case {case}");
        assert_eq!(timed.windows_announced, base.windows_announced, "case {case}");
        assert_eq!(timed.final_time, base.final_time, "case {case}");

        if shards == 1 {
            let mut ref_trace = Vec::new();
            jasda::coordinator::run_reference_traced(c, jobs, 400_000, Some(&mut ref_trace));
            assert_eq!(timed_trace.len(), ref_trace.len(), "case {case}: vs reference");
            for (t, r) in timed_trace.iter().zip(&ref_trace) {
                assert_eq!(
                    t, r,
                    "case {case}: round {} diverged from run_reference with the \
                     deadline armed",
                    t.round
                );
            }
        }
    }
}

#[test]
fn prop_faulty_rounds_terminate_and_stay_conflict_free() {
    // ISSUE 7 acceptance: under a randomized `FaultPlan` that crashes a
    // non-empty subset of agents mid-run (crash > 0 forces at least one
    // crash window, and `after_announce` windows reproduce the exact
    // "announce landed, reply never comes" wedge), with delays,
    // corruptions, and drops layered on top:
    //   - every round terminates under the deadline — proved by the run
    //     finishing at all, since a single wedged collection loop would
    //     hang the whole run;
    //   - surviving jobs still make progress: every job completes, which
    //     needs quarantine re-admission and Resync healing to work;
    //   - no round's award set has same-job interval overlaps or
    //     same-slice double bookings, across shard counts and both
    //     transports (partial bid sets must clear like empty bids);
    //   - both clearing policies survive the same plans (ISSUE 8): the
    //     `exact` arm runs per-slice windows under a tight 5 ms budget,
    //     so rounds mix solved, improved, and budget-exhausted exact
    //     passes — all of which must terminate under the deadline and
    //     stay conflict-free exactly like greedy (exhaustion falls back
    //     to the greedy incumbent mid-round, never wedges a round).
    let mut rng = Rng::new(0xFA7A1);
    let mut adversity = 0u64;
    let mut exact_consulted = 0u64;
    for (i, &seed) in fault_seeds().iter().enumerate() {
        for &shards in &[1usize, 2] {
            for mode in jasda::config::ClearingMode::ALL {
                let mut c = jasda::config::SimConfig::default();
                c.seed = 23_000 + seed;
                c.cluster.layout = "balanced".into();
                c.engine.iteration_period = 25;
                c.jasda.fmp_bins = 16;
                c.jasda.shards = shards;
                c.jasda.parallel = 2;
                // Cycle every transport across the sweep, so the same
                // plans are exercised both through the FaultyTransport
                // wrapper (loopback, framed) and at the socket layer
                // (crash = closed connection + refused reconnect,
                // corrupt = bent stream byte, delay = held frame).
                let kinds = jasda::config::TransportKind::ALL;
                c.jasda.transport = kinds[(i + shards) % kinds.len()];
                #[cfg(not(unix))]
                if matches!(
                    c.jasda.transport,
                    jasda::config::TransportKind::Tcp | jasda::config::TransportKind::Unix
                ) {
                    c.jasda.transport = jasda::config::TransportKind::Framed;
                }
                c.jasda.clearing = mode;
                if mode == jasda::config::ClearingMode::Exact {
                    // Per-slice announcements give the solver real
                    // multi-window rounds; the tight budget forces the
                    // fallback path to fire under load.
                    c.jasda.announce_per_slice = true;
                    c.jasda.clearing_budget_ms = 5;
                }
                c.jasda.round_timeout_ms = 400;
                c.jasda.faults.seed = seed;
                c.jasda.faults.crash = 0.5;
                c.jasda.faults.delay = 0.25;
                c.jasda.faults.corrupt = 0.25;
                c.jasda.faults.drop = 0.25;
                c.jasda.faults.horizon_rounds = 24;
                c.jasda.faults.crash_rounds = 8;
                c.validate().expect("fault config with deadline is valid");
                let jobs = random_trace(&mut rng, 4);
                let n = jobs.len();

                let mut trace = Vec::new();
                let out = jasda::coordinator::run_protocol_traced(
                    c,
                    jobs,
                    400_000,
                    Some(&mut trace),
                );
                assert_eq!(
                    out.completed_jobs, n,
                    "seed {seed} shards={shards} clearing={}: all jobs must survive \
                     the fault plan: {out:?}",
                    mode.name()
                );
                adversity += out.rounds_timed_out
                    + out.stragglers
                    + out.sends_dropped
                    + out.frames_rejected
                    + out.agents_quarantined;
                exact_consulted += out.exact_rounds;
                for rd in &trace {
                    for (a_i, a) in rd.awards.iter().enumerate() {
                        for b in rd.awards.iter().skip(a_i + 1) {
                            if a.job == b.job {
                                assert!(
                                    !a.interval.overlaps(&b.interval),
                                    "seed {seed} shards={shards} clearing={} round {}: \
                                     job {} holds overlapping awards {:?} / {:?} under \
                                     faults",
                                    mode.name(),
                                    rd.round,
                                    a.job,
                                    a.interval,
                                    b.interval
                                );
                            }
                            if a.slice == b.slice {
                                assert!(
                                    !a.interval.overlaps(&b.interval),
                                    "seed {seed} shards={shards} clearing={} round {}: \
                                     slice {} double-booked under faults",
                                    mode.name(),
                                    rd.round,
                                    a.slice
                                );
                            }
                        }
                    }
                }
            }
        }
    }
    // The sweep must actually have been adversarial: a forced crash
    // window inside the horizon always eats a send or burns a deadline,
    // so zero observed fault effects means the injection is dead code.
    assert!(adversity > 0, "fault sweep observed no fault effects at all");
    // And the exact arm must actually have reached the solver gate, or
    // its half of the sweep degenerates into a second greedy run.
    assert!(exact_consulted > 0, "exact arm never saw a multi-window round");
}

// ---------------------------------------------------------------------
// Exact global clearing (ISSUE 8): the branch-and-bound pass dominates
// the greedy reconciliation merge per round, awards only conflict-free
// sets, degenerates to greedy at K = 1 and at a zero budget, and the
// exact path can never double-commit a variant greedy already accepted.
// ---------------------------------------------------------------------

/// A synthetic bid variant for direct [`ClearingEngine`] drives: a tiny
/// safe FMP (1.0 ± 0.1 GiB against 20 GiB windows, so every row is
/// eligible) and `quality` steering the composite score through φ[0].
#[allow(clippy::too_many_arguments)]
fn bid_variant(
    id: u32,
    job: u32,
    slice: u32,
    start: u64,
    end: u64,
    work_offset: f64,
    work: f64,
    quality: f64,
) -> jasda::job::Variant {
    use jasda::job::variants::{DeclaredFeatures, SysFeatures};
    use jasda::trp::Fmp;
    use std::sync::Arc;
    jasda::job::Variant {
        id,
        job,
        slice,
        interval: Interval::new(start, end),
        work,
        work_offset,
        fmp: Arc::new(Fmp { mu: vec![1.0; 4], sigma: vec![0.1; 4] }),
        violation_prob: 0.0,
        declared: DeclaredFeatures {
            phi_honest: [quality, 0.0, 0.0, 0.0],
            phi: [quality, 0.0, 0.0, 0.0],
            h_tilde: 0.0,
        },
        sys: SysFeatures { util: 0.0, frag: 0.0 },
    }
}

/// Drive one [`ClearingEngine::clear`] round and return the emitted
/// awards as `(window slice, variant id, score)` in emission order,
/// plus the round's counters. Window `w` carries `slice = w`.
fn run_clear_round(
    mode: jasda::config::ClearingMode,
    budget_ms: u64,
    threads: usize,
    windows: &[Window],
    window_rows: &[(usize, usize)],
    pool: &[jasda::job::Variant],
) -> (Vec<(u32, u32, f64)>, jasda::jasda::clearing::ClearStats) {
    let mut cfg = JasdaConfig::default();
    cfg.fmp_bins = 4;
    cfg.clearing = mode;
    cfg.clearing_budget_ms = budget_ms;
    let mut engine = jasda::jasda::clearing::ClearingEngine::new();
    let workers = jasda::jasda::pool::WorkerPool::new(threads);
    let mut scorer = NativeScorer;
    let mut awards: Vec<(u32, u32, f64)> = Vec::new();
    let stats = engine.clear(
        &cfg,
        windows,
        window_rows,
        pool,
        &mut |_| jasda::jasda::clearing::RowCtx { age: 0.0, trust: 1.0, hist: 0.0 },
        &mut scorer,
        &workers,
        &mut |acc| awards.push((acc.window.slice, acc.variant.id, acc.score)),
    );
    (awards, stats)
}

/// Per-row composite scores via the engine's exact batch recipe (same
/// per-row capacities, trust = 1, hist = age = 0); rows are independent
/// and bit-identical at any thread count, so these match what the
/// engine scored to the bit.
fn composite_scores(
    windows: &[Window],
    window_rows: &[(usize, usize)],
    pool: &[jasda::job::Variant],
) -> Vec<f64> {
    let cfg = JasdaConfig::default();
    let mut b = ScoreBatch::with_bins(4);
    b.capacity = windows[0].capacity_gb as f32;
    b.theta = cfg.theta as f32;
    b.lambda = cfg.lambda as f32;
    let alpha = cfg.alpha.as_array();
    let beta = cfg.beta.as_array();
    b.alpha = [alpha[0] as f32, alpha[1] as f32, alpha[2] as f32, alpha[3] as f32];
    b.beta = [beta[0] as f32, beta[1] as f32, beta[2] as f32, beta[3] as f32];
    for v in pool {
        let phi = [v.declared.phi[0], v.declared.phi[1], v.declared.phi[2], v.declared.phi[3]];
        b.push(&v.fmp.mu, &v.fmp.sigma, phi, [v.sys.util, v.sys.frag, 0.0], 1.0, 0.0);
    }
    if windows.len() > 1 {
        for (w, &(start, end)) in windows.iter().zip(window_rows) {
            b.row_capacity.extend(std::iter::repeat(w.capacity_gb as f32).take(end - start));
        }
    }
    let out = NativeScorer.score(&b).expect("reference scoring");
    (0..pool.len())
        .map(|i| if out.eligible[i] { out.score[i] as f64 } else { 0.0 })
        .collect()
}

/// Exhaustive optimum over the engine's feasible space: within a window
/// selections must be temporally disjoint (what WIS enforces); across
/// windows same-job temporal or work-range overlaps are forbidden (the
/// `keys_conflict` rule). Exponential — tiny instances only.
fn brute_force_round(wins: &[usize], pool: &[jasda::job::Variant], scores: &[f64]) -> f64 {
    use jasda::jasda::clearing::{keys_conflict, variant_key};
    let n = wins.len();
    assert!(n <= 14, "brute force is exponential");
    let mut best = 0.0f64;
    'subset: for mask in 0u32..(1 << n) {
        let mut total = 0.0;
        for i in 0..n {
            if mask & (1 << i) == 0 {
                continue;
            }
            if scores[i] <= 0.0 {
                continue 'subset;
            }
            for j in 0..i {
                if mask & (1 << j) == 0 {
                    continue;
                }
                let ok = if wins[i] == wins[j] {
                    !pool[i].interval.overlaps(&pool[j].interval)
                } else {
                    !keys_conflict(&variant_key(&pool[i]), &variant_key(&pool[j]))
                };
                if !ok {
                    continue 'subset;
                }
            }
            total += scores[i];
        }
        if total > best {
            best = total;
        }
    }
    best
}

#[test]
fn prop_exact_clearing_dominates_greedy_and_is_optimal() {
    // ISSUE 8 acceptance, per decision round on randomized instances:
    //   - exact welfare >= greedy welfare (the greedy result is the
    //     incumbent, so the solver can only improve on it);
    //   - when the search completes (no budget/node-cap exhaustion) the
    //     exact welfare equals the exhaustive optimum over the engine's
    //     feasible space;
    //   - exact award sets are conflict-free under the same rules the
    //     greedy merge enforces;
    //   - K = 1 rounds never consult the solver and are bit-identical
    //     to greedy, and welfare ties keep greedy's decisions verbatim;
    //   - decisions and node trajectories are identical at every worker
    //     budget.
    use jasda::config::ClearingMode;
    let mut rng = Rng::new(0xE8AC7);
    let mut improved_seen = 0u64;
    for case in 0..120 {
        let k = 1 + rng.index(4);
        let n_jobs = 1 + rng.index(4) as u64;
        let mut pool: Vec<jasda::job::Variant> = Vec::new();
        let mut windows: Vec<Window> = Vec::new();
        let mut window_rows: Vec<(usize, usize)> = Vec::new();
        for w in 0..k {
            windows.push(Window {
                slice: w as u32,
                capacity_gb: 20.0,
                speed: 1.0,
                interval: Interval::new(0, 220),
            });
            let row0 = pool.len();
            for _ in 0..rng.index(4) {
                let job = rng.below(n_jobs) as u32;
                let s = rng.below(150);
                let e = s + 10 + rng.below(50);
                // Work offsets on a coarse grid with work == the grid
                // step, so cross-window work-range collisions actually
                // occur (offset equality <=> range overlap).
                let off = rng.below(3) as f64 * 40.0;
                let q = 0.1 + 0.8 * rng.uniform();
                let id = pool.len() as u32;
                pool.push(bid_variant(id, job, w as u32, s, e, off, 40.0, q));
            }
            window_rows.push((row0, pool.len()));
        }
        if pool.is_empty() {
            continue;
        }

        let (greedy, _) =
            run_clear_round(ClearingMode::Greedy, 10, 1, &windows, &window_rows, &pool);
        let (greedy_par, _) =
            run_clear_round(ClearingMode::Greedy, 10, 4, &windows, &window_rows, &pool);
        assert_eq!(greedy, greedy_par, "case {case}: greedy diverged across worker budgets");
        let (exact, estats) =
            run_clear_round(ClearingMode::Exact, 10_000, 1, &windows, &window_rows, &pool);
        let (exact_par, estats_par) =
            run_clear_round(ClearingMode::Exact, 10_000, 4, &windows, &window_rows, &pool);
        assert_eq!(exact, exact_par, "case {case}: exact diverged across worker budgets");
        assert_eq!(
            estats.exact_nodes, estats_par.exact_nodes,
            "case {case}: node trajectory must not depend on the pool budget"
        );

        let gw: f64 = greedy.iter().map(|a| a.2).sum();
        let ew: f64 = exact.iter().map(|a| a.2).sum();
        assert!(
            ew >= gw - 1e-9,
            "case {case}: exact welfare {ew} fell below greedy {gw}"
        );
        if k == 1 {
            assert_eq!(exact, greedy, "case {case}: K=1 must be bit-identical to greedy");
            assert_eq!(estats.exact_rounds, 0, "case {case}: K=1 never consults the solver");
        }
        if estats.exact_improved == 0 {
            assert_eq!(
                exact, greedy,
                "case {case}: without strict improvement the greedy decisions must \
                 survive verbatim"
            );
        } else {
            improved_seen += 1;
        }

        // Exact awards obey the same conflict rules greedy enforces.
        use jasda::jasda::clearing::{keys_conflict, variant_key};
        for i in 0..exact.len() {
            for j in 0..i {
                let (wi, idi, _) = exact[i];
                let (wj, idj, _) = exact[j];
                let a = &pool[idi as usize];
                let b = &pool[idj as usize];
                if wi == wj {
                    assert!(
                        !a.interval.overlaps(&b.interval),
                        "case {case}: window {wi} awarded overlapping variants \
                         {idi}/{idj}"
                    );
                } else {
                    assert!(
                        !keys_conflict(&variant_key(a), &variant_key(b)),
                        "case {case}: cross-window conflict between awards {idi} \
                         (w{wi}) and {idj} (w{wj})"
                    );
                }
            }
        }

        // Against the exhaustive reference whenever the search finished.
        if estats.exact_budget_exhausted == 0 {
            let scores = composite_scores(&windows, &window_rows, &pool);
            let mut wins = vec![0usize; pool.len()];
            for (w, &(r0, r1)) in window_rows.iter().enumerate() {
                for slot in &mut wins[r0..r1] {
                    *slot = w;
                }
            }
            let opt = brute_force_round(&wins, &pool, &scores);
            assert!(
                (ew - opt).abs() < 1e-6,
                "case {case}: exact welfare {ew} != exhaustive optimum {opt}"
            );
        }
    }
    // The sweep must exercise the improvement path, or the solver is
    // effectively dead code behind its own gates.
    assert!(improved_seen > 0, "no randomized case ever improved on greedy");
}

#[test]
fn exact_clearing_replaces_greedy_without_duplicate_awards() {
    // Regression pin for the single-emission-site fix: greedy accepts
    // {a, c} in window 0 (blocking job 1's better variant b in window
    // 1); exact replaces the solution with {c, b}. Variant c belongs to
    // BOTH solutions — with the historical two-call-site emission the
    // exact path would have committed c a second time. The engine must
    // emit each final award exactly once.
    use jasda::config::ClearingMode;
    let windows = vec![
        Window { slice: 0, capacity_gb: 20.0, speed: 1.0, interval: Interval::new(0, 100) },
        Window { slice: 1, capacity_gb: 20.0, speed: 1.0, interval: Interval::new(0, 100) },
    ];
    let pool = vec![
        bid_variant(0, 1, 0, 0, 50, 0.0, 50.0, 0.1), // a: job 1, low value
        bid_variant(1, 2, 0, 50, 100, 0.0, 50.0, 0.9), // c: job 2, high value
        bid_variant(2, 1, 1, 0, 100, 0.0, 100.0, 0.8), // b: job 1, conflicts with a
    ];
    let window_rows = vec![(0usize, 2usize), (2, 3)];

    let (greedy, gstats) =
        run_clear_round(ClearingMode::Greedy, 10, 1, &windows, &window_rows, &pool);
    assert_eq!(
        greedy.iter().map(|a| a.1).collect::<Vec<_>>(),
        vec![0, 1],
        "greedy clears window 0 first ({{a, c}}) and b is conflict-filtered"
    );
    assert_eq!(gstats.exact_rounds, 0, "greedy mode never consults the solver");

    let (exact, estats) =
        run_clear_round(ClearingMode::Exact, 10_000, 2, &windows, &window_rows, &pool);
    assert_eq!(estats.exact_rounds, 1);
    assert_eq!(estats.exact_improved, 1, "dropping a for b strictly improves welfare");
    assert_eq!(estats.exact_budget_exhausted, 0);
    assert_eq!(estats.exact_nodes, 3, "root plus the two children of the (a, b) branch");
    let ids: Vec<u32> = exact.iter().map(|a| a.1).collect();
    assert_eq!(
        ids,
        vec![1, 2],
        "exact must award c then b — c exactly once even though it sits in both the \
         greedy incumbent and the exact solution"
    );

    let s = composite_scores(&windows, &window_rows, &pool);
    let gw: f64 = greedy.iter().map(|a| a.2).sum();
    let ew: f64 = exact.iter().map(|a| a.2).sum();
    assert!((gw - (s[0] + s[1])).abs() < 1e-9, "greedy welfare is score(a) + score(c)");
    assert!((ew - (s[1] + s[2])).abs() < 1e-9, "exact welfare is score(c) + score(b)");
    assert!(ew > gw, "the uplift is score(b) - score(a) > 0");
}

#[test]
fn prop_zero_budget_exact_is_decision_identical_to_greedy() {
    // ISSUE 8 acceptance: with `clearing_budget_ms` forced to 0 the
    // exact path never starts its search — every consulted round falls
    // back to the greedy incumbent instantly — so `clearing = "exact"`
    // must be decision-identical to `greedy` across the full protocol
    // matrix: K in {1, 2, per-slice} x shards in {1, 2, 4} x both
    // transports.
    let mut rng = Rng::new(0xB8D6E7);
    let mut case = 0u64;
    let mut consulted = 0u64;
    for (k, per_slice) in [(1usize, false), (2, false), (1, true)] {
        for shards in [1usize, 2, 4] {
            for transport in jasda::config::TransportKind::ALL {
                let mut c = jasda::config::SimConfig::default();
                c.seed = 18_000 + case;
                c.cluster.layout = "balanced".into();
                c.engine.iteration_period = 25;
                c.jasda.fmp_bins = 16;
                c.jasda.announce_k = k;
                c.jasda.announce_per_slice = per_slice;
                c.jasda.shards = shards;
                c.jasda.parallel = if case % 2 == 0 { 1 } else { 4 };
                c.jasda.transport = transport;
                let jobs = random_trace(&mut rng, 3);

                let mut base_trace = Vec::new();
                let base = jasda::coordinator::run_protocol_traced(
                    c.clone(),
                    jobs.clone(),
                    400_000,
                    Some(&mut base_trace),
                );
                let mut ecfg = c;
                ecfg.jasda.clearing = jasda::config::ClearingMode::Exact;
                ecfg.jasda.clearing_budget_ms = 0;
                ecfg.validate().expect("zero-budget exact config is valid");
                let mut exact_trace = Vec::new();
                let exact = jasda::coordinator::run_protocol_traced(
                    ecfg,
                    jobs,
                    400_000,
                    Some(&mut exact_trace),
                );

                assert_eq!(exact_trace.len(), base_trace.len(), "case {case}: round count");
                for (e, b) in exact_trace.iter().zip(&base_trace) {
                    assert_eq!(
                        e, b,
                        "case {case} K={k} ps={per_slice} shards={shards} \
                         transport={}: round {} decisions diverged under zero-budget \
                         exact clearing",
                        transport.name(),
                        e.round
                    );
                }
                assert_eq!(exact.rounds, base.rounds, "case {case}");
                assert_eq!(exact.awards, base.awards, "case {case}");
                assert_eq!(exact.final_time, base.final_time, "case {case}");
                assert_eq!(
                    exact.exact_budget_exhausted, exact.exact_rounds,
                    "case {case}: a zero budget counts every consulted round as exhausted"
                );
                assert_eq!(
                    exact.exact_nodes, 0,
                    "case {case}: a zero budget must never expand a node"
                );
                if k == 1 && !per_slice {
                    assert_eq!(
                        exact.exact_rounds, 0,
                        "case {case}: single-window rounds never consult the solver"
                    );
                }
                consulted += exact.exact_rounds;
                case += 1;
            }
        }
    }
    // If no round ever reached the solver gate the identity above is
    // vacuous — make sure the sweep produced multi-window exact rounds.
    assert!(consulted > 0, "sweep never produced a multi-window exact round");
}

// ---------------------------------------------------------------------
// Production scenario harness + streaming metrics oracle (ISSUE 10).
// ---------------------------------------------------------------------

/// A randomized (but always valid) production scenario, small enough
/// that full simulations of it stay cheap.
fn random_scenario(rng: &mut Rng) -> jasda::config::ScenarioConfig {
    let mut s = jasda::config::ScenarioConfig::default();
    s.jobs = 20 + rng.index(40);
    s.seed = if rng.chance(0.25) { 0 } else { 1 + rng.below(100_000) };
    s.tenants = 1 + rng.index(4);
    s.tenant_weight_ratio = [1.0, 1.5, 2.0][rng.index(3)];
    s.work_alpha = 1.2 + rng.uniform();
    s.work_cap = 20_000.0;
    s.base_rate_per_sec = 1.0 + 4.0 * rng.uniform();
    s.diurnal_amplitude = 0.9 * rng.uniform();
    s.diurnal_period = if rng.chance(0.3) { 0 } else { 10_000 + rng.below(90_000) };
    s.burst_prob = 0.1 * rng.uniform();
    s.deadline_fraction = rng.uniform();
    s.metrics_window = 500 + rng.below(5_000);
    s
}

#[test]
fn prop_streaming_metrics_match_exact_oracle() {
    // ISSUE 10 invariant: on identical runs, the O(buckets) streaming
    // layer agrees with the exact in-memory oracle — bit-identical on
    // utilization/makespan/counts/max-starvation, ~exact on means
    // (summation order differs), and within the sketch's relative
    // accuracy (plus integer rounding) on percentiles.
    use jasda::metrics::streaming::StreamingMetrics;
    let mut rng = Rng::new(0x57AE);
    for case in 0..6 {
        let scenario = random_scenario(&mut rng);
        let mut c = jasda::config::SimConfig::default();
        c.seed = 40_000 + case as u64;
        c.cluster.layout = "heterogeneous".into();
        c.engine.max_time = 40_000_000;
        c.jasda.fmp_bins = 16;
        c.jasda.scenario = scenario.clone();
        c.validate().expect("random scenario validates");
        let jobs = jasda::workload::ScenarioGenerator::new(scenario).generate(c.seed);
        let name = ["jasda", "fcfs", "sjf"][case % 3];

        let sched = jasda::baselines::by_name(name, &c.jasda).unwrap();
        let exact = jasda::sim::SimEngine::new(c.clone(), sched).run(jobs.clone());
        let sched = jasda::baselines::by_name(name, &c.jasda).unwrap();
        let sm = StreamingMetrics::new(c.jasda.scenario.metrics_window, 0.01);
        let run = jasda::sim::SimEngine::new(c, sched).with_streaming(sm).run(jobs);
        let sm = run.streaming.expect("streaming path");

        let em = &exact.metrics;
        assert!(
            run.metrics.jobs.is_empty(),
            "case {case} {name}: streaming run must not keep per-job vectors"
        );
        assert_eq!(em.utilization, sm.utilization(), "case {case} {name}: utilization");
        assert_eq!(em.makespan, sm.makespan(), "case {case} {name}: makespan");
        let exact_completed = em.jobs.iter().filter(|j| j.completed.is_some()).count();
        assert_eq!(exact_completed as u64, sm.completed(), "case {case} {name}: completed");
        assert_eq!(em.unfinished as u64, sm.unfinished(), "case {case} {name}: unfinished");
        assert_eq!(
            em.max_starvation(),
            sm.max_starvation(),
            "case {case} {name}: max starvation"
        );
        match (em.mean_jct(), sm.mean_jct()) {
            (Some(e), Some(s)) => assert!(
                (e - s).abs() <= 1e-9 * e.max(1.0),
                "case {case} {name}: mean_jct exact {e} vs streaming {s}"
            ),
            (e, s) => assert_eq!(e.is_some(), s.is_some(), "case {case} {name}: mean_jct"),
        }
        for p in [0.5, 0.9, 0.99] {
            if let (Some(e), Some(s)) = (em.jct_percentile(p), sm.jct_percentile(p)) {
                assert!(
                    (e - s).abs() <= e * 0.025 + 1.0,
                    "case {case} {name}: p{p} jct exact {e} vs sketch {s}"
                );
            }
        }
        if let (Some(e), Some(s)) = (em.p95_wait(), sm.p95_wait()) {
            assert!(
                (e - s).abs() <= e * 0.025 + 1.0,
                "case {case} {name}: p95 wait exact {e} vs sketch {s}"
            );
        }
    }
}

#[test]
fn prop_scenario_generation_bit_reproducible() {
    // ISSUE 10 invariant: a scenario trace is a pure function of its
    // seed — regenerating from the same config yields bit-identical
    // jobs, and an explicit scenario seed makes the run seed irrelevant.
    let mut rng = Rng::new(0xB17);
    for case in 0..20 {
        let mut s = random_scenario(&mut rng);
        s.jobs = 10 + rng.index(60);
        let run_seed = rng.next_u64();
        let a = jasda::workload::ScenarioGenerator::new(s.clone()).generate(run_seed);
        let b = jasda::workload::ScenarioGenerator::new(s.clone()).generate(run_seed);
        assert_eq!(a.len(), b.len(), "case {case}: length");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id, "case {case}");
            assert_eq!(x.arrival, y.arrival, "case {case}");
            assert_eq!(x.class, y.class, "case {case}");
            assert_eq!(x.weight.to_bits(), y.weight.to_bits(), "case {case}");
            assert_eq!(x.deadline, y.deadline, "case {case}");
            assert_eq!(x.trp, y.trp, "case {case}");
            assert_eq!(x.atom_work.to_bits(), y.atom_work.to_bits(), "case {case}");
        }
        if s.seed != 0 {
            let c2 = jasda::workload::ScenarioGenerator::new(s).generate(run_seed ^ 0x5555);
            for (x, y) in a.iter().zip(&c2) {
                assert_eq!(x.arrival, y.arrival, "case {case}: scenario seed must win");
                assert_eq!(x.trp, y.trp, "case {case}: scenario seed must win");
            }
        }
    }
}
