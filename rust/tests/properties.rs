//! Property-based tests over the DESIGN.md §6 invariants.
//!
//! No proptest in the offline environment, so these are hand-rolled
//! property loops driven by the deterministic `sim::Rng`: each test
//! generates hundreds of random cases and asserts the invariant; failing
//! seeds are printed so cases can be replayed.

use jasda::config::JasdaConfig;
use jasda::jasda::calibration::Calibration;
use jasda::jasda::clearing::{select_best_compatible, WisItem};
use jasda::jasda::scoring::{NativeScorer, ScoreBatch, ScorerBackend};
use jasda::job::variants::generate_variants;
use jasda::job::{Job, JobState};
use jasda::mig::{Reservation, Timeline, Window};
use jasda::sim::Rng;
use jasda::trp::{Phase, Trp};
use jasda::types::Interval;

/// Exhaustive WIS reference (exponential, n <= 14).
fn brute_force(items: &[WisItem]) -> f64 {
    let m = items.len();
    let mut best = 0.0f64;
    'subset: for mask in 0u32..(1 << m) {
        let mut total = 0.0;
        for i in 0..m {
            if mask & (1 << i) != 0 {
                for j in 0..i {
                    if mask & (1 << j) != 0
                        && items[i].interval.overlaps(&items[j].interval)
                    {
                        continue 'subset;
                    }
                }
                total += items[i].score;
            }
        }
        best = best.max(total);
    }
    best
}

#[test]
fn prop_wis_optimal_and_feasible() {
    let mut rng = Rng::new(0xA11CE);
    for case in 0..400 {
        let n = 1 + rng.index(14);
        let items: Vec<WisItem> = (0..n)
            .map(|_| {
                let s = rng.below(200);
                WisItem {
                    interval: Interval::new(s, s + 1 + rng.below(60)),
                    score: rng.uniform(),
                }
            })
            .collect();
        let sol = select_best_compatible(&items);
        // Optimality.
        let best = brute_force(&items);
        assert!(
            (sol.total_score - best).abs() < 1e-9,
            "case {case}: dp {} vs brute {best}: {items:?}",
            sol.total_score
        );
        // Feasibility + consistency.
        for i in 0..sol.selected.len() {
            for j in 0..i {
                assert!(!items[sol.selected[i]]
                    .interval
                    .overlaps(&items[sol.selected[j]].interval));
            }
        }
        let sum: f64 = sol.selected.iter().map(|&i| items[i].score).sum();
        assert!((sum - sol.total_score).abs() < 1e-9);
    }
}

#[test]
fn prop_timeline_never_overlaps_and_coalesces() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..300 {
        let mut tl = Timeline::new();
        let mut accepted: Vec<Interval> = Vec::new();
        for k in 0..40 {
            let s = rng.below(2_000);
            let iv = Interval::new(s, s + 1 + rng.below(100));
            let free = tl.is_free(&iv);
            let expect_free = accepted.iter().all(|a| !a.overlaps(&iv));
            assert_eq!(free, expect_free, "case {case}.{k}: is_free disagrees with model");
            let r = tl.reserve(Reservation { job: k, subjob_seq: 0, interval: iv });
            assert_eq!(r.is_ok(), expect_free);
            if r.is_ok() {
                accepted.push(iv);
            }
        }
        // Sorted, pairwise disjoint.
        let entries = tl.entries();
        for w in entries.windows(2) {
            assert!(w[0].interval.start <= w[1].interval.start);
            assert!(!w[0].interval.overlaps(&w[1].interval));
        }
        // Idle gaps + busy ticks partition the horizon.
        let busy = tl.busy_ticks(0, 3_000);
        let idle: u64 =
            tl.idle_gaps(0, 3_000, 1).iter().map(|g| g.interval.len()).sum();
        assert_eq!(busy + idle, 3_000, "case {case}: busy+idle must cover horizon");
    }
}

#[test]
fn prop_scores_normalized_when_weights_are() {
    let mut rng = Rng::new(0xCAFE);
    let mut scorer = NativeScorer;
    for case in 0..200 {
        // Random normalized weights.
        let mut alpha = [rng.uniform() as f32; 4];
        for a in alpha.iter_mut() {
            *a = rng.uniform() as f32;
        }
        let asum: f32 = alpha.iter().sum();
        for a in alpha.iter_mut() {
            *a /= asum.max(1.0); // Σα ≤ 1
        }
        let mut beta = [0.0f32; 4];
        for b in beta.iter_mut() {
            *b = rng.uniform() as f32;
        }
        let bsum: f32 = beta.iter().sum();
        for b in beta.iter_mut() {
            *b /= bsum.max(1.0);
        }

        let mut batch = ScoreBatch::with_bins(8);
        batch.capacity = rng.uniform_range(5.0, 40.0) as f32;
        batch.theta = rng.uniform_range(0.01, 0.3) as f32;
        batch.lambda = rng.uniform() as f32;
        batch.alpha = alpha;
        batch.beta = beta;
        for _ in 0..16 {
            let mu: Vec<f64> = (0..8).map(|_| rng.uniform_range(0.5, 45.0)).collect();
            let sigma: Vec<f64> = (0..8).map(|_| rng.uniform_range(0.0, 3.0)).collect();
            batch.push(
                &mu,
                &sigma,
                [rng.uniform(), rng.uniform(), rng.uniform(), rng.uniform()],
                [rng.uniform(), rng.uniform(), rng.uniform()],
                rng.uniform(),
                rng.uniform(),
            );
        }
        let out = scorer.score(&batch).unwrap();
        for i in 0..batch.m {
            assert!(
                (0.0..=1.0).contains(&out.score[i]),
                "case {case}: score {} out of [0,1]",
                out.score[i]
            );
            assert!((0.0..=1.0).contains(&out.violation[i]));
            assert!((0.0..=1.0).contains(&out.headroom[i]));
            if !out.eligible[i] {
                assert_eq!(out.score[i], 0.0);
            }
        }
    }
}

#[test]
fn prop_reliability_bounds_and_monotonicity() {
    let mut rng = Rng::new(0xD00D);
    for _case in 0..200 {
        let kappa = rng.uniform_range(0.5, 10.0);
        let mut cal = Calibration::new(1, kappa, 0.7, [0.45, 0.25, 0.15, 0.15]);
        let mut last_rho = 1.0;
        let constant_err = rng.uniform();
        for _ in 0..30 {
            // Feed a constant per-feature error: mean error converges to
            // it, so rho must be non-increasing.
            let declared = [constant_err, 0.5, constant_err, 0.5];
            let observed = [0.0, 0.5, 0.0, 0.5];
            cal.verify(0, &declared, &observed, 0.4);
            let t = cal.trust(0);
            assert!(t.rho > 0.0 && t.rho <= 1.0, "rho out of (0,1]: {}", t.rho);
            assert!(t.rho <= last_rho + 1e-12, "rho increased under constant error");
            assert!((0.0..=1.0).contains(&t.mean_error));
            assert!((0.0..=1.0).contains(&t.hist_avg));
            last_rho = t.rho;
        }
    }
}

#[test]
fn prop_generated_variants_always_eligible() {
    let mut rng = Rng::new(0xF00D);
    let cfg = JasdaConfig { fmp_bins: 16, tau_min: 50, ..JasdaConfig::default() };
    for case in 0..300 {
        let work = rng.uniform_range(200.0, 20_000.0);
        let mem = rng.uniform_range(0.5, 18.0);
        let noise = mem * rng.uniform_range(0.02, 0.2);
        let trp = Trp {
            phases: vec![
                Phase::new(work * 0.3, mem * 0.8, noise, rng.uniform()),
                Phase::new(work * 0.7, mem, noise, rng.uniform() * 0.3),
            ],
            duration_cv: rng.uniform_range(0.0, 0.2),
        };
        let mut job = Job::new(0, "p", 0, trp, None, 1.0, work * rng.uniform_range(0.1, 0.6), 0.0);
        job.state = JobState::Active;
        job.done_work = work * rng.uniform() * 0.8;

        let cap = [5.0, 10.0, 20.0, 40.0][rng.index(4)];
        let speed = [1.0 / 7.0, 2.0 / 7.0, 3.0 / 7.0, 4.0 / 7.0, 1.0][rng.index(5)];
        let start = rng.below(10_000);
        let len = 1 + rng.below(30_000);
        let window = Window {
            slice: 3,
            capacity_gb: cap,
            speed,
            interval: Interval::new(start, start + len),
        };

        let vs = generate_variants(&job, &window, &cfg);
        let mut prev_end = window.t_min();
        for (k, v) in vs.iter().enumerate() {
            assert!(
                window.interval.contains(&v.interval),
                "case {case}.{k}: variant escapes window"
            );
            assert!(v.duration() >= cfg.tau_min, "case {case}.{k}: below tau_min");
            assert!(
                v.violation_prob <= cfg.theta + 1e-12,
                "case {case}.{k}: unsafe variant emitted"
            );
            assert!(v.work <= job.pending_work() + 1e-6);
            assert!(v.declared.h_tilde >= 0.0 && v.declared.h_tilde <= 1.0);
            assert!(v.sys.util > 0.0 && v.sys.util <= 1.0);
            assert!(v.sys.frag >= 0.0 && v.sys.frag <= 1.0);
            // Chain variants are ordered and non-overlapping.
            if v.work_offset > 0.0 {
                assert!(v.interval.start >= prev_end, "case {case}.{k}: chain overlap");
            }
            prev_end = prev_end.max(v.interval.end);
        }
        assert!(vs.len() <= cfg.max_variants_per_job + 1);
    }
}

#[test]
fn prop_fmp_violation_monotone_in_capacity_and_sigma() {
    let mut rng = Rng::new(0x5EED);
    for _ in 0..300 {
        let mem = rng.uniform_range(1.0, 30.0);
        let trp = Trp {
            phases: vec![Phase::new(
                1000.0,
                mem,
                mem * rng.uniform_range(0.01, 0.3),
                rng.uniform(),
            )],
            duration_cv: 0.1,
        };
        let fmp = trp.fmp_bins(0.0, 1000.0, 16);
        let caps = [mem * 0.8, mem * 1.05, mem * 1.3, mem * 2.0];
        let viols: Vec<f64> = caps.iter().map(|&c| fmp.violation_prob(c)).collect();
        for w in viols.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "violation not monotone in capacity: {viols:?}");
        }
        for &v in &viols {
            assert!((0.0..=1.0).contains(&v));
        }
    }
}

#[test]
fn prop_age_factor_bounds_and_reset() {
    let mut rng = Rng::new(0xA6E);
    for _ in 0..200 {
        let arrival = rng.below(10_000);
        let trp = Trp { phases: vec![Phase::new(100.0, 1.0, 0.1, 0.0)], duration_cv: 0.0 };
        let mut job = Job::new(0, "a", arrival, trp, None, 1.0, 50.0, 0.0);
        let scale = 1 + rng.below(100_000);
        let mut last = 0.0;
        let mut t = arrival;
        for _ in 0..20 {
            t += rng.below(20_000);
            let a = job.age_factor(t, scale);
            assert!((0.0..=1.0).contains(&a));
            assert!(a + 1e-12 >= last, "age must be non-decreasing while unselected");
            last = a;
        }
        // Selection resets the clock.
        job.last_selected = t;
        assert_eq!(job.age_factor(t, scale), 0.0);
    }
}

#[test]
fn prop_rng_fork_streams_do_not_collide() {
    let root = Rng::new(123);
    let mut seen = std::collections::HashSet::new();
    for stream in 0..500u64 {
        let mut r = root.fork(stream);
        let v = (r.next_u64(), r.next_u64());
        assert!(seen.insert(v), "fork({stream}) collided");
    }
}
