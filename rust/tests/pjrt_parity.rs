//! Integration tests for the PJRT-executed AOT artifact: the L1/L2
//! pipeline loaded from `artifacts/scorer.hlo.txt` must agree with the
//! rust-native mirror to f32 precision, and a full JASDA simulation run
//! on the PJRT backend must make the *same decisions* as the native one.
//!
//! These tests require `make artifacts` to have run; they are skipped
//! (with a loud message) when the artifact is missing so `cargo test`
//! stays usable before the first artifact build. The whole file is gated
//! on the `pjrt` cargo feature (the offline default build ships only the
//! stub scorer).

#![cfg(feature = "pjrt")]

use jasda::config::SimConfig;
use jasda::jasda::scoring::{NativeScorer, ScoreBatch, ScorerBackend};
use jasda::jasda::JasdaScheduler;
use jasda::runtime::{PjrtScorer, T_BINS};
use jasda::sim::{Rng, SimEngine};
use jasda::workload::WorkloadGenerator;

fn scorer_or_skip() -> Option<PjrtScorer> {
    let path = jasda::runtime::artifacts_dir().join("scorer.hlo.txt");
    if !path.exists() {
        eprintln!("SKIP: {} missing — run `make artifacts`", path.display());
        return None;
    }
    Some(PjrtScorer::load(&path).expect("artifact compiles"))
}

/// Random batch covering safe, unsafe, and boundary rows.
fn random_batch(seed: u64, m: usize) -> ScoreBatch {
    let mut rng = Rng::new(seed);
    let mut b = ScoreBatch::with_bins(T_BINS);
    b.capacity = 20.0;
    b.theta = 0.05;
    b.lambda = 0.6;
    b.alpha = [0.45, 0.25, 0.15, 0.15];
    b.beta = [0.45, 0.2, 0.15, 0.2];
    for _ in 0..m {
        let base = rng.uniform_range(1.0, 19.0);
        let mu: Vec<f64> = (0..T_BINS).map(|_| base + rng.uniform_range(-1.0, 1.0)).collect();
        let sigma: Vec<f64> = (0..T_BINS).map(|_| rng.uniform_range(0.02, 2.5)).collect();
        b.push(
            &mu,
            &sigma,
            [rng.uniform(), rng.uniform(), rng.uniform(), rng.uniform()],
            [rng.uniform(), rng.uniform(), rng.uniform()],
            rng.uniform(),
            rng.uniform(),
        );
    }
    b
}

#[test]
fn pjrt_matches_native_scorer() {
    let Some(mut pjrt) = scorer_or_skip() else { return };
    let mut native = NativeScorer;
    for seed in [1u64, 2, 3] {
        // Sizes exercise padding (non-multiples) and multi-chunk batches.
        for m in [1usize, 7, 255, 256, 300] {
            let b = random_batch(seed * 1000 + m as u64, m);
            let a = native.score(&b).expect("native");
            let p = pjrt.score(&b).expect("pjrt");
            assert_eq!(a.score.len(), m);
            assert_eq!(p.score.len(), m);
            for i in 0..m {
                assert!(
                    (a.score[i] - p.score[i]).abs() < 1e-4,
                    "seed {seed} m {m} row {i}: native {} vs pjrt {}",
                    a.score[i],
                    p.score[i]
                );
                assert!(
                    (a.violation[i] - p.violation[i]).abs() < 1e-4,
                    "violation mismatch row {i}"
                );
                assert!(
                    (a.headroom[i] - p.headroom[i]).abs() < 1e-5,
                    "headroom mismatch row {i}"
                );
                // Eligibility may only flip within float noise of theta.
                if (a.violation[i] - b.theta).abs() > 1e-3 {
                    assert_eq!(a.eligible[i], p.eligible[i], "eligibility row {i}");
                }
            }
        }
    }
}

#[test]
fn pjrt_backend_runs_full_simulation_identically() {
    let Some(pjrt) = scorer_or_skip() else { return };
    let mut cfg = SimConfig::default();
    cfg.cluster.layout = "balanced".into();
    cfg.workload.num_jobs = 12;
    cfg.seed = 11;
    let jobs = WorkloadGenerator::new(cfg.workload.clone()).generate(cfg.seed);

    let native_out = SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(cfg.jasda.clone())))
        .run(jobs.clone());
    let pjrt_out = SimEngine::new(
        cfg.clone(),
        Box::new(JasdaScheduler::with_scorer(cfg.jasda.clone(), Box::new(pjrt))),
    )
    .run(jobs);

    assert_eq!(native_out.metrics.unfinished, 0);
    assert_eq!(pjrt_out.metrics.unfinished, 0);
    // Decisions (and therefore the entire trajectory) must match: scores
    // agree to ~1e-6 and WIS tie-breaks are deterministic.
    assert_eq!(native_out.metrics.total_commits, pjrt_out.metrics.total_commits);
    assert_eq!(native_out.metrics.makespan, pjrt_out.metrics.makespan);
    assert_eq!(native_out.metrics.mean_jct(), pjrt_out.metrics.mean_jct());
}

#[test]
fn pjrt_rejects_wrong_bin_count() {
    let Some(mut pjrt) = scorer_or_skip() else { return };
    let mut b = ScoreBatch::with_bins(16);
    b.capacity = 10.0;
    b.theta = 0.05;
    b.lambda = 0.5;
    b.push(&[4.0; 16], &[0.2; 16], [0.5; 4], [0.5; 3], 1.0, 0.5);
    assert!(pjrt.score(&b).is_err(), "T mismatch must be a clean error");
}
