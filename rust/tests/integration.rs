//! Cross-module integration tests: full runs over the public API,
//! schedule-validity audits, trace round-trips through the engine, the
//! threaded protocol runtime, and failure-injection scenarios.

use jasda::baselines::{by_name, ALL_SCHEDULERS};
use jasda::config::{SimConfig, WindowPolicy};
use jasda::jasda::JasdaScheduler;
use jasda::job::JobState;
use jasda::mig::Cluster;
use jasda::sim::{RunOutcome, SimEngine};
use jasda::types::Interval;
use jasda::workload::{load_trace, save_trace, WorkloadGenerator};

fn cfg(seed: u64, n: usize, rate: f64) -> SimConfig {
    let mut c = SimConfig::default();
    c.seed = seed;
    c.cluster.layout = "heterogeneous".into();
    c.workload.num_jobs = n;
    c.workload.arrival_rate_per_sec = rate;
    // Disable compaction so the full schedule can be audited afterwards.
    c.engine.compact_after = 0;
    c
}

/// Audit a finished run: no overlapping reservations anywhere, no
/// reservation before the owning job's arrival, all work conserved.
fn audit(out: &RunOutcome) {
    for s in out.cluster.slices() {
        let entries = s.timeline.entries();
        for w in entries.windows(2) {
            assert!(
                !w[0].interval.overlaps(&w[1].interval),
                "overlap on slice {}: {} vs {}",
                s.id,
                w[0].interval,
                w[1].interval
            );
        }
        for r in entries {
            let job = out.jobs.get(r.job);
            assert!(
                r.interval.start >= job.arrival,
                "job {} scheduled at {} before arrival {}",
                r.job,
                r.interval.start,
                job.arrival
            );
        }
    }
    for job in out.jobs.iter() {
        assert!(job.done_work <= job.total_work() + 1.0, "job {} over-credited", job.id);
        if job.state == JobState::Completed {
            assert!(
                (job.done_work - job.total_work()).abs() < 1.0,
                "job {} completed with work gap",
                job.id
            );
            assert!(job.completed_at.is_some());
        }
    }
}

#[test]
fn every_scheduler_produces_valid_schedules() {
    let c = cfg(5, 40, 0.3);
    let jobs = WorkloadGenerator::new(c.workload.clone()).generate(c.seed);
    for name in ALL_SCHEDULERS {
        let sched = by_name(name, &c.jasda).unwrap();
        let out = SimEngine::new(c.clone(), sched).run(jobs.clone());
        assert_eq!(out.metrics.unfinished, 0, "{name}: {}", out.metrics.summary());
        audit(&out);
    }
}

#[test]
fn trace_round_trip_reproduces_run_exactly() {
    let c = cfg(17, 25, 0.25);
    let jobs = WorkloadGenerator::new(c.workload.clone()).generate(c.seed);
    let dir = std::env::temp_dir().join("jasda_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("round.jsonl");
    save_trace(&path, &jobs).unwrap();
    let reloaded = load_trace(&path).unwrap();

    let a = SimEngine::new(c.clone(), Box::new(JasdaScheduler::new(c.jasda.clone())))
        .run(jobs)
        .metrics;
    let b = SimEngine::new(c.clone(), Box::new(JasdaScheduler::new(c.jasda.clone())))
        .run(reloaded)
        .metrics;
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.total_commits, b.total_commits);
    assert_eq!(a.mean_jct(), b.mean_jct());
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn all_window_policies_complete() {
    let c0 = cfg(23, 30, 0.3);
    let jobs = WorkloadGenerator::new(c0.workload.clone()).generate(c0.seed);
    for policy in WindowPolicy::ALL {
        let mut c = c0.clone();
        c.jasda.window_policy = policy;
        let out = SimEngine::new(c.clone(), Box::new(JasdaScheduler::new(c.jasda.clone())))
            .run(jobs.clone());
        assert_eq!(out.metrics.unfinished, 0, "{policy:?}");
        audit(&out);
    }
}

#[test]
fn multi_window_k_sweep_completes_and_audits() {
    // K-window clearing (ISSUE 1 tentpole): every K (and per-slice mode)
    // must finish the workload with a valid, non-overlapping schedule.
    let c0 = cfg(67, 30, 0.35);
    let jobs = WorkloadGenerator::new(c0.workload.clone()).generate(c0.seed);
    for (k, per_slice) in [(1usize, false), (2, false), (4, false), (1, true)] {
        let mut c = c0.clone();
        c.jasda.announce_k = k;
        c.jasda.announce_per_slice = per_slice;
        let out = SimEngine::new(c.clone(), Box::new(JasdaScheduler::new(c.jasda.clone())))
            .run(jobs.clone());
        assert_eq!(out.metrics.unfinished, 0, "k={k} per_slice={per_slice}");
        audit(&out);
    }
}

#[test]
fn multi_window_raises_commit_throughput_under_burst() {
    // ISSUE 1 acceptance: with K > 1 on a contended burst, commitments
    // per iteration strictly exceed the K=1 baseline and makespan does
    // not regress. A long iteration period puts the run in the
    // decision-round-limited regime where one window per round visibly
    // serializes the cluster.
    let mut c = cfg(71, 40, 0.3);
    c.workload.arrival_rate_per_sec = 1e6; // effectively simultaneous burst
    c.engine.iteration_period = 500;
    let jobs = WorkloadGenerator::new(c.workload.clone()).generate(c.seed);

    let run_with = |k: usize, per_slice: bool| {
        let mut ck = c.clone();
        ck.jasda.announce_k = k;
        ck.jasda.announce_per_slice = per_slice;
        SimEngine::new(ck.clone(), Box::new(JasdaScheduler::new(ck.jasda.clone())))
            .run(jobs.clone())
            .metrics
    };
    let base = run_with(1, false);
    assert_eq!(base.unfinished, 0);
    for (k, per_slice) in [(4usize, false), (1, true)] {
        let m = run_with(k, per_slice);
        assert_eq!(m.unfinished, 0, "k={k} per_slice={per_slice}");
        assert!(
            m.commits_per_iteration() > base.commits_per_iteration(),
            "k={k} per_slice={per_slice}: {:.3} commits/iter vs baseline {:.3}",
            m.commits_per_iteration(),
            base.commits_per_iteration()
        );
        assert!(
            m.makespan <= base.makespan + base.makespan / 20,
            "k={k} per_slice={per_slice}: makespan regressed {} vs {}",
            m.makespan,
            base.makespan
        );
        assert!(m.max_commits_per_iter >= 1);
    }
}

#[test]
fn announce_lead_still_completes() {
    // §5.1(a) mitigation (i): announce windows ahead of their start.
    for lead in [0u64, 100, 1000] {
        let mut c = cfg(29, 20, 0.25);
        c.jasda.announce_lead = lead;
        let out = SimEngine::new(c.clone(), Box::new(JasdaScheduler::new(c.jasda.clone())))
            .run(WorkloadGenerator::new(c.workload.clone()).generate(c.seed));
        assert_eq!(out.metrics.unfinished, 0, "lead {lead}");
        audit(&out);
    }
}

#[test]
fn multi_gpu_scales_out() {
    // Same workload, more GPUs -> makespan must not increase (and should
    // drop substantially under contention).
    let mut jcts = Vec::new();
    for gpus in [1u32, 2, 4] {
        let mut c = cfg(31, 60, 0.6);
        c.cluster.num_gpus = gpus;
        let out = SimEngine::new(c.clone(), Box::new(JasdaScheduler::new(c.jasda.clone())))
            .run(WorkloadGenerator::new(c.workload.clone()).generate(c.seed));
        assert_eq!(out.metrics.unfinished, 0, "gpus {gpus}");
        jcts.push(out.metrics.mean_jct().unwrap());
    }
    assert!(jcts[1] < jcts[0], "2 GPUs should beat 1: {jcts:?}");
    assert!(jcts[2] <= jcts[1] * 1.05, "4 GPUs should not be worse than 2: {jcts:?}");
}

#[test]
fn misreporters_lose_trust_end_to_end() {
    let mut c = cfg(37, 40, 0.3);
    c.workload.misreport_fraction = 0.25;
    c.workload.misreport_bias = 0.9;
    let jobs = WorkloadGenerator::new(c.workload.clone()).generate(c.seed);
    let liars: Vec<u32> =
        jobs.iter().filter(|j| j.misreport_bias > 0.0).map(|j| j.id).collect();
    assert!(!liars.is_empty());

    let mut sched = JasdaScheduler::new(c.jasda.clone());
    // Run through the engine by boxing a reference-capturing wrapper is
    // not possible; instead run and inspect rho through stats afterwards.
    let out = SimEngine::new(c.clone(), Box::new(JasdaScheduler::new(c.jasda.clone())))
        .run(jobs.clone());
    assert_eq!(out.metrics.unfinished, 0);
    let mean_rho = out.scheduler_stats.get("mean_rho").unwrap().as_f64().unwrap();
    assert!(mean_rho < 1.0, "misreporting population must dent mean rho");

    // Direct check on a standalone scheduler fed by the engine.
    let out2 = {
        let mut eng = SimEngine::new(c.clone(), Box::new(JasdaScheduler::new(c.jasda.clone())));
        eng.run(jobs)
    };
    let _ = &mut sched;
    assert_eq!(out2.metrics.unfinished, 0);
}

#[test]
fn protocol_matches_engine_population() {
    // The threaded protocol runtime must complete the same workload the
    // in-process engine completes.
    let c = cfg(41, 15, 0.25);
    let jobs = WorkloadGenerator::new(c.workload.clone()).generate(c.seed);
    let engine_out = SimEngine::new(c.clone(), Box::new(JasdaScheduler::new(c.jasda.clone())))
        .run(jobs.clone());
    assert_eq!(engine_out.metrics.unfinished, 0);
    let proto = jasda::coordinator::run_protocol(c, jobs, 3_000_000);
    assert_eq!(proto.completed_jobs, proto.total_jobs, "{proto:?}");
    assert!(proto.awards >= proto.total_jobs as u64);
}

#[test]
fn sharded_framed_protocol_completes_generated_workload() {
    // End-to-end over the deployment-shaped stack: two leader shards,
    // every message crossing as wire frames, bandwidth-lean announces.
    // The same workload the engine and the single-leader protocol
    // complete must complete here too, with no backpressure drops.
    let mut c = cfg(41, 15, 0.25);
    c.jasda.shards = 2;
    c.jasda.transport = jasda::config::TransportKind::Framed;
    c.jasda.announce_top = 2;
    c.jasda.announce_per_slice = true;
    let jobs = WorkloadGenerator::new(c.workload.clone()).generate(c.seed);
    let n = jobs.len();
    let proto = jasda::coordinator::run_protocol(c, jobs, 3_000_000);
    assert_eq!(proto.completed_jobs, n, "{proto:?}");
    assert_eq!(proto.sends_dropped, 0, "synchronous rounds must not fill inboxes");
}

#[test]
fn burst_arrival_storm_is_absorbed() {
    // Failure injection: all jobs arrive at t=0 (worst-case burst).
    let mut c = cfg(43, 50, 10.0);
    c.workload.arrival_rate_per_sec = 1e6; // effectively simultaneous
    let jobs = WorkloadGenerator::new(c.workload.clone()).generate(c.seed);
    assert!(jobs.iter().all(|j| j.arrival < 100));
    let out = SimEngine::new(c.clone(), Box::new(JasdaScheduler::new(c.jasda.clone())))
        .run(jobs);
    assert_eq!(out.metrics.unfinished, 0, "{}", out.metrics.summary());
    audit(&out);
}

#[test]
fn degenerate_single_job_single_slice() {
    let mut c = cfg(47, 1, 0.1);
    c.cluster.layout = "whole".into();
    let out = SimEngine::new(c.clone(), Box::new(JasdaScheduler::new(c.jasda.clone())))
        .run(WorkloadGenerator::new(c.workload.clone()).generate(c.seed));
    assert_eq!(out.metrics.unfinished, 0);
    let m = &out.metrics;
    // A lone job on a whole GPU: slowdown should be close to the declared
    // duration margin (certainly < 2).
    assert!(m.max_slowdown().unwrap() < 2.0, "{}", m.summary());
}

#[test]
fn cluster_window_queries_respect_commitments() {
    // White-box: after a run, candidate windows never overlap existing
    // reservations.
    let c = cfg(53, 20, 0.3);
    let out = SimEngine::new(c.clone(), Box::new(JasdaScheduler::new(c.jasda.clone())))
        .run(WorkloadGenerator::new(c.workload.clone()).generate(c.seed));
    let cluster: &Cluster = &out.cluster;
    let mid = out.metrics.makespan / 2;
    for w in cluster.candidate_windows(mid, 50_000, 10) {
        let slice = cluster.slice(w.slice);
        assert!(
            slice.timeline.is_free(&Interval::new(w.interval.start, w.interval.end)),
            "candidate window overlaps a reservation"
        );
    }
}

#[test]
fn config_json_drives_run() {
    let text = r#"{
        "seed": 9,
        "cluster": {"num_gpus": 1, "layout": "balanced"},
        "workload": {"num_jobs": 8, "arrival_rate_per_sec": 0.2},
        "jasda": {"lambda": 0.7, "window_policy": "slack_aware"}
    }"#;
    let c = SimConfig::from_json_str(text).unwrap();
    c.validate().unwrap();
    assert_eq!(c.jasda.window_policy, WindowPolicy::SlackAware);
    let out = SimEngine::new(c.clone(), Box::new(JasdaScheduler::new(c.jasda.clone())))
        .run(WorkloadGenerator::new(c.workload.clone()).generate(c.seed));
    assert_eq!(out.metrics.unfinished, 0);
}

#[test]
fn repack_mode_completes_and_reports() {
    let mut c = cfg(59, 40, 0.4);
    c.jasda.repack = true;
    let out = SimEngine::new(c.clone(), Box::new(JasdaScheduler::new(c.jasda.clone())))
        .run(WorkloadGenerator::new(c.workload.clone()).generate(c.seed));
    assert_eq!(out.metrics.unfinished, 0);
    audit(&out);
    let repacks = out.scheduler_stats.get("repack_iterations").unwrap().as_u64().unwrap();
    // Under this contended trace fragmentation crosses the threshold at
    // least occasionally.
    assert!(repacks > 0, "repack never triggered");
}

#[test]
fn slow_agent_bids_are_dropped_without_blocking_the_round() {
    // ISSUE 7 satellite: drop-don't-block at the transport boundary.
    // Two responsive hand-rolled agents plus one stalled agent whose
    // depth-1 inbox is already full: the announce broadcast drops only
    // the slow agent's copy, the round's collection sees exactly the
    // fast agents' bids, and nothing ever blocks.
    use jasda::coordinator::messages::{AgentReply, CompletionReport, ToAgent};
    use jasda::coordinator::transport::{LoopbackTransport, Recv, Transport};
    use std::sync::mpsc;
    use std::time::{Duration, Instant};

    let (reply_tx, replies) = mpsc::channel::<AgentReply>();
    let mut to_agents = Vec::new();
    let mut handles = Vec::new();
    for agent in 0..2u32 {
        let (tx, rx) = mpsc::sync_channel::<ToAgent>(4);
        to_agents.push(tx);
        let rtx = reply_tx.clone();
        handles.push(std::thread::spawn(move || {
            while let Ok(msg) = rx.recv() {
                match msg {
                    ToAgent::Announce { round, .. } => {
                        let _ = rtx.send(AgentReply::Bid {
                            job: agent,
                            round,
                            bids: vec![],
                            done: false,
                        });
                    }
                    ToAgent::Shutdown => break,
                    _ => {}
                }
            }
        }));
    }
    // The slow agent: a depth-1 inbox nobody drains, pre-filled so the
    // next send must drop rather than block.
    let (slow_tx, _slow_rx_keepalive) = mpsc::sync_channel::<ToAgent>(1);
    slow_tx
        .try_send(ToAgent::Completed(CompletionReport {
            planned_work: 1.0,
            realized_work: 1.0,
            at: 0,
        }))
        .unwrap();
    to_agents.push(slow_tx);
    drop(reply_tx);
    let mut t = LoopbackTransport::from_parts(to_agents, replies, handles);

    let announce =
        ToAgent::Announce { round: 9, now: 0, windows: std::sync::Arc::new(Vec::new()) };
    let mut dropped = Vec::new();
    let delivered = t.broadcast(&announce, &[], &mut dropped);
    assert_eq!(delivered, 2, "both fast agents get the announce");
    assert_eq!(dropped, vec![2], "only the stalled agent's copy is dropped");

    // Collect exactly `delivered` replies under a deadline: the round
    // completes with the fast agents' bids and no trace of agent 2.
    let deadline = Instant::now() + Duration::from_secs(5);
    let mut got = Vec::new();
    while got.len() < delivered {
        match t.recv_deadline(Some(deadline)) {
            Recv::Msg(AgentReply::Bid { job, round, .. }) => {
                assert_eq!(round, 9);
                got.push(job);
            }
            other => panic!("expected a fast agent's bid, got {other:?}"),
        }
    }
    got.sort_unstable();
    assert_eq!(got, vec![0, 1], "exactly the fast agents' bids, nobody else's");
    assert!(matches!(t.try_recv(), Recv::Empty), "no stray replies");
    t.shutdown();
}

#[test]
fn corrupt_reply_frames_surface_and_do_not_wedge_the_protocol() {
    // ISSUE 7 satellite: a reply frame that fails wire decoding is a
    // leader-visible event, not a silent loss. With `corrupt = 1.0`
    // every agent's reply is corrupted exactly once somewhere in the
    // fault horizon; the run must still complete every job, the rejects
    // must be counted — and because a reject is *counted as that
    // agent's reply*, no round waits out its deadline for it.
    let mut c = cfg(41, 8, 0.25);
    c.jasda.transport = jasda::config::TransportKind::Framed;
    c.jasda.round_timeout_ms = 500;
    c.jasda.faults.seed = 7;
    c.jasda.faults.corrupt = 1.0;
    c.jasda.faults.horizon_rounds = 16;
    c.validate().unwrap();
    let jobs = WorkloadGenerator::new(c.workload.clone()).generate(c.seed);
    let n = jobs.len();
    let proto = jasda::coordinator::run_protocol(c, jobs, 3_000_000);
    assert_eq!(proto.completed_jobs, n, "{proto:?}");
    assert!(proto.frames_rejected >= 1, "corrupt frames must be counted: {proto:?}");
    assert_eq!(
        proto.rounds_timed_out, 0,
        "a reject is a counted reply — it must not burn the deadline: {proto:?}"
    );
}

#[test]
fn protocol_survives_randomized_fault_storm_with_counters() {
    // ISSUE 7 tentpole, end-to-end over a generated workload: crash
    // windows (including after-announce crashes — the scenario that
    // wedged the deadline-less loop), stragglers, corruption, and drops
    // all at once. The run must complete every job — which exercises
    // deadline expiry, quarantine, backoff probes, and Resync healing —
    // and the outcome counters must show the storm actually happened.
    let mut c = cfg(41, 10, 0.25);
    c.jasda.round_timeout_ms = 400;
    c.jasda.faults.seed = 11;
    c.jasda.faults.crash = 0.7;
    c.jasda.faults.delay = 0.4;
    c.jasda.faults.corrupt = 0.4;
    c.jasda.faults.drop = 0.4;
    c.jasda.faults.horizon_rounds = 32;
    c.jasda.faults.crash_rounds = 10;
    c.validate().unwrap();
    let jobs = WorkloadGenerator::new(c.workload.clone()).generate(c.seed);
    let n = jobs.len();
    let proto = jasda::coordinator::run_protocol(c, jobs, 3_000_000);
    assert_eq!(proto.completed_jobs, n, "fault storm must not lose jobs: {proto:?}");
    assert!(
        proto.rounds_timed_out
            + proto.stragglers
            + proto.sends_dropped
            + proto.frames_rejected
            > 0,
        "the storm must leave a trace in the counters: {proto:?}"
    );
}

#[test]
fn duration_weighted_clearing_reduces_atomization() {
    let c0 = cfg(61, 40, 0.35);
    let jobs = WorkloadGenerator::new(c0.workload.clone()).generate(c0.seed);
    let plain = SimEngine::new(c0.clone(), Box::new(JasdaScheduler::new(c0.jasda.clone())))
        .run(jobs.clone())
        .metrics;
    let mut c = c0.clone();
    c.jasda.duration_weighted_clearing = true;
    let dw = SimEngine::new(c.clone(), Box::new(JasdaScheduler::new(c.jasda.clone())))
        .run(jobs)
        .metrics;
    assert_eq!(plain.unfinished, 0);
    assert_eq!(dw.unfinished, 0);
    // Measured F6 finding (EXPERIMENTS.md): duration weighting does NOT
    // reduce atomization, because variant generation caps chunk length at
    // the atom size — the bid pool contains no long variants for the
    // weighted objective to prefer. The ablation documents that the
    // subjob inflation lives in announcement/generation, not clearing.
    assert!(
        dw.mean_subjobs().unwrap() <= plain.mean_subjobs().unwrap() * 1.1,
        "dw {} vs plain {}",
        dw.mean_subjobs().unwrap(),
        plain.mean_subjobs().unwrap()
    );
}

// ---------------------------------------------------------------------
// Production scenario harness (ISSUE 10).
// ---------------------------------------------------------------------

#[test]
fn scenario_smoke_all_transports() {
    // A small production-shaped trace — every fairness group present,
    // the "light" adversity preset armed — must run to completion
    // through every transport, and a streamed metrics file written
    // alongside one engine run must parse line by line.
    use jasda::config::TransportKind;
    let mut c = SimConfig::default();
    c.seed = 909;
    c.cluster.layout = "heterogeneous".into();
    let s = &mut c.jasda.scenario;
    s.jobs = 12;
    s.seed = 777;
    s.tenants = 3;
    s.work_cap = 4_000.0; // keep protocol rounds short
    s.deadline_fraction = 0.5;
    s.adversity = "light".into();
    s.metrics_window = 2_000;
    c.jasda.apply_scenario_adversity().unwrap();
    c.validate().unwrap();
    assert!(c.jasda.faults.crash > 0.0, "light preset must arm the fault plan");
    let jobs =
        jasda::workload::ScenarioGenerator::new(c.jasda.scenario.clone()).generate(c.seed);
    let groups: std::collections::BTreeSet<&str> =
        jobs.iter().filter_map(|j| j.class.split_once(':').map(|(g, _)| g)).collect();
    assert_eq!(groups.len(), c.jasda.scenario.tenants, "all fairness groups present");

    for transport in TransportKind::ALL {
        #[cfg(not(unix))]
        let transport = match transport {
            TransportKind::Tcp | TransportKind::Unix => TransportKind::Framed,
            t => t,
        };
        let mut tc = c.clone();
        tc.jasda.transport = transport;
        let out = jasda::coordinator::run_protocol(tc, jobs.clone(), 2_000_000);
        assert_eq!(
            out.completed_jobs,
            out.total_jobs,
            "{}: scenario smoke must complete: {out:?}",
            transport.name()
        );
    }

    // Engine pass with a real file sink: every emitted line is JSON and
    // the stream terminates with the summary record.
    use jasda::metrics::streaming::{StreamingMetrics, DEFAULT_REL_ACCURACY};
    let path = std::env::temp_dir().join("jasda_scenario_smoke_metrics.jsonl");
    let sink = std::io::BufWriter::new(std::fs::File::create(&path).unwrap());
    let sm = StreamingMetrics::new(c.jasda.scenario.metrics_window, DEFAULT_REL_ACCURACY)
        .with_sink(Box::new(sink));
    let sched = Box::new(JasdaScheduler::new(c.jasda.clone()));
    let out = SimEngine::new(c, sched).with_streaming(sm).run(jobs);
    let sm = out.streaming.expect("streaming path");
    assert_eq!(sm.sink_errors(), 0);
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len() as u64, sm.lines_emitted());
    for line in &lines {
        jasda::util::Json::parse(line).expect("streamed line parses as JSON");
    }
    let last = jasda::util::Json::parse(lines.last().unwrap()).unwrap();
    assert_eq!(last.get("type").and_then(jasda::util::Json::as_str), Some("summary"));
    assert_eq!(
        last.get("schema").and_then(jasda::util::Json::as_str),
        Some("jasda.stream_metrics.v1")
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn all_unfinished_trace_emits_no_nan_cells() {
    // NaN audit regression: a trace where nothing ever completes (the
    // job's footprint exceeds every slice) must still render a fully
    // machine-parseable comparison row — `-` cells, never `NaN`/`inf`.
    let mut c = SimConfig::default();
    c.cluster.layout = "heterogeneous".into();
    c.engine.max_time = 50_000;
    let trp = jasda::trp::Trp {
        phases: vec![jasda::trp::Phase::new(1_000.0, 30.0, 0.1, 0.1)],
        duration_cv: 0.0,
    };
    let jobs = vec![jasda::job::Job::new(0, "big", 0, trp, None, 1.0, 100.0, 0.0)];
    let jcfg = c.jasda.clone();
    let out = SimEngine::new(c, Box::new(JasdaScheduler::new(jcfg))).run(jobs);
    assert_eq!(out.metrics.unfinished, 1, "the job must not fit anywhere");
    let row = jasda::report::comparison_row(&out.metrics);
    for cell in &row {
        // (The check is per cell: the *header* "unfinished" legitimately
        // contains the substring "inf".)
        assert!(!cell.contains("NaN") && !cell.contains("inf"), "leaked non-finite: {cell}");
    }
    let mut t = jasda::report::Table::new("t", &jasda::report::comparison_headers());
    t.push_row(row);
    assert!(!t.to_csv().contains("NaN"), "CSV leaked NaN");
}

#[test]
fn million_job_trace_streams_in_log_bounded_memory() {
    // ISSUE 10 acceptance: a 1M-job production trace flows through the
    // streaming layer job by job — no per-job vectors anywhere — and the
    // aggregator's distribution state stays O(buckets), three orders of
    // magnitude below the job count.
    use jasda::metrics::streaming::{StreamingMetrics, DEFAULT_REL_ACCURACY};
    let mut s = jasda::config::ScenarioConfig::default();
    s.jobs = 1_000_000;
    s.seed = 99;
    let gen = jasda::workload::ScenarioGenerator::new(s);
    let mut sm = StreamingMetrics::new(50_000, DEFAULT_REL_ACCURACY)
        .with_sink(Box::new(std::io::sink()));
    let mut makespan = 0u64;
    gen.for_each(0, |job| {
        let work = job.trp.total_work();
        let completed = job.arrival + (work * 1.5) as u64;
        makespan = makespan.max(completed);
        sm.record_completion(
            &job.class,
            job.weight,
            job.arrival,
            completed,
            work,
            (work / 100.0).ceil() as u32,
            (work * 0.1) as u64,
            job.deadline,
        );
    });
    sm.finalize(0.9, 0.1, makespan);
    assert_eq!(sm.completed(), 1_000_000);
    assert!(sm.total_buckets() < 2_000, "buckets: {}", sm.total_buckets());
    assert!(sm.lines_emitted() > 10, "windows must have rolled: {}", sm.lines_emitted());
    assert!(sm.mean_jct().is_some());
    assert!(sm.jct_percentile(0.99).is_some());
}
