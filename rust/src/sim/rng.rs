//! Deterministic pseudo-random number generation for the simulator.
//!
//! A dependency-free SplitMix64 / xoshiro256** stack with the handful of
//! distributions the framework needs (uniform, normal, exponential,
//! Poisson, log-normal). Every simulation run is fully reproducible from a
//! single `u64` seed; substreams are derived with [`Rng::fork`] so that
//! adding a consumer does not perturb unrelated streams.

/// Deterministic RNG (xoshiro256** seeded via SplitMix64).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create an RNG from a seed. Distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent substream keyed by `stream`.
    ///
    /// Used to give each job / each module its own stream so that the
    /// number of draws one consumer makes never shifts another consumer's
    /// sequence (critical for apples-to-apples scheduler comparisons).
    pub fn fork(&self, stream: u64) -> Rng {
        // Mix the current state with the stream id through SplitMix64.
        let mut sm = self.s[0] ^ self.s[3] ^ stream.wrapping_mul(0xA24B_AED4_963E_E407);
        Rng::new(splitmix64(&mut sm))
    }

    /// Next raw 64-bit value (xoshiro256**).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (n must be > 0). Lemire-style rejection
    /// keeps the distribution exactly uniform.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (one value per call; the pair's
    /// second member is discarded to keep the stream consumption pattern
    /// simple and predictable).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Normal truncated below at `lo` (resampled; `lo` should be within a
    /// few σ of the mean for efficiency — all our uses are).
    pub fn normal_trunc_lo(&mut self, mean: f64, std: f64, lo: f64) -> f64 {
        if std <= 0.0 {
            return mean.max(lo);
        }
        for _ in 0..64 {
            let x = self.normal_ms(mean, std);
            if x >= lo {
                return x;
            }
        }
        lo // pathological tail: clamp
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0, "exponential rate must be positive");
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Poisson-distributed count with the given mean (Knuth for small
    /// means, normal approximation above 64).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        assert!(mean >= 0.0);
        if mean == 0.0 {
            return 0;
        }
        if mean > 64.0 {
            return self.normal_ms(mean, mean.sqrt()).round().max(0.0) as u64;
        }
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.uniform();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Log-normal: `exp(N(mu, sigma))`.
    #[inline]
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn fork_streams_independent_of_draw_count() {
        let root = Rng::new(99);
        let mut f1 = root.fork(1);
        // Consuming from the root clone must not change what fork(1) yields.
        let mut root2 = Rng::new(99);
        let _ = root2.uniform();
        let mut f1b = Rng::new(99).fork(1);
        assert_eq!(f1.next_u64(), f1b.next_u64());
        let mut f2 = root.fork(2);
        assert_ne!(Rng::new(99).fork(1).next_u64(), f2.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} too far from 0.5");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(11);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal_ms(3.0, 2.0);
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 3.0).abs() < 0.05, "mean {mean}");
        assert!((var - 4.0).abs() < 0.15, "var {var}");
    }

    #[test]
    fn normal_trunc_lo_respects_bound() {
        let mut r = Rng::new(8);
        for _ in 0..2000 {
            assert!(r.normal_trunc_lo(1.0, 5.0, 0.5) >= 0.5);
        }
        // Degenerate std returns clamped mean.
        assert_eq!(r.normal_trunc_lo(1.0, 0.0, 2.0), 2.0);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(6);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let m_small: f64 = (0..n).map(|_| r.poisson(3.0) as f64).sum::<f64>() / n as f64;
        assert!((m_small - 3.0).abs() < 0.1, "small-mean poisson {m_small}");
        let m_big: f64 = (0..n).map(|_| r.poisson(100.0) as f64).sum::<f64>() / n as f64;
        assert!((m_big - 100.0).abs() < 0.5, "large-mean poisson {m_big}");
        assert_eq!(r.poisson(0.0), 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut v: Vec<u32> = (0..20).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
    }
}
