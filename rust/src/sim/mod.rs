//! Simulation substrate: deterministic RNG and the discrete-event engine
//! that realizes committed subjobs and drives pluggable schedulers.

pub mod engine;
pub mod rng;

pub use engine::{Commitment, RunOutcome, Scheduler, SimEngine, SubjobRecord};
pub use rng::Rng;
