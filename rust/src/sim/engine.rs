//! Discrete-event simulation engine.
//!
//! The engine owns the cluster, the job population, simulated time, and
//! the realization of committed subjobs (sampling actual durations and
//! memory trajectories from each job's TRP — the "ground truth" the
//! paper's ex-post verification step compares declarations against).
//! Schedulers plug in through the [`Scheduler`] trait; JASDA and every
//! baseline implement it, so all comparisons share identical substrate
//! dynamics.
//!
//! Operation is iteration-driven (assumption A3 of §4.1): the engine
//! advances in fixed scheduler periods; before each iteration it admits
//! arrivals and processes subjob completions that occurred since the last
//! tick, then calls [`Scheduler::iterate`] and applies the returned
//! commitments.

use crate::config::SimConfig;
use crate::job::{utility, JobSet, JobState};
use crate::metrics::streaming::StreamingMetrics;
use crate::metrics::{JobMetrics, RunMetrics};
use crate::mig::{Cluster, PartitionLayout, Reservation};
use crate::sim::rng::Rng;
use crate::types::{Interval, JobId, SliceId, Time};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

/// A scheduling decision: reserve `interval` on `slice` for a subjob of
/// `job` covering `work` (full-GPU tick equivalents).
#[derive(Debug, Clone)]
pub struct Commitment {
    /// Job receiving the reservation.
    pub job: JobId,
    /// Target slice.
    pub slice: SliceId,
    /// Reserved interval (declared duration).
    pub interval: Interval,
    /// Planned work chunk.
    pub work: f64,
    /// Declared job-side feature vector (what the job claimed).
    pub declared_phi: [f64; 4],
    /// Composite score at selection time (diagnostics).
    pub score: f64,
    /// Length of the announced window the variant was selected from
    /// (needed to re-evaluate the energy feature ex post).
    pub window_len: u64,
}

/// Everything known about a subjob after it finished: the input to the
/// ex-post verification step (paper Eq. (6)) and to metrics.
#[derive(Debug, Clone)]
pub struct SubjobRecord {
    /// Owning job.
    pub job: JobId,
    /// Slice it ran on.
    pub slice: SliceId,
    /// Per-job subjob sequence number.
    pub subjob_seq: u32,
    /// Originally reserved interval.
    pub reserved: Interval,
    /// Actual end time (≤ reserved.end; ≥ start).
    pub realized_end: Time,
    /// Planned work.
    pub planned_work: f64,
    /// Work actually completed (< planned if the reservation ran out).
    pub realized_work: f64,
    /// Declared feature vector φ (possibly misreported).
    pub declared_phi: [f64; 4],
    /// Observed feature vector φ^observed, measured from the realization.
    pub observed_phi: [f64; 4],
    /// Commit time.
    pub committed_at: Time,
}

/// A pluggable scheduler. JASDA and all baselines implement this.
pub trait Scheduler {
    /// Human-readable scheduler name (used in reports).
    fn name(&self) -> &str;

    /// One scheduling iteration at time `now`. May inspect the cluster
    /// and mutate per-job bookkeeping (e.g. bid counters), and returns
    /// the commitments to apply. Returned intervals must start at or
    /// after `now` and must be reservable (non-overlapping).
    fn iterate(
        &mut self,
        now: Time,
        cluster: &Cluster,
        jobs: &mut JobSet,
        rng: &mut Rng,
    ) -> Vec<Commitment>;

    /// Post-execution feedback (drives JASDA's calibration loop §4.2.1).
    fn on_subjob_complete(&mut self, _rec: &SubjobRecord) {}

    /// Scheduler-internal diagnostics for reports.
    fn stats(&self) -> crate::util::Json {
        crate::util::Json::Obj(Default::default())
    }
}

/// Pending completion event.
#[derive(Debug, Clone)]
struct PendingCompletion {
    fire_at: Time,
    rec: SubjobRecord,
    /// remaining_work of the job at commit time (for observed φ_JCT).
    speed: f64,
    window_len: u64,
    realized_duration: u64,
}

/// Heap key: (time, seq) so simultaneous completions pop deterministically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct HeapKey(Time, u64);

/// Result of a full simulation run.
#[derive(Debug)]
pub struct RunOutcome {
    /// Aggregated metrics. On the streaming path `metrics.jobs` is left
    /// empty (no per-job vector); run-level counters are still filled.
    pub metrics: RunMetrics,
    /// Final cluster state (timelines retain uncompacted history).
    pub cluster: Cluster,
    /// Final job states.
    pub jobs: JobSet,
    /// Scheduler diagnostics (`Scheduler::stats`).
    pub scheduler_stats: crate::util::Json,
    /// Streaming metrics, if the engine ran with
    /// [`SimEngine::with_streaming`] (production-scale path).
    pub streaming: Option<StreamingMetrics>,
}

/// The simulation engine.
pub struct SimEngine {
    cfg: SimConfig,
    scheduler: Box<dyn Scheduler>,
    events: BinaryHeap<Reverse<(HeapKey, usize)>>,
    /// Slab of in-flight completions. Fired entries are taken out of
    /// their slot and the index goes onto `free_slots` for reuse, so
    /// memory stays O(max outstanding subjobs) instead of O(total
    /// subjobs) over a long run.
    pending: Vec<Option<PendingCompletion>>,
    free_slots: Vec<usize>,
    event_seq: u64,
    streaming: Option<StreamingMetrics>,
}

impl SimEngine {
    /// Build an engine for `cfg` driving the given scheduler.
    pub fn new(cfg: SimConfig, scheduler: Box<dyn Scheduler>) -> Self {
        SimEngine {
            cfg,
            scheduler,
            events: BinaryHeap::new(),
            pending: Vec::new(),
            free_slots: Vec::new(),
            event_seq: 0,
            streaming: None,
        }
    }

    /// Attach a streaming metrics aggregator (the production-scale
    /// path): per-job bookkeeping is dropped as soon as each job
    /// completes and `RunMetrics::jobs` stays empty, so metrics memory
    /// is O(histogram buckets + active jobs) instead of O(total jobs).
    pub fn with_streaming(mut self, streaming: StreamingMetrics) -> Self {
        self.streaming = Some(streaming);
        self
    }

    /// Take a fired completion out of its slab slot, recycling the slot.
    fn take_pending(&mut self, idx: usize) -> PendingCompletion {
        let pc = self.pending[idx].take().expect("completion event fired twice");
        self.free_slots.push(idx);
        pc
    }

    /// Run the simulation over a job population until every job
    /// completes (or `engine.max_time` elapses). Returns the outcome.
    pub fn run(&mut self, jobs: Vec<crate::job::Job>) -> RunOutcome {
        let layout = PartitionLayout::stock(&self.cfg.cluster.layout)
            .expect("validated layout name");
        let mut cluster = Cluster::new(self.cfg.cluster.num_gpus, &layout);
        let mut jobs = JobSet::new(jobs);
        let mut rng = Rng::new(self.cfg.seed).fork(0xE46); // engine realization stream
        let mut sched_rng = Rng::new(self.cfg.seed).fork(0x5C4E); // scheduler stream

        let mut metrics = RunMetrics {
            scheduler: self.scheduler.name().to_string(),
            ..RunMetrics::default()
        };
        if let Some(sm) = self.streaming.as_mut() {
            sm.scheduler = self.scheduler.name().to_string();
        }
        // Starvation bookkeeping is keyed by JobId (not slot index):
        // trace workloads may carry non-contiguous or non-zero-based ids.
        // Populated lazily on a job's first commitment (with the arrival
        // time as the fallback baseline), so the maps never hold more
        // than the jobs that have actually been touched — and on the
        // streaming path entries are dropped again at job completion.
        let mut max_waits: BTreeMap<JobId, u64> = BTreeMap::new();
        let mut last_progress: BTreeMap<JobId, Time> = BTreeMap::new();
        let mut last_event_time: Time = 0;
        let mut completed_jobs: usize = 0;
        let total_jobs = jobs.len();

        let period = self.cfg.engine.iteration_period;
        let mut now: Time = jobs.iter().map(|j| j.arrival).min().unwrap_or(0);
        let mut last_compact: Time = now;
        // Utilization accounting survives history compaction: busy time in
        // compacted regions is folded into `busy_acc` before entries drop.
        let mut busy_acc: f64 = 0.0;
        let mut compact_base: Time = now;

        loop {
            // 1. Fire completions due by `now`.
            while let Some(Reverse((HeapKey(t, _), idx))) = self.events.peek().copied() {
                if t > now {
                    break;
                }
                self.events.pop();
                let pc = self.take_pending(idx);
                if let Some(done) =
                    self.handle_completion(&pc, &mut cluster, &mut jobs, &mut metrics)
                {
                    completed_jobs += 1;
                    self.note_job_finished(done, &jobs, &mut max_waits, &mut last_progress);
                }
                last_event_time = last_event_time.max(pc.rec.realized_end);
            }

            // 2. Admit arrivals.
            jobs.admit_until(now);

            // 3. Scheduler iteration.
            let t0 = std::time::Instant::now();
            let commitments = self.scheduler.iterate(now, &cluster, &mut jobs, &mut sched_rng);
            let iter_ns = t0.elapsed().as_nanos() as u64;
            metrics.sched_wall_ns += iter_ns;
            metrics.max_sched_iter_ns = metrics.max_sched_iter_ns.max(iter_ns);
            metrics.iterations += 1;

            // 4. Apply commitments: reserve, track waits, sample realization.
            // Only commitments that actually reserve (apply_commitment
            // drops zero-work/empty no-ops) count toward the
            // per-iteration throughput metric.
            let mut applied_commits = 0u64;
            for c in commitments {
                if self.apply_commitment(&c, now, &mut cluster, &mut jobs, &mut rng, &mut metrics)
                {
                    applied_commits += 1;
                    if let Some(sm) = self.streaming.as_mut() {
                        sm.record_commit(now);
                    }
                }
                let since = last_progress
                    .get(&c.job)
                    .copied()
                    .unwrap_or_else(|| jobs.get(c.job).arrival);
                let wait = now.saturating_sub(since);
                let w = max_waits.entry(c.job).or_insert(0);
                *w = (*w).max(wait);
                last_progress.insert(c.job, now);
            }
            metrics.max_commits_per_iter = metrics.max_commits_per_iter.max(applied_commits);

            // 5. Track waiting (starvation) for still-waiting active jobs.
            // (max_wait is finalized lazily; see final pass below.)

            // 6. Compact old history (accumulating busy time first).
            if self.cfg.engine.compact_after > 0
                && now > last_compact + self.cfg.engine.compact_after
            {
                let keep_from = now.saturating_sub(self.cfg.engine.compact_after);
                for s in cluster.slices() {
                    busy_acc += s.speed() * s.timeline.busy_ticks(compact_base, keep_from) as f64;
                }
                cluster.compact_before(keep_from);
                compact_base = keep_from;
                last_compact = now;
            }

            // 7. Termination. (The running counter mirrors
            // `jobs.all_completed()` without an O(jobs) scan per tick.)
            if completed_jobs == total_jobs && self.events.is_empty() {
                break;
            }
            if now >= self.cfg.engine.max_time {
                break;
            }
            now += period;
        }

        // Drain outstanding completions past the horizon.
        while let Some(Reverse((HeapKey(t, _), idx))) = self.events.pop() {
            let _ = t;
            let pc = self.take_pending(idx);
            if let Some(done) = self.handle_completion(&pc, &mut cluster, &mut jobs, &mut metrics) {
                completed_jobs += 1;
                self.note_job_finished(done, &jobs, &mut max_waits, &mut last_progress);
            }
            last_event_time = last_event_time.max(pc.rec.realized_end);
        }
        let _ = completed_jobs;

        // Finalize waiting gaps for unfinished jobs.
        for j in jobs.iter() {
            if j.state == JobState::Active {
                let since = last_progress.get(&j.id).copied().unwrap_or(j.arrival);
                let wait = now.saturating_sub(since);
                let w = max_waits.entry(j.id).or_insert(0);
                *w = (*w).max(wait);
            }
        }

        let first_arrival = jobs.iter().map(|j| j.arrival).min().unwrap_or(0);
        let makespan = jobs
            .iter()
            .filter_map(|j| j.completed_at)
            .max()
            .unwrap_or(last_event_time.max(now));
        metrics.makespan = makespan;
        // Utilization over [first_arrival, busy_end): accumulated busy time
        // from compacted history plus what the timelines still hold.
        let busy_end = makespan.max(last_event_time).max(first_arrival + 1);
        let mut busy_total = busy_acc;
        let mut cap_per_tick = 0.0;
        for s in cluster.slices() {
            busy_total += s.speed() * s.timeline.busy_ticks(compact_base, busy_end) as f64;
            cap_per_tick += s.speed();
        }
        let cap = cap_per_tick * (busy_end - first_arrival) as f64;
        metrics.utilization = if cap > 0.0 { (busy_total / cap).clamp(0.0, 1.0) } else { 0.0 };
        // Fragmentation over the retained (uncompacted) span.
        metrics.mean_fragmentation = cluster.mean_fragmentation(compact_base.max(first_arrival), busy_end);
        metrics.unfinished = jobs.iter().filter(|j| j.state != JobState::Completed).count();
        if let Some(sm) = self.streaming.as_mut() {
            // Streaming path: completed jobs were recorded (and their
            // bookkeeping dropped) as they finished; only the unfinished
            // stragglers' waits remain to be folded in. `metrics.jobs`
            // stays empty — no per-job vector on this path.
            for j in jobs.iter() {
                if j.state != JobState::Completed {
                    sm.record_unfinished_wait(max_waits.get(&j.id).copied().unwrap_or(0));
                }
            }
            sm.finalize(metrics.utilization, metrics.mean_fragmentation, makespan);
        } else {
            metrics.jobs = jobs
                .iter()
                .map(|j| JobMetrics {
                    job: j.id,
                    class: j.class.clone(),
                    arrival: j.arrival,
                    completed: j.completed_at,
                    work: j.total_work(),
                    subjobs: j.subjobs_done,
                    max_wait: max_waits.get(&j.id).copied().unwrap_or(0),
                    deadline_met: j.deadline.map(|d| j.completed_at.map_or(false, |c| c <= d)),
                    weight: j.weight,
                })
                .collect();
        }

        RunOutcome {
            metrics,
            cluster,
            jobs,
            scheduler_stats: self.scheduler.stats(),
            streaming: self.streaming.take(),
        }
    }

    /// Streaming-path completion hook: fold the finished job into the
    /// aggregator and drop its per-job bookkeeping so memory tracks
    /// *active* jobs, not total jobs. No-op on the exact path (the final
    /// per-job pass still needs the maps there).
    fn note_job_finished(
        &mut self,
        id: JobId,
        jobs: &JobSet,
        max_waits: &mut BTreeMap<JobId, u64>,
        last_progress: &mut BTreeMap<JobId, Time>,
    ) {
        if let Some(sm) = self.streaming.as_mut() {
            let wait = max_waits.remove(&id).unwrap_or(0);
            last_progress.remove(&id);
            sm.record_job(jobs.get(id), wait);
        }
    }

    /// Apply one commitment: validate + reserve the interval, advance the
    /// job's reserved work, and schedule the realized completion.
    /// Returns false for no-ops (zero effective work / empty interval)
    /// that reserve nothing.
    fn apply_commitment(
        &mut self,
        c: &Commitment,
        now: Time,
        cluster: &mut Cluster,
        jobs: &mut JobSet,
        rng: &mut Rng,
        metrics: &mut RunMetrics,
    ) -> bool {
        let slice_speed = cluster.slice(c.slice).speed();
        let job = jobs.get_mut(c.job);
        debug_assert!(job.state == JobState::Active, "commitment for non-active job");
        let work = c.work.min(job.pending_work());
        if work <= 1e-9 || c.interval.is_empty() {
            return false;
        }
        let seq = job.subjob_seq;
        cluster
            .slice_mut(c.slice)
            .timeline
            .reserve(Reservation { job: c.job, subjob_seq: seq, interval: c.interval })
            .unwrap_or_else(|e| panic!("scheduler {} emitted overlapping commitment: {e}",
                self.scheduler.name()));

        let job = jobs.get_mut(c.job);
        let remaining_at_commit = job.remaining_work();
        job.subjob_seq += 1;
        job.reserved_work += work;
        job.last_selected = now;
        job.last_slice = Some(c.slice);
        job.variants_won += 1;
        metrics.total_commits += 1;

        // Realization: the ground truth the scheduler cannot see yet.
        let realized_duration = job.trp.sample_duration(rng, work, slice_speed);
        let reserved_len = c.interval.len();
        let (realized_end, realized_work) = if realized_duration <= reserved_len {
            (c.interval.start + realized_duration, work)
        } else {
            // Reservation expired first: the subjob checkpoints at the
            // window boundary with proportional progress (atomicity is
            // preserved; the rest re-enters the bid pool).
            (c.interval.end, work * reserved_len as f64 / realized_duration as f64)
        };

        // Observed job-side features (what ex-post verification compares
        // against the declaration).
        let observed_phi = [
            utility::phi_jct(realized_work, remaining_at_commit),
            utility::phi_qos(job, realized_end),
            utility::phi_energy(
                realized_end.saturating_sub(c.interval.start),
                slice_speed,
                c.window_len,
            ),
            c.declared_phi[3], // locality is exact by construction
        ];

        let rec = SubjobRecord {
            job: c.job,
            slice: c.slice,
            subjob_seq: seq,
            reserved: c.interval,
            realized_end,
            planned_work: work,
            realized_work,
            declared_phi: c.declared_phi,
            observed_phi,
            committed_at: now,
        };
        let pc = PendingCompletion {
            fire_at: realized_end,
            rec,
            speed: slice_speed,
            window_len: c.window_len,
            realized_duration,
        };
        let idx = match self.free_slots.pop() {
            Some(slot) => {
                self.pending[slot] = Some(pc);
                slot
            }
            None => {
                self.pending.push(Some(pc));
                self.pending.len() - 1
            }
        };
        self.event_seq += 1;
        self.events.push(Reverse((HeapKey(realized_end, self.event_seq), idx)));
        true
    }

    /// Fire a completion: credit work, free unused reservation tail,
    /// notify the scheduler, finalize the job if done. Returns the job's
    /// id when this completion finished the whole job.
    fn handle_completion(
        &mut self,
        pc: &PendingCompletion,
        cluster: &mut Cluster,
        jobs: &mut JobSet,
        metrics: &mut RunMetrics,
    ) -> Option<JobId> {
        let _ = (pc.speed, pc.window_len, pc.realized_duration, pc.fire_at);
        let rec = &pc.rec;
        let job = jobs.get_mut(rec.job);
        job.reserved_work = (job.reserved_work - rec.planned_work).max(0.0);
        job.done_work += rec.realized_work;
        job.subjobs_done += 1;

        // Early finishers free their reservation tail for future windows.
        if rec.realized_end < rec.reserved.end {
            cluster.slice_mut(rec.slice).timeline.truncate(
                rec.job,
                rec.subjob_seq,
                rec.realized_end,
            );
        }

        let finished = if job.remaining_work() <= 1e-6 && job.state == JobState::Active {
            job.state = JobState::Completed;
            job.completed_at = Some(rec.realized_end);
            Some(rec.job)
        } else {
            None
        };
        let _ = metrics;
        self.scheduler.on_subjob_complete(rec);
        finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::Job;
    use crate::trp::{Phase, Trp};

    /// Trivial greedy scheduler: earliest idle gap, one chunk per
    /// iteration, first bidder wins. Exists to exercise the engine.
    struct GreedyFcfs;

    impl Scheduler for GreedyFcfs {
        fn name(&self) -> &str {
            "greedy-test"
        }

        fn iterate(
            &mut self,
            now: Time,
            cluster: &Cluster,
            jobs: &mut JobSet,
            _rng: &mut Rng,
        ) -> Vec<Commitment> {
            let bidder = match jobs.bidders().map(|j| j.id).min() {
                Some(id) => id,
                None => return vec![],
            };
            let job = jobs.get(bidder);
            // earliest gap on any slice
            let mut best: Option<(SliceId, Interval, f64)> = None;
            for s in cluster.slices() {
                if let Some(g) = s.timeline.earliest_gap(now, now + 10_000, 10) {
                    let cand = (s.id, g.interval, s.speed());
                    if best.map_or(true, |(_, b, _)| cand.1.start < b.start) {
                        best = Some(cand);
                    }
                }
            }
            let (slice, gap, speed) = match best {
                Some(b) => b,
                None => return vec![],
            };
            // Memory check: skip slices the job can't fit on.
            let cap = cluster.slice(slice).capacity_gb();
            if job.trp.peak_mem_gb() > cap {
                return vec![];
            }
            let avail = gap.len().min(2000);
            let work = (avail as f64 * speed).min(job.pending_work());
            let dur = job.trp.predicted_duration(work, speed, 0.9);
            if dur > gap.len() {
                return vec![];
            }
            vec![Commitment {
                job: bidder,
                slice,
                interval: Interval::new(gap.start, gap.start + dur),
                work,
                declared_phi: [0.5; 4],
                score: 0.5,
                window_len: gap.len(),
            }]
        }
    }

    fn tiny_jobs(n: u32) -> Vec<Job> {
        (0..n)
            .map(|i| {
                let trp = Trp {
                    phases: vec![Phase::new(500.0, 3.0, 0.1, 0.1)],
                    duration_cv: 0.05,
                };
                Job::new(i, "tiny", (i as u64) * 100, trp, None, 1.0, 250.0, 0.0)
            })
            .collect()
    }

    fn test_cfg() -> SimConfig {
        let mut cfg = SimConfig::default();
        cfg.cluster.layout = "balanced".into();
        cfg.engine.iteration_period = 20;
        cfg
    }

    #[test]
    fn engine_completes_all_jobs() {
        let mut eng = SimEngine::new(test_cfg(), Box::new(GreedyFcfs));
        let out = eng.run(tiny_jobs(4));
        assert_eq!(out.metrics.unfinished, 0, "all jobs must finish");
        assert!(out.jobs.all_completed());
        for j in out.jobs.iter() {
            assert!(j.completed_at.is_some());
            assert!((j.done_work - j.total_work()).abs() < 1.0);
            assert!(j.subjobs_done >= 1);
        }
        assert!(out.metrics.makespan > 0);
        assert!(out.metrics.utilization > 0.0 && out.metrics.utilization <= 1.0);
        assert!(out.metrics.total_commits >= 4);
    }

    #[test]
    fn engine_is_deterministic() {
        let m1 = SimEngine::new(test_cfg(), Box::new(GreedyFcfs)).run(tiny_jobs(4)).metrics;
        let m2 = SimEngine::new(test_cfg(), Box::new(GreedyFcfs)).run(tiny_jobs(4)).metrics;
        assert_eq!(m1.makespan, m2.makespan);
        assert_eq!(m1.total_commits, m2.total_commits);
        assert_eq!(m1.mean_jct(), m2.mean_jct());
    }

    #[test]
    fn seed_changes_realization() {
        let mut cfg2 = test_cfg();
        cfg2.seed = 1234;
        let m1 = SimEngine::new(test_cfg(), Box::new(GreedyFcfs)).run(tiny_jobs(4)).metrics;
        let m2 = SimEngine::new(cfg2, Box::new(GreedyFcfs)).run(tiny_jobs(4)).metrics;
        // Different realization noise -> (almost surely) different makespan.
        assert_ne!(m1.makespan, m2.makespan);
    }

    #[test]
    fn arrivals_respected() {
        let mut eng = SimEngine::new(test_cfg(), Box::new(GreedyFcfs));
        let out = eng.run(tiny_jobs(3));
        for j in out.jobs.iter() {
            // No subjob may start before the job arrives; JCT >= ideal.
            let jct = j.jct().unwrap();
            assert!(jct as f64 >= 500.0 * 0.5, "jct {jct} suspiciously small");
        }
    }

    #[test]
    fn sparse_job_ids_run_end_to_end() {
        // Regression: starvation stats used to be indexed by `id as usize`
        // and panicked (or corrupted) on non-contiguous trace ids.
        let mut jobs = tiny_jobs(3);
        jobs[0].id = 4_000_000;
        jobs[1].id = 17;
        jobs[2].id = 90;
        let mut eng = SimEngine::new(test_cfg(), Box::new(GreedyFcfs));
        let out = eng.run(jobs);
        assert_eq!(out.metrics.unfinished, 0, "{}", out.metrics.summary());
        let ids: Vec<JobId> = out.metrics.jobs.iter().map(|j| j.job).collect();
        assert_eq!(ids, vec![4_000_000, 17, 90], "reported ids must be the trace ids");
        for j in &out.metrics.jobs {
            assert!(j.completed.is_some());
            assert!(j.max_wait < 1_000_000, "wait stats corrupt for job {}", j.job);
        }
    }

    #[test]
    fn pending_completion_slots_are_reused() {
        // Regression: the pending slab used to grow by one entry per
        // subjob forever. With slot reuse its size is bounded by the
        // maximum number of concurrently outstanding completions, far
        // below the total commit count on a long run. Arrivals are
        // spaced far apart so each job's subjobs complete before the
        // next job shows up — outstanding completions stay small while
        // total commits keep growing.
        let jobs: Vec<Job> = (0..12)
            .map(|i| {
                let trp = Trp {
                    phases: vec![Phase::new(2000.0, 3.0, 0.1, 0.1)],
                    duration_cv: 0.05,
                };
                Job::new(i, "tiny", (i as u64) * 10_000, trp, None, 1.0, 100.0, 0.0)
            })
            .collect();
        let mut eng = SimEngine::new(test_cfg(), Box::new(GreedyFcfs));
        let out = eng.run(jobs);
        assert_eq!(out.metrics.unfinished, 0);
        assert!(out.metrics.total_commits > 30, "want many subjobs, got {}", out.metrics.total_commits);
        assert!(
            (eng.pending.len() as u64) < out.metrics.total_commits / 2,
            "slab grew like total commits: {} slots for {} commits",
            eng.pending.len(),
            out.metrics.total_commits
        );
        // Every slot is free again after the run drains.
        assert_eq!(eng.free_slots.len(), eng.pending.len());
        assert!(eng.pending.iter().all(|s| s.is_none()));
    }

    #[test]
    fn streaming_matches_exact_on_small_run() {
        let exact = SimEngine::new(test_cfg(), Box::new(GreedyFcfs)).run(tiny_jobs(4));
        let sm = crate::metrics::streaming::StreamingMetrics::new(1_000, 0.01);
        let out =
            SimEngine::new(test_cfg(), Box::new(GreedyFcfs)).with_streaming(sm).run(tiny_jobs(4));
        let s = out.streaming.expect("streaming outcome present");
        assert!(out.metrics.jobs.is_empty(), "no per-job vector on the streaming path");
        assert_eq!(s.utilization(), exact.metrics.utilization);
        assert_eq!(s.makespan(), exact.metrics.makespan);
        let done = exact.metrics.jobs.iter().filter(|j| j.completed.is_some()).count();
        assert_eq!(s.completed() as usize, done);
        assert_eq!(s.unfinished() as usize, exact.metrics.unfinished);
        let (a, b) = (s.mean_jct().unwrap(), exact.metrics.mean_jct().unwrap());
        assert!((a - b).abs() < 1e-9 * b.max(1.0), "mean jct {a} vs {b}");
        assert_eq!(s.max_starvation(), exact.metrics.max_starvation());
    }

    #[test]
    fn max_time_guard_stops_runaway() {
        // A scheduler that never schedules anything.
        struct Never;
        impl Scheduler for Never {
            fn name(&self) -> &str {
                "never"
            }
            fn iterate(
                &mut self,
                _: Time,
                _: &Cluster,
                _: &mut JobSet,
                _: &mut Rng,
            ) -> Vec<Commitment> {
                vec![]
            }
        }
        let mut cfg = test_cfg();
        cfg.engine.max_time = 5_000;
        let out = SimEngine::new(cfg, Box::new(Never)).run(tiny_jobs(2));
        assert_eq!(out.metrics.unfinished, 2);
        assert!(out.metrics.iterations > 0);
    }

    #[test]
    fn observed_features_populated() {
        struct Capture(std::rc::Rc<std::cell::RefCell<Vec<SubjobRecord>>>);
        impl Scheduler for Capture {
            fn name(&self) -> &str {
                "capture"
            }
            fn iterate(
                &mut self,
                now: Time,
                cluster: &Cluster,
                jobs: &mut JobSet,
                rng: &mut Rng,
            ) -> Vec<Commitment> {
                GreedyFcfs.iterate(now, cluster, jobs, rng)
            }
            fn on_subjob_complete(&mut self, rec: &SubjobRecord) {
                self.0.borrow_mut().push(rec.clone());
            }
        }
        let recs = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let out =
            SimEngine::new(test_cfg(), Box::new(Capture(recs.clone()))).run(tiny_jobs(2));
        assert_eq!(out.metrics.unfinished, 0);
        let recs = recs.borrow();
        assert!(!recs.is_empty());
        for r in recs.iter() {
            assert!(r.realized_work > 0.0 && r.realized_work <= r.planned_work + 1e-9);
            assert!(r.realized_end <= r.reserved.end);
            assert!(r.realized_end > r.reserved.start);
            for &phi in &r.observed_phi {
                assert!((0.0..=1.0).contains(&phi), "observed phi {phi} out of range");
            }
        }
    }
}
