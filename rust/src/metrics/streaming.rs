//! Streaming metrics for production-scale traces.
//!
//! The exact [`RunMetrics`](super::RunMetrics) keeps one
//! [`JobMetrics`](super::JobMetrics) record per job, which is fine for the
//! synthetic evaluation traces but caps runs far below the "millions of
//! jobs" north star. This module provides the O(buckets) alternative the
//! production harness (see [`crate::workload::ScenarioGenerator`]) runs on:
//!
//! * [`HistogramSketch`] — a DDSketch-style log-bucketed histogram with a
//!   configurable relative accuracy. Memory is `O(ln(max/min) / ln γ)`
//!   buckets regardless of how many values are recorded (≈700 buckets for
//!   1% accuracy over a `[1, 10⁶]` tick range), and any percentile is
//!   answered within the configured relative error.
//! * [`StreamingMetrics`] — the run-level aggregator: JCT / wait /
//!   slowdown sketches, per-fairness-group counters, deadline hit rates,
//!   and fixed-width time windows that are emitted incrementally as JSONL
//!   to an optional sink while the run progresses.
//!
//! Sums, counts, means, utilization, and the Jain index are computed from
//! exact accumulators and therefore match the in-memory oracle bit for
//! bit; only percentiles are sketch-approximate (within one histogram
//! bucket). The differential property test in `tests/properties.rs` holds
//! both implementations to that contract on randomized small traces.

use std::collections::BTreeMap;
use std::io::Write;

use crate::job::Job;
use crate::types::{Duration, Time};
use crate::util::Json;

/// Default relative accuracy for percentile sketches (1%).
pub const DEFAULT_REL_ACCURACY: f64 = 0.01;

/// DDSketch-style log-bucketed histogram over non-negative values.
///
/// Values `< 1.0` (sub-tick) collapse into a dedicated zero bucket;
/// values `≥ 1.0` land in bucket `ceil(ln v / ln γ)` with
/// `γ = (1 + rel) / (1 - rel)`, so every bucket's representative value is
/// within `rel` relative error of anything stored in it. Count, sum, sum
/// of squares, min, and max are tracked exactly.
#[derive(Debug, Clone)]
pub struct HistogramSketch {
    rel: f64,
    gamma: f64,
    gamma_ln: f64,
    buckets: BTreeMap<i64, u64>,
    zero: u64,
    count: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl HistogramSketch {
    /// New sketch with the given relative accuracy (in `(0, 1)`).
    pub fn new(rel: f64) -> Self {
        assert!(rel > 0.0 && rel < 1.0, "relative accuracy must be in (0,1)");
        let gamma = (1.0 + rel) / (1.0 - rel);
        HistogramSketch {
            rel,
            gamma,
            gamma_ln: gamma.ln(),
            buckets: BTreeMap::new(),
            zero: 0,
            count: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one value. Negative or non-finite values are ignored.
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            return;
        }
        self.count += 1;
        self.sum += v;
        self.sum_sq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if v < 1.0 {
            self.zero += 1;
        } else {
            let idx = (v.ln() / self.gamma_ln).ceil() as i64;
            *self.buckets.entry(idx).or_insert(0) += 1;
        }
    }

    /// Ceil-based nearest-rank percentile (`p` in `[0, 1]`), answered from
    /// bucket representatives and clamped to the observed `[min, max]`.
    pub fn percentile(&self, p: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let rank = ((p * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = self.zero;
        if rank <= seen {
            // Sub-tick values: everything in the zero bucket is < 1.0,
            // so the observed minimum is the tightest representative.
            return Some(self.min);
        }
        for (&idx, &n) in &self.buckets {
            seen += n;
            if rank <= seen {
                let rep = ((idx as f64 - 1.0) * self.gamma_ln).exp() * (1.0 + self.gamma) / 2.0;
                return Some(rep.clamp(self.min, self.max));
            }
        }
        Some(self.max)
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of recorded values.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact sum of squares of recorded values.
    pub fn sum_sq(&self) -> f64 {
        self.sum_sq
    }

    /// Exact mean, if any values were recorded.
    pub fn mean(&self) -> Option<f64> {
        if self.count == 0 {
            None
        } else {
            Some(self.sum / self.count as f64)
        }
    }

    /// Exact minimum recorded value.
    pub fn min(&self) -> Option<f64> {
        if self.count == 0 {
            None
        } else {
            Some(self.min)
        }
    }

    /// Exact maximum recorded value.
    pub fn max(&self) -> Option<f64> {
        if self.count == 0 {
            None
        } else {
            Some(self.max)
        }
    }

    /// Number of occupied buckets — the sketch's memory footprint.
    pub fn bucket_count(&self) -> usize {
        self.buckets.len() + usize::from(self.zero > 0)
    }

    /// Configured relative accuracy.
    pub fn relative_accuracy(&self) -> f64 {
        self.rel
    }
}

/// One fixed-width emission window's counters.
#[derive(Debug, Clone, Default)]
struct Window {
    idx: u64,
    completions: u64,
    commits: u64,
    work: f64,
    deadline_hits: u64,
    deadline_total: u64,
}

impl Window {
    fn is_empty(&self) -> bool {
        self.completions == 0 && self.commits == 0
    }
}

/// Per-fairness-group exact accumulators (keyed by the tenant prefix of
/// the job class, i.e. the part before the first `:`).
#[derive(Debug, Clone, Default)]
pub struct GroupStats {
    /// Completed jobs in this group.
    pub jobs: u64,
    /// Tenant weight (last seen; constant per group by construction).
    pub weight: f64,
    /// Sum of JCTs (ticks) over completed jobs.
    pub jct_sum: f64,
    /// Sum of slowdowns over completed jobs with positive work.
    pub slowdown_sum: f64,
}

/// Streaming replacement for [`RunMetrics`](super::RunMetrics) on large
/// runs: O(buckets) memory, optional incremental JSONL emission.
///
/// The engine calls [`record_commit`](Self::record_commit) per committed
/// subjob, [`record_job`](Self::record_job) per completed job,
/// [`record_unfinished_wait`](Self::record_unfinished_wait) for jobs that
/// never finished, and [`finalize`](Self::finalize) once at the end of the
/// run. Window lines are emitted as each window closes; `finalize` emits
/// the terminal `{"type":"summary",...}` line.
pub struct StreamingMetrics {
    /// Scheduler name that produced the run (stamped by the engine).
    pub scheduler: String,
    window_len: u64,
    cur: Window,
    sink: Option<Box<dyn Write>>,
    jct: HistogramSketch,
    wait: HistogramSketch,
    slowdown: HistogramSketch,
    groups: BTreeMap<String, GroupStats>,
    completed: u64,
    deadline_hits: u64,
    deadline_total: u64,
    unfinished: u64,
    subjobs_sum: u64,
    utilization: f64,
    mean_fragmentation: f64,
    makespan: Time,
    lines_emitted: u64,
    sink_errors: u64,
}

impl std::fmt::Debug for StreamingMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StreamingMetrics")
            .field("scheduler", &self.scheduler)
            .field("completed", &self.completed)
            .field("unfinished", &self.unfinished)
            .field("jct_buckets", &self.jct.bucket_count())
            .field("windows_emitted", &self.lines_emitted)
            .finish_non_exhaustive()
    }
}

fn group_key(class: &str) -> &str {
    class.split(':').next().unwrap_or(class)
}

impl StreamingMetrics {
    /// New aggregator with the given window length (ticks) and percentile
    /// sketch relative accuracy.
    pub fn new(window_len: u64, rel: f64) -> Self {
        assert!(window_len > 0, "window length must be positive");
        StreamingMetrics {
            scheduler: String::new(),
            window_len,
            cur: Window::default(),
            sink: None,
            jct: HistogramSketch::new(rel),
            wait: HistogramSketch::new(rel),
            slowdown: HistogramSketch::new(rel),
            groups: BTreeMap::new(),
            completed: 0,
            deadline_hits: 0,
            deadline_total: 0,
            unfinished: 0,
            subjobs_sum: 0,
            utilization: 0.0,
            mean_fragmentation: 0.0,
            makespan: 0,
            lines_emitted: 0,
            sink_errors: 0,
        }
    }

    /// Attach a JSONL sink (e.g. a buffered file). Without a sink the
    /// aggregator still maintains every statistic; it just emits nothing.
    pub fn with_sink(mut self, sink: Box<dyn Write>) -> Self {
        self.sink = Some(sink);
        self
    }

    /// Advance the current window to the one containing `t`, flushing the
    /// previous window's line if it saw any activity. Event times are
    /// monotone in the engine, so windows close exactly once.
    fn roll(&mut self, t: Time) {
        let w = t / self.window_len;
        if w != self.cur.idx {
            self.flush_window();
            self.cur.idx = w;
        }
    }

    fn flush_window(&mut self) {
        if self.cur.is_empty() {
            return;
        }
        if let Some(sink) = self.sink.as_mut() {
            let line = Json::obj(vec![
                ("type", "window".into()),
                ("t0", (self.cur.idx * self.window_len).into()),
                ("t1", ((self.cur.idx + 1) * self.window_len).into()),
                ("completions", self.cur.completions.into()),
                ("commits", self.cur.commits.into()),
                ("work", self.cur.work.into()),
                ("deadline_hits", self.cur.deadline_hits.into()),
                ("deadline_total", self.cur.deadline_total.into()),
            ]);
            if writeln!(sink, "{line}").is_err() {
                self.sink_errors += 1;
            } else {
                self.lines_emitted += 1;
            }
        }
        self.cur = Window { idx: self.cur.idx, ..Window::default() };
    }

    /// Record one committed subjob at time `now`.
    pub fn record_commit(&mut self, now: Time) {
        self.roll(now);
        self.cur.commits += 1;
    }

    /// Record one completed job from its raw fields.
    #[allow(clippy::too_many_arguments)]
    pub fn record_completion(
        &mut self,
        class: &str,
        weight: f64,
        arrival: Time,
        completed: Time,
        work: f64,
        subjobs: u32,
        max_wait: Duration,
        deadline: Option<Time>,
    ) {
        self.roll(completed);
        self.completed += 1;
        self.subjobs_sum += u64::from(subjobs);
        let jct = completed.saturating_sub(arrival) as f64;
        self.jct.record(jct);
        self.wait.record(max_wait as f64);
        let slowdown = if work > 0.0 {
            let s = jct / work;
            self.slowdown.record(s);
            s
        } else {
            0.0
        };
        self.cur.completions += 1;
        self.cur.work += work;
        if let Some(d) = deadline {
            self.deadline_total += 1;
            self.cur.deadline_total += 1;
            if completed <= d {
                self.deadline_hits += 1;
                self.cur.deadline_hits += 1;
            }
        }
        let key = group_key(class);
        if let Some(g) = self.groups.get_mut(key) {
            g.jobs += 1;
            g.jct_sum += jct;
            g.slowdown_sum += slowdown;
        } else {
            self.groups.insert(
                key.to_string(),
                GroupStats { jobs: 1, weight, jct_sum: jct, slowdown_sum: slowdown },
            );
        }
    }

    /// Record one completed job (adapter over [`record_completion`]).
    ///
    /// [`record_completion`]: Self::record_completion
    pub fn record_job(&mut self, job: &Job, max_wait: Duration) {
        let completed = job.completed_at.expect("record_job requires a completed job");
        self.record_completion(
            &job.class,
            job.weight,
            job.arrival,
            completed,
            job.trp.total_work(),
            job.subjobs_done,
            max_wait,
            job.deadline,
        );
    }

    /// Record a job that never completed within the run. Its longest wait
    /// still feeds the wait sketch, matching the exact oracle.
    pub fn record_unfinished_wait(&mut self, max_wait: Duration) {
        self.unfinished += 1;
        self.wait.record(max_wait as f64);
    }

    /// Close the run: flush the last window, stamp run-level quantities
    /// (computed exactly by the engine), and emit the summary line.
    pub fn finalize(&mut self, utilization: f64, mean_fragmentation: f64, makespan: Time) {
        self.utilization = utilization;
        self.mean_fragmentation = mean_fragmentation;
        self.makespan = makespan;
        self.flush_window();
        if let Some(sink) = self.sink.as_mut() {
            let line = self.render_summary();
            let mut ok = writeln!(sink, "{line}").is_ok();
            ok &= sink.flush().is_ok();
            if ok {
                self.lines_emitted += 1;
            } else {
                self.sink_errors += 1;
            }
        }
    }

    fn render_summary(&self) -> String {
        self.summary_json().to_string()
    }

    /// Exact mean JCT over completed jobs.
    pub fn mean_jct(&self) -> Option<f64> {
        self.jct.mean()
    }

    /// Sketch-approximate JCT percentile over completed jobs.
    pub fn jct_percentile(&self, p: f64) -> Option<f64> {
        self.jct.percentile(p)
    }

    /// Exact mean slowdown over completed jobs with positive work.
    pub fn mean_slowdown(&self) -> Option<f64> {
        self.slowdown.mean()
    }

    /// Exact max slowdown.
    pub fn max_slowdown(&self) -> Option<f64> {
        self.slowdown.max()
    }

    /// Jain fairness index over slowdowns, computed exactly from the
    /// sketch's sum / sum-of-squares accumulators.
    pub fn jain_fairness(&self) -> Option<f64> {
        let n = self.slowdown.count();
        if n == 0 {
            return None;
        }
        let s1 = self.slowdown.sum();
        let s2 = self.slowdown.sum_sq();
        if s2 == 0.0 {
            return None;
        }
        Some(s1 * s1 / (n as f64 * s2))
    }

    /// Sketch-approximate p95 of per-job longest waits (all jobs,
    /// finished or not).
    pub fn p95_wait(&self) -> Option<f64> {
        self.wait.percentile(0.95)
    }

    /// Exact maximum per-job wait (ticks).
    pub fn max_starvation(&self) -> u64 {
        self.wait.max().map_or(0, |m| m as u64)
    }

    /// Exact fraction of deadline-carrying completed jobs that met their
    /// deadline.
    pub fn deadline_met_rate(&self) -> Option<f64> {
        if self.deadline_total == 0 {
            None
        } else {
            Some(self.deadline_hits as f64 / self.deadline_total as f64)
        }
    }

    /// Jobs completed per simulated second.
    pub fn throughput_per_sec(&self) -> f64 {
        if self.makespan == 0 {
            return 0.0;
        }
        self.completed as f64 / (self.makespan as f64 / 1000.0)
    }

    /// Exact mean subjobs per completed job.
    pub fn mean_subjobs(&self) -> Option<f64> {
        if self.completed == 0 {
            None
        } else {
            Some(self.subjobs_sum as f64 / self.completed as f64)
        }
    }

    /// Completed-job count.
    pub fn completed(&self) -> u64 {
        self.completed
    }

    /// Jobs that never completed within the run.
    pub fn unfinished(&self) -> u64 {
        self.unfinished
    }

    /// Compute-weighted utilization (stamped at finalize).
    pub fn utilization(&self) -> f64 {
        self.utilization
    }

    /// Mean per-slice fragmentation (stamped at finalize).
    pub fn mean_fragmentation(&self) -> f64 {
        self.mean_fragmentation
    }

    /// Run makespan (stamped at finalize).
    pub fn makespan(&self) -> Time {
        self.makespan
    }

    /// Per-fairness-group accumulators.
    pub fn groups(&self) -> &BTreeMap<String, GroupStats> {
        &self.groups
    }

    /// Window JSONL lines successfully emitted (incl. the summary line).
    pub fn lines_emitted(&self) -> u64 {
        self.lines_emitted
    }

    /// Sink write failures (counted, never panicking the run).
    pub fn sink_errors(&self) -> u64 {
        self.sink_errors
    }

    /// Total occupied histogram buckets across all three sketches — the
    /// aggregator's distribution-memory footprint.
    pub fn total_buckets(&self) -> usize {
        self.jct.bucket_count() + self.wait.bucket_count() + self.slowdown.bucket_count()
    }

    /// Run summary as JSON (schema `jasda.stream_metrics.v1`). This is
    /// also the terminal JSONL line emitted by [`finalize`](Self::finalize).
    pub fn summary_json(&self) -> Json {
        let opt = |x: Option<f64>| x.map_or(Json::Null, Json::Num);
        Json::obj(vec![
            ("schema", "jasda.stream_metrics.v1".into()),
            ("type", "summary".into()),
            ("scheduler", self.scheduler.clone().into()),
            ("makespan", self.makespan.into()),
            ("utilization", self.utilization.into()),
            ("mean_fragmentation", self.mean_fragmentation.into()),
            ("completed", self.completed.into()),
            ("unfinished", self.unfinished.into()),
            ("mean_jct", opt(self.mean_jct())),
            ("p50_jct", opt(self.jct_percentile(0.5))),
            ("p95_jct", opt(self.jct_percentile(0.95))),
            ("p99_jct", opt(self.jct_percentile(0.99))),
            ("mean_slowdown", opt(self.mean_slowdown())),
            ("max_slowdown", opt(self.max_slowdown())),
            ("jain_fairness", opt(self.jain_fairness())),
            ("p95_wait", opt(self.p95_wait())),
            ("max_starvation", self.max_starvation().into()),
            ("deadline_met_rate", opt(self.deadline_met_rate())),
            ("throughput_per_sec", self.throughput_per_sec().into()),
            ("mean_subjobs", opt(self.mean_subjobs())),
            ("total_buckets", self.total_buckets().into()),
            ("windows_emitted", self.lines_emitted.into()),
            ("sink_errors", self.sink_errors.into()),
            (
                "groups",
                Json::Arr(
                    self.groups
                        .iter()
                        .map(|(k, g)| {
                            let n = g.jobs.max(1) as f64;
                            Json::obj(vec![
                                ("group", k.clone().into()),
                                ("jobs", g.jobs.into()),
                                ("weight", g.weight.into()),
                                ("mean_jct", (g.jct_sum / n).into()),
                                ("mean_slowdown", (g.slowdown_sum / n).into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// One-line human summary (streaming counterpart of
    /// [`RunMetrics::summary`](super::RunMetrics::summary)).
    pub fn summary_line(&self) -> String {
        format!(
            "{} [streaming]: util={:.3} done={} meanJCT={:.0} p95JCT={:.0} jain={:.3} starv={} unfinished={}",
            self.scheduler,
            self.utilization,
            self.completed,
            self.mean_jct().unwrap_or(f64::NAN),
            self.jct_percentile(0.95).unwrap_or(f64::NAN),
            self.jain_fairness().unwrap_or(f64::NAN),
            self.max_starvation(),
            self.unfinished,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    /// A `Write` sink whose buffer stays inspectable after the box moves
    /// into the aggregator.
    #[derive(Clone, Default)]
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);

    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn sketch_percentiles_within_relative_error() {
        let mut h = HistogramSketch::new(0.01);
        for v in 1..=10_000u64 {
            h.record(v as f64);
        }
        for &p in &[0.01, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
            let exact = (p * 10_000.0).ceil().max(1.0);
            let got = h.percentile(p).unwrap();
            let rel_err = (got - exact).abs() / exact;
            assert!(rel_err <= 0.0201, "p{p}: got {got}, exact {exact}, err {rel_err}");
        }
        assert_eq!(h.count(), 10_000);
        assert_eq!(h.min(), Some(1.0));
        assert_eq!(h.max(), Some(10_000.0));
        assert!((h.mean().unwrap() - 5_000.5).abs() < 1e-9);
    }

    #[test]
    fn sketch_memory_is_log_bounded() {
        let mut h = HistogramSketch::new(0.01);
        // 200k values spanning [1, 1e6): bucket count tracks the span's
        // log, not the record count.
        for i in 0..200_000u64 {
            h.record(1.0 + (i as f64 * 4.999)); // up to ~1e6
        }
        let bound = ((1e6f64).ln() / ((1.02f64 / 0.98).ln()) + 8.0) as usize;
        assert!(h.bucket_count() <= bound, "{} buckets > bound {bound}", h.bucket_count());
        assert!(h.bucket_count() < 1_000);
    }

    #[test]
    fn sketch_zero_bucket_and_empty() {
        let empty = HistogramSketch::new(0.05);
        assert_eq!(empty.percentile(0.5), None);
        assert_eq!(empty.mean(), None);
        assert_eq!(empty.min(), None);
        assert_eq!(empty.max(), None);
        assert_eq!(empty.bucket_count(), 0);

        let mut h = HistogramSketch::new(0.05);
        h.record(0.0);
        h.record(0.25);
        h.record(f64::NAN); // ignored
        h.record(-3.0); // ignored
        assert_eq!(h.count(), 2);
        assert_eq!(h.percentile(0.5), Some(0.0));
        assert_eq!(h.bucket_count(), 1);
    }

    #[test]
    fn windows_emit_jsonl_and_summary() {
        let buf = SharedBuf::default();
        let mut m =
            StreamingMetrics::new(1_000, DEFAULT_REL_ACCURACY).with_sink(Box::new(buf.clone()));
        m.scheduler = "jasda".into();
        m.record_commit(100);
        m.record_completion("t0:inf", 1.0, 0, 500, 400.0, 2, 50, Some(600));
        m.record_commit(1_500); // closes window 0
        m.record_completion("t1:train", 2.0, 200, 2_400, 2_000.0, 3, 120, Some(2_000));
        m.record_unfinished_wait(4_000);
        m.finalize(0.8, 0.1, 2_400);

        let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // Window 0, window 1, window 2, summary.
        assert_eq!(lines.len(), 4, "{text}");
        for l in &lines {
            Json::parse(l).expect("every emitted line parses as JSON");
        }
        assert!(lines[0].contains("\"type\":\"window\""));
        assert!(lines[3].contains("\"type\":\"summary\""));
        assert!(lines[3].contains("\"schema\":\"jasda.stream_metrics.v1\""));
        assert_eq!(m.lines_emitted(), 4);
        assert_eq!(m.sink_errors(), 0);
        assert_eq!(m.completed(), 2);
        assert_eq!(m.unfinished(), 1);
        // Deadline: job 0 met (500 <= 600), job 1 missed (2400 > 2000).
        assert_eq!(m.deadline_met_rate(), Some(0.5));
        // Groups keyed by tenant prefix.
        assert_eq!(m.groups().len(), 2);
        assert_eq!(m.groups()["t0"].jobs, 1);
        assert_eq!(m.groups()["t1"].weight, 2.0);
        // Wait sketch includes the unfinished job's wait.
        assert_eq!(m.max_starvation(), 4_000);
    }

    #[test]
    fn no_sink_still_aggregates() {
        let mut m = StreamingMetrics::new(500, 0.01);
        for i in 0..100u64 {
            m.record_completion("t0:mix", 1.0, i * 10, i * 10 + 200, 100.0, 1, 5, None);
        }
        m.finalize(0.5, 0.0, 1_200);
        assert_eq!(m.completed(), 100);
        assert_eq!(m.lines_emitted(), 0);
        assert_eq!(m.mean_jct(), Some(200.0));
        // All slowdowns equal -> Jain index exactly 1.
        assert!((m.jain_fairness().unwrap() - 1.0).abs() < 1e-12);
        assert_eq!(m.summary_json().get("completed").and_then(Json::as_u64), Some(100));
    }

    #[test]
    fn sink_errors_are_counted_not_fatal() {
        struct Failing;
        impl Write for Failing {
            fn write(&mut self, _: &[u8]) -> std::io::Result<usize> {
                Err(std::io::Error::other("nope"))
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Err(std::io::Error::other("nope"))
            }
        }
        let mut m = StreamingMetrics::new(100, 0.01).with_sink(Box::new(Failing));
        m.record_completion("t0:inf", 1.0, 0, 50, 10.0, 1, 0, None);
        m.record_commit(500); // rolls + fails to write window 0
        m.finalize(1.0, 0.0, 500);
        assert!(m.sink_errors() >= 2);
        assert_eq!(m.lines_emitted(), 0);
        assert_eq!(m.completed(), 1);
    }
}
