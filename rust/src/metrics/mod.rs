//! Run metrics: the quantities the paper's evaluation agenda names —
//! utilization, job completion time, temporal fairness / starvation,
//! fragmentation, and scheduling overhead (§4.6, §6(a)).

use crate::types::{Duration, JobId, Time};

pub mod streaming;

/// Per-job outcome record.
#[derive(Debug, Clone)]
pub struct JobMetrics {
    /// Job id.
    pub job: JobId,
    /// Job class name.
    pub class: String,
    /// Arrival time.
    pub arrival: Time,
    /// Completion time (None = never finished within the run).
    pub completed: Option<Time>,
    /// Total work of the job (full-GPU tick equivalents) — the job's
    /// ideal JCT on a dedicated full GPU.
    pub work: f64,
    /// Number of subjobs the job was split into.
    pub subjobs: u32,
    /// Longest gap (ticks) between consecutive selections while the job
    /// was waiting — the starvation indicator of §4.3.
    pub max_wait: Duration,
    /// Whether the job had a deadline and met it.
    pub deadline_met: Option<bool>,
    /// Tenant weight.
    pub weight: f64,
}

impl JobMetrics {
    /// Job completion time (ticks), if finished.
    pub fn jct(&self) -> Option<u64> {
        self.completed.map(|c| c.saturating_sub(self.arrival))
    }

    /// Finish-time-fairness style slowdown: JCT / ideal dedicated-GPU
    /// runtime. 1.0 = as fast as exclusive use of a full GPU.
    pub fn slowdown(&self) -> Option<f64> {
        let jct = self.jct()? as f64;
        if self.work <= 0.0 {
            return None;
        }
        Some(jct / self.work)
    }
}

/// Aggregate metrics for one simulation run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// Scheduler name that produced the run.
    pub scheduler: String,
    /// Last completion time (or last event) of the run.
    pub makespan: Time,
    /// Compute-weighted cluster utilization over [first arrival, makespan].
    pub utilization: f64,
    /// Mean per-slice fragmentation over the run span.
    pub mean_fragmentation: f64,
    /// Per-job records.
    pub jobs: Vec<JobMetrics>,
    /// Scheduler iterations executed.
    pub iterations: u64,
    /// Iterations in which at least one bid was received.
    pub iterations_with_bids: u64,
    /// Total variants submitted across all iterations (Σ M).
    pub total_variants: u64,
    /// Total subjobs committed.
    pub total_commits: u64,
    /// Largest number of commitments any single iteration produced
    /// (multi-window clearing raises this above the per-window optimum).
    pub max_commits_per_iter: u64,
    /// Wall-clock nanoseconds spent inside `Scheduler::iterate`.
    pub sched_wall_ns: u64,
    /// Slowest single `Scheduler::iterate` call (ns) — the per-decision
    /// latency tail the incremental/parallel pipeline targets.
    pub max_sched_iter_ns: u64,
    /// Jobs that never completed within the run.
    pub unfinished: usize,
}

/// Ceil-based nearest-rank percentile: the smallest sample value `v`
/// such that at least `p·n` of the sample is `≤ v`. The previous
/// `round((n-1)·p)` indexing under-reported tail percentiles on small
/// samples (e.g. p95 of n=12 picked the 11th value, not the 12th).
fn percentile(sorted: &[f64], p: f64) -> Option<f64> {
    if sorted.is_empty() {
        return None;
    }
    let n = sorted.len();
    let rank = (p * n as f64).ceil() as usize;
    Some(sorted[rank.clamp(1, n) - 1])
}

impl RunMetrics {
    /// Compute-weighted utilization (0..1).
    pub fn utilization(&self) -> f64 {
        self.utilization
    }

    /// Mean JCT in ticks over completed jobs.
    pub fn mean_jct(&self) -> Option<f64> {
        let jcts: Vec<f64> = self.jobs.iter().filter_map(|j| j.jct()).map(|x| x as f64).collect();
        if jcts.is_empty() {
            None
        } else {
            Some(jcts.iter().sum::<f64>() / jcts.len() as f64)
        }
    }

    /// JCT percentile (p in [0,1]) over completed jobs.
    pub fn jct_percentile(&self, p: f64) -> Option<f64> {
        let mut jcts: Vec<f64> =
            self.jobs.iter().filter_map(|j| j.jct()).map(|x| x as f64).collect();
        jcts.sort_by(|a, b| a.total_cmp(b));
        percentile(&jcts, p)
    }

    /// Mean slowdown (finish-time fairness ratio) over completed jobs.
    pub fn mean_slowdown(&self) -> Option<f64> {
        let s: Vec<f64> = self.jobs.iter().filter_map(|j| j.slowdown()).collect();
        if s.is_empty() {
            None
        } else {
            Some(s.iter().sum::<f64>() / s.len() as f64)
        }
    }

    /// Jain fairness index over per-job slowdowns:
    /// `(Σx)² / (n·Σx²)` with x = slowdown. 1 = perfectly equal slowdowns.
    pub fn jain_fairness(&self) -> Option<f64> {
        let xs: Vec<f64> = self.jobs.iter().filter_map(|j| j.slowdown()).collect();
        if xs.is_empty() {
            return None;
        }
        let s1: f64 = xs.iter().sum();
        let s2: f64 = xs.iter().map(|x| x * x).sum();
        if s2 == 0.0 {
            return None;
        }
        Some(s1 * s1 / (xs.len() as f64 * s2))
    }

    /// Worst (max) slowdown — the tail unfairness the age term targets.
    pub fn max_slowdown(&self) -> Option<f64> {
        self.jobs.iter().filter_map(|j| j.slowdown()).max_by(f64::total_cmp)
    }

    /// Maximum waiting gap between selections across all jobs (ticks):
    /// the starvation headline of §4.3.
    pub fn max_starvation(&self) -> Duration {
        self.jobs.iter().map(|j| j.max_wait).max().unwrap_or(0)
    }

    /// p95 of per-job max waiting gaps.
    pub fn p95_wait(&self) -> Option<f64> {
        let mut ws: Vec<f64> = self.jobs.iter().map(|j| j.max_wait as f64).collect();
        ws.sort_by(|a, b| a.total_cmp(b));
        percentile(&ws, 0.95)
    }

    /// Fraction of deadline-carrying jobs that met their deadline.
    pub fn deadline_met_rate(&self) -> Option<f64> {
        let with: Vec<bool> = self.jobs.iter().filter_map(|j| j.deadline_met).collect();
        if with.is_empty() {
            None
        } else {
            Some(with.iter().filter(|&&m| m).count() as f64 / with.len() as f64)
        }
    }

    /// Jobs completed per simulated second.
    pub fn throughput_per_sec(&self) -> f64 {
        let done = self.jobs.iter().filter(|j| j.completed.is_some()).count();
        if self.makespan == 0 {
            return 0.0;
        }
        done as f64 / (self.makespan as f64 / 1000.0)
    }

    /// Mean subjobs per completed job (atomization degree).
    pub fn mean_subjobs(&self) -> Option<f64> {
        let done: Vec<f64> = self
            .jobs
            .iter()
            .filter(|j| j.completed.is_some())
            .map(|j| j.subjobs as f64)
            .collect();
        if done.is_empty() {
            None
        } else {
            Some(done.iter().sum::<f64>() / done.len() as f64)
        }
    }

    /// Mean wall-clock scheduler overhead per iteration (ns).
    pub fn sched_ns_per_iteration(&self) -> f64 {
        if self.iterations == 0 {
            return 0.0;
        }
        self.sched_wall_ns as f64 / self.iterations as f64
    }

    /// Mean commitments per scheduler iteration — the decision-round
    /// throughput that K-window announcement is designed to raise.
    pub fn commits_per_iteration(&self) -> f64 {
        if self.iterations == 0 {
            return 0.0;
        }
        self.total_commits as f64 / self.iterations as f64
    }

    /// Full metrics as JSON (for `jasda run --json`).
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let opt = |x: Option<f64>| x.map_or(Json::Null, Json::Num);
        Json::obj(vec![
            ("scheduler", self.scheduler.clone().into()),
            ("makespan", self.makespan.into()),
            ("utilization", self.utilization.into()),
            ("mean_fragmentation", self.mean_fragmentation.into()),
            ("iterations", self.iterations.into()),
            ("total_commits", self.total_commits.into()),
            ("commits_per_iteration", self.commits_per_iteration().into()),
            ("max_commits_per_iter", self.max_commits_per_iter.into()),
            ("sched_wall_ns", self.sched_wall_ns.into()),
            ("max_sched_iter_ns", self.max_sched_iter_ns.into()),
            ("unfinished", self.unfinished.into()),
            ("mean_jct", opt(self.mean_jct())),
            ("p95_jct", opt(self.jct_percentile(0.95))),
            ("mean_slowdown", opt(self.mean_slowdown())),
            ("max_slowdown", opt(self.max_slowdown())),
            ("jain_fairness", opt(self.jain_fairness())),
            ("max_starvation", self.max_starvation().into()),
            ("deadline_met_rate", opt(self.deadline_met_rate())),
            ("throughput_per_sec", self.throughput_per_sec().into()),
            ("mean_subjobs", opt(self.mean_subjobs())),
            (
                "jobs",
                Json::Arr(
                    self.jobs
                        .iter()
                        .map(|j| {
                            Json::obj(vec![
                                ("job", j.job.into()),
                                ("class", j.class.clone().into()),
                                ("arrival", j.arrival.into()),
                                ("completed", j.completed.map_or(Json::Null, |c| c.into())),
                                ("work", j.work.into()),
                                ("subjobs", j.subjobs.into()),
                                ("max_wait", j.max_wait.into()),
                                (
                                    "deadline_met",
                                    j.deadline_met.map_or(Json::Null, Json::Bool),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{}: util={:.3} meanJCT={:.0} p95JCT={:.0} jain={:.3} maxSlow={:.2} starv={} commits={} unfinished={}",
            self.scheduler,
            self.utilization,
            self.mean_jct().unwrap_or(f64::NAN),
            self.jct_percentile(0.95).unwrap_or(f64::NAN),
            self.jain_fairness().unwrap_or(f64::NAN),
            self.max_slowdown().unwrap_or(f64::NAN),
            self.max_starvation(),
            self.total_commits,
            self.unfinished,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jm(job: JobId, arrival: Time, completed: Option<Time>, work: f64, max_wait: u64) -> JobMetrics {
        JobMetrics {
            job,
            class: "t".into(),
            arrival,
            completed,
            work,
            subjobs: 2,
            max_wait,
            deadline_met: None,
            weight: 1.0,
        }
    }

    fn sample() -> RunMetrics {
        RunMetrics {
            scheduler: "test".into(),
            makespan: 10_000,
            utilization: 0.8,
            mean_fragmentation: 0.1,
            jobs: vec![
                jm(0, 0, Some(2000), 1000.0, 100),
                jm(1, 0, Some(4000), 1000.0, 700),
                jm(2, 1000, Some(3000), 500.0, 300),
                jm(3, 2000, None, 800.0, 4000),
            ],
            iterations: 100,
            iterations_with_bids: 80,
            total_variants: 500,
            total_commits: 7,
            max_commits_per_iter: 2,
            sched_wall_ns: 1_000_000,
            max_sched_iter_ns: 50_000,
            unfinished: 1,
        }
    }

    #[test]
    fn jct_and_slowdown() {
        let m = sample();
        assert_eq!(m.jobs[0].jct(), Some(2000));
        assert_eq!(m.jobs[3].jct(), None);
        assert_eq!(m.jobs[0].slowdown(), Some(2.0));
        assert_eq!(m.jobs[2].slowdown(), Some(4.0));
    }

    #[test]
    fn aggregates() {
        let m = sample();
        // completed jcts: 2000, 4000, 2000 -> mean 2666.67
        assert!((m.mean_jct().unwrap() - 8000.0 / 3.0).abs() < 1e-9);
        // slowdowns: 2, 4, 4
        assert!((m.mean_slowdown().unwrap() - 10.0 / 3.0).abs() < 1e-9);
        assert_eq!(m.max_slowdown(), Some(4.0));
        let jain = m.jain_fairness().unwrap();
        let expect = (10.0f64 * 10.0) / (3.0 * (4.0 + 16.0 + 16.0));
        assert!((jain - expect).abs() < 1e-12);
        assert_eq!(m.max_starvation(), 4000);
        assert_eq!(m.throughput_per_sec(), 0.3);
        assert_eq!(m.mean_subjobs(), Some(2.0));
        assert_eq!(m.sched_ns_per_iteration(), 10_000.0);
        assert_eq!(m.commits_per_iteration(), 0.07);
        assert_eq!(RunMetrics::default().commits_per_iteration(), 0.0);
    }

    #[test]
    fn percentiles() {
        let m = sample();
        // sorted jcts [2000, 2000, 4000]; p95 -> rank ceil(3*0.95)=3
        assert_eq!(m.jct_percentile(0.95), Some(4000.0));
        assert_eq!(m.jct_percentile(0.0), Some(2000.0));
        assert!(m.p95_wait().unwrap() >= 700.0);
    }

    #[test]
    fn percentile_uses_ceil_nearest_rank() {
        // n=12: ceil-rank p95 = rank 12 (the max). The old round((n-1)p)
        // indexing picked index 10 -> 11.0, under-reporting the tail.
        let sorted: Vec<f64> = (1..=12).map(|x| x as f64).collect();
        assert_eq!(percentile(&sorted, 0.95), Some(12.0));
        assert_eq!(percentile(&sorted, 0.5), Some(6.0));
        assert_eq!(percentile(&sorted, 1.0), Some(12.0));
        assert_eq!(percentile(&sorted, 0.0), Some(1.0));
        assert_eq!(percentile(&[], 0.5), None);
    }

    #[test]
    fn empty_metrics_are_none() {
        let m = RunMetrics::default();
        assert_eq!(m.mean_jct(), None);
        assert_eq!(m.jain_fairness(), None);
        assert_eq!(m.deadline_met_rate(), None);
        assert_eq!(m.mean_subjobs(), None);
        assert_eq!(m.max_starvation(), 0);
        assert_eq!(m.throughput_per_sec(), 0.0);
    }

    #[test]
    fn deadline_rate() {
        let mut m = sample();
        m.jobs[0].deadline_met = Some(true);
        m.jobs[1].deadline_met = Some(false);
        m.jobs[2].deadline_met = Some(true);
        assert!((m.deadline_met_rate().unwrap() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_contains_key_fields() {
        let s = sample().summary();
        assert!(s.contains("util=0.800"));
        assert!(s.contains("test:"));
    }
}
