//! # JASDA — Job-Aware Scheduling in Scheduler-Driven Job Atomization
//!
//! A complete reproduction of the JASDA scheduling framework (Konopa, Fesl,
//! Beránek, 2025): a market-inspired, bidirectional scheduling loop for
//! MIG-partitioned GPUs in which jobs act as autonomous agents that bid
//! scored *subjob variants* into scheduler-announced execution windows, and
//! the scheduler clears each window optimally via Weighted Interval
//! Scheduling (WIS).
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordinator: the JASDA interaction cycle
//!   (generalized to K announced windows per iteration — see
//!   [`config::JasdaConfig::announce_k`] and `announce_per_slice`),
//!   scoring/calibration/fairness policies, per-window WIS clearing with
//!   cross-window reconciliation (the shared
//!   [`jasda::clearing::ClearingEngine`] running on a persistent
//!   [`jasda::pool::WorkerPool`]), a discrete-event MIG cluster simulator
//!   substrate, baseline schedulers, workload generators, metrics, and a
//!   thread-per-agent bid–response protocol runtime ([`coordinator`])
//!   driving the same engine through multi-window `Announce`/`Bid`
//!   rounds — behind a pluggable [`coordinator::transport::Transport`]
//!   (in-process loopback or length-prefixed byte frames) and sharded
//!   into N leaders with cross-shard reconciliation
//!   ([`config::JasdaConfig::shards`]) — property-tested
//!   decision-identical to the in-process loop.
//!
//! A top-level `README.md` maps the module layout; `docs/CONFIG.md` is
//! the configuration reference.
//! * **L2 (python/compile/model.py)** — the batched variant-scoring
//!   pipeline expressed in JAX, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/scoring.py)** — the scoring hot-spot as a
//!   Pallas kernel (interpret mode for CPU-PJRT execution).
//!
//! Python never runs on the scheduling path: `make artifacts` lowers the
//! L2/L1 pipeline once; [`runtime::PjrtScorer`] loads and executes the
//! resulting artifact via the PJRT C API.
//!
//! ## Quick start
//!
//! ```no_run
//! use jasda::config::SimConfig;
//! use jasda::jasda::JasdaScheduler;
//! use jasda::sim::SimEngine;
//! use jasda::workload::WorkloadGenerator;
//!
//! let cfg = SimConfig::default();
//! let workload = WorkloadGenerator::new(cfg.workload.clone()).generate(42);
//! let scheduler = JasdaScheduler::new(cfg.jasda.clone());
//! let mut engine = SimEngine::new(cfg.clone(), Box::new(scheduler));
//! let outcome = engine.run(workload);
//! println!("utilization = {:.3}", outcome.metrics.utilization());
//! ```

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod jasda;
pub mod job;
pub mod metrics;
pub mod mig;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod trp;
pub mod types;
pub mod workload;

pub mod util;

pub use types::{Duration, GpuId, JobId, SliceId, Time, VariantId};
