//! Monolithic baseline schedulers: jobs are indivisible units (no
//! atomization) — the "classical centralized scheduler" family that
//! Table 1 contrasts JASDA against, and the "treat individual jobs as
//! indivisible, monolithic entities" limitation §2 attributes to
//! prior auction approaches.
//!
//! Four queue-ordering disciplines share one placement engine:
//! * **FCFS** — arrival order, one placement per iteration (head of line);
//! * **SJF** — shortest remaining work first;
//! * **EDF** — earliest deadline first (deadline-less jobs last);
//! * **Backfill** — FCFS head placement plus conservative backfilling of
//!   later jobs into gaps that end before the head's start.

use crate::baselines::common::{
    earliest_monolithic_placement, placement_commitment, BaselineConfig,
};
use crate::job::JobSet;
use crate::mig::Cluster;
use crate::sim::{Commitment, Rng, Scheduler};
use crate::types::Time;

/// Queue ordering discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Discipline {
    /// First come, first served.
    Fcfs,
    /// Shortest (remaining) job first.
    Sjf,
    /// Earliest deadline first.
    Edf,
    /// FCFS + conservative backfilling.
    Backfill,
}

/// A monolithic scheduler with a fixed discipline.
pub struct MonolithicScheduler {
    discipline: Discipline,
    cfg: BaselineConfig,
    name: &'static str,
}

impl MonolithicScheduler {
    /// Build with the given discipline and default baseline knobs.
    pub fn new(discipline: Discipline) -> Self {
        Self::with_config(discipline, BaselineConfig::default())
    }

    /// Build with explicit knobs.
    pub fn with_config(discipline: Discipline, cfg: BaselineConfig) -> Self {
        let name = match discipline {
            Discipline::Fcfs => "fcfs",
            Discipline::Sjf => "sjf",
            Discipline::Edf => "edf",
            Discipline::Backfill => "backfill",
        };
        MonolithicScheduler { discipline, cfg, name }
    }

    /// Bidder ids in discipline order.
    fn ordered_queue(&self, jobs: &JobSet) -> Vec<u32> {
        let mut q: Vec<u32> = jobs.bidders().map(|j| j.id).collect();
        match self.discipline {
            Discipline::Fcfs | Discipline::Backfill => {
                q.sort_by_key(|&id| (jobs.get(id).arrival, id));
            }
            Discipline::Sjf => {
                q.sort_by(|&a, &b| {
                    jobs.get(a)
                        .pending_work()
                        .total_cmp(&jobs.get(b).pending_work())
                        .then(a.cmp(&b))
                });
            }
            Discipline::Edf => {
                q.sort_by_key(|&id| (jobs.get(id).deadline.unwrap_or(Time::MAX), id));
            }
        }
        q
    }
}

impl Scheduler for MonolithicScheduler {
    fn name(&self) -> &str {
        self.name
    }

    fn iterate(
        &mut self,
        now: Time,
        cluster: &Cluster,
        jobs: &mut JobSet,
        _rng: &mut Rng,
    ) -> Vec<Commitment> {
        let queue = self.ordered_queue(jobs);
        let Some(&head) = queue.first() else {
            return vec![];
        };

        let mut commits = Vec::new();
        // A scratch cluster clone tracks intra-iteration reservations so
        // backfilled placements don't collide (engine applies them later).
        let mut scratch: Option<Cluster> = None;

        let head_job = jobs.get(head);
        let head_placement = earliest_monolithic_placement(head_job, cluster, now, &self.cfg);
        let head_start = match &head_placement {
            Some((slice, iv, work)) => {
                commits.push(placement_commitment(head_job, *slice, *iv, *work));
                if self.discipline == Discipline::Backfill {
                    let mut c = cluster.clone();
                    c.slice_mut(*slice)
                        .timeline
                        .reserve(crate::mig::Reservation {
                            job: head,
                            subjob_seq: u32::MAX, // scratch-only marker
                            interval: *iv,
                        })
                        .expect("scratch reservation");
                    scratch = Some(c);
                }
                iv.start
            }
            // Head can't be placed: strict disciplines head-of-line block;
            // backfill may still slot later jobs anywhere (it cannot delay
            // a head that has no start yet within the horizon).
            None => {
                if self.discipline != Discipline::Backfill {
                    return vec![];
                }
                scratch = Some(cluster.clone());
                Time::MAX
            }
        };

        if self.discipline == Discipline::Backfill {
            let scratch = scratch.as_mut().expect("scratch cluster set");
            for &id in queue.iter().skip(1) {
                let job = jobs.get(id);
                if let Some((slice, iv, work)) =
                    earliest_monolithic_placement(job, scratch, now, &self.cfg)
                {
                    // Conservative: never start at/after the head's start
                    // (can't delay the head or jump its queue position).
                    if iv.end <= head_start {
                        commits.push(placement_commitment(job, slice, iv, work));
                        scratch
                            .slice_mut(slice)
                            .timeline
                            .reserve(crate::mig::Reservation {
                                job: id,
                                subjob_seq: u32::MAX,
                                interval: iv,
                            })
                            .expect("scratch reservation");
                    }
                }
            }
        }
        commits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::job::Job;
    use crate::sim::SimEngine;
    use crate::trp::{Phase, Trp};

    fn jobs_spec(spec: &[(f64, f64, Time)]) -> Vec<Job> {
        spec.iter()
            .enumerate()
            .map(|(i, &(mem, work, arrival))| {
                let trp =
                    Trp { phases: vec![Phase::new(work, mem, 0.15, 0.1)], duration_cv: 0.05 };
                Job::new(i as u32, "t", arrival, trp, None, 1.0, work, 0.0)
            })
            .collect()
    }

    fn cfg() -> SimConfig {
        let mut c = SimConfig::default();
        c.cluster.layout = "balanced".into();
        c.engine.iteration_period = 25;
        c
    }

    fn run(d: Discipline, jobs: Vec<Job>) -> crate::metrics::RunMetrics {
        SimEngine::new(cfg(), Box::new(MonolithicScheduler::new(d))).run(jobs).metrics
    }

    #[test]
    fn all_disciplines_complete_simple_workload() {
        let spec = [(5.0, 800.0, 0), (5.0, 400.0, 50), (5.0, 1200.0, 100), (12.0, 600.0, 150)];
        for d in [Discipline::Fcfs, Discipline::Sjf, Discipline::Edf, Discipline::Backfill] {
            let m = run(d, jobs_spec(&spec));
            assert_eq!(m.unfinished, 0, "{d:?}: {}", m.summary());
            // Monolithic: exactly one subjob per job.
            for j in &m.jobs {
                assert_eq!(j.subjobs, 1, "{d:?} split a job");
            }
        }
    }

    #[test]
    fn sjf_beats_fcfs_on_mean_jct_for_skewed_sizes() {
        // One huge and many small jobs contend at t=0 (all need the same
        // 20 GiB slice): SJF should get a much better mean JCT.
        let mut spec = vec![(15.0, 20_000.0, 0)];
        for _ in 0..6 {
            spec.push((15.0, 500.0, 0));
        }
        let fcfs = run(Discipline::Fcfs, jobs_spec(&spec));
        let sjf = run(Discipline::Sjf, jobs_spec(&spec));
        assert_eq!(fcfs.unfinished, 0);
        assert_eq!(sjf.unfinished, 0);
        assert!(
            sjf.mean_jct().unwrap() < fcfs.mean_jct().unwrap(),
            "sjf {} vs fcfs {}",
            sjf.mean_jct().unwrap(),
            fcfs.mean_jct().unwrap()
        );
    }

    #[test]
    fn edf_orders_by_deadline() {
        let mut jobs = jobs_spec(&[(15.0, 1000.0, 0), (15.0, 1000.0, 0)]);
        jobs[0].deadline = Some(1_000_000);
        jobs[1].deadline = Some(2_000); // urgent
        let m = run(Discipline::Edf, jobs);
        assert_eq!(m.unfinished, 0);
        // The urgent job (1) should complete before job 0 on the big slice.
        let c0 = m.jobs[0].completed.unwrap();
        let c1 = m.jobs[1].completed.unwrap();
        assert!(c1 < c0, "urgent deadline job must finish first: {c1} vs {c0}");
    }

    #[test]
    fn backfill_fills_ahead_of_blocked_head() {
        // Head needs 15 GiB (only slice 0). Small jobs should backfill
        // onto other slices rather than wait behind it.
        let spec = [
            (15.0, 4000.0, 0),  // head hog on slice 0
            (15.0, 4000.0, 10), // queued behind on slice 0
            (4.0, 500.0, 20),   // small, could run anywhere
        ];
        let fcfs = run(Discipline::Fcfs, jobs_spec(&spec));
        let bf = run(Discipline::Backfill, jobs_spec(&spec));
        assert_eq!(bf.unfinished, 0);
        let small_fcfs = fcfs.jobs[2].jct().unwrap();
        let small_bf = bf.jobs[2].jct().unwrap();
        assert!(
            small_bf <= small_fcfs,
            "backfill should not hurt the small job: {small_bf} vs {small_fcfs}"
        );
    }

    #[test]
    fn deterministic() {
        let spec = [(5.0, 800.0, 0), (9.0, 700.0, 30)];
        let a = run(Discipline::Backfill, jobs_spec(&spec));
        let b = run(Discipline::Backfill, jobs_spec(&spec));
        assert_eq!(a.makespan, b.makespan);
    }
}
