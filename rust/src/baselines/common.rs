//! Shared placement logic for the baseline schedulers.

use crate::job::Job;
use crate::mig::Cluster;
use crate::sim::Commitment;
use crate::types::{Duration, Interval, SliceId, Time};

/// Baseline policy knobs (kept deliberately small: baselines are the
/// paper's comparison strawmen, not the contribution).
#[derive(Debug, Clone)]
pub struct BaselineConfig {
    /// Idle-window search horizon (ticks).
    pub horizon: Duration,
    /// Probabilistic safety bound θ (same contract as JASDA's §4.1(a)).
    pub theta: f64,
    /// Declared-duration quantile.
    pub duration_quantile: f64,
    /// FMP discretization bins for safety checks.
    pub fmp_bins: usize,
    /// Minimum placement duration (matches JASDA's τ_min for fairness).
    pub tau_min: Duration,
}

impl Default for BaselineConfig {
    fn default() -> Self {
        BaselineConfig {
            horizon: 200_000,
            theta: 0.05,
            duration_quantile: 0.9,
            fmp_bins: 64,
            tau_min: 20,
        }
    }
}

/// Is the whole remaining execution of `job` memory-safe on a slice of
/// `capacity_gb` at bound `theta`?
pub fn whole_job_safe(job: &Job, capacity_gb: f64, theta: f64, bins: usize) -> bool {
    let w0 = job.work_cursor();
    let w1 = job.total_work();
    if w1 - w0 <= 0.0 {
        return false;
    }
    job.trp.fmp_bins(w0, w1, bins).violation_prob(capacity_gb) <= theta
}

/// Earliest monolithic placement of the job's entire pending work across
/// all slices: returns `(slice, interval, work)` of the earliest-starting
/// feasible reservation, preferring faster slices on start ties.
pub fn earliest_monolithic_placement(
    job: &Job,
    cluster: &Cluster,
    now: Time,
    cfg: &BaselineConfig,
) -> Option<(SliceId, Interval, f64)> {
    let work = job.pending_work();
    if work <= 1e-9 {
        return None;
    }
    let mut best: Option<(SliceId, Interval, f64, f64)> = None; // + speed
    for s in cluster.slices() {
        if !whole_job_safe(job, s.capacity_gb(), cfg.theta, cfg.fmp_bins) {
            continue;
        }
        let dur = job
            .trp
            .predicted_duration(work, s.speed(), cfg.duration_quantile)
            .max(cfg.tau_min);
        if let Some(gap) = s.timeline.earliest_gap(now, now + cfg.horizon, dur) {
            let iv = Interval::new(gap.interval.start, gap.interval.start + dur);
            let better = match &best {
                None => true,
                Some((_, b, _, bs)) => {
                    iv.start < b.start || (iv.start == b.start && s.speed() > *bs)
                }
            };
            if better {
                best = Some((s.id, iv, work, s.speed()));
            }
        }
    }
    best.map(|(id, iv, w, _)| (id, iv, w))
}

/// Wrap a placement into an engine commitment with neutral declared
/// features (baselines have no bidding layer).
pub fn placement_commitment(
    job: &Job,
    slice: SliceId,
    interval: Interval,
    work: f64,
) -> Commitment {
    let _ = job;
    Commitment {
        job: job.id,
        slice,
        interval,
        work,
        declared_phi: [0.5; 4],
        score: 0.0,
        window_len: interval.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::JobState;
    use crate::mig::PartitionLayout;
    use crate::trp::{Phase, Trp};

    fn job(mem: f64, work: f64) -> Job {
        let trp = Trp { phases: vec![Phase::new(work, mem, 0.2, 0.1)], duration_cv: 0.05 };
        let mut j = Job::new(0, "t", 0, trp, None, 1.0, work, 0.0);
        j.state = JobState::Active;
        j
    }

    #[test]
    fn placement_prefers_earliest_then_fastest() {
        let cluster = Cluster::new(1, &PartitionLayout::balanced()); // 3g+2g+2g
        let j = job(5.0, 700.0);
        let (slice, iv, work) =
            earliest_monolithic_placement(&j, &cluster, 0, &BaselineConfig::default()).unwrap();
        assert_eq!(slice, 0, "all free at t=0; fastest (3g) wins the tie");
        assert_eq!(iv.start, 0);
        assert_eq!(work, 700.0);
    }

    #[test]
    fn memory_unsafe_slices_skipped() {
        let cluster = Cluster::new(1, &PartitionLayout::balanced());
        let j = job(15.0, 700.0); // only the 3g.20gb slice is safe
        let (slice, _, _) =
            earliest_monolithic_placement(&j, &cluster, 0, &BaselineConfig::default()).unwrap();
        assert_eq!(slice, 0);
        let j = job(25.0, 700.0); // fits nothing on `balanced`
        assert!(
            earliest_monolithic_placement(&j, &cluster, 0, &BaselineConfig::default()).is_none()
        );
    }

    #[test]
    fn busy_fast_slice_falls_back_to_slow() {
        use crate::mig::Reservation;
        let mut cluster = Cluster::new(1, &PartitionLayout::balanced());
        cluster
            .slice_mut(0)
            .timeline
            .reserve(Reservation { job: 9, subjob_seq: 0, interval: Interval::new(0, 100_000) })
            .unwrap();
        let j = job(5.0, 700.0);
        let (slice, iv, _) =
            earliest_monolithic_placement(&j, &cluster, 0, &BaselineConfig::default()).unwrap();
        assert_ne!(slice, 0);
        assert_eq!(iv.start, 0);
    }

    #[test]
    fn finished_job_has_no_placement() {
        let cluster = Cluster::new(1, &PartitionLayout::balanced());
        let mut j = job(5.0, 700.0);
        j.done_work = 700.0;
        assert!(
            earliest_monolithic_placement(&j, &cluster, 0, &BaselineConfig::default()).is_none()
        );
    }
}
