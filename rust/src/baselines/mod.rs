//! Baseline schedulers (the comparison points of Table 1 and §6(a)):
//! monolithic disciplines (FCFS/SJF/EDF/backfill), the SJA-style
//! centralized atomizer, and a Themis-like fairness auction.
//!
//! All baselines run on the identical simulator substrate and safety
//! contract as JASDA, so measured deltas isolate scheduling-model
//! differences — exactly what Table 1 compares conceptually.

pub mod atomized;
pub mod common;
pub mod monolithic;

pub use atomized::{SjaCentralScheduler, ThemisLikeScheduler};
pub use common::BaselineConfig;
pub use monolithic::{Discipline, MonolithicScheduler};

use crate::sim::Scheduler;

/// Instantiate a scheduler by name. Knows every baseline plus `jasda`
/// (with the supplied JASDA config). Used by the CLI and benches.
pub fn by_name(
    name: &str,
    jasda_cfg: &crate::config::JasdaConfig,
) -> Option<Box<dyn Scheduler>> {
    match name {
        "jasda" => Some(Box::new(crate::jasda::JasdaScheduler::new(jasda_cfg.clone()))),
        "fcfs" => Some(Box::new(MonolithicScheduler::new(Discipline::Fcfs))),
        "sjf" => Some(Box::new(MonolithicScheduler::new(Discipline::Sjf))),
        "edf" => Some(Box::new(MonolithicScheduler::new(Discipline::Edf))),
        "backfill" => Some(Box::new(MonolithicScheduler::new(Discipline::Backfill))),
        "sja_central" => Some(Box::new(SjaCentralScheduler::new())),
        "themis_like" => Some(Box::new(ThemisLikeScheduler::new())),
        _ => None,
    }
}

/// All scheduler names, JASDA first.
pub const ALL_SCHEDULERS: [&str; 7] =
    ["jasda", "fcfs", "sjf", "edf", "backfill", "sja_central", "themis_like"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::JasdaConfig;

    #[test]
    fn by_name_knows_all() {
        let cfg = JasdaConfig::default();
        for name in ALL_SCHEDULERS {
            let s = by_name(name, &cfg).unwrap_or_else(|| panic!("unknown {name}"));
            assert_eq!(s.name(), name);
        }
        assert!(by_name("nope", &cfg).is_none());
    }
}
