//! Atomized baseline schedulers: jobs are split into subjobs, but all
//! decisions stay scheduler-side — no bidding, no job-declared scores.
//!
//! * [`SjaCentralScheduler`] — the SJA predecessor as the paper describes
//!   it: jobs are decomposed opportunistically into eligible atoms that
//!   fill announced windows, but "the scheduler alone performs global
//!   evaluation and allocation" (§1). Selection is FCFS-among-safe, so
//!   the delta between this and JASDA isolates the value of the
//!   *job-aware* bidding/scoring layer.
//! * [`ThemisLikeScheduler`] — a finish-time-fairness auction in the
//!   spirit of Themis (§2): each window is leased to the job whose
//!   projected finish-time fairness ratio is currently worst.

use crate::baselines::common::BaselineConfig;
use crate::job::{Job, JobSet};
use crate::mig::{Cluster, Window};
use crate::sim::{Commitment, Rng, Scheduler};
use crate::types::{Interval, Time};

/// Fill `window` with consecutive atoms of `job` (scheduler-side carving,
/// same τ_min/safety contract as JASDA's job-side generation). Returns
/// commitments for as much of the window as the job can safely use.
fn carve_atoms(
    job: &Job,
    window: &Window,
    cfg: &BaselineConfig,
    max_atoms: usize,
) -> Vec<Commitment> {
    let mut out = Vec::new();
    let mut t = window.t_min();
    let mut offset = 0.0;
    let pending = job.pending_work();
    while out.len() < max_atoms {
        let avail = window.interval.end.saturating_sub(t);
        if avail < cfg.tau_min {
            break;
        }
        // Work that fits the remaining window at the declared quantile.
        let z = if job.trp.duration_cv > 0.0 {
            crate::trp::math::normal_quantile(cfg.duration_quantile).max(0.0)
        } else {
            0.0
        };
        let w_fit = avail as f64 * window.speed / (1.0 + z * job.trp.duration_cv);
        let w = w_fit.min(job.atom_work).min(pending - offset);
        if w <= 1e-9 {
            break;
        }
        let mut dur = job.trp.predicted_duration(w, window.speed, cfg.duration_quantile);
        // Final slivers round up to τ_min (same anti-starvation rule as
        // JASDA's job-side generation).
        if dur < cfg.tau_min {
            if offset + w >= pending - 1e-9 {
                dur = cfg.tau_min;
            } else {
                break;
            }
        }
        if t + dur > window.interval.end {
            break;
        }
        // Safety over the atom's work range.
        let w0 = job.work_cursor() + offset;
        let fmp = job.trp.fmp_bins(w0, w0 + w, cfg.fmp_bins);
        if fmp.violation_prob(window.capacity_gb) > cfg.theta {
            break;
        }
        out.push(Commitment {
            job: job.id,
            slice: window.slice,
            interval: Interval::new(t, t + dur),
            work: w,
            declared_phi: [0.5; 4],
            score: 0.0,
            window_len: window.delta_t(),
        });
        t += dur;
        offset += w;
        if offset >= pending - 1e-9 {
            break;
        }
    }
    out
}

/// Earliest candidate window across the cluster.
fn earliest_window(cluster: &Cluster, now: Time, cfg: &BaselineConfig) -> Option<Window> {
    cluster
        .candidate_windows(now, cfg.horizon, cfg.tau_min)
        .into_iter()
        .min_by_key(|w| (w.interval.start, std::cmp::Reverse(w.delta_t()), w.slice))
}

/// SJA-style centralized atomizer: earliest window, FCFS job choice,
/// scheduler-side carving.
pub struct SjaCentralScheduler {
    cfg: BaselineConfig,
    /// Max atoms carved per window (mirrors JASDA's V_max).
    max_atoms: usize,
}

impl SjaCentralScheduler {
    /// Build with default knobs.
    pub fn new() -> Self {
        SjaCentralScheduler { cfg: BaselineConfig::default(), max_atoms: 4 }
    }

    /// Build with explicit knobs.
    pub fn with_config(cfg: BaselineConfig, max_atoms: usize) -> Self {
        SjaCentralScheduler { cfg, max_atoms }
    }
}

impl Default for SjaCentralScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for SjaCentralScheduler {
    fn name(&self) -> &str {
        "sja_central"
    }

    fn iterate(
        &mut self,
        now: Time,
        cluster: &Cluster,
        jobs: &mut JobSet,
        _rng: &mut Rng,
    ) -> Vec<Commitment> {
        let Some(window) = earliest_window(cluster, now, &self.cfg) else {
            return vec![];
        };
        // FCFS among jobs with any safe atom for this window.
        let mut queue: Vec<u32> = jobs.bidders().map(|j| j.id).collect();
        queue.sort_by_key(|&id| (jobs.get(id).arrival, id));
        for id in queue {
            let commits = carve_atoms(jobs.get(id), &window, &self.cfg, self.max_atoms);
            if !commits.is_empty() {
                return commits;
            }
        }
        vec![]
    }
}

/// Themis-like finish-time-fairness lease scheduler.
pub struct ThemisLikeScheduler {
    cfg: BaselineConfig,
    max_atoms: usize,
}

impl ThemisLikeScheduler {
    /// Build with default knobs.
    pub fn new() -> Self {
        ThemisLikeScheduler { cfg: BaselineConfig::default(), max_atoms: 4 }
    }

    /// Projected finish-time fairness ratio ρ of a job at `now`: the
    /// job's age-plus-remaining runtime divided by its ideal dedicated
    /// runtime, weighted by tenant weight. Higher = worse off.
    fn ftf(job: &Job, now: Time) -> f64 {
        let ideal = job.total_work().max(1.0);
        let elapsed = now.saturating_sub(job.arrival) as f64;
        let projected = elapsed + job.remaining_work();
        (projected / ideal) * job.weight
    }
}

impl Default for ThemisLikeScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for ThemisLikeScheduler {
    fn name(&self) -> &str {
        "themis_like"
    }

    fn iterate(
        &mut self,
        now: Time,
        cluster: &Cluster,
        jobs: &mut JobSet,
        _rng: &mut Rng,
    ) -> Vec<Commitment> {
        let Some(window) = earliest_window(cluster, now, &self.cfg) else {
            return vec![];
        };
        // Lease the window to the worst-off job that can use it.
        let mut order: Vec<u32> = jobs.bidders().map(|j| j.id).collect();
        order.sort_by(|&a, &b| {
            Self::ftf(jobs.get(b), now)
                .total_cmp(&Self::ftf(jobs.get(a), now))
                .then(a.cmp(&b))
        });
        for id in order {
            let commits = carve_atoms(jobs.get(id), &window, &self.cfg, self.max_atoms);
            if !commits.is_empty() {
                return commits;
            }
        }
        vec![]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::SimEngine;
    use crate::trp::{Phase, Trp};

    fn jobs_spec(spec: &[(f64, f64, Time)]) -> Vec<Job> {
        spec.iter()
            .enumerate()
            .map(|(i, &(mem, work, arrival))| {
                let trp =
                    Trp { phases: vec![Phase::new(work, mem, 0.15, 0.1)], duration_cv: 0.05 };
                Job::new(i as u32, "t", arrival, trp, None, 1.0, work / 3.0, 0.0)
            })
            .collect()
    }

    fn cfg() -> SimConfig {
        let mut c = SimConfig::default();
        c.cluster.layout = "balanced".into();
        c.engine.iteration_period = 25;
        c
    }

    #[test]
    fn sja_central_completes_and_atomizes() {
        let spec = [(5.0, 1500.0, 0), (8.0, 900.0, 100), (15.0, 1200.0, 200)];
        let m = SimEngine::new(cfg(), Box::new(SjaCentralScheduler::new()))
            .run(jobs_spec(&spec))
            .metrics;
        assert_eq!(m.unfinished, 0, "{}", m.summary());
        assert!(
            m.jobs.iter().any(|j| j.subjobs > 1),
            "atomization must split at least one job"
        );
    }

    #[test]
    fn themis_completes_and_balances() {
        let spec = [(5.0, 2000.0, 0), (5.0, 2000.0, 0), (5.0, 2000.0, 0)];
        let m = SimEngine::new(cfg(), Box::new(ThemisLikeScheduler::new()))
            .run(jobs_spec(&spec))
            .metrics;
        assert_eq!(m.unfinished, 0, "{}", m.summary());
        // Symmetric jobs -> high fairness.
        assert!(m.jain_fairness().unwrap() > 0.8, "jain {}", m.jain_fairness().unwrap());
    }

    #[test]
    fn ftf_prefers_older_jobs() {
        let js = jobs_spec(&[(5.0, 1000.0, 0), (5.0, 1000.0, 500)]);
        let f0 = ThemisLikeScheduler::ftf(&js[0], 1000);
        let f1 = ThemisLikeScheduler::ftf(&js[1], 1000);
        assert!(f0 > f1, "older job is worse off: {f0} vs {f1}");
    }

    #[test]
    fn carve_respects_window_and_tau_min() {
        let mut j = jobs_spec(&[(5.0, 10_000.0, 0)]).remove(0);
        j.state = crate::job::JobState::Active;
        let w = Window {
            slice: 0,
            capacity_gb: 10.0,
            speed: 1.0,
            interval: Interval::new(100, 600),
        };
        let cfg = BaselineConfig::default();
        let commits = carve_atoms(&j, &w, &cfg, 8);
        assert!(!commits.is_empty());
        let mut prev_end = 100;
        for c in &commits {
            assert!(c.interval.start >= prev_end);
            assert!(c.interval.end <= 600);
            assert!(c.interval.len() >= cfg.tau_min);
            prev_end = c.interval.end;
        }
    }

    #[test]
    fn carve_nothing_for_unsafe_window() {
        let mut j = jobs_spec(&[(15.0, 1000.0, 0)]).remove(0);
        j.state = crate::job::JobState::Active;
        let w = Window {
            slice: 0,
            capacity_gb: 5.0,
            speed: 1.0,
            interval: Interval::new(0, 1000),
        };
        assert!(carve_atoms(&j, &w, &BaselineConfig::default(), 4).is_empty());
    }
}
