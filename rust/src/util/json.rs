//! Minimal JSON: a self-contained parser and serializer.
//!
//! The offline build environment provides no serde, so the framework
//! carries its own JSON implementation. It supports the full JSON value
//! model (objects, arrays, strings with escapes, numbers, booleans,
//! null), preserves object insertion order, and round-trips everything
//! the framework writes (configs, traces, metrics, scheduler stats).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (stored as f64; integers up to 2^53 round-trip).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object. BTreeMap gives deterministic serialization order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64, if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// As u64 (rejects negatives / fractions).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= 2f64.powi(53) => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    /// As usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse JSON text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                // Whole numbers print without a fraction, but only inside
                // the f64-exact integer range (|x| < 2^53): beyond it the
                // `as i64` cast would be lossy and — past 2^63 — saturate
                // to i64::MAX, silently corrupting values like 1e300.
                // Such magnitudes fall through to Rust's f64 formatter,
                // which emits a full (exponent-free) decimal expansion
                // that parses back to the identical f64.
                if x.fract() == 0.0 && x.abs() < 2f64.powi(53) {
                    out.push_str(&format!("{}", *x as i64));
                } else if x.is_finite() {
                    out.push_str(&format!("{x}"));
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    item.write(out, indent, depth + 1);
                }
                if indent.is_some() && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(v: &[T]) -> Json {
        Json::Arr(v.iter().cloned().map(Into::into).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// Human-readable message.
    pub msg: String,
    /// Byte offset of the error.
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogates are replaced (framework never emits them).
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn escapes_round_trip() {
        let original = Json::Str("line1\nline2\t\"quoted\" \\slash π".into());
        let text = original.to_string();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escape_parses() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn round_trip_complex() {
        let v = Json::obj(vec![
            ("name", "jasda".into()),
            ("nums", vec![1.5f64, 2.0, -7.25].into()),
            ("nested", Json::obj(vec![("ok", true.into()), ("n", Json::Null)])),
            ("empty_arr", Json::Arr(vec![])),
            ("empty_obj", Json::Obj(Default::default())),
        ]);
        for text in [v.to_string(), v.to_string_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v, "failed on: {text}");
        }
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn huge_whole_floats_do_not_saturate() {
        // Regression: whole floats outside the i64-exact range must use
        // the float formatter, never an `as i64` cast (which is lossy
        // from 2^53 and saturates to i64::MAX from 2^63 — 1e300 must not
        // serialize as 9223372036854775807).
        for v in [1e300, -1e300, 2f64.powi(63), 2f64.powi(53), -(2f64.powi(53))] {
            let text = Json::Num(v).to_string();
            assert!(
                !text.contains("9223372036854775807"),
                "{v} saturated to i64::MAX: {text}"
            );
            assert_eq!(Json::parse(&text).unwrap(), Json::Num(v), "{v} failed round-trip");
        }
        // The largest exactly-representable integers still print as
        // integers; the first value past the boundary does not break.
        assert_eq!(Json::Num(2f64.powi(53) - 1.0).to_string(), "9007199254740991");
        assert_eq!(Json::parse("9007199254740992").unwrap(), Json::Num(2f64.powi(53)));
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"u": 7, "f": 7.5, "b": true, "s": "x", "a": [1]}"#).unwrap();
        assert_eq!(v.get("u").unwrap().as_u64(), Some(7));
        assert_eq!(v.get("u").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("f").unwrap().as_u64(), None, "fractional not u64");
        assert_eq!(v.get("f").unwrap().as_f64(), Some(7.5));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(v.get("zzz"), None);
        assert_eq!(Json::Null.get("x"), None);
    }

    #[test]
    fn errors_are_positioned() {
        for bad in ["{", "[1,", "\"abc", "{\"a\" 1}", "tru", "1.2.3", "[1] x"] {
            let e = Json::parse(bad).unwrap_err();
            assert!(!e.msg.is_empty(), "{bad}");
        }
    }

    #[test]
    fn whitespace_tolerated() {
        let v = Json::parse(" \n\t{ \"a\" : [ 1 , 2 ] } \r\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }
}
