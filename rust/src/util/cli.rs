//! Minimal command-line argument parsing (no external deps).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positionals.
//! Unknown flags are an error, so typos surface immediately.

use std::collections::BTreeMap;

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// `--key value` / `--key=value` options.
    opts: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    flags: Vec<String>,
    /// Positional arguments in order.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    /// `value_opts` lists option names that consume a value.
    pub fn parse(
        argv: impl Iterator<Item = String>,
        value_opts: &[&str],
        flag_opts: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                if value_opts.contains(&key.as_str()) {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{key} requires a value"))?,
                    };
                    out.opts.insert(key, v);
                } else if flag_opts.contains(&key.as_str()) {
                    if inline.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    out.flags.push(key);
                } else {
                    return Err(format!("unknown option --{key}"));
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Value of `--key`, if given.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// Whether `--flag` was given.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Typed option with default.
    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("invalid value for --{key}: '{v}'")),
        }
    }

    /// Comma-separated list option.
    pub fn opt_list_f64(&self, key: &str, default: &[f64]) -> Result<Vec<f64>, String> {
        match self.opt(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse::<f64>().map_err(|_| format!("bad f64 '{s}' in --{key}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Result<Args, String> {
        Args::parse(
            args.iter().map(|s| s.to_string()),
            &["seed", "scheduler", "lambdas"],
            &["json", "csv"],
        )
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["run", "--seed", "7", "--scheduler=fcfs", "--json", "extra"]).unwrap();
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.opt("seed"), Some("7"));
        assert_eq!(a.opt("scheduler"), Some("fcfs"));
        assert!(a.flag("json"));
        assert!(!a.flag("csv"));
    }

    #[test]
    fn typed_and_list() {
        let a = parse(&["--seed", "42", "--lambdas", "0.3,0.5, 0.7"]).unwrap();
        assert_eq!(a.opt_parse("seed", 0u64).unwrap(), 42);
        assert_eq!(a.opt_parse("missing", 5u32).unwrap(), 5);
        assert_eq!(a.opt_list_f64("lambdas", &[]).unwrap(), vec![0.3, 0.5, 0.7]);
        assert_eq!(a.opt_list_f64("none", &[1.0]).unwrap(), vec![1.0]);
    }

    #[test]
    fn errors() {
        assert!(parse(&["--bogus"]).is_err());
        assert!(parse(&["--seed"]).is_err());
        assert!(parse(&["--json=1"]).is_err());
        let a = parse(&["--seed", "x"]).unwrap();
        assert!(a.opt_parse("seed", 0u64).is_err());
        assert!(a.opt_list_f64("seed", &[]).is_err());
    }
}
