//! Measurement harness used by all `rust/benches/*` targets (the offline
//! environment has no criterion; this provides the same discipline:
//! warm-up, repeated timed samples, median/mean/min reporting).

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    /// Median time per iteration.
    pub median: Duration,
    /// Mean time per iteration.
    pub mean: Duration,
    /// Fastest sample.
    pub min: Duration,
    /// Slowest sample.
    pub max: Duration,
    /// Iterations per sample.
    pub iters: u32,
    /// Number of samples.
    pub samples: u32,
}

impl Measurement {
    /// ns per iteration (median).
    pub fn ns_per_iter(&self) -> f64 {
        self.median.as_nanos() as f64
    }

    /// Human-readable form.
    pub fn display(&self) -> String {
        format!(
            "median {:>12} mean {:>12} min {:>12} ({} samples x {} iters)",
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.min),
            self.samples,
            self.iters
        )
    }
}

/// Format a duration adaptively (ns/µs/ms/s).
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Time `f`, returning per-iteration statistics. Automatically picks an
/// iteration count so each sample runs ≥ `min_sample_ms` ms, then takes
/// `samples` samples. Results of `f` are passed to `std::hint::black_box`
/// by the caller's closure convention (return something observable).
pub fn bench<T>(samples: u32, min_sample_ms: u64, mut f: impl FnMut() -> T) -> Measurement {
    // Warm-up + calibration.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let one = t0.elapsed().max(Duration::from_nanos(50));
    let target = Duration::from_millis(min_sample_ms.max(1));
    let iters = ((target.as_nanos() / one.as_nanos()).clamp(1, 1_000_000)) as u32;

    let mut per_iter: Vec<Duration> = Vec::with_capacity(samples as usize);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        per_iter.push(t.elapsed() / iters);
    }
    per_iter.sort();
    let median = per_iter[per_iter.len() / 2];
    let mean = per_iter.iter().sum::<Duration>() / samples;
    Measurement {
        median,
        mean,
        min: per_iter[0],
        max: *per_iter.last().unwrap(),
        iters,
        samples,
    }
}

/// Run and report a named benchmark in one line.
pub fn run_case<T>(name: &str, samples: u32, min_sample_ms: u64, f: impl FnMut() -> T) -> Measurement {
    let m = bench(samples, min_sample_ms, f);
    println!("{name:<48} {}", m.display());
    m
}

/// Print a bench header.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let m = bench(5, 1, || {
            let mut s = 0u64;
            for i in 0..100 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(m.median.as_nanos() > 0);
        assert!(m.min <= m.median && m.median <= m.max);
        assert!(m.iters >= 1);
        assert_eq!(m.samples, 5);
    }

    #[test]
    fn fmt_dur_scales() {
        assert_eq!(fmt_dur(Duration::from_nanos(12)), "12 ns");
        assert_eq!(fmt_dur(Duration::from_nanos(1500)), "1.50 µs");
        assert_eq!(fmt_dur(Duration::from_micros(2500)), "2.50 ms");
        assert_eq!(fmt_dur(Duration::from_millis(1500)), "1.500 s");
    }
}
