//! In-crate infrastructure the offline environment would otherwise pull
//! from crates.io: JSON (configs/traces/metrics), CLI parsing, and the
//! benchmark measurement harness.

pub mod bench;
pub mod cli;
pub mod json;

pub use json::Json;
