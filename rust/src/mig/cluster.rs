//! Cluster model: GPUs, their MIG slices, and cluster-wide window queries.
//!
//! The cluster is the shared state both JASDA and the baseline schedulers
//! operate on. A [`Slice`] couples a [`SliceProfile`] with a reservation
//! [`Timeline`]; a [`Cluster`] owns every slice across every GPU and
//! answers the queries the announcement phase needs: candidate idle
//! windows, utilization, and fragmentation.

use crate::mig::profile::{PartitionLayout, SliceProfile};
use crate::mig::timeline::{IdleGap, Timeline};
use crate::types::{Duration, GpuId, Interval, SliceId, Time};

/// One MIG slice: profile + committed reservation timeline.
#[derive(Debug, Clone)]
pub struct Slice {
    /// Cluster-unique slice id.
    pub id: SliceId,
    /// Owning GPU.
    pub gpu: GpuId,
    /// MIG profile (capacity + compute fraction).
    pub profile: SliceProfile,
    /// Committed subjob reservations.
    pub timeline: Timeline,
}

impl Slice {
    /// Memory capacity `c_k` in GiB.
    #[inline]
    pub fn capacity_gb(&self) -> f64 {
        self.profile.mem_gb()
    }

    /// Relative execution speed (full GPU = 1.0).
    #[inline]
    pub fn speed(&self) -> f64 {
        self.profile.speed()
    }
}

/// A candidate announcement window `w* = (s_k, c_k, t_min, Δt)` (paper §3.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Window {
    /// Slice the window lives on.
    pub slice: SliceId,
    /// Slice memory capacity `c_k` in GiB.
    pub capacity_gb: f64,
    /// Slice execution speed (full GPU = 1.0) — exposed so jobs can
    /// predict subjob durations on this slice.
    pub speed: f64,
    /// Window interval `[t_min, t_min + Δt)`.
    pub interval: Interval,
}

impl Window {
    /// Window start `t_min`.
    #[inline]
    pub fn t_min(&self) -> Time {
        self.interval.start
    }

    /// Window length `Δt`.
    #[inline]
    pub fn delta_t(&self) -> Duration {
        self.interval.len()
    }
}

/// The full MIG cluster: every slice of every GPU.
#[derive(Debug, Clone)]
pub struct Cluster {
    slices: Vec<Slice>,
    gpus: u32,
}

impl Cluster {
    /// Build a cluster of `num_gpus` GPUs, each partitioned with `layout`.
    pub fn new(num_gpus: u32, layout: &PartitionLayout) -> Self {
        let mut slices = Vec::new();
        let mut next_id: SliceId = 0;
        for gpu in 0..num_gpus {
            for &profile in &layout.slices {
                slices.push(Slice { id: next_id, gpu, profile, timeline: Timeline::new() });
                next_id += 1;
            }
        }
        Cluster { slices, gpus: num_gpus }
    }

    /// Build a heterogeneous cluster from per-GPU layouts.
    pub fn heterogeneous(layouts: &[PartitionLayout]) -> Self {
        let mut slices = Vec::new();
        let mut next_id: SliceId = 0;
        for (gpu, layout) in layouts.iter().enumerate() {
            for &profile in &layout.slices {
                slices.push(Slice {
                    id: next_id,
                    gpu: gpu as GpuId,
                    profile,
                    timeline: Timeline::new(),
                });
                next_id += 1;
            }
        }
        Cluster { slices, gpus: layouts.len() as u32 }
    }

    /// Number of GPUs.
    pub fn num_gpus(&self) -> u32 {
        self.gpus
    }

    /// Number of slices.
    pub fn num_slices(&self) -> usize {
        self.slices.len()
    }

    /// All slices.
    pub fn slices(&self) -> &[Slice] {
        &self.slices
    }

    /// Mutable access to a slice by id.
    pub fn slice_mut(&mut self, id: SliceId) -> &mut Slice {
        &mut self.slices[id as usize]
    }

    /// Slice by id.
    pub fn slice(&self, id: SliceId) -> &Slice {
        &self.slices[id as usize]
    }

    /// Enumerate every candidate window across all slices — idle gaps in
    /// `[from, from + horizon)` of at least `min_len` ticks — into a
    /// caller-owned buffer (cleared first). Enumeration runs off each
    /// slice's incremental gap index, so a scheduler that reuses the
    /// buffer allocates nothing on this path.
    pub fn collect_windows(
        &self,
        from: Time,
        horizon: Duration,
        min_len: Duration,
        out: &mut Vec<Window>,
    ) {
        let to = from.saturating_add(horizon);
        out.clear();
        for s in &self.slices {
            let (id, capacity_gb, speed) = (s.id, s.capacity_gb(), s.speed());
            s.timeline.for_each_gap(from, to, min_len, |IdleGap { interval }| {
                out.push(Window { slice: id, capacity_gb, speed, interval });
            });
        }
    }

    /// [`Cluster::collect_windows`] into a fresh vector (convenience for
    /// tests, baselines, and the coordinator runtime).
    pub fn candidate_windows(
        &self,
        from: Time,
        horizon: Duration,
        min_len: Duration,
    ) -> Vec<Window> {
        let mut windows = Vec::new();
        self.collect_windows(from, horizon, min_len, &mut windows);
        windows
    }

    /// Total idle residues shorter than `tau_min` across all slices in
    /// `[from, to)` — the rolling-repack trigger input (paper §3.5),
    /// answered from the per-slice gap indexes without allocating.
    pub fn count_unusable_residues(&self, from: Time, to: Time, tau_min: Duration) -> usize {
        self.slices
            .iter()
            .map(|s| s.timeline.count_unusable_residues(from, to, tau_min))
            .sum()
    }

    /// Compute-weighted utilization of the cluster over `[from, to)`:
    /// busy-ticks weighted by slice compute fraction, normalized by the
    /// cluster's total compute-time capacity. This is the "utilization"
    /// headline metric (a 1g slice busy contributes 1/7 of a GPU).
    pub fn utilization(&self, from: Time, to: Time) -> f64 {
        if to <= from {
            return 0.0;
        }
        let span = (to - from) as f64;
        let mut busy_weighted = 0.0;
        let mut cap_weighted = 0.0;
        for s in &self.slices {
            let w = s.speed();
            busy_weighted += w * s.timeline.busy_ticks(from, to) as f64;
            cap_weighted += w * span;
        }
        if cap_weighted == 0.0 {
            0.0
        } else {
            busy_weighted / cap_weighted
        }
    }

    /// Mean per-slice fragmentation over `[from, to)` (paper §3.5 repack
    /// trigger metric).
    pub fn mean_fragmentation(&self, from: Time, to: Time) -> f64 {
        if self.slices.is_empty() {
            return 0.0;
        }
        let sum: f64 = self.slices.iter().map(|s| s.timeline.fragmentation(from, to)).sum();
        sum / self.slices.len() as f64
    }

    /// Drop reservation history ending at or before `t` on all slices.
    pub fn compact_before(&mut self, t: Time) -> usize {
        self.slices.iter_mut().map(|s| s.timeline.compact_before(t)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::timeline::Reservation;

    #[test]
    fn cluster_construction_assigns_unique_ids() {
        let c = Cluster::new(2, &PartitionLayout::balanced());
        assert_eq!(c.num_gpus(), 2);
        assert_eq!(c.num_slices(), 6);
        let ids: Vec<SliceId> = c.slices().iter().map(|s| s.id).collect();
        assert_eq!(ids, (0..6).collect::<Vec<_>>());
        assert_eq!(c.slice(0).gpu, 0);
        assert_eq!(c.slice(3).gpu, 1);
    }

    #[test]
    fn heterogeneous_cluster() {
        let c = Cluster::heterogeneous(&[PartitionLayout::whole(), PartitionLayout::seven_small()]);
        assert_eq!(c.num_gpus(), 2);
        assert_eq!(c.num_slices(), 8);
        assert_eq!(c.slice(0).profile, SliceProfile::P7g40gb);
        assert_eq!(c.slice(1).profile, SliceProfile::P1g5gb);
    }

    #[test]
    fn candidate_windows_cover_all_slices() {
        let mut c = Cluster::new(1, &PartitionLayout::balanced());
        c.slice_mut(0)
            .timeline
            .reserve(Reservation { job: 1, subjob_seq: 0, interval: Interval::new(0, 50) })
            .unwrap();
        let ws = c.candidate_windows(0, 100, 1);
        // slice 0 has gap [50,100); slices 1,2 each have [0,100)
        assert_eq!(ws.len(), 3);
        let w0 = ws.iter().find(|w| w.slice == 0).unwrap();
        assert_eq!(w0.interval, Interval::new(50, 100));
        assert_eq!(w0.capacity_gb, 20.0);
        let w1 = ws.iter().find(|w| w.slice == 1).unwrap();
        assert_eq!(w1.delta_t(), 100);
        assert_eq!(w1.capacity_gb, 10.0);
    }

    #[test]
    fn collect_windows_reuses_buffer_and_matches_wrapper() {
        let mut c = Cluster::new(1, &PartitionLayout::balanced());
        c.slice_mut(1)
            .timeline
            .reserve(Reservation { job: 1, subjob_seq: 0, interval: Interval::new(20, 60) })
            .unwrap();
        let mut buf = vec![Window {
            slice: 99,
            capacity_gb: 0.0,
            speed: 0.0,
            interval: Interval::new(0, 1),
        }];
        c.collect_windows(0, 100, 1, &mut buf);
        assert_eq!(buf, c.candidate_windows(0, 100, 1), "buffer path must match wrapper");
        assert!(buf.iter().all(|w| w.slice != 99), "buffer must be cleared first");
    }

    #[test]
    fn cluster_residue_count_sums_slices() {
        let mut c = Cluster::new(1, &PartitionLayout::balanced());
        // Slice 0: a 4-tick residue between two reservations.
        c.slice_mut(0)
            .timeline
            .reserve(Reservation { job: 1, subjob_seq: 0, interval: Interval::new(0, 10) })
            .unwrap();
        c.slice_mut(0)
            .timeline
            .reserve(Reservation { job: 1, subjob_seq: 1, interval: Interval::new(14, 40) })
            .unwrap();
        // Slice 1: a 2-tick residue at the head of the query span.
        c.slice_mut(1)
            .timeline
            .reserve(Reservation { job: 2, subjob_seq: 0, interval: Interval::new(2, 50) })
            .unwrap();
        assert_eq!(c.count_unusable_residues(0, 100, 8), 2);
        assert_eq!(c.count_unusable_residues(0, 100, 3), 1);
    }

    #[test]
    fn utilization_weights_by_compute() {
        let mut c = Cluster::new(1, &PartitionLayout::balanced()); // 3g+2g+2g
        // Fill the 3g slice fully for [0,100).
        c.slice_mut(0)
            .timeline
            .reserve(Reservation { job: 1, subjob_seq: 0, interval: Interval::new(0, 100) })
            .unwrap();
        let u = c.utilization(0, 100);
        // busy 3/7 * 100 of capacity 7/7 * 100 = 3/7.
        assert!((u - 3.0 / 7.0).abs() < 1e-12, "u = {u}");
        assert_eq!(c.utilization(100, 100), 0.0);
    }

    #[test]
    fn mean_fragmentation_and_compact() {
        let mut c = Cluster::new(1, &PartitionLayout::seven_small());
        for (i, t) in [(0u32, 10u64), (1, 20), (2, 30)] {
            c.slice_mut(i)
                .timeline
                .reserve(Reservation { job: i, subjob_seq: 0, interval: Interval::new(t, t + 5) })
                .unwrap();
        }
        assert!(c.mean_fragmentation(0, 100) > 0.0);
        assert_eq!(c.compact_before(40), 3);
        assert_eq!(c.compact_before(40), 0);
    }
}
