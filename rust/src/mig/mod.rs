//! MIG substrate: slice profiles, partition layouts, per-slice reservation
//! timelines, and the cluster model the schedulers operate on.
//!
//! The paper evaluates JASDA on MIG-enabled GPUs; since no physical MIG
//! hardware is available here, this module provides a behaviorally
//! faithful simulated substrate (see DESIGN.md §4): the NVIDIA profile
//! table fixes slice capacities and compute fractions, and timelines
//! enforce the non-overlap invariant the clearing phase relies on.
//!
//! Each [`Timeline`] additionally maintains an **incremental gap index**
//! (§Perf iteration 2) so window announcement and the repack trigger
//! read idle structure with an O(log n) search per query instead of
//! re-deriving it from the reservation list every scheduler iteration; see
//! [`timeline`] for the invariants and
//! [`Cluster::collect_windows`]/[`Cluster::count_unusable_residues`]
//! for the cluster-wide zero-allocation entry points.

pub mod cluster;
pub mod profile;
pub mod timeline;

pub use cluster::{Cluster, Slice, Window};
pub use profile::{PartitionLayout, SliceProfile};
pub use timeline::{IdleGap, Reservation, Timeline};
