//! MIG substrate: slice profiles, partition layouts, per-slice reservation
//! timelines, and the cluster model the schedulers operate on.
//!
//! The paper evaluates JASDA on MIG-enabled GPUs; since no physical MIG
//! hardware is available here, this module provides a behaviorally
//! faithful simulated substrate (see DESIGN.md §4): the NVIDIA profile
//! table fixes slice capacities and compute fractions, and timelines
//! enforce the non-overlap invariant the clearing phase relies on.

pub mod cluster;
pub mod profile;
pub mod timeline;

pub use cluster::{Cluster, Slice, Window};
pub use profile::{PartitionLayout, SliceProfile};
pub use timeline::{IdleGap, Reservation, Timeline};
