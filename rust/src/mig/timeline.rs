//! Per-slice reservation timeline and idle-window extraction.
//!
//! Each MIG slice owns a [`Timeline`]: a sorted, non-overlapping list of
//! committed subjob reservations. The scheduler's *window announcement*
//! step (paper §3.1) queries the timeline for contiguous idle regions; the
//! *commit* step (paper §3.5) inserts the reservations selected by the WIS
//! clearing phase. Overlap is rejected structurally, so a committed
//! schedule can never violate the non-preemption invariant.
//!
//! # Incremental gap index (§Perf iteration 2)
//!
//! Window announcement runs every scheduler iteration, so re-deriving the
//! idle structure from the reservation list each tick is the dominant
//! cost on dense timelines. The timeline therefore maintains a
//! **persistent interior-gap index** — the sorted list of idle intervals
//! between consecutive reservations — updated on every mutation
//! ([`Timeline::reserve`], [`Timeline::release`],
//! [`Timeline::truncate`], [`Timeline::compact_before`]) with an
//! O(log n) position lookup plus the same O(n) `Vec` shift the entry
//! list itself pays.
//! [`Timeline::for_each_gap`] then enumerates the idle windows of any
//! query span without allocating and without walking reservations, and
//! [`Timeline::count_unusable_residues`] answers the rolling-repack
//! trigger (paper §3.5) from the same index. [`Timeline::idle_gaps_scan`]
//! keeps the original full timeline walk as the recompute reference the
//! property tests compare the index against.

use crate::types::{Duration, Interval, JobId, Time};

/// A committed, non-preemptive reservation of a slice by one subjob.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Reservation {
    /// Job the subjob belongs to.
    pub job: JobId,
    /// Monotone per-job subjob sequence number (0-based).
    pub subjob_seq: u32,
    /// Reserved execution interval.
    pub interval: Interval,
}

/// Sorted, non-overlapping reservation list for one slice.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    /// Reservations sorted by start time; pairwise non-overlapping.
    entries: Vec<Reservation>,
    /// Incremental gap index: the idle intervals *between* consecutive
    /// reservations (positive length only), sorted by start. Because
    /// reservation end times are strictly increasing, gap starts are
    /// unique and the index is binary-searchable. The open regions
    /// before the first and after the last reservation are not stored —
    /// they depend on the query span and are derived in
    /// [`Timeline::for_each_gap`].
    gaps: Vec<Interval>,
}

/// An idle gap on a slice, as announced to jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IdleGap {
    /// Gap interval (clipped to the query horizon).
    pub interval: Interval,
}

impl Timeline {
    /// Empty timeline.
    pub fn new() -> Self {
        Timeline { entries: Vec::new(), gaps: Vec::new() }
    }

    /// The interior-gap index: idle intervals between consecutive
    /// reservations, sorted by start. Maintained incrementally.
    pub fn gap_index(&self) -> &[Interval] {
        &self.gaps
    }

    /// Remove the index entry starting at `start`, if present.
    fn remove_gap_starting_at(&mut self, start: Time) {
        if let Ok(i) = self.gaps.binary_search_by(|g| g.start.cmp(&start)) {
            self.gaps.remove(i);
        }
    }

    /// Insert a gap into the index (no-op for empty intervals).
    fn insert_gap(&mut self, start: Time, end: Time) {
        if start < end {
            let i = self.gaps.partition_point(|g| g.start < start);
            self.gaps.insert(i, Interval::new(start, end));
        }
    }

    /// Debug-build invariant: the index equals a fresh recompute from the
    /// reservation list. Compiled out of release builds.
    fn debug_check_gaps(&self) {
        #[cfg(debug_assertions)]
        {
            let mut expect = Vec::new();
            for w in self.entries.windows(2) {
                if w[0].interval.end < w[1].interval.start {
                    expect.push(Interval::new(w[0].interval.end, w[1].interval.start));
                }
            }
            debug_assert_eq!(self.gaps, expect, "gap index diverged from timeline");
        }
    }

    /// Number of reservations.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if no reservations exist.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// All reservations in start order.
    pub fn entries(&self) -> &[Reservation] {
        &self.entries
    }

    /// Position of the first entry whose end is after `t` (binary search).
    fn first_ending_after(&self, t: Time) -> usize {
        self.entries.partition_point(|r| r.interval.end <= t)
    }

    /// True if `interval` overlaps no existing reservation.
    pub fn is_free(&self, interval: &Interval) -> bool {
        if interval.is_empty() {
            return true;
        }
        let i = self.first_ending_after(interval.start);
        match self.entries.get(i) {
            Some(r) => !r.interval.overlaps(interval),
            None => true,
        }
    }

    /// Insert a reservation; fails if it overlaps any existing one.
    pub fn reserve(&mut self, res: Reservation) -> anyhow::Result<()> {
        if res.interval.is_empty() {
            anyhow::bail!("empty reservation interval {}", res.interval);
        }
        if !self.is_free(&res.interval) {
            anyhow::bail!(
                "reservation {} for job {} overlaps an existing commitment",
                res.interval,
                res.job
            );
        }
        let pos = self.entries.partition_point(|r| r.interval.start < res.interval.start);
        // Index maintenance: the new reservation lands between `left`
        // and `right`; their shared gap (if any) is split by it.
        let left_end = pos.checked_sub(1).map(|i| self.entries[i].interval.end);
        let right_start = self.entries.get(pos).map(|r| r.interval.start);
        if let (Some(le), Some(rs)) = (left_end, right_start) {
            if le < rs {
                self.remove_gap_starting_at(le);
            }
        }
        if let Some(le) = left_end {
            self.insert_gap(le, res.interval.start);
        }
        if let Some(rs) = right_start {
            self.insert_gap(res.interval.end, rs);
        }
        self.entries.insert(pos, res);
        self.debug_check_gaps();
        Ok(())
    }

    /// Remove a reservation (used by the rolling-repack pass). Returns the
    /// removed entry if found.
    pub fn release(&mut self, job: JobId, subjob_seq: u32) -> Option<Reservation> {
        let pos = self
            .entries
            .iter()
            .position(|r| r.job == job && r.subjob_seq == subjob_seq)?;
        let r = self.entries.remove(pos);
        // Index maintenance: the gaps bordering the removed reservation
        // merge into one (or dissolve into the leading/trailing region).
        let left_end = pos.checked_sub(1).map(|i| self.entries[i].interval.end);
        let right_start = self.entries.get(pos).map(|e| e.interval.start);
        if let Some(le) = left_end {
            if le < r.interval.start {
                self.remove_gap_starting_at(le);
            }
        }
        if let Some(rs) = right_start {
            if r.interval.end < rs {
                self.remove_gap_starting_at(r.interval.end);
            }
        }
        if let (Some(le), Some(rs)) = (left_end, right_start) {
            self.insert_gap(le, rs);
        }
        self.debug_check_gaps();
        Some(r)
    }

    /// Truncate a reservation's end (the realized subjob finished early).
    /// Returns false if the reservation was not found or `new_end` does not
    /// shrink it.
    pub fn truncate(&mut self, job: JobId, subjob_seq: u32, new_end: Time) -> bool {
        for i in 0..self.entries.len() {
            let r = &self.entries[i];
            if r.job == job && r.subjob_seq == subjob_seq {
                if new_end > r.interval.start && new_end < r.interval.end {
                    let old_end = r.interval.end;
                    self.entries[i].interval.end = new_end;
                    // Index maintenance: the gap toward the next
                    // reservation grows backward (or appears).
                    if let Some(rs) = self.entries.get(i + 1).map(|e| e.interval.start) {
                        if old_end < rs {
                            self.remove_gap_starting_at(old_end);
                        }
                        self.insert_gap(new_end, rs);
                    }
                    self.debug_check_gaps();
                    return true;
                }
                return false;
            }
        }
        false
    }

    /// Drop reservations that end at or before `t` (history compaction).
    /// Returns how many entries were removed.
    pub fn compact_before(&mut self, t: Time) -> usize {
        let keep_from = self.first_ending_after(t);
        if keep_from == 0 {
            return 0;
        }
        // Index maintenance: gaps start at the end of some reservation;
        // exactly the gaps following a dropped reservation (end <= t)
        // are dropped with it.
        let g0 = self.gaps.partition_point(|g| g.start <= t);
        self.gaps.drain(..g0);
        let n = self.entries.drain(..keep_from).count();
        self.debug_check_gaps();
        n
    }

    /// Visit the idle gaps in `[from, to)` of at least `min_len` ticks,
    /// in start order, **without allocating**: interior gaps come from
    /// the incremental index (binary search to the first relevant one),
    /// and the open regions before the first / after the last
    /// reservation are derived from the entry bounds. Produces exactly
    /// the intervals of [`Timeline::idle_gaps_scan`].
    pub fn for_each_gap(&self, from: Time, to: Time, min_len: Duration, mut f: impl FnMut(IdleGap)) {
        if from >= to {
            return;
        }
        let min_len = min_len.max(1);
        let (first, last) = match (self.entries.first(), self.entries.last()) {
            (Some(a), Some(b)) => (a, b),
            _ => {
                if to - from >= min_len {
                    f(IdleGap { interval: Interval::new(from, to) });
                }
                return;
            }
        };
        // Leading region before the first reservation.
        if from < first.interval.start {
            let gap = Interval::new(from, first.interval.start.min(to));
            if gap.len() >= min_len {
                f(IdleGap { interval: gap });
            }
        }
        // Interior gaps, clipped to the query span.
        let i0 = self.gaps.partition_point(|g| g.end <= from);
        for g in &self.gaps[i0..] {
            if g.start >= to {
                break;
            }
            let gap = Interval::new(g.start.max(from), g.end.min(to));
            if gap.len() >= min_len {
                f(IdleGap { interval: gap });
            }
        }
        // Trailing region after the last reservation.
        if last.interval.end < to {
            let gap = Interval::new(last.interval.end.max(from), to);
            if gap.len() >= min_len {
                f(IdleGap { interval: gap });
            }
        }
    }

    /// Idle gaps in `[from, horizon)`, each at least `min_len` ticks
    /// long, as an owned vector (convenience wrapper over
    /// [`Timeline::for_each_gap`]; hot paths use the closure form).
    pub fn idle_gaps(&self, from: Time, horizon: Time, min_len: Duration) -> Vec<IdleGap> {
        let mut gaps = Vec::new();
        self.for_each_gap(from, horizon, min_len, |g| gaps.push(g));
        gaps
    }

    /// Recompute-from-scratch reference for [`Timeline::idle_gaps`]: the
    /// original full timeline walk. Kept as the oracle the property
    /// tests compare the incremental gap index against.
    pub fn idle_gaps_scan(&self, from: Time, horizon: Time, min_len: Duration) -> Vec<IdleGap> {
        let mut gaps = Vec::new();
        if from >= horizon {
            return gaps;
        }
        let mut cursor = from;
        for r in &self.entries[self.first_ending_after(from)..] {
            if r.interval.start >= horizon {
                break;
            }
            if r.interval.start > cursor {
                let gap = Interval::new(cursor, r.interval.start.min(horizon));
                if gap.len() >= min_len {
                    gaps.push(IdleGap { interval: gap });
                }
            }
            cursor = cursor.max(r.interval.end);
        }
        if cursor < horizon {
            let gap = Interval::new(cursor, horizon);
            if gap.len() >= min_len {
                gaps.push(IdleGap { interval: gap });
            }
        }
        gaps
    }

    /// Number of idle residues in `[from, to)` too short to ever host a
    /// subjob (`0 < len < tau_min`) — the rolling-repack trigger metric
    /// (paper §3.5), answered from the gap index without allocating.
    pub fn count_unusable_residues(&self, from: Time, to: Time, tau_min: Duration) -> usize {
        let mut n = 0;
        self.for_each_gap(from, to, 1, |g| {
            if g.interval.len() < tau_min {
                n += 1;
            }
        });
        n
    }

    /// Earliest idle gap in `[from, horizon)` of at least `min_len`, if any.
    pub fn earliest_gap(&self, from: Time, horizon: Time, min_len: Duration) -> Option<IdleGap> {
        // Same walk as idle_gaps but returns at the first hit.
        if from >= horizon {
            return None;
        }
        let mut cursor = from;
        for r in &self.entries[self.first_ending_after(from)..] {
            if r.interval.start >= horizon {
                break;
            }
            if r.interval.start > cursor {
                let gap = Interval::new(cursor, r.interval.start.min(horizon));
                if gap.len() >= min_len {
                    return Some(IdleGap { interval: gap });
                }
            }
            cursor = cursor.max(r.interval.end);
        }
        if cursor < horizon {
            let gap = Interval::new(cursor, horizon);
            if gap.len() >= min_len {
                return Some(IdleGap { interval: gap });
            }
        }
        None
    }

    /// Total busy ticks within `[from, to)`.
    pub fn busy_ticks(&self, from: Time, to: Time) -> Duration {
        if from >= to {
            return 0;
        }
        let window = Interval::new(from, to);
        self.entries[self.first_ending_after(from)..]
            .iter()
            .take_while(|r| r.interval.start < to)
            .filter_map(|r| r.interval.intersect(&window))
            .map(|iv| iv.len())
            .sum()
    }

    /// Fragmentation in `[from, to)`: 1 − (largest idle gap / total idle).
    ///
    /// 0 means all idle time is one contiguous block (no fragmentation);
    /// values near 1 mean idle time is shattered into many small gaps.
    /// Returns 0 when there is no idle time at all.
    pub fn fragmentation(&self, from: Time, to: Time) -> f64 {
        let mut total: u64 = 0;
        let mut largest: u64 = 0;
        self.for_each_gap(from, to, 1, |g| {
            let len = g.interval.len();
            total += len;
            largest = largest.max(len);
        });
        if total == 0 {
            return 0.0;
        }
        1.0 - largest as f64 / total as f64
    }

    /// The reservation active at tick `t`, if any.
    pub fn active_at(&self, t: Time) -> Option<&Reservation> {
        let i = self.first_ending_after(t);
        self.entries.get(i).filter(|r| r.interval.contains_tick(t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn res(job: JobId, seq: u32, s: Time, e: Time) -> Reservation {
        Reservation { job, subjob_seq: seq, interval: Interval::new(s, e) }
    }

    #[test]
    fn reserve_keeps_sorted_and_rejects_overlap() {
        let mut tl = Timeline::new();
        tl.reserve(res(1, 0, 50, 60)).unwrap();
        tl.reserve(res(2, 0, 10, 20)).unwrap();
        tl.reserve(res(3, 0, 30, 40)).unwrap();
        let starts: Vec<Time> = tl.entries().iter().map(|r| r.interval.start).collect();
        assert_eq!(starts, vec![10, 30, 50]);
        // Overlapping inserts fail in every overlap configuration.
        assert!(tl.reserve(res(4, 0, 15, 25)).is_err()); // tail overlap
        assert!(tl.reserve(res(4, 0, 5, 15)).is_err()); // head overlap
        assert!(tl.reserve(res(4, 0, 0, 100)).is_err()); // containing
        assert!(tl.reserve(res(4, 0, 52, 58)).is_err()); // contained
        assert!(tl.reserve(res(4, 0, 20, 30)).is_ok()); // exactly adjacent ok
        assert_eq!(tl.len(), 4);
    }

    #[test]
    fn empty_reservation_rejected() {
        let mut tl = Timeline::new();
        assert!(tl.reserve(res(1, 0, 10, 10)).is_err());
    }

    #[test]
    fn idle_gaps_basic() {
        let mut tl = Timeline::new();
        tl.reserve(res(1, 0, 10, 20)).unwrap();
        tl.reserve(res(2, 0, 40, 50)).unwrap();
        let gaps = tl.idle_gaps(0, 100, 1);
        let ivs: Vec<(Time, Time)> =
            gaps.iter().map(|g| (g.interval.start, g.interval.end)).collect();
        assert_eq!(ivs, vec![(0, 10), (20, 40), (50, 100)]);
    }

    #[test]
    fn idle_gaps_min_len_filters() {
        let mut tl = Timeline::new();
        tl.reserve(res(1, 0, 10, 20)).unwrap();
        tl.reserve(res(2, 0, 25, 50)).unwrap();
        let gaps = tl.idle_gaps(0, 60, 8);
        let ivs: Vec<(Time, Time)> =
            gaps.iter().map(|g| (g.interval.start, g.interval.end)).collect();
        assert_eq!(ivs, vec![(0, 10), (50, 60)], "the 5-tick gap must be filtered");
    }

    #[test]
    fn idle_gaps_clip_to_horizon_and_from() {
        let mut tl = Timeline::new();
        tl.reserve(res(1, 0, 10, 20)).unwrap();
        let gaps = tl.idle_gaps(15, 18, 1);
        assert!(gaps.is_empty(), "query window fully inside a reservation");
        let gaps = tl.idle_gaps(12, 30, 1);
        assert_eq!(gaps.len(), 1);
        assert_eq!(gaps[0].interval, Interval::new(20, 30));
    }

    #[test]
    fn earliest_gap_matches_idle_gaps_head() {
        let mut tl = Timeline::new();
        tl.reserve(res(1, 0, 0, 30)).unwrap();
        tl.reserve(res(2, 0, 35, 60)).unwrap();
        let g = tl.earliest_gap(0, 100, 4).unwrap();
        assert_eq!(g.interval, Interval::new(30, 35));
        let g = tl.earliest_gap(0, 100, 6).unwrap();
        assert_eq!(g.interval, Interval::new(60, 100));
        assert!(tl.earliest_gap(0, 30, 31).is_none());
    }

    #[test]
    fn busy_ticks_and_fragmentation() {
        let mut tl = Timeline::new();
        assert_eq!(tl.busy_ticks(0, 100), 0);
        assert_eq!(tl.fragmentation(0, 100), 0.0, "one big idle gap -> 0 frag");
        tl.reserve(res(1, 0, 10, 20)).unwrap();
        tl.reserve(res(2, 0, 40, 80)).unwrap();
        assert_eq!(tl.busy_ticks(0, 100), 50);
        assert_eq!(tl.busy_ticks(15, 45), 10);
        // gaps: [0,10) len 10, [20,40) len 20, [80,100) len 20 -> total 50, largest 20
        let f = tl.fragmentation(0, 100);
        assert!((f - (1.0 - 20.0 / 50.0)).abs() < 1e-12);
        // Fully busy window -> no idle -> 0 by convention.
        assert_eq!(tl.fragmentation(40, 80), 0.0);
    }

    #[test]
    fn release_and_truncate() {
        let mut tl = Timeline::new();
        tl.reserve(res(1, 0, 10, 20)).unwrap();
        tl.reserve(res(1, 1, 30, 40)).unwrap();
        assert!(tl.truncate(1, 1, 35));
        assert_eq!(tl.entries()[1].interval, Interval::new(30, 35));
        assert!(!tl.truncate(1, 1, 45), "cannot grow via truncate");
        assert!(!tl.truncate(1, 1, 30), "cannot empty via truncate");
        let r = tl.release(1, 0).unwrap();
        assert_eq!(r.interval, Interval::new(10, 20));
        assert_eq!(tl.len(), 1);
        assert!(tl.release(9, 9).is_none());
    }

    #[test]
    fn compact_before_drops_history() {
        let mut tl = Timeline::new();
        tl.reserve(res(1, 0, 0, 10)).unwrap();
        tl.reserve(res(2, 0, 10, 20)).unwrap();
        tl.reserve(res(3, 0, 30, 40)).unwrap();
        assert_eq!(tl.compact_before(20), 2);
        assert_eq!(tl.len(), 1);
        assert_eq!(tl.compact_before(20), 0);
    }

    #[test]
    fn gap_index_tracks_mutations() {
        let mut tl = Timeline::new();
        assert!(tl.gap_index().is_empty());
        tl.reserve(res(1, 0, 10, 20)).unwrap();
        assert!(tl.gap_index().is_empty(), "single entry has no interior gap");
        tl.reserve(res(2, 0, 40, 50)).unwrap();
        assert_eq!(tl.gap_index(), &[Interval::new(20, 40)]);
        // Split the gap by inserting into its middle.
        tl.reserve(res(3, 0, 25, 30)).unwrap();
        assert_eq!(tl.gap_index(), &[Interval::new(20, 25), Interval::new(30, 40)]);
        // Adjacent insert leaves a single-sided gap.
        tl.reserve(res(4, 0, 20, 25)).unwrap();
        assert_eq!(tl.gap_index(), &[Interval::new(30, 40)]);
        // Release merges neighbors back.
        tl.release(3, 0).unwrap();
        assert_eq!(tl.gap_index(), &[Interval::new(25, 40)]);
        // Truncate grows the following gap backward.
        assert!(tl.truncate(4, 0, 22));
        assert_eq!(tl.gap_index(), &[Interval::new(22, 40)]);
        // Truncating the last entry touches no interior gap.
        assert!(tl.truncate(2, 0, 45));
        assert_eq!(tl.gap_index(), &[Interval::new(22, 40)]);
        // Compaction drops gaps that trail dropped reservations.
        assert_eq!(tl.compact_before(22), 2);
        assert!(tl.gap_index().is_empty());
    }

    #[test]
    fn for_each_gap_matches_scan() {
        let mut tl = Timeline::new();
        for (j, s, e) in [(1u32, 10u64, 20u64), (2, 20, 25), (3, 40, 50), (4, 80, 90)] {
            tl.reserve(res(j, 0, s, e)).unwrap();
        }
        for &(from, to, min_len) in &[
            (0u64, 100u64, 1u64),
            (0, 100, 8),
            (12, 45, 1),
            (22, 60, 3),
            (50, 80, 1),
            (95, 99, 1),
            (60, 60, 1),
            (5, 10, 1),
        ] {
            assert_eq!(
                tl.idle_gaps(from, to, min_len),
                tl.idle_gaps_scan(from, to, min_len),
                "index vs scan mismatch for [{from},{to}) min {min_len}"
            );
        }
        assert_eq!(Timeline::new().idle_gaps(3, 9, 1), Timeline::new().idle_gaps_scan(3, 9, 1));
    }

    #[test]
    fn count_unusable_residues_matches_filtered_scan() {
        let mut tl = Timeline::new();
        tl.reserve(res(1, 0, 10, 20)).unwrap();
        tl.reserve(res(2, 0, 24, 50)).unwrap(); // 4-tick residue
        tl.reserve(res(3, 0, 52, 70)).unwrap(); // 2-tick residue
        for &(from, to, tau) in &[(0u64, 100u64, 8u64), (0, 100, 3), (15, 53, 8), (0, 26, 8)] {
            let expect = tl
                .idle_gaps_scan(from, to, 1)
                .iter()
                .filter(|g| g.interval.len() < tau)
                .count();
            assert_eq!(
                tl.count_unusable_residues(from, to, tau),
                expect,
                "residue count mismatch for [{from},{to}) tau {tau}"
            );
        }
    }

    #[test]
    fn active_at_finds_running_reservation() {
        let mut tl = Timeline::new();
        tl.reserve(res(7, 3, 10, 20)).unwrap();
        assert_eq!(tl.active_at(15).map(|r| r.job), Some(7));
        assert_eq!(tl.active_at(20), None, "end is exclusive");
        assert_eq!(tl.active_at(5), None);
    }
}
