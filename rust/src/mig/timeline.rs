//! Per-slice reservation timeline and idle-window extraction.
//!
//! Each MIG slice owns a [`Timeline`]: a sorted, non-overlapping list of
//! committed subjob reservations. The scheduler's *window announcement*
//! step (paper §3.1) queries the timeline for contiguous idle regions; the
//! *commit* step (paper §3.5) inserts the reservations selected by the WIS
//! clearing phase. Overlap is rejected structurally, so a committed
//! schedule can never violate the non-preemption invariant.

use crate::types::{Duration, Interval, JobId, Time};

/// A committed, non-preemptive reservation of a slice by one subjob.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Reservation {
    /// Job the subjob belongs to.
    pub job: JobId,
    /// Monotone per-job subjob sequence number (0-based).
    pub subjob_seq: u32,
    /// Reserved execution interval.
    pub interval: Interval,
}

/// Sorted, non-overlapping reservation list for one slice.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    /// Reservations sorted by start time; pairwise non-overlapping.
    entries: Vec<Reservation>,
}

/// An idle gap on a slice, as announced to jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IdleGap {
    /// Gap interval (clipped to the query horizon).
    pub interval: Interval,
}

impl Timeline {
    /// Empty timeline.
    pub fn new() -> Self {
        Timeline { entries: Vec::new() }
    }

    /// Number of reservations.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if no reservations exist.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// All reservations in start order.
    pub fn entries(&self) -> &[Reservation] {
        &self.entries
    }

    /// Position of the first entry whose end is after `t` (binary search).
    fn first_ending_after(&self, t: Time) -> usize {
        self.entries.partition_point(|r| r.interval.end <= t)
    }

    /// True if `interval` overlaps no existing reservation.
    pub fn is_free(&self, interval: &Interval) -> bool {
        if interval.is_empty() {
            return true;
        }
        let i = self.first_ending_after(interval.start);
        match self.entries.get(i) {
            Some(r) => !r.interval.overlaps(interval),
            None => true,
        }
    }

    /// Insert a reservation; fails if it overlaps any existing one.
    pub fn reserve(&mut self, res: Reservation) -> anyhow::Result<()> {
        if res.interval.is_empty() {
            anyhow::bail!("empty reservation interval {}", res.interval);
        }
        if !self.is_free(&res.interval) {
            anyhow::bail!(
                "reservation {} for job {} overlaps an existing commitment",
                res.interval,
                res.job
            );
        }
        let pos = self.entries.partition_point(|r| r.interval.start < res.interval.start);
        self.entries.insert(pos, res);
        Ok(())
    }

    /// Remove a reservation (used by the rolling-repack pass). Returns the
    /// removed entry if found.
    pub fn release(&mut self, job: JobId, subjob_seq: u32) -> Option<Reservation> {
        let pos = self
            .entries
            .iter()
            .position(|r| r.job == job && r.subjob_seq == subjob_seq)?;
        Some(self.entries.remove(pos))
    }

    /// Truncate a reservation's end (the realized subjob finished early).
    /// Returns false if the reservation was not found or `new_end` does not
    /// shrink it.
    pub fn truncate(&mut self, job: JobId, subjob_seq: u32, new_end: Time) -> bool {
        for r in &mut self.entries {
            if r.job == job && r.subjob_seq == subjob_seq {
                if new_end > r.interval.start && new_end < r.interval.end {
                    r.interval.end = new_end;
                    return true;
                }
                return false;
            }
        }
        false
    }

    /// Drop reservations that end at or before `t` (history compaction).
    /// Returns how many entries were removed.
    pub fn compact_before(&mut self, t: Time) -> usize {
        let keep_from = self.first_ending_after(t);
        if keep_from == 0 {
            return 0;
        }
        self.entries.drain(..keep_from).count()
    }

    /// Enumerate idle gaps in `[from, horizon)`, each at least `min_len`
    /// ticks long. This is the raw material of window announcement.
    pub fn idle_gaps(&self, from: Time, horizon: Time, min_len: Duration) -> Vec<IdleGap> {
        let mut gaps = Vec::new();
        if from >= horizon {
            return gaps;
        }
        let mut cursor = from;
        for r in &self.entries[self.first_ending_after(from)..] {
            if r.interval.start >= horizon {
                break;
            }
            if r.interval.start > cursor {
                let gap = Interval::new(cursor, r.interval.start.min(horizon));
                if gap.len() >= min_len {
                    gaps.push(IdleGap { interval: gap });
                }
            }
            cursor = cursor.max(r.interval.end);
        }
        if cursor < horizon {
            let gap = Interval::new(cursor, horizon);
            if gap.len() >= min_len {
                gaps.push(IdleGap { interval: gap });
            }
        }
        gaps
    }

    /// Earliest idle gap in `[from, horizon)` of at least `min_len`, if any.
    pub fn earliest_gap(&self, from: Time, horizon: Time, min_len: Duration) -> Option<IdleGap> {
        // Same walk as idle_gaps but returns at the first hit.
        if from >= horizon {
            return None;
        }
        let mut cursor = from;
        for r in &self.entries[self.first_ending_after(from)..] {
            if r.interval.start >= horizon {
                break;
            }
            if r.interval.start > cursor {
                let gap = Interval::new(cursor, r.interval.start.min(horizon));
                if gap.len() >= min_len {
                    return Some(IdleGap { interval: gap });
                }
            }
            cursor = cursor.max(r.interval.end);
        }
        if cursor < horizon {
            let gap = Interval::new(cursor, horizon);
            if gap.len() >= min_len {
                return Some(IdleGap { interval: gap });
            }
        }
        None
    }

    /// Total busy ticks within `[from, to)`.
    pub fn busy_ticks(&self, from: Time, to: Time) -> Duration {
        if from >= to {
            return 0;
        }
        let window = Interval::new(from, to);
        self.entries[self.first_ending_after(from)..]
            .iter()
            .take_while(|r| r.interval.start < to)
            .filter_map(|r| r.interval.intersect(&window))
            .map(|iv| iv.len())
            .sum()
    }

    /// Fragmentation in `[from, to)`: 1 − (largest idle gap / total idle).
    ///
    /// 0 means all idle time is one contiguous block (no fragmentation);
    /// values near 1 mean idle time is shattered into many small gaps.
    /// Returns 0 when there is no idle time at all.
    pub fn fragmentation(&self, from: Time, to: Time) -> f64 {
        let gaps = self.idle_gaps(from, to, 1);
        let total: u64 = gaps.iter().map(|g| g.interval.len()).sum();
        if total == 0 {
            return 0.0;
        }
        let largest = gaps.iter().map(|g| g.interval.len()).max().unwrap_or(0);
        1.0 - largest as f64 / total as f64
    }

    /// The reservation active at tick `t`, if any.
    pub fn active_at(&self, t: Time) -> Option<&Reservation> {
        let i = self.first_ending_after(t);
        self.entries.get(i).filter(|r| r.interval.contains_tick(t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn res(job: JobId, seq: u32, s: Time, e: Time) -> Reservation {
        Reservation { job, subjob_seq: seq, interval: Interval::new(s, e) }
    }

    #[test]
    fn reserve_keeps_sorted_and_rejects_overlap() {
        let mut tl = Timeline::new();
        tl.reserve(res(1, 0, 50, 60)).unwrap();
        tl.reserve(res(2, 0, 10, 20)).unwrap();
        tl.reserve(res(3, 0, 30, 40)).unwrap();
        let starts: Vec<Time> = tl.entries().iter().map(|r| r.interval.start).collect();
        assert_eq!(starts, vec![10, 30, 50]);
        // Overlapping inserts fail in every overlap configuration.
        assert!(tl.reserve(res(4, 0, 15, 25)).is_err()); // tail overlap
        assert!(tl.reserve(res(4, 0, 5, 15)).is_err()); // head overlap
        assert!(tl.reserve(res(4, 0, 0, 100)).is_err()); // containing
        assert!(tl.reserve(res(4, 0, 52, 58)).is_err()); // contained
        assert!(tl.reserve(res(4, 0, 20, 30)).is_ok()); // exactly adjacent ok
        assert_eq!(tl.len(), 4);
    }

    #[test]
    fn empty_reservation_rejected() {
        let mut tl = Timeline::new();
        assert!(tl.reserve(res(1, 0, 10, 10)).is_err());
    }

    #[test]
    fn idle_gaps_basic() {
        let mut tl = Timeline::new();
        tl.reserve(res(1, 0, 10, 20)).unwrap();
        tl.reserve(res(2, 0, 40, 50)).unwrap();
        let gaps = tl.idle_gaps(0, 100, 1);
        let ivs: Vec<(Time, Time)> =
            gaps.iter().map(|g| (g.interval.start, g.interval.end)).collect();
        assert_eq!(ivs, vec![(0, 10), (20, 40), (50, 100)]);
    }

    #[test]
    fn idle_gaps_min_len_filters() {
        let mut tl = Timeline::new();
        tl.reserve(res(1, 0, 10, 20)).unwrap();
        tl.reserve(res(2, 0, 25, 50)).unwrap();
        let gaps = tl.idle_gaps(0, 60, 8);
        let ivs: Vec<(Time, Time)> =
            gaps.iter().map(|g| (g.interval.start, g.interval.end)).collect();
        assert_eq!(ivs, vec![(0, 10), (50, 60)], "the 5-tick gap must be filtered");
    }

    #[test]
    fn idle_gaps_clip_to_horizon_and_from() {
        let mut tl = Timeline::new();
        tl.reserve(res(1, 0, 10, 20)).unwrap();
        let gaps = tl.idle_gaps(15, 18, 1);
        assert!(gaps.is_empty(), "query window fully inside a reservation");
        let gaps = tl.idle_gaps(12, 30, 1);
        assert_eq!(gaps.len(), 1);
        assert_eq!(gaps[0].interval, Interval::new(20, 30));
    }

    #[test]
    fn earliest_gap_matches_idle_gaps_head() {
        let mut tl = Timeline::new();
        tl.reserve(res(1, 0, 0, 30)).unwrap();
        tl.reserve(res(2, 0, 35, 60)).unwrap();
        let g = tl.earliest_gap(0, 100, 4).unwrap();
        assert_eq!(g.interval, Interval::new(30, 35));
        let g = tl.earliest_gap(0, 100, 6).unwrap();
        assert_eq!(g.interval, Interval::new(60, 100));
        assert!(tl.earliest_gap(0, 30, 31).is_none());
    }

    #[test]
    fn busy_ticks_and_fragmentation() {
        let mut tl = Timeline::new();
        assert_eq!(tl.busy_ticks(0, 100), 0);
        assert_eq!(tl.fragmentation(0, 100), 0.0, "one big idle gap -> 0 frag");
        tl.reserve(res(1, 0, 10, 20)).unwrap();
        tl.reserve(res(2, 0, 40, 80)).unwrap();
        assert_eq!(tl.busy_ticks(0, 100), 50);
        assert_eq!(tl.busy_ticks(15, 45), 10);
        // gaps: [0,10) len 10, [20,40) len 20, [80,100) len 20 -> total 50, largest 20
        let f = tl.fragmentation(0, 100);
        assert!((f - (1.0 - 20.0 / 50.0)).abs() < 1e-12);
        // Fully busy window -> no idle -> 0 by convention.
        assert_eq!(tl.fragmentation(40, 80), 0.0);
    }

    #[test]
    fn release_and_truncate() {
        let mut tl = Timeline::new();
        tl.reserve(res(1, 0, 10, 20)).unwrap();
        tl.reserve(res(1, 1, 30, 40)).unwrap();
        assert!(tl.truncate(1, 1, 35));
        assert_eq!(tl.entries()[1].interval, Interval::new(30, 35));
        assert!(!tl.truncate(1, 1, 45), "cannot grow via truncate");
        assert!(!tl.truncate(1, 1, 30), "cannot empty via truncate");
        let r = tl.release(1, 0).unwrap();
        assert_eq!(r.interval, Interval::new(10, 20));
        assert_eq!(tl.len(), 1);
        assert!(tl.release(9, 9).is_none());
    }

    #[test]
    fn compact_before_drops_history() {
        let mut tl = Timeline::new();
        tl.reserve(res(1, 0, 0, 10)).unwrap();
        tl.reserve(res(2, 0, 10, 20)).unwrap();
        tl.reserve(res(3, 0, 30, 40)).unwrap();
        assert_eq!(tl.compact_before(20), 2);
        assert_eq!(tl.len(), 1);
        assert_eq!(tl.compact_before(20), 0);
    }

    #[test]
    fn active_at_finds_running_reservation() {
        let mut tl = Timeline::new();
        tl.reserve(res(7, 3, 10, 20)).unwrap();
        assert_eq!(tl.active_at(15).map(|r| r.job), Some(7));
        assert_eq!(tl.active_at(20), None, "end is exclusive");
        assert_eq!(tl.active_at(5), None);
    }
}
