//! NVIDIA MIG slice profiles (A100-40GB generation).
//!
//! A MIG-capable GPU is partitioned into isolated *GPU instances* whose
//! sizes are drawn from a fixed profile table. The unit of compute is one
//! seventh of the GPU's SM complement ("1g"); memory comes in 5 GiB steps
//! on the 40 GiB part. JASDA's decisions depend on exactly two profile
//! attributes: the slice's memory capacity `c_k` (the safety bound of
//! paper §4.1(a)) and its compute fraction (which sets subjob execution
//! speed in the simulator).


/// A MIG slice profile, named after the NVIDIA `Ng.Mgb` convention.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SliceProfile {
    /// 1g.5gb — 1/7 compute, 5 GiB.
    P1g5gb,
    /// 2g.10gb — 2/7 compute, 10 GiB.
    P2g10gb,
    /// 3g.20gb — 3/7 compute, 20 GiB.
    P3g20gb,
    /// 4g.20gb — 4/7 compute, 20 GiB.
    P4g20gb,
    /// 7g.40gb — full GPU, 40 GiB.
    P7g40gb,
}

impl SliceProfile {
    /// All profiles, smallest first.
    pub const ALL: [SliceProfile; 5] = [
        SliceProfile::P1g5gb,
        SliceProfile::P2g10gb,
        SliceProfile::P3g20gb,
        SliceProfile::P4g20gb,
        SliceProfile::P7g40gb,
    ];

    /// Memory capacity `c_k` in GiB.
    pub fn mem_gb(&self) -> f64 {
        match self {
            SliceProfile::P1g5gb => 5.0,
            SliceProfile::P2g10gb => 10.0,
            SliceProfile::P3g20gb => 20.0,
            SliceProfile::P4g20gb => 20.0,
            SliceProfile::P7g40gb => 40.0,
        }
    }

    /// Compute capacity in sevenths of the full GPU.
    pub fn compute_sevenths(&self) -> u32 {
        match self {
            SliceProfile::P1g5gb => 1,
            SliceProfile::P2g10gb => 2,
            SliceProfile::P3g20gb => 3,
            SliceProfile::P4g20gb => 4,
            SliceProfile::P7g40gb => 7,
        }
    }

    /// Relative execution speed of the slice (full GPU = 1.0).
    ///
    /// Work units in the simulator are defined as "full-GPU tick
    /// equivalents": a subjob carrying `w` work occupies a slice for
    /// `w / speed()` ticks.
    #[inline]
    pub fn speed(&self) -> f64 {
        self.compute_sevenths() as f64 / 7.0
    }

    /// Canonical NVIDIA profile name.
    pub fn name(&self) -> &'static str {
        match self {
            SliceProfile::P1g5gb => "1g.5gb",
            SliceProfile::P2g10gb => "2g.10gb",
            SliceProfile::P3g20gb => "3g.20gb",
            SliceProfile::P4g20gb => "4g.20gb",
            SliceProfile::P7g40gb => "7g.40gb",
        }
    }

    /// Parse a profile from its NVIDIA name.
    pub fn parse(s: &str) -> Option<SliceProfile> {
        Self::ALL.iter().copied().find(|p| p.name() == s)
    }
}

impl std::fmt::Display for SliceProfile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A named GPU partition layout: the multiset of slice profiles carved out
/// of one physical GPU. Valid layouts keep the compute total ≤ 7 sevenths
/// (memory follows automatically on the 40 GiB part for the standard
/// layouts used here).
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionLayout {
    /// Human-readable layout name (e.g. `"balanced"`).
    pub name: String,
    /// Slice profiles carved out of the GPU.
    pub slices: Vec<SliceProfile>,
}

impl PartitionLayout {
    /// Build and validate a layout.
    pub fn new(name: impl Into<String>, slices: Vec<SliceProfile>) -> anyhow::Result<Self> {
        let layout = PartitionLayout { name: name.into(), slices };
        layout.validate()?;
        Ok(layout)
    }

    /// Check MIG feasibility: total compute ≤ 7/7 and at least one slice.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.slices.is_empty() {
            anyhow::bail!("partition layout '{}' has no slices", self.name);
        }
        let total: u32 = self.slices.iter().map(|p| p.compute_sevenths()).sum();
        if total > 7 {
            anyhow::bail!(
                "partition layout '{}' oversubscribes compute: {total}/7 sevenths",
                self.name
            );
        }
        Ok(())
    }

    /// Total memory across slices in GiB.
    pub fn total_mem_gb(&self) -> f64 {
        self.slices.iter().map(|p| p.mem_gb()).sum()
    }

    /// The `1g×7` layout: seven small slices.
    pub fn seven_small() -> Self {
        PartitionLayout::new("7x1g", vec![SliceProfile::P1g5gb; 7]).unwrap()
    }

    /// A balanced mixed layout: 3g + 2g + 2g (the common "3-way" split).
    pub fn balanced() -> Self {
        PartitionLayout::new(
            "balanced",
            vec![SliceProfile::P3g20gb, SliceProfile::P2g10gb, SliceProfile::P2g10gb],
        )
        .unwrap()
    }

    /// Heterogeneous layout 4g + 2g + 1g covering small-to-large demand.
    pub fn heterogeneous() -> Self {
        PartitionLayout::new(
            "heterogeneous",
            vec![SliceProfile::P4g20gb, SliceProfile::P2g10gb, SliceProfile::P1g5gb],
        )
        .unwrap()
    }

    /// Whole-GPU layout (no slicing): one 7g slice.
    pub fn whole() -> Self {
        PartitionLayout::new("whole", vec![SliceProfile::P7g40gb]).unwrap()
    }

    /// Look up a named stock layout.
    pub fn stock(name: &str) -> Option<Self> {
        match name {
            "7x1g" => Some(Self::seven_small()),
            "balanced" => Some(Self::balanced()),
            "heterogeneous" => Some(Self::heterogeneous()),
            "whole" => Some(Self::whole()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_table_matches_nvidia_spec() {
        assert_eq!(SliceProfile::P1g5gb.mem_gb(), 5.0);
        assert_eq!(SliceProfile::P2g10gb.mem_gb(), 10.0);
        assert_eq!(SliceProfile::P3g20gb.mem_gb(), 20.0);
        assert_eq!(SliceProfile::P4g20gb.mem_gb(), 20.0);
        assert_eq!(SliceProfile::P7g40gb.mem_gb(), 40.0);
        assert_eq!(SliceProfile::P7g40gb.compute_sevenths(), 7);
        assert!((SliceProfile::P1g5gb.speed() - 1.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn profile_name_round_trip() {
        for p in SliceProfile::ALL {
            assert_eq!(SliceProfile::parse(p.name()), Some(p));
        }
        assert_eq!(SliceProfile::parse("8g.80gb"), None);
    }

    #[test]
    fn stock_layouts_are_valid() {
        for name in ["7x1g", "balanced", "heterogeneous", "whole"] {
            let l = PartitionLayout::stock(name).unwrap();
            l.validate().unwrap();
        }
        assert!(PartitionLayout::stock("nope").is_none());
    }

    #[test]
    fn oversubscribed_layout_rejected() {
        let r = PartitionLayout::new("bad", vec![SliceProfile::P4g20gb, SliceProfile::P4g20gb]);
        assert!(r.is_err());
        let r = PartitionLayout::new("empty", vec![]);
        assert!(r.is_err());
    }

    #[test]
    fn seven_small_fills_gpu() {
        let l = PartitionLayout::seven_small();
        let total: u32 = l.slices.iter().map(|p| p.compute_sevenths()).sum();
        assert_eq!(total, 7);
        assert_eq!(l.total_mem_gb(), 35.0);
    }
}
