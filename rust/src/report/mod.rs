//! Table renderers: markdown and CSV output for benches, examples, and
//! the CLI — the machinery that regenerates the paper's tables.

use crate::metrics::streaming::StreamingMetrics;
use crate::metrics::RunMetrics;

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Table title (printed above).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Rows of cells.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New empty table.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header arity).
    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as aligned markdown.
    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (RFC-4180-ish; quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// The standard per-scheduler comparison row used across benches.
pub fn comparison_headers() -> Vec<&'static str> {
    vec![
        "scheduler",
        "util",
        "mean_jct",
        "p95_jct",
        "mean_slowdown",
        "jain",
        "max_starv",
        "deadline_rate",
        "frag",
        "subjobs/job",
        "unfinished",
    ]
}

/// One numeric cell. Tables are machine-parsed downstream (CSV), so a
/// non-finite value renders as an explicit `-` cell instead of leaking a
/// literal `NaN`/`inf` (only the human summary line may carry NaN).
fn cell(v: f64, prec: usize) -> String {
    if v.is_finite() {
        format!("{v:.prec$}")
    } else {
        "-".to_string()
    }
}

/// Format one run's metrics as a comparison row.
pub fn comparison_row(m: &RunMetrics) -> Vec<String> {
    let f = |x: Option<f64>| x.map_or("-".to_string(), |v| cell(v, 3));
    let f0 = |x: Option<f64>| x.map_or("-".to_string(), |v| cell(v, 0));
    vec![
        m.scheduler.clone(),
        cell(m.utilization, 3),
        f0(m.mean_jct()),
        f0(m.jct_percentile(0.95)),
        f(m.mean_slowdown()),
        f(m.jain_fairness()),
        format!("{}", m.max_starvation()),
        f(m.deadline_met_rate()),
        cell(m.mean_fragmentation, 3),
        f(m.mean_subjobs()),
        format!("{}", m.unfinished),
    ]
}

/// Format one streaming run as the same comparison row (headers from
/// [`comparison_headers`]), so production-trace benches can put exact
/// and streaming schedulers side by side in one table.
pub fn streaming_comparison_row(m: &StreamingMetrics) -> Vec<String> {
    let f = |x: Option<f64>| x.map_or("-".to_string(), |v| cell(v, 3));
    let f0 = |x: Option<f64>| x.map_or("-".to_string(), |v| cell(v, 0));
    vec![
        m.scheduler.clone(),
        cell(m.utilization(), 3),
        f0(m.mean_jct()),
        f0(m.jct_percentile(0.95)),
        f(m.mean_slowdown()),
        f(m.jain_fairness()),
        format!("{}", m.max_starvation()),
        f(m.deadline_met_rate()),
        cell(m.mean_fragmentation(), 3),
        f(m.mean_subjobs()),
        format!("{}", m.unfinished()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment() {
        let mut t = Table::new("T", &["a", "long_header"]);
        t.push_row(vec!["x".into(), "1".into()]);
        t.push_row(vec!["yyyy".into(), "22".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| a    | long_header |"));
        let lines: Vec<&str> = md.lines().collect();
        // All table lines equal width.
        let widths: Vec<usize> =
            lines.iter().filter(|l| l.starts_with('|')).map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{md}");
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.push_row(vec!["x,y".into(), "q\"q".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"q\""));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("", &["a"]);
        t.push_row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn comparison_row_shapes() {
        let m = RunMetrics { scheduler: "x".into(), utilization: 0.5, ..Default::default() };
        let row = comparison_row(&m);
        assert_eq!(row.len(), comparison_headers().len());
        assert_eq!(row[0], "x");
        assert_eq!(row[2], "-", "no completed jobs -> dash");
    }

    #[test]
    fn non_finite_cells_render_as_dash() {
        // Regression: an all-unfinished run must not leak `NaN` into the
        // machine-parsed CSV — every cell is either a number or `-`.
        let m = RunMetrics {
            scheduler: "x".into(),
            utilization: f64::NAN,
            mean_fragmentation: f64::INFINITY,
            ..Default::default()
        };
        let row = comparison_row(&m);
        for c in &row {
            assert!(!c.contains("NaN") && !c.contains("inf"), "leaked non-finite: {c}");
        }
        assert_eq!(row[1], "-");
        assert_eq!(row[8], "-");
    }

    #[test]
    fn streaming_row_matches_headers() {
        let mut m = StreamingMetrics::new(1_000, 0.01);
        m.scheduler = "stream".into();
        let row = streaming_comparison_row(&m);
        assert_eq!(row.len(), comparison_headers().len());
        assert_eq!(row[0], "stream");
        assert_eq!(row[2], "-", "no completions -> dash");
        m.record_completion("t0:inf", 1.0, 0, 100, 50.0, 1, 10, None);
        m.finalize(0.5, 0.1, 100);
        let row = streaming_comparison_row(&m);
        assert_eq!(row[1], "0.500");
        assert_eq!(row[2], "100");
        assert_eq!(row[10], "0");
    }
}
