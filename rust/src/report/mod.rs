//! Table renderers: markdown and CSV output for benches, examples, and
//! the CLI — the machinery that regenerates the paper's tables.

use crate::metrics::RunMetrics;

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Table title (printed above).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Rows of cells.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New empty table.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header arity).
    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as aligned markdown.
    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (RFC-4180-ish; quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// The standard per-scheduler comparison row used across benches.
pub fn comparison_headers() -> Vec<&'static str> {
    vec![
        "scheduler",
        "util",
        "mean_jct",
        "p95_jct",
        "mean_slowdown",
        "jain",
        "max_starv",
        "deadline_rate",
        "frag",
        "subjobs/job",
        "unfinished",
    ]
}

/// Format one run's metrics as a comparison row.
pub fn comparison_row(m: &RunMetrics) -> Vec<String> {
    let f = |x: Option<f64>| x.map_or("-".to_string(), |v| format!("{v:.3}"));
    let f0 = |x: Option<f64>| x.map_or("-".to_string(), |v| format!("{v:.0}"));
    vec![
        m.scheduler.clone(),
        format!("{:.3}", m.utilization),
        f0(m.mean_jct()),
        f0(m.jct_percentile(0.95)),
        f(m.mean_slowdown()),
        f(m.jain_fairness()),
        format!("{}", m.max_starvation()),
        f(m.deadline_met_rate()),
        format!("{:.3}", m.mean_fragmentation),
        f(m.mean_subjobs()),
        format!("{}", m.unfinished),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment() {
        let mut t = Table::new("T", &["a", "long_header"]);
        t.push_row(vec!["x".into(), "1".into()]);
        t.push_row(vec!["yyyy".into(), "22".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| a    | long_header |"));
        let lines: Vec<&str> = md.lines().collect();
        // All table lines equal width.
        let widths: Vec<usize> =
            lines.iter().filter(|l| l.starts_with('|')).map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{md}");
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.push_row(vec!["x,y".into(), "q\"q".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"q\""));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("", &["a"]);
        t.push_row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn comparison_row_shapes() {
        let m = RunMetrics { scheduler: "x".into(), utilization: 0.5, ..Default::default() };
        let row = comparison_row(&m);
        assert_eq!(row.len(), comparison_headers().len());
        assert_eq!(row[0], "x");
        assert_eq!(row[2], "-", "no completed jobs -> dash");
    }
}
