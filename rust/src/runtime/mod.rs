//! PJRT runtime: load and execute the AOT-compiled L1/L2 scoring pipeline
//! from rust (python never runs on the scheduling path).
//!
//! `make artifacts` lowers `python/compile/model.py` (JAX, calling the
//! Pallas kernel) to **HLO text** (see DESIGN.md — the xla_extension
//! 0.5.1 bundled with the `xla` crate rejects jax≥0.5 serialized protos,
//! so text is the interchange format). [`PjrtScorer`] compiles the
//! artifact once on the PJRT CPU client and then serves
//! [`ScorerBackend::score`] calls by padding batches to the artifact's
//! fixed `[M_PAD, T]` shape.
//!
//! The executing implementation depends on the external `xla` crate and
//! is gated behind the off-by-default `pjrt` cargo feature (the offline
//! build has no registry access; enabling the feature requires adding
//! `xla` to `[dependencies]`). Without the feature a stub [`PjrtScorer`]
//! with the identical API is compiled that fails cleanly at load time, so
//! the `--pjrt` CLI path, benches, and examples keep building.

use crate::jasda::scoring::{ScoreBatch, ScoreOutput, ScorerBackend};
use std::path::{Path, PathBuf};

/// Fixed batch rows the AOT artifact was lowered with (must match
/// `python/compile/aot.py`).
pub const M_PAD: usize = 256;
/// Fixed FMP bins of the artifact (must match `python/compile/aot.py`).
pub const T_BINS: usize = 64;
/// Number of scalar parameters in the params vector:
/// `[capacity, theta, lambda, alpha(4), beta(4)]`.
pub const N_PARAMS: usize = 11;

/// Resolve the artifacts directory: `$JASDA_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("JASDA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::*;

    /// A compiled HLO module on the PJRT CPU client.
    pub struct HloExecutable {
        exe: xla::PjRtLoadedExecutable,
        /// Artifact path, for diagnostics.
        pub path: PathBuf,
    }

    impl HloExecutable {
        /// Load HLO text from `path` and compile it.
        pub fn load(client: &xla::PjRtClient, path: &Path) -> anyhow::Result<Self> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))?;
            Ok(HloExecutable { exe, path: path.to_path_buf() })
        }

        /// Execute with literal inputs; returns the flattened output tuple.
        pub fn run(&self, args: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
            let result = self
                .exe
                .execute::<xla::Literal>(args)
                .map_err(|e| anyhow::anyhow!("executing {}: {e:?}", self.path.display()))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetching result: {e:?}"))?;
            lit.to_tuple().map_err(|e| anyhow::anyhow!("untupling result: {e:?}"))
        }
    }

    pub(super) fn f32_literal(data: &[f32], dims: &[usize]) -> anyhow::Result<xla::Literal> {
        let n: usize = dims.iter().product();
        anyhow::ensure!(
            data.len() == n,
            "literal data/shape mismatch: {} vs {:?}",
            data.len(),
            dims
        );
        let bytes: &[u8] =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
        xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
            .map_err(|e| anyhow::anyhow!("creating literal: {e:?}"))
    }

    /// The PJRT-backed scoring backend (L1/L2 on the hot path).
    pub struct PjrtScorer {
        exe: HloExecutable,
        // Reusable padded staging buffers (allocation-free steady state).
        mu: Vec<f32>,
        sigma: Vec<f32>,
        phi: Vec<f32>,
        psi: Vec<f32>,
        trust: Vec<f32>,
        hist: Vec<f32>,
        valid: Vec<f32>,
    }

    impl PjrtScorer {
        /// Load `scorer.hlo.txt` from the default artifacts directory.
        pub fn from_default_artifacts() -> anyhow::Result<Self> {
            Self::load(&artifacts_dir().join("scorer.hlo.txt"))
        }

        /// Load and compile the scorer artifact at `path`.
        pub fn load(path: &Path) -> anyhow::Result<Self> {
            anyhow::ensure!(
                path.exists(),
                "scorer artifact {} not found — run `make artifacts` first",
                path.display()
            );
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("creating PJRT client: {e:?}"))?;
            let exe = HloExecutable::load(&client, path)?;
            Ok(PjrtScorer {
                exe,
                mu: vec![0.0; M_PAD * T_BINS],
                sigma: vec![0.0; M_PAD * T_BINS],
                phi: vec![0.0; M_PAD * 4],
                psi: vec![0.0; M_PAD * 3],
                trust: vec![0.0; M_PAD],
                hist: vec![0.0; M_PAD],
                valid: vec![0.0; M_PAD],
            })
        }

        /// Score one padded chunk of up to [`M_PAD`] rows starting at
        /// `row0`, all sharing `capacity` (the artifact takes a scalar
        /// capacity; multi-window batches are split into uniform runs by
        /// the caller).
        fn score_chunk(
            &mut self,
            b: &ScoreBatch,
            row0: usize,
            rows: usize,
            capacity: f32,
            out: &mut ScoreOutput,
        ) -> anyhow::Result<()> {
            // Stage into padded buffers; padded lanes get valid=0 and benign
            // sigma so the kernel's math stays finite.
            self.mu.fill(0.0);
            self.sigma.fill(1.0);
            self.phi.fill(0.0);
            self.psi.fill(0.0);
            self.trust.fill(1.0);
            self.hist.fill(0.0);
            self.valid.fill(0.0);
            let t = b.t;
            self.mu[..rows * t].copy_from_slice(&b.mu[row0 * t..(row0 + rows) * t]);
            self.sigma[..rows * t].copy_from_slice(&b.sigma[row0 * t..(row0 + rows) * t]);
            self.phi[..rows * 4].copy_from_slice(&b.phi[row0 * 4..(row0 + rows) * 4]);
            self.psi[..rows * 3].copy_from_slice(&b.psi[row0 * 3..(row0 + rows) * 3]);
            self.trust[..rows].copy_from_slice(&b.trust[row0..row0 + rows]);
            self.hist[..rows].copy_from_slice(&b.hist[row0..row0 + rows]);
            self.valid[..rows].fill(1.0);

            let mut params = [0.0f32; N_PARAMS];
            params[0] = capacity;
            params[1] = b.theta;
            params[2] = b.lambda;
            params[3..7].copy_from_slice(&b.alpha);
            params[7..11].copy_from_slice(&b.beta);

            let args = [
                f32_literal(&self.mu, &[M_PAD, T_BINS])?,
                f32_literal(&self.sigma, &[M_PAD, T_BINS])?,
                f32_literal(&self.phi, &[M_PAD, 4])?,
                f32_literal(&self.psi, &[M_PAD, 3])?,
                f32_literal(&self.trust, &[M_PAD])?,
                f32_literal(&self.hist, &[M_PAD])?,
                f32_literal(&self.valid, &[M_PAD])?,
                f32_literal(&params, &[N_PARAMS])?,
            ];
            let outputs = self.exe.run(&args)?;
            anyhow::ensure!(outputs.len() == 3, "scorer artifact must return 3 outputs");
            let score = outputs[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            let viol = outputs[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            let head = outputs[2].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            for i in 0..rows {
                let eligible = viol[i] <= b.theta;
                out.score.push(if eligible { score[i] } else { 0.0 });
                out.violation.push(viol[i]);
                out.headroom.push(head[i]);
                out.eligible.push(eligible);
            }
            Ok(())
        }
    }

    impl ScorerBackend for PjrtScorer {
        fn name(&self) -> &str {
            "pjrt"
        }

        fn score(&mut self, b: &ScoreBatch) -> anyhow::Result<ScoreOutput> {
            anyhow::ensure!(
                b.t == T_BINS,
                "PJRT scorer artifact was lowered with T={T_BINS} bins, got {}",
                b.t
            );
            anyhow::ensure!(
                b.row_capacity.is_empty() || b.row_capacity.len() == b.m,
                "row_capacity must be empty or length m"
            );
            let mut out = ScoreOutput::default();
            let mut row = 0;
            while row < b.m {
                // Rows must share a capacity within one artifact call;
                // multi-window batches carry per-row capacities, grouped
                // by announcement window, so runs are few and long.
                let cap = b.capacity_of(row);
                let mut end = row + 1;
                while end < b.m && end - row < M_PAD && b.capacity_of(end) == cap {
                    end += 1;
                }
                self.score_chunk(b, row, end - row, cap, &mut out)?;
                row = end;
            }
            Ok(out)
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{HloExecutable, PjrtScorer};

/// Stub compiled when the `pjrt` feature is off: same API, fails cleanly
/// at load time so CLI/bench/example code paths keep working.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtScorer {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl PjrtScorer {
    /// Load `scorer.hlo.txt` from the default artifacts directory.
    pub fn from_default_artifacts() -> anyhow::Result<Self> {
        Self::load(&artifacts_dir().join("scorer.hlo.txt"))
    }

    /// Load and compile the scorer artifact at `path`. Always fails in
    /// stub builds (after the same missing-artifact check as the real
    /// implementation, so error messages stay consistent).
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        anyhow::ensure!(
            path.exists(),
            "scorer artifact {} not found — run `make artifacts` first",
            path.display()
        );
        anyhow::bail!(
            "this binary was built without the `pjrt` cargo feature; \
             rebuild with `--features pjrt` (requires the `xla` dependency)"
        )
    }
}

#[cfg(not(feature = "pjrt"))]
impl ScorerBackend for PjrtScorer {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn score(&mut self, _b: &ScoreBatch) -> anyhow::Result<ScoreOutput> {
        anyhow::bail!("pjrt backend unavailable: built without the `pjrt` feature")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_default() {
        if std::env::var_os("JASDA_ARTIFACTS").is_none() {
            assert_eq!(artifacts_dir(), PathBuf::from("artifacts"));
        }
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let err = match PjrtScorer::load(Path::new("/nonexistent/scorer.hlo.txt")) {
            Err(e) => e,
            Ok(_) => panic!("expected an error"),
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_reports_missing_feature() {
        // An existing path gets past the artifact check and must then
        // report the disabled feature, not a confusing compile error.
        let dir = std::env::temp_dir().join("jasda_runtime_stub_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("scorer.hlo.txt");
        std::fs::write(&path, "HloModule stub").unwrap();
        let err = PjrtScorer::load(&path).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    // Full PJRT parity tests live in rust/tests/pjrt_parity.rs (they need
    // `make artifacts` to have produced the HLO and the `pjrt` feature).
}
