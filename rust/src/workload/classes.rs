//! Job classes: parameterized templates for the workload mixes the paper
//! motivates (AI training/inference, analytics, Agriculture 4.0).
//!
//! Each class fixes the *shape* of the TRP (phase structure, memory
//! levels, burstiness, atomization granularity); instantiation draws the
//! scale parameters (total work, memory) from class-specific log-normal
//! distributions so populations are heterogeneous but reproducible.

use crate::job::Job;
use crate::sim::Rng;
use crate::trp::{Phase, Trp};
use crate::types::Time;

/// The built-in job classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobClass {
    /// Small model training: warm-up ramp then long steady phase,
    /// moderate memory, medium atoms (checkpoint every few minutes).
    TrainSmall,
    /// Large model training: high memory (needs 3g/4g+ slices), long,
    /// coarse atoms.
    TrainLarge,
    /// Inference burst: short, small memory, tight deadline, fine atoms.
    InferenceBurst,
    /// Data analytics: medium length, spiky memory (bursty joins).
    Analytics,
    /// Agriculture 4.0 pipeline: periodic sensing + inference stages,
    /// small-to-medium memory, deadline-bound (daily windows).
    AgriPipeline,
}

/// Distribution parameters for one class.
#[derive(Debug, Clone)]
pub struct JobClassSpec {
    /// Class enum value.
    pub class: JobClass,
    /// Canonical name used in config mixes.
    pub name: &'static str,
    /// Log-normal (mu, sigma) of total work in ticks (full-GPU).
    pub work_lognorm: (f64, f64),
    /// Log-normal (mu, sigma) of steady memory (GiB).
    pub mem_lognorm: (f64, f64),
    /// Memory noise std as a fraction of the level.
    pub mem_noise: f64,
    /// Atom size as a fraction of total work.
    pub atom_frac: f64,
    /// Duration CV (realization noise).
    pub duration_cv: f64,
    /// Deadline slack multiplier over ideal runtime (None = no deadline).
    pub deadline_slack: Option<f64>,
    /// Tenant weight.
    pub weight: f64,
}

impl JobClass {
    /// All classes.
    pub const ALL: [JobClass; 5] = [
        JobClass::TrainSmall,
        JobClass::TrainLarge,
        JobClass::InferenceBurst,
        JobClass::Analytics,
        JobClass::AgriPipeline,
    ];

    /// Parse a class by config name.
    pub fn parse(name: &str) -> Option<JobClass> {
        Self::ALL.iter().copied().find(|c| c.spec().name == name)
    }

    /// The class's distribution spec.
    pub fn spec(&self) -> JobClassSpec {
        match self {
            JobClass::TrainSmall => JobClassSpec {
                class: *self,
                name: "train_small",
                // e^8.5 ≈ 4900 ticks of work
                work_lognorm: (8.5, 0.5),
                // e^1.8 ≈ 6 GiB
                mem_lognorm: (1.8, 0.3),
                mem_noise: 0.06,
                atom_frac: 0.15,
                duration_cv: 0.08,
                deadline_slack: None,
                weight: 1.0,
            },
            JobClass::TrainLarge => JobClassSpec {
                class: *self,
                name: "train_large",
                // e^9.6 ≈ 14.8k ticks
                work_lognorm: (9.6, 0.4),
                // e^2.75 ≈ 15.6 GiB — needs 3g/4g/7g slices
                mem_lognorm: (2.75, 0.15),
                mem_noise: 0.05,
                atom_frac: 0.2,
                duration_cv: 0.1,
                deadline_slack: None,
                weight: 2.0,
            },
            JobClass::InferenceBurst => JobClassSpec {
                class: *self,
                name: "inference_burst",
                // e^6.6 ≈ 735 ticks
                work_lognorm: (6.6, 0.5),
                // e^1.0 ≈ 2.7 GiB — fits 1g slices
                mem_lognorm: (1.0, 0.3),
                mem_noise: 0.08,
                atom_frac: 0.34,
                duration_cv: 0.12,
                deadline_slack: Some(12.0),
                weight: 1.0,
            },
            JobClass::Analytics => JobClassSpec {
                class: *self,
                name: "analytics",
                work_lognorm: (8.0, 0.6),
                mem_lognorm: (1.6, 0.4),
                mem_noise: 0.18, // spiky joins
                atom_frac: 0.25,
                duration_cv: 0.15,
                deadline_slack: None,
                weight: 1.0,
            },
            JobClass::AgriPipeline => JobClassSpec {
                class: *self,
                name: "agri_pipeline",
                work_lognorm: (7.4, 0.4),
                mem_lognorm: (1.3, 0.25),
                mem_noise: 0.1,
                atom_frac: 0.25,
                duration_cv: 0.1,
                deadline_slack: Some(20.0),
                weight: 1.0,
            },
        }
    }
}

impl JobClassSpec {
    /// Draw one job instance.
    pub fn instantiate(&self, id: u32, arrival: Time, rng: &mut Rng) -> Job {
        let work = rng.log_normal(self.work_lognorm.0, self.work_lognorm.1).max(100.0);
        // Clamp so every job fits at least a 20 GiB (3g/4g) slice even at
        // its bursty tail (1.05x level + >3 sigma of noise must stay under
        // 20 GiB) — no job is structurally unschedulable.
        let mem = rng.log_normal(self.mem_lognorm.0, self.mem_lognorm.1).clamp(0.5, 13.5);
        let noise = (mem * self.mem_noise).max(0.05);

        let phases = match self.class {
            // Training: warm-up ramp -> steady -> bursty tail.
            JobClass::TrainSmall | JobClass::TrainLarge => vec![
                Phase::new(work * 0.1, mem * 0.75, noise, 0.6),
                Phase::new(work * 0.8, mem, noise, 0.15),
                Phase::new(work * 0.1, mem * 1.05, noise * 2.0, 0.1),
            ],
            // Inference: fast ramp, short steady.
            JobClass::InferenceBurst => vec![
                Phase::new(work * 0.2, mem, noise, 0.4),
                Phase::new(work * 0.8, mem, noise, 0.0),
            ],
            // Analytics: alternating spiky stages.
            JobClass::Analytics => vec![
                Phase::new(work * 0.3, mem * 0.6, noise, 0.3),
                Phase::new(work * 0.3, mem * 1.1, noise * 1.8, 0.1),
                Phase::new(work * 0.4, mem * 0.8, noise, 0.1),
            ],
            // Agri pipeline: sense (light) -> infer (heavier) -> aggregate.
            JobClass::AgriPipeline => vec![
                Phase::new(work * 0.35, mem * 0.5, noise, 0.3),
                Phase::new(work * 0.4, mem, noise, 0.2),
                Phase::new(work * 0.25, mem * 0.7, noise, 0.1),
            ],
        };

        // Enforce schedulability by construction: every phase must pass
        // the chunk-level safety product on the largest common slice
        // (20 GiB): mu + 3.3 sigma <= 19 GiB keeps a 64-bin FMP violation
        // probability under theta = 0.05. Jobs whose draw exceeds this are
        // scaled down proportionally (they'd be rejected by any admission
        // control in practice).
        let mut phases = phases;
        let worst =
            phases.iter().map(|p| p.mem_gb + 3.3 * p.mem_std_gb).fold(0.0, f64::max);
        if worst > 19.0 {
            let scale = 19.0 / worst;
            for p in &mut phases {
                p.mem_gb *= scale;
                p.mem_std_gb *= scale;
            }
        }

        let trp = Trp { phases, duration_cv: self.duration_cv };
        let total = trp.total_work();
        let deadline = self
            .deadline_slack
            .map(|s| arrival + (total * s).round() as Time);
        let atom = (total * self.atom_frac).max(50.0);
        Job::new(id, self.name, arrival, trp, deadline, self.weight, atom, 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trip() {
        for c in JobClass::ALL {
            assert_eq!(JobClass::parse(c.spec().name), Some(c));
        }
        assert_eq!(JobClass::parse("bogus"), None);
    }

    #[test]
    fn instantiation_is_sane() {
        let mut rng = Rng::new(5);
        for c in JobClass::ALL {
            for i in 0..20 {
                let j = c.spec().instantiate(i, 1000, &mut rng);
                assert!(j.total_work() >= 100.0, "{}: work {}", j.class, j.total_work());
                let peak = j.trp.peak_mem_gb();
                assert!(peak > 0.0 && peak <= 40.0, "{}: peak {peak}", j.class);
                assert!(j.atom_work >= 50.0);
                assert!(j.atom_work <= j.total_work() + 1e-9 || j.total_work() < 50.0);
                assert_eq!(j.arrival, 1000);
                if let Some(d) = j.deadline {
                    assert!(d > j.arrival);
                }
            }
        }
    }

    #[test]
    fn class_scale_ordering() {
        // Across a population, train_large is bigger/heavier than
        // inference_burst in both work and memory.
        let mut rng = Rng::new(17);
        let n = 200;
        let mean = |c: JobClass, rng: &mut Rng| {
            let mut w = 0.0;
            let mut m = 0.0;
            for i in 0..n {
                let j = c.spec().instantiate(i, 0, rng);
                w += j.total_work();
                m += j.trp.peak_mem_gb();
            }
            (w / n as f64, m / n as f64)
        };
        let (w_big, m_big) = mean(JobClass::TrainLarge, &mut rng);
        let (w_inf, m_inf) = mean(JobClass::InferenceBurst, &mut rng);
        assert!(w_big > 5.0 * w_inf, "{w_big} vs {w_inf}");
        assert!(m_big > 3.0 * m_inf, "{m_big} vs {m_inf}");
    }

    #[test]
    fn inference_always_has_deadline() {
        let mut rng = Rng::new(2);
        for i in 0..50 {
            let j = JobClass::InferenceBurst.spec().instantiate(i, 500, &mut rng);
            assert!(j.deadline.is_some());
        }
    }
}
