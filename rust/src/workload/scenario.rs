//! Production-scale scenario generator.
//!
//! The classic [`WorkloadGenerator`](super::WorkloadGenerator) draws from
//! hand-tuned job classes under stationary Poisson arrivals — right for
//! the paper's controlled experiments, wrong for the production-shaped
//! traces the multi-tenant MIG literature evaluates on. This generator
//! produces those instead, configured by
//! [`ScenarioConfig`](crate::config::ScenarioConfig):
//!
//! * **Heavy-tailed sizes** — total work is truncated-Pareto
//!   (`work_alpha`, `work_min`, `work_cap`), so a small fraction of jobs
//!   carries most of the demand.
//! * **Diurnal + bursty arrivals** — a sinusoidal day/night rate envelope
//!   with exponentially-sized burst episodes layered on top.
//! * **Multi-tenant fairness groups** — each job belongs to tenant `g`
//!   with geometric weight `tenant_weight_ratio^g`, encoded in the class
//!   name as `t<g>:<shape>` so group metrics need no side table.
//! * **Deadline/SLO classes** — a configured fraction of jobs carries an
//!   absolute deadline at `arrival + deadline_slack × ideal_runtime`.
//!
//! Everything is drawn from forked substreams of one seed, so a trace is
//! bit-reproducible from `(config, seed)` alone, and
//! [`for_each`](ScenarioGenerator::for_each) yields jobs one at a time so
//! million-job traces never need to be materialized to be inspected.

use crate::config::ScenarioConfig;
use crate::job::Job;
use crate::sim::Rng;
use crate::trp::{Phase, Trp};
use crate::types::Time;

/// Substream ids (see [`Rng::fork`]): one per concern, so adding draws to
/// one never perturbs the others.
const STREAM_ARRIVALS: u64 = 0xA221;
const STREAM_SIZES: u64 = 0x512E;
const STREAM_TENANT: u64 = 0x7E4A;

/// Job shape bucket, picked by total work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Shape {
    /// Short inference-like job: fast ramp, small memory, fine atoms.
    Inf,
    /// Mid-size analytics-like job: spiky memory, medium atoms.
    Mix,
    /// Long training-like job: warm-up ramp, high memory, coarse atoms.
    Train,
}

impl Shape {
    const ALL: [Shape; 3] = [Shape::Inf, Shape::Mix, Shape::Train];

    fn of_work(work: f64) -> Shape {
        if work < 1_000.0 {
            Shape::Inf
        } else if work < 8_000.0 {
            Shape::Mix
        } else {
            Shape::Train
        }
    }

    fn name(self) -> &'static str {
        match self {
            Shape::Inf => "inf",
            Shape::Mix => "mix",
            Shape::Train => "train",
        }
    }

    /// (mem log-normal (mu, sigma), mem noise fraction, atom fraction,
    /// duration CV) — scale parameters per shape, mirroring the built-in
    /// class specs.
    fn params(self) -> ((f64, f64), f64, f64, f64) {
        match self {
            Shape::Inf => ((1.0, 0.3), 0.08, 0.34, 0.12),
            Shape::Mix => ((1.6, 0.35), 0.18, 0.25, 0.15),
            Shape::Train => ((2.4, 0.25), 0.05, 0.15, 0.1),
        }
    }
}

/// Generates production-shaped job traces, deterministic in one seed.
#[derive(Debug, Clone)]
pub struct ScenarioGenerator {
    cfg: ScenarioConfig,
    /// `class_names[g][shape]` — interned `t<g>:<shape>` labels so the
    /// per-job cost is one `String` clone, not a `format!`.
    class_names: Vec<[String; 3]>,
}

impl ScenarioGenerator {
    /// Build a generator. The config must already be validated.
    pub fn new(cfg: ScenarioConfig) -> Self {
        let class_names = (0..cfg.tenants)
            .map(|g| {
                [
                    format!("t{g}:{}", Shape::Inf.name()),
                    format!("t{g}:{}", Shape::Mix.name()),
                    format!("t{g}:{}", Shape::Train.name()),
                ]
            })
            .collect();
        ScenarioGenerator { cfg, class_names }
    }

    /// Generate the full trace as a vector (small/medium runs).
    pub fn generate(&self, run_seed: u64) -> Vec<Job> {
        let mut jobs = Vec::with_capacity(self.cfg.jobs);
        self.for_each(run_seed, |j| jobs.push(j));
        jobs
    }

    /// Stream the trace one job at a time in arrival order, O(1) memory
    /// per job — the path million-job traces use.
    pub fn for_each<F: FnMut(Job)>(&self, run_seed: u64, mut f: F) {
        let root = Rng::new(self.cfg.seed_or(run_seed));
        let mut arr_rng = root.fork(STREAM_ARRIVALS);
        let mut size_rng = root.fork(STREAM_SIZES);
        let mut ten_rng = root.fork(STREAM_TENANT);

        let base_per_tick = self.cfg.base_rate_per_sec / 1000.0;
        let two_pi = 2.0 * std::f64::consts::PI;
        let mut t = 0.0f64;
        // Remaining ticks of the active burst episode (0 = not bursting).
        let mut burst_left = 0.0f64;

        for id in 0..self.cfg.jobs {
            if burst_left <= 0.0
                && self.cfg.burst_mean_len > 0
                && arr_rng.chance(self.cfg.burst_prob)
            {
                burst_left = arr_rng.exponential(1.0 / self.cfg.burst_mean_len as f64);
            }
            let diurnal = if self.cfg.diurnal_period == 0 {
                1.0
            } else {
                let phase = two_pi * t / self.cfg.diurnal_period as f64;
                1.0 + self.cfg.diurnal_amplitude * phase.sin()
            };
            let mult = if burst_left > 0.0 { self.cfg.burst_mult } else { 1.0 };
            let gap = arr_rng.exponential(base_per_tick * diurnal * mult);
            t += gap;
            burst_left -= gap;
            let arrival = t.round() as Time;

            f(self.instantiate(id as u32, arrival, &mut size_rng, &mut ten_rng));
        }
    }

    /// Draw one job: truncated-Pareto work, shape-dependent TRP, tenant
    /// label/weight, and an optional SLO deadline.
    fn instantiate(&self, id: u32, arrival: Time, size_rng: &mut Rng, ten_rng: &mut Rng) -> Job {
        // Inverse-CDF truncated Pareto: u in [0,1) so (1-u) is in (0,1]
        // and the draw is >= work_min; the cap bounds the tail.
        let u = size_rng.uniform();
        let work = (self.cfg.work_min * (1.0 - u).powf(-1.0 / self.cfg.work_alpha))
            .min(self.cfg.work_cap);
        let shape = Shape::of_work(work);
        let ((mem_mu, mem_sigma), noise_frac, atom_frac, duration_cv) = shape.params();

        // Same memory envelope as the built-in classes: clamp so every
        // job fits a 20 GiB slice even at its bursty tail.
        let mem = size_rng.log_normal(mem_mu, mem_sigma).clamp(0.5, 13.5);
        let noise = (mem * noise_frac).max(0.05);

        let mut phases = match shape {
            Shape::Inf => vec![
                Phase::new(work * 0.2, mem, noise, 0.4),
                Phase::new(work * 0.8, mem, noise, 0.0),
            ],
            Shape::Mix => vec![
                Phase::new(work * 0.3, mem * 0.6, noise, 0.3),
                Phase::new(work * 0.3, mem * 1.1, noise * 1.8, 0.1),
                Phase::new(work * 0.4, mem * 0.8, noise, 0.1),
            ],
            Shape::Train => vec![
                Phase::new(work * 0.1, mem * 0.75, noise, 0.6),
                Phase::new(work * 0.8, mem, noise, 0.15),
                Phase::new(work * 0.1, mem * 1.05, noise * 2.0, 0.1),
            ],
        };
        // Schedulability by construction (as in `classes.rs`): keep
        // mu + 3.3 sigma <= 19 GiB on every phase.
        let worst = phases.iter().map(|p| p.mem_gb + 3.3 * p.mem_std_gb).fold(0.0, f64::max);
        if worst > 19.0 {
            let scale = 19.0 / worst;
            for p in &mut phases {
                p.mem_gb *= scale;
                p.mem_std_gb *= scale;
            }
        }

        let tenant = ten_rng.index(self.cfg.tenants);
        let weight = self.cfg.tenant_weight_ratio.powi(tenant as i32);
        let class = self.class_names[tenant][shape as usize].clone();

        let trp = Trp { phases, duration_cv };
        let total = trp.total_work();
        let deadline = if ten_rng.chance(self.cfg.deadline_fraction) {
            Some(arrival + (total * self.cfg.deadline_slack).round() as Time)
        } else {
            None
        };
        let atom = (total * atom_frac).max(50.0);
        Job::new(id, class, arrival, trp, deadline, weight, atom, 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(jobs: usize) -> ScenarioConfig {
        ScenarioConfig { jobs, seed: 42, ..ScenarioConfig::default() }
    }

    #[test]
    fn generates_count_with_monotone_arrivals_and_ids() {
        let jobs = ScenarioGenerator::new(small_cfg(300)).generate(0);
        assert_eq!(jobs.len(), 300);
        for w in jobs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id as usize, i);
            assert!(j.total_work() > 0.0);
            assert!(j.atom_work >= 50.0);
        }
    }

    #[test]
    fn bit_reproducible_from_seed() {
        let g = ScenarioGenerator::new(small_cfg(200));
        let a = g.generate(0);
        let b = ScenarioGenerator::new(small_cfg(200)).generate(0);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.class, y.class);
            assert_eq!(x.weight, y.weight);
            assert_eq!(x.deadline, y.deadline);
            assert_eq!(x.trp, y.trp);
            assert_eq!(x.atom_work, y.atom_work);
        }
        // The scenario's own seed wins over the run seed.
        let c = g.generate(12345);
        assert_eq!(a[7].arrival, c[7].arrival);
        assert_eq!(a[7].trp, c[7].trp);
    }

    #[test]
    fn work_is_heavy_tailed_and_truncated() {
        let cfg = small_cfg(2_000);
        let jobs = ScenarioGenerator::new(cfg.clone()).generate(0);
        let mut works: Vec<f64> = jobs.iter().map(|j| j.total_work()).collect();
        works.sort_by(f64::total_cmp);
        let median = works[works.len() / 2];
        let max = *works.last().unwrap();
        for &w in &works {
            assert!(w >= cfg.work_min * 0.999, "work {w} below scale");
            assert!(w <= cfg.work_cap * 1.001, "work {w} above cap");
        }
        // Pareto alpha=1.6: the max dwarfs the median.
        assert!(max > 20.0 * median, "max {max} vs median {median}");
        // The cap actually binds somewhere in a 2k draw.
        assert!(max > cfg.work_cap * 0.999, "cap never reached: {max}");
    }

    #[test]
    fn tenants_weights_and_shapes_cover() {
        let mut cfg = small_cfg(1_500);
        cfg.tenants = 3;
        cfg.tenant_weight_ratio = 2.0;
        let jobs = ScenarioGenerator::new(cfg).generate(0);
        let mut seen_tenant = [false; 3];
        let mut seen_shape = [false; 3];
        for j in &jobs {
            let (t, shape) = j.class.split_once(':').expect("class is t<g>:<shape>");
            let g: usize = t.strip_prefix('t').unwrap().parse().unwrap();
            seen_tenant[g] = true;
            let si = Shape::ALL.iter().position(|s| s.name() == shape).unwrap();
            seen_shape[si] = true;
            assert_eq!(j.weight, 2.0f64.powi(g as i32));
        }
        assert!(seen_tenant.iter().all(|&b| b), "{seen_tenant:?}");
        assert!(seen_shape.iter().all(|&b| b), "{seen_shape:?}");
    }

    #[test]
    fn deadline_fraction_and_slack_hold() {
        let mut cfg = small_cfg(2_000);
        cfg.deadline_fraction = 0.4;
        cfg.deadline_slack = 6.0;
        let jobs = ScenarioGenerator::new(cfg).generate(0);
        let with: Vec<&Job> = jobs.iter().filter(|j| j.deadline.is_some()).collect();
        let frac = with.len() as f64 / jobs.len() as f64;
        assert!((frac - 0.4).abs() < 0.05, "deadline fraction {frac}");
        for j in with {
            let d = j.deadline.unwrap();
            let expect = j.arrival + (j.total_work() * 6.0).round() as Time;
            assert_eq!(d, expect);
        }
    }

    #[test]
    fn memory_stays_schedulable() {
        let jobs = ScenarioGenerator::new(small_cfg(1_000)).generate(0);
        for j in &jobs {
            for p in &j.trp.phases {
                assert!(
                    p.mem_gb + 3.3 * p.mem_std_gb <= 19.0 + 1e-9,
                    "{}: mu {} sigma {}",
                    j.class,
                    p.mem_gb,
                    p.mem_std_gb
                );
            }
        }
    }

    #[test]
    fn streaming_for_each_matches_generate() {
        let g = ScenarioGenerator::new(small_cfg(150));
        let materialized = g.generate(0);
        let mut streamed = Vec::new();
        g.for_each(0, |j| streamed.push(j));
        assert_eq!(materialized.len(), streamed.len());
        for (a, b) in materialized.iter().zip(&streamed) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.class, b.class);
            assert_eq!(a.trp, b.trp);
        }
    }

    #[test]
    fn burst_episodes_compress_gaps() {
        // With violent bursts, the gap distribution is more dispersed
        // than the burst-free baseline (its CV exceeds the exponential's
        // 1.0 because gaps mix two very different rates).
        let mut cfg = small_cfg(4_000);
        cfg.diurnal_period = 0;
        cfg.burst_prob = 0.05;
        cfg.burst_mult = 20.0;
        cfg.burst_mean_len = 3_000;
        let jobs = ScenarioGenerator::new(cfg).generate(0);
        let gaps: Vec<f64> =
            jobs.windows(2).map(|w| (w[1].arrival - w[0].arrival) as f64).collect();
        let n = gaps.len() as f64;
        let mean = gaps.iter().sum::<f64>() / n;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / n;
        let cv = var.sqrt() / mean;
        assert!(cv > 1.15, "gap CV {cv} not over-dispersed");
    }
}
