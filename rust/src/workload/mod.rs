//! Workload generation: job classes, Poisson arrivals, and traces.
//!
//! Job classes model the heterogeneous mixes the paper motivates (§1):
//! AI training and inference, data analytics, and Agriculture 4.0
//! pipelines (periodic sensing/inference bursts). Arrivals follow a
//! Poisson process with bounded rate — the stationarity assumption behind
//! the §4.6 asymptotics.

pub mod classes;
pub mod scenario;
pub mod trace;

use crate::config::WorkloadConfig;
use crate::job::Job;
use crate::sim::Rng;
use crate::types::Time;

pub use classes::{JobClass, JobClassSpec};
pub use scenario::ScenarioGenerator;
pub use trace::{load_trace, save_trace, TraceRecord};

/// Generates reproducible job populations from a [`WorkloadConfig`].
#[derive(Debug, Clone)]
pub struct WorkloadGenerator {
    cfg: WorkloadConfig,
}

impl WorkloadGenerator {
    /// Build a generator.
    pub fn new(cfg: WorkloadConfig) -> Self {
        WorkloadGenerator { cfg }
    }

    /// Generate the job population for a run, deterministic in `seed`.
    pub fn generate(&self, seed: u64) -> Vec<Job> {
        let mut rng = Rng::new(seed).fork(0x307B);
        let specs: Vec<(JobClassSpec, f64)> = self
            .cfg
            .mix
            .iter()
            .filter_map(|(name, w)| JobClass::parse(name).map(|c| (c.spec(), *w)))
            .collect();
        assert!(!specs.is_empty(), "workload mix resolved to no known classes");
        let total_w: f64 = specs.iter().map(|(_, w)| w).sum();

        let mut jobs = Vec::with_capacity(self.cfg.num_jobs);
        let mut t: f64 = 0.0;
        let rate_per_tick = self.cfg.arrival_rate_per_sec / 1000.0;
        for id in 0..self.cfg.num_jobs {
            t += rng.exponential(rate_per_tick);
            let arrival = t.round() as Time;

            // Pick a class by weight.
            let mut pick = rng.uniform() * total_w;
            let mut chosen = &specs[0].0;
            for (spec, w) in &specs {
                if pick < *w {
                    chosen = spec;
                    break;
                }
                pick -= w;
            }

            let misreport = if rng.chance(self.cfg.misreport_fraction) {
                self.cfg.misreport_bias
            } else {
                0.0
            };
            let mut job = chosen.instantiate(id as u32, arrival, &mut rng);
            job.misreport_bias = misreport;
            jobs.push(job);
        }
        jobs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    fn gen(n: usize, rate: f64) -> Vec<Job> {
        let cfg = WorkloadConfig {
            num_jobs: n,
            arrival_rate_per_sec: rate,
            ..WorkloadConfig::default()
        };
        WorkloadGenerator::new(cfg).generate(7)
    }

    #[test]
    fn generates_requested_count_with_monotone_arrivals() {
        let jobs = gen(50, 1.0);
        assert_eq!(jobs.len(), 50);
        for w in jobs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id as usize, i);
            assert!(j.total_work() > 0.0);
            assert!(j.trp.peak_mem_gb() > 0.0);
            assert!(j.atom_work > 0.0);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let cfg = WorkloadConfig::default();
        let a = WorkloadGenerator::new(cfg.clone()).generate(9);
        let b = WorkloadGenerator::new(cfg).generate(9);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.class, y.class);
            assert_eq!(x.total_work(), y.total_work());
        }
    }

    #[test]
    fn arrival_rate_roughly_matches() {
        let jobs = gen(400, 2.0); // 2 jobs/s => mean gap 500 ticks
        let last = jobs.last().unwrap().arrival as f64;
        let mean_gap = last / 400.0;
        assert!((mean_gap - 500.0).abs() < 100.0, "mean gap {mean_gap}");
    }

    #[test]
    fn misreport_fraction_applied() {
        let cfg = WorkloadConfig {
            num_jobs: 300,
            misreport_fraction: 0.3,
            misreport_bias: 0.5,
            ..WorkloadConfig::default()
        };
        let jobs = WorkloadGenerator::new(cfg).generate(11);
        let liars = jobs.iter().filter(|j| j.misreport_bias > 0.0).count();
        let frac = liars as f64 / jobs.len() as f64;
        assert!((frac - 0.3).abs() < 0.08, "liar fraction {frac}");
    }

    #[test]
    fn mix_respects_weights() {
        let cfg = WorkloadConfig {
            num_jobs: 500,
            mix: vec![("inference_burst".into(), 0.8), ("train_small".into(), 0.2)],
            ..WorkloadConfig::default()
        };
        let jobs = WorkloadGenerator::new(cfg).generate(3);
        let inf = jobs.iter().filter(|j| j.class == "inference_burst").count() as f64;
        assert!((inf / 500.0 - 0.8).abs() < 0.06);
    }

    #[test]
    #[should_panic]
    fn unknown_mix_panics() {
        let cfg = WorkloadConfig {
            mix: vec![("no_such_class".into(), 1.0)],
            ..WorkloadConfig::default()
        };
        WorkloadGenerator::new(cfg).generate(1);
    }
}
