//! Workload trace persistence: save generated populations to JSON-lines
//! and reload them, so experiments can be re-run on the exact same trace
//! (and traces can be shared across schedulers / machines).

use crate::job::Job;
use crate::trp::{Phase, Trp};
use crate::types::Time;
use crate::util::Json;
use std::io::{BufRead, Write};

/// One trace line: the static description of a job (dynamic state is
/// reset on load).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    /// Job id.
    pub id: u32,
    /// Class name.
    pub class: String,
    /// Arrival tick.
    pub arrival: Time,
    /// Resource profile.
    pub trp: Trp,
    /// Optional absolute deadline.
    pub deadline: Option<Time>,
    /// Tenant weight.
    pub weight: f64,
    /// Atomization granularity.
    pub atom_work: f64,
    /// Misreport bias.
    pub misreport_bias: f64,
}

impl From<&Job> for TraceRecord {
    fn from(j: &Job) -> Self {
        TraceRecord {
            id: j.id,
            class: j.class.clone(),
            arrival: j.arrival,
            trp: j.trp.clone(),
            deadline: j.deadline,
            weight: j.weight,
            atom_work: j.atom_work,
            misreport_bias: j.misreport_bias,
        }
    }
}

fn phase_to_json(p: &Phase) -> Json {
    Json::obj(vec![
        ("work", p.work.into()),
        ("mem_gb", p.mem_gb.into()),
        ("mem_std_gb", p.mem_std_gb.into()),
        ("ramp_frac", p.ramp_frac.into()),
    ])
}

fn phase_from_json(v: &Json) -> anyhow::Result<Phase> {
    let f = |k: &str| {
        v.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("phase missing numeric '{k}'"))
    };
    Ok(Phase {
        work: f("work")?,
        mem_gb: f("mem_gb")?,
        mem_std_gb: f("mem_std_gb")?,
        ramp_frac: f("ramp_frac")?,
    })
}

impl TraceRecord {
    /// Serialize to one JSON value.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", self.id.into()),
            ("class", self.class.clone().into()),
            ("arrival", self.arrival.into()),
            (
                "trp",
                Json::obj(vec![
                    ("phases", Json::Arr(self.trp.phases.iter().map(phase_to_json).collect())),
                    ("duration_cv", self.trp.duration_cv.into()),
                ]),
            ),
            ("deadline", self.deadline.map_or(Json::Null, |d| d.into())),
            ("weight", self.weight.into()),
            ("atom_work", self.atom_work.into()),
            ("misreport_bias", self.misreport_bias.into()),
        ])
    }

    /// Parse from a JSON value.
    pub fn from_json(v: &Json) -> anyhow::Result<TraceRecord> {
        let num = |k: &str| {
            v.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow::anyhow!("missing '{k}'"))
        };
        let trp_v = v.get("trp").ok_or_else(|| anyhow::anyhow!("missing 'trp'"))?;
        let phases_v = trp_v
            .get("phases")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing 'trp.phases'"))?;
        let phases: anyhow::Result<Vec<Phase>> = phases_v.iter().map(phase_from_json).collect();
        let deadline = match v.get("deadline") {
            None | Some(Json::Null) => None,
            Some(d) => {
                Some(d.as_u64().ok_or_else(|| anyhow::anyhow!("deadline must be integer"))?)
            }
        };
        Ok(TraceRecord {
            id: num("id")? as u32,
            class: v
                .get("class")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("missing 'class'"))?
                .to_string(),
            arrival: v
                .get("arrival")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow::anyhow!("missing 'arrival'"))?,
            trp: Trp {
                phases: phases?,
                duration_cv: trp_v
                    .get("duration_cv")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow::anyhow!("missing 'trp.duration_cv'"))?,
            },
            deadline,
            weight: num("weight")?,
            atom_work: num("atom_work")?,
            misreport_bias: num("misreport_bias")?,
        })
    }

    /// Reconstruct a fresh (unstarted) job.
    pub fn into_job(self) -> Job {
        Job::new(
            self.id,
            self.class,
            self.arrival,
            self.trp,
            self.deadline,
            self.weight,
            self.atom_work,
            self.misreport_bias,
        )
    }
}

/// Write jobs as JSON-lines.
pub fn save_trace(path: &std::path::Path, jobs: &[Job]) -> anyhow::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for j in jobs {
        writeln!(f, "{}", TraceRecord::from(j).to_json())?;
    }
    Ok(())
}

/// Load jobs from a JSON-lines trace.
pub fn load_trace(path: &std::path::Path) -> anyhow::Result<Vec<Job>> {
    let f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut jobs = Vec::new();
    for (n, line) in f.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(&line).map_err(|e| anyhow::anyhow!("trace line {}: {e}", n + 1))?;
        jobs.push(
            TraceRecord::from_json(&v)
                .map_err(|e| anyhow::anyhow!("trace line {}: {e}", n + 1))?
                .into_job(),
        );
    }
    Ok(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::workload::WorkloadGenerator;

    #[test]
    fn trace_round_trip() {
        let jobs = WorkloadGenerator::new(WorkloadConfig {
            num_jobs: 12,
            ..WorkloadConfig::default()
        })
        .generate(4);
        let dir = std::env::temp_dir().join("jasda_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        save_trace(&path, &jobs).unwrap();
        let back = load_trace(&path).unwrap();
        assert_eq!(back.len(), jobs.len());
        for (a, b) in jobs.iter().zip(&back) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.class, b.class);
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.trp, b.trp);
            assert_eq!(a.deadline, b.deadline);
            assert_eq!(a.atom_work, b.atom_work);
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn record_json_round_trip_with_deadline() {
        let jobs = WorkloadGenerator::new(WorkloadConfig {
            num_jobs: 30,
            mix: vec![("inference_burst".into(), 1.0)],
            ..WorkloadConfig::default()
        })
        .generate(9);
        for j in &jobs {
            let rec = TraceRecord::from(j);
            let back = TraceRecord::from_json(&rec.to_json()).unwrap();
            assert_eq!(rec, back);
            assert!(back.deadline.is_some());
        }
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("jasda_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "not json\n").unwrap();
        assert!(load_trace(&path).is_err());
        std::fs::write(&path, "{\"id\": 0}\n").unwrap();
        assert!(load_trace(&path).is_err(), "incomplete record must fail");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(load_trace(std::path::Path::new("/no/such/file.jsonl")).is_err());
    }
}
