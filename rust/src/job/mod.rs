//! Job model: the agent side of the JASDA interaction (paper §3.2–§3.3).
//!
//! A [`Job`] owns a [`Trp`] resource profile, tracks its work progress,
//! and — through [`variants::generate_variants`] — autonomously turns
//! scheduler window announcements into scored subjob bids. Jobs are
//! independent agents (assumption A2): nothing in this module reads
//! another job's state.

pub mod utility;
pub mod variants;

use crate::trp::Trp;
use crate::types::{JobId, SliceId, Time};

pub use variants::{DeclaredFeatures, SysFeatures, Variant};

/// Lifecycle state of a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Not yet arrived (exists in the workload trace only).
    Future,
    /// Arrived and has unfinished work.
    Active,
    /// All work completed.
    Completed,
}

/// A job: static description + dynamic progress state.
#[derive(Debug, Clone)]
pub struct Job {
    /// Unique id (admission order).
    pub id: JobId,
    /// Job-class name (from the workload generator).
    pub class: String,
    /// Arrival time.
    pub arrival: Time,
    /// Temporal resource profile (drives durations, memory, safety).
    pub trp: Trp,
    /// Optional QoS deadline (absolute tick) for the φ_QoS feature.
    pub deadline: Option<Time>,
    /// Tenant weight (used by fairness metrics and Themis-like baseline).
    pub weight: f64,
    /// Maximum work per subjob — the spacing of the job's natural
    /// preemption points (SJA atomization granularity).
    pub atom_work: f64,
    /// Multiplicative inflation this job applies to its declared
    /// utilities (0 = honest). Exercises §4.2.1.
    pub misreport_bias: f64,

    // ---- dynamic state ----
    /// Lifecycle state.
    pub state: JobState,
    /// Work already executed and credited (full-GPU tick equivalents).
    pub done_work: f64,
    /// Work committed to reservations but not yet completed.
    pub reserved_work: f64,
    /// Completion time, once finished.
    pub completed_at: Option<Time>,
    /// Last time any variant of this job was selected (age baseline).
    /// Initialized to the arrival time.
    pub last_selected: Time,
    /// Slice of the most recent committed subjob (locality feature).
    pub last_slice: Option<SliceId>,
    /// Monotone subjob sequence counter.
    pub subjob_seq: u32,
    /// Number of completed subjobs.
    pub subjobs_done: u32,
    /// Number of iterations in which this job submitted ≥1 bid.
    pub bids_submitted: u64,
    /// Number of variants of this job ever selected.
    pub variants_won: u64,
}

impl Job {
    /// Create a freshly arrived-in-the-future job.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: JobId,
        class: impl Into<String>,
        arrival: Time,
        trp: Trp,
        deadline: Option<Time>,
        weight: f64,
        atom_work: f64,
        misreport_bias: f64,
    ) -> Self {
        Job {
            id,
            class: class.into(),
            arrival,
            trp,
            deadline,
            weight,
            atom_work,
            misreport_bias,
            state: JobState::Future,
            done_work: 0.0,
            reserved_work: 0.0,
            completed_at: None,
            last_selected: arrival,
            last_slice: None,
            subjob_seq: 0,
            subjobs_done: 0,
            bids_submitted: 0,
            variants_won: 0,
        }
    }

    /// Total work of the job.
    #[inline]
    pub fn total_work(&self) -> f64 {
        self.trp.total_work()
    }

    /// Work not yet committed to any reservation — what the job bids with.
    #[inline]
    pub fn pending_work(&self) -> f64 {
        (self.total_work() - self.done_work - self.reserved_work).max(0.0)
    }

    /// Work not yet completed (committed-but-running counts as remaining).
    #[inline]
    pub fn remaining_work(&self) -> f64 {
        (self.total_work() - self.done_work).max(0.0)
    }

    /// Cursor into the TRP work axis where the next *bid* chunk starts.
    #[inline]
    pub fn work_cursor(&self) -> f64 {
        self.done_work + self.reserved_work
    }

    /// True if the job can bid: active with uncommitted work left.
    #[inline]
    pub fn can_bid(&self) -> bool {
        self.state == JobState::Active && self.pending_work() > 1e-9
    }

    /// Normalized age factor `A_i(t) ∈ [0,1]` (paper §4.3): waiting time
    /// since the last successful selection, saturating at `age_scale`.
    pub fn age_factor(&self, now: Time, age_scale: u64) -> f64 {
        age_factor(self.last_selected, now, age_scale)
    }

    /// Job completion time, if finished.
    pub fn jct(&self) -> Option<u64> {
        self.completed_at.map(|c| c.saturating_sub(self.arrival))
    }
}

/// Normalized age factor `A_i(t) ∈ [0,1]` (paper §4.3) from a raw
/// last-selected timestamp: waiting time since the last successful
/// selection, saturating at `age_scale` (0 disables the term). A free
/// function so [`Job::age_factor`] and the coordinator leader — which
/// tracks `last_selected` in its own bookkeeping, not in a [`Job`] —
/// compute bit-identical fairness terms.
pub fn age_factor(last_selected: Time, now: Time, age_scale: u64) -> f64 {
    if age_scale == 0 {
        return 0.0;
    }
    let waited = now.saturating_sub(last_selected);
    (waited as f64 / age_scale as f64).min(1.0)
}

/// The population of jobs in a run, indexed by [`JobId`].
///
/// Ids only have to be unique — trace workloads may carry sparse,
/// non-zero-based ids; an id→slot map resolves lookups while iteration
/// keeps the original (arrival-generation) order.
#[derive(Debug, Clone, Default)]
pub struct JobSet {
    jobs: Vec<Job>,
    /// Lookup-only (iteration always walks `jobs` in insertion order),
    /// so a HashMap keeps the per-variant hot-path lookup O(1) without
    /// costing determinism.
    index: std::collections::HashMap<JobId, usize>,
    /// Slots sorted by `(arrival, slot)` — the admission scan order.
    arrival_order: Vec<usize>,
    /// First entry of `arrival_order` not yet passed by `admit_until`,
    /// making admission amortized O(1) per job instead of O(n) per call
    /// (the old full scan dominated million-job production traces).
    admit_cursor: usize,
}

impl JobSet {
    /// Build from a workload. Ids must be unique but may be sparse.
    pub fn new(jobs: Vec<Job>) -> Self {
        let mut index = std::collections::HashMap::with_capacity(jobs.len());
        for (i, j) in jobs.iter().enumerate() {
            let prev = index.insert(j.id, i);
            assert!(prev.is_none(), "duplicate job id {}", j.id);
        }
        let mut arrival_order: Vec<usize> = (0..jobs.len()).collect();
        arrival_order.sort_by_key(|&i| (jobs[i].arrival, i));
        JobSet { jobs, index, arrival_order, admit_cursor: 0 }
    }

    /// Slot of a job id (panics on unknown ids, like slice indexing did).
    #[inline]
    fn slot(&self, id: JobId) -> usize {
        *self.index.get(&id).unwrap_or_else(|| panic!("unknown job id {id}"))
    }

    /// Number of jobs (all states).
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    /// True if there are no jobs at all.
    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Job by id.
    pub fn get(&self, id: JobId) -> &Job {
        &self.jobs[self.slot(id)]
    }

    /// Mutable job by id.
    pub fn get_mut(&mut self, id: JobId) -> &mut Job {
        let slot = self.slot(id);
        &mut self.jobs[slot]
    }

    /// All jobs.
    pub fn iter(&self) -> impl Iterator<Item = &Job> {
        self.jobs.iter()
    }

    /// All jobs, mutable.
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut Job> {
        self.jobs.iter_mut()
    }

    /// Jobs currently able to bid.
    pub fn bidders(&self) -> impl Iterator<Item = &Job> {
        self.jobs.iter().filter(|j| j.can_bid())
    }

    /// Mark arrivals: flip `Future -> Active` for jobs with
    /// `arrival <= now`. Returns how many jobs arrived. Amortized O(1)
    /// per admitted job via the arrival-sorted cursor.
    pub fn admit_until(&mut self, now: Time) -> usize {
        let mut n = 0;
        while let Some(&slot) = self.arrival_order.get(self.admit_cursor) {
            let j = &mut self.jobs[slot];
            if j.arrival > now {
                break;
            }
            if j.state == JobState::Future {
                j.state = JobState::Active;
                n += 1;
            }
            self.admit_cursor += 1;
        }
        n
    }

    /// True when every job has completed.
    pub fn all_completed(&self) -> bool {
        self.jobs.iter().all(|j| j.state == JobState::Completed)
    }

    /// Count of jobs in a given state.
    pub fn count_state(&self, s: JobState) -> usize {
        self.jobs.iter().filter(|j| j.state == s).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trp::Phase;

    fn mini_job(id: JobId, arrival: Time) -> Job {
        let trp =
            Trp { phases: vec![Phase::new(1000.0, 4.0, 0.2, 0.1)], duration_cv: 0.05 };
        Job::new(id, "t", arrival, trp, None, 1.0, 300.0, 0.0)
    }

    #[test]
    fn work_accounting() {
        let mut j = mini_job(0, 0);
        assert_eq!(j.total_work(), 1000.0);
        assert_eq!(j.pending_work(), 1000.0);
        j.reserved_work = 300.0;
        assert_eq!(j.pending_work(), 700.0);
        assert_eq!(j.work_cursor(), 300.0);
        j.done_work = 300.0;
        j.reserved_work = 0.0;
        assert_eq!(j.remaining_work(), 700.0);
        assert_eq!(j.pending_work(), 700.0);
    }

    #[test]
    fn can_bid_requires_active_and_pending() {
        let mut j = mini_job(0, 10);
        assert!(!j.can_bid(), "future job cannot bid");
        j.state = JobState::Active;
        assert!(j.can_bid());
        j.reserved_work = 1000.0;
        assert!(!j.can_bid(), "fully reserved job has nothing to bid");
    }

    #[test]
    fn age_factor_saturates() {
        let mut j = mini_job(0, 0);
        j.state = JobState::Active;
        assert_eq!(j.age_factor(0, 1000), 0.0);
        assert_eq!(j.age_factor(500, 1000), 0.5);
        assert_eq!(j.age_factor(5000, 1000), 1.0);
        j.last_selected = 400;
        assert_eq!(j.age_factor(900, 1000), 0.5);
        assert_eq!(j.age_factor(900, 0), 0.0, "age disabled");
    }

    #[test]
    fn jobset_admission_and_completion() {
        let mut set = JobSet::new(vec![mini_job(0, 0), mini_job(1, 100), mini_job(2, 200)]);
        assert_eq!(set.admit_until(50), 1);
        assert_eq!(set.admit_until(50), 0, "idempotent");
        assert_eq!(set.admit_until(150), 1);
        assert_eq!(set.count_state(JobState::Active), 2);
        assert_eq!(set.bidders().count(), 2);
        assert!(!set.all_completed());
        for j in set.iter_mut() {
            j.state = JobState::Completed;
            j.completed_at = Some(1000);
        }
        assert!(set.all_completed());
        assert_eq!(set.get(1).jct(), Some(900));
    }

    #[test]
    fn jobset_accepts_sparse_ids() {
        // Trace workloads may carry non-contiguous, non-zero-based ids.
        let mut set = JobSet::new(vec![mini_job(1000, 0), mini_job(5, 100), mini_job(77, 50)]);
        assert_eq!(set.len(), 3);
        assert_eq!(set.get(1000).arrival, 0);
        assert_eq!(set.get(5).arrival, 100);
        set.get_mut(77).done_work = 3.0;
        assert_eq!(set.get(77).done_work, 3.0);
        // Iteration preserves construction (generation) order.
        let ids: Vec<JobId> = set.iter().map(|j| j.id).collect();
        assert_eq!(ids, vec![1000, 5, 77]);
    }

    #[test]
    #[should_panic]
    fn jobset_rejects_duplicate_ids() {
        JobSet::new(vec![mini_job(3, 0), mini_job(3, 10)]);
    }

    #[test]
    #[should_panic]
    fn jobset_unknown_id_panics() {
        let set = JobSet::new(vec![mini_job(1, 0)]);
        let _ = set.get(2);
    }
}
