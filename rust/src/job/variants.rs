//! Job-side variant generation (paper §3.2, §4.1).
//!
//! Upon a window announcement `w* = (s_k, c_k, t_min, Δt)`, each job
//! autonomously generates up to `V_max` *eligible* subjob variants:
//! work chunks bounded by the job's atomization granularity, placed
//! back-to-back from the window start (a chain of candidate subjobs, as in
//! the paper's worked example where J_A fills the window with two
//! consecutive variants), plus a shorter alternative first chunk that
//! trades progress for a better energy/fragmentation profile.
//!
//! Every emitted variant is **safe-by-construction**: its FMP violation
//! probability over the predicted interval is ≤ θ, its duration respects
//! τ_min, and its interval lies inside the announced window. Ineligible
//! candidates are silently dropped — jobs that can produce nothing stay
//! silent (§3.2).
//!
//! # Plan/stamp split (§Perf iteration 2)
//!
//! Generation is factored into two stages so the scheduler can reuse
//! work across announced windows with the same *shape*:
//!
//! 1. [`plan_chunks`] computes everything that depends only on the
//!    window shape `(c_k, speed, Δt)` and the job's current progress —
//!    chunk sizing, declared durations, FMP discretization, and the
//!    safety check. This is the expensive stage (FMP bins per chunk).
//! 2. [`stamp_variants`] turns a plan into concrete [`Variant`]s for one
//!    announced window, filling in the position-dependent parts only
//!    (absolute interval, QoS/locality features, misreporting).
//!
//! [`generate_variants`] composes the two, so cached-plan stamping and
//! one-shot generation run the identical arithmetic and produce
//! bit-identical variants.

use crate::config::JasdaConfig;
use crate::job::{utility, Job};
use crate::mig::Window;
use crate::trp::math::normal_quantile;
use crate::trp::Fmp;
use crate::types::{Duration, Interval, JobId, SliceId, Time, VariantId};
use std::sync::Arc;

/// The φ feature vector a job declares with a bid, plus its aggregate h̃.
///
/// Order matches the scoring kernel: `[jct, qos, energy, locality]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeclaredFeatures {
    /// Honest feature values (kept for ex-post comparison in tests; the
    /// scheduler never reads these).
    pub phi_honest: [f64; 4],
    /// Declared (possibly misreported) feature values — what the
    /// scheduler sees.
    pub phi: [f64; 4],
    /// Declared aggregate job utility `h̃(v) = Σ α_i φ_i`.
    pub h_tilde: f64,
}

/// System-side features the variant itself determines (ψ_util, ψ_frag).
/// Headroom and age are filled in by the scheduler/scoring backend.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SysFeatures {
    /// ψ_util — fraction of the announced window this variant occupies.
    pub util: f64,
    /// ψ_frag — 1 minus the unusable residue the variant would leave
    /// (a leftover gap shorter than τ_min counts as wasted).
    pub frag: f64,
}

/// One subjob variant `v_{i,k,w*} = (s_k, t_start, Δt̃_i, TRP_i)` (§3.2).
#[derive(Debug, Clone)]
pub struct Variant {
    /// Pool-local id, assigned by the scheduler when bids are collected.
    pub id: VariantId,
    /// Proposing job.
    pub job: JobId,
    /// Slice of the announced window.
    pub slice: SliceId,
    /// Predicted execution interval `I(v) = [t_start, t_start + Δt̃)`.
    pub interval: Interval,
    /// Work chunk (full-GPU tick equivalents) the subjob covers.
    pub work: f64,
    /// Work-axis offset of the chunk relative to the job's cursor at
    /// generation time (0 for the first chunk of a chain).
    pub work_offset: f64,
    /// Discretized FMP over the chunk (input to the scoring kernel).
    /// Shared with the plan it was stamped from, so re-announcing the
    /// same window shape never re-discretizes or deep-copies the FMP.
    pub fmp: Arc<Fmp>,
    /// Job's own safety estimate `Pr(max RAM > c_k | FMP)`.
    pub violation_prob: f64,
    /// Declared job-side features.
    pub declared: DeclaredFeatures,
    /// Variant-determined system features.
    pub sys: SysFeatures,
}

impl Variant {
    /// Declared duration Δt̃ in ticks.
    #[inline]
    pub fn duration(&self) -> u64 {
        self.interval.len()
    }
}

/// Maximum work chunk whose *declared* (quantile-inflated) duration fits
/// into `avail` ticks on a slice of `speed`.
fn max_work_for(avail: u64, speed: f64, cv: f64, quantile: f64) -> f64 {
    let z = if cv > 0.0 { normal_quantile(quantile) } else { 0.0 };
    let inflation = 1.0 + z.max(0.0) * cv;
    (avail as f64) * speed / inflation
}

/// ψ_frag for a variant ending `leftover` ticks before the window end:
/// residues shorter than τ_min are unusable and penalized.
fn psi_frag(leftover: u64, window_len: u64, tau_min: u64) -> f64 {
    if window_len == 0 {
        return 0.0;
    }
    let wasted = if leftover > 0 && leftover < tau_min { leftover } else { 0 };
    (1.0 - wasted as f64 / window_len as f64).clamp(0.0, 1.0)
}

/// One chunk of a job's variant plan for a window *shape* — everything
/// about a candidate variant that does not depend on where the window
/// sits on the time axis or which slice id it carries. Chunks are
/// eligible by construction (τ_min, containment, safety vs the shape's
/// capacity all hold).
#[derive(Debug, Clone)]
pub struct PlannedChunk {
    /// Work chunk (full-GPU tick equivalents).
    pub work: f64,
    /// Work-axis offset relative to the job's cursor (0 = first chunk).
    pub work_offset: f64,
    /// Start offset from the window start (ticks).
    pub rel_start: Duration,
    /// Declared duration Δt̃ (ticks).
    pub duration: Duration,
    /// Discretized FMP over the chunk.
    pub fmp: Arc<Fmp>,
    /// Job's safety estimate vs the shape's capacity (≤ θ).
    pub violation_prob: f64,
}

/// Build one planned chunk covering `work` at `rel_start` ticks into a
/// window of shape `(capacity_gb, speed, delta_t)`, or `None` if it is
/// ineligible.
#[allow(clippy::too_many_arguments)]
fn plan_chunk(
    job: &Job,
    cfg: &JasdaConfig,
    capacity_gb: f64,
    speed: f64,
    delta_t: Duration,
    work: f64,
    work_offset: f64,
    rel_start: Duration,
) -> Option<PlannedChunk> {
    if work <= 1e-9 {
        return None;
    }
    let mut duration = job.trp.predicted_duration(work, speed, cfg.duration_quantile);
    // Eligibility: τ_min and window containment. A chunk that finishes
    // the job's remaining work may round its reservation *up* to τ_min —
    // otherwise a sub-τ_min tail could never be scheduled and the job
    // would starve on its last sliver of work.
    if duration < cfg.tau_min {
        let is_final = work_offset + work >= job.pending_work() - 1e-9;
        if is_final {
            duration = cfg.tau_min;
        } else {
            return None;
        }
    }
    let rel_end = rel_start.checked_add(duration)?;
    if rel_end > delta_t {
        return None;
    }
    // Safe-by-construction (§4.1(a)): FMP violation probability ≤ θ.
    let w0 = job.work_cursor() + work_offset;
    let fmp = job.trp.fmp_bins(w0, w0 + work, cfg.fmp_bins);
    let violation_prob = fmp.violation_prob(capacity_gb);
    if violation_prob > cfg.theta {
        return None;
    }
    Some(PlannedChunk {
        work,
        work_offset,
        rel_start,
        duration,
        fmp: Arc::new(fmp),
        violation_prob,
    })
}

/// Plan the job's eligible chunk portfolio for a window shape
/// `(capacity_gb, speed, delta_t)` — the shape-invariant half of
/// "GenerateVariants" (paper §3.2). Two windows with the same shape get
/// the same plan, which is what makes the scheduler's per-iteration plan
/// cache sound.
///
/// Strategy (each candidate is still subjected to full eligibility):
/// 1. *Chain fill*: consecutive chunks of at most `atom_work`, placed
///    back-to-back from the window start until work, window, or `V_max`
///    runs out — this is what lets a job occupy a whole window through
///    several short atoms (Table 3's J_A pattern).
/// 2. *Alternative half chunk*: a half-size first chunk, giving the
///    clearing phase a lower-utilization / lower-energy alternative.
pub fn plan_chunks(
    job: &Job,
    cfg: &JasdaConfig,
    capacity_gb: f64,
    speed: f64,
    delta_t: Duration,
) -> Vec<PlannedChunk> {
    let mut out = Vec::new();
    if !job.can_bid() || delta_t == 0 {
        return out;
    }

    let mut rel = 0;
    let mut offset = 0.0;
    let pending = job.pending_work();

    // 1. Chain fill.
    while out.len() < cfg.max_variants_per_job {
        let avail = delta_t.saturating_sub(rel);
        if avail < cfg.tau_min {
            break;
        }
        let w_fit = max_work_for(avail, speed, job.trp.duration_cv, cfg.duration_quantile);
        let w = w_fit.min(job.atom_work).min(pending - offset);
        match plan_chunk(job, cfg, capacity_gb, speed, delta_t, w, offset, rel) {
            Some(c) => {
                rel = c.rel_start + c.duration;
                offset += c.work;
                out.push(c);
            }
            None => break,
        }
        if offset >= pending - 1e-9 {
            break;
        }
    }

    // 2. Alternative half-size first chunk (distinct duration only).
    if out.len() < cfg.max_variants_per_job {
        if let Some(first) = out.first() {
            let (half, first_duration) = (first.work / 2.0, first.duration);
            if let Some(c) = plan_chunk(job, cfg, capacity_gb, speed, delta_t, half, 0.0, 0) {
                if c.duration != first_duration {
                    out.push(c);
                }
            }
        }
    }

    out
}

/// Stamp one planned chunk into a concrete [`Variant`] for an announced
/// window of the plan's shape: place the interval on the time axis and
/// evaluate the position-dependent features (QoS, locality,
/// misreporting). Cheap — no FMP work, the plan's profile is shared.
pub fn stamp_variant(job: &Job, window: &Window, cfg: &JasdaConfig, chunk: &PlannedChunk) -> Variant {
    let t_start: Time = window.t_min() + chunk.rel_start;
    let t_end = t_start + chunk.duration;
    let interval = Interval::new(t_start, t_end);

    // Job-side features (honest), then the declared (possibly inflated)
    // copy the scheduler actually sees.
    let phi_honest = [
        utility::phi_jct(chunk.work, job.remaining_work() - chunk.work_offset),
        utility::phi_qos(job, t_end),
        utility::phi_energy(chunk.duration, window.speed, window.delta_t()),
        utility::phi_locality(job, window),
    ];
    let phi = utility::misreport(&phi_honest, job.misreport_bias);
    let h = utility::h_tilde(&cfg.alpha.as_array(), &phi);

    let window_len = window.delta_t();
    let leftover = window.interval.end.saturating_sub(t_end);
    let sys = SysFeatures {
        util: (chunk.duration as f64 / window_len as f64).clamp(0.0, 1.0),
        frag: psi_frag(leftover, window_len, cfg.tau_min),
    };

    Variant {
        id: 0, // assigned at pool assembly
        job: job.id,
        slice: window.slice,
        interval,
        work: chunk.work,
        work_offset: chunk.work_offset,
        fmp: chunk.fmp.clone(),
        violation_prob: chunk.violation_prob,
        declared: DeclaredFeatures { phi_honest, phi, h_tilde: h },
        sys,
    }
}

/// Stamp a whole plan for one announced window, appending to `out`.
pub fn stamp_variants(
    job: &Job,
    window: &Window,
    cfg: &JasdaConfig,
    plan: &[PlannedChunk],
    out: &mut Vec<Variant>,
) {
    for chunk in plan {
        out.push(stamp_variant(job, window, cfg, chunk));
    }
}

/// Generate the job's eligible variant portfolio for an announced window
/// (paper §3.2 "GenerateVariants"): plan against the window's shape,
/// then stamp onto its position. Returns an empty vec when the job stays
/// silent.
pub fn generate_variants(job: &Job, window: &Window, cfg: &JasdaConfig) -> Vec<Variant> {
    let plan = plan_chunks(job, cfg, window.capacity_gb, window.speed, window.delta_t());
    let mut out = Vec::with_capacity(plan.len());
    stamp_variants(job, window, cfg, &plan, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::JobState;
    use crate::trp::{Phase, Trp};

    fn test_cfg() -> JasdaConfig {
        JasdaConfig { tau_min: 10, fmp_bins: 16, ..JasdaConfig::default() }
    }

    fn test_job(mem_gb: f64, total_work: f64, atom: f64) -> Job {
        let trp = Trp {
            phases: vec![Phase::new(total_work, mem_gb, 0.3, 0.1)],
            duration_cv: 0.05,
        };
        let mut j = Job::new(1, "t", 0, trp, None, 1.0, atom, 0.0);
        j.state = JobState::Active;
        j
    }

    fn test_window(cap: f64, speed: f64, start: Time, len: u64) -> Window {
        Window { slice: 2, capacity_gb: cap, speed, interval: Interval::new(start, start + len) }
    }

    #[test]
    fn silent_when_memory_unsafe() {
        // Job needs ~18 GiB; window slice has 10 GiB -> no eligible variant.
        let job = test_job(18.0, 1000.0, 500.0);
        let w = test_window(10.0, 1.0, 100, 200);
        assert!(generate_variants(&job, &w, &test_cfg()).is_empty());
    }

    #[test]
    fn silent_when_window_below_tau_min() {
        let job = test_job(4.0, 1000.0, 500.0);
        let w = test_window(10.0, 1.0, 100, 5); // 5 < tau_min=10
        assert!(generate_variants(&job, &w, &test_cfg()).is_empty());
    }

    #[test]
    fn silent_when_not_active() {
        let mut job = test_job(4.0, 1000.0, 500.0);
        job.state = JobState::Future;
        let w = test_window(10.0, 1.0, 0, 1000);
        assert!(generate_variants(&job, &w, &test_cfg()).is_empty());
    }

    #[test]
    fn chain_fills_window_with_atoms() {
        // atom=100 work at speed 1.0 -> ~109-tick chunks (0.9-quantile
        // margin); window 400 ticks -> expect a chain of ~3 + alternative.
        let job = test_job(4.0, 10_000.0, 100.0);
        let w = test_window(10.0, 1.0, 50, 400);
        let cfg = test_cfg();
        let vs = generate_variants(&job, &w, &cfg);
        assert!(vs.len() >= 3, "expected a chain, got {}", vs.len());
        assert!(vs.len() <= cfg.max_variants_per_job + 1);
        // Chain variants are back-to-back from the window start.
        assert_eq!(vs[0].interval.start, 50);
        assert_eq!(vs[1].interval.start, vs[0].interval.end);
        // All inside the window, all >= tau_min, all safe.
        for v in &vs {
            assert!(w.interval.contains(&v.interval));
            assert!(v.duration() >= cfg.tau_min);
            assert!(v.violation_prob <= cfg.theta);
            assert!(v.declared.h_tilde >= 0.0 && v.declared.h_tilde <= 1.0);
            assert!(v.sys.util > 0.0 && v.sys.util <= 1.0);
        }
        // Work offsets are consecutive.
        assert!((vs[1].work_offset - vs[0].work).abs() < 1e-9);
    }

    #[test]
    fn respects_pending_work_cap() {
        // Job with only 50 work left: one small variant (plus maybe a
        // half alternative), never exceeding pending work.
        let mut job = test_job(4.0, 1000.0, 400.0);
        job.done_work = 950.0;
        let w = test_window(10.0, 1.0, 0, 1000);
        let vs = generate_variants(&job, &w, &test_cfg());
        assert!(!vs.is_empty());
        let total: f64 = vs.iter().filter(|v| v.work_offset == 0.0).map(|v| v.work).sum();
        // first-chunk variants each cover <= pending work
        for v in &vs {
            assert!(v.work <= 50.0 + 1e-9, "variant work {} exceeds pending", v.work);
        }
        assert!(total > 0.0);
    }

    #[test]
    fn slower_slice_longer_duration() {
        let job = test_job(4.0, 10_000.0, 100.0);
        let cfg = test_cfg();
        let fast = generate_variants(&job, &test_window(10.0, 1.0, 0, 2000), &cfg);
        let slow = generate_variants(&job, &test_window(10.0, 1.0 / 7.0, 0, 2000), &cfg);
        assert!(!fast.is_empty() && !slow.is_empty());
        assert!(
            slow[0].duration() > fast[0].duration() * 6,
            "1/7-speed slice should take ~7x: {} vs {}",
            slow[0].duration(),
            fast[0].duration()
        );
    }

    #[test]
    fn misreporting_inflates_declared_only() {
        let mut job = test_job(4.0, 10_000.0, 100.0);
        job.misreport_bias = 0.5;
        let w = test_window(10.0, 1.0, 0, 500);
        let vs = generate_variants(&job, &w, &test_cfg());
        assert!(!vs.is_empty());
        let v = &vs[0];
        assert!(v.declared.phi[0] >= v.declared.phi_honest[0]);
        assert!(
            v.declared.phi != v.declared.phi_honest,
            "bias must change the declared vector"
        );
    }

    #[test]
    fn variant_count_bounded_by_vmax() {
        let job = test_job(4.0, 100_000.0, 50.0);
        let w = test_window(10.0, 1.0, 0, 100_000);
        let mut cfg = test_cfg();
        cfg.max_variants_per_job = 3;
        let vs = generate_variants(&job, &w, &cfg);
        assert!(vs.len() <= 4, "V_max chain + 1 alternative, got {}", vs.len());
        assert!(vs.iter().filter(|v| v.work_offset > 0.0).count() <= 2);
    }

    #[test]
    fn cached_plan_stamps_identically_across_same_shape_windows() {
        // Two windows with the same (capacity, speed, Δt) shape but
        // different positions/slices: stamping one window's plan onto
        // the other must equal generating from scratch, bit for bit.
        let job = test_job(4.0, 10_000.0, 100.0);
        let cfg = test_cfg();
        let w_a = test_window(10.0, 1.0, 50, 400);
        let mut w_b = test_window(10.0, 1.0, 777, 400);
        w_b.slice = 5;
        let plan = plan_chunks(&job, &cfg, w_a.capacity_gb, w_a.speed, w_a.delta_t());
        assert!(!plan.is_empty());
        let mut stamped = Vec::new();
        stamp_variants(&job, &w_b, &cfg, &plan, &mut stamped);
        let fresh = generate_variants(&job, &w_b, &cfg);
        assert_eq!(stamped.len(), fresh.len());
        for (s, f) in stamped.iter().zip(&fresh) {
            assert_eq!(s.interval, f.interval);
            assert_eq!(s.slice, f.slice);
            assert_eq!(s.work, f.work);
            assert_eq!(s.work_offset, f.work_offset);
            assert_eq!(s.violation_prob, f.violation_prob);
            assert_eq!(s.declared.phi, f.declared.phi);
            assert_eq!(s.declared.h_tilde, f.declared.h_tilde);
            assert_eq!((s.sys.util, s.sys.frag), (f.sys.util, f.sys.frag));
            assert_eq!(s.fmp.mu, f.fmp.mu);
            assert_eq!(s.fmp.sigma, f.fmp.sigma);
        }
    }

    #[test]
    fn psi_frag_penalizes_unusable_residue() {
        assert_eq!(psi_frag(0, 100, 10), 1.0, "exact fill leaves nothing");
        assert_eq!(psi_frag(50, 100, 10), 1.0, "usable leftover is fine");
        assert!((psi_frag(5, 100, 10) - 0.95).abs() < 1e-12, "5-tick residue wasted");
        assert_eq!(psi_frag(5, 0, 10), 0.0);
    }

    #[test]
    fn max_work_for_inflation() {
        // cv=0 -> no inflation.
        assert!((max_work_for(100, 1.0, 0.0, 0.9) - 100.0).abs() < 1e-9);
        // cv>0 at 0.9 quantile -> less work fits.
        let w = max_work_for(100, 1.0, 0.1, 0.9);
        assert!(w < 100.0 && w > 80.0, "w = {w}");
        // Speed scales linearly.
        assert!((max_work_for(100, 0.5, 0.0, 0.9) - 50.0).abs() < 1e-9);
    }
}
