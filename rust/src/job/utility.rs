//! Job-side utility features φ_i (paper §4.2, Eq. (2)).
//!
//! Each feature is normalized to `[0,1]` with "higher = more desirable"
//! orientation, exactly as the paper's normalization scheme requires. The
//! same formulas are re-evaluated on *observed* quantities after execution
//! for the ex-post verification step (Eq. (6)).

use crate::job::Job;
use crate::mig::Window;
use crate::types::Time;

/// Relative idle power of a slice (fraction of full-GPU dynamic power).
pub const P_IDLE: f64 = 0.25;
/// Relative dynamic power coefficient (scales with slice speed).
pub const P_DYN: f64 = 0.75;

/// φ_JCT — expected completion-progress gain: the fraction of the job's
/// remaining work this chunk covers (paper: `1 − ΔJCT/ΔJCT_max`; covering
/// more remaining work is the discrete equivalent).
pub fn phi_jct(work: f64, remaining_work: f64) -> f64 {
    if remaining_work <= 0.0 {
        return 0.0;
    }
    (work / remaining_work).clamp(0.0, 1.0)
}

/// φ_QoS — urgency-graded deadline adherence. Jobs without a deadline
/// report a low-stakes 0.25; deadline-carrying jobs report between 0.5
/// (plenty of slack) and 1.0 (slack nearly exhausted) while the subjob
/// still finishes in time, and 0 once the deadline is already blown.
/// Grading by urgency is what lets a QoS-first policy (λ high, Table 2)
/// actually prioritize the jobs whose deadlines are at risk.
pub fn phi_qos(job: &Job, predicted_end: Time) -> f64 {
    match job.deadline {
        None => 0.25,
        Some(d) => {
            if predicted_end > d {
                return 0.0;
            }
            let total = d.saturating_sub(job.arrival).max(1) as f64;
            let slack = d.saturating_sub(predicted_end) as f64;
            let urgency = (1.0 - slack / total).clamp(0.0, 1.0);
            0.5 + 0.5 * urgency
        }
    }
}

/// Normalized energy of running a subjob of `duration` ticks on a slice of
/// the given `speed`: `E(v) = duration · (P_idle + P_dyn·speed)`, with
/// `E_max = window_len · (P_idle + P_dyn)` (a full-GPU slice busy for the
/// whole window).
pub fn energy(duration: u64, speed: f64) -> f64 {
    duration as f64 * (P_IDLE + P_DYN * speed)
}

/// φ_energy — `1 − E(v)/E_max` (paper §4.2's ψ_energy transformation,
/// applied job-side as an energy-cost preference).
pub fn phi_energy(duration: u64, speed: f64, window_len: u64) -> f64 {
    if window_len == 0 {
        return 0.0;
    }
    let e_max = energy(window_len, 1.0);
    (1.0 - energy(duration, speed) / e_max).clamp(0.0, 1.0)
}

/// φ_loc — slice-affinity feature (§4.1(b) data-reuse preference): 1 when
/// the announced window is on the slice of the previous subjob (warm
/// caches / resident data), 0.5 for the first subjob, 0 otherwise.
pub fn phi_locality(job: &Job, window: &Window) -> f64 {
    match job.last_slice {
        None => 0.5,
        Some(s) if s == window.slice => 1.0,
        Some(_) => 0.0,
    }
}

/// Combine features with the α weights: `h̃(v) = Σ α_i φ_i` (Eq. (2),
/// normalized form). With Σα ≤ 1 and φ ∈ [0,1], h̃ ∈ [0,1].
pub fn h_tilde(alpha: &[f64; 4], phi: &[f64; 4]) -> f64 {
    alpha.iter().zip(phi).map(|(a, p)| a * p).sum()
}

/// Apply a misreport bias to a (honest) feature vector: inflates the
/// self-assessed features the scheduler cannot immediately check (JCT
/// gain, energy), leaving exact features (QoS indicator, locality)
/// untouched. Clamped to [0,1] so declared scores stay normalized.
pub fn misreport(phi: &[f64; 4], bias: f64) -> [f64; 4] {
    if bias == 0.0 {
        return *phi;
    }
    [
        (phi[0] * (1.0 + bias)).clamp(0.0, 1.0),
        phi[1],
        (phi[2] * (1.0 + bias)).clamp(0.0, 1.0),
        phi[3],
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trp::{Phase, Trp};

    fn job_with_deadline(deadline: Option<Time>) -> Job {
        let trp = Trp { phases: vec![Phase::new(1000.0, 4.0, 0.2, 0.1)], duration_cv: 0.05 };
        Job::new(0, "t", 0, trp, deadline, 1.0, 300.0, 0.0)
    }

    fn window_on(slice: u32) -> Window {
        Window {
            slice,
            capacity_gb: 10.0,
            speed: 2.0 / 7.0,
            interval: crate::types::Interval::new(100, 200),
        }
    }

    #[test]
    fn phi_jct_fraction_of_remaining() {
        assert_eq!(phi_jct(250.0, 1000.0), 0.25);
        assert_eq!(phi_jct(2000.0, 1000.0), 1.0, "clamped");
        assert_eq!(phi_jct(10.0, 0.0), 0.0);
    }

    #[test]
    fn phi_qos_deadline_logic() {
        let j = job_with_deadline(Some(500));
        // In time, 80% of slack consumed -> high urgency.
        let tight = phi_qos(&j, 400);
        // In time, barely any slack consumed -> low urgency.
        let loose = phi_qos(&j, 50);
        assert!(tight > loose, "{tight} vs {loose}");
        assert!((0.5..=1.0).contains(&tight));
        assert!((0.5..=1.0).contains(&loose));
        assert_eq!(phi_qos(&j, 500), 1.0, "zero slack left, still in time");
        assert_eq!(phi_qos(&j, 600), 0.0, "deadline blown");
        let j = job_with_deadline(None);
        assert_eq!(phi_qos(&j, 600), 0.25, "no deadline -> low stakes");
    }

    #[test]
    fn phi_energy_monotone() {
        // Shorter run on a slower slice costs less energy -> higher phi.
        let short = phi_energy(20, 1.0 / 7.0, 100);
        let long = phi_energy(90, 1.0, 100);
        assert!(short > long, "{short} vs {long}");
        assert!((0.0..=1.0).contains(&short));
        assert!((0.0..=1.0).contains(&long));
        assert_eq!(phi_energy(10, 1.0, 0), 0.0);
        // Full window on the full GPU = max energy -> phi 0.
        assert_eq!(phi_energy(100, 1.0, 100), 0.0);
    }

    #[test]
    fn phi_locality_cases() {
        let mut j = job_with_deadline(None);
        assert_eq!(phi_locality(&j, &window_on(3)), 0.5, "first subjob is neutral");
        j.last_slice = Some(3);
        assert_eq!(phi_locality(&j, &window_on(3)), 1.0);
        assert_eq!(phi_locality(&j, &window_on(4)), 0.0);
    }

    #[test]
    fn h_tilde_stays_normalized() {
        let alpha = [0.45, 0.25, 0.15, 0.15];
        assert!(h_tilde(&alpha, &[1.0; 4]) <= 1.0 + 1e-12);
        assert_eq!(h_tilde(&alpha, &[0.0; 4]), 0.0);
        let h = h_tilde(&alpha, &[0.5, 1.0, 0.2, 0.0]);
        assert!((h - (0.45 * 0.5 + 0.25 + 0.15 * 0.2)).abs() < 1e-12);
    }

    #[test]
    fn misreport_inflates_only_soft_features() {
        let honest = [0.4, 1.0, 0.6, 0.5];
        let lied = misreport(&honest, 0.5);
        assert!((lied[0] - 0.6).abs() < 1e-12);
        assert_eq!(lied[1], 1.0, "QoS indicator is exact, not inflatable");
        assert!((lied[2] - 0.9).abs() < 1e-12);
        assert_eq!(lied[3], 0.5, "locality is exact");
        // Clamping.
        let lied = misreport(&[0.9, 0.0, 0.9, 0.0], 1.0);
        assert_eq!(lied[0], 1.0);
        // Zero bias is identity.
        assert_eq!(misreport(&honest, 0.0), honest);
    }
}
