//! `jasda` — the framework launcher.
//!
//! Subcommands:
//! * `run` — simulate one scheduler on a generated (or traced) workload;
//! * `compare` — run every scheduler on the same workload, print the
//!   comparison table (the Table-1 / headline experiment);
//! * `sweep` — sweep the λ policy parameter (the Table-2 experiment);
//! * `protocol` — drive the threaded bid–response protocol runtime;
//! * `gen-trace` — generate and save a workload trace;
//! * `example` — print the paper's §4.5 worked example step by step.

use jasda::baselines::{by_name, ALL_SCHEDULERS};
use jasda::config::{ScoringBackend, SimConfig};
use jasda::jasda::JasdaScheduler;
use jasda::metrics::streaming::{StreamingMetrics, DEFAULT_REL_ACCURACY};
use jasda::report::{comparison_headers, comparison_row, Table};
use jasda::sim::SimEngine;
use jasda::util::cli::Args;
use jasda::workload::{load_trace, save_trace, ScenarioGenerator, WorkloadGenerator};
use std::path::{Path, PathBuf};

const USAGE: &str = "\
jasda — JASDA: job-aware scheduling on MIG GPUs

USAGE:
  jasda <COMMAND> [OPTIONS]

COMMANDS:
  run        Run one scheduler and print its metrics
  compare    Run all schedulers on the identical workload; print the table
  sweep      Sweep the λ policy parameter (paper Table 2)
  protocol   Drive the threaded bid–response protocol runtime
  gen-trace  Generate a workload trace file (positional: output path)
  example    Reproduce the paper's §4.5 worked example

OPTIONS:
  --config <file.json>   JSON config (defaults apply if omitted)
  --seed <u64>           Override the RNG seed
  --scheduler <name>     run: jasda|fcfs|sjf|edf|backfill|sja_central|themis_like
  --trace <file.jsonl>   run/compare: load workload from a trace
  --stream-metrics <f>   run: stream windowed metrics to <f> as JSONL and keep
                         only O(buckets) metric state (production-scale runs)
  --lambdas <a,b,c>      sweep: λ values (default 0.3,0.5,0.7)
  --max-rounds <n>       protocol: round cap (default 200000)
  --pjrt                 run: use the PJRT scoring backend (needs `make artifacts`)
  --json                 run: emit full metrics as JSON
  --csv                  compare: emit CSV instead of markdown

Setting jasda.scenario.jobs > 0 in the config switches workload
generation to the production-scale scenario harness (heavy-tailed sizes,
diurnal+bursty arrivals, fairness groups, SLO deadlines; see
docs/CONFIG.md), and jasda.scenario.adversity = light|heavy arms the
seeded protocol fault plan for `protocol` runs.
";

fn load_config(args: &Args) -> anyhow::Result<SimConfig> {
    let mut cfg = match args.opt("config") {
        Some(p) => SimConfig::from_json_file(Path::new(p))?,
        None => SimConfig::default(),
    };
    if let Some(seed) = args.opt("seed") {
        cfg.seed = seed.parse().map_err(|_| anyhow::anyhow!("bad --seed '{seed}'"))?;
    }
    cfg.jasda.apply_scenario_adversity()?;
    cfg.validate()?;
    Ok(cfg)
}

fn workload(cfg: &SimConfig, trace: Option<&str>) -> anyhow::Result<Vec<jasda::job::Job>> {
    match trace {
        Some(p) => load_trace(Path::new(p)),
        None if cfg.jasda.scenario.enabled() => {
            Ok(ScenarioGenerator::new(cfg.jasda.scenario.clone()).generate(cfg.seed))
        }
        None => Ok(WorkloadGenerator::new(cfg.workload.clone()).generate(cfg.seed)),
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(
        std::env::args().skip(1),
        &["config", "seed", "scheduler", "trace", "stream-metrics", "lambdas", "max-rounds"],
        &["pjrt", "json", "csv", "help"],
    )
    .map_err(|e| anyhow::anyhow!("{e}\n\n{USAGE}"))?;

    if args.flag("help") || args.positional.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let cfg = load_config(&args)?;

    match args.positional[0].as_str() {
        "run" => cmd_run(&args, cfg),
        "compare" => cmd_compare(&args, cfg),
        "sweep" => cmd_sweep(&args, cfg),
        "protocol" => cmd_protocol(&args, cfg),
        "gen-trace" => cmd_gen_trace(&args, cfg),
        "example" => {
            print_worked_example();
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn cmd_run(args: &Args, cfg: SimConfig) -> anyhow::Result<()> {
    let scheduler = args.opt("scheduler").unwrap_or("jasda");
    let jobs = workload(&cfg, args.opt("trace"))?;
    let sched: Box<dyn jasda::sim::Scheduler> = if args.flag("pjrt") && scheduler == "jasda" {
        let mut jcfg = cfg.jasda.clone();
        jcfg.backend = ScoringBackend::Pjrt;
        let scorer = jasda::runtime::PjrtScorer::from_default_artifacts()?;
        Box::new(JasdaScheduler::with_scorer(jcfg, Box::new(scorer)))
    } else {
        by_name(scheduler, &cfg.jasda)
            .ok_or_else(|| anyhow::anyhow!("unknown scheduler '{scheduler}'"))?
    };
    let out = if let Some(path) = args.opt("stream-metrics") {
        let file = std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("cannot create --stream-metrics file '{path}': {e}"))?;
        let sm = StreamingMetrics::new(cfg.jasda.scenario.metrics_window, DEFAULT_REL_ACCURACY)
            .with_sink(Box::new(std::io::BufWriter::new(file)));
        SimEngine::new(cfg, sched).with_streaming(sm).run(jobs)
    } else {
        SimEngine::new(cfg, sched).run(jobs)
    };
    if let Some(sm) = &out.streaming {
        if args.flag("json") {
            println!("{}", sm.summary_json().to_string_pretty());
        } else {
            println!("{}", sm.summary_line());
            println!("scheduler stats: {}", out.scheduler_stats);
        }
    } else if args.flag("json") {
        println!("{}", out.metrics.to_json().to_string_pretty());
    } else {
        println!("{}", out.metrics.summary());
        println!("scheduler stats: {}", out.scheduler_stats);
    }
    Ok(())
}

fn cmd_compare(args: &Args, cfg: SimConfig) -> anyhow::Result<()> {
    let jobs = workload(&cfg, args.opt("trace"))?;
    let mut table = Table::new(
        format!(
            "Scheduler comparison — {} jobs, {} GPU(s) '{}' layout, seed {}",
            jobs.len(),
            cfg.cluster.num_gpus,
            cfg.cluster.layout,
            cfg.seed
        ),
        &comparison_headers(),
    );
    for name in ALL_SCHEDULERS {
        let sched = by_name(name, &cfg.jasda).expect("known scheduler");
        let out = SimEngine::new(cfg.clone(), sched).run(jobs.clone());
        table.push_row(comparison_row(&out.metrics));
    }
    if args.flag("csv") {
        print!("{}", table.to_csv());
    } else {
        print!("{}", table.to_markdown());
    }
    Ok(())
}

fn cmd_sweep(args: &Args, cfg: SimConfig) -> anyhow::Result<()> {
    let lambdas =
        args.opt_list_f64("lambdas", &[0.3, 0.5, 0.7]).map_err(|e| anyhow::anyhow!("{e}"))?;
    let jobs = workload(&cfg, None)?;
    let mut table = Table::new(
        "λ policy sweep (paper Table 2)",
        &["lambda", "policy", "util", "mean_jct", "p95_jct", "deadline_rate", "jain"],
    );
    for &l in &lambdas {
        let mut jcfg = cfg.jasda.clone();
        jcfg.lambda = l;
        let policy = if l >= 0.65 {
            "QoS-first"
        } else if l <= 0.35 {
            "Utilization-first"
        } else {
            "Balanced"
        };
        let out =
            SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(jcfg))).run(jobs.clone());
        let m = &out.metrics;
        let f = |x: Option<f64>| x.map_or("-".to_string(), |v| format!("{v:.3}"));
        table.push_row(vec![
            format!("{l:.2}"),
            policy.into(),
            format!("{:.3}", m.utilization),
            f(m.mean_jct()),
            f(m.jct_percentile(0.95)),
            f(m.deadline_met_rate()),
            f(m.jain_fairness()),
        ]);
    }
    print!("{}", table.to_markdown());
    Ok(())
}

fn cmd_protocol(args: &Args, cfg: SimConfig) -> anyhow::Result<()> {
    let max_rounds =
        args.opt_parse("max-rounds", 200_000u64).map_err(|e| anyhow::anyhow!("{e}"))?;
    let jobs = workload(&cfg, None)?;
    let transport = cfg.jasda.transport.name();
    let out = jasda::coordinator::run_protocol(cfg, jobs, max_rounds);
    println!(
        "protocol[{transport}]: rounds={} announcements={} windows={} (+{} silent) bids={} \
         variants={} awards={} conflicts={} completed={}/{} vtime={} wall={:?} \
         decision={:.0}ns/round",
        out.rounds,
        out.announcements,
        out.windows_announced,
        out.windows_silent,
        out.bids,
        out.variants,
        out.awards,
        out.cross_window_conflicts,
        out.completed_jobs,
        out.total_jobs,
        out.final_time,
        out.wall,
        out.decision_ns_per_round(),
    );
    if out.rounds_timed_out + out.frames_rejected + out.agents_quarantined + out.sends_dropped > 0
    {
        println!(
            "faults: timed_out_rounds={} stragglers={} frames_rejected={} quarantined={} \
             readmitted={} sends_dropped={} unknown_job_bids={}",
            out.rounds_timed_out,
            out.stragglers,
            out.frames_rejected,
            out.agents_quarantined,
            out.readmissions,
            out.sends_dropped,
            out.unknown_job_bids,
        );
    }
    Ok(())
}

fn cmd_gen_trace(args: &Args, cfg: SimConfig) -> anyhow::Result<()> {
    let out: PathBuf = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("gen-trace needs an output path"))?
        .into();
    let jobs = workload(&cfg, None)?;
    save_trace(&out, &jobs)?;
    println!("wrote {} jobs to {}", jobs.len(), out.display());
    Ok(())
}

/// Reproduce §4.5: the deterministic single-iteration example.
fn print_worked_example() {
    use jasda::jasda::clearing::{select_best_compatible, WisItem};
    use jasda::types::Interval;

    println!("Paper §4.5 worked example — window w* = (s2, 20 GB, t_min=40, Δt=10)\n");
    let names = ["v_A1", "v_A2", "v_B1"];
    let items = [
        (Interval::new(40, 47), 0.75, 0.55),
        (Interval::new(47, 50), 0.60, 0.70),
        (Interval::new(40, 50), 0.80, 0.60),
    ];
    let lambda = 0.6;
    println!("{:<6} {:>5} {:>4} {:>6} {:>6} {:>7}", "bid", "start", "end", "h", "f_sys", "Score");
    let wis: Vec<WisItem> = items
        .iter()
        .map(|&(iv, h, f)| WisItem { interval: iv, score: lambda * h + (1.0 - lambda) * f })
        .collect();
    for (n, (&(iv, h, f), w)) in names.iter().zip(items.iter().zip(&wis)) {
        println!(
            "{:<6} {:>5} {:>4} {:>6.2} {:>6.2} {:>7.2}",
            n, iv.start, iv.end, h, f, w.score
        );
    }
    let sol = select_best_compatible(&wis);
    let chosen: Vec<&str> = sol.selected.iter().map(|&i| names[i]).collect();
    println!("\nWIS selection: {{{}}}, total score {:.2}", chosen.join(", "), sol.total_score);
    println!("(paper: {{v_A1, v_A2}} with total 1.31)");
}
