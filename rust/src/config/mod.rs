//! Typed configuration for the whole framework, loadable from JSON.
//!
//! Every policy parameter named in the paper is exposed here: the
//! job/system trade-off `λ` and feature weights `α_i`, `β_j` (§4.2), the
//! safety bound `θ` and minimum subjob duration `τ_min` (§4.1), the
//! calibration smoothing `γ` and reliability sensitivity `κ` (§4.2.1),
//! the age weight `β_age` (§4.3), the window-selection policy (§3.1 /
//! §5.1(c)), and the announce-ahead lead time (§5.1(a) mitigation (i)).
//!
//! Config files are JSON (the offline build has no serde/toml; the JSON
//! layer is the in-crate [`crate::util::json`]). Partial configs merge
//! over defaults; unknown keys are rejected so typos surface.
//!
//! A human-oriented reference table of every key — type, default, and
//! semantics, including the K-window announcement knobs
//! (`jasda.announce_k`, `jasda.announce_per_slice`) and the worker-pool
//! budget (`jasda.parallel`) — lives in `docs/CONFIG.md` at the
//! repository root; this module is the authoritative machine-checked
//! definition it indexes.

use crate::types::{Duration, Time};
use crate::util::Json;
use std::collections::BTreeMap;

/// Which idle window the scheduler announces each iteration (§3.1, §5.1(c)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WindowPolicy {
    /// Earliest start time first — the paper's prototype default.
    EarliestStart,
    /// Longest window first (greedy capacity exposure).
    LongestFirst,
    /// Largest slack (window length × slice speed) first.
    SlackAware,
    /// Prefer windows on the most fragmented slice (defrag pressure).
    FragmentationAware,
    /// Rotate across slices round-robin to equalize exposure.
    RoundRobin,
}

impl Default for WindowPolicy {
    fn default() -> Self {
        WindowPolicy::EarliestStart
    }
}

impl WindowPolicy {
    /// Config-file name.
    pub fn name(&self) -> &'static str {
        match self {
            WindowPolicy::EarliestStart => "earliest_start",
            WindowPolicy::LongestFirst => "longest_first",
            WindowPolicy::SlackAware => "slack_aware",
            WindowPolicy::FragmentationAware => "fragmentation_aware",
            WindowPolicy::RoundRobin => "round_robin",
        }
    }

    /// Parse from a config-file name.
    pub fn parse(s: &str) -> Option<WindowPolicy> {
        Self::ALL.iter().copied().find(|p| p.name() == s)
    }

    /// All policies.
    pub const ALL: [WindowPolicy; 5] = [
        WindowPolicy::EarliestStart,
        WindowPolicy::LongestFirst,
        WindowPolicy::SlackAware,
        WindowPolicy::FragmentationAware,
        WindowPolicy::RoundRobin,
    ];
}

/// Which transport carries leader ↔ agent protocol messages in
/// [`run_protocol`](crate::coordinator::run_protocol).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process channels of typed messages (default). Zero
    /// serialization; the shape every test was green on before the
    /// transport split.
    Loopback,
    /// Length-prefixed byte frames through the hand-rolled forward-only
    /// codec of `coordinator::wire` — the deployment-shaped path, still
    /// bit-identical in decisions because the codec round-trips every
    /// field exactly.
    Framed,
    /// The same frames over real TCP sockets: agents connect to the
    /// leader's listener (`jasda.listen_addr`, default `127.0.0.1:0`)
    /// and the leader serves every connection from one poll-driven I/O
    /// thread. Decisions stay bit-identical to `loopback`.
    Tcp,
    /// The same frames over Unix-domain sockets (`jasda.listen_addr` a
    /// filesystem path, default a fresh socket under the system temp
    /// directory). Unix targets only.
    Unix,
}

impl Default for TransportKind {
    fn default() -> Self {
        TransportKind::Loopback
    }
}

impl TransportKind {
    /// Config-file name.
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Loopback => "loopback",
            TransportKind::Framed => "framed",
            TransportKind::Tcp => "tcp",
            TransportKind::Unix => "unix",
        }
    }

    /// Parse from a config-file name.
    pub fn parse(s: &str) -> Option<TransportKind> {
        Self::ALL.iter().copied().find(|t| t.name() == s)
    }

    /// All transports.
    pub const ALL: [TransportKind; 4] = [
        TransportKind::Loopback,
        TransportKind::Framed,
        TransportKind::Tcp,
        TransportKind::Unix,
    ];
}

/// How the round's cross-window conflict graph is cleared once the
/// per-window WIS solutions exist (`jasda.clearing`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClearingMode {
    /// Sequential reconciliation in announcement order (default): each
    /// window keeps its WIS optimum after filtering against earlier
    /// windows' awards. The paper's behavior, and the exact solver's
    /// incumbent/fallback and test oracle.
    Greedy,
    /// Global branch-and-bound over the round's job × window conflict
    /// graph: greedy solution as incumbent, per-window WIS relaxation as
    /// upper bound, best-first expansion. Falls back to the greedy
    /// incumbent when `jasda.clearing_budget_ms` is exhausted, so round
    /// deadlines are never violated.
    Exact,
}

impl Default for ClearingMode {
    fn default() -> Self {
        ClearingMode::Greedy
    }
}

impl ClearingMode {
    /// Config-file name.
    pub fn name(&self) -> &'static str {
        match self {
            ClearingMode::Greedy => "greedy",
            ClearingMode::Exact => "exact",
        }
    }

    /// Parse from a config-file name.
    pub fn parse(s: &str) -> Option<ClearingMode> {
        Self::ALL.iter().copied().find(|m| m.name() == s)
    }

    /// All clearing modes.
    pub const ALL: [ClearingMode; 2] = [ClearingMode::Greedy, ClearingMode::Exact];
}

/// Which backend evaluates the batched scoring pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoringBackend {
    /// Pure-rust mirror of the L1/L2 pipeline (default; allocation-free).
    Native,
    /// AOT-compiled JAX/Pallas artifact executed via PJRT (L1/L2 on the
    /// hot path). Requires `make artifacts`.
    Pjrt,
}

impl Default for ScoringBackend {
    fn default() -> Self {
        ScoringBackend::Native
    }
}

// --- small JSON plumbing helpers -----------------------------------------

fn expect_obj<'a>(v: &'a Json, what: &str) -> anyhow::Result<&'a BTreeMap<String, Json>> {
    match v {
        Json::Obj(m) => Ok(m),
        _ => anyhow::bail!("{what} must be a JSON object"),
    }
}

fn need_f64(v: &Json, what: &str) -> anyhow::Result<f64> {
    v.as_f64().ok_or_else(|| anyhow::anyhow!("{what} must be a number"))
}

fn need_u64(v: &Json, what: &str) -> anyhow::Result<u64> {
    v.as_u64().ok_or_else(|| anyhow::anyhow!("{what} must be a non-negative integer"))
}

fn need_bool(v: &Json, what: &str) -> anyhow::Result<bool> {
    v.as_bool().ok_or_else(|| anyhow::anyhow!("{what} must be a boolean"))
}

fn need_str<'a>(v: &'a Json, what: &str) -> anyhow::Result<&'a str> {
    v.as_str().ok_or_else(|| anyhow::anyhow!("{what} must be a string"))
}

// --------------------------------------------------------------------------

/// Job-side feature weights `α_i` (must sum to ≤ 1) — paper Eq. (2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AlphaWeights {
    /// Weight of the JCT/progress feature φ_JCT.
    pub jct: f64,
    /// Weight of the QoS indicator φ_QoS.
    pub qos: f64,
    /// Weight of the energy feature φ_energy.
    pub energy: f64,
    /// Weight of the slice-affinity / locality feature φ_loc.
    pub locality: f64,
}

impl Default for AlphaWeights {
    fn default() -> Self {
        AlphaWeights { jct: 0.45, qos: 0.25, energy: 0.15, locality: 0.15 }
    }
}

impl AlphaWeights {
    /// Weights as an array in kernel order `[jct, qos, energy, locality]`.
    pub fn as_array(&self) -> [f64; 4] {
        [self.jct, self.qos, self.energy, self.locality]
    }

    /// Sum of weights (normalization requires ≤ 1).
    pub fn sum(&self) -> f64 {
        self.jct + self.qos + self.energy + self.locality
    }

    fn merge_json(&mut self, v: &Json) -> anyhow::Result<()> {
        for (k, val) in expect_obj(v, "alpha")? {
            let x = need_f64(val, k)?;
            match k.as_str() {
                "jct" => self.jct = x,
                "qos" => self.qos = x,
                "energy" => self.energy = x,
                "locality" => self.locality = x,
                other => anyhow::bail!("unknown alpha key '{other}'"),
            }
        }
        Ok(())
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("jct", self.jct.into()),
            ("qos", self.qos.into()),
            ("energy", self.energy.into()),
            ("locality", self.locality.into()),
        ])
    }
}

/// System-side feature weights `β_j` (must sum to ≤ 1) — paper Eq. (3),
/// including the age term β_age of §4.3.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BetaWeights {
    /// Weight of the utilization-gain feature ψ_util.
    pub util: f64,
    /// Weight of the memory-headroom feature ψ_mem_headroom.
    pub headroom: f64,
    /// Weight of the fragmentation feature ψ_frag.
    pub frag: f64,
    /// Weight of the age factor A_i(t) (β_age; 0 disables §4.3).
    pub age: f64,
}

impl Default for BetaWeights {
    fn default() -> Self {
        BetaWeights { util: 0.45, headroom: 0.2, frag: 0.15, age: 0.2 }
    }
}

impl BetaWeights {
    /// Weights as an array in kernel order `[util, headroom, frag, age]`.
    pub fn as_array(&self) -> [f64; 4] {
        [self.util, self.headroom, self.frag, self.age]
    }

    /// Sum of weights (normalization requires ≤ 1).
    pub fn sum(&self) -> f64 {
        self.util + self.headroom + self.frag + self.age
    }

    fn merge_json(&mut self, v: &Json) -> anyhow::Result<()> {
        for (k, val) in expect_obj(v, "beta")? {
            let x = need_f64(val, k)?;
            match k.as_str() {
                "util" => self.util = x,
                "headroom" => self.headroom = x,
                "frag" => self.frag = x,
                "age" => self.age = x,
                other => anyhow::bail!("unknown beta key '{other}'"),
            }
        }
        Ok(())
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("util", self.util.into()),
            ("headroom", self.headroom.into()),
            ("frag", self.frag.into()),
            ("age", self.age.into()),
        ])
    }
}

/// Deterministic fault-injection knobs for the protocol runtime
/// (`jasda.faults.*`). All probabilities default to 0 — faults off, the
/// protocol bit-identical to the fault-free coordinator. With any
/// probability > 0 a seeded
/// [`FaultPlan`](crate::coordinator::faults::FaultPlan) is drawn at
/// protocol start and applied by a `FaultyTransport` wrapper; the run
/// then also requires `jasda.round_timeout_ms > 0`, because a crashed
/// agent's reply never arrives and only the round deadline keeps the
/// collection loop live (enforced by [`JasdaConfig::validate`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultsConfig {
    /// Seed of the fault plan (independent of the workload seed, so the
    /// same trace can be replayed under different adversity).
    pub seed: u64,
    /// Per-agent probability of one crash window (unreachable for a
    /// finite span of rounds). When > 0 at least one crash is forced
    /// into the plan so a "crash test" can never silently degenerate
    /// into a fault-free run.
    pub crash: f64,
    /// Per-agent probability of one straggler reply (held, delivered
    /// rounds late, discarded by the round-tag check).
    pub delay: f64,
    /// Per-agent probability of one corrupted reply (surfaces to the
    /// leader as a rejected frame).
    pub corrupt: f64,
    /// Per-agent probability of one dropped leader→agent send.
    pub drop: f64,
    /// Rounds `[0, horizon_rounds)` fault trigger points are drawn from.
    pub horizon_rounds: u64,
    /// Max crash-window length in rounds (crash windows are always
    /// finite, so re-admission — and thus liveness — stays provable).
    pub crash_rounds: u64,
    /// Max straggler delay in rounds.
    pub delay_rounds: u64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            seed: 0,
            crash: 0.0,
            delay: 0.0,
            corrupt: 0.0,
            drop: 0.0,
            horizon_rounds: 64,
            crash_rounds: 8,
            delay_rounds: 3,
        }
    }
}

impl FaultsConfig {
    /// Whether any fault shape can fire (any probability > 0).
    pub fn enabled(&self) -> bool {
        self.crash > 0.0 || self.delay > 0.0 || self.corrupt > 0.0 || self.drop > 0.0
    }

    fn merge_json(&mut self, v: &Json) -> anyhow::Result<()> {
        for (k, val) in expect_obj(v, "faults")? {
            match k.as_str() {
                "seed" => self.seed = need_u64(val, k)?,
                "crash" => self.crash = need_f64(val, k)?,
                "delay" => self.delay = need_f64(val, k)?,
                "corrupt" => self.corrupt = need_f64(val, k)?,
                "drop" => self.drop = need_f64(val, k)?,
                "horizon_rounds" => self.horizon_rounds = need_u64(val, k)?,
                "crash_rounds" => self.crash_rounds = need_u64(val, k)?,
                "delay_rounds" => self.delay_rounds = need_u64(val, k)?,
                other => anyhow::bail!("unknown faults key '{other}'"),
            }
        }
        Ok(())
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("seed", self.seed.into()),
            ("crash", self.crash.into()),
            ("delay", self.delay.into()),
            ("corrupt", self.corrupt.into()),
            ("drop", self.drop.into()),
            ("horizon_rounds", self.horizon_rounds.into()),
            ("crash_rounds", self.crash_rounds.into()),
            ("delay_rounds", self.delay_rounds.into()),
        ])
    }
}

/// Production-scale scenario harness knobs (`jasda.scenario.*`).
///
/// When `jobs > 0` the CLI's workload source switches from the
/// class-mix [`WorkloadConfig`] generator to the trace-driven
/// [`ScenarioGenerator`](crate::workload::ScenarioGenerator):
/// heavy-tailed (truncated-Pareto) job sizes, a diurnal + bursty
/// arrival process, multi-tenant fairness groups with geometric
/// weights, and a deadline/SLO job fraction — the workload shape the
/// multi-tenant MIG literature evaluates on. The `adversity` preset
/// additionally drives the protocol runtime's
/// [`FaultsConfig`]/`FaultPlan` from scenario config (see
/// [`JasdaConfig::apply_scenario_adversity`]), and `metrics_window`
/// sizes the windowed counters of the streaming metrics layer
/// ([`crate::metrics::streaming`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioConfig {
    /// Jobs to generate. `0` (default) disables the scenario harness —
    /// the classic `workload.*` generator stays in charge.
    pub jobs: usize,
    /// Scenario RNG seed; `0` = derive from the run's master `seed`.
    /// A trace is bit-reproducible from this seed alone.
    pub seed: u64,
    /// Mean arrival rate (jobs per simulated second) before diurnal and
    /// burst modulation.
    pub base_rate_per_sec: f64,
    /// Diurnal modulation depth in [0,1): the instantaneous rate swings
    /// between `base·(1−a)` and `base·(1+a)` over one period.
    pub diurnal_amplitude: f64,
    /// Diurnal period in ticks (`0` disables the sinusoid).
    pub diurnal_period: Duration,
    /// Per-arrival probability of starting a burst episode.
    pub burst_prob: f64,
    /// Rate multiplier while a burst episode is active (≥ 1).
    pub burst_mult: f64,
    /// Mean burst episode length in ticks (exponentially distributed).
    pub burst_mean_len: Duration,
    /// Pareto tail index of job sizes (> 1 keeps the mean finite;
    /// smaller = heavier tail).
    pub work_alpha: f64,
    /// Minimum job work in ticks (the Pareto scale parameter).
    pub work_min: f64,
    /// Hard truncation of job work in ticks (≥ `work_min`).
    pub work_cap: f64,
    /// Number of multi-tenant fairness groups (≥ 1). Jobs are labelled
    /// `t<g>:<shape>` so per-group metrics can be recovered from the
    /// class string alone.
    pub tenants: usize,
    /// Geometric tenant weight ratio: group `g` carries weight
    /// `ratio^g` (1.0 = all tenants equal).
    pub tenant_weight_ratio: f64,
    /// Fraction of jobs carrying an SLO deadline, in [0,1].
    pub deadline_fraction: f64,
    /// Deadline slack: `deadline = arrival + slack × ideal_runtime`
    /// (> 1 for satisfiable SLOs).
    pub deadline_slack: f64,
    /// Protocol adversity preset driving the seeded fault plan:
    /// `none` | `light` | `heavy`.
    pub adversity: String,
    /// Window length (ticks) of the streaming metrics layer's windowed
    /// counters.
    pub metrics_window: Duration,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            jobs: 0,
            seed: 0,
            base_rate_per_sec: 4.0,
            diurnal_amplitude: 0.6,
            diurnal_period: 100_000,
            burst_prob: 0.02,
            burst_mult: 4.0,
            burst_mean_len: 2_000,
            work_alpha: 1.6,
            work_min: 150.0,
            work_cap: 60_000.0,
            tenants: 4,
            tenant_weight_ratio: 2.0,
            deadline_fraction: 0.35,
            deadline_slack: 8.0,
            adversity: "none".into(),
            metrics_window: 5_000,
        }
    }
}

impl ScenarioConfig {
    /// Known adversity preset names.
    pub const ADVERSITY_PRESETS: [&'static str; 3] = ["none", "light", "heavy"];

    /// Whether the scenario harness drives workload generation.
    pub fn enabled(&self) -> bool {
        self.jobs > 0
    }

    /// The scenario seed, falling back to the run seed when unset.
    pub fn seed_or(&self, run_seed: u64) -> u64 {
        if self.seed != 0 {
            self.seed
        } else {
            run_seed
        }
    }

    /// Validate ranges (always checked, so a disabled-but-misspelled
    /// scenario section still surfaces typos).
    pub fn validate(&self) -> anyhow::Result<()> {
        if !Self::ADVERSITY_PRESETS.contains(&self.adversity.as_str()) {
            anyhow::bail!(
                "unknown scenario adversity preset '{}' (want none|light|heavy)",
                self.adversity
            );
        }
        if self.base_rate_per_sec <= 0.0 {
            anyhow::bail!("scenario.base_rate_per_sec must be > 0");
        }
        if !(0.0..1.0).contains(&self.diurnal_amplitude) {
            anyhow::bail!(
                "scenario.diurnal_amplitude must be in [0,1), got {}",
                self.diurnal_amplitude
            );
        }
        if !(0.0..=1.0).contains(&self.burst_prob) {
            anyhow::bail!("scenario.burst_prob must be in [0,1], got {}", self.burst_prob);
        }
        if self.burst_mult < 1.0 {
            anyhow::bail!("scenario.burst_mult must be >= 1, got {}", self.burst_mult);
        }
        if self.burst_prob > 0.0 && self.burst_mean_len == 0 {
            anyhow::bail!("scenario.burst_mean_len must be > 0 when bursts are enabled");
        }
        if self.work_alpha <= 1.0 {
            anyhow::bail!(
                "scenario.work_alpha must be > 1 (finite-mean Pareto tail), got {}",
                self.work_alpha
            );
        }
        if self.work_min < 50.0 {
            anyhow::bail!("scenario.work_min must be >= 50 ticks, got {}", self.work_min);
        }
        if self.work_cap < self.work_min {
            anyhow::bail!("scenario.work_cap must be >= work_min");
        }
        if self.tenants == 0 {
            anyhow::bail!("scenario.tenants must be >= 1");
        }
        if self.tenant_weight_ratio <= 0.0 {
            anyhow::bail!("scenario.tenant_weight_ratio must be > 0");
        }
        if !(0.0..=1.0).contains(&self.deadline_fraction) {
            anyhow::bail!(
                "scenario.deadline_fraction must be in [0,1], got {}",
                self.deadline_fraction
            );
        }
        if self.deadline_fraction > 0.0 && self.deadline_slack <= 1.0 {
            anyhow::bail!("scenario.deadline_slack must be > 1 for satisfiable SLOs");
        }
        if self.metrics_window == 0 {
            anyhow::bail!("scenario.metrics_window must be > 0");
        }
        Ok(())
    }

    fn merge_json(&mut self, v: &Json) -> anyhow::Result<()> {
        for (k, val) in expect_obj(v, "scenario")? {
            match k.as_str() {
                "jobs" => self.jobs = need_u64(val, k)? as usize,
                "seed" => self.seed = need_u64(val, k)?,
                "base_rate_per_sec" => self.base_rate_per_sec = need_f64(val, k)?,
                "diurnal_amplitude" => self.diurnal_amplitude = need_f64(val, k)?,
                "diurnal_period" => self.diurnal_period = need_u64(val, k)?,
                "burst_prob" => self.burst_prob = need_f64(val, k)?,
                "burst_mult" => self.burst_mult = need_f64(val, k)?,
                "burst_mean_len" => self.burst_mean_len = need_u64(val, k)?,
                "work_alpha" => self.work_alpha = need_f64(val, k)?,
                "work_min" => self.work_min = need_f64(val, k)?,
                "work_cap" => self.work_cap = need_f64(val, k)?,
                "tenants" => self.tenants = need_u64(val, k)? as usize,
                "tenant_weight_ratio" => self.tenant_weight_ratio = need_f64(val, k)?,
                "deadline_fraction" => self.deadline_fraction = need_f64(val, k)?,
                "deadline_slack" => self.deadline_slack = need_f64(val, k)?,
                "adversity" => self.adversity = need_str(val, k)?.to_string(),
                "metrics_window" => self.metrics_window = need_u64(val, k)?,
                other => anyhow::bail!("unknown scenario key '{other}'"),
            }
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("jobs", self.jobs.into()),
            ("seed", self.seed.into()),
            ("base_rate_per_sec", self.base_rate_per_sec.into()),
            ("diurnal_amplitude", self.diurnal_amplitude.into()),
            ("diurnal_period", self.diurnal_period.into()),
            ("burst_prob", self.burst_prob.into()),
            ("burst_mult", self.burst_mult.into()),
            ("burst_mean_len", self.burst_mean_len.into()),
            ("work_alpha", self.work_alpha.into()),
            ("work_min", self.work_min.into()),
            ("work_cap", self.work_cap.into()),
            ("tenants", self.tenants.into()),
            ("tenant_weight_ratio", self.tenant_weight_ratio.into()),
            ("deadline_fraction", self.deadline_fraction.into()),
            ("deadline_slack", self.deadline_slack.into()),
            ("adversity", self.adversity.as_str().into()),
            ("metrics_window", self.metrics_window.into()),
        ])
    }
}

/// All JASDA policy parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct JasdaConfig {
    /// Job/system trade-off λ ∈ [0,1] — Eq. (1)/(4); Table 2 sweeps this.
    pub lambda: f64,
    /// Job-side feature weights α.
    pub alpha: AlphaWeights,
    /// System-side feature weights β.
    pub beta: BetaWeights,
    /// Probabilistic safety bound θ — §4.1(a).
    pub theta: f64,
    /// Minimum subjob duration τ_min (ticks) — §4.1.
    pub tau_min: Duration,
    /// Calibration smoothing γ ∈ [0,1] — Eq. (5). 1 = trust declaration.
    pub gamma: f64,
    /// Reliability sensitivity κ > 0 — Eq. (8).
    pub kappa: f64,
    /// Enable ex-ante calibration + ex-post verification (§4.2.1).
    pub calibration: bool,
    /// Enable the age-aware fairness term (§4.3); if false the β_age
    /// weight is ignored.
    pub age_priority: bool,
    /// Waiting time (ticks) at which the age factor A_i(t) saturates at 1.
    pub age_scale: Duration,
    /// Quantile at which jobs declare predicted durations.
    pub duration_quantile: f64,
    /// Window announcement policy.
    pub window_policy: WindowPolicy,
    /// Announce-ahead lead (ticks): windows are announced this far before
    /// their start so jobs have generation time — §5.1(a) mitigation (i).
    pub announce_lead: Duration,
    /// How far ahead (ticks) the scheduler looks for idle windows.
    pub announce_horizon: Duration,
    /// Windows announced (and cleared) per iteration, K ≥ 1. The paper's
    /// prototype uses one window per cycle; K > 1 generalizes §3.1/§3.5
    /// so several slices' gaps clear concurrently per decision round
    /// (fragmentation-aware MIG schedulers show this is what keeps wide
    /// clusters packed). K = 1 reproduces the single-window loop exactly.
    pub announce_k: usize,
    /// Per-slice announcement mode: ignore `announce_k` and announce one
    /// window per slice that currently has a candidate window, so every
    /// free slice is offered for bidding each iteration.
    pub announce_per_slice: bool,
    /// Worker-thread budget for the clearing pipeline's fan-out stages
    /// (variant generation, batched scoring, per-window WIS). `0` = use
    /// the machine's available parallelism; `1` = fully serial. Results
    /// are bit-identical at every setting (the stages are row/window
    /// independent and the cross-window reconciliation merge stays
    /// sequential in announcement order), so this is purely a
    /// latency/throughput knob.
    pub parallel: usize,
    /// Leader shards in the protocol runtime, N ≥ 1. Each shard owns the
    /// slices with `slice % shards == shard`, runs the shared clearing
    /// engine on its own worker pool, and a cross-shard reconciler
    /// (reusing the cross-window conflict rules) keeps the combined round
    /// free of double-awards. `1` = the single leader (decision-identical
    /// to the pre-shard coordinator). Only the protocol runtime reads
    /// this; the in-process scheduler is unaffected.
    pub shards: usize,
    /// Transport carrying leader ↔ agent messages in the protocol
    /// runtime: in-process typed channels (`loopback`), length-prefixed
    /// byte frames through the hand-rolled wire codec (`framed`), or the
    /// same frames over real sockets (`tcp` / `unix`, Unix targets
    /// only), served by one poll-driven leader I/O thread.
    pub transport: TransportKind,
    /// Listen address for the socket transports. For `tcp` a
    /// `host:port` pair (empty = `127.0.0.1:0`, an ephemeral port); for
    /// `unix` a filesystem path (empty = a fresh socket under the
    /// system temp directory, removed on shutdown). Ignored by
    /// `loopback`/`framed`.
    pub listen_addr: String,
    /// Per-connection write-buffer capacity (frames) for the socket
    /// transports' drop-don't-block backpressure, ≥ 1. A frame that
    /// would overflow a slow connection's buffer is dropped and counted
    /// in `sends_dropped`, mirroring the bounded in-process queues.
    pub socket_queue: usize,
    /// Per-round bid-collection deadline in wall-clock milliseconds for
    /// the protocol runtime. `0` (default) = no deadline: the leader
    /// blocks until every delivered announce is answered, the exact
    /// pre-deadline behavior (bit-identity preserved). With a deadline,
    /// a round clears with whatever bids arrived in time; stragglers'
    /// bids for that round are discarded by the round-tag check and the
    /// timeout is counted in `ProtocolOutcome::rounds_timed_out`.
    pub round_timeout_ms: u64,
    /// Deterministic fault injection (off by default); see
    /// [`FaultsConfig`].
    pub faults: FaultsConfig,
    /// Production-scale scenario harness (off by default); see
    /// [`ScenarioConfig`].
    pub scenario: ScenarioConfig,
    /// Bandwidth-lean announcement: cap each shard's broadcast to the
    /// policy's top-N candidate windows (§5.1(a) bandwidth mitigation).
    /// `0` = no cap (broadcast the full candidate set). A shard whose
    /// capped broadcast drew no bids falls back to its full set the next
    /// round, so the cap can never starve a job that only fits an
    /// unranked window.
    pub announce_top: usize,
    /// Max variants a single job may bid **per announced window**
    /// (V_max, §4.6). With `announce_k > 1` or per-slice announcement a
    /// job may bid into each announced window, so its per-iteration
    /// total is bounded by K·V_max.
    pub max_variants_per_job: usize,
    /// FMP discretization bins per variant (T of the scoring kernel).
    pub fmp_bins: usize,
    /// Enable the rolling repack pass (§3.5).
    pub repack: bool,
    /// Extension (EXPERIMENTS.md F6): weight each variant's WIS score by
    /// the fraction of the window it occupies. The paper's sum-based
    /// objective (§4.4) structurally favors many short variants (each
    /// contributes its constant feature terms to the sum); duration
    /// weighting makes the clearing objective approximate score-weighted
    /// *busy time* instead.
    pub duration_weighted_clearing: bool,
    /// Cross-window clearing policy: `greedy` reconciles windows
    /// sequentially in announcement order (the paper's loop, and the
    /// oracle every property test compares against); `exact` solves the
    /// round's job × window conflict graph globally by branch-and-bound,
    /// using the greedy result as incumbent and falling back to it when
    /// the latency budget runs out. K = 1 rounds have no cross-window
    /// constraints, so both modes are bit-identical there.
    pub clearing: ClearingMode,
    /// Wall-clock budget (ms) for the exact clearing solve per round.
    /// When exhausted mid-search the engine commits the best solution
    /// found so far (at worst the greedy incumbent), so `clearing=exact`
    /// can never stall a round past the PR-7 deadline semantics. `0`
    /// skips the search entirely — `exact` then is decision-identical to
    /// `greedy` by construction. Ignored under `clearing=greedy`.
    pub clearing_budget_ms: u64,
    /// Scoring backend (native mirror vs PJRT artifact).
    pub backend: ScoringBackend,
}

impl Default for JasdaConfig {
    fn default() -> Self {
        JasdaConfig {
            lambda: 0.5,
            alpha: AlphaWeights::default(),
            beta: BetaWeights::default(),
            theta: 0.05,
            tau_min: 250,
            gamma: 0.7,
            kappa: 4.0,
            calibration: true,
            age_priority: true,
            age_scale: 30_000,
            duration_quantile: 0.9,
            window_policy: WindowPolicy::EarliestStart,
            announce_lead: 0,
            announce_horizon: 20_000,
            announce_k: 1,
            announce_per_slice: false,
            parallel: 0,
            shards: 1,
            transport: TransportKind::Loopback,
            listen_addr: String::new(),
            socket_queue: 64,
            round_timeout_ms: 0,
            faults: FaultsConfig::default(),
            scenario: ScenarioConfig::default(),
            announce_top: 0,
            max_variants_per_job: 4,
            fmp_bins: 64,
            repack: false,
            duration_weighted_clearing: false,
            clearing: ClearingMode::Greedy,
            clearing_budget_ms: 10,
            backend: ScoringBackend::Native,
        }
    }
}

impl JasdaConfig {
    /// Validate parameter ranges the paper's equations assume.
    pub fn validate(&self) -> anyhow::Result<()> {
        if !(0.0..=1.0).contains(&self.lambda) {
            anyhow::bail!("lambda must be in [0,1], got {}", self.lambda);
        }
        if self.alpha.sum() > 1.0 + 1e-9 {
            anyhow::bail!("alpha weights must sum to <= 1, got {}", self.alpha.sum());
        }
        if self.beta.sum() > 1.0 + 1e-9 {
            anyhow::bail!("beta weights must sum to <= 1, got {}", self.beta.sum());
        }
        if !(0.0..=1.0).contains(&self.theta) {
            anyhow::bail!("theta must be in [0,1], got {}", self.theta);
        }
        if !(0.0..=1.0).contains(&self.gamma) {
            anyhow::bail!("gamma must be in [0,1], got {}", self.gamma);
        }
        if self.kappa <= 0.0 {
            anyhow::bail!("kappa must be > 0, got {}", self.kappa);
        }
        if self.tau_min == 0 {
            anyhow::bail!("tau_min must be > 0 (paper requires tau_min > 0)");
        }
        if !(0.0 < self.duration_quantile && self.duration_quantile < 1.0) {
            anyhow::bail!("duration_quantile must be in (0,1)");
        }
        if self.fmp_bins == 0 || self.max_variants_per_job == 0 {
            anyhow::bail!("fmp_bins and max_variants_per_job must be > 0");
        }
        if self.announce_k == 0 {
            anyhow::bail!("announce_k must be >= 1 (1 = the paper's single-window loop)");
        }
        if self.shards == 0 {
            anyhow::bail!("shards must be >= 1 (1 = the single-leader coordinator)");
        }
        if self.socket_queue == 0 {
            anyhow::bail!("socket_queue must be >= 1 (per-connection write-buffer frames)");
        }
        for (name, p) in [
            ("faults.crash", self.faults.crash),
            ("faults.delay", self.faults.delay),
            ("faults.corrupt", self.faults.corrupt),
            ("faults.drop", self.faults.drop),
        ] {
            if !(0.0..=1.0).contains(&p) {
                anyhow::bail!("{name} must be a probability in [0,1], got {p}");
            }
        }
        if self.faults.enabled() {
            if self.round_timeout_ms == 0 {
                anyhow::bail!(
                    "fault injection requires round_timeout_ms > 0: a crashed agent's \
                     reply never arrives, and only the round deadline keeps collection live"
                );
            }
            if self.faults.horizon_rounds == 0 {
                anyhow::bail!("faults.horizon_rounds must be > 0 when faults are enabled");
            }
        }
        self.scenario.validate()?;
        Ok(())
    }

    /// Expand the scenario's `adversity` preset into concrete
    /// [`FaultsConfig`] probabilities driving the protocol runtime's
    /// seeded `FaultPlan` (agent crashes mid-round, stragglers,
    /// corrupt/shaded bids, dropped sends). Explicitly-set fault
    /// probabilities win over the preset; a preset also supplies the
    /// round deadline fault injection requires if none is configured.
    /// The `heavy` preset mirrors the CI fault matrix's proven-live
    /// plan shape. Call once after loading config, before `validate`.
    pub fn apply_scenario_adversity(&mut self) -> anyhow::Result<()> {
        let (crash, delay, corrupt, drop) = match self.scenario.adversity.as_str() {
            "none" => return Ok(()),
            "light" => (0.15, 0.1, 0.05, 0.05),
            "heavy" => (0.5, 0.25, 0.25, 0.25),
            other => anyhow::bail!(
                "unknown scenario adversity preset '{other}' (want none|light|heavy)"
            ),
        };
        if !self.faults.enabled() {
            self.faults.crash = crash;
            self.faults.delay = delay;
            self.faults.corrupt = corrupt;
            self.faults.drop = drop;
            self.faults.horizon_rounds = 24;
            self.faults.crash_rounds = 8;
            if self.faults.seed == 0 {
                // Derive from the scenario seed so the same trace replays
                // under the same adversity by default.
                self.faults.seed = self.scenario.seed.wrapping_add(1).max(1);
            }
        }
        if self.round_timeout_ms == 0 {
            self.round_timeout_ms = 400;
        }
        Ok(())
    }

    fn merge_json(&mut self, v: &Json) -> anyhow::Result<()> {
        for (k, val) in expect_obj(v, "jasda")? {
            match k.as_str() {
                "lambda" => self.lambda = need_f64(val, k)?,
                "alpha" => self.alpha.merge_json(val)?,
                "beta" => self.beta.merge_json(val)?,
                "theta" => self.theta = need_f64(val, k)?,
                "tau_min" => self.tau_min = need_u64(val, k)?,
                "gamma" => self.gamma = need_f64(val, k)?,
                "kappa" => self.kappa = need_f64(val, k)?,
                "calibration" => self.calibration = need_bool(val, k)?,
                "age_priority" => self.age_priority = need_bool(val, k)?,
                "age_scale" => self.age_scale = need_u64(val, k)?,
                "duration_quantile" => self.duration_quantile = need_f64(val, k)?,
                "window_policy" => {
                    let name = need_str(val, k)?;
                    self.window_policy = WindowPolicy::parse(name)
                        .ok_or_else(|| anyhow::anyhow!("unknown window_policy '{name}'"))?;
                }
                "announce_lead" => self.announce_lead = need_u64(val, k)?,
                "announce_horizon" => self.announce_horizon = need_u64(val, k)?,
                "announce_k" => self.announce_k = need_u64(val, k)? as usize,
                "announce_per_slice" => self.announce_per_slice = need_bool(val, k)?,
                "parallel" => self.parallel = need_u64(val, k)? as usize,
                "shards" => self.shards = need_u64(val, k)? as usize,
                "transport" => {
                    let name = need_str(val, k)?;
                    self.transport = TransportKind::parse(name)
                        .ok_or_else(|| anyhow::anyhow!("unknown transport '{name}'"))?;
                }
                "listen_addr" => self.listen_addr = need_str(val, k)?.to_string(),
                "socket_queue" => self.socket_queue = need_u64(val, k)? as usize,
                "round_timeout_ms" => self.round_timeout_ms = need_u64(val, k)?,
                "faults" => self.faults.merge_json(val)?,
                "scenario" => self.scenario.merge_json(val)?,
                "announce_top" => self.announce_top = need_u64(val, k)? as usize,
                "max_variants_per_job" => {
                    self.max_variants_per_job = need_u64(val, k)? as usize
                }
                "fmp_bins" => self.fmp_bins = need_u64(val, k)? as usize,
                "repack" => self.repack = need_bool(val, k)?,
                "duration_weighted_clearing" => {
                    self.duration_weighted_clearing = need_bool(val, k)?
                }
                "clearing" => {
                    let name = need_str(val, k)?;
                    self.clearing = ClearingMode::parse(name)
                        .ok_or_else(|| anyhow::anyhow!("unknown clearing mode '{name}'"))?;
                }
                "clearing_budget_ms" => self.clearing_budget_ms = need_u64(val, k)?,
                "backend" => {
                    self.backend = match need_str(val, k)? {
                        "native" => ScoringBackend::Native,
                        "pjrt" => ScoringBackend::Pjrt,
                        other => anyhow::bail!("unknown backend '{other}'"),
                    }
                }
                other => anyhow::bail!("unknown jasda key '{other}'"),
            }
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lambda", self.lambda.into()),
            ("alpha", self.alpha.to_json()),
            ("beta", self.beta.to_json()),
            ("theta", self.theta.into()),
            ("tau_min", self.tau_min.into()),
            ("gamma", self.gamma.into()),
            ("kappa", self.kappa.into()),
            ("calibration", self.calibration.into()),
            ("age_priority", self.age_priority.into()),
            ("age_scale", self.age_scale.into()),
            ("duration_quantile", self.duration_quantile.into()),
            ("window_policy", self.window_policy.name().into()),
            ("announce_lead", self.announce_lead.into()),
            ("announce_horizon", self.announce_horizon.into()),
            ("announce_k", self.announce_k.into()),
            ("announce_per_slice", self.announce_per_slice.into()),
            ("parallel", self.parallel.into()),
            ("shards", self.shards.into()),
            ("transport", self.transport.name().into()),
            ("listen_addr", self.listen_addr.as_str().into()),
            ("socket_queue", self.socket_queue.into()),
            ("round_timeout_ms", self.round_timeout_ms.into()),
            ("faults", self.faults.to_json()),
            ("scenario", self.scenario.to_json()),
            ("announce_top", self.announce_top.into()),
            ("max_variants_per_job", self.max_variants_per_job.into()),
            ("fmp_bins", self.fmp_bins.into()),
            ("repack", self.repack.into()),
            ("duration_weighted_clearing", self.duration_weighted_clearing.into()),
            ("clearing", self.clearing.name().into()),
            ("clearing_budget_ms", self.clearing_budget_ms.into()),
            (
                "backend",
                match self.backend {
                    ScoringBackend::Native => "native",
                    ScoringBackend::Pjrt => "pjrt",
                }
                .into(),
            ),
        ])
    }
}

/// Cluster shape.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Number of GPUs.
    pub num_gpus: u32,
    /// Stock partition layout name: `7x1g`, `balanced`, `heterogeneous`,
    /// `whole`.
    pub layout: String,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig { num_gpus: 1, layout: "heterogeneous".into() }
    }
}

impl ClusterConfig {
    fn merge_json(&mut self, v: &Json) -> anyhow::Result<()> {
        for (k, val) in expect_obj(v, "cluster")? {
            match k.as_str() {
                "num_gpus" => self.num_gpus = need_u64(val, k)? as u32,
                "layout" => self.layout = need_str(val, k)?.to_string(),
                other => anyhow::bail!("unknown cluster key '{other}'"),
            }
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("num_gpus", self.num_gpus.into()),
            ("layout", self.layout.clone().into()),
        ])
    }
}

/// Workload generation parameters (details in [`crate::workload`]).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    /// Number of jobs to generate.
    pub num_jobs: usize,
    /// Mean arrival rate in jobs per simulated second.
    pub arrival_rate_per_sec: f64,
    /// Job-class mix weights: (class name, relative weight).
    pub mix: Vec<(String, f64)>,
    /// Fraction of jobs that misreport utilities.
    pub misreport_fraction: f64,
    /// Multiplicative inflation misreporting jobs apply to declared
    /// utilities (e.g. 0.5 declares 1.5× the honest value, clamped).
    pub misreport_bias: f64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            num_jobs: 40,
            arrival_rate_per_sec: 0.15,
            mix: vec![
                ("train_small".into(), 0.3),
                ("train_large".into(), 0.15),
                ("inference_burst".into(), 0.3),
                ("analytics".into(), 0.15),
                ("agri_pipeline".into(), 0.1),
            ],
            misreport_fraction: 0.0,
            misreport_bias: 0.5,
        }
    }
}

impl WorkloadConfig {
    fn merge_json(&mut self, v: &Json) -> anyhow::Result<()> {
        for (k, val) in expect_obj(v, "workload")? {
            match k.as_str() {
                "num_jobs" => self.num_jobs = need_u64(val, k)? as usize,
                "arrival_rate_per_sec" => self.arrival_rate_per_sec = need_f64(val, k)?,
                "misreport_fraction" => self.misreport_fraction = need_f64(val, k)?,
                "misreport_bias" => self.misreport_bias = need_f64(val, k)?,
                "mix" => {
                    let arr = val
                        .as_arr()
                        .ok_or_else(|| anyhow::anyhow!("mix must be an array"))?;
                    let mut mix = Vec::new();
                    for item in arr {
                        let pair = item
                            .as_arr()
                            .filter(|p| p.len() == 2)
                            .ok_or_else(|| anyhow::anyhow!("mix entries are [name, weight]"))?;
                        mix.push((
                            need_str(&pair[0], "mix name")?.to_string(),
                            need_f64(&pair[1], "mix weight")?,
                        ));
                    }
                    self.mix = mix;
                }
                other => anyhow::bail!("unknown workload key '{other}'"),
            }
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("num_jobs", self.num_jobs.into()),
            ("arrival_rate_per_sec", self.arrival_rate_per_sec.into()),
            (
                "mix",
                Json::Arr(
                    self.mix
                        .iter()
                        .map(|(n, w)| Json::Arr(vec![n.clone().into(), (*w).into()]))
                        .collect(),
                ),
            ),
            ("misreport_fraction", self.misreport_fraction.into()),
            ("misreport_bias", self.misreport_bias.into()),
        ])
    }
}

/// Simulation-engine parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineConfig {
    /// Scheduler iteration period in ticks (one announcement per tick).
    pub iteration_period: Duration,
    /// Hard simulated-time stop (safety net against livelock).
    pub max_time: Time,
    /// Compact reservation history older than this many ticks (0 = never).
    pub compact_after: Duration,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { iteration_period: 50, max_time: 50_000_000, compact_after: 200_000 }
    }
}

impl EngineConfig {
    fn merge_json(&mut self, v: &Json) -> anyhow::Result<()> {
        for (k, val) in expect_obj(v, "engine")? {
            match k.as_str() {
                "iteration_period" => self.iteration_period = need_u64(val, k)?,
                "max_time" => self.max_time = need_u64(val, k)?,
                "compact_after" => self.compact_after = need_u64(val, k)?,
                other => anyhow::bail!("unknown engine key '{other}'"),
            }
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("iteration_period", self.iteration_period.into()),
            ("max_time", self.max_time.into()),
            ("compact_after", self.compact_after.into()),
        ])
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SimConfig {
    /// Master RNG seed; a run is fully reproducible from this.
    pub seed: u64,
    /// Cluster shape.
    pub cluster: ClusterConfig,
    /// Engine parameters.
    pub engine: EngineConfig,
    /// JASDA policy parameters.
    pub jasda: JasdaConfig,
    /// Workload generation.
    pub workload: WorkloadConfig,
}

impl SimConfig {
    /// Load from a JSON config file. Missing fields keep their defaults;
    /// unknown keys are rejected so typos surface immediately.
    pub fn from_json_file(path: &std::path::Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading config {}: {e}", path.display()))?;
        let cfg = Self::from_json_str(&text)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse from JSON text (defaults fill missing fields).
    pub fn from_json_str(text: &str) -> anyhow::Result<Self> {
        let v = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = SimConfig::default();
        for (key, val) in expect_obj(&v, "top level")? {
            match key.as_str() {
                "seed" => cfg.seed = need_u64(val, "seed")?,
                "cluster" => cfg.cluster.merge_json(val)?,
                "engine" => cfg.engine.merge_json(val)?,
                "jasda" => cfg.jasda.merge_json(val)?,
                "workload" => cfg.workload.merge_json(val)?,
                other => anyhow::bail!("unknown config key '{other}'"),
            }
        }
        Ok(cfg)
    }

    /// Serialize to JSON (round-trips through [`Self::from_json_str`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", self.seed.into()),
            ("cluster", self.cluster.to_json()),
            ("engine", self.engine.to_json()),
            ("jasda", self.jasda.to_json()),
            ("workload", self.workload.to_json()),
        ])
    }

    /// Validate all sections.
    pub fn validate(&self) -> anyhow::Result<()> {
        self.jasda.validate()?;
        if crate::mig::PartitionLayout::stock(&self.cluster.layout).is_none() {
            anyhow::bail!("unknown partition layout '{}'", self.cluster.layout);
        }
        if self.cluster.num_gpus == 0 {
            anyhow::bail!("num_gpus must be > 0");
        }
        if self.workload.arrival_rate_per_sec <= 0.0 {
            anyhow::bail!("arrival_rate_per_sec must be > 0");
        }
        if self.engine.iteration_period == 0 {
            anyhow::bail!("iteration_period must be > 0");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        SimConfig::default().validate().unwrap();
    }

    #[test]
    fn default_weights_sum_leq_one() {
        assert!(AlphaWeights::default().sum() <= 1.0 + 1e-12);
        assert!(BetaWeights::default().sum() <= 1.0 + 1e-12);
    }

    #[test]
    fn json_round_trip() {
        let mut cfg = SimConfig::default();
        cfg.seed = 1234;
        cfg.jasda.window_policy = WindowPolicy::SlackAware;
        cfg.jasda.backend = ScoringBackend::Pjrt;
        cfg.jasda.announce_k = 3;
        cfg.jasda.announce_per_slice = true;
        cfg.jasda.parallel = 4;
        cfg.jasda.shards = 3;
        cfg.jasda.transport = TransportKind::Framed;
        cfg.jasda.listen_addr = "127.0.0.1:7070".into();
        cfg.jasda.socket_queue = 8;
        cfg.jasda.announce_top = 2;
        cfg.jasda.round_timeout_ms = 250;
        cfg.jasda.clearing = ClearingMode::Exact;
        cfg.jasda.clearing_budget_ms = 25;
        cfg.jasda.faults.seed = 99;
        cfg.jasda.faults.crash = 0.25;
        cfg.jasda.faults.delay_rounds = 5;
        cfg.jasda.scenario.jobs = 50_000;
        cfg.jasda.scenario.seed = 77;
        cfg.jasda.scenario.tenants = 6;
        cfg.jasda.scenario.work_alpha = 1.3;
        cfg.jasda.scenario.adversity = "heavy".into();
        cfg.jasda.scenario.metrics_window = 2_500;
        cfg.workload.mix = vec![("analytics".into(), 1.0)];
        let text = cfg.to_json().to_string_pretty();
        let back = SimConfig::from_json_str(&text).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let cfg =
            SimConfig::from_json_str(r#"{"seed": 7, "jasda": {"lambda": 0.3}}"#).unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.jasda.lambda, 0.3);
        assert_eq!(cfg.jasda.theta, JasdaConfig::default().theta);
        assert_eq!(cfg.cluster, ClusterConfig::default());
    }

    #[test]
    fn unknown_keys_rejected() {
        assert!(SimConfig::from_json_str(r#"{"sede": 7}"#).is_err());
        assert!(SimConfig::from_json_str(r#"{"jasda": {"lambada": 0.3}}"#).is_err());
        assert!(SimConfig::from_json_str(r#"{"jasda": {"window_policy": "bogus"}}"#).is_err());
        assert!(SimConfig::from_json_str(r#"{"jasda": {"transport": "pigeon"}}"#).is_err());
        assert!(SimConfig::from_json_str(r#"{"jasda": {"clearing": "simplex"}}"#).is_err());
        assert!(SimConfig::from_json_str(r#"{"jasda": {"faults": {"crush": 1}}}"#).is_err());
        assert!(SimConfig::from_json_str(r#"{"jasda": {"scenario": {"jbos": 9}}}"#).is_err());
        assert!(SimConfig::from_json_str(r#"{"workload": {"mix": [["a"]]}}"#).is_err());
    }

    #[test]
    fn window_policy_name_round_trip() {
        for p in WindowPolicy::ALL {
            assert_eq!(WindowPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(WindowPolicy::parse("zzz"), None);
    }

    #[test]
    fn transport_kind_name_round_trip() {
        for t in TransportKind::ALL {
            assert_eq!(TransportKind::parse(t.name()), Some(t));
        }
        assert_eq!(TransportKind::parse("zzz"), None);
    }

    #[test]
    fn clearing_mode_name_round_trip() {
        for m in ClearingMode::ALL {
            assert_eq!(ClearingMode::parse(m.name()), Some(m));
        }
        assert_eq!(ClearingMode::parse("lp"), None);
        assert_eq!(ClearingMode::default(), ClearingMode::Greedy);
    }

    #[test]
    fn invalid_params_rejected() {
        let mut cfg = SimConfig::default();
        cfg.jasda.lambda = 1.5;
        assert!(cfg.validate().is_err());

        let mut cfg = SimConfig::default();
        cfg.jasda.alpha.jct = 0.9; // pushes sum over 1
        assert!(cfg.validate().is_err());

        let mut cfg = SimConfig::default();
        cfg.jasda.tau_min = 0;
        assert!(cfg.validate().is_err());

        let mut cfg = SimConfig::default();
        cfg.cluster.layout = "nonsense".into();
        assert!(cfg.validate().is_err());

        let mut cfg = SimConfig::default();
        cfg.jasda.kappa = 0.0;
        assert!(cfg.validate().is_err());

        let mut cfg = SimConfig::default();
        cfg.jasda.announce_k = 0;
        assert!(cfg.validate().is_err());

        let mut cfg = SimConfig::default();
        cfg.jasda.shards = 0;
        assert!(cfg.validate().is_err());

        let mut cfg = SimConfig::default();
        cfg.jasda.gamma = -0.1;
        assert!(cfg.validate().is_err());

        // Fault injection without a round deadline would wedge collection.
        let mut cfg = SimConfig::default();
        cfg.jasda.faults.crash = 0.5;
        assert!(cfg.validate().is_err());
        cfg.jasda.round_timeout_ms = 100;
        cfg.validate().unwrap();
        cfg.jasda.faults.horizon_rounds = 0;
        assert!(cfg.validate().is_err());

        let mut cfg = SimConfig::default();
        cfg.jasda.faults.corrupt = 1.5; // not a probability
        cfg.jasda.round_timeout_ms = 100;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn scenario_validation_and_adversity_presets() {
        // Range checks surface even with the harness disabled (jobs=0).
        let mut cfg = SimConfig::default();
        cfg.jasda.scenario.adversity = "chaos".into();
        assert!(cfg.validate().is_err());

        let mut cfg = SimConfig::default();
        cfg.jasda.scenario.work_alpha = 1.0; // infinite-mean tail
        assert!(cfg.validate().is_err());

        let mut cfg = SimConfig::default();
        cfg.jasda.scenario.diurnal_amplitude = 1.0; // rate would hit 0
        assert!(cfg.validate().is_err());

        let mut cfg = SimConfig::default();
        cfg.jasda.scenario.work_cap = 10.0; // below work_min
        assert!(cfg.validate().is_err());

        let mut cfg = SimConfig::default();
        cfg.jasda.scenario.tenants = 0;
        assert!(cfg.validate().is_err());

        let mut cfg = SimConfig::default();
        cfg.jasda.scenario.metrics_window = 0;
        assert!(cfg.validate().is_err());

        // "none" is a no-op.
        let mut cfg = SimConfig::default();
        cfg.jasda.apply_scenario_adversity().unwrap();
        assert!(!cfg.jasda.faults.enabled());
        assert_eq!(cfg.jasda.round_timeout_ms, 0);

        // A preset turns faults on and supplies the required deadline,
        // producing a config that validates as-is.
        let mut cfg = SimConfig::default();
        cfg.jasda.scenario.adversity = "light".into();
        cfg.jasda.apply_scenario_adversity().unwrap();
        assert!(cfg.jasda.faults.enabled());
        assert!(cfg.jasda.faults.seed > 0);
        assert!(cfg.jasda.round_timeout_ms > 0);
        cfg.validate().unwrap();

        // Explicit fault probabilities win over the preset.
        let mut cfg = SimConfig::default();
        cfg.jasda.scenario.adversity = "heavy".into();
        cfg.jasda.faults.crash = 0.01;
        cfg.jasda.apply_scenario_adversity().unwrap();
        assert_eq!(cfg.jasda.faults.crash, 0.01);
        assert_eq!(cfg.jasda.faults.drop, 0.0);
        cfg.validate().unwrap();
    }

    #[test]
    fn from_json_file_missing_path_errors() {
        let r = SimConfig::from_json_file(std::path::Path::new("/nonexistent/x.json"));
        assert!(r.is_err());
    }
}
