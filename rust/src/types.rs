//! Core scalar types shared across the crate.
//!
//! All simulated time is measured in integer **ticks**; one tick is one
//! millisecond of simulated wall-clock time. Memory is measured in **GiB**
//! as `f64` (MIG slice capacities are 5/10/20/40 GiB on A100-class parts).
//! Compute capacity is measured in **sevenths** of a full GPU, matching the
//! NVIDIA MIG compute-slice granularity (a 7g profile owns the whole GPU).


/// Simulated time in ticks (1 tick = 1 ms of simulated time).
pub type Time = u64;

/// Simulated duration in ticks.
pub type Duration = u64;

/// Unique job identifier, assigned at arrival in admission order.
pub type JobId = u32;

/// Identifier of a MIG slice, unique across the whole cluster.
pub type SliceId = u32;

/// Identifier of a physical GPU in the cluster.
pub type GpuId = u32;

/// Identifier of a variant within one scheduling iteration's bid pool.
pub type VariantId = u32;

/// Convert ticks to (simulated) seconds.
#[inline]
pub fn ticks_to_secs(t: Time) -> f64 {
    t as f64 / 1000.0
}

/// Convert (simulated) seconds to ticks, rounding to the nearest tick.
#[inline]
pub fn secs_to_ticks(s: f64) -> Time {
    (s * 1000.0).round().max(0.0) as Time
}

/// A half-open time interval `[start, end)` on a slice timeline.
///
/// Empty intervals (`start >= end`) are permitted as degenerate values but
/// never stored in timelines.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Interval {
    /// Inclusive start tick.
    pub start: Time,
    /// Exclusive end tick.
    pub end: Time,
}

impl Interval {
    /// Create a new interval; callers must ensure `start <= end`.
    #[inline]
    pub fn new(start: Time, end: Time) -> Self {
        debug_assert!(start <= end, "interval start {start} > end {end}");
        Interval { start, end }
    }

    /// Length of the interval in ticks.
    #[inline]
    pub fn len(&self) -> Duration {
        self.end.saturating_sub(self.start)
    }

    /// True if the interval contains no ticks.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.start >= self.end
    }

    /// True if `self` and `other` share at least one tick.
    ///
    /// Half-open semantics: `[0,10)` and `[10,20)` do **not** overlap —
    /// exactly the compatibility rule the WIS clearing phase uses
    /// (paper §4.4 constraint (i)).
    #[inline]
    pub fn overlaps(&self, other: &Interval) -> bool {
        self.start < other.end && other.start < self.end
    }

    /// True if `self` fully contains `other`.
    #[inline]
    pub fn contains(&self, other: &Interval) -> bool {
        self.start <= other.start && other.end <= self.end
    }

    /// True if tick `t` lies inside the interval.
    #[inline]
    pub fn contains_tick(&self, t: Time) -> bool {
        self.start <= t && t < self.end
    }

    /// Intersection of two intervals, or `None` if they do not overlap.
    pub fn intersect(&self, other: &Interval) -> Option<Interval> {
        let s = self.start.max(other.start);
        let e = self.end.min(other.end);
        if s < e {
            Some(Interval::new(s, e))
        } else {
            None
        }
    }
}

impl std::fmt::Display for Interval {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}, {})", self.start, self.end)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interval_len_and_empty() {
        assert_eq!(Interval::new(5, 15).len(), 10);
        assert!(Interval::new(7, 7).is_empty());
        assert!(!Interval::new(7, 8).is_empty());
    }

    #[test]
    fn interval_overlap_half_open() {
        let a = Interval::new(0, 10);
        let b = Interval::new(10, 20);
        let c = Interval::new(9, 11);
        assert!(!a.overlaps(&b), "adjacent half-open intervals must not overlap");
        assert!(a.overlaps(&c));
        assert!(b.overlaps(&c));
        assert!(c.overlaps(&a));
    }

    #[test]
    fn interval_contains() {
        let outer = Interval::new(0, 100);
        assert!(outer.contains(&Interval::new(0, 100)));
        assert!(outer.contains(&Interval::new(10, 90)));
        assert!(!outer.contains(&Interval::new(10, 101)));
        assert!(outer.contains_tick(0));
        assert!(outer.contains_tick(99));
        assert!(!outer.contains_tick(100));
    }

    #[test]
    fn interval_intersect() {
        let a = Interval::new(0, 10);
        assert_eq!(a.intersect(&Interval::new(5, 15)), Some(Interval::new(5, 10)));
        assert_eq!(a.intersect(&Interval::new(10, 15)), None);
        assert_eq!(a.intersect(&Interval::new(2, 4)), Some(Interval::new(2, 4)));
    }

    #[test]
    fn tick_conversions_round_trip() {
        assert_eq!(ticks_to_secs(1500), 1.5);
        assert_eq!(secs_to_ticks(1.5), 1500);
        assert_eq!(secs_to_ticks(ticks_to_secs(123_456)), 123_456);
        assert_eq!(secs_to_ticks(-1.0), 0, "negative seconds clamp to zero");
    }
}
