//! The shard layer of the protocol runtime: N leader shards, each
//! owning a disjoint slice subset, plus the cross-shard reconciler.
//!
//! Slices are striped across shards by [`shard_of`] (`slice % shards`).
//! Each shard carries its own [`WindowSelector`] (policy state such as
//! the round-robin cursor is per-shard), its own [`ClearingEngine`]
//! scratch, its own scorer, and its own [`WorkerPool`] slice of the
//! configured `jasda.parallel` budget — shards share *nothing* mutable,
//! which is what makes the decision phase embarrassingly shardable.
//!
//! What shards cannot decide alone is job-level consistency: a job may
//! win in shard 0 and have an overlapping variant pending in shard 2.
//! The [`ShardReconciler`] closes that hole by replaying the *identical*
//! cross-window conflict rules
//! ([`conflicts_with_accepted`](crate::jasda::clearing::conflicts_with_accepted))
//! across shard boundaries: shards decide sequentially in shard order,
//! every acceptance is recorded, and later shards' bid pools are
//! pre-filtered against the record before their clearing runs. Within a
//! shard the engine's own reconciliation still applies, so the union of
//! both layers enforces exactly the single-leader invariants — the
//! property tests assert `shards=1` is decision-identical to the
//! pre-shard coordinator and `shards=N` never commits a conflict the
//! single leader would have caught.
//!
//! Partial bid sets need no special handling here: under a round
//! deadline (`jasda.round_timeout_ms`) or agent faults, some agents'
//! portfolios are simply absent from `bids_by_slot` when the shards
//! decide, which is indistinguishable from those agents bidding empty —
//! each shard clears whatever arrived, and the reconciler's predicate
//! is per-award, so cross-shard conflict-freedom holds for any subset
//! of bidders (the fault-injection property tests assert this under
//! randomized crash/straggler plans).
//!
//! Clearing policy composes the same way: under `jasda.clearing =
//! "exact"` each shard's engine emits exactly one final solution (the
//! branch-and-bound result, or its greedy incumbent on budget
//! exhaustion) through `on_accept`, and those are the only awards the
//! leader commits here — so the cross-shard record always reflects the
//! same global decision the shard made, never a provisional greedy pass
//! the solver later replaced.

use crate::jasda::clearing::{conflicts_with_accepted, variant_key, AwardKey, ClearingEngine};
use crate::jasda::pool::WorkerPool;
use crate::jasda::scoring::NativeScorer;
pub use crate::jasda::window::shard_of;
use crate::jasda::window::WindowSelector;
use crate::job::Variant;

/// One leader shard's private decision state.
pub(super) struct LeaderShard {
    /// Policy state (round-robin cursor, fragmentation scratch).
    pub selector: WindowSelector,
    /// Clearing scratch buffers.
    pub engine: ClearingEngine,
    /// Scoring backend.
    pub scorer: NativeScorer,
    /// This shard's slice of the worker budget.
    pub wpool: WorkerPool,
    /// Whether this shard's previous *capped* broadcast drew no bid
    /// variants — the `announce_top` silence-fallback latch: when set,
    /// the next round broadcasts the shard's full candidate set.
    pub last_round_silent: bool,
}

/// Build `shards` leader shards, splitting the resolved `jasda.parallel`
/// worker budget evenly (each shard gets at least 1). With one shard
/// this is the exact pre-shard configuration: one selector, one engine,
/// one pool with the full budget.
pub(super) fn make_shards(shards: usize, parallel: usize) -> Vec<LeaderShard> {
    let n = shards.max(1);
    let per_shard = (WorkerPool::resolve_budget(parallel) / n).max(1);
    (0..n)
        .map(|_| LeaderShard {
            selector: WindowSelector::new(),
            engine: ClearingEngine::new(),
            scorer: NativeScorer,
            wpool: WorkerPool::new(per_shard),
            last_round_silent: false,
        })
        .collect()
}

/// Cross-shard award record for one round: the same `(job, interval,
/// work-range)` tuples the clearing engine reconciles windows with,
/// promoted to shard scope.
#[derive(Debug, Default)]
pub struct ShardReconciler {
    accepted: Vec<AwardKey>,
}

impl ShardReconciler {
    /// Empty reconciler.
    pub fn new() -> Self {
        Self::default()
    }

    /// Forget the previous round's awards.
    pub fn begin_round(&mut self) {
        self.accepted.clear();
    }

    /// Would `v` violate a conflict rule against an earlier shard's
    /// award this round? (Exactly the engine's cross-window predicate.)
    pub fn conflicts(&self, v: &Variant) -> bool {
        conflicts_with_accepted(&self.accepted, v)
    }

    /// Record an accepted variant so later shards filter against it.
    pub fn commit(&mut self, v: &Variant) {
        self.accepted.push(variant_key(v));
    }

    /// Awards recorded this round.
    pub fn len(&self) -> usize {
        self.accepted.len()
    }

    /// Whether no award has been recorded this round.
    pub fn is_empty(&self) -> bool {
        self.accepted.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::variants::{DeclaredFeatures, SysFeatures};
    use crate::trp::Fmp;
    use crate::types::Interval;
    use std::sync::Arc;

    fn v(job: u32, start: u64, end: u64, work_offset: f64, work: f64) -> Variant {
        Variant {
            id: 0,
            job,
            slice: 0,
            interval: Interval::new(start, end),
            work,
            work_offset,
            fmp: Arc::new(Fmp { mu: vec![1.0], sigma: vec![0.1] }),
            violation_prob: 0.0,
            declared: DeclaredFeatures {
                phi_honest: [0.0; 4],
                phi: [0.0; 4],
                h_tilde: 0.0,
            },
            sys: SysFeatures { util: 0.0, frag: 0.0 },
        }
    }

    #[test]
    fn shard_of_stripes_slices() {
        assert_eq!(shard_of(0, 2), 0);
        assert_eq!(shard_of(1, 2), 1);
        assert_eq!(shard_of(2, 2), 0);
        assert_eq!(shard_of(5, 1), 0);
        assert_eq!(shard_of(5, 0), 0, "degenerate shard count maps to shard 0");
    }

    #[test]
    fn reconciler_blocks_overlapping_interval_same_job_only() {
        let mut r = ShardReconciler::new();
        r.begin_round();
        r.commit(&v(1, 100, 200, 0.0, 50.0));
        // Same job, overlapping time, disjoint work range: conflict.
        assert!(r.conflicts(&v(1, 150, 250, 100.0, 50.0)));
        // Same job, disjoint time, overlapping work range: conflict.
        assert!(r.conflicts(&v(1, 300, 400, 25.0, 50.0)));
        // Same job, disjoint time and work: no conflict.
        assert!(!r.conflicts(&v(1, 300, 400, 50.0, 50.0)));
        // Different job, same everything: no conflict.
        assert!(!r.conflicts(&v(2, 150, 250, 0.0, 50.0)));
    }

    #[test]
    fn reconciler_resets_between_rounds() {
        let mut r = ShardReconciler::new();
        r.commit(&v(1, 0, 10, 0.0, 5.0));
        assert_eq!(r.len(), 1);
        r.begin_round();
        assert!(r.is_empty());
        assert!(!r.conflicts(&v(1, 0, 10, 0.0, 5.0)));
    }

    #[test]
    fn make_shards_splits_budget() {
        let shards = make_shards(4, 8);
        assert_eq!(shards.len(), 4);
        for s in &shards {
            assert_eq!(s.wpool.budget(), 2);
        }
        // More shards than workers: every shard still gets a serial pool.
        let shards = make_shards(4, 2);
        for s in &shards {
            assert_eq!(s.wpool.budget(), 1);
        }
    }
}
