//! Real-socket transport for the protocol runtime: the [`wire`] frames
//! of [`FramedTransport`](super::transport::FramedTransport) carried
//! over TCP or Unix-domain sockets (`jasda.transport = "tcp" | "unix"`).
//!
//! The protocol is unchanged — only the I/O moves. Agents connect to
//! the leader's listener, identify themselves with a 4-byte
//! little-endian agent-index hello, and then exchange the exact
//! length-prefixed frames the framed transport exchanges over channels.
//! Decisions stay bit-identical to
//! [`LoopbackTransport`](super::transport::LoopbackTransport)
//! (`tests/properties.rs` asserts it): the codec round-trips every
//! field exactly, the spawn barrier delivers round 0 to every agent,
//! and the leader collects bids by slot, so arrival order is
//! irrelevant.
//!
//! # One poll loop, not a thread per agent
//!
//! The leader side runs a **single** I/O thread that serves every
//! connection from one `poll(2)` readiness loop — no blocking read per
//! agent, which is what lets one leader hold a thousand agent sockets
//! (the ROADMAP's 10k-agent target is a listener away, not a thread
//! pool away). Per connection the thread keeps:
//!
//! - a [`wire::FrameReader`] reassembling frames from partial reads —
//!   the same single validation path (`wire::frame_len`) the framed
//!   transport uses, so there is no second codec to drift;
//! - a bounded write buffer (`jasda.socket_queue` frames) with a
//!   partial-write cursor: the leader's send path only ever *enqueues*,
//!   and a frame that would overflow a slow connection's buffer is
//!   dropped and reported (`sends_dropped`) — drop-don't-block, exactly
//!   the in-process backpressure contract.
//!
//! A wake pipe (socketpair) gets one byte after every enqueue, so the
//! poll loop never waits on a timeout to notice work: leader sends and
//! agent replies both land on the next loop pass.
//!
//! # Failure semantics
//!
//! - A reply stream that desynchronizes (bad length prefix) surfaces as
//!   [`Recv::Rejected`] for that agent — feeding the leader's
//!   quarantine streak — and the connection is closed; a frame that
//!   arrives intact but fails decode is likewise `Rejected`.
//! - A disconnected agent's sends fail until it reconnects, which marks
//!   it dirty on the leader and routes it through the existing
//!   `Resync` re-admission path. Reconnects re-identify with the same
//!   hello; buffered frames from the dead connection are discarded
//!   (they were lost on the wire).
//! - [`Transport::recv_deadline`] routes through the shared
//!   `recv_deadline_on` helper, so the pinned already-expired deadline
//!   semantics are identical across transports.
//!
//! # Fault injection at the socket layer
//!
//! The seeded [`FaultPlan`] applies directly to the connections instead
//! of through a `FaultyTransport` wrapper, so the PR-7 property suite
//! runs unmodified against real sockets:
//!
//! - **crash** = close the connection (flushing first when the plan
//!   says the announce still lands) and refuse the agent's reconnect
//!   hello until the crash window passes;
//! - **corrupt** = flip a byte on the received stream (the frame's tag
//!   byte), so the real decode path rejects it;
//! - **delay** = buffer the received reply frame at the socket boundary
//!   and release it rounds later, when the round-tag check discards it
//!   as stale;
//! - **drop** = lose one leader→agent frame before it is written.
//!
//! The plan's round index tracks the leader's announces (an atomic
//! updated on every `Announce` send), mirroring how `FaultyTransport`
//! learns the round by peeking at outgoing messages.

use super::faults::FaultPlan;
use super::messages::ToAgent;
use super::transport::{recv_deadline_on, Recv, RecvEnd, Transport};
use super::wire;
use crate::config::{JasdaConfig, TransportKind};
use crate::job::Job;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

// Minimal poll(2) binding — the only libc surface this module needs,
// declared by hand because the crate is std-only.
#[repr(C)]
struct PollFd {
    fd: RawFd,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

#[cfg(target_os = "macos")]
type NFds = std::os::raw::c_uint;
#[cfg(not(target_os = "macos"))]
type NFds = std::os::raw::c_ulong;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NFds, timeout: i32) -> i32;
}

/// Poll-loop pass timeout (ms). The wake pipe makes the loop reactive;
/// the timeout only bounds how late a stop flag or a held straggler
/// release can be noticed when nothing else is happening.
const POLL_TIMEOUT_MS: i32 = 100;
/// Agent-endpoint blocking-read timeout: how often a parked agent
/// re-checks the stop flag.
const AGENT_READ_TIMEOUT: Duration = Duration::from_millis(25);
/// Agent reconnect retry pause.
const RECONNECT_PAUSE: Duration = Duration::from_millis(5);
/// How long [`Transport::shutdown`] waits for queued `Shutdown`
/// frames to flush before tearing the I/O thread down.
const SHUTDOWN_FLUSH: Duration = Duration::from_millis(500);
/// Spawn-barrier limit: every agent must have said hello by then.
const CONNECT_BARRIER: Duration = Duration::from_secs(30);

/// Distinguishes concurrently running transports' default Unix socket
/// paths within one process (tests run many in parallel).
static SOCK_SEQ: AtomicU64 = AtomicU64::new(0);

enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener),
}

impl Listener {
    fn as_raw_fd(&self) -> RawFd {
        match self {
            Listener::Tcp(l) => l.as_raw_fd(),
            Listener::Unix(l) => l.as_raw_fd(),
        }
    }

    fn accept(&self) -> std::io::Result<Conn> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                let _ = s.set_nodelay(true);
                Ok(Conn::Tcp(s))
            }
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                Ok(Conn::Unix(s))
            }
        }
    }
}

/// Where agent endpoints connect.
#[derive(Clone)]
enum ConnectTo {
    Tcp(SocketAddr),
    Unix(PathBuf),
}

impl ConnectTo {
    fn connect(&self) -> std::io::Result<Conn> {
        match self {
            ConnectTo::Tcp(addr) => {
                let s = TcpStream::connect(addr)?;
                let _ = s.set_nodelay(true);
                Ok(Conn::Tcp(s))
            }
            ConnectTo::Unix(path) => UnixStream::connect(path).map(Conn::Unix),
        }
    }
}

/// One stream of either family, so the poll loop and the agent
/// endpoints are written once.
enum Conn {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Conn {
    fn as_raw_fd(&self) -> RawFd {
        match self {
            Conn::Tcp(s) => s.as_raw_fd(),
            Conn::Unix(s) => s.as_raw_fd(),
        }
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_nonblocking(nb),
            Conn::Unix(s) => s.set_nonblocking(nb),
        }
    }

    fn set_read_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(t),
            Conn::Unix(s) => s.set_read_timeout(t),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            Conn::Unix(s) => s.flush(),
        }
    }
}

/// Per-agent bounded write buffer, shared between the leader's send
/// path (enqueue) and the I/O thread (drain).
#[derive(Default)]
struct SendQueue {
    /// The agent has a live, identified connection. Sends to a dead
    /// agent fail immediately (→ dirty-mark → `Resync` on reconnect).
    alive: bool,
    /// Close this connection once `frames` is flushed (crash
    /// injection; set with `frames` cleared for an immediate close).
    kill: bool,
    /// Encoded frames awaiting the socket.
    frames: VecDeque<Vec<u8>>,
}

/// State shared between the leader handle, the I/O thread, and the
/// agent endpoints.
struct Shared {
    queues: Vec<Mutex<SendQueue>>,
    /// Per-connection write-buffer capacity (frames).
    cap: usize,
    /// Current round, learned from outgoing `Announce`s — indexes the
    /// fault plan, exactly as `FaultyTransport` tracks it.
    round: AtomicU64,
    /// Tear-down flag: the I/O thread and every agent endpoint exit.
    stop: AtomicBool,
    /// Agents that have said hello at least once (spawn barrier).
    connected: AtomicUsize,
    /// Reply-side fault plan (crash swallows, delays, corruption),
    /// applied by the I/O thread as frames arrive.
    reply_faults: Mutex<FaultPlan>,
}

/// What the I/O thread hands the leader per received frame.
enum IoEvent {
    /// A complete frame from `agent` (possibly corrupted by the plan).
    Frame(usize, Vec<u8>),
    /// `agent`'s stream desynchronized (bad length prefix); the
    /// connection was closed. Surfaces as [`Recv::Rejected`].
    Desync(usize),
}

/// Leader-side state for one live connection in the poll loop.
struct ConnState {
    conn: Conn,
    reader: wire::FrameReader,
    /// Partially written frame and its cursor.
    in_flight: Option<(Vec<u8>, usize)>,
}

/// An accepted connection whose 4-byte hello has not fully arrived.
struct Pending {
    conn: Conn,
    hello: [u8; 4],
    got: usize,
}

/// TCP / Unix-domain-socket [`Transport`]: one poll-driven leader I/O
/// thread, one endpoint thread per agent. See the module docs.
pub struct SocketTransport {
    n: usize,
    shared: Arc<Shared>,
    replies: mpsc::Receiver<IoEvent>,
    /// Write end of the wake pipe (nonblocking; a full pipe means the
    /// I/O thread already has a wake pending).
    wake: UnixStream,
    io_handle: Option<JoinHandle<()>>,
    agent_handles: Vec<JoinHandle<()>>,
    /// Send-side fault plan (crash windows, one-shot drops).
    plan: FaultPlan,
    /// Reused encode buffer (a broadcast encodes once).
    scratch: Vec<u8>,
    frames_rejected: u64,
    /// Default Unix socket path to unlink on shutdown.
    unix_path: Option<PathBuf>,
    shut: bool,
}

impl SocketTransport {
    /// Bind the listener, start the I/O thread, spawn one endpoint
    /// thread per job, and block until every agent has said hello —
    /// the barrier that makes round 0 reach everyone, which (with
    /// ample queues) is what keeps healthy socket runs bit-identical
    /// to loopback. `cfg.transport` picks TCP vs Unix; `plan` is the
    /// seeded fault schedule (empty = no adversity).
    ///
    /// Panics when the listen address cannot be bound — the protocol
    /// runtime has no error path, and an unusable address is a
    /// configuration mistake, not a runtime condition.
    pub fn spawn(jobs: Vec<Job>, cfg: &JasdaConfig, plan: FaultPlan) -> SocketTransport {
        let n = jobs.len();
        let (listener, target, unix_path) = bind(cfg);
        let shared = Arc::new(Shared {
            queues: (0..n).map(|_| Mutex::new(SendQueue::default())).collect(),
            cap: cfg.socket_queue.max(1),
            round: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            connected: AtomicUsize::new(0),
            reply_faults: Mutex::new(plan.clone()),
        });
        let (reply_tx, replies) = mpsc::channel();
        let (wake, wake_rx) = UnixStream::pair().expect("wake socketpair");
        wake.set_nonblocking(true).expect("nonblocking wake");
        wake_rx.set_nonblocking(true).expect("nonblocking wake");

        let io_shared = Arc::clone(&shared);
        let io_handle = Some(std::thread::spawn(move || {
            io_loop(io_shared, listener, wake_rx, reply_tx);
        }));

        let mut agent_handles = Vec::with_capacity(n);
        for (agent, job) in jobs.into_iter().enumerate() {
            let jcfg = cfg.clone();
            let target = target.clone();
            let sh = Arc::clone(&shared);
            agent_handles.push(std::thread::spawn(move || {
                agent_endpoint(agent, job, jcfg, target, sh);
            }));
        }

        let t0 = Instant::now();
        while shared.connected.load(Ordering::SeqCst) < n {
            assert!(
                t0.elapsed() < CONNECT_BARRIER,
                "socket transport: {}/{} agents connected within {CONNECT_BARRIER:?}",
                shared.connected.load(Ordering::SeqCst),
                n
            );
            std::thread::sleep(Duration::from_millis(1));
        }

        SocketTransport {
            n,
            shared,
            replies,
            wake,
            io_handle,
            agent_handles,
            plan,
            scratch: Vec::new(),
            frames_rejected: 0,
            unix_path,
            shut: false,
        }
    }

    fn wake(&self) {
        // WouldBlock = the pipe is full = a wake is already pending.
        let _ = (&self.wake).write(&[1]);
    }

    /// Enqueue one already-encoded frame for `agent`, applying the
    /// send-side fault plan. Returns `false` when the frame was not
    /// queued (dead agent, full buffer, or an injected fault).
    fn enqueue(&mut self, agent: usize, announce: bool) -> bool {
        let round = self.shared.round.load(Ordering::SeqCst);
        if self.plan.send_crashed(agent, round, announce) {
            // Crash window: fail the send and close the live
            // connection (immediately — pending frames are lost).
            let mut q = self.shared.queues[agent].lock().unwrap();
            q.frames.clear();
            q.kill = true;
            return false;
        }
        let deliver_then_crash = announce
            && self
                .plan
                .crashes
                .iter()
                .any(|c| c.agent == agent && c.after_announce && round == c.from);
        if FaultPlan::take_one_shot(&mut self.plan.drops, agent, round) {
            return false;
        }
        let mut q = self.shared.queues[agent].lock().unwrap();
        if !q.alive || q.frames.len() >= self.shared.cap {
            return false;
        }
        q.frames.push_back(self.scratch.clone());
        if deliver_then_crash {
            // The agent "dies after the announce landed": flush this
            // frame, then close the connection.
            q.kill = true;
        }
        true
    }

    fn map_event(&mut self, ev: IoEvent) -> Recv {
        match ev {
            IoEvent::Frame(agent, frame) => match wire::decode_agent_reply(&frame) {
                Ok(reply) => Recv::Msg(reply),
                Err(_) => {
                    self.frames_rejected += 1;
                    Recv::Rejected { agent }
                }
            },
            IoEvent::Desync(agent) => {
                self.frames_rejected += 1;
                Recv::Rejected { agent }
            }
        }
    }
}

impl Transport for SocketTransport {
    fn agents(&self) -> usize {
        self.n
    }

    fn send(&mut self, agent: usize, msg: &ToAgent) -> bool {
        let announce = if let ToAgent::Announce { round, .. } = msg {
            self.shared.round.store(*round, Ordering::SeqCst);
            true
        } else {
            false
        };
        self.scratch.clear();
        if wire::encode_to_agent(msg, &mut self.scratch).is_err() {
            return false;
        }
        let ok = self.enqueue(agent, announce);
        self.wake();
        ok
    }

    fn broadcast(&mut self, msg: &ToAgent, skip: &[bool], dropped: &mut Vec<usize>) -> usize {
        dropped.clear();
        let announce = if let ToAgent::Announce { round, .. } = msg {
            self.shared.round.store(*round, Ordering::SeqCst);
            true
        } else {
            false
        };
        self.scratch.clear();
        // Oversize encode: the leader's fault — deliver to nobody,
        // blame nobody (same no-poison contract as FramedTransport).
        if wire::encode_to_agent(msg, &mut self.scratch).is_err() {
            return 0;
        }
        let mut delivered = 0;
        for agent in 0..self.n {
            if skip.get(agent).copied().unwrap_or(false) {
                continue;
            }
            if self.enqueue(agent, announce) {
                delivered += 1;
            } else {
                dropped.push(agent);
            }
        }
        self.wake();
        delivered
    }

    fn recv_deadline(&mut self, deadline: Option<Instant>) -> Recv {
        match recv_deadline_on(&self.replies, deadline) {
            Ok(ev) => self.map_event(ev),
            Err(RecvEnd::Empty) => Recv::Empty,
            Err(RecvEnd::Disconnected) => Recv::Disconnected,
        }
    }

    fn try_recv(&mut self) -> Recv {
        match self.replies.try_recv() {
            Ok(ev) => self.map_event(ev),
            Err(mpsc::TryRecvError::Empty) => Recv::Empty,
            Err(mpsc::TryRecvError::Disconnected) => Recv::Disconnected,
        }
    }

    fn frames_rejected(&self) -> u64 {
        self.frames_rejected
    }

    fn shutdown(&mut self) {
        if self.shut {
            return;
        }
        self.shut = true;
        // Best-effort Shutdown frame to every live agent…
        self.scratch.clear();
        if wire::encode_to_agent(&ToAgent::Shutdown, &mut self.scratch).is_ok() {
            for q in self.shared.queues.iter() {
                let mut q = q.lock().unwrap();
                if q.alive && q.frames.len() < self.shared.cap {
                    q.frames.push_back(self.scratch.clone());
                }
            }
        }
        self.wake();
        // …give the I/O thread a bounded window to flush it…
        let t0 = Instant::now();
        while t0.elapsed() < SHUTDOWN_FLUSH {
            let busy = self
                .shared
                .queues
                .iter()
                .any(|q| {
                    let q = q.lock().unwrap();
                    q.alive && !q.frames.is_empty()
                });
            if !busy {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        // …then stop everything. Agents that missed the frame see the
        // stop flag on their next read-timeout pass and exit anyway.
        self.shared.stop.store(true, Ordering::SeqCst);
        self.wake();
        if let Some(h) = self.io_handle.take() {
            let _ = h.join();
        }
        for h in self.agent_handles.drain(..) {
            let _ = h.join();
        }
        if let Some(p) = self.unix_path.take() {
            let _ = std::fs::remove_file(p);
        }
    }
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Bind the configured listener; returns it with the agents' connect
/// target and the Unix socket path to unlink on shutdown (if any).
fn bind(cfg: &JasdaConfig) -> (Listener, ConnectTo, Option<PathBuf>) {
    match cfg.transport {
        TransportKind::Tcp => {
            let addr =
                if cfg.listen_addr.is_empty() { "127.0.0.1:0" } else { cfg.listen_addr.as_str() };
            let l = TcpListener::bind(addr)
                .unwrap_or_else(|e| panic!("jasda: cannot bind tcp listener on {addr}: {e}"));
            l.set_nonblocking(true).expect("nonblocking listener");
            let local = l.local_addr().expect("listener address");
            (Listener::Tcp(l), ConnectTo::Tcp(local), None)
        }
        TransportKind::Unix => {
            let path = if cfg.listen_addr.is_empty() {
                std::env::temp_dir().join(format!(
                    "jasda-{}-{}.sock",
                    std::process::id(),
                    SOCK_SEQ.fetch_add(1, Ordering::SeqCst)
                ))
            } else {
                PathBuf::from(&cfg.listen_addr)
            };
            // A stale socket file from a crashed run blocks bind.
            let _ = std::fs::remove_file(&path);
            let l = UnixListener::bind(&path).unwrap_or_else(|e| {
                panic!("jasda: cannot bind unix listener on {}: {e}", path.display())
            });
            l.set_nonblocking(true).expect("nonblocking listener");
            (Listener::Unix(l), ConnectTo::Unix(path.clone()), Some(path))
        }
        other => panic!("SocketTransport::spawn called with transport '{}'", other.name()),
    }
}

/// Close `agent`'s connection (if any) and mark its queue dead.
fn disconnect(shared: &Shared, conns: &mut [Option<ConnState>], agent: usize) {
    if conns[agent].take().is_some() {
        let mut q = shared.queues[agent].lock().unwrap();
        q.alive = false;
        // Unflushed frames died with the connection.
        q.frames.clear();
    }
}

/// Drain readable bytes from one connection, reassembling and
/// delivering frames. Returns `false` when the connection must close.
fn service_read(
    shared: &Shared,
    reply_tx: &mpsc::Sender<IoEvent>,
    held: &mut Vec<(u64, usize, Vec<u8>)>,
    st: &mut ConnState,
    agent: usize,
    buf: &mut [u8],
) -> bool {
    loop {
        match st.conn.read(buf) {
            Ok(0) => return false,
            Ok(k) => {
                st.reader.feed(&buf[..k]);
                loop {
                    match st.reader.next_frame() {
                        Ok(Some(frame)) => deliver_reply(shared, reply_tx, held, agent, frame),
                        Ok(None) => break,
                        Err(_) => {
                            // Desynchronized stream: every later byte is
                            // garbage. Reject + drop the connection.
                            let _ = reply_tx.send(IoEvent::Desync(agent));
                            return false;
                        }
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
}

/// Run one received reply frame through the reply-side fault plan, then
/// hand it to the leader.
fn deliver_reply(
    shared: &Shared,
    reply_tx: &mpsc::Sender<IoEvent>,
    held: &mut Vec<(u64, usize, Vec<u8>)>,
    agent: usize,
    mut frame: Vec<u8>,
) {
    let round = shared.round.load(Ordering::SeqCst);
    let mut plan = shared.reply_faults.lock().unwrap();
    if plan.reply_crashed(agent, round) {
        return;
    }
    if let Some(by) = plan.take_delay(agent, round) {
        held.push((round + by, agent, frame));
        return;
    }
    if FaultPlan::take_one_shot(&mut plan.corrupts, agent, round) && frame.len() > 4 {
        // Flip the tag byte on the stream: the frame still parses as a
        // frame but fails wire decoding → `Recv::Rejected`.
        frame[4] ^= 0xFF;
    }
    drop(plan);
    let _ = reply_tx.send(IoEvent::Frame(agent, frame));
}

/// Flush `agent`'s write buffer as far as the socket accepts. Returns
/// `false` when the connection must close.
fn service_write(shared: &Shared, st: &mut ConnState, agent: usize) -> bool {
    loop {
        if st.in_flight.is_none() {
            let mut q = shared.queues[agent].lock().unwrap();
            match q.frames.pop_front() {
                Some(f) => st.in_flight = Some((f, 0)),
                None => return true,
            }
        }
        let (frame, pos) = st.in_flight.as_mut().expect("in-flight frame");
        match st.conn.write(&frame[*pos..]) {
            Ok(0) => return false,
            Ok(k) => {
                *pos += k;
                if *pos == frame.len() {
                    st.in_flight = None;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
}

/// A completed hello: attach (or refuse) the connection.
fn admit(shared: &Shared, conns: &mut [Option<ConnState>], seen: &mut [bool], p: Pending) {
    let agent = u32::from_le_bytes(p.hello) as usize;
    if agent >= conns.len() {
        return; // bogus hello: drop the connection
    }
    let round = shared.round.load(Ordering::SeqCst);
    let refused = shared.reply_faults.lock().unwrap().send_crashed(agent, round, false);
    if !refused {
        // Replace any previous connection for this agent.
        disconnect(shared, conns, agent);
        {
            let mut q = shared.queues[agent].lock().unwrap();
            q.alive = true;
            q.kill = false;
            q.frames.clear();
        }
        let conn = p.conn;
        conns[agent] = Some(ConnState { conn, reader: wire::FrameReader::new(), in_flight: None });
    }
    // Count the hello either way — the spawn barrier must not hang on
    // an agent whose crash window opens at round 0. Counted last, so a
    // leader that saw the barrier complete also sees the live queue.
    if !seen[agent] {
        seen[agent] = true;
        shared.connected.fetch_add(1, Ordering::SeqCst);
    }
}

/// The leader's single I/O thread: poll readiness across the wake
/// pipe, the listener, half-identified connections, and every live
/// agent connection; then service exactly what is ready.
fn io_loop(
    shared: Arc<Shared>,
    listener: Listener,
    wake_rx: UnixStream,
    reply_tx: mpsc::Sender<IoEvent>,
) {
    let n = shared.queues.len();
    let mut conns: Vec<Option<ConnState>> = (0..n).map(|_| None).collect();
    let mut pending: Vec<Pending> = Vec::new();
    // Delayed reply frames: `(release_round, agent, frame)`.
    let mut held: Vec<(u64, usize, Vec<u8>)> = Vec::new();
    let mut seen = vec![false; n];
    let mut fds: Vec<PollFd> = Vec::new();
    let mut conn_rows: Vec<usize> = Vec::new();
    let mut buf = vec![0u8; 64 * 1024];

    while !shared.stop.load(Ordering::SeqCst) {
        // Release held stragglers whose round has come.
        let round = shared.round.load(Ordering::SeqCst);
        let mut i = 0;
        while i < held.len() {
            if held[i].0 <= round {
                let (_, agent, frame) = held.swap_remove(i);
                let _ = reply_tx.send(IoEvent::Frame(agent, frame));
            } else {
                i += 1;
            }
        }

        // Crash kills: close marked connections once flushed.
        for agent in 0..n {
            let flushed = {
                let q = shared.queues[agent].lock().unwrap();
                q.kill && q.frames.is_empty()
            };
            let in_flight_done =
                conns[agent].as_ref().map_or(true, |c| c.in_flight.is_none());
            if flushed && in_flight_done {
                disconnect(&shared, &mut conns, agent);
                shared.queues[agent].lock().unwrap().kill = false;
            }
        }

        // Build the poll set: wake pipe, listener, pending hellos,
        // live connections (write interest only with queued output).
        fds.clear();
        fds.push(PollFd { fd: wake_rx.as_raw_fd(), events: POLLIN, revents: 0 });
        fds.push(PollFd { fd: listener.as_raw_fd(), events: POLLIN, revents: 0 });
        let pend0 = fds.len();
        for p in &pending {
            fds.push(PollFd { fd: p.conn.as_raw_fd(), events: POLLIN, revents: 0 });
        }
        let n_pending = pending.len();
        conn_rows.clear();
        for (agent, slot) in conns.iter().enumerate() {
            if let Some(st) = slot {
                let mut events = POLLIN;
                let want_write = st.in_flight.is_some()
                    || !shared.queues[agent].lock().unwrap().frames.is_empty();
                if want_write {
                    events |= POLLOUT;
                }
                fds.push(PollFd { fd: st.conn.as_raw_fd(), events, revents: 0 });
                conn_rows.push(agent);
            }
        }

        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NFds, POLL_TIMEOUT_MS) };
        if rc < 0 {
            continue; // EINTR: just re-enter the loop
        }

        // Wake pipe: drain it (its only job is ending the poll call).
        if fds[0].revents != 0 {
            loop {
                match (&wake_rx).read(&mut buf) {
                    Ok(0) => break,
                    Ok(_) => continue,
                    Err(_) => break,
                }
            }
        }

        // Live connections: read replies, flush queued frames.
        for (k, &agent) in conn_rows.iter().enumerate() {
            let r = fds[pend0 + n_pending + k].revents;
            if r == 0 {
                continue;
            }
            let mut dead = false;
            if let Some(st) = conns[agent].as_mut() {
                if r & (POLLIN | POLLERR | POLLHUP | POLLNVAL) != 0 {
                    dead = !service_read(&shared, &reply_tx, &mut held, st, agent, &mut buf);
                }
                if !dead && r & POLLOUT != 0 {
                    dead = !service_write(&shared, st, agent);
                }
            }
            if dead {
                disconnect(&shared, &mut conns, agent);
            }
        }

        // Pending hellos (descending index: swap_remove-safe).
        for idx in (0..n_pending).rev() {
            let r = fds[pend0 + idx].revents;
            if r == 0 {
                continue;
            }
            let p = &mut pending[idx];
            match p.conn.read(&mut p.hello[p.got..]) {
                Ok(0) => {
                    pending.swap_remove(idx);
                }
                Ok(k) => {
                    p.got += k;
                    if p.got == 4 {
                        let p = pending.swap_remove(idx);
                        admit(&shared, &mut conns, &mut seen, p);
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                Err(_) => {
                    pending.swap_remove(idx);
                }
            }
        }

        // New connections.
        if fds[1].revents != 0 {
            loop {
                match listener.accept() {
                    Ok(conn) => {
                        if conn.set_nonblocking(true).is_ok() {
                            pending.push(Pending { conn, hello: [0; 4], got: 0 });
                        }
                    }
                    Err(_) => break,
                }
            }
        }
    }
    // Dropping `conns` and `listener` closes every socket; agents see
    // EOF, check the stop flag, and exit.
}

/// One agent endpoint: connect (with retry), say hello, then run the
/// shared `agent_loop` over the socket — reconnecting on any stream
/// failure until the leader's stop flag is set. The identical job
/// logic drives loopback channels, in-process frames, and sockets.
fn agent_endpoint(agent: usize, job: Job, cfg: JasdaConfig, target: ConnectTo, shared: Arc<Shared>) {
    struct Link {
        conn: Option<Conn>,
        reader: wire::FrameReader,
    }
    let link = Rc::new(RefCell::new(Link { conn: None, reader: wire::FrameReader::new() }));
    let hello = (agent as u32).to_le_bytes();

    let connect = {
        let link = Rc::clone(&link);
        let shared = Arc::clone(&shared);
        let target = target.clone();
        move || -> bool {
            // Ensure a live, identified connection; `false` = stopping.
            loop {
                if shared.stop.load(Ordering::SeqCst) {
                    return false;
                }
                if link.borrow().conn.is_some() {
                    return true;
                }
                match target.connect() {
                    Ok(mut c) => {
                        let _ = c.set_read_timeout(Some(AGENT_READ_TIMEOUT));
                        if c.write_all(&hello).is_ok() {
                            let mut l = link.borrow_mut();
                            l.reader.clear();
                            l.conn = Some(c);
                        }
                    }
                    Err(_) => std::thread::sleep(RECONNECT_PAUSE),
                }
            }
        }
    };

    let recv = {
        let link = Rc::clone(&link);
        move || -> Option<ToAgent> {
            let mut buf = [0u8; 16 * 1024];
            loop {
                if !connect() {
                    return None;
                }
                let mut l = link.borrow_mut();
                // Drain frames already reassembled before reading more.
                loop {
                    match l.reader.next_frame() {
                        Ok(Some(frame)) => match wire::decode_to_agent(&frame) {
                            Ok(msg) => return Some(msg),
                            Err(_) => continue, // skip an undecodable frame
                        },
                        Ok(None) => break,
                        Err(_) => {
                            // Desync: drop the stream, reconnect clean.
                            l.conn = None;
                            l.reader.clear();
                            break;
                        }
                    }
                }
                if l.conn.is_none() {
                    continue;
                }
                match l.conn.as_mut().expect("live connection").read(&mut buf) {
                    Ok(0) => {
                        // EOF: the leader closed us (crash injection or
                        // replacement). Pause before reconnecting so a
                        // refuse-on-hello crash window doesn't become a
                        // tight accept/close spin.
                        l.conn = None;
                        l.reader.clear();
                        drop(l);
                        std::thread::sleep(RECONNECT_PAUSE);
                    }
                    Ok(k) => {
                        let chunk = buf[..k].to_vec();
                        l.reader.feed(&chunk);
                    }
                    Err(e)
                        if e.kind() == ErrorKind::WouldBlock
                            || e.kind() == ErrorKind::TimedOut =>
                    {
                        // Read timeout: loop to re-check the stop flag.
                    }
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => {
                        l.conn = None;
                        l.reader.clear();
                    }
                }
            }
        }
    };

    let send = {
        let link = Rc::clone(&link);
        let mut out: Vec<u8> = Vec::new();
        move |reply| -> bool {
            out.clear();
            if wire::encode_agent_reply(&reply, &mut out).is_err() {
                // Oversized reply: the agent's own loss — swallow it
                // (the leader's round deadline covers the missing bid).
                return true;
            }
            let mut l = link.borrow_mut();
            if let Some(c) = l.conn.as_mut() {
                if c.write_all(&out).is_err() {
                    // The reply died with the stream; reconnect on the
                    // next receive. A lost reply is a crash-shaped
                    // fault the leader's deadline already covers.
                    l.conn = None;
                    l.reader.clear();
                }
            }
            true
        }
    };

    super::agent_loop(job, cfg, recv, send);
}

#[cfg(test)]
mod tests {
    use super::super::messages::{AgentReply, CompletionReport};
    use super::*;
    use crate::config::SimConfig;
    use crate::trp::{Phase, Trp};

    fn jobs(n: u32) -> Vec<Job> {
        (0..n)
            .map(|i| {
                let trp = Trp {
                    phases: vec![Phase::new(800.0, 4.0, 0.2, 0.1)],
                    duration_cv: 0.05,
                };
                Job::new(i, "p", (i as u64) * 100, trp, None, 1.0, 300.0, 0.0)
            })
            .collect()
    }

    fn jcfg(kind: TransportKind) -> JasdaConfig {
        let mut c = SimConfig::default().jasda;
        c.transport = kind;
        c.fmp_bins = 16;
        c
    }

    #[test]
    fn round_trips_frames_over_unix_sockets() {
        let cfg = jcfg(TransportKind::Unix);
        let mut t = SocketTransport::spawn(jobs(3), &cfg, FaultPlan::default());
        assert_eq!(t.agents(), 3);
        let announce = ToAgent::Announce {
            round: 0,
            now: 200,
            windows: Arc::new(vec![crate::mig::Window {
                slice: 0,
                capacity_gb: 20.0,
                speed: 1.0,
                interval: crate::types::Interval::new(200, 10_000),
            }]),
        };
        let mut dropped = Vec::new();
        let delivered = t.broadcast(&announce, &[], &mut dropped);
        assert_eq!(delivered, 3);
        assert!(dropped.is_empty());
        let mut replies = 0;
        let deadline = Instant::now() + Duration::from_secs(10);
        while replies < delivered {
            match t.recv_deadline(Some(deadline)) {
                Recv::Msg(AgentReply::Bid { round, .. }) => {
                    assert_eq!(round, 0);
                    replies += 1;
                }
                other => panic!("expected a bid, got {other:?}"),
            }
        }
        t.shutdown();
        t.shutdown(); // idempotent
    }

    #[test]
    fn round_trips_frames_over_tcp() {
        let cfg = jcfg(TransportKind::Tcp);
        let mut t = SocketTransport::spawn(jobs(2), &cfg, FaultPlan::default());
        let msg = ToAgent::Completed(CompletionReport {
            planned_work: 1.0,
            realized_work: 1.0,
            at: 10,
        });
        assert!(t.send(0, &msg), "send to a connected agent must land");
        t.shutdown();
    }

    #[test]
    fn expired_deadline_is_empty_even_with_replies_queued() {
        let cfg = jcfg(TransportKind::Unix);
        let mut t = SocketTransport::spawn(jobs(1), &cfg, FaultPlan::default());
        let announce = ToAgent::Announce {
            round: 0,
            now: 0,
            windows: Arc::new(vec![crate::mig::Window {
                slice: 0,
                capacity_gb: 20.0,
                speed: 1.0,
                interval: crate::types::Interval::new(0, 10_000),
            }]),
        };
        assert!(t.send(0, &announce));
        // Let the reply arrive at the leader's queue…
        std::thread::sleep(Duration::from_millis(100));
        // …then an already-expired deadline still dequeues nothing.
        let expired = Instant::now();
        assert!(matches!(t.recv_deadline(Some(expired)), Recv::Empty));
        match t.recv_deadline(Some(Instant::now() + Duration::from_secs(10))) {
            Recv::Msg(AgentReply::Bid { round, .. }) => assert_eq!(round, 0),
            other => panic!("queued bid must survive the expired receive, got {other:?}"),
        }
        t.shutdown();
    }

    #[test]
    fn desynced_stream_surfaces_as_rejected() {
        // A raw client that says hello and then writes garbage where a
        // length prefix belongs: the leader must attribute the reject
        // and survive.
        let mut cfg = jcfg(TransportKind::Unix);
        cfg.listen_addr = String::new();
        let mut t = SocketTransport::spawn(jobs(1), &cfg, FaultPlan::default());
        let path = t.unix_path.clone().expect("unix transport binds a path");
        let mut rogue = UnixStream::connect(path).expect("connect rogue");
        rogue.write_all(&0u32.to_le_bytes()).expect("hello");
        let huge = (u32::MAX).to_le_bytes();
        rogue.write_all(&huge).expect("bogus prefix");
        match t.recv_deadline(Some(Instant::now() + Duration::from_secs(10))) {
            Recv::Rejected { agent } => assert_eq!(agent, 0),
            other => panic!("desync must surface as Rejected, got {other:?}"),
        }
        assert_eq!(t.frames_rejected(), 1);
        t.shutdown();
    }
}
