//! Protocol messages between the leader and job agents.
//!
//! The message vocabulary is intentionally minimal — it is exactly the
//! information flow of the paper's interaction cycle (Fig. Algorithm 1),
//! generalized to **multi-window rounds**: announcements flow down, bids
//! flow up, awards and completion reports flow down. Agents never see
//! other agents' bids or the global schedule (§5.1(d)
//! information-visibility contract).
//!
//! One round exchanges at most four message kinds per agent:
//!
//! ```text
//!  leader                                agent (one thread per job)
//!    │  Announce { round, now, windows } → │   windows = the round's
//!    │                                     │   candidate set, in a
//!    │                                     │   leader-chosen order
//!    │ ← Bid { job, round, bids, done }    │   bids[w] answers windows[w]
//!    │    (exactly one reply per agent;    │   (empty = silent on w)
//!    │     all-empty bids = silent round)  │
//!    │  … leader clears ≤ K windows …      │
//!    │  Awarded { round, variant_ids,    → │   ids are *agent-assigned*
//!    │            now }                    │   (see Bid), so the agent
//!    │                                     │   resolves them locally
//!    │  … later, when a subjob ends …      │
//!    │  Completed { planned_work,        → │   agent advances its work
//!    │              realized_work, at }    │   cursor / completes
//! ```
//!
//! Outside the happy path a fifth message, [`ToAgent::Resync`], carries
//! the leader's ground-truth work accounting to an agent re-admitted
//! after quarantine (see the failure-semantics section of the
//! [coordinator module docs](super)); it flows down only as the
//! re-admission probe, never during a healthy round.
//!
//! Why the announcement carries the whole candidate set rather than
//! exactly K windows: the leader only *clears* up to K windows per
//! round, but it cannot know in advance which candidates will draw no
//! bids (the "silent window" sparsity mode of §5.1(a)). Shipping the
//! candidates in one message lets the leader skip silent windows and
//! fall through to the next candidate **without another round-trip**,
//! which is exactly what the in-process scheduler's announce loop does —
//! the property tests pin the two paths to identical decisions.
//!
//! These are the *typed* messages; how they move is the transport
//! layer's business ([`super::transport`]). Under the framed transport
//! every one of them crosses as a length-prefixed byte frame in the
//! hand-rolled [`super::wire`] format, and the round-trip property tests
//! pin the codec to these definitions field by field.

use crate::job::Variant;
use crate::mig::Window;
use crate::types::Time;
use std::sync::Arc;

/// Leader → agent messages.
#[derive(Debug, Clone)]
pub enum ToAgent {
    /// Step 1: the round's candidate windows are open for bidding. The
    /// leader will clear at most K of them (`jasda.announce_k`, or one
    /// per slice under `announce_per_slice`).
    Announce {
        /// Round (iteration) counter; echoed back in [`AgentReply::Bid`]
        /// so stale replies can never be mistaken for current ones.
        round: u64,
        /// Current leader time (drives agent activation: an agent whose
        /// job has `arrival <= now` becomes active on receipt).
        now: Time,
        /// Candidate windows, in the leader's enumeration order. Bids
        /// must be indexed by position in this vector. Shared (`Arc`) so
        /// a broadcast to N agents is N refcount bumps, not N deep
        /// copies of the window list.
        windows: Arc<Vec<Window>>,
    },
    /// Step 5: some of the agent's variants were selected.
    Awarded(Award),
    /// A previously awarded subjob finished executing.
    Completed(CompletionReport),
    /// Re-admission probe after quarantine: the leader's ground truth
    /// for the agent's award/plan state, so a restarted (or long
    /// partitioned) agent overwrites whatever award and completion
    /// messages it missed and rejoins consistently.
    Resync(Resync),
    /// Tear down the agent task.
    Shutdown,
}

/// Leader ground truth carried by a re-admission probe.
///
/// A quarantined agent may have missed any number of `Awarded` and
/// `Completed` messages; its local `done_work`/`reserved_work` cursors
/// are stale and its next bids would re-offer work the leader already
/// holds in flight. The probe replaces both cursors with the leader's
/// accounting, which is exactly the state the agent's bids must be
/// consistent with.
#[derive(Debug, Clone)]
pub struct Resync {
    /// Round the probe was sent in (diagnostics; the next `Announce`
    /// carries the round the agent actually bids into).
    pub round: u64,
    /// Current leader time (drives activation, like `Announce`).
    pub now: Time,
    /// Work the leader has credited as realized (fired completions).
    pub done_work: f64,
    /// Planned work currently awarded and in flight — the agent's
    /// outstanding awards, from the leader's completion slab.
    pub outstanding_awards: f64,
}

/// Award notice (a subset of the agent's last bid).
#[derive(Debug, Clone)]
pub struct Award {
    /// Round the bid was placed in.
    pub round: u64,
    /// Ids of the winning variants, **as assigned by the agent** in its
    /// [`AgentReply::Bid`] (unique within one reply). Agent-assigned ids
    /// mean the agent can resolve an award against its own last bid
    /// without sharing the leader's pool numbering — the leader's
    /// pool-row ids never leave the leader.
    pub variant_ids: Vec<u32>,
    /// Commit time (becomes the job's `last_selected` for the age term).
    pub now: Time,
}

/// Completion report for one subjob.
#[derive(Debug, Clone)]
pub struct CompletionReport {
    /// Work that was committed for the subjob.
    pub planned_work: f64,
    /// Work actually realized (≤ planned; less when the reservation ran
    /// out before the sampled duration).
    pub realized_work: f64,
    /// Completion time (realized end, ≤ the reserved end).
    pub at: Time,
}

/// Agent → leader messages.
#[derive(Debug, Clone)]
pub enum AgentReply {
    /// Step 3: the agent's bid for one round — one entry per announced
    /// window, in announcement order.
    Bid {
        /// Bidding job id.
        job: u32,
        /// Round being answered (copied from the announcement).
        round: u64,
        /// Per-window variant portfolios: `bids[w]` answers
        /// `windows[w]` of the announcement; an empty vector means the
        /// agent is silent on that window. Variant `id`s are assigned by
        /// the agent, unique across the whole reply, and echoed back in
        /// [`Award::variant_ids`].
        bids: Vec<Vec<Variant>>,
        /// Whether the job has completed all of its work (diagnostics;
        /// the leader tracks completion from its own realization
        /// ground truth).
        done: bool,
    },
}
