//! Protocol messages between the leader and job agents.
//!
//! The message vocabulary is intentionally minimal — it is exactly the
//! information flow of the paper's interaction cycle (Fig. Algorithm 1):
//! announcements flow down, bids flow up, awards and completion reports
//! flow down. Agents never see other agents' bids or the global schedule
//! (§5.1(d) information-visibility contract).

use crate::job::Variant;
use crate::mig::Window;
use crate::types::Time;

/// Leader → agent messages.
#[derive(Debug, Clone)]
pub enum ToAgent {
    /// Step 1: a window `w*` is open for bidding in `round`.
    Announce {
        /// Round (iteration) counter.
        round: u64,
        /// Current scheduler time.
        now: Time,
        /// The announced window.
        window: Window,
    },
    /// Step 5: some of the agent's variants were selected.
    Awarded(Award),
    /// A previously awarded subjob finished executing.
    Completed(CompletionReport),
    /// Tear down the agent task.
    Shutdown,
}

/// Award notice (subset of the agent's last bid).
#[derive(Debug, Clone)]
pub struct Award {
    /// Round the bid was placed in.
    pub round: u64,
    /// Ids (bid-local) of the winning variants.
    pub variant_ids: Vec<u32>,
    /// Commit time.
    pub now: Time,
}

/// Completion report for one subjob.
#[derive(Debug, Clone)]
pub struct CompletionReport {
    /// Work that was committed.
    pub planned_work: f64,
    /// Work actually realized (≤ planned).
    pub realized_work: f64,
    /// Completion time.
    pub at: Time,
}

/// Agent → leader messages.
#[derive(Debug, Clone)]
pub enum AgentReply {
    /// Step 3: the agent's bid for `round` (empty `variants` = silent).
    Bid {
        /// Bidding job.
        job: u32,
        /// Round being answered.
        round: u64,
        /// Eligible scored variants (may be empty).
        variants: Vec<Variant>,
        /// Whether the job has completed all work.
        done: bool,
    },
}
