//! Deterministic fault injection for the protocol runtime.
//!
//! Robustness claims need adversity that is *reproducible*: a flaky
//! sleep-based chaos harness can neither bisect a liveness regression
//! nor run in CI with a fixed seed grid. This module makes adversity a
//! pure function of a seed: a [`FaultPlan`] is drawn once from
//! [`FaultsConfig`](crate::config::FaultsConfig) and then applied
//! mechanically by a [`FaultyTransport`] wrapped around any inner
//! [`Transport`] — the leader and agents run unmodified, the message
//! plane misbehaves on schedule.
//!
//! Four fault shapes, mirroring what a real deployment sees:
//!
//! - **Crash windows** ([`CrashWindow`]): agent `i` is unreachable for
//!   rounds `[from, until)` — its sends fail and any replies it produces
//!   are swallowed. With `after_announce` set, the round-`from` announce
//!   is still *delivered* and only the reply is lost: the exact
//!   "agent died after the announce landed" scenario that wedged the
//!   deadline-less collection loop forever.
//! - **Delays** ([`DelayFault`]): one reply is held and released `by`
//!   rounds later, when the round-tag check discards it as stale — the
//!   straggler path.
//! - **Corruption**: one reply surfaces as [`Recv::Rejected`] (a frame
//!   that fails wire decoding), feeding the leader's quarantine streak.
//! - **Drops**: one leader→agent send is silently lost.
//!
//! The wrapper learns the current round by peeking at outgoing
//! [`ToAgent::Announce`] messages, so a round-indexed plan needs no
//! extra plumbing through the leader. Because every crash window is
//! finite, a plan never makes an agent unreachable forever — the
//! leader's backoff probes eventually land and liveness (every job
//! completes) stays provable; the property tests in
//! `tests/properties.rs` assert exactly that over randomized plans.

use super::messages::{AgentReply, ToAgent};
use super::transport::{Recv, Transport};
use crate::config::FaultsConfig;
use crate::sim::Rng;
use crate::types::JobId;
use std::collections::BTreeMap;
use std::time::Instant;

/// Agent `agent` is unreachable for rounds `[from, until)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CrashWindow {
    /// Crashed agent index.
    pub agent: usize,
    /// First unreachable round.
    pub from: u64,
    /// First reachable round again (exclusive end; always finite).
    pub until: u64,
    /// When set, the round-`from` announce is still delivered and only
    /// the agent's reply is swallowed — the crash happens *after* the
    /// announce landed, so the leader is left waiting on a reply that
    /// never comes (the wedge the round deadline exists for).
    pub after_announce: bool,
}

/// One reply from `agent` in round `round` is delivered `by` rounds
/// late (the round-tag check then discards it as stale).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DelayFault {
    /// Delayed agent index.
    pub agent: usize,
    /// Round whose reply is held.
    pub round: u64,
    /// Rounds to hold it for.
    pub by: u64,
}

/// A complete, deterministic schedule of adversity for one run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Unreachability windows.
    pub crashes: Vec<CrashWindow>,
    /// Straggler replies.
    pub delays: Vec<DelayFault>,
    /// One-shot reply corruptions: `(agent, round)` — the agent's reply
    /// in that round surfaces as [`Recv::Rejected`].
    pub corrupts: Vec<(usize, u64)>,
    /// One-shot send drops: `(agent, round)` — one leader→agent send in
    /// that round is lost.
    pub drops: Vec<(usize, u64)>,
}

impl FaultPlan {
    /// Draw a plan from the config knobs: each agent independently gets
    /// each fault shape with the configured probability, with rounds
    /// drawn uniformly from `[0, horizon_rounds)`. When `crash > 0` at
    /// least one crash is forced so "test with crashes" cannot silently
    /// degenerate into a fault-free run on an unlucky seed. Same seed +
    /// same config + same agent count → identical plan.
    pub fn random(cfg: &FaultsConfig, agents: usize) -> FaultPlan {
        let mut plan = FaultPlan::default();
        if agents == 0 || cfg.horizon_rounds == 0 {
            return plan;
        }
        let mut rng = Rng::new(cfg.seed).fork(0xFA017);
        let horizon = cfg.horizon_rounds;
        for agent in 0..agents {
            if cfg.crash > 0.0 && rng.chance(cfg.crash) {
                plan.crashes.push(Self::rand_crash(&mut rng, agent, horizon, cfg.crash_rounds));
            }
            if cfg.delay > 0.0 && rng.chance(cfg.delay) {
                let by = 1 + rng.below(cfg.delay_rounds.max(1));
                plan.delays.push(DelayFault { agent, round: rng.below(horizon), by });
            }
            if cfg.corrupt > 0.0 && rng.chance(cfg.corrupt) {
                plan.corrupts.push((agent, rng.below(horizon)));
            }
            if cfg.drop > 0.0 && rng.chance(cfg.drop) {
                plan.drops.push((agent, rng.below(horizon)));
            }
        }
        if cfg.crash > 0.0 && plan.crashes.is_empty() {
            let agent = rng.index(agents);
            plan.crashes.push(Self::rand_crash(&mut rng, agent, horizon, cfg.crash_rounds));
        }
        plan
    }

    fn rand_crash(rng: &mut Rng, agent: usize, horizon: u64, crash_rounds: u64) -> CrashWindow {
        let from = rng.below(horizon);
        let len = 1 + rng.below(crash_rounds.max(1));
        CrashWindow { agent, from, until: from + len, after_announce: rng.chance(0.5) }
    }

    /// Is a leader→`agent` send in `round` eaten by a crash window?
    /// `announce` marks announce-shaped sends, which an `after_announce`
    /// crash still lets through in its first round.
    ///
    /// `pub(crate)` because the socket transport applies the same plan
    /// at the connection layer (see `coordinator::socket`) instead of
    /// through a [`FaultyTransport`] wrapper.
    pub(crate) fn send_crashed(&self, agent: usize, round: u64, announce: bool) -> bool {
        self.crashes.iter().any(|c| {
            c.agent == agent
                && round >= c.from
                && round < c.until
                && !(announce && c.after_announce && round == c.from)
        })
    }

    /// Is a reply from `agent` tagged `round` swallowed by a crash?
    pub(crate) fn reply_crashed(&self, agent: usize, round: u64) -> bool {
        self.crashes.iter().any(|c| c.agent == agent && round >= c.from && round < c.until)
    }

    pub(crate) fn take_delay(&mut self, agent: usize, round: u64) -> Option<u64> {
        let i = self.delays.iter().position(|d| d.agent == agent && d.round == round)?;
        Some(self.delays.swap_remove(i).by)
    }

    pub(crate) fn take_one_shot(shots: &mut Vec<(usize, u64)>, agent: usize, round: u64) -> bool {
        match shots.iter().position(|&(a, r)| a == agent && r == round) {
            Some(i) => {
                shots.swap_remove(i);
                true
            }
            None => false,
        }
    }
}

/// Counters for the faults a [`FaultyTransport`] actually fired
/// (a plan entry outside the rounds the run reached never fires).
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultStats {
    /// Leader→agent sends eaten by crash windows.
    pub sends_crashed: u64,
    /// Leader→agent sends eaten by one-shot drop faults.
    pub sends_dropped: u64,
    /// Agent replies swallowed by crash windows.
    pub replies_swallowed: u64,
    /// Agent replies held and re-delivered late.
    pub replies_delayed: u64,
    /// Agent replies surfaced as rejected frames.
    pub replies_corrupted: u64,
}

/// A [`Transport`] wrapper that applies a [`FaultPlan`] to an inner
/// transport. The leader cannot tell it apart from a genuinely
/// misbehaving message plane: sends fail, replies vanish, stale replies
/// straggle in, frames reject — all on the plan's deterministic
/// schedule.
pub struct FaultyTransport {
    inner: Box<dyn Transport>,
    plan: FaultPlan,
    /// Job id → agent index, to attribute replies to plan entries.
    slot: BTreeMap<JobId, usize>,
    /// Current round, learned from outgoing `Announce` messages.
    round: u64,
    /// Delayed replies: `(release_round, reply)`.
    held: Vec<(u64, AgentReply)>,
    /// What actually fired.
    pub stats: FaultStats,
}

impl FaultyTransport {
    /// Wrap `inner`, applying `plan`. `slot` maps job ids to agent
    /// indexes (the same mapping the leader uses).
    pub fn new(inner: Box<dyn Transport>, plan: FaultPlan, slot: BTreeMap<JobId, usize>) -> Self {
        FaultyTransport { inner, plan, slot, round: 0, held: Vec::new(), stats: FaultStats::default() }
    }

    /// Pop a held reply whose release round has arrived, if any.
    fn release_held(&mut self) -> Option<AgentReply> {
        let i = self.held.iter().position(|&(release, _)| release <= self.round)?;
        self.stats.replies_delayed += 1;
        Some(self.held.swap_remove(i).1)
    }

    /// Run one inner receive result through the plan. `None` means the
    /// reply was absorbed (swallowed or held) and the caller should
    /// receive again.
    fn filter(&mut self, got: Recv) -> Option<Recv> {
        let reply = match got {
            Recv::Msg(reply) => reply,
            other => return Some(other),
        };
        let AgentReply::Bid { job, round, .. } = &reply;
        let Some(&agent) = self.slot.get(job) else { return Some(Recv::Msg(reply)) };
        let tagged = *round;
        if self.plan.reply_crashed(agent, tagged) {
            self.stats.replies_swallowed += 1;
            return None;
        }
        if let Some(by) = self.plan.take_delay(agent, tagged) {
            self.held.push((tagged + by, reply));
            return None;
        }
        if FaultPlan::take_one_shot(&mut self.plan.corrupts, agent, tagged) {
            self.stats.replies_corrupted += 1;
            return Some(Recv::Rejected { agent });
        }
        Some(Recv::Msg(reply))
    }
}

impl Transport for FaultyTransport {
    fn agents(&self) -> usize {
        self.inner.agents()
    }

    fn send(&mut self, agent: usize, msg: &ToAgent) -> bool {
        let announce = if let ToAgent::Announce { round, .. } = msg {
            self.round = *round;
            true
        } else {
            false
        };
        if self.plan.send_crashed(agent, self.round, announce) {
            self.stats.sends_crashed += 1;
            return false;
        }
        if FaultPlan::take_one_shot(&mut self.plan.drops, agent, self.round) {
            self.stats.sends_dropped += 1;
            return false;
        }
        self.inner.send(agent, msg)
    }

    fn recv_deadline(&mut self, deadline: Option<Instant>) -> Recv {
        loop {
            if let Some(reply) = self.release_held() {
                return Recv::Msg(reply);
            }
            let got = self.inner.recv_deadline(deadline);
            if let Some(out) = self.filter(got) {
                return out;
            }
        }
    }

    fn try_recv(&mut self) -> Recv {
        loop {
            if let Some(reply) = self.release_held() {
                return Recv::Msg(reply);
            }
            let got = self.inner.try_recv();
            if let Some(out) = self.filter(got) {
                return out;
            }
        }
    }

    fn frames_rejected(&self) -> u64 {
        self.inner.frames_rejected() + self.stats.replies_corrupted
    }

    fn shutdown(&mut self) {
        self.inner.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::VecDeque;

    fn faults_cfg() -> FaultsConfig {
        FaultsConfig { seed: 42, crash: 0.5, delay: 0.3, corrupt: 0.2, drop: 0.2, ..Default::default() }
    }

    #[test]
    fn plans_are_deterministic_in_seed() {
        let cfg = faults_cfg();
        let a = FaultPlan::random(&cfg, 8);
        let b = FaultPlan::random(&cfg, 8);
        assert_eq!(a, b);
        let other = FaultsConfig { seed: 43, ..cfg };
        assert_ne!(FaultPlan::random(&other, 8), a, "different seeds should differ");
    }

    #[test]
    fn crash_probability_forces_at_least_one_crash() {
        // Even a tiny crash probability must yield a crash: scan seeds
        // until one draws none organically, then check the forcing.
        let mut cfg = FaultsConfig { crash: 0.01, ..faults_cfg() };
        for seed in 0..64 {
            cfg.seed = seed;
            let plan = FaultPlan::random(&cfg, 4);
            assert!(!plan.crashes.is_empty(), "seed {seed} produced a crash-free plan");
            for c in &plan.crashes {
                assert!(c.until > c.from, "crash windows must be non-empty");
                assert!(c.agent < 4);
            }
        }
    }

    #[test]
    fn empty_or_disabled_configs_yield_empty_plans() {
        assert_eq!(FaultPlan::random(&FaultsConfig::default(), 8), FaultPlan::default());
        assert_eq!(FaultPlan::random(&faults_cfg(), 0), FaultPlan::default());
        let no_horizon = FaultsConfig { horizon_rounds: 0, ..faults_cfg() };
        assert_eq!(FaultPlan::random(&no_horizon, 8), FaultPlan::default());
    }

    /// Scripted inner transport: records sends, serves queued replies.
    struct StubTransport {
        agents: usize,
        sent: Vec<(usize, ToAgent)>,
        queue: VecDeque<AgentReply>,
    }

    impl StubTransport {
        fn new(agents: usize, queue: Vec<AgentReply>) -> Self {
            StubTransport { agents, sent: Vec::new(), queue: queue.into() }
        }
    }

    impl Transport for StubTransport {
        fn agents(&self) -> usize {
            self.agents
        }
        fn send(&mut self, agent: usize, msg: &ToAgent) -> bool {
            self.sent.push((agent, msg.clone()));
            true
        }
        fn recv_deadline(&mut self, _deadline: Option<Instant>) -> Recv {
            match self.queue.pop_front() {
                Some(reply) => Recv::Msg(reply),
                None => Recv::Empty,
            }
        }
        fn try_recv(&mut self) -> Recv {
            self.recv_deadline(None)
        }
        fn shutdown(&mut self) {}
    }

    fn bid(job: JobId, round: u64) -> AgentReply {
        AgentReply::Bid { job, round, bids: vec![], done: false }
    }

    fn announce(round: u64) -> ToAgent {
        ToAgent::Announce { round, now: 0, windows: std::sync::Arc::new(Vec::new()) }
    }

    fn slot2() -> BTreeMap<JobId, usize> {
        [(10, 0), (20, 1)].into_iter().collect()
    }

    #[test]
    fn crash_window_eats_sends_and_replies() {
        let plan = FaultPlan {
            crashes: vec![CrashWindow { agent: 0, from: 2, until: 4, after_announce: false }],
            ..FaultPlan::default()
        };
        let stub = StubTransport::new(2, vec![bid(10, 2), bid(20, 2)]);
        let mut t = FaultyTransport::new(Box::new(stub), plan, slot2());
        assert!(t.send(0, &announce(1)), "round 1: before the window, send delivers");
        assert!(!t.send(0, &announce(2)), "round 2: inside the window, send fails");
        assert!(t.send(1, &announce(2)), "other agents unaffected");
        // Agent 0's reply is swallowed, agent 1's passes through.
        match t.recv_deadline(None) {
            Recv::Msg(AgentReply::Bid { job, .. }) => assert_eq!(job, 20),
            other => panic!("expected agent 1's bid, got {other:?}"),
        }
        assert_eq!(t.stats.sends_crashed, 1);
        assert_eq!(t.stats.replies_swallowed, 1);
        assert!(t.send(0, &announce(4)), "round 4: window over, send delivers again");
    }

    #[test]
    fn after_announce_crash_delivers_announce_but_swallows_reply() {
        let plan = FaultPlan {
            crashes: vec![CrashWindow { agent: 0, from: 3, until: 4, after_announce: true }],
            ..FaultPlan::default()
        };
        let stub = StubTransport::new(1, vec![bid(10, 3)]);
        let mut t = FaultyTransport::new(Box::new(stub), plan, [(10, 0)].into_iter().collect());
        assert!(t.send(0, &announce(3)), "the round-3 announce itself still lands");
        assert!(!t.send(0, &ToAgent::Shutdown), "but nothing else that round does");
        assert!(matches!(t.recv_deadline(None), Recv::Empty), "and the reply is swallowed");
        assert_eq!(t.stats.replies_swallowed, 1);
    }

    #[test]
    fn delayed_reply_released_when_round_advances() {
        let plan = FaultPlan {
            delays: vec![DelayFault { agent: 0, round: 1, by: 2 }],
            ..FaultPlan::default()
        };
        let stub = StubTransport::new(1, vec![bid(10, 1)]);
        let mut t = FaultyTransport::new(Box::new(stub), plan, [(10, 0)].into_iter().collect());
        let _ = t.send(0, &announce(1));
        assert!(matches!(t.recv_deadline(None), Recv::Empty), "held in round 1");
        let _ = t.send(0, &announce(3));
        match t.recv_deadline(None) {
            Recv::Msg(AgentReply::Bid { job, round, .. }) => {
                assert_eq!(job, 10);
                assert_eq!(round, 1, "the straggler still carries its original round tag");
            }
            other => panic!("expected the released straggler, got {other:?}"),
        }
        assert_eq!(t.stats.replies_delayed, 1);
    }

    #[test]
    fn corrupt_and_drop_fire_exactly_once() {
        let plan = FaultPlan {
            corrupts: vec![(0, 1)],
            drops: vec![(0, 2)],
            ..FaultPlan::default()
        };
        let stub = StubTransport::new(1, vec![bid(10, 1), bid(10, 1)]);
        let mut t = FaultyTransport::new(Box::new(stub), plan, [(10, 0)].into_iter().collect());
        assert!(t.send(0, &announce(1)), "round 1 has no send faults");
        match t.recv_deadline(None) {
            Recv::Rejected { agent } => assert_eq!(agent, 0),
            other => panic!("expected one corrupt reply, got {other:?}"),
        }
        assert!(matches!(t.recv_deadline(None), Recv::Msg(_)), "second reply passes clean");
        assert!(!t.send(0, &announce(2)), "the round-2 one-shot drop eats the next send");
        assert!(t.send(0, &ToAgent::Shutdown), "and only that one");
        assert_eq!(t.stats.replies_corrupted, 1);
        assert_eq!(t.stats.sends_dropped, 1);
        assert_eq!(t.frames_rejected(), 1);
    }
}
