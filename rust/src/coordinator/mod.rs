//! The bidirectional bid–response protocol runtime (paper §5.1(f) /
//! §6(e)): JASDA as an actual distributed negotiation between a leader
//! thread (the scheduler) and autonomous job-agent threads, over message
//! channels (std::sync::mpsc; the offline build has no tokio, and the
//! protocol is synchronous-round anyway — see DESIGN.md).
//!
//! The [`SimEngine`](crate::sim::SimEngine) calls job-side code as plain
//! functions; this module is the deployment-shaped variant where jobs are
//! *threads*: each agent owns its private job state and replies to
//! window announcements with bids; the leader owns the cluster, trust
//! state, clearing, and ground-truth realization. Messages are the only
//! coupling — exactly the information-visibility contract of §5.1(d)
//! (jobs see announced windows and their own awards, nothing else).

pub mod messages;

use crate::config::SimConfig;
use crate::jasda::calibration::Calibration;
use crate::jasda::clearing::{select_best_compatible, WisItem};
use crate::jasda::scoring::{NativeScorer, ScoreBatch, ScorerBackend};
use crate::jasda::window::WindowSelector;
use crate::job::variants::generate_variants;
use crate::job::{Job, JobState};
use crate::mig::{Cluster, PartitionLayout, Reservation};
use crate::sim::Rng;
use crate::types::{JobId, Time};
use messages::{AgentReply, Award, CompletionReport, ToAgent};
use std::collections::BinaryHeap;
use std::sync::mpsc;

/// Outcome of a protocol run.
#[derive(Debug, Clone)]
pub struct ProtocolOutcome {
    /// Rounds (announcement cycles) executed.
    pub rounds: u64,
    /// Announcements broadcast.
    pub announcements: u64,
    /// Bid messages received (silent replies excluded).
    pub bids: u64,
    /// Variants received in bids.
    pub variants: u64,
    /// Awards granted.
    pub awards: u64,
    /// Jobs completed.
    pub completed_jobs: usize,
    /// Total jobs.
    pub total_jobs: usize,
    /// Final virtual time.
    pub final_time: Time,
    /// Wall-clock duration of the run.
    pub wall: std::time::Duration,
}

/// Job-agent thread: owns its job, answers announcements autonomously.
fn agent_task(
    mut job: Job,
    cfg: crate::config::JasdaConfig,
    rx: mpsc::Receiver<ToAgent>,
    tx: mpsc::Sender<AgentReply>,
) {
    // Variants proposed in the current round, kept so awards can be
    // resolved to work amounts (the leader echoes variant ids back).
    let mut last_bid: Vec<crate::job::Variant> = Vec::new();
    while let Ok(msg) = rx.recv() {
        match msg {
            ToAgent::Announce { round, now, window } => {
                if job.state == JobState::Future && job.arrival <= now {
                    job.state = JobState::Active;
                }
                last_bid = generate_variants(&job, &window, &cfg);
                let reply = AgentReply::Bid {
                    job: job.id,
                    round,
                    variants: last_bid.clone(),
                    done: job.state == JobState::Completed,
                };
                if tx.send(reply).is_err() {
                    return;
                }
            }
            ToAgent::Awarded(Award { round: _, variant_ids, now }) => {
                for vid in variant_ids {
                    if let Some(v) = last_bid.iter().find(|v| v.id == vid) {
                        job.reserved_work += v.work.min(job.pending_work());
                        job.last_selected = now;
                        job.last_slice = Some(v.slice);
                    }
                }
            }
            ToAgent::Completed(CompletionReport { planned_work, realized_work, at }) => {
                job.reserved_work = (job.reserved_work - planned_work).max(0.0);
                job.done_work += realized_work;
                if job.remaining_work() <= 1e-6 && job.state == JobState::Active {
                    job.state = JobState::Completed;
                    job.completed_at = Some(at);
                }
            }
            ToAgent::Shutdown => return,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct PendingKey(Time, u64);

struct PendingDone {
    job: JobId,
    slice: u32,
    seq: u32,
    reserved: crate::types::Interval,
    realized_end: Time,
    planned_work: f64,
    realized_work: f64,
    declared_phi: [f64; 4],
}

/// Run the full protocol: spawn one agent thread per job, drive
/// announcement rounds until all jobs complete (or `max_rounds`).
pub fn run_protocol(cfg: SimConfig, jobs: Vec<Job>, max_rounds: u64) -> ProtocolOutcome {
    let wall0 = std::time::Instant::now();
    let n_jobs = jobs.len();
    let layout = PartitionLayout::stock(&cfg.cluster.layout).expect("layout");
    let mut cluster = Cluster::new(cfg.cluster.num_gpus, &layout);
    let mut rng = Rng::new(cfg.seed).fork(0xC00D);
    let mut calibration =
        Calibration::new(n_jobs, cfg.jasda.kappa, cfg.jasda.gamma, cfg.jasda.alpha.as_array());
    let mut scorer = NativeScorer;
    let mut selector = WindowSelector::new();

    // Leader-side read-only job facts + bookkeeping. Vectors are in
    // population order; `slot` maps a (possibly sparse, trace-supplied)
    // JobId to its vector index so ids are never used as indices.
    let slot: std::collections::BTreeMap<JobId, usize> =
        jobs.iter().enumerate().map(|(i, j)| (j.id, i)).collect();
    assert_eq!(slot.len(), n_jobs, "protocol runtime requires unique job ids");
    let trps: Vec<crate::trp::Trp> = jobs.iter().map(|j| j.trp.clone()).collect();
    let arrivals: Vec<Time> = jobs.iter().map(|j| j.arrival).collect();
    let totals: Vec<f64> = jobs.iter().map(|j| j.total_work()).collect();
    let mut remaining: Vec<f64> = totals.clone();
    let mut last_selected: Vec<Time> = arrivals.clone();
    let mut seq: Vec<u32> = vec![0; n_jobs];
    let mut done: Vec<bool> = vec![false; n_jobs];

    // Spawn agents.
    let (reply_tx, reply_rx) = mpsc::channel::<AgentReply>();
    let mut agent_tx: Vec<mpsc::Sender<ToAgent>> = Vec::with_capacity(n_jobs);
    let mut handles = Vec::with_capacity(n_jobs);
    for job in jobs {
        let (tx, rx) = mpsc::channel::<ToAgent>();
        agent_tx.push(tx);
        let jcfg = cfg.jasda.clone();
        let rtx = reply_tx.clone();
        handles.push(std::thread::spawn(move || agent_task(job, jcfg, rx, rtx)));
    }
    drop(reply_tx);

    let mut out = ProtocolOutcome {
        rounds: 0,
        announcements: 0,
        bids: 0,
        variants: 0,
        awards: 0,
        completed_jobs: 0,
        total_jobs: n_jobs,
        final_time: 0,
        wall: std::time::Duration::ZERO,
    };

    let period = cfg.engine.iteration_period;
    let mut now: Time = arrivals.iter().min().copied().unwrap_or(0);
    let mut events: BinaryHeap<std::cmp::Reverse<(PendingKey, usize)>> = BinaryHeap::new();
    // Slab of in-flight completions with slot reuse (same scheme as
    // SimEngine): memory stays O(outstanding), not O(total subjobs).
    let mut pending: Vec<Option<PendingDone>> = Vec::new();
    let mut free_slots: Vec<usize> = Vec::new();
    let mut event_seq = 0u64;

    for round in 0..max_rounds {
        out.rounds = round + 1;
        // 1. Fire due completions; report to agents + verify trust.
        while let Some(&std::cmp::Reverse((PendingKey(t, _), idx))) = events.peek() {
            if t > now {
                break;
            }
            events.pop();
            let p = pending[idx].take().expect("completion fired twice");
            free_slots.push(idx);
            let js = slot[&p.job];
            remaining[js] -= p.realized_work;
            if p.realized_end < p.reserved.end {
                cluster.slice_mut(p.slice).timeline.truncate(p.job, p.seq, p.realized_end);
            }
            // Ex-post verification (leader-side ground truth).
            let observed = [
                (p.realized_work / p.planned_work.max(1e-9)).clamp(0.0, 1.0)
                    * p.declared_phi[0],
                p.declared_phi[1],
                p.declared_phi[2],
                p.declared_phi[3],
            ];
            let h_obs: f64 = cfg
                .jasda
                .alpha
                .as_array()
                .iter()
                .zip(&observed)
                .map(|(a, o)| a * o)
                .sum();
            calibration.verify(p.job, &p.declared_phi, &observed, h_obs);
            let report = ToAgent::Completed(CompletionReport {
                planned_work: p.planned_work,
                realized_work: p.realized_work,
                at: p.realized_end,
            });
            let _ = agent_tx[js].send(report);
            if remaining[js] <= 1e-6 && !done[js] {
                done[js] = true;
                out.completed_jobs += 1;
            }
        }
        if out.completed_jobs == n_jobs {
            break;
        }

        // 2. Announce one window to every agent.
        let candidates = cluster.candidate_windows(
            now + cfg.jasda.announce_lead,
            cfg.jasda.announce_horizon,
            cfg.jasda.tau_min,
        );
        let window = match selector.select(
            cfg.jasda.window_policy,
            &candidates,
            &cluster,
            now,
            cfg.jasda.announce_horizon,
        ) {
            Some(i) => candidates[i],
            None => {
                now += period;
                continue;
            }
        };
        out.announcements += 1;
        for tx in &agent_tx {
            let _ = tx.send(ToAgent::Announce { round, now, window });
        }

        // 3. Collect one reply per agent (silent = empty variants).
        let mut pool: Vec<crate::job::Variant> = Vec::new();
        let mut replies = 0;
        while replies < n_jobs {
            match reply_rx.recv() {
                Ok(AgentReply::Bid { job: _, round: r, variants, done: _ }) => {
                    if r == round {
                        replies += 1;
                        if !variants.is_empty() {
                            out.bids += 1;
                            pool.extend(variants);
                        }
                    }
                }
                Err(_) => break,
            }
        }
        for (i, v) in pool.iter_mut().enumerate() {
            v.id = i as u32;
        }
        out.variants += pool.len() as u64;
        if pool.is_empty() {
            now += period;
            continue;
        }

        // 4. Score + clear (same pipeline as the in-process scheduler).
        let mut batch = ScoreBatch::with_bins(cfg.jasda.fmp_bins);
        batch.capacity = window.capacity_gb as f32;
        batch.theta = cfg.jasda.theta as f32;
        batch.lambda = cfg.jasda.lambda as f32;
        let alpha = cfg.jasda.alpha.as_array();
        let beta = cfg.jasda.beta.as_array();
        batch.alpha = alpha.map(|x| x as f32);
        batch.beta = beta.map(|x| x as f32);
        for v in &pool {
            let j = slot[&v.job];
            let age = if cfg.jasda.age_priority {
                let waited = now.saturating_sub(last_selected[j]);
                (waited as f64 / cfg.jasda.age_scale.max(1) as f64).min(1.0)
            } else {
                0.0
            };
            let (trust, hist) = if cfg.jasda.calibration {
                (calibration.trust_weight(v.job), calibration.hist_avg(v.job))
            } else {
                (1.0, 0.0)
            };
            batch.push(
                &v.fmp.mu,
                &v.fmp.sigma,
                [v.declared.phi[0], v.declared.phi[1], v.declared.phi[2], v.declared.phi[3]],
                [v.sys.util, v.sys.frag, age],
                trust,
                hist,
            );
        }
        let scored = scorer.score(&batch).expect("native scorer");
        let mut items = Vec::new();
        let mut item_to_pool = Vec::new();
        for (i, v) in pool.iter().enumerate() {
            if scored.eligible[i] && scored.score[i] > 0.0 {
                items.push(WisItem { interval: v.interval, score: scored.score[i] as f64 });
                item_to_pool.push(i);
            }
        }
        let sol = select_best_compatible(&items);

        // 5. Award + reserve + realize.
        let mut per_job_awards: std::collections::HashMap<JobId, Vec<u32>> =
            std::collections::HashMap::new();
        for &k in &sol.selected {
            let v = &pool[item_to_pool[k]];
            let j = slot[&v.job];
            let work = v.work.min(remaining[j].max(0.0));
            if work <= 1e-9 {
                continue;
            }
            let s = seq[j];
            seq[j] += 1;
            cluster
                .slice_mut(v.slice)
                .timeline
                .reserve(Reservation { job: v.job, subjob_seq: s, interval: v.interval })
                .expect("cleared variants are non-overlapping");
            last_selected[j] = now;
            out.awards += 1;
            per_job_awards.entry(v.job).or_default().push(v.id);

            let speed = cluster.slice(v.slice).speed();
            let realized_duration = trps[j].sample_duration(&mut rng, work, speed);
            let reserved_len = v.interval.len();
            let (realized_end, realized_work) = if realized_duration <= reserved_len {
                (v.interval.start + realized_duration, work)
            } else {
                (v.interval.end, work * reserved_len as f64 / realized_duration as f64)
            };
            let pd = PendingDone {
                job: v.job,
                slice: v.slice,
                seq: s,
                reserved: v.interval,
                realized_end,
                planned_work: work,
                realized_work,
                declared_phi: v.declared.phi,
            };
            let idx = match free_slots.pop() {
                Some(reused) => {
                    pending[reused] = Some(pd);
                    reused
                }
                None => {
                    pending.push(Some(pd));
                    pending.len() - 1
                }
            };
            event_seq += 1;
            events.push(std::cmp::Reverse((PendingKey(realized_end, event_seq), idx)));
        }
        for (job, variant_ids) in per_job_awards {
            let _ =
                agent_tx[slot[&job]].send(ToAgent::Awarded(Award { round, variant_ids, now }));
        }

        now += period;
    }

    // Drain outstanding completions for accounting.
    while let Some(std::cmp::Reverse((PendingKey(t, _), idx))) = events.pop() {
        let p = pending[idx].take().expect("completion fired twice");
        let js = slot[&p.job];
        remaining[js] -= p.realized_work;
        now = now.max(t);
        if remaining[js] <= 1e-6 && !done[js] {
            done[js] = true;
            out.completed_jobs += 1;
        }
    }

    for tx in &agent_tx {
        let _ = tx.send(ToAgent::Shutdown);
    }
    for h in handles {
        let _ = h.join();
    }
    out.final_time = now;
    out.wall = wall0.elapsed();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trp::{Phase, Trp};

    fn jobs(n: u32) -> Vec<Job> {
        (0..n)
            .map(|i| {
                let trp = Trp {
                    phases: vec![Phase::new(800.0, 4.0, 0.2, 0.1)],
                    duration_cv: 0.05,
                };
                Job::new(i, "p", (i as u64) * 100, trp, None, 1.0, 300.0, 0.0)
            })
            .collect()
    }

    fn cfg() -> SimConfig {
        let mut c = SimConfig::default();
        c.cluster.layout = "balanced".into();
        c.engine.iteration_period = 25;
        c.jasda.fmp_bins = 16;
        c
    }

    #[test]
    fn protocol_completes_all_jobs() {
        let out = run_protocol(cfg(), jobs(5), 100_000);
        assert_eq!(out.completed_jobs, 5, "{out:?}");
        assert!(out.announcements > 0);
        assert!(out.bids > 0);
        assert!(out.awards >= 5);
        assert!(out.variants >= out.bids);
    }

    #[test]
    fn protocol_handles_sparse_job_ids() {
        let mut js = jobs(3);
        js[0].id = 500;
        js[1].id = 7;
        js[2].id = 10_000;
        let out = run_protocol(cfg(), js, 100_000);
        assert_eq!(out.completed_jobs, 3, "{out:?}");
    }

    #[test]
    fn protocol_with_no_jobs_terminates() {
        let out = run_protocol(cfg(), vec![], 10);
        assert_eq!(out.completed_jobs, 0);
        assert_eq!(out.total_jobs, 0);
    }

    #[test]
    fn round_cap_respected() {
        let out = run_protocol(cfg(), jobs(3), 5);
        assert!(out.rounds <= 5);
    }
}
