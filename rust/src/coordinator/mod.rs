//! The bidirectional bid–response protocol runtime (paper §5.1(f) /
//! §6(e)): JASDA as an actual distributed negotiation between leader
//! shards (the scheduler side) and autonomous job-agent threads.
//!
//! The [`SimEngine`](crate::sim::SimEngine) calls job-side code as plain
//! functions; this module is the deployment-shaped variant where jobs are
//! *threads*: each agent owns its private job state and replies to
//! window announcements with bids; the leader owns the cluster, trust
//! state, clearing, and ground-truth realization. Messages are the only
//! coupling — exactly the information-visibility contract of §5.1(d)
//! (jobs see announced windows and their own awards, nothing else).
//!
//! # The three layers
//!
//! - **Transport** ([`transport`]): how messages move. A [`Transport`]
//!   trait with bounded per-agent queues and drop-don't-block
//!   backpressure; [`LoopbackTransport`] carries typed values over
//!   channels (default), [`FramedTransport`] carries length-prefixed
//!   byte frames through the hand-rolled [`wire`] codec, and
//!   [`SocketTransport`](socket::SocketTransport) carries those same
//!   frames over real TCP or Unix-domain sockets, the leader side
//!   served by a single poll-driven I/O thread (no thread per agent).
//! - **Shards** ([`shard`]): who decides. `jasda.shards` leader shards
//!   each own the slices with `slice % shards == shard` and run the
//!   shared [`ClearingEngine`](crate::jasda::clearing::ClearingEngine)
//!   on their own [`WorkerPool`](crate::jasda::pool::WorkerPool); agents
//!   bid at whichever shards announce feasible windows.
//! - **Reconciliation** ([`shard::ShardReconciler`]): why N shards stay
//!   consistent. Shards decide sequentially each round and later shards'
//!   bid pools are pre-filtered with the *identical* conflict predicate
//!   the engine uses across windows, so no job ever holds temporally
//!   overlapping awards — or double-awarded work — across shards.
//!
//! # One multi-shard round
//!
//! ```text
//!  leader (N shards)                           agents (thread per job)
//!    │                                               │
//!    │ 1. enumerate candidate windows off the        │
//!    │    cluster gap indexes; stripe them across    │
//!    │    shards (slice % N); cap each shard's set   │
//!    │    to its policy top-`announce_top` (full     │
//!    │    set again after a silent capped round)     │
//!    │                                               │
//!    │ 2. Announce { round, now, windows } ────────▶ │  one broadcast
//!    │    (bounded inbox: a slow agent's copy is     │  (loopback values,
//!    │     dropped, the round proceeds without it)   │   wire frames, or
//!    │                                               │   frames over a
//!    │                                               │   tcp/unix socket)
//!    │                                               │
//!    │                      3. each agent plans once │
//!    │                         per window *shape*    │
//!    │                         (shape-keyed plan     │
//!    │                         cache), stamps per    │
//!    │                         window, and replies   │
//!    │ ◀──────────── Bid { job, round, bids, done }  │  one reply each
//!    │                                               │
//!    │ 4. per shard, in shard order:                 │
//!    │      a. replay the policy selection loop      │
//!    │         over the shard's candidates (silent   │
//!    │         windows skipped), pre-filtering bids  │
//!    │         that conflict with earlier shards'    │
//!    │         awards this round                     │
//!    │      b. ClearingEngine on the shard's own     │
//!    │         WorkerPool: batched scoring, per-     │
//!    │         window WIS, cross-window              │
//!    │         reconciliation                        │
//!    │      c. record acceptances in the cross-      │
//!    │         shard reconciler                      │
//!    │                                               │
//!    │ 5. Awarded { round, variant_ids, now } ─────▶ │  winners only
//!    │    + reserve on slice timelines               │
//!    │    + realize ground truth (sampled durations) │
//!    │                                               │
//!    │    … later, when a reservation ends …         │
//!    │ 6. Completed { planned, realized, at } ─────▶ │  owner only
//!    │    + ex-post verification → calibration       │
//!    ▼                                               ▼
//! ```
//!
//! # Decision parity with the in-process scheduler
//!
//! [`run_reference`] is the single-process oracle: the **same** leader
//! environment (realization RNG, completion slab, calibration updates,
//! award clamping) but with decisions made by an embedded
//! [`JasdaScheduler`] over a leader-maintained job mirror, exactly as
//! the engine path would. `tests/properties.rs` asserts, on random
//! traces for K ∈ {1, 2, per-slice}, that [`run_protocol`] with
//! `shards=1` — over **either** transport — produces identical per-round
//! windows and awards to [`run_reference`], and that `shards ∈ {2, 4}`
//! never violates a conflict rule the single leader would have caught.
//! The protocol runtime is a *transport* for the paper's loop, not a
//! different scheduler.
//!
//! # Failure semantics
//!
//! With `jasda.round_timeout_ms > 0` the bid-collection phase of every
//! round runs under a hard wall-clock deadline, so agent failure —
//! injectable deterministically through [`faults`] (wrapped around the
//! in-process transports; applied directly at the connection layer by
//! the socket transport: crash = close + refuse reconnect, corrupt =
//! flip a byte on the stream, delay = hold the received frame) —
//! degrades only the faulty agent, never the round:
//!
//! ```text
//!  round r                                           deadline ──────┐
//!  leader ──Announce──┬───────────── collect ───────────────────────┤ clear with
//!                     │                                             │ whatever
//!  agent A ───────────┴── Bid(r) ──▶ counted                        │ arrived;
//!  agent B (crashed) ──── ∅          counted as a straggler at the  │ stragglers'
//!                                    deadline; its Bid(r) arriving  │ late bids
//!                                    next round is discarded by the │ discarded by
//!                                    round-tag check                │ the round tag
//!  agent C ────────────── garbage ─▶ Rejected{C}: counted as C's    │
//!                                    reply (collection cannot       │
//!                                    wedge) + fed to C's            │
//!                                    quarantine streak              ▼
//! ```
//!
//! An agent whose sends fail repeatedly (3 consecutive) or whose frames
//! keep failing wire decode is **quarantined**: skipped in broadcasts
//! (no deadline budget wasted on it) and probed with exponential
//! backoff (2, 4, … up to 64 rounds). A probe that lands carries
//! [`ToAgent::Resync`] — the leader's ground-truth work accounting — so
//! a restarted or long-partitioned agent overwrites its stale
//! `done_work`/`reserved_work` cursors and bids consistently from the
//! next announce on. Short outages that dodge the quarantine threshold
//! are healed the same way: an agent that missed any state-bearing
//! message (`Completed`/`Awarded`) is marked dirty and probed every
//! round until a `Resync` lands, so a transiently unreachable agent can
//! never under-bid forever on cursors it failed to hear about.
//! [`ProtocolOutcome`] counts every step
//! (`rounds_timed_out`, `stragglers`, `frames_rejected`,
//! `agents_quarantined`, `readmissions`). With the deadline off
//! (default) none of this machinery can trigger and the run stays
//! bit-identical to the pre-deadline coordinator.

pub mod faults;
pub mod messages;
pub mod shard;
#[cfg(unix)]
pub mod socket;
pub mod transport;
pub mod wire;

use crate::config::{SimConfig, TransportKind};
use crate::jasda::calibration::Calibration;
use crate::jasda::clearing::{Accepted, RowCtx};
use crate::jasda::window::{announce_target, shard_round_policy, WindowSelector};
use crate::jasda::JasdaScheduler;
use crate::job::variants::{plan_chunks, stamp_variants, PlannedChunk};
use crate::job::{age_factor, Job, JobSet, JobState, Variant};
use crate::mig::{Cluster, PartitionLayout, Reservation, Window};
use crate::sim::{Rng, Scheduler, SubjobRecord};
use crate::types::{Interval, JobId, SliceId, Time};
use faults::{FaultPlan, FaultyTransport};
use messages::{AgentReply, Award, CompletionReport, Resync, ToAgent};
use shard::{make_shards, shard_of, ShardReconciler};
use std::collections::BinaryHeap;
use std::sync::Arc;
use transport::{FramedTransport, LoopbackTransport, Recv, Transport, DEFAULT_AGENT_QUEUE};

/// Outcome of a protocol run.
#[derive(Debug, Clone)]
pub struct ProtocolOutcome {
    /// Rounds (announcement cycles) executed.
    pub rounds: u64,
    /// Announce broadcasts sent (rounds with at least one candidate).
    pub announcements: u64,
    /// Rounds in which at least one window gathered bids and cleared.
    pub rounds_with_bids: u64,
    /// Windows that gathered bids and were cleared.
    pub windows_announced: u64,
    /// Selected candidates skipped because they drew no bids.
    pub windows_silent: u64,
    /// Bid messages with at least one non-empty per-window portfolio.
    pub bids: u64,
    /// Variants received in bids (across all candidate windows).
    pub variants: u64,
    /// Awards granted.
    pub awards: u64,
    /// Eligible variants dropped by cross-window reconciliation (within
    /// one shard's clearing).
    pub cross_window_conflicts: u64,
    /// Bid variants excluded before a shard's clearing because their job
    /// already won a conflicting award in an earlier shard this round
    /// (always 0 with `shards = 1`).
    pub cross_shard_conflicts: u64,
    /// Candidate windows withheld from broadcasts by `announce_top`.
    pub windows_suppressed: u64,
    /// Rounds in which a shard re-broadcast its full candidate set
    /// because its previous capped broadcast drew no bids.
    pub announce_fallbacks: u64,
    /// Messages dropped by transport backpressure (bounded agent
    /// inboxes) or dead agents.
    pub sends_dropped: u64,
    /// Rounds whose bid collection hit the `round_timeout_ms` deadline
    /// and cleared with a partial bid set (0 with the deadline off).
    pub rounds_timed_out: u64,
    /// Delivered announcements that had not been answered when their
    /// round's deadline expired, summed over timed-out rounds.
    pub stragglers: u64,
    /// Reply frames that failed wire decoding (each counted as its
    /// sender's reply so collection cannot wedge on a corrupt frame).
    pub frames_rejected: u64,
    /// Agents quarantined after repeated send failures or rejected
    /// frames (counts entries into quarantine, so an agent that relapses
    /// after re-admission is counted again).
    pub agents_quarantined: u64,
    /// Quarantined agents re-admitted by a delivered Resync probe.
    pub readmissions: u64,
    /// Bids naming a job id the leader does not know (counted as
    /// replies, then skipped).
    pub unknown_job_bids: u64,
    /// Shard-rounds in which the exact global clearing solver ran
    /// (`jasda.clearing = "exact"` with more than one announced window;
    /// 0 under `clearing=greedy`).
    pub exact_rounds: u64,
    /// Branch-and-bound nodes evaluated by the exact solver, summed over
    /// shard-rounds.
    pub exact_nodes: u64,
    /// Exact solves cut short by the `jasda.clearing_budget_ms` budget
    /// (each fell back to the best feasible solution found so far, at
    /// worst the greedy incumbent).
    pub exact_budget_exhausted: u64,
    /// Shard-rounds where the exact solution strictly improved on the
    /// greedy incumbent's welfare.
    pub exact_improved: u64,
    /// Wall time spent in the exact solver, summed over shard-rounds.
    pub exact_ns: u64,
    /// Jobs completed.
    pub completed_jobs: usize,
    /// Total jobs.
    pub total_jobs: usize,
    /// Final virtual time.
    pub final_time: Time,
    /// Wall-clock duration of the run.
    pub wall: std::time::Duration,
    /// Leader-side decision wall time (selection replay + clearing +
    /// award application), summed over rounds.
    pub decision_ns: u64,
    /// Worst single-round leader decision time.
    pub max_round_decision_ns: u64,
}

impl ProtocolOutcome {
    fn new(total_jobs: usize) -> Self {
        ProtocolOutcome {
            rounds: 0,
            announcements: 0,
            rounds_with_bids: 0,
            windows_announced: 0,
            windows_silent: 0,
            bids: 0,
            variants: 0,
            awards: 0,
            cross_window_conflicts: 0,
            cross_shard_conflicts: 0,
            windows_suppressed: 0,
            announce_fallbacks: 0,
            sends_dropped: 0,
            rounds_timed_out: 0,
            stragglers: 0,
            frames_rejected: 0,
            agents_quarantined: 0,
            readmissions: 0,
            unknown_job_bids: 0,
            exact_rounds: 0,
            exact_nodes: 0,
            exact_budget_exhausted: 0,
            exact_improved: 0,
            exact_ns: 0,
            completed_jobs: 0,
            total_jobs,
            final_time: 0,
            wall: std::time::Duration::ZERO,
            decision_ns: 0,
            max_round_decision_ns: 0,
        }
    }

    /// Mean leader decision latency per round with at least one
    /// candidate (ns).
    pub fn decision_ns_per_round(&self) -> f64 {
        if self.announcements == 0 {
            return 0.0;
        }
        self.decision_ns as f64 / self.announcements as f64
    }
}

/// One award in a round's decision trace.
#[derive(Debug, Clone, PartialEq)]
pub struct AwardRec {
    /// Winning job.
    pub job: JobId,
    /// Slice reserved.
    pub slice: SliceId,
    /// Reserved interval.
    pub interval: Interval,
    /// Work committed (after the leader's remaining-work clamp).
    pub work: f64,
}

/// Decision record of one round that cleared at least one window — the
/// unit compared by the protocol-vs-scheduler parity property tests.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundDecision {
    /// Round counter.
    pub round: u64,
    /// Leader time at the decision.
    pub now: Time,
    /// Windows cleared this round, in announcement order (shard order,
    /// then each shard's selection order).
    pub windows: Vec<Window>,
    /// Awards, in commitment (reconciliation) order.
    pub awards: Vec<AwardRec>,
}

/// Job-agent endpoint logic: owns its job, answers announcements
/// autonomously. Transport-agnostic — `recv` blocks for the next
/// leader message (`None` = disconnected) and `send` delivers a reply
/// (`false` = leader gone), so the identical agent drives both the
/// loopback channels and the framed byte path.
///
/// The agent mirrors the scheduler-side generation pipeline: one
/// [`plan_chunks`] call per distinct window *shape* `(c_k, speed, Δt)`
/// (the agent-local shape-keyed plan cache), then one cheap
/// [`stamp_variants`] per announced window — identical arithmetic to
/// `generate_variants`, so agent bids are bit-identical to what the
/// in-process scheduler would generate from the same job state.
fn agent_loop<R, S>(mut job: Job, cfg: crate::config::JasdaConfig, mut recv: R, mut send: S)
where
    R: FnMut() -> Option<ToAgent>,
    S: FnMut(AgentReply) -> bool,
{
    // Variants proposed in the current round (flattened across windows),
    // kept so awards can be resolved to work amounts: the leader echoes
    // the *agent-assigned* variant ids back.
    let mut last_bid: Vec<Variant> = Vec::new();
    // Agent-local plan cache, cleared every round (plans depend on the
    // job's work cursor, which only moves on award/completion).
    let mut plans: std::collections::HashMap<(u64, u64, u64), Vec<PlannedChunk>> =
        std::collections::HashMap::new();
    while let Some(msg) = recv() {
        match msg {
            ToAgent::Announce { round, now, windows } => {
                if job.state == JobState::Future && job.arrival <= now {
                    job.state = JobState::Active;
                }
                last_bid.clear();
                plans.clear();
                let mut bids: Vec<Vec<Variant>> = Vec::with_capacity(windows.len());
                let mut next_id: u32 = 0;
                for w in windows.iter() {
                    let key = (w.capacity_gb.to_bits(), w.speed.to_bits(), w.delta_t());
                    let plan = plans.entry(key).or_insert_with(|| {
                        plan_chunks(&job, &cfg, w.capacity_gb, w.speed, w.delta_t())
                    });
                    let mut vs = Vec::with_capacity(plan.len());
                    stamp_variants(&job, w, &cfg, plan, &mut vs);
                    for v in &mut vs {
                        v.id = next_id;
                        next_id += 1;
                    }
                    last_bid.extend(vs.iter().cloned());
                    bids.push(vs);
                }
                if !last_bid.is_empty() {
                    job.bids_submitted += 1;
                }
                let reply = AgentReply::Bid {
                    job: job.id,
                    round,
                    bids,
                    done: job.state == JobState::Completed,
                };
                if !send(reply) {
                    return;
                }
            }
            ToAgent::Awarded(Award { round: _, variant_ids, now }) => {
                for vid in variant_ids {
                    if let Some(v) = last_bid.iter().find(|v| v.id == vid) {
                        job.reserved_work += v.work.min(job.pending_work());
                        job.last_selected = now;
                        job.last_slice = Some(v.slice);
                    }
                }
            }
            ToAgent::Completed(CompletionReport { planned_work, realized_work, at }) => {
                job.reserved_work = (job.reserved_work - planned_work).max(0.0);
                job.done_work += realized_work;
                if job.remaining_work() <= 1e-6 && job.state == JobState::Active {
                    job.state = JobState::Completed;
                    job.completed_at = Some(at);
                }
            }
            ToAgent::Resync(Resync { round: _, now, done_work, outstanding_awards }) => {
                // Re-admission after quarantine: the agent may have
                // missed any number of awards and completions, so its
                // cursors are replaced wholesale with the leader's
                // ground truth. Pending per-round state is stale too.
                if job.state == JobState::Future && job.arrival <= now {
                    job.state = JobState::Active;
                }
                job.done_work = done_work;
                job.reserved_work = outstanding_awards;
                last_bid.clear();
                plans.clear();
                if job.remaining_work() <= 1e-6 && job.state == JobState::Active {
                    job.state = JobState::Completed;
                    job.completed_at = Some(now);
                }
            }
            ToAgent::Shutdown => return,
        }
    }
}

/// Consecutive send failures (or rejected frames) before an agent is
/// quarantined. One transient inbox-full drop should not eject an
/// agent; three in a row means it is not draining at all.
const QUARANTINE_AFTER: u32 = 3;
/// First re-admission probe fires this many rounds after quarantine.
const PROBE_BACKOFF_START: u64 = 2;
/// Probe backoff doubles up to this cap (rounds).
const PROBE_BACKOFF_MAX: u64 = 64;

/// Leader-side failure tracking for one agent. Healthy agents stay at
/// the default state forever; the struct only changes when sends fail
/// or frames reject, so the fault-free path is untouched.
#[derive(Debug, Clone, Copy, Default)]
struct AgentHealth {
    /// Consecutive failed sends (reset by any delivered send).
    send_failures: u32,
    /// Consecutive rejected reply frames (reset by any decoded reply).
    rejected_frames: u32,
    /// Skipped in broadcasts; reachable only through probes.
    quarantined: bool,
    /// A state-bearing message (`Completed`/`Awarded`) failed to
    /// deliver, so the agent's cursors may have diverged from the
    /// leader's ground truth; it is healed with a `Resync` at the next
    /// successful contact. (A dropped `Announce` costs only that
    /// round's bid and does not set this.)
    dirty: bool,
    /// Round of the next re-admission probe.
    next_probe: u64,
    /// Current probe backoff (rounds).
    backoff: u64,
}

impl AgentHealth {
    /// Record a failed send; returns `true` when this crosses the
    /// quarantine threshold (caller enters quarantine + counts it).
    fn on_send_failed(&mut self) -> bool {
        self.send_failures += 1;
        !self.quarantined && self.send_failures >= QUARANTINE_AFTER
    }

    /// Record a rejected frame; same contract as [`Self::on_send_failed`].
    fn on_frame_rejected(&mut self) -> bool {
        self.rejected_frames += 1;
        !self.quarantined && self.rejected_frames >= QUARANTINE_AFTER
    }

    fn enter_quarantine(&mut self, round: u64) {
        self.quarantined = true;
        self.backoff = PROBE_BACKOFF_START;
        self.next_probe = round + self.backoff;
    }

    /// A probe failed to deliver: back off exponentially.
    fn probe_failed(&mut self, round: u64) {
        self.backoff = (self.backoff * 2).min(PROBE_BACKOFF_MAX);
        self.next_probe = round + self.backoff;
    }

    /// A probe delivered: the agent is healthy again.
    fn readmit(&mut self) {
        *self = AgentHealth::default();
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct PendingKey(Time, u64);

/// An in-flight subjob completion, realized at award time.
struct PendingDone {
    job: JobId,
    slice: u32,
    seq: u32,
    reserved: Interval,
    realized_end: Time,
    planned_work: f64,
    realized_work: f64,
    declared_phi: [f64; 4],
}

/// A completion that just fired, handed to the run-loop's sink so the
/// protocol path can message the owning agent and the reference path can
/// feed the embedded scheduler's verification hook.
struct Fired {
    slot: usize,
    job: JobId,
    slice: SliceId,
    seq: u32,
    reserved: Interval,
    realized_end: Time,
    planned_work: f64,
    realized_work: f64,
    declared_phi: [f64; 4],
    observed_phi: [f64; 4],
}

/// Everything the leader owns besides decision-making: the cluster and
/// its ground truth, per-job bookkeeping, the completion slab, and the
/// trust state. Shared verbatim between [`run_protocol`] (decisions via
/// message-passing agents, 1..N shards) and [`run_reference`] (decisions
/// via an embedded [`JasdaScheduler`]), which is what makes the two runs
/// comparable round for round.
struct LeaderEnv {
    cluster: Cluster,
    rng: Rng,
    /// Population-order read-only job facts. `slot` maps a (possibly
    /// sparse, trace-supplied) JobId to its vector index so ids are
    /// never used as indices.
    slot: std::collections::BTreeMap<JobId, usize>,
    trps: Vec<crate::trp::Trp>,
    /// Total work per job, fixed at start (for Resync's `done_work`:
    /// total − remaining is the leader's realized-work ground truth).
    total_work: Vec<f64>,
    remaining: Vec<f64>,
    last_selected: Vec<Time>,
    seq: Vec<u32>,
    done: Vec<bool>,
    completed_jobs: usize,
    calibration: Calibration,
    /// Slab of in-flight completions with slot reuse (same scheme as
    /// SimEngine): memory stays O(outstanding), not O(total subjobs).
    events: BinaryHeap<std::cmp::Reverse<(PendingKey, usize)>>,
    pending: Vec<Option<PendingDone>>,
    free_slots: Vec<usize>,
    event_seq: u64,
}

impl LeaderEnv {
    fn new(cfg: &SimConfig, jobs: &[Job]) -> Self {
        let layout = PartitionLayout::stock(&cfg.cluster.layout).expect("layout");
        let slot: std::collections::BTreeMap<JobId, usize> =
            jobs.iter().enumerate().map(|(i, j)| (j.id, i)).collect();
        assert_eq!(slot.len(), jobs.len(), "protocol runtime requires unique job ids");
        LeaderEnv {
            cluster: Cluster::new(cfg.cluster.num_gpus, &layout),
            rng: Rng::new(cfg.seed).fork(0xC00D),
            slot,
            trps: jobs.iter().map(|j| j.trp.clone()).collect(),
            total_work: jobs.iter().map(|j| j.total_work()).collect(),
            remaining: jobs.iter().map(|j| j.total_work()).collect(),
            last_selected: jobs.iter().map(|j| j.arrival).collect(),
            seq: vec![0; jobs.len()],
            done: vec![false; jobs.len()],
            completed_jobs: 0,
            calibration: Calibration::new(
                jobs.len(),
                cfg.jasda.kappa,
                cfg.jasda.gamma,
                cfg.jasda.alpha.as_array(),
            ),
            events: BinaryHeap::new(),
            pending: Vec::new(),
            free_slots: Vec::new(),
            event_seq: 0,
        }
    }

    /// Fire every completion due at or before `now`: release/truncate the
    /// reservation, run ex-post verification (Eq. (6)–(8)) into the trust
    /// state, update remaining-work accounting, and hand the event to
    /// `sink` (protocol: report to the owning agent; reference: feed the
    /// embedded scheduler's verification hook and the job mirror).
    fn fire_due(&mut self, now: Time, alpha: &[f64; 4], sink: &mut dyn FnMut(&Fired)) {
        while let Some(&std::cmp::Reverse((PendingKey(t, _), idx))) = self.events.peek() {
            if t > now {
                break;
            }
            self.events.pop();
            let p = self.pending[idx].take().expect("completion fired twice");
            self.free_slots.push(idx);
            let js = self.slot[&p.job];
            self.remaining[js] -= p.realized_work;
            if p.realized_end < p.reserved.end {
                self.cluster
                    .slice_mut(p.slice)
                    .timeline
                    .truncate(p.job, p.seq, p.realized_end);
            }
            // Ex-post verification (leader-side ground truth).
            let observed = [
                (p.realized_work / p.planned_work.max(1e-9)).clamp(0.0, 1.0)
                    * p.declared_phi[0],
                p.declared_phi[1],
                p.declared_phi[2],
                p.declared_phi[3],
            ];
            let h_obs: f64 = alpha.iter().zip(&observed).map(|(a, o)| a * o).sum();
            self.calibration.verify(p.job, &p.declared_phi, &observed, h_obs);
            sink(&Fired {
                slot: js,
                job: p.job,
                slice: p.slice,
                seq: p.seq,
                reserved: p.reserved,
                realized_end: p.realized_end,
                planned_work: p.planned_work,
                realized_work: p.realized_work,
                declared_phi: p.declared_phi,
                observed_phi: observed,
            });
            if self.remaining[js] <= 1e-6 && !self.done[js] {
                self.done[js] = true;
                self.completed_jobs += 1;
            }
        }
    }

    /// Commit one accepted variant: clamp to the job's remaining work,
    /// reserve the interval on the slice timeline, and realize the
    /// ground-truth duration (sampling the leader RNG). Returns the
    /// clamped planned work, or `None` when the job has nothing left to
    /// run (the award is dropped, exactly as before the K-window port).
    #[allow(clippy::too_many_arguments)]
    fn award(
        &mut self,
        now: Time,
        job: JobId,
        slice: SliceId,
        interval: Interval,
        work: f64,
        declared_phi: [f64; 4],
    ) -> Option<f64> {
        let j = self.slot[&job];
        let work = work.min(self.remaining[j].max(0.0));
        if work <= 1e-9 {
            return None;
        }
        let s = self.seq[j];
        self.seq[j] += 1;
        self.cluster
            .slice_mut(slice)
            .timeline
            .reserve(Reservation { job, subjob_seq: s, interval })
            .expect("cleared variants are non-overlapping");
        self.last_selected[j] = now;

        let speed = self.cluster.slice(slice).speed();
        let realized_duration = self.trps[j].sample_duration(&mut self.rng, work, speed);
        let reserved_len = interval.len();
        let (realized_end, realized_work) = if realized_duration <= reserved_len {
            (interval.start + realized_duration, work)
        } else {
            (interval.end, work * reserved_len as f64 / realized_duration as f64)
        };
        let pd = PendingDone {
            job,
            slice,
            seq: s,
            reserved: interval,
            realized_end,
            planned_work: work,
            realized_work,
            declared_phi,
        };
        let idx = match self.free_slots.pop() {
            Some(reused) => {
                self.pending[reused] = Some(pd);
                reused
            }
            None => {
                self.pending.push(Some(pd));
                self.pending.len() - 1
            }
        };
        self.event_seq += 1;
        self.events.push(std::cmp::Reverse((PendingKey(realized_end, self.event_seq), idx)));
        Some(work)
    }

    /// Ground truth for a re-admission probe: work realized so far and
    /// planned work currently in flight (outstanding awards) for the
    /// job in `slot` — exactly the two cursors an agent's bids must be
    /// consistent with.
    fn resync_state(&self, slot: usize) -> (f64, f64) {
        let done = (self.total_work[slot] - self.remaining[slot]).max(0.0);
        let outstanding: f64 = self
            .pending
            .iter()
            .flatten()
            .filter(|p| self.slot[&p.job] == slot)
            .map(|p| p.planned_work)
            .sum();
        (done, outstanding)
    }

    /// Drain outstanding completions for final accounting; returns the
    /// advanced virtual time.
    fn drain(&mut self, mut now: Time) -> Time {
        while let Some(std::cmp::Reverse((PendingKey(t, _), idx))) = self.events.pop() {
            let p = self.pending[idx].take().expect("completion fired twice");
            let js = self.slot[&p.job];
            self.remaining[js] -= p.realized_work;
            now = now.max(t);
            if self.remaining[js] <= 1e-6 && !self.done[js] {
                self.done[js] = true;
                self.completed_jobs += 1;
            }
        }
        now
    }
}

/// One shard's selection replay: the in-process scheduler's announce
/// loop (policy pick → silent skip → per-slice retain → stop at K),
/// operating on the bids already collected from the agents. Appends the
/// per-window pool rows in population (= bidder) order, so pool layout is
/// identical to the in-process [`Scheduler::iterate`] layout.
///
/// `candidates` is the shard's broadcast slice, starting at position
/// `cand_base` of the combined broadcast; `bids[slot][cand_base + i]` is
/// job `slot`'s portfolio for shard candidate `i`. `keep` is the
/// cross-shard pre-filter: variants it rejects never enter the pool (and
/// are counted in the returned `filtered`).
///
/// Returns `(announced, window_rows, silent, filtered)`; `pool` and
/// `agent_vid` (the agent-assigned id of each pool row, for award
/// echoes) are appended in place, with `window_rows` indexing the
/// absolute `pool`.
#[allow(clippy::too_many_arguments)]
fn replay_selection(
    selector: &mut WindowSelector,
    policy: crate::config::WindowPolicy,
    cluster: &Cluster,
    now: Time,
    horizon: u64,
    k_target: usize,
    per_slice: bool,
    candidates: &[Window],
    cand_base: usize,
    bids: &[Vec<Vec<Variant>>],
    pool: &mut Vec<Variant>,
    agent_vid: &mut Vec<u32>,
    keep: &mut dyn FnMut(&Variant) -> bool,
) -> (Vec<Window>, Vec<(usize, usize)>, u64, u64) {
    let mut work: Vec<Window> = candidates.to_vec();
    let mut orig: Vec<usize> = (0..candidates.len()).collect();
    let mut announced: Vec<Window> = Vec::new();
    let mut window_rows: Vec<(usize, usize)> = Vec::new();
    let mut silent = 0u64;
    let mut filtered = 0u64;
    while announced.len() < k_target {
        let idx = match selector.select(policy, &work, cluster, now, horizon) {
            Some(i) => i,
            None => break,
        };
        let window = work.swap_remove(idx);
        let cand = cand_base + orig.swap_remove(idx);

        let row0 = pool.len();
        for per_job in bids {
            for v in &per_job[cand] {
                if !keep(v) {
                    filtered += 1;
                    continue;
                }
                agent_vid.push(v.id);
                pool.push(v.clone());
            }
        }
        if pool.len() == row0 {
            // Silent window: skip it; it is not a real announcement.
            silent += 1;
            continue;
        }
        window_rows.push((row0, pool.len()));
        if per_slice {
            // One window per slice: further candidates on this slice are
            // out of this round.
            let slice = window.slice;
            let mut i = 0;
            while i < work.len() {
                if work[i].slice == slice {
                    work.swap_remove(i);
                    orig.swap_remove(i);
                } else {
                    i += 1;
                }
            }
        }
        announced.push(window);
    }
    (announced, window_rows, silent, filtered)
}

/// Run the full protocol: spawn one agent thread per job behind the
/// configured transport, drive multi-window announcement rounds across
/// `jasda.shards` leader shards until all jobs complete (or
/// `max_rounds`).
pub fn run_protocol(cfg: SimConfig, jobs: Vec<Job>, max_rounds: u64) -> ProtocolOutcome {
    run_protocol_traced(cfg, jobs, max_rounds, None)
}

/// [`run_protocol`] with an optional per-round decision trace (used by
/// the decision-parity property tests; `None` skips all recording).
pub fn run_protocol_traced(
    cfg: SimConfig,
    jobs: Vec<Job>,
    max_rounds: u64,
    mut trace: Option<&mut Vec<RoundDecision>>,
) -> ProtocolOutcome {
    let wall0 = std::time::Instant::now();
    let n_jobs = jobs.len();
    let mut env = LeaderEnv::new(&cfg, &jobs);
    let alpha = cfg.jasda.alpha.as_array();
    let shards_n = cfg.jasda.shards.max(1);
    let mut shards = make_shards(shards_n, cfg.jasda.parallel);
    let mut reconciler = ShardReconciler::new();

    // Spawn agents behind the configured transport. One seeded fault
    // plan serves both injection styles below.
    let plan = if cfg.jasda.faults.enabled() {
        FaultPlan::random(&cfg.jasda.faults, n_jobs)
    } else {
        FaultPlan::default()
    };
    let mut transport: Box<dyn Transport> = match cfg.jasda.transport {
        TransportKind::Loopback => {
            Box::new(LoopbackTransport::spawn(jobs, &cfg.jasda, DEFAULT_AGENT_QUEUE))
        }
        TransportKind::Framed => {
            Box::new(FramedTransport::spawn(jobs, &cfg.jasda, DEFAULT_AGENT_QUEUE))
        }
        // The socket transport applies the plan itself, at the
        // connection layer (crash = close, corrupt = flip a stream
        // byte, delay = hold the frame) — no wrapper.
        #[cfg(unix)]
        TransportKind::Tcp | TransportKind::Unix => {
            Box::new(socket::SocketTransport::spawn(jobs, &cfg.jasda, plan.clone()))
        }
        #[cfg(not(unix))]
        TransportKind::Tcp | TransportKind::Unix => {
            panic!("socket transports require a Unix target")
        }
    };
    // Fault injection wraps the in-process transports, so the leader
    // below runs the identical code path with and without adversity
    // (config validation guarantees a round deadline exists whenever
    // faults are on).
    if cfg.jasda.faults.enabled()
        && !matches!(cfg.jasda.transport, TransportKind::Tcp | TransportKind::Unix)
    {
        transport = Box::new(FaultyTransport::new(transport, plan, env.slot.clone()));
    }

    let mut out = ProtocolOutcome::new(n_jobs);
    let period = cfg.engine.iteration_period;
    let mut now: Time = env.last_selected.iter().min().copied().unwrap_or(0);
    // Per-round bid store: bids_by_slot[slot][cand] = that job's
    // portfolio for broadcast candidate `cand`.
    let mut bids_by_slot: Vec<Vec<Vec<Variant>>> = vec![Vec::new(); n_jobs];
    let mut pool: Vec<Variant> = Vec::new();
    let mut agent_vid: Vec<u32> = Vec::new();
    let mut cand_scratch: Vec<Window> = Vec::new();
    let mut shard_cands: Vec<Vec<Window>> = vec![Vec::new(); shards_n];
    let mut shard_ranges: Vec<(usize, usize)> = vec![(0, 0); shards_n];
    let mut dropped: Vec<usize> = Vec::new();
    // Per-agent failure tracking and the broadcast skip mask it feeds
    // (all-healthy and never written on the fault-free path).
    let mut health: Vec<AgentHealth> = vec![AgentHealth::default(); n_jobs];
    let mut skip: Vec<bool> = vec![false; n_jobs];

    for round in 0..max_rounds {
        out.rounds = round + 1;
        // 1. Fire due completions; report to the owning agents.
        // Quarantined agents get nothing (their Resync probe will carry
        // the consolidated ground truth instead); a failed send feeds
        // the owner's quarantine streak.
        let transport_ref = &mut transport;
        let out_ref = &mut out;
        let health_ref = &mut health;
        env.fire_due(now, &alpha, &mut |f: &Fired| {
            if health_ref[f.slot].quarantined {
                out_ref.sends_dropped += 1;
                return;
            }
            let report = ToAgent::Completed(CompletionReport {
                planned_work: f.planned_work,
                realized_work: f.realized_work,
                at: f.realized_end,
            });
            if transport_ref.send(f.slot, &report) {
                health_ref[f.slot].send_failures = 0;
            } else {
                out_ref.sends_dropped += 1;
                health_ref[f.slot].dirty = true;
                if health_ref[f.slot].on_send_failed() {
                    health_ref[f.slot].enter_quarantine(round);
                    out_ref.agents_quarantined += 1;
                }
            }
        });
        out.completed_jobs = env.completed_jobs;
        if env.completed_jobs == n_jobs {
            break;
        }

        // 1b. Resync probes (before candidate enumeration, so
        // candidate-less rounds cannot starve them). Quarantined agents
        // are probed on their exponential backoff; dirty agents (a
        // state-bearing send failed, their cursors may have diverged)
        // are probed every round until one lands. A delivered probe
        // carries the leader's ground truth and restores the agent to
        // full health; a failed one backs off (quarantined) or feeds
        // the failure streak (dirty).
        for slot in 0..n_jobs {
            if env.done[slot] {
                continue;
            }
            let due = if health[slot].quarantined {
                round >= health[slot].next_probe
            } else {
                health[slot].dirty
            };
            if !due {
                continue;
            }
            let (done_work, outstanding_awards) = env.resync_state(slot);
            let msg = ToAgent::Resync(Resync { round, now, done_work, outstanding_awards });
            if transport.send(slot, &msg) {
                out.readmissions += u64::from(health[slot].quarantined);
                health[slot].readmit();
            } else if health[slot].quarantined {
                health[slot].probe_failed(round);
            } else {
                out.sends_dropped += 1;
                if health[slot].on_send_failed() {
                    health[slot].enter_quarantine(round);
                    out.agents_quarantined += 1;
                }
            }
        }

        // 2. Enumerate candidate windows, stripe them across shards, and
        // apply each shard's `announce_top` cap (with the silence
        // fallback). The combined broadcast is the per-shard subsets
        // concatenated in shard order, so every shard's candidates form
        // one contiguous range.
        env.cluster.collect_windows(
            now + cfg.jasda.announce_lead,
            cfg.jasda.announce_horizon,
            cfg.jasda.tau_min,
            &mut cand_scratch,
        );
        if cand_scratch.is_empty() {
            now += period;
            continue;
        }
        for list in shard_cands.iter_mut() {
            list.clear();
        }
        for &w in &cand_scratch {
            shard_cands[shard_of(w.slice, shards_n)].push(w);
        }
        let top = cfg.jasda.announce_top;
        let mut combined: Vec<Window> = Vec::with_capacity(cand_scratch.len());
        for s in 0..shards_n {
            let cands = &shard_cands[s];
            let c0 = combined.len();
            if top == 0 || cands.len() <= top {
                combined.extend_from_slice(cands);
            } else if shards[s].last_round_silent {
                // The previous capped broadcast drew nothing: offer the
                // full set so the cap cannot starve an unranked window.
                out.announce_fallbacks += 1;
                combined.extend_from_slice(cands);
            } else {
                // Rank with a *cloned* selector: persistent policy state
                // (the round-robin cursor) must only advance in the real
                // selection replay below.
                let (policy, _) =
                    shard_round_policy(&cfg.jasda, &env.cluster, now, s, shards_n);
                let mut ranker = shards[s].selector.clone();
                let mut work = cands.clone();
                for _ in 0..top {
                    match ranker.select(
                        policy,
                        &work,
                        &env.cluster,
                        now,
                        cfg.jasda.announce_horizon,
                    ) {
                        Some(i) => combined.push(work.swap_remove(i)),
                        None => break,
                    }
                }
                out.windows_suppressed += work.len() as u64;
            }
            shard_ranges[s] = (c0, combined.len());
        }
        if combined.is_empty() {
            now += period;
            continue;
        }
        out.announcements += 1;

        // 3. One broadcast (bounded inboxes: a slow agent's copy is
        // dropped and the round proceeds without its bids; quarantined
        // agents are skipped outright), then collect one reply per
        // *delivered* announcement — under the round deadline when
        // `round_timeout_ms` is set.
        let windows = Arc::new(combined);
        let announce =
            ToAgent::Announce { round, now, windows: Arc::clone(&windows) };
        for (slot, s) in skip.iter_mut().enumerate() {
            *s = health[slot].quarantined;
        }
        let delivered = transport.broadcast(&announce, &skip, &mut dropped);
        out.sends_dropped += dropped.len() as u64;
        // A delivered broadcast resets the owner's failure streak; a
        // dropped one extends it (only agents that were actually
        // attempted — skipped ones keep their state untouched).
        for slot in 0..n_jobs {
            if !skip[slot] && !dropped.contains(&slot) {
                health[slot].send_failures = 0;
            }
        }
        for &slot in &dropped {
            if health[slot].on_send_failed() {
                health[slot].enter_quarantine(round);
                out.agents_quarantined += 1;
            }
        }
        for b in bids_by_slot.iter_mut() {
            b.clear();
            b.resize(windows.len(), Vec::new());
        }
        let deadline = if cfg.jasda.round_timeout_ms > 0 {
            Some(
                std::time::Instant::now()
                    + std::time::Duration::from_millis(cfg.jasda.round_timeout_ms),
            )
        } else {
            None
        };
        let mut replies = 0usize;
        while replies < delivered {
            match transport.recv_deadline(deadline) {
                Recv::Msg(AgentReply::Bid { job, round: r, bids, done: _ }) => {
                    if r != round {
                        // Straggler from a timed-out round: not part of
                        // this round's accounting at all.
                        continue;
                    }
                    replies += 1;
                    let Some(&slot) = env.slot.get(&job) else {
                        out.unknown_job_bids += 1;
                        continue;
                    };
                    health[slot].rejected_frames = 0;
                    let n: usize = bids.iter().map(|b| b.len()).sum();
                    if n > 0 {
                        out.bids += 1;
                        out.variants += n as u64;
                    }
                    if bids.len() == windows.len() {
                        bids_by_slot[slot] = bids;
                    }
                }
                Recv::Rejected { agent } => {
                    // An undecodable frame is still its sender's reply
                    // for this round — collection must not wedge on it —
                    // and feeds the sender's quarantine streak.
                    out.frames_rejected += 1;
                    replies += 1;
                    if health[agent].on_frame_rejected() {
                        health[agent].enter_quarantine(round);
                        out.agents_quarantined += 1;
                    }
                }
                Recv::Empty => {
                    // Deadline expired: clear with what arrived.
                    out.rounds_timed_out += 1;
                    out.stragglers += (delivered - replies) as u64;
                    break;
                }
                Recv::Disconnected => break,
            }
        }

        // 4. Decide, shard by shard in shard order: replay the announce
        // loop over the shard's candidates (pre-filtering bids that
        // conflict with earlier shards' acceptances this round), clear
        // with the shard's engine on its own pool, and record
        // acceptances in the cross-shard reconciler.
        let t_decide = std::time::Instant::now();
        pool.clear();
        agent_vid.clear();
        reconciler.begin_round();
        let mut announced_all: Vec<Window> = Vec::new();
        let mut accepted_rows: Vec<usize> = Vec::new();
        let mut any_window = false;
        for s in 0..shards_n {
            let (c0, c1) = shard_ranges[s];
            if c0 == c1 {
                continue;
            }
            // announce_top silence latch: did this shard's broadcast
            // draw any bid variant at all?
            let mut shard_variants = 0usize;
            for per_job in &bids_by_slot {
                for c in c0..c1 {
                    shard_variants += per_job[c].len();
                }
            }
            shards[s].last_round_silent = shard_variants == 0;

            let (policy, _) = shard_round_policy(&cfg.jasda, &env.cluster, now, s, shards_n);
            let shard_cand = &windows[c0..c1];
            let k_target = announce_target(&cfg.jasda, shard_cand);
            let row_base = pool.len();
            let sh = &mut shards[s];
            let rec = &reconciler;
            let (announced, window_rows, silent, filtered) = replay_selection(
                &mut sh.selector,
                policy,
                &env.cluster,
                now,
                cfg.jasda.announce_horizon,
                k_target,
                cfg.jasda.announce_per_slice,
                shard_cand,
                c0,
                &bids_by_slot,
                &mut pool,
                &mut agent_vid,
                &mut |v| !rec.conflicts(v),
            );
            out.windows_silent += silent;
            out.cross_shard_conflicts += filtered;
            out.windows_announced += announced.len() as u64;
            if announced.is_empty() {
                continue;
            }
            any_window = true;
            // (Pool rows keep their agent-assigned ids; the engine and
            // the award path identify variants by row index /
            // `agent_vid`. The engine sees rows relative to this shard's
            // pool segment.)
            let rel_rows: Vec<(usize, usize)> =
                window_rows.iter().map(|&(a, b)| (a - row_base, b - row_base)).collect();

            let jcfg = &cfg.jasda;
            let env_ro = &env;
            let mut row_ctx = |v: &Variant| {
                let slot = env_ro.slot[&v.job];
                let age = if jcfg.age_priority {
                    age_factor(env_ro.last_selected[slot], now, jcfg.age_scale)
                } else {
                    0.0
                };
                let (trust, hist) = if jcfg.calibration {
                    (
                        env_ro.calibration.trust_weight(v.job),
                        env_ro.calibration.hist_avg(v.job),
                    )
                } else {
                    (1.0, 0.0)
                };
                RowCtx { age, trust, hist }
            };
            let n_before = accepted_rows.len();
            {
                let shard_pool = &pool[row_base..];
                let mut on_accept =
                    |acc: Accepted<'_>| accepted_rows.push(row_base + acc.row);
                let cstats = sh.engine.clear(
                    jcfg,
                    &announced,
                    &rel_rows,
                    shard_pool,
                    &mut row_ctx,
                    &mut sh.scorer,
                    &sh.wpool,
                    &mut on_accept,
                );
                out.cross_window_conflicts += cstats.cross_window_conflicts;
                out.exact_rounds += cstats.exact_rounds;
                out.exact_nodes += cstats.exact_nodes;
                out.exact_budget_exhausted += cstats.exact_budget_exhausted;
                out.exact_improved += cstats.exact_improved;
                out.exact_ns += cstats.exact_ns;
            }
            for &row in &accepted_rows[n_before..] {
                reconciler.commit(&pool[row]);
            }
            announced_all.extend(announced);
        }
        if !any_window {
            // All candidates were silent: the selection replays above
            // are still leader decision work — account for them.
            let decide_ns = t_decide.elapsed().as_nanos() as u64;
            out.decision_ns += decide_ns;
            out.max_round_decision_ns = out.max_round_decision_ns.max(decide_ns);
            now += period;
            continue;
        }
        out.rounds_with_bids += 1;

        // 5. Award + reserve + realize, in commitment order (shard
        // order, then each shard's reconciliation order); then notify
        // each winning agent once (BTreeMap keeps send order
        // deterministic; per-agent id order is acceptance order).
        let mut per_job_awards: std::collections::BTreeMap<JobId, Vec<u32>> =
            std::collections::BTreeMap::new();
        let mut round_awards: Vec<AwardRec> = Vec::new();
        for &row in &accepted_rows {
            let v = &pool[row];
            if let Some(work) =
                env.award(now, v.job, v.slice, v.interval, v.work, v.declared.phi)
            {
                out.awards += 1;
                per_job_awards.entry(v.job).or_default().push(agent_vid[row]);
                if trace.is_some() {
                    round_awards.push(AwardRec {
                        job: v.job,
                        slice: v.slice,
                        interval: v.interval,
                        work,
                    });
                }
            }
        }
        for (job, variant_ids) in per_job_awards {
            let msg = ToAgent::Awarded(Award { round, variant_ids, now });
            let slot = env.slot[&job];
            if transport.send(slot, &msg) {
                health[slot].send_failures = 0;
            } else {
                out.sends_dropped += 1;
                health[slot].dirty = true;
                if health[slot].on_send_failed() {
                    health[slot].enter_quarantine(round);
                    out.agents_quarantined += 1;
                }
            }
        }
        let decide_ns = t_decide.elapsed().as_nanos() as u64;
        out.decision_ns += decide_ns;
        out.max_round_decision_ns = out.max_round_decision_ns.max(decide_ns);
        if let Some(t) = trace.as_deref_mut() {
            t.push(RoundDecision { round, now, windows: announced_all, awards: round_awards });
        }

        now += period;
    }

    now = env.drain(now);
    out.completed_jobs = env.completed_jobs;
    transport.shutdown();
    out.final_time = now;
    out.wall = wall0.elapsed();
    out
}

/// The single-process decision oracle: the identical leader environment
/// (realization RNG stream, completion slab, calibration updates, award
/// clamping, round cadence) with decisions made by an embedded
/// [`JasdaScheduler`] over a leader-maintained job mirror — no threads,
/// no messages. The parity property tests compare this against
/// [`run_protocol`] round for round; it is also the honest baseline for
/// measuring what the message transport itself costs.
pub fn run_reference(cfg: SimConfig, jobs: Vec<Job>, max_rounds: u64) -> ProtocolOutcome {
    run_reference_traced(cfg, jobs, max_rounds, None)
}

/// [`run_reference`] with an optional per-round decision trace.
pub fn run_reference_traced(
    cfg: SimConfig,
    jobs: Vec<Job>,
    max_rounds: u64,
    mut trace: Option<&mut Vec<RoundDecision>>,
) -> ProtocolOutcome {
    let wall0 = std::time::Instant::now();
    let n_jobs = jobs.len();
    let mut env = LeaderEnv::new(&cfg, &jobs);
    let mut sched = JasdaScheduler::new(cfg.jasda.clone());
    // The mirror evolves exactly as the agents' private job states do:
    // activation on announce, reservation bookkeeping on award, work
    // accounting on completion.
    let mut mirror = JobSet::new(jobs);
    let mut dummy_rng = Rng::new(0);
    let alpha = cfg.jasda.alpha.as_array();

    let mut out = ProtocolOutcome::new(n_jobs);
    let period = cfg.engine.iteration_period;
    let mut now: Time = env.last_selected.iter().min().copied().unwrap_or(0);
    let mut cand_scratch: Vec<Window> = Vec::new();

    for round in 0..max_rounds {
        out.rounds = round + 1;
        // 1. Fire due completions into the mirror + scheduler feedback.
        let sched_ref = &mut sched;
        let mirror_ref = &mut mirror;
        env.fire_due(now, &alpha, &mut |f: &Fired| {
            let j = mirror_ref.get_mut(f.job);
            j.reserved_work = (j.reserved_work - f.planned_work).max(0.0);
            j.done_work += f.realized_work;
            if j.remaining_work() <= 1e-6 && j.state == JobState::Active {
                j.state = JobState::Completed;
                j.completed_at = Some(f.realized_end);
            }
            sched_ref.on_subjob_complete(&SubjobRecord {
                job: f.job,
                slice: f.slice,
                subjob_seq: f.seq,
                reserved: f.reserved,
                realized_end: f.realized_end,
                planned_work: f.planned_work,
                realized_work: f.realized_work,
                declared_phi: f.declared_phi,
                observed_phi: f.observed_phi,
                committed_at: 0,
            });
        });
        out.completed_jobs = env.completed_jobs;
        if env.completed_jobs == n_jobs {
            break;
        }

        // 2–4. Announce/bid/clear happen inside the scheduler; rounds
        // with no candidate windows skip it, exactly as the protocol
        // leader skips its broadcast. (The scratch buffer avoids a
        // per-round allocation; the scheduler re-enumerates internally,
        // which is inherent to using it unmodified as the oracle.)
        env.cluster.collect_windows(
            now + cfg.jasda.announce_lead,
            cfg.jasda.announce_horizon,
            cfg.jasda.tau_min,
            &mut cand_scratch,
        );
        if cand_scratch.is_empty() {
            now += period;
            continue;
        }
        out.announcements += 1;
        mirror.admit_until(now);

        let t_decide = std::time::Instant::now();
        let commitments = sched.iterate(now, &env.cluster, &mut mirror, &mut dummy_rng);
        let announced: Vec<Window> = sched.last_announced().to_vec();
        out.windows_announced += announced.len() as u64;
        if announced.is_empty() {
            let decide_ns = t_decide.elapsed().as_nanos() as u64;
            out.decision_ns += decide_ns;
            out.max_round_decision_ns = out.max_round_decision_ns.max(decide_ns);
            now += period;
            continue;
        }
        out.rounds_with_bids += 1;

        // 5. Award + reserve + realize, mirroring the agents' award
        // handler for accepted commitments.
        let mut round_awards: Vec<AwardRec> = Vec::new();
        for c in &commitments {
            if let Some(work) =
                env.award(now, c.job, c.slice, c.interval, c.work, c.declared_phi)
            {
                out.awards += 1;
                let j = mirror.get_mut(c.job);
                j.reserved_work += c.work.min(j.pending_work());
                j.last_selected = now;
                j.last_slice = Some(c.slice);
                if trace.is_some() {
                    round_awards.push(AwardRec {
                        job: c.job,
                        slice: c.slice,
                        interval: c.interval,
                        work,
                    });
                }
            }
        }
        let decide_ns = t_decide.elapsed().as_nanos() as u64;
        out.decision_ns += decide_ns;
        out.max_round_decision_ns = out.max_round_decision_ns.max(decide_ns);
        if let Some(t) = trace.as_deref_mut() {
            t.push(RoundDecision { round, now, windows: announced, awards: round_awards });
        }

        now += period;
    }

    now = env.drain(now);
    out.completed_jobs = env.completed_jobs;
    out.final_time = now;
    out.wall = wall0.elapsed();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WindowPolicy;
    use crate::trp::{Phase, Trp};
    use std::sync::mpsc;

    fn jobs(n: u32) -> Vec<Job> {
        (0..n)
            .map(|i| {
                let trp = Trp {
                    phases: vec![Phase::new(800.0, 4.0, 0.2, 0.1)],
                    duration_cv: 0.05,
                };
                Job::new(i, "p", (i as u64) * 100, trp, None, 1.0, 300.0, 0.0)
            })
            .collect()
    }

    fn cfg() -> SimConfig {
        let mut c = SimConfig::default();
        c.cluster.layout = "balanced".into();
        c.engine.iteration_period = 25;
        c.jasda.fmp_bins = 16;
        c
    }

    #[test]
    fn protocol_completes_all_jobs() {
        let out = run_protocol(cfg(), jobs(5), 100_000);
        assert_eq!(out.completed_jobs, 5, "{out:?}");
        assert!(out.announcements > 0);
        assert!(out.bids > 0);
        assert!(out.awards >= 5);
        assert!(out.variants >= out.bids);
        assert!(out.windows_announced > 0);
        assert!(out.decision_ns > 0);
        assert_eq!(out.sends_dropped, 0, "synchronous rounds must never fill an inbox");
    }

    #[test]
    fn protocol_handles_sparse_job_ids() {
        let mut js = jobs(3);
        js[0].id = 500;
        js[1].id = 7;
        js[2].id = 10_000;
        let out = run_protocol(cfg(), js, 100_000);
        assert_eq!(out.completed_jobs, 3, "{out:?}");
    }

    #[test]
    fn protocol_with_no_jobs_terminates() {
        let out = run_protocol(cfg(), vec![], 10);
        assert_eq!(out.completed_jobs, 0);
        assert_eq!(out.total_jobs, 0);
    }

    #[test]
    fn round_cap_respected() {
        let out = run_protocol(cfg(), jobs(3), 5);
        assert!(out.rounds <= 5);
    }

    #[test]
    fn protocol_clears_multiple_windows_per_round() {
        let mut c = cfg();
        c.jasda.announce_per_slice = true;
        let out = run_protocol(c, jobs(6), 100_000);
        assert_eq!(out.completed_jobs, 6, "{out:?}");
        // On a 3-slice layout, per-slice announcement must clear more
        // windows than it has bidding rounds (jobs bid into every slice
        // they fit, so bidding rounds clear several windows at once).
        assert!(
            out.windows_announced > out.rounds_with_bids,
            "multi-window rounds expected: {out:?}"
        );
    }

    #[test]
    fn reference_completes_and_matches_protocol_decisions_smoke() {
        // The full random-trace parity property lives in
        // tests/properties.rs; this is the fast in-module smoke check.
        for (k, per_slice) in [(1usize, false), (2, false), (1, true)] {
            let mut c = cfg();
            c.jasda.announce_k = k;
            c.jasda.announce_per_slice = per_slice;
            let mut tp = Vec::new();
            let mut tr = Vec::new();
            let p = run_protocol_traced(c.clone(), jobs(4), 200_000, Some(&mut tp));
            let r = run_reference_traced(c, jobs(4), 200_000, Some(&mut tr));
            assert_eq!(p.completed_jobs, 4, "{p:?}");
            assert_eq!(r.completed_jobs, 4, "{r:?}");
            assert_eq!(tp.len(), tr.len(), "K={k} per_slice={per_slice}");
            for (a, b) in tp.iter().zip(&tr) {
                assert_eq!(a, b, "K={k} per_slice={per_slice}");
            }
            assert_eq!(p.rounds, r.rounds);
            assert_eq!(p.awards, r.awards);
            assert_eq!(p.final_time, r.final_time);
        }
    }

    #[test]
    fn framed_transport_matches_loopback_decisions() {
        // The wire codec must be decision-invisible: identical traces
        // whether messages cross as typed values or as byte frames.
        let mut c = cfg();
        c.jasda.announce_per_slice = true;
        let mut tl = Vec::new();
        let mut tf = Vec::new();
        let p = run_protocol_traced(c.clone(), jobs(4), 200_000, Some(&mut tl));
        let mut cf = c;
        cf.jasda.transport = TransportKind::Framed;
        let f = run_protocol_traced(cf, jobs(4), 200_000, Some(&mut tf));
        assert_eq!(p.completed_jobs, 4, "{p:?}");
        assert_eq!(f.completed_jobs, 4, "{f:?}");
        assert_eq!(tl.len(), tf.len());
        for (a, b) in tl.iter().zip(&tf) {
            assert_eq!(a, b);
        }
        assert_eq!(p.final_time, f.final_time);
    }

    #[test]
    #[cfg(unix)]
    fn socket_transports_match_loopback_decisions() {
        // Real sockets must be decision-invisible too: the spawn
        // barrier plus blocking collection (no deadline) means no
        // frame is ever dropped in a healthy run, so tcp and unix
        // traces are bit-identical to the loopback trace.
        let mut c = cfg();
        c.jasda.announce_per_slice = true;
        let mut tl = Vec::new();
        let p = run_protocol_traced(c.clone(), jobs(4), 200_000, Some(&mut tl));
        assert_eq!(p.completed_jobs, 4, "{p:?}");
        for kind in [TransportKind::Tcp, TransportKind::Unix] {
            let mut cs = c.clone();
            cs.jasda.transport = kind;
            let mut ts = Vec::new();
            let s = run_protocol_traced(cs, jobs(4), 200_000, Some(&mut ts));
            assert_eq!(s.completed_jobs, 4, "{kind:?}: {s:?}");
            assert_eq!(s.sends_dropped, 0, "{kind:?}: healthy run must drop nothing");
            assert_eq!(tl.len(), ts.len(), "{kind:?}");
            for (a, b) in tl.iter().zip(&ts) {
                assert_eq!(a, b, "{kind:?}");
            }
            assert_eq!(p.final_time, s.final_time, "{kind:?}");
        }
    }

    #[test]
    fn generous_round_deadline_changes_no_decision() {
        // With healthy agents a deadline the agents comfortably beat
        // must be invisible: same decisions, no timed-out rounds.
        let mut timed = cfg();
        timed.jasda.round_timeout_ms = 5_000;
        let mut tt = Vec::new();
        let mut tb = Vec::new();
        let t = run_protocol_traced(timed, jobs(4), 200_000, Some(&mut tt));
        let b = run_protocol_traced(cfg(), jobs(4), 200_000, Some(&mut tb));
        assert_eq!(t.completed_jobs, 4, "{t:?}");
        assert_eq!(t.rounds_timed_out, 0, "healthy agents must beat a 5s deadline: {t:?}");
        assert_eq!(t.stragglers, 0);
        assert_eq!(tt, tb, "a generous deadline must not alter decisions");
    }

    #[test]
    fn crashed_agents_recover_and_all_jobs_complete() {
        // Deterministic crash plans (forced non-empty): rounds must
        // keep terminating under the deadline and every finite crash
        // must end in recovery — all jobs complete on every seed. The
        // quarantine/readmission machinery must engage on at least one
        // of the seeds.
        let mut quarantined = 0u64;
        let mut readmitted = 0u64;
        let mut dropped = 0u64;
        for seed in 0..4 {
            let mut c = cfg();
            c.jasda.round_timeout_ms = 500;
            c.jasda.faults.crash = 0.6;
            c.jasda.faults.seed = seed;
            c.jasda.faults.horizon_rounds = 24;
            c.jasda.faults.crash_rounds = 10;
            c.validate().unwrap();
            let out = run_protocol(c, jobs(4), 200_000);
            assert_eq!(out.completed_jobs, 4, "seed {seed}: jobs must survive crashes: {out:?}");
            quarantined += out.agents_quarantined;
            readmitted += out.readmissions;
            dropped += out.sends_dropped;
        }
        assert!(dropped > 0, "no seed's crash windows ate a send");
        assert!(quarantined > 0, "no seed engaged quarantine");
        assert!(readmitted > 0, "no quarantined agent was re-admitted");
    }

    #[test]
    fn sharded_leader_completes_with_conflict_free_rounds() {
        let mut c = cfg();
        c.jasda.shards = 2;
        c.jasda.announce_per_slice = true;
        let mut trace = Vec::new();
        let out = run_protocol_traced(c, jobs(6), 200_000, Some(&mut trace));
        assert_eq!(out.completed_jobs, 6, "{out:?}");
        for rd in &trace {
            for (i, a) in rd.awards.iter().enumerate() {
                for b in rd.awards.iter().skip(i + 1) {
                    if a.job == b.job {
                        assert!(
                            !a.interval.overlaps(&b.interval),
                            "round {}: job {} holds overlapping awards",
                            rd.round,
                            a.job
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn announce_top_caps_broadcast_and_still_completes() {
        let mut c = cfg();
        c.jasda.announce_top = 1;
        c.jasda.announce_per_slice = true;
        let out = run_protocol(c, jobs(5), 200_000);
        assert_eq!(out.completed_jobs, 5, "{out:?}");
        assert!(out.windows_suppressed > 0, "cap never engaged: {out:?}");
    }

    #[test]
    fn announce_top_falls_back_after_silence() {
        // Round-robin ranking eventually offers only the 10 GiB slices;
        // a 14 GiB job is silent on those capped rounds, so the next
        // round must re-broadcast the full set.
        let mut c = cfg();
        c.jasda.announce_top = 1;
        c.jasda.window_policy = WindowPolicy::RoundRobin;
        let trp =
            Trp { phases: vec![Phase::new(800.0, 14.0, 0.2, 0.1)], duration_cv: 0.05 };
        let job = Job::new(0, "p", 0, trp, None, 1.0, 300.0, 0.0);
        let out = run_protocol(c, vec![job], 200_000);
        assert_eq!(out.completed_jobs, 1, "{out:?}");
        assert!(
            out.announce_fallbacks > 0,
            "silent capped round must trigger the full-set fallback: {out:?}"
        );
    }

    #[test]
    fn agent_resolves_awards_by_agent_assigned_ids() {
        // Regression: award ids must be the agent's own numbering, so a
        // winning agent's reserved-work accounting actually moves. With
        // the old leader-pool-id echo, awards never resolved and the
        // agent kept re-bidding already-reserved work. Drive one agent
        // directly: award its whole first bid, then verify the job is
        // silent on the next announcement (pending work hit zero).
        let trp = Trp { phases: vec![Phase::new(600.0, 4.0, 0.2, 0.1)], duration_cv: 0.05 };
        let job = Job::new(9, "p", 0, trp, None, 1.0, 300.0, 0.0);
        let jcfg = crate::config::JasdaConfig { fmp_bins: 16, ..Default::default() };
        let (to_tx, to_rx) = mpsc::channel();
        let (re_tx, re_rx) = mpsc::channel();
        let handle = std::thread::spawn(move || {
            agent_loop(job, jcfg, || to_rx.recv().ok(), |reply| re_tx.send(reply).is_ok())
        });

        let window = Window {
            slice: 0,
            capacity_gb: 20.0,
            speed: 1.0,
            interval: Interval::new(0, 10_000),
        };
        let windows = std::sync::Arc::new(vec![window]);
        to_tx
            .send(ToAgent::Announce { round: 0, now: 0, windows: windows.clone() })
            .unwrap();
        let AgentReply::Bid { bids, round, .. } = re_rx.recv().unwrap();
        assert_eq!(round, 0);
        let ids: Vec<u32> = bids[0].iter().map(|v| v.id).collect();
        assert!(!ids.is_empty(), "active job must bid into a roomy window");

        // Award every proposed variant: the chain covers all pending
        // work, and the agent clamps each award by its own pending.
        to_tx
            .send(ToAgent::Awarded(Award { round: 0, variant_ids: ids, now: 0 }))
            .unwrap();
        to_tx.send(ToAgent::Announce { round: 1, now: 25, windows }).unwrap();
        let AgentReply::Bid { bids: second, round, .. } = re_rx.recv().unwrap();
        assert_eq!(round, 1);
        assert!(
            second.iter().all(|b| b.is_empty()),
            "fully reserved job must be silent — award ids failed to resolve: {second:?}"
        );
        to_tx.send(ToAgent::Shutdown).unwrap();
        handle.join().unwrap();
    }
}
