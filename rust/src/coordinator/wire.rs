//! Hand-rolled wire codec for the protocol messages (zero dependencies,
//! no serde — the offline build constraint, and the same forward-only
//! philosophy as the lazy-scan JSON reader in `util::json`: one cursor,
//! no intermediate tree, no backtracking).
//!
//! # Frame layout
//!
//! Every message is one *frame*: a `u32` little-endian payload length
//! followed by the payload. The payload starts with a one-byte tag and
//! then the message fields in declaration order:
//!
//! - integers as LEB128 varints (u64; u32 fields are range-checked on
//!   decode),
//! - `f64` as the 8 little-endian bytes of [`f64::to_bits`] — bit-exact
//!   round-trip, which is what keeps `FramedTransport` decisions
//!   identical to `LoopbackTransport` (the parity property tests compare
//!   them directly),
//! - intervals as `start` + `len` varints (lengths compress better than
//!   absolute ends),
//! - bools as a single 0/1 byte.
//!
//! A [`AgentReply::Bid`] additionally carries an **FMP table**: the
//! distinct [`Fmp`]s referenced by the reply's variants, in first-use
//! order, each variant storing only its table index. Variants in one bid
//! share FMPs through `Arc` (one per cached plan); the table keeps that
//! sharing on the wire *and* restores it on decode, so a framed bid costs
//! one FMP serialization per plan, not per variant.
//!
//! # Hostile input
//!
//! Decoding never panics and never trusts a length it has not yet seen
//! bytes for: every read is bounds-checked ([`WireError::Eof`]), frames
//! above [`MAX_FRAME`] are rejected before any allocation sized by them,
//! vectors grow by `push` (never `with_capacity` from a wire length),
//! FMP table indices are range-checked, and a decoded payload must be
//! consumed exactly ([`WireError::Trailing`]). The truncation/garbage
//! tests below drive every reject path.
//!
//! The cap cuts both ways: encoding enforces [`MAX_FRAME`] too
//! ([`WireError::Oversize`]), so a leader can never emit a frame its
//! own peers are guaranteed to reject — see [`end_frame`].
//!
//! # Frame validation
//!
//! There is exactly one frame-validation path: [`frame_len`] checks a
//! length prefix against [`MAX_FRAME`], and both [`frame_payload`]
//! (whole-frame transports) and [`FrameReader`] (byte-stream
//! transports) go through it, so the two framings cannot drift.

use super::messages::{AgentReply, Award, CompletionReport, Resync, ToAgent};
use crate::job::variants::{DeclaredFeatures, SysFeatures};
use crate::job::Variant;
use crate::mig::Window;
use crate::trp::Fmp;
use crate::types::Interval;
use std::sync::Arc;

/// Hard cap on a frame's payload length (bytes). Generously above any
/// real round (a 10k-variant bid is ~2 MB) while keeping a hostile
/// length prefix from looking plausible.
pub const MAX_FRAME: usize = 64 << 20;

/// Message tags (first payload byte).
const TAG_ANNOUNCE: u8 = 1;
const TAG_AWARDED: u8 = 2;
const TAG_COMPLETED: u8 = 3;
const TAG_SHUTDOWN: u8 = 4;
const TAG_RESYNC: u8 = 5;
const TAG_BID: u8 = 0x11;

/// Codec failure. Every variant but [`Oversize`](WireError::Oversize)
/// is a decode-side reject; `Oversize` is the single encode-side error
/// (a message whose frame would exceed [`MAX_FRAME`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireError {
    /// Ran out of bytes mid-value.
    Eof,
    /// Unknown message tag.
    BadTag(u8),
    /// Malformed varint (more than 10 continuation bytes) or a varint
    /// value out of range for the field (e.g. a u32 field > u32::MAX).
    Varint,
    /// Frame-level violation: short/oversized length prefix, or a
    /// payload field inconsistent with the data (bad bool byte, FMP
    /// index past the table, interval overflow).
    Frame,
    /// The payload decoded cleanly but left unconsumed bytes.
    Trailing,
    /// Encode-side reject: the message's frame would exceed
    /// [`MAX_FRAME`]. The output buffer is restored to its pre-frame
    /// length, so nothing half-written can reach the wire.
    Oversize,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Eof => write!(f, "unexpected end of frame"),
            WireError::BadTag(t) => write!(f, "unknown message tag {t:#04x}"),
            WireError::Varint => write!(f, "malformed or out-of-range varint"),
            WireError::Frame => write!(f, "malformed frame"),
            WireError::Trailing => write!(f, "trailing bytes after message"),
            WireError::Oversize => write!(f, "message exceeds MAX_FRAME at encode time"),
        }
    }
}

impl std::error::Error for WireError {}

// --- primitive writers ----------------------------------------------------

fn put_var(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_bool(out: &mut Vec<u8>, v: bool) {
    out.push(u8::from(v));
}

fn put_interval(out: &mut Vec<u8>, iv: &Interval) {
    put_var(out, iv.start);
    put_var(out, iv.end - iv.start);
}

// --- primitive reader -----------------------------------------------------

/// Forward-only bounds-checked cursor over one frame payload.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Wrap a payload.
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    /// Whether every byte has been consumed.
    pub fn is_empty(&self) -> bool {
        self.pos >= self.buf.len()
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        let b = *self.buf.get(self.pos).ok_or(WireError::Eof)?;
        self.pos += 1;
        Ok(b)
    }

    fn var(&mut self) -> Result<u64, WireError> {
        let mut v: u64 = 0;
        for shift in (0..64).step_by(7) {
            let b = self.u8()?;
            let low = u64::from(b & 0x7f);
            // The 10th byte may only contribute the u64's top bit.
            if shift == 63 && low > 1 {
                return Err(WireError::Varint);
            }
            v |= low << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
        }
        Err(WireError::Varint)
    }

    fn var_u32(&mut self) -> Result<u32, WireError> {
        u32::try_from(self.var()?).map_err(|_| WireError::Varint)
    }

    fn var_usize(&mut self) -> Result<usize, WireError> {
        usize::try_from(self.var()?).map_err(|_| WireError::Varint)
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        let end = self.pos.checked_add(8).ok_or(WireError::Eof)?;
        let bytes = self.buf.get(self.pos..end).ok_or(WireError::Eof)?;
        self.pos = end;
        Ok(f64::from_bits(u64::from_le_bytes(bytes.try_into().expect("8-byte slice"))))
    }

    fn bool(&mut self) -> Result<bool, WireError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(WireError::Frame),
        }
    }

    fn interval(&mut self) -> Result<Interval, WireError> {
        let start = self.var()?;
        let len = self.var()?;
        let end = start.checked_add(len).ok_or(WireError::Frame)?;
        Ok(Interval::new(start, end))
    }
}

// --- framing --------------------------------------------------------------

/// Reserve the 4-byte length prefix; returns its offset for
/// [`end_frame`].
fn begin_frame(out: &mut Vec<u8>) -> usize {
    let at = out.len();
    out.extend_from_slice(&[0u8; 4]);
    at
}

/// Patch the length prefix reserved by [`begin_frame`].
///
/// Enforces [`MAX_FRAME`] at encode time: an over-cap message truncates
/// `out` back to where the frame began and reports
/// [`WireError::Oversize`], so the sender sees the failure instead of
/// emitting a frame every receiver is guaranteed to reject (which,
/// with receiver-attributed rejects feeding quarantine, would punish
/// the *peers* for a frame the sender produced).
fn end_frame(out: &mut Vec<u8>, at: usize) -> Result<(), WireError> {
    let len = out.len() - at - 4;
    if len > MAX_FRAME {
        out.truncate(at);
        return Err(WireError::Oversize);
    }
    out[at..at + 4].copy_from_slice(&(len as u32).to_le_bytes());
    Ok(())
}

/// Validate a 4-byte length prefix and return the payload length.
///
/// The **single** frame-validation gate: [`frame_payload`] and
/// [`FrameReader`] both call this, so whole-frame and byte-stream
/// transports apply the identical [`MAX_FRAME`] cap.
pub fn frame_len(prefix: [u8; 4]) -> Result<usize, WireError> {
    let len = u32::from_le_bytes(prefix) as usize;
    if len > MAX_FRAME {
        return Err(WireError::Frame);
    }
    Ok(len)
}

/// Validate a frame's length prefix and return its payload.
pub fn frame_payload(frame: &[u8]) -> Result<&[u8], WireError> {
    let prefix: [u8; 4] =
        frame.get(..4).ok_or(WireError::Frame)?.try_into().expect("4-byte slice");
    let len = frame_len(prefix)?;
    if frame.len() - 4 != len {
        return Err(WireError::Frame);
    }
    Ok(&frame[4..])
}

/// Incremental frame reassembler for byte-stream transports.
///
/// A socket read hands back an arbitrary run of bytes — possibly half a
/// length prefix, possibly three frames and a bit of a fourth. `feed`
/// the bytes as they arrive and drain complete frames with
/// [`next_frame`]; each yielded `Vec<u8>` is a full frame (prefix
/// included), ready for [`decode_to_agent`] / [`decode_agent_reply`].
///
/// Length prefixes are validated through [`frame_len`] — the same gate
/// [`frame_payload`] uses — before any allocation sized by them. An
/// `Err` from [`next_frame`] means the stream is desynchronized (there
/// is no way to find the next frame boundary after a bad prefix): the
/// caller must drop the connection and [`clear`](FrameReader::clear)
/// the reader before reusing it.
#[derive(Default)]
pub struct FrameReader {
    buf: Vec<u8>,
    pos: usize,
}

impl FrameReader {
    /// An empty reader.
    pub fn new() -> Self {
        FrameReader::default()
    }

    /// Append bytes read off the stream. Consumed frames are compacted
    /// away here, so the buffer never holds more than the unconsumed
    /// tail plus this read.
    pub fn feed(&mut self, bytes: &[u8]) {
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame, `Ok(None)` if more bytes are
    /// needed, or `Err` if the stream is desynchronized (bad length
    /// prefix — drop the connection).
    pub fn next_frame(&mut self) -> Result<Option<Vec<u8>>, WireError> {
        let avail = self.buf.len() - self.pos;
        if avail < 4 {
            return Ok(None);
        }
        let prefix: [u8; 4] =
            self.buf[self.pos..self.pos + 4].try_into().expect("4-byte slice");
        let len = frame_len(prefix)?;
        if avail < 4 + len {
            return Ok(None);
        }
        let frame = self.buf[self.pos..self.pos + 4 + len].to_vec();
        self.pos += 4 + len;
        Ok(Some(frame))
    }

    /// Drop all buffered bytes (reconnect path).
    pub fn clear(&mut self) {
        self.buf.clear();
        self.pos = 0;
    }
}

// --- ToAgent --------------------------------------------------------------

fn put_window(out: &mut Vec<u8>, w: &Window) {
    put_var(out, u64::from(w.slice));
    put_f64(out, w.capacity_gb);
    put_f64(out, w.speed);
    put_interval(out, &w.interval);
}

fn read_window(r: &mut Reader<'_>) -> Result<Window, WireError> {
    Ok(Window {
        slice: r.var_u32()?,
        capacity_gb: r.f64()?,
        speed: r.f64()?,
        interval: r.interval()?,
    })
}

/// Append one framed leader → agent message to `out`.
///
/// Fails only with [`WireError::Oversize`] (frame over [`MAX_FRAME`]),
/// in which case `out` is restored to its incoming length.
pub fn encode_to_agent(msg: &ToAgent, out: &mut Vec<u8>) -> Result<(), WireError> {
    let at = begin_frame(out);
    match msg {
        ToAgent::Announce { round, now, windows } => {
            out.push(TAG_ANNOUNCE);
            put_var(out, *round);
            put_var(out, *now);
            put_var(out, windows.len() as u64);
            for w in windows.iter() {
                put_window(out, w);
            }
        }
        ToAgent::Awarded(a) => {
            out.push(TAG_AWARDED);
            put_var(out, a.round);
            put_var(out, a.now);
            put_var(out, a.variant_ids.len() as u64);
            for &id in &a.variant_ids {
                put_var(out, u64::from(id));
            }
        }
        ToAgent::Completed(c) => {
            out.push(TAG_COMPLETED);
            put_f64(out, c.planned_work);
            put_f64(out, c.realized_work);
            put_var(out, c.at);
        }
        ToAgent::Resync(rs) => {
            out.push(TAG_RESYNC);
            put_var(out, rs.round);
            put_var(out, rs.now);
            put_f64(out, rs.done_work);
            put_f64(out, rs.outstanding_awards);
        }
        ToAgent::Shutdown => out.push(TAG_SHUTDOWN),
    }
    end_frame(out, at)
}

/// Decode one framed leader → agent message.
pub fn decode_to_agent(frame: &[u8]) -> Result<ToAgent, WireError> {
    let mut r = Reader::new(frame_payload(frame)?);
    let msg = match r.u8()? {
        TAG_ANNOUNCE => {
            let round = r.var()?;
            let now = r.var()?;
            let n = r.var_usize()?;
            let mut windows = Vec::new();
            for _ in 0..n {
                windows.push(read_window(&mut r)?);
            }
            ToAgent::Announce { round, now, windows: Arc::new(windows) }
        }
        TAG_AWARDED => {
            let round = r.var()?;
            let now = r.var()?;
            let n = r.var_usize()?;
            let mut variant_ids = Vec::new();
            for _ in 0..n {
                variant_ids.push(r.var_u32()?);
            }
            ToAgent::Awarded(Award { round, variant_ids, now })
        }
        TAG_COMPLETED => ToAgent::Completed(CompletionReport {
            planned_work: r.f64()?,
            realized_work: r.f64()?,
            at: r.var()?,
        }),
        TAG_RESYNC => ToAgent::Resync(Resync {
            round: r.var()?,
            now: r.var()?,
            done_work: r.f64()?,
            outstanding_awards: r.f64()?,
        }),
        TAG_SHUTDOWN => ToAgent::Shutdown,
        t => return Err(WireError::BadTag(t)),
    };
    if !r.is_empty() {
        return Err(WireError::Trailing);
    }
    Ok(msg)
}

// --- AgentReply -----------------------------------------------------------

fn put_variant(out: &mut Vec<u8>, v: &Variant, fmp_index: usize) {
    put_var(out, u64::from(v.id));
    put_var(out, u64::from(v.slice));
    put_interval(out, &v.interval);
    put_f64(out, v.work);
    put_f64(out, v.work_offset);
    put_var(out, fmp_index as u64);
    put_f64(out, v.violation_prob);
    for x in v.declared.phi_honest {
        put_f64(out, x);
    }
    for x in v.declared.phi {
        put_f64(out, x);
    }
    put_f64(out, v.declared.h_tilde);
    put_f64(out, v.sys.util);
    put_f64(out, v.sys.frag);
}

fn read_variant(r: &mut Reader<'_>, job: u32, fmps: &[Arc<Fmp>]) -> Result<Variant, WireError> {
    let id = r.var_u32()?;
    let slice = r.var_u32()?;
    let interval = r.interval()?;
    let work = r.f64()?;
    let work_offset = r.f64()?;
    let fmp_index = r.var_usize()?;
    let fmp = fmps.get(fmp_index).ok_or(WireError::Frame)?;
    let violation_prob = r.f64()?;
    let mut phi_honest = [0.0f64; 4];
    for x in &mut phi_honest {
        *x = r.f64()?;
    }
    let mut phi = [0.0f64; 4];
    for x in &mut phi {
        *x = r.f64()?;
    }
    let h_tilde = r.f64()?;
    let util = r.f64()?;
    let frag = r.f64()?;
    Ok(Variant {
        id,
        job,
        slice,
        interval,
        work,
        work_offset,
        fmp: Arc::clone(fmp),
        violation_prob,
        declared: DeclaredFeatures { phi_honest, phi, h_tilde },
        sys: SysFeatures { util, frag },
    })
}

/// Append one framed agent → leader message to `out`.
///
/// The variant `job` fields are not written (every variant in a bid
/// belongs to the bidding job); decode restores them from the reply's
/// `job` field.
///
/// Fails only with [`WireError::Oversize`] (frame over [`MAX_FRAME`]),
/// in which case `out` is restored to its incoming length.
pub fn encode_agent_reply(msg: &AgentReply, out: &mut Vec<u8>) -> Result<(), WireError> {
    let AgentReply::Bid { job, round, bids, done } = msg;
    let at = begin_frame(out);
    out.push(TAG_BID);
    put_var(out, u64::from(*job));
    put_var(out, *round);
    put_bool(out, *done);

    // FMP table: distinct Arcs in first-use order. The distinct count is
    // the number of cached plans (a handful), so the linear scan is fine.
    let mut fmps: Vec<&Arc<Fmp>> = Vec::new();
    for per_window in bids {
        for v in per_window {
            if !fmps.iter().any(|f| Arc::ptr_eq(f, &v.fmp)) {
                fmps.push(&v.fmp);
            }
        }
    }
    put_var(out, fmps.len() as u64);
    for f in &fmps {
        debug_assert_eq!(f.mu.len(), f.sigma.len());
        put_var(out, f.mu.len() as u64);
        for &x in &f.mu {
            put_f64(out, x);
        }
        for &x in &f.sigma {
            put_f64(out, x);
        }
    }

    put_var(out, bids.len() as u64);
    for per_window in bids {
        put_var(out, per_window.len() as u64);
        for v in per_window {
            let idx = fmps
                .iter()
                .position(|f| Arc::ptr_eq(f, &v.fmp))
                .expect("every variant FMP is in the table");
            put_variant(out, v, idx);
        }
    }
    end_frame(out, at)
}

/// Decode one framed agent → leader message.
pub fn decode_agent_reply(frame: &[u8]) -> Result<AgentReply, WireError> {
    let mut r = Reader::new(frame_payload(frame)?);
    match r.u8()? {
        TAG_BID => {}
        t => return Err(WireError::BadTag(t)),
    }
    let job = r.var_u32()?;
    let round = r.var()?;
    let done = r.bool()?;

    let n_fmps = r.var_usize()?;
    let mut fmps: Vec<Arc<Fmp>> = Vec::new();
    for _ in 0..n_fmps {
        let bins = r.var_usize()?;
        let mut mu = Vec::new();
        for _ in 0..bins {
            mu.push(r.f64()?);
        }
        let mut sigma = Vec::new();
        for _ in 0..bins {
            sigma.push(r.f64()?);
        }
        fmps.push(Arc::new(Fmp { mu, sigma }));
    }

    let n_windows = r.var_usize()?;
    let mut bids: Vec<Vec<Variant>> = Vec::new();
    for _ in 0..n_windows {
        let n_variants = r.var_usize()?;
        let mut per_window = Vec::new();
        for _ in 0..n_variants {
            per_window.push(read_variant(&mut r, job, &fmps)?);
        }
        bids.push(per_window);
    }
    if !r.is_empty() {
        return Err(WireError::Trailing);
    }
    Ok(AgentReply::Bid { job, round, bids, done })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fmp(seed: f64, bins: usize) -> Arc<Fmp> {
        Arc::new(Fmp {
            mu: (0..bins).map(|i| seed + i as f64 * 0.25).collect(),
            sigma: (0..bins).map(|i| 0.1 + seed * i as f64).collect(),
        })
    }

    fn variant(id: u32, job: u32, fmp: &Arc<Fmp>) -> Variant {
        Variant {
            id,
            job,
            slice: id % 3,
            interval: Interval::new(100 + u64::from(id), 600 + u64::from(id) * 7),
            work: 123.456 + f64::from(id),
            work_offset: 0.5 * f64::from(id),
            fmp: Arc::clone(fmp),
            violation_prob: 0.0125,
            declared: DeclaredFeatures {
                phi_honest: [0.1, 0.2, 0.3, 0.4],
                phi: [0.15, 0.2, 0.3, 0.4],
                h_tilde: 0.2875,
            },
            sys: SysFeatures { util: 0.75, frag: 0.9 },
        }
    }

    fn assert_variant_eq(a: &Variant, b: &Variant) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.job, b.job);
        assert_eq!(a.slice, b.slice);
        assert_eq!(a.interval, b.interval);
        assert_eq!(a.work.to_bits(), b.work.to_bits());
        assert_eq!(a.work_offset.to_bits(), b.work_offset.to_bits());
        assert_eq!(a.fmp.mu, b.fmp.mu);
        assert_eq!(a.fmp.sigma, b.fmp.sigma);
        assert_eq!(a.violation_prob.to_bits(), b.violation_prob.to_bits());
        for i in 0..4 {
            assert_eq!(a.declared.phi_honest[i].to_bits(), b.declared.phi_honest[i].to_bits());
            assert_eq!(a.declared.phi[i].to_bits(), b.declared.phi[i].to_bits());
        }
        assert_eq!(a.declared.h_tilde.to_bits(), b.declared.h_tilde.to_bits());
        assert_eq!(a.sys.util.to_bits(), b.sys.util.to_bits());
        assert_eq!(a.sys.frag.to_bits(), b.sys.frag.to_bits());
    }

    #[test]
    fn announce_round_trips() {
        let windows = vec![
            Window {
                slice: 0,
                capacity_gb: 20.0,
                speed: 3.0 / 7.0,
                interval: Interval::new(25, 20_025),
            },
            Window { slice: 2, capacity_gb: 10.0, speed: 2.0 / 7.0, interval: Interval::new(0, 7) },
        ];
        let msg = ToAgent::Announce { round: 42, now: 1_050, windows: Arc::new(windows.clone()) };
        let mut buf = Vec::new();
        encode_to_agent(&msg, &mut buf).unwrap();
        match decode_to_agent(&buf).unwrap() {
            ToAgent::Announce { round, now, windows: got } => {
                assert_eq!(round, 42);
                assert_eq!(now, 1_050);
                assert_eq!(*got, windows);
            }
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn awarded_completed_shutdown_round_trip() {
        let mut buf = Vec::new();
        encode_to_agent(
            &ToAgent::Awarded(Award { round: 7, variant_ids: vec![0, 3, u32::MAX], now: 175 }),
            &mut buf,
        )
        .unwrap();
        match decode_to_agent(&buf).unwrap() {
            ToAgent::Awarded(a) => {
                assert_eq!(a.round, 7);
                assert_eq!(a.variant_ids, vec![0, 3, u32::MAX]);
                assert_eq!(a.now, 175);
            }
            other => panic!("wrong message: {other:?}"),
        }

        buf.clear();
        let c = CompletionReport { planned_work: 300.5, realized_work: 299.25, at: 9_001 };
        encode_to_agent(&ToAgent::Completed(c), &mut buf).unwrap();
        match decode_to_agent(&buf).unwrap() {
            ToAgent::Completed(got) => {
                assert_eq!(got.planned_work.to_bits(), 300.5f64.to_bits());
                assert_eq!(got.realized_work.to_bits(), 299.25f64.to_bits());
                assert_eq!(got.at, 9_001);
            }
            other => panic!("wrong message: {other:?}"),
        }

        buf.clear();
        encode_to_agent(&ToAgent::Shutdown, &mut buf).unwrap();
        assert!(matches!(decode_to_agent(&buf).unwrap(), ToAgent::Shutdown));
    }

    #[test]
    fn resync_round_trips_bit_exact() {
        let mut buf = Vec::new();
        let rs = Resync {
            round: 19,
            now: 4_750,
            done_work: 123.456789,
            outstanding_awards: 0.015625,
        };
        encode_to_agent(&ToAgent::Resync(rs), &mut buf).unwrap();
        match decode_to_agent(&buf).unwrap() {
            ToAgent::Resync(got) => {
                assert_eq!(got.round, 19);
                assert_eq!(got.now, 4_750);
                assert_eq!(got.done_work.to_bits(), 123.456789f64.to_bits());
                assert_eq!(got.outstanding_awards.to_bits(), 0.015625f64.to_bits());
            }
            other => panic!("wrong message: {other:?}"),
        }
        // A truncated Resync fails cleanly like every other message.
        for cut in 0..buf.len() {
            assert!(decode_to_agent(&buf[..cut]).is_err(), "cut at {cut} accepted");
        }
    }

    #[test]
    fn bid_round_trips_and_restores_fmp_sharing() {
        let f0 = fmp(1.0, 16);
        let f1 = fmp(2.0, 16);
        // Window 0: two variants sharing f0 (one plan, two chunks), one
        // on f1. Window 1: silent. Window 2: f0 again (same shape).
        let bids = vec![
            vec![variant(0, 9, &f0), variant(1, 9, &f0), variant(2, 9, &f1)],
            vec![],
            vec![variant(3, 9, &f0)],
        ];
        let msg = AgentReply::Bid { job: 9, round: 3, bids: bids.clone(), done: false };
        let mut buf = Vec::new();
        encode_agent_reply(&msg, &mut buf).unwrap();
        let AgentReply::Bid { job, round, bids: got, done } = decode_agent_reply(&buf).unwrap();
        assert_eq!(job, 9);
        assert_eq!(round, 3);
        assert!(!done);
        assert_eq!(got.len(), bids.len());
        for (gw, bw) in got.iter().zip(&bids) {
            assert_eq!(gw.len(), bw.len());
            for (g, b) in gw.iter().zip(bw) {
                assert_variant_eq(g, b);
            }
        }
        // Arc sharing is restored: variants 0, 1, and 3 share one FMP
        // allocation; variant 2 has its own.
        assert!(Arc::ptr_eq(&got[0][0].fmp, &got[0][1].fmp));
        assert!(Arc::ptr_eq(&got[0][0].fmp, &got[2][0].fmp));
        assert!(!Arc::ptr_eq(&got[0][0].fmp, &got[0][2].fmp));
    }

    #[test]
    fn truncated_frames_are_rejected() {
        let f = fmp(1.5, 8);
        let msg = AgentReply::Bid {
            job: 4,
            round: 11,
            bids: vec![vec![variant(0, 4, &f)]],
            done: true,
        };
        let mut buf = Vec::new();
        encode_agent_reply(&msg, &mut buf).unwrap();
        // Any prefix shorter than the full frame fails the length check.
        for cut in 0..buf.len() {
            assert!(decode_agent_reply(&buf[..cut]).is_err(), "cut at {cut} accepted");
        }
        // Truncated payload with a "fixed up" length prefix fails inside
        // the payload instead (Eof), never panics.
        for cut in 5..buf.len() {
            let mut short = buf[..cut].to_vec();
            let plen = (cut - 4) as u32;
            short[0..4].copy_from_slice(&plen.to_le_bytes());
            assert!(decode_agent_reply(&short).is_err(), "patched cut at {cut} accepted");
        }
    }

    #[test]
    fn bad_tags_are_rejected() {
        let mut buf = Vec::new();
        encode_to_agent(&ToAgent::Shutdown, &mut buf).unwrap();
        let mut bad = buf.clone();
        bad[4] = 0xEE;
        assert_eq!(decode_to_agent(&bad).unwrap_err(), WireError::BadTag(0xEE));
        // A ToAgent tag is not a valid AgentReply tag and vice versa.
        assert_eq!(decode_agent_reply(&buf).unwrap_err(), WireError::BadTag(TAG_SHUTDOWN));
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let mut buf = Vec::new();
        encode_to_agent(&ToAgent::Shutdown, &mut buf).unwrap();
        buf.push(0);
        let plen = (buf.len() - 4) as u32;
        buf[0..4].copy_from_slice(&plen.to_le_bytes());
        assert_eq!(decode_to_agent(&buf).unwrap_err(), WireError::Trailing);
    }

    #[test]
    fn oversized_length_prefix_is_rejected() {
        let mut buf = vec![0u8; 8];
        buf[0..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert_eq!(decode_to_agent(&buf).unwrap_err(), WireError::Frame);
    }

    #[test]
    fn random_bytes_never_panic() {
        // Deterministic xorshift fuzz: whatever the bytes, decode must
        // return (Ok or Err), never panic or overflow.
        let mut state = 0x9E3779B97F4A7C15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for case in 0..2_000 {
            let len = (next() % 64) as usize;
            let mut frame = vec![0u8; len];
            for b in frame.iter_mut() {
                *b = next() as u8;
            }
            // Half the cases get a consistent length prefix so decoding
            // reaches the payload logic.
            if case % 2 == 0 && len >= 4 {
                let plen = (len - 4) as u32;
                frame[0..4].copy_from_slice(&plen.to_le_bytes());
            }
            let _ = decode_to_agent(&frame);
            let _ = decode_agent_reply(&frame);
        }
    }

    #[test]
    fn over_cap_message_fails_to_encode_and_restores_buffer() {
        // A just-over-cap bid must fail at *encode* time with a real
        // error — not ship a frame every receiver rejects (poisoning
        // the round and the receivers' health streaks). 16 bytes/bin
        // (mu + sigma), so this many bins crosses MAX_FRAME by a hair.
        let bins = MAX_FRAME / 16 + 1;
        let big = Arc::new(Fmp { mu: vec![0.5; bins], sigma: vec![0.25; bins] });
        let msg = AgentReply::Bid {
            job: 1,
            round: 2,
            bids: vec![vec![variant(0, 1, &big)]],
            done: false,
        };
        let mut buf = b"prior".to_vec();
        assert_eq!(encode_agent_reply(&msg, &mut buf), Err(WireError::Oversize));
        assert_eq!(buf, b"prior", "failed encode must not leave a partial frame");
        // The buffer stays usable: an in-cap message encodes after the
        // failure and decodes cleanly.
        buf.clear();
        encode_to_agent(&ToAgent::Shutdown, &mut buf).unwrap();
        assert!(matches!(decode_to_agent(&buf).unwrap(), ToAgent::Shutdown));
    }

    /// A three-frame stream exercising every message shape the reader
    /// will see: a windowed announce, a multi-variant bid, a shutdown.
    fn sample_stream() -> (Vec<u8>, Vec<Vec<u8>>) {
        let f = fmp(1.0, 8);
        let mut frames = Vec::new();
        let mut one = Vec::new();
        encode_to_agent(
            &ToAgent::Announce {
                round: 3,
                now: 250,
                windows: Arc::new(vec![Window {
                    slice: 1,
                    capacity_gb: 10.0,
                    speed: 2.0 / 7.0,
                    interval: Interval::new(50, 900),
                }]),
            },
            &mut one,
        )
        .unwrap();
        frames.push(one.clone());
        one.clear();
        encode_agent_reply(
            &AgentReply::Bid {
                job: 4,
                round: 3,
                bids: vec![vec![variant(0, 4, &f), variant(1, 4, &f)]],
                done: false,
            },
            &mut one,
        )
        .unwrap();
        frames.push(one.clone());
        one.clear();
        encode_to_agent(&ToAgent::Shutdown, &mut one).unwrap();
        frames.push(one);
        let stream: Vec<u8> = frames.iter().flatten().copied().collect();
        (stream, frames)
    }

    fn drain(r: &mut FrameReader) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        while let Some(f) = r.next_frame().expect("valid stream") {
            out.push(f);
        }
        out
    }

    #[test]
    fn frame_reader_reassembles_at_every_split_point() {
        // Per-byte fragmentation sweep: whatever point the stream is
        // cut at — mid-prefix, mid-payload, on a frame boundary — the
        // reader yields the identical frame sequence.
        let (stream, frames) = sample_stream();
        for split in 0..=stream.len() {
            let mut r = FrameReader::new();
            let mut got = Vec::new();
            r.feed(&stream[..split]);
            got.extend(drain(&mut r));
            r.feed(&stream[split..]);
            got.extend(drain(&mut r));
            assert_eq!(got, frames, "split at {split} changed the frame sequence");
        }
        // Worst case: one byte per read.
        let mut r = FrameReader::new();
        let mut got = Vec::new();
        for &b in &stream {
            r.feed(&[b]);
            got.extend(drain(&mut r));
        }
        assert_eq!(got, frames, "byte-at-a-time feed changed the frame sequence");
    }

    #[test]
    fn frame_reader_rejects_oversized_prefix_and_recovers_on_clear() {
        let mut r = FrameReader::new();
        r.feed(&u32::MAX.to_le_bytes());
        assert_eq!(r.next_frame(), Err(WireError::Frame), "same cap as frame_payload");
        // Desync is sticky until the caller clears (drop-connection
        // path); after clear the reader works again.
        assert_eq!(r.next_frame(), Err(WireError::Frame));
        r.clear();
        let (stream, frames) = sample_stream();
        r.feed(&stream);
        assert_eq!(drain(&mut r), frames);
    }
}
