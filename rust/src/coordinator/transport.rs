//! The transport layer of the protocol runtime: how leader ↔ agent
//! messages move, separated from *what* they mean.
//!
//! The leader drives a [`Transport`] and never touches channels or bytes
//! directly. Two implementations:
//!
//! - [`LoopbackTransport`] — the original in-process plumbing, rebuilt on
//!   **bounded** per-agent queues: typed [`ToAgent`] values over
//!   `mpsc::sync_channel`, replies over one shared unbounded channel.
//!   Default, and the reference for decision parity.
//! - [`FramedTransport`] — every message crosses as a length-prefixed
//!   byte frame through the [`wire`](super::wire) codec: encoded on
//!   send, decoded on receive, on both sides. In-process transport of
//!   real bytes — the deployment-shaped path, exercised by the parity
//!   tests to prove serialization changes no decision.
//!
//! # Backpressure
//!
//! Each agent's inbox holds at most [`DEFAULT_AGENT_QUEUE`] messages and
//! the leader only ever *tries* to send: when an agent has fallen behind
//! far enough to fill its queue, the message is dropped and the send
//! reports it. A dropped `Announce` means the leader does not wait for —
//! and the round proceeds without — that agent's bids: a slow agent
//! degrades only its own participation, never the round. Queue depth is
//! sized so this cannot trigger in the synchronous-round runs (the
//! leader blocks on reply collection each round, bounding in-flight
//! messages per agent to a small constant), keeping Loopback
//! bit-identical to the pre-transport coordinator.
//!
//! # Shutdown
//!
//! [`Transport::shutdown`] sends best-effort `Shutdown`s, then *closes*
//! every agent inbox by dropping the senders. Agents drain what is
//! queued and exit on channel disconnect, so a full queue (which would
//! drop the `Shutdown` message itself) can never leave a thread hanging
//! in `join`.

use super::messages::{AgentReply, ToAgent};
use super::wire;
use crate::config::JasdaConfig;
use crate::job::Job;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// Per-agent inbox capacity (messages). One synchronous round keeps at
/// most a handful of messages in flight per agent (one `Announce`, one
/// `Awarded`, a few `Completed`s — subjobs last ≥ τ_min, many rounds),
/// so 64 is an order of magnitude of headroom, not a tuning knob.
pub const DEFAULT_AGENT_QUEUE: usize = 64;

/// Message plane between one leader and its job agents.
///
/// Sends are non-blocking and fallible (bounded queues — see the module
/// docs); receive blocks until a reply or disconnect. Implementations
/// own the agent threads and reclaim them in [`shutdown`](Self::shutdown).
pub trait Transport {
    /// Number of agents.
    fn agents(&self) -> usize;

    /// Try to deliver `msg` to agent `agent`. Returns `false` when the
    /// message was dropped (inbox full, or the agent is gone).
    fn send(&mut self, agent: usize, msg: &ToAgent) -> bool;

    /// Deliver `msg` to every agent; returns the number delivered and
    /// records the agents whose copy was dropped in `dropped`.
    fn broadcast(&mut self, msg: &ToAgent, dropped: &mut Vec<usize>) -> usize {
        dropped.clear();
        let mut delivered = 0;
        for agent in 0..self.agents() {
            if self.send(agent, msg) {
                delivered += 1;
            } else {
                dropped.push(agent);
            }
        }
        delivered
    }

    /// Block for the next agent reply; `None` once every agent has
    /// disconnected.
    fn recv(&mut self) -> Option<AgentReply>;

    /// Tear down: close every agent inbox and join the agent threads.
    /// Idempotent.
    fn shutdown(&mut self);
}

/// In-process transport: typed messages over std channels (default).
pub struct LoopbackTransport {
    to_agents: Vec<mpsc::SyncSender<ToAgent>>,
    replies: mpsc::Receiver<AgentReply>,
    handles: Vec<JoinHandle<()>>,
}

impl LoopbackTransport {
    /// Spawn one agent thread per job, each with a `queue`-deep inbox.
    pub fn spawn(jobs: Vec<Job>, cfg: &JasdaConfig, queue: usize) -> Self {
        let cap = queue.max(1);
        let (reply_tx, replies) = mpsc::channel::<AgentReply>();
        let mut to_agents = Vec::with_capacity(jobs.len());
        let mut handles = Vec::with_capacity(jobs.len());
        for job in jobs {
            let (tx, rx) = mpsc::sync_channel::<ToAgent>(cap);
            to_agents.push(tx);
            let jcfg = cfg.clone();
            let rtx = reply_tx.clone();
            handles.push(std::thread::spawn(move || {
                super::agent_loop(job, jcfg, || rx.recv().ok(), |reply| rtx.send(reply).is_ok());
            }));
        }
        drop(reply_tx);
        LoopbackTransport { to_agents, replies, handles }
    }
}

impl Transport for LoopbackTransport {
    fn agents(&self) -> usize {
        self.to_agents.len()
    }

    fn send(&mut self, agent: usize, msg: &ToAgent) -> bool {
        self.to_agents[agent].try_send(msg.clone()).is_ok()
    }

    fn recv(&mut self) -> Option<AgentReply> {
        self.replies.recv().ok()
    }

    fn shutdown(&mut self) {
        for tx in &self.to_agents {
            let _ = tx.try_send(ToAgent::Shutdown);
        }
        // Closing the inboxes is the reliable signal: agents drain and
        // exit on disconnect even if the Shutdown above was dropped.
        self.to_agents.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Byte-frame transport: every message is encoded by the [`wire`] codec
/// into a length-prefixed frame on send and decoded on the receiving
/// side, in both directions. Undecodable frames are dropped by the
/// receiver (counted as silence), never propagated as panics.
pub struct FramedTransport {
    to_agents: Vec<mpsc::SyncSender<Vec<u8>>>,
    replies: mpsc::Receiver<Vec<u8>>,
    handles: Vec<JoinHandle<()>>,
    /// Reused encode buffer (a broadcast encodes once, clones per agent).
    scratch: Vec<u8>,
}

impl FramedTransport {
    /// Spawn one agent thread per job; agent endpoints decode/encode the
    /// same frames the leader side does.
    pub fn spawn(jobs: Vec<Job>, cfg: &JasdaConfig, queue: usize) -> Self {
        let cap = queue.max(1);
        let (reply_tx, replies) = mpsc::channel::<Vec<u8>>();
        let mut to_agents = Vec::with_capacity(jobs.len());
        let mut handles = Vec::with_capacity(jobs.len());
        for job in jobs {
            let (tx, rx) = mpsc::sync_channel::<Vec<u8>>(cap);
            to_agents.push(tx);
            let jcfg = cfg.clone();
            let rtx = reply_tx.clone();
            handles.push(std::thread::spawn(move || {
                let mut buf: Vec<u8> = Vec::new();
                super::agent_loop(
                    job,
                    jcfg,
                    || loop {
                        let frame = rx.recv().ok()?;
                        match wire::decode_to_agent(&frame) {
                            Ok(msg) => return Some(msg),
                            Err(_) => continue,
                        }
                    },
                    |reply| {
                        buf.clear();
                        wire::encode_agent_reply(&reply, &mut buf);
                        rtx.send(buf.clone()).is_ok()
                    },
                );
            }));
        }
        drop(reply_tx);
        FramedTransport { to_agents, replies, handles, scratch: Vec::new() }
    }
}

impl Transport for FramedTransport {
    fn agents(&self) -> usize {
        self.to_agents.len()
    }

    fn send(&mut self, agent: usize, msg: &ToAgent) -> bool {
        self.scratch.clear();
        wire::encode_to_agent(msg, &mut self.scratch);
        self.to_agents[agent].try_send(self.scratch.clone()).is_ok()
    }

    fn broadcast(&mut self, msg: &ToAgent, dropped: &mut Vec<usize>) -> usize {
        dropped.clear();
        self.scratch.clear();
        wire::encode_to_agent(msg, &mut self.scratch);
        let mut delivered = 0;
        for (agent, tx) in self.to_agents.iter().enumerate() {
            if tx.try_send(self.scratch.clone()).is_ok() {
                delivered += 1;
            } else {
                dropped.push(agent);
            }
        }
        delivered
    }

    fn recv(&mut self) -> Option<AgentReply> {
        loop {
            let frame = self.replies.recv().ok()?;
            if let Ok(reply) = wire::decode_agent_reply(&frame) {
                return Some(reply);
            }
        }
    }

    fn shutdown(&mut self) {
        self.scratch.clear();
        wire::encode_to_agent(&ToAgent::Shutdown, &mut self.scratch);
        for tx in &self.to_agents {
            let _ = tx.try_send(self.scratch.clone());
        }
        self.to_agents.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::messages::CompletionReport;
    use super::*;

    fn completed() -> ToAgent {
        ToAgent::Completed(CompletionReport { planned_work: 1.0, realized_work: 1.0, at: 10 })
    }

    #[test]
    fn loopback_backpressure_drops_when_queue_full() {
        // A transport whose single "agent" never drains its depth-1
        // inbox: the first send lands, the second is dropped — and only
        // that agent is affected, the call never blocks.
        let (tx, _rx_keepalive) = mpsc::sync_channel::<ToAgent>(1);
        let (_reply_tx, replies) = mpsc::channel::<AgentReply>();
        let mut t =
            LoopbackTransport { to_agents: vec![tx], replies, handles: Vec::new() };
        assert!(t.send(0, &completed()));
        assert!(!t.send(0, &completed()), "full inbox must drop, not block");
        let mut dropped = Vec::new();
        assert_eq!(t.broadcast(&completed(), &mut dropped), 0);
        assert_eq!(dropped, vec![0]);
    }

    #[test]
    fn send_to_dead_agent_reports_drop() {
        let (tx, rx) = mpsc::sync_channel::<ToAgent>(4);
        drop(rx);
        let (_reply_tx, replies) = mpsc::channel::<AgentReply>();
        let mut t =
            LoopbackTransport { to_agents: vec![tx], replies, handles: Vec::new() };
        assert!(!t.send(0, &completed()));
        t.shutdown();
        t.shutdown(); // idempotent
    }

    #[test]
    fn framed_backpressure_drops_when_queue_full() {
        let (tx, _rx_keepalive) = mpsc::sync_channel::<Vec<u8>>(1);
        let (_reply_tx, replies) = mpsc::channel::<Vec<u8>>();
        let mut t = FramedTransport {
            to_agents: vec![tx],
            replies,
            handles: Vec::new(),
            scratch: Vec::new(),
        };
        assert!(t.send(0, &completed()));
        assert!(!t.send(0, &completed()));
    }

    #[test]
    fn framed_recv_skips_garbage_frames() {
        let (reply_tx, replies) = mpsc::channel::<Vec<u8>>();
        let mut t = FramedTransport {
            to_agents: Vec::new(),
            replies,
            handles: Vec::new(),
            scratch: Vec::new(),
        };
        reply_tx.send(vec![0xDE, 0xAD]).unwrap();
        let mut good = Vec::new();
        wire::encode_agent_reply(
            &AgentReply::Bid { job: 3, round: 1, bids: vec![], done: false },
            &mut good,
        );
        reply_tx.send(good).unwrap();
        drop(reply_tx);
        match t.recv() {
            Some(AgentReply::Bid { job, round, .. }) => {
                assert_eq!(job, 3);
                assert_eq!(round, 1);
            }
            None => panic!("good frame after garbage must be delivered"),
        }
        assert!(t.recv().is_none(), "disconnect after draining");
    }
}
