//! The transport layer of the protocol runtime: how leader ↔ agent
//! messages move, separated from *what* they mean.
//!
//! The leader drives a [`Transport`] and never touches channels or bytes
//! directly. Two implementations:
//!
//! - [`LoopbackTransport`] — the original in-process plumbing, rebuilt on
//!   **bounded** per-agent queues: typed [`ToAgent`] values over
//!   `mpsc::sync_channel`, replies over one shared unbounded channel.
//!   Default, and the reference for decision parity.
//! - [`FramedTransport`] — every message crosses as a length-prefixed
//!   byte frame through the [`wire`](super::wire) codec: encoded on
//!   send, decoded on receive, on both sides. In-process transport of
//!   real bytes — the deployment-shaped path, exercised by the parity
//!   tests to prove serialization changes no decision.
//! - [`SocketTransport`](super::socket::SocketTransport) — the same
//!   frames over real TCP or Unix-domain sockets (`jasda.transport =
//!   "tcp" | "unix"`): agents connect to the leader's listener and the
//!   leader serves every connection from **one** poll-driven I/O
//!   thread, reassembling frames from partial reads with
//!   [`wire::FrameReader`](super::wire::FrameReader). Same protocol,
//!   real I/O.
//!
//! [`FaultyTransport`](super::faults::FaultyTransport) wraps the
//! in-process transports to inject deterministic adversity (crashes,
//! delays, corruption, drops) for the robustness tests; the socket
//! transport applies the same [`FaultPlan`](super::faults::FaultPlan)
//! directly at the socket layer (crash = close the connection, corrupt
//! = flip bytes on the stream, delay = hold the write).
//!
//! # Backpressure
//!
//! Each agent's inbox holds at most [`DEFAULT_AGENT_QUEUE`] messages and
//! the leader only ever *tries* to send: when an agent has fallen behind
//! far enough to fill its queue, the message is dropped and the send
//! reports it. A dropped `Announce` means the leader does not wait for —
//! and the round proceeds without — that agent's bids: a slow agent
//! degrades only its own participation, never the round. Queue depth is
//! sized so this cannot trigger in the synchronous-round runs (the
//! leader blocks on reply collection each round, bounding in-flight
//! messages per agent to a small constant), keeping Loopback
//! bit-identical to the pre-transport coordinator.
//!
//! # Deadlines
//!
//! Receives are deadline-aware: [`Transport::recv_deadline`] blocks at
//! most until a caller-chosen instant and reports [`Recv::Empty`] when
//! the deadline passes, and [`Transport::try_recv`] never blocks at
//! all. The leader's per-round bid deadline (`jasda.round_timeout_ms`)
//! is built on exactly this: a round clears with whatever bids arrived
//! in time, instead of blocking forever on an agent that died after the
//! announce was delivered. Passing `None` as the deadline restores the
//! original block-until-reply behavior bit for bit.
//!
//! An **already-expired** deadline dequeues nothing: expiry is checked
//! before any receive attempt, so a queued reply can never be delivered
//! *after* an instant the caller already declared passed (a bare
//! `recv_timeout` with a zero duration does not guarantee that). All
//! bundled transports route through one shared helper, so the pinned
//! semantics cannot drift between them.
//!
//! # Decode failures
//!
//! A reply frame that fails wire decoding is **not** silently dropped:
//! the framed transport reports it as [`Recv::Rejected`] with the
//! sending agent's index and counts it in
//! [`Transport::frames_rejected`]. The leader counts the reject as that
//! agent's reply (so collection cannot wedge on a corrupt frame) and
//! feeds its quarantine streak. The typed loopback transport cannot
//! produce rejects.
//!
//! # Shutdown
//!
//! [`Transport::shutdown`] sends best-effort `Shutdown`s, then *closes*
//! every agent inbox by dropping the senders. Agents drain what is
//! queued and exit on channel disconnect, so a full queue (which would
//! drop the `Shutdown` message itself) can never leave a thread hanging
//! in `join`.

use super::messages::{AgentReply, ToAgent};
use super::wire;
use crate::config::JasdaConfig;
use crate::job::Job;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Per-agent inbox capacity (messages). One synchronous round keeps at
/// most a handful of messages in flight per agent (one `Announce`, one
/// `Awarded`, a few `Completed`s — subjobs last ≥ τ_min, many rounds),
/// so 64 is an order of magnitude of headroom, not a tuning knob.
pub const DEFAULT_AGENT_QUEUE: usize = 64;

/// One receive attempt's outcome.
#[derive(Debug)]
pub enum Recv {
    /// A decoded agent reply.
    Msg(AgentReply),
    /// A frame arrived but failed wire decoding; `agent` is the sender.
    /// Produced by the framed transport (and by injected corruption),
    /// never by the typed loopback path.
    Rejected {
        /// Index of the agent whose frame was rejected.
        agent: usize,
    },
    /// Nothing arrived before the deadline ([`Transport::recv_deadline`])
    /// or nothing was queued ([`Transport::try_recv`]).
    Empty,
    /// Every agent endpoint has disconnected.
    Disconnected,
}

/// Message plane between one leader and its job agents.
///
/// Sends are non-blocking and fallible (bounded queues — see the module
/// docs); receives are deadline-aware. Implementations own the agent
/// threads and reclaim them in [`shutdown`](Self::shutdown).
pub trait Transport {
    /// Number of agents.
    fn agents(&self) -> usize;

    /// Try to deliver `msg` to agent `agent`. Returns `false` when the
    /// message was dropped (inbox full, or the agent is gone).
    fn send(&mut self, agent: usize, msg: &ToAgent) -> bool;

    /// Deliver `msg` to every agent not masked out by `skip` (an empty
    /// slice skips nobody; the leader passes its quarantine mask);
    /// returns the number delivered and records the agents whose copy
    /// was dropped in `dropped`.
    fn broadcast(&mut self, msg: &ToAgent, skip: &[bool], dropped: &mut Vec<usize>) -> usize {
        dropped.clear();
        let mut delivered = 0;
        for agent in 0..self.agents() {
            if skip.get(agent).copied().unwrap_or(false) {
                continue;
            }
            if self.send(agent, msg) {
                delivered += 1;
            } else {
                dropped.push(agent);
            }
        }
        delivered
    }

    /// Block for the next agent reply. With `Some(deadline)` give up at
    /// that instant and return [`Recv::Empty`]; with `None` block until
    /// a reply or disconnect (the pre-deadline behavior).
    ///
    /// An already-expired deadline must return [`Recv::Empty`] without
    /// dequeuing anything, even when replies are queued — see the
    /// module docs (# Deadlines).
    fn recv_deadline(&mut self, deadline: Option<Instant>) -> Recv;

    /// Non-blocking receive: whatever is queued right now, else
    /// [`Recv::Empty`].
    fn try_recv(&mut self) -> Recv;

    /// Reply frames rejected by wire decoding so far. Typed transports
    /// return 0; the framed transport counts every [`Recv::Rejected`]
    /// it reported.
    fn frames_rejected(&self) -> u64 {
        0
    }

    /// Tear down: close every agent inbox and join the agent threads.
    /// Idempotent.
    fn shutdown(&mut self);
}

/// How a deadline-aware receive ended without a message.
pub(crate) enum RecvEnd {
    /// Deadline passed (or was already expired) with nothing dequeued.
    Empty,
    /// Every sender is gone.
    Disconnected,
}

/// Deadline-aware receive on an `mpsc` reply stream — the one
/// implementation of the pinned `recv_deadline` semantics, shared by
/// every bundled transport (loopback, framed, socket).
///
/// The intended already-expired behavior, pinned here: a deadline at or
/// before "now" returns [`RecvEnd::Empty`] **without dequeuing**, even
/// if a reply is sitting in the queue. `recv_timeout` with a zero
/// duration does not guarantee that — it may still take an available
/// message, delivering a reply *after* the round deadline the
/// collection loop already declared passed — so expiry is checked
/// before any receive attempt. `None` blocks until a reply or
/// disconnect.
pub(crate) fn recv_deadline_on<T>(
    rx: &mpsc::Receiver<T>,
    deadline: Option<Instant>,
) -> Result<T, RecvEnd> {
    match deadline {
        None => rx.recv().map_err(|_| RecvEnd::Disconnected),
        Some(d) => {
            let now = Instant::now();
            if d <= now {
                return Err(RecvEnd::Empty);
            }
            match rx.recv_timeout(d - now) {
                Ok(got) => Ok(got),
                Err(mpsc::RecvTimeoutError::Timeout) => Err(RecvEnd::Empty),
                Err(mpsc::RecvTimeoutError::Disconnected) => Err(RecvEnd::Disconnected),
            }
        }
    }
}

/// In-process transport: typed messages over std channels (default).
pub struct LoopbackTransport {
    to_agents: Vec<mpsc::SyncSender<ToAgent>>,
    replies: mpsc::Receiver<AgentReply>,
    handles: Vec<JoinHandle<()>>,
}

impl LoopbackTransport {
    /// Spawn one agent thread per job, each with a `queue`-deep inbox.
    pub fn spawn(jobs: Vec<Job>, cfg: &JasdaConfig, queue: usize) -> Self {
        let cap = queue.max(1);
        let (reply_tx, replies) = mpsc::channel::<AgentReply>();
        let mut to_agents = Vec::with_capacity(jobs.len());
        let mut handles = Vec::with_capacity(jobs.len());
        for job in jobs {
            let (tx, rx) = mpsc::sync_channel::<ToAgent>(cap);
            to_agents.push(tx);
            let jcfg = cfg.clone();
            let rtx = reply_tx.clone();
            handles.push(std::thread::spawn(move || {
                super::agent_loop(job, jcfg, || rx.recv().ok(), |reply| rtx.send(reply).is_ok());
            }));
        }
        drop(reply_tx);
        LoopbackTransport { to_agents, replies, handles }
    }

    /// Build a transport over externally created endpoints — for test
    /// harnesses and custom agent implementations. `to_agents[i]` is
    /// agent `i`'s inbox, `replies` the shared reply stream, `handles`
    /// the threads to join on shutdown (may be empty).
    pub fn from_parts(
        to_agents: Vec<mpsc::SyncSender<ToAgent>>,
        replies: mpsc::Receiver<AgentReply>,
        handles: Vec<JoinHandle<()>>,
    ) -> Self {
        LoopbackTransport { to_agents, replies, handles }
    }
}

impl Transport for LoopbackTransport {
    fn agents(&self) -> usize {
        self.to_agents.len()
    }

    fn send(&mut self, agent: usize, msg: &ToAgent) -> bool {
        self.to_agents[agent].try_send(msg.clone()).is_ok()
    }

    fn recv_deadline(&mut self, deadline: Option<Instant>) -> Recv {
        match recv_deadline_on(&self.replies, deadline) {
            Ok(reply) => Recv::Msg(reply),
            Err(RecvEnd::Empty) => Recv::Empty,
            Err(RecvEnd::Disconnected) => Recv::Disconnected,
        }
    }

    fn try_recv(&mut self) -> Recv {
        match self.replies.try_recv() {
            Ok(reply) => Recv::Msg(reply),
            Err(mpsc::TryRecvError::Empty) => Recv::Empty,
            Err(mpsc::TryRecvError::Disconnected) => Recv::Disconnected,
        }
    }

    fn shutdown(&mut self) {
        for tx in &self.to_agents {
            let _ = tx.try_send(ToAgent::Shutdown);
        }
        // Closing the inboxes is the reliable signal: agents drain and
        // exit on disconnect even if the Shutdown above was dropped.
        self.to_agents.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Byte-frame transport: every message is encoded by the [`wire`] codec
/// into a length-prefixed frame on send and decoded on the receiving
/// side, in both directions. Reply frames carry the sending agent's
/// index out of band (in deployment this is the connection identity),
/// so an undecodable frame is attributed — reported as
/// [`Recv::Rejected`] and counted — instead of silently lost.
pub struct FramedTransport {
    to_agents: Vec<mpsc::SyncSender<Vec<u8>>>,
    replies: mpsc::Receiver<(usize, Vec<u8>)>,
    handles: Vec<JoinHandle<()>>,
    /// Reused encode buffer (a broadcast encodes once, clones per agent).
    scratch: Vec<u8>,
    /// Reply frames that failed wire decoding.
    frames_rejected: u64,
}

impl FramedTransport {
    /// Spawn one agent thread per job; agent endpoints decode/encode the
    /// same frames the leader side does.
    pub fn spawn(jobs: Vec<Job>, cfg: &JasdaConfig, queue: usize) -> Self {
        let cap = queue.max(1);
        let (reply_tx, replies) = mpsc::channel::<(usize, Vec<u8>)>();
        let mut to_agents = Vec::with_capacity(jobs.len());
        let mut handles = Vec::with_capacity(jobs.len());
        for (agent, job) in jobs.into_iter().enumerate() {
            let (tx, rx) = mpsc::sync_channel::<Vec<u8>>(cap);
            to_agents.push(tx);
            let jcfg = cfg.clone();
            let rtx = reply_tx.clone();
            handles.push(std::thread::spawn(move || {
                let mut buf: Vec<u8> = Vec::new();
                super::agent_loop(
                    job,
                    jcfg,
                    || loop {
                        let frame = rx.recv().ok()?;
                        match wire::decode_to_agent(&frame) {
                            Ok(msg) => return Some(msg),
                            Err(_) => continue,
                        }
                    },
                    |reply| {
                        buf.clear();
                        match wire::encode_agent_reply(&reply, &mut buf) {
                            Ok(()) => rtx.send((agent, buf.clone())).is_ok(),
                            // An oversized reply is this agent's own
                            // loss: swallow it (the leader's round
                            // deadline covers the missing bid) rather
                            // than tearing the agent down over one bad
                            // message.
                            Err(_) => true,
                        }
                    },
                );
            }));
        }
        drop(reply_tx);
        FramedTransport { to_agents, replies, handles, scratch: Vec::new(), frames_rejected: 0 }
    }

    /// Build a transport over externally created endpoints — the framed
    /// counterpart of [`LoopbackTransport::from_parts`]. Reply frames
    /// are `(agent index, frame bytes)` pairs.
    pub fn from_parts(
        to_agents: Vec<mpsc::SyncSender<Vec<u8>>>,
        replies: mpsc::Receiver<(usize, Vec<u8>)>,
        handles: Vec<JoinHandle<()>>,
    ) -> Self {
        FramedTransport { to_agents, replies, handles, scratch: Vec::new(), frames_rejected: 0 }
    }

    fn decode_reply(&mut self, agent: usize, frame: &[u8]) -> Recv {
        match wire::decode_agent_reply(frame) {
            Ok(reply) => Recv::Msg(reply),
            Err(_) => {
                self.frames_rejected += 1;
                Recv::Rejected { agent }
            }
        }
    }
}

impl Transport for FramedTransport {
    fn agents(&self) -> usize {
        self.to_agents.len()
    }

    fn send(&mut self, agent: usize, msg: &ToAgent) -> bool {
        self.scratch.clear();
        if wire::encode_to_agent(msg, &mut self.scratch).is_err() {
            return false;
        }
        self.to_agents[agent].try_send(self.scratch.clone()).is_ok()
    }

    fn broadcast(&mut self, msg: &ToAgent, skip: &[bool], dropped: &mut Vec<usize>) -> usize {
        dropped.clear();
        self.scratch.clear();
        // An encode failure (oversized frame) is the *sender's* fault:
        // deliver to nobody and blame nobody. Reporting every receiver
        // in `dropped` would feed their quarantine streaks for a frame
        // the leader produced — the poisoning the encode-time cap
        // exists to prevent.
        if wire::encode_to_agent(msg, &mut self.scratch).is_err() {
            return 0;
        }
        let mut delivered = 0;
        for (agent, tx) in self.to_agents.iter().enumerate() {
            if skip.get(agent).copied().unwrap_or(false) {
                continue;
            }
            if tx.try_send(self.scratch.clone()).is_ok() {
                delivered += 1;
            } else {
                dropped.push(agent);
            }
        }
        delivered
    }

    fn recv_deadline(&mut self, deadline: Option<Instant>) -> Recv {
        let (agent, frame) = match recv_deadline_on(&self.replies, deadline) {
            Ok(got) => got,
            Err(RecvEnd::Empty) => return Recv::Empty,
            Err(RecvEnd::Disconnected) => return Recv::Disconnected,
        };
        self.decode_reply(agent, &frame)
    }

    fn try_recv(&mut self) -> Recv {
        let (agent, frame) = match self.replies.try_recv() {
            Ok(got) => got,
            Err(mpsc::TryRecvError::Empty) => return Recv::Empty,
            Err(mpsc::TryRecvError::Disconnected) => return Recv::Disconnected,
        };
        self.decode_reply(agent, &frame)
    }

    fn frames_rejected(&self) -> u64 {
        self.frames_rejected
    }

    fn shutdown(&mut self) {
        self.scratch.clear();
        if wire::encode_to_agent(&ToAgent::Shutdown, &mut self.scratch).is_ok() {
            for tx in &self.to_agents {
                let _ = tx.try_send(self.scratch.clone());
            }
        }
        self.to_agents.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::messages::CompletionReport;
    use super::*;
    use std::time::Duration;

    fn completed() -> ToAgent {
        ToAgent::Completed(CompletionReport { planned_work: 1.0, realized_work: 1.0, at: 10 })
    }

    #[test]
    fn loopback_backpressure_drops_when_queue_full() {
        // A transport whose single "agent" never drains its depth-1
        // inbox: the first send lands, the second is dropped — and only
        // that agent is affected, the call never blocks.
        let (tx, _rx_keepalive) = mpsc::sync_channel::<ToAgent>(1);
        let (_reply_tx, replies) = mpsc::channel::<AgentReply>();
        let mut t = LoopbackTransport::from_parts(vec![tx], replies, Vec::new());
        assert!(t.send(0, &completed()));
        assert!(!t.send(0, &completed()), "full inbox must drop, not block");
        let mut dropped = Vec::new();
        assert_eq!(t.broadcast(&completed(), &[], &mut dropped), 0);
        assert_eq!(dropped, vec![0]);
    }

    #[test]
    fn broadcast_skip_mask_excludes_agents() {
        let (tx0, _k0) = mpsc::sync_channel::<ToAgent>(4);
        let (tx1, _k1) = mpsc::sync_channel::<ToAgent>(4);
        let (_reply_tx, replies) = mpsc::channel::<AgentReply>();
        let mut t = LoopbackTransport::from_parts(vec![tx0, tx1], replies, Vec::new());
        let mut dropped = Vec::new();
        // Skipped agents are neither delivered to nor reported dropped.
        assert_eq!(t.broadcast(&completed(), &[true, false], &mut dropped), 1);
        assert!(dropped.is_empty());
        assert_eq!(_k1.try_recv().ok().map(|_| ()), Some(()));
        assert!(_k0.try_recv().is_err(), "skipped agent must not receive the broadcast");
    }

    #[test]
    fn send_to_dead_agent_reports_drop() {
        let (tx, rx) = mpsc::sync_channel::<ToAgent>(4);
        drop(rx);
        let (_reply_tx, replies) = mpsc::channel::<AgentReply>();
        let mut t = LoopbackTransport::from_parts(vec![tx], replies, Vec::new());
        assert!(!t.send(0, &completed()));
        t.shutdown();
        t.shutdown(); // idempotent
    }

    #[test]
    fn recv_deadline_times_out_and_delivers_late_nothing() {
        let (_reply_tx, replies) = mpsc::channel::<AgentReply>();
        let mut t = LoopbackTransport::from_parts(Vec::new(), replies, Vec::new());
        let deadline = Instant::now() + Duration::from_millis(5);
        assert!(matches!(t.recv_deadline(Some(deadline)), Recv::Empty));
        assert!(Instant::now() >= deadline, "deadline receive must wait out the deadline");
        assert!(matches!(t.try_recv(), Recv::Empty));
        drop(_reply_tx);
        assert!(matches!(t.try_recv(), Recv::Disconnected));
    }

    #[test]
    fn framed_backpressure_drops_when_queue_full() {
        let (tx, _rx_keepalive) = mpsc::sync_channel::<Vec<u8>>(1);
        let (_reply_tx, replies) = mpsc::channel::<(usize, Vec<u8>)>();
        let mut t = FramedTransport::from_parts(vec![tx], replies, Vec::new());
        assert!(t.send(0, &completed()));
        assert!(!t.send(0, &completed()));
    }

    #[test]
    fn framed_recv_reports_garbage_frames_with_sender() {
        let (reply_tx, replies) = mpsc::channel::<(usize, Vec<u8>)>();
        let mut t = FramedTransport::from_parts(Vec::new(), replies, Vec::new());
        reply_tx.send((7, vec![0xDE, 0xAD])).unwrap();
        let mut good = Vec::new();
        wire::encode_agent_reply(
            &AgentReply::Bid { job: 3, round: 1, bids: vec![], done: false },
            &mut good,
        )
        .unwrap();
        reply_tx.send((0, good)).unwrap();
        drop(reply_tx);
        // The garbage frame is surfaced — attributed to its sender and
        // counted — not swallowed.
        match t.recv_deadline(None) {
            Recv::Rejected { agent } => assert_eq!(agent, 7),
            other => panic!("garbage frame must be rejected, got {other:?}"),
        }
        assert_eq!(t.frames_rejected(), 1);
        match t.recv_deadline(None) {
            Recv::Msg(AgentReply::Bid { job, round, .. }) => {
                assert_eq!(job, 3);
                assert_eq!(round, 1);
            }
            other => panic!("good frame after garbage must be delivered, got {other:?}"),
        }
        assert!(matches!(t.recv_deadline(None), Recv::Disconnected), "disconnect after draining");
        assert_eq!(t.frames_rejected(), 1);
    }

    #[test]
    fn expired_deadline_never_dequeues_a_waiting_reply() {
        // Regression (pinned in `recv_deadline_on`): a deadline that
        // has already passed returns Empty even when a reply is queued.
        // The old per-transport code computed a saturating zero wait
        // and called recv_timeout, which may still dequeue — delivering
        // a reply *after* the round deadline the collection loop had
        // declared passed. Every transport shares the helper, so one
        // queue-backed check covers loopback, framed, and socket.
        let (reply_tx, replies) = mpsc::channel::<AgentReply>();
        let mut t = LoopbackTransport::from_parts(Vec::new(), replies, Vec::new());
        reply_tx.send(AgentReply::Bid { job: 1, round: 0, bids: vec![], done: false }).unwrap();
        let expired = Instant::now();
        for _ in 0..3 {
            assert!(
                matches!(t.recv_deadline(Some(expired)), Recv::Empty),
                "expired deadline must not dequeue"
            );
        }
        // The reply was left in place: a live deadline still takes it.
        match t.recv_deadline(Some(Instant::now() + Duration::from_secs(5))) {
            Recv::Msg(AgentReply::Bid { job, .. }) => assert_eq!(job, 1),
            other => panic!("queued reply must survive expired receives, got {other:?}"),
        }
    }

    #[test]
    fn framed_broadcast_oversize_poisons_nobody() {
        use crate::mig::Window;
        use crate::types::Interval;
        use std::sync::Arc;
        // Enough windows to push the Announce frame over MAX_FRAME
        // (19 encoded bytes per window at these field values).
        let n = wire::MAX_FRAME / 16;
        let windows: Vec<Window> = (0..n)
            .map(|_| Window {
                slice: 1,
                capacity_gb: 10.0,
                speed: 0.5,
                interval: Interval::new(1, 2),
            })
            .collect();
        let msg = ToAgent::Announce { round: 1, now: 0, windows: Arc::new(windows) };
        let (tx, rx) = mpsc::sync_channel::<Vec<u8>>(4);
        let (_reply_tx, replies) = mpsc::channel::<(usize, Vec<u8>)>();
        let mut t = FramedTransport::from_parts(vec![tx], replies, Vec::new());
        let mut dropped = Vec::new();
        // The leader produced the bad frame: deliver to nobody, blame
        // nobody — receivers reported as dropped would feed quarantine
        // streaks for the sender's fault.
        assert_eq!(t.broadcast(&msg, &[], &mut dropped), 0);
        assert!(dropped.is_empty(), "oversize encode must not blame receivers");
        assert!(!t.send(0, &msg), "single-send of an oversized message fails too");
        assert!(rx.try_recv().is_err(), "no frame may reach the agent");
    }
}
