//! The composite scoring pipeline (paper §4.2, Eqs. (1)–(5)) as a batched
//! computation, plus the pluggable backend abstraction.
//!
//! One scheduling iteration pools M variant bids; each variant carries a
//! T-bin FMP `(μ, σ)` matrix and its normalized feature vectors. The
//! pipeline computes, per variant:
//!
//! 1. **Safety** — `viol = 1 − Π_t Φ((c_k − μ_t)/σ_t)` (eligibility §4.1a);
//! 2. **Headroom** — `ψ_mem = mean_t clip((c_k − μ_t)/c_k, 0, 1)`;
//! 3. **Calibrated job utility** — `ĥ = trust·h̃ + (1−trust)·HistAvg`
//!    with `h̃ = Σ α_i φ_i` and `trust = γ·ρ_J` (Eq. (5) with the ρ_J
//!    feedback of §4.2.1 folded into the smoothing weight);
//! 4. **System utility** — `f̃ = β·[ψ_util, ψ_mem, ψ_frag, A_i(t)]`;
//! 5. **Score** — `λ·ĥ + (1−λ)·f̃`, zeroed for ineligible/padded lanes.
//!
//! This exact pipeline (same erf polynomial, f32 arithmetic) is what the
//! L1 Pallas kernel computes; [`NativeScorer`] is the rust mirror used by
//! default and in parity tests against the PJRT artifact.

use crate::jasda::pool::WorkerPool;

/// Numerical floor for σ, shared with the kernel.
pub const SIGMA_EPS: f32 = 1e-6;

/// One batch of variants to score. Row-major `[M, T]` FMP matrices plus
/// per-variant feature vectors; scalar policy parameters ride along.
#[derive(Debug, Clone, Default)]
pub struct ScoreBatch {
    /// Number of (real) variants M.
    pub m: usize,
    /// FMP bins per variant T.
    pub t: usize,
    /// Mean memory per bin, `[M*T]` row-major (GiB).
    pub mu: Vec<f32>,
    /// Memory std per bin, `[M*T]` row-major (GiB).
    pub sigma: Vec<f32>,
    /// Declared job features φ = [jct, qos, energy, locality], `[M*4]`.
    pub phi: Vec<f32>,
    /// System features [ψ_util, ψ_frag, A_i(t)], `[M*3]` (headroom is
    /// computed in-pipeline from the FMP).
    pub psi: Vec<f32>,
    /// Per-variant calibration weight `trust = γ·ρ_J ∈ [0,1]`, `[M]`.
    pub trust: Vec<f32>,
    /// Per-variant historical average of verified scores, `[M]`.
    pub hist: Vec<f32>,
    /// Slice capacity c_k (GiB), uniform across the batch. Used when
    /// [`ScoreBatch::row_capacity`] is empty (the single-window case).
    pub capacity: f32,
    /// Per-row slice capacity c_k (GiB) for batches pooling bids across
    /// several announced windows (K-window clearing): row `i` is scored
    /// against `row_capacity[i]`. Empty means "uniform `capacity`".
    /// When non-empty the length must equal `m`.
    pub row_capacity: Vec<f32>,
    /// Safety bound θ.
    pub theta: f32,
    /// Job/system trade-off λ.
    pub lambda: f32,
    /// Job-side weights α (order [jct, qos, energy, locality]).
    pub alpha: [f32; 4],
    /// System-side weights β (order [util, headroom, frag, age]).
    pub beta: [f32; 4],
}

impl ScoreBatch {
    /// Allocate an empty batch with the given FMP bin count.
    pub fn with_bins(t: usize) -> Self {
        ScoreBatch { t, ..Default::default() }
    }

    /// Append one variant row. `fmp_mu`/`fmp_sigma` must have length `t`.
    pub fn push(
        &mut self,
        fmp_mu: &[f64],
        fmp_sigma: &[f64],
        phi: [f64; 4],
        psi: [f64; 3],
        trust: f64,
        hist: f64,
    ) {
        assert_eq!(fmp_mu.len(), self.t, "FMP bin mismatch");
        assert_eq!(fmp_sigma.len(), self.t);
        self.mu.extend(fmp_mu.iter().map(|&x| x as f32));
        self.sigma.extend(fmp_sigma.iter().map(|&x| x as f32));
        self.phi.extend(phi.iter().map(|&x| x as f32));
        self.psi.extend(psi.iter().map(|&x| x as f32));
        self.trust.push(trust as f32);
        self.hist.push(hist as f32);
        self.m += 1;
    }

    /// True when the batch has no rows.
    pub fn is_empty(&self) -> bool {
        self.m == 0
    }

    /// Reset the batch for reuse (keeps `t`, the policy scalars, and all
    /// allocated capacity) — the scheduler's scratch-buffer path.
    pub fn clear(&mut self) {
        self.m = 0;
        self.mu.clear();
        self.sigma.clear();
        self.phi.clear();
        self.psi.clear();
        self.trust.clear();
        self.hist.clear();
        self.row_capacity.clear();
    }

    /// Capacity row `i` is scored against: the per-row value when the
    /// batch spans several windows, else the uniform scalar.
    #[inline]
    pub fn capacity_of(&self, i: usize) -> f32 {
        if self.row_capacity.is_empty() {
            self.capacity
        } else {
            self.row_capacity[i]
        }
    }
}

/// Scores and diagnostics for a batch, row-aligned with the input.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ScoreOutput {
    /// Composite scores `Score(v) ∈ [0,1]`; 0 for ineligible rows.
    pub score: Vec<f32>,
    /// Safety violation probabilities.
    pub violation: Vec<f32>,
    /// Mean memory headroom ψ_mem per row.
    pub headroom: Vec<f32>,
    /// Eligibility mask (violation ≤ θ).
    pub eligible: Vec<bool>,
}

impl ScoreOutput {
    /// Size all lanes for `m` rows, reusing allocated capacity.
    pub fn resize(&mut self, m: usize) {
        self.score.clear();
        self.score.resize(m, 0.0);
        self.violation.clear();
        self.violation.resize(m, 0.0);
        self.headroom.clear();
        self.headroom.resize(m, 0.0);
        self.eligible.clear();
        self.eligible.resize(m, false);
    }
}

/// A scoring backend: either the native mirror or the PJRT-executed
/// AOT artifact (see `runtime::PjrtScorer`).
pub trait ScorerBackend {
    /// Backend name for reports.
    fn name(&self) -> &str;
    /// Score a batch.
    fn score(&mut self, batch: &ScoreBatch) -> anyhow::Result<ScoreOutput>;
    /// Score a batch into a reusable output buffer, with a worker-thread
    /// budget (`threads <= 1` = serial). Rows are independent, so
    /// backends that honor the budget produce bit-identical results at
    /// any thread count; backends with their own execution model may
    /// ignore it. Default: delegate to [`ScorerBackend::score`].
    fn score_into(
        &mut self,
        batch: &ScoreBatch,
        out: &mut ScoreOutput,
        threads: usize,
    ) -> anyhow::Result<()> {
        let _ = threads;
        *out = self.score(batch)?;
        Ok(())
    }
    /// Score a batch into a reusable output buffer, fanning row chunks
    /// out on a persistent [`WorkerPool`] instead of spawning scoped
    /// threads. Same bit-identity contract as [`ScorerBackend::score_into`]
    /// (rows are independent; chunking is deterministic). Default:
    /// delegate to `score_into` with the pool's budget, which is correct
    /// for backends with their own execution model (e.g. PJRT).
    fn score_into_pooled(
        &mut self,
        batch: &ScoreBatch,
        out: &mut ScoreOutput,
        pool: &WorkerPool,
    ) -> anyhow::Result<()> {
        self.score_into(batch, out, pool.budget())
    }
}

/// erf via Abramowitz–Stegun 7.1.26 in f32 — the *same* polynomial the
/// Pallas kernel and jnp oracle use, so backends agree to float precision.
#[inline]
pub fn erf_f32(x: f32) -> f32 {
    const A1: f32 = 0.254829592;
    const A2: f32 = -0.284496736;
    const A3: f32 = 1.421413741;
    const A4: f32 = -1.453152027;
    const A5: f32 = 1.061405429;
    const P: f32 = 0.3275911;
    let sign = if x < 0.0 { -1.0f32 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + P * x);
    let y = 1.0 - (((((A5 * t + A4) * t) + A3) * t + A2) * t + A1) * t * (-x * x).exp();
    sign * y
}

/// Φ(x) in f32, clamped into (0,1) for log safety — kernel-identical.
#[inline]
pub fn normal_cdf_f32(x: f32) -> f32 {
    let c = 0.5 * (1.0 + erf_f32(x / std::f32::consts::SQRT_2));
    c.clamp(1e-12, 1.0)
}

/// Pure-rust scoring backend mirroring the L1/L2 pipeline bit-for-bit
/// (same formulas, f32 arithmetic, same clamps).
#[derive(Debug, Default)]
pub struct NativeScorer;

/// Shape validation shared by the scoring entry points.
fn validate_batch(b: &ScoreBatch) -> anyhow::Result<()> {
    let (m, t) = (b.m, b.t);
    anyhow::ensure!(b.mu.len() == m * t, "mu shape mismatch");
    anyhow::ensure!(b.sigma.len() == m * t, "sigma shape mismatch");
    anyhow::ensure!(b.phi.len() == m * 4 && b.psi.len() == m * 3, "feature shape mismatch");
    anyhow::ensure!(b.trust.len() == m && b.hist.len() == m, "calibration shape mismatch");
    anyhow::ensure!(
        b.row_capacity.is_empty() || b.row_capacity.len() == m,
        "row_capacity must be empty or length m"
    );
    Ok(())
}

/// Score rows `rows` of a (validated) batch into output slices indexed
/// relative to `rows.start`. Every row is computed by exactly the serial
/// pipeline's arithmetic; parallel callers hand disjoint row chunks to
/// worker threads and results stay bit-identical at any thread count.
pub fn score_rows_into(
    b: &ScoreBatch,
    rows: std::ops::Range<usize>,
    score: &mut [f32],
    violation: &mut [f32],
    headroom_out: &mut [f32],
    eligible_out: &mut [bool],
) {
    let t = b.t;
    for (k, i) in rows.enumerate() {
        let c = b.capacity_of(i);
        let inv_c = 1.0 / c;
        let row = i * t;
        // 1) safety. The survival product Π Φ(z_t) is accumulated
        // directly in f64 instead of summing f32 logs: mathematically
        // identical (Φ is clamped ≥ 1e-12, so 64 bins bottom out at
        // 1e-768 ≫ f64::MIN_POSITIVE), and it removes one `ln` per
        // bin from the hot loop (§Perf iteration 1).
        let mut surv = 1.0f64;
        let mut head = 0.0f32;
        let mus = &b.mu[row..row + t];
        let sigmas = &b.sigma[row..row + t];
        for (&mu, &sigma) in mus.iter().zip(sigmas) {
            let gap = c - mu;
            let sig = sigma.max(SIGMA_EPS);
            // Deep-safe shortcut (§Perf iteration 2): Φ(z) ≥ 1−4e-9
            // for z ≥ 6, so the factor is 1.0 to beyond f32
            // precision — skip the erf. Most bins of healthy
            // variants take this branch.
            if gap < 6.0 * sig {
                surv *= normal_cdf_f32(gap / sig) as f64;
            }
            head += (gap * inv_c).clamp(0.0, 1.0);
        }
        let viol = ((1.0 - surv) as f32).clamp(0.0, 1.0);
        let headroom = head / t as f32;

        // 2) calibrated job utility.
        let phi = &b.phi[i * 4..i * 4 + 4];
        let h_tilde: f32 = (0..4).map(|j| b.alpha[j] * phi[j]).sum();
        let trust = b.trust[i];
        let h_cal = trust * h_tilde + (1.0 - trust) * b.hist[i];

        // 3) system utility with in-pipeline headroom.
        let psi = &b.psi[i * 3..i * 3 + 3];
        let f_sys =
            b.beta[0] * psi[0] + b.beta[1] * headroom + b.beta[2] * psi[1] + b.beta[3] * psi[2];

        // 4) composite + eligibility gating.
        let s = b.lambda * h_cal + (1.0 - b.lambda) * f_sys;
        let eligible = viol <= b.theta;
        violation[k] = viol;
        headroom_out[k] = headroom;
        eligible_out[k] = eligible;
        score[k] = if eligible { s.clamp(0.0, 1.0) } else { 0.0 };
    }
}

/// Rows below which a worker thread is not worth its spawn cost.
const PAR_MIN_ROWS_PER_THREAD: usize = 256;

impl ScorerBackend for NativeScorer {
    fn name(&self) -> &str {
        "native"
    }

    fn score(&mut self, b: &ScoreBatch) -> anyhow::Result<ScoreOutput> {
        let mut out = ScoreOutput::default();
        self.score_into(b, &mut out, 1)?;
        Ok(out)
    }

    fn score_into(
        &mut self,
        b: &ScoreBatch,
        out: &mut ScoreOutput,
        threads: usize,
    ) -> anyhow::Result<()> {
        validate_batch(b)?;
        let m = b.m;
        out.resize(m);
        let workers = threads.min(m / PAR_MIN_ROWS_PER_THREAD.max(1)).max(1);
        if workers <= 1 {
            score_rows_into(
                b,
                0..m,
                &mut out.score,
                &mut out.violation,
                &mut out.headroom,
                &mut out.eligible,
            );
            return Ok(());
        }
        // Fan the row space out over `workers` disjoint chunks. Rows are
        // independent and each is computed by the same arithmetic as the
        // serial path, so the output is bit-identical.
        let chunk = (m + workers - 1) / workers;
        std::thread::scope(|scope| {
            let mut score_rest = out.score.as_mut_slice();
            let mut viol_rest = out.violation.as_mut_slice();
            let mut head_rest = out.headroom.as_mut_slice();
            let mut elig_rest = out.eligible.as_mut_slice();
            let mut start = 0usize;
            while start < m {
                let len = chunk.min(m - start);
                let (sc, sr) = score_rest.split_at_mut(len);
                let (vi, vr) = viol_rest.split_at_mut(len);
                let (he, hr) = head_rest.split_at_mut(len);
                let (el, er) = elig_rest.split_at_mut(len);
                let rows = start..start + len;
                scope.spawn(move || score_rows_into(b, rows, sc, vi, he, el));
                score_rest = sr;
                viol_rest = vr;
                head_rest = hr;
                elig_rest = er;
                start += len;
            }
        });
        Ok(())
    }

    fn score_into_pooled(
        &mut self,
        b: &ScoreBatch,
        out: &mut ScoreOutput,
        pool: &WorkerPool,
    ) -> anyhow::Result<()> {
        validate_batch(b)?;
        let m = b.m;
        out.resize(m);
        // Same worker-count formula and chunking as the scoped-thread
        // path, so the two are bit-identical by construction; only the
        // thread spawn cost differs.
        let workers = pool.budget().min(m / PAR_MIN_ROWS_PER_THREAD.max(1)).max(1);
        if workers <= 1 {
            score_rows_into(
                b,
                0..m,
                &mut out.score,
                &mut out.violation,
                &mut out.headroom,
                &mut out.eligible,
            );
            return Ok(());
        }
        let chunk = (m + workers - 1) / workers;
        pool.scope(|scope| {
            let mut score_rest = out.score.as_mut_slice();
            let mut viol_rest = out.violation.as_mut_slice();
            let mut head_rest = out.headroom.as_mut_slice();
            let mut elig_rest = out.eligible.as_mut_slice();
            let mut start = 0usize;
            while start < m {
                let len = chunk.min(m - start);
                let (sc, sr) = score_rest.split_at_mut(len);
                let (vi, vr) = viol_rest.split_at_mut(len);
                let (he, hr) = head_rest.split_at_mut(len);
                let (el, er) = elig_rest.split_at_mut(len);
                let rows = start..start + len;
                scope.spawn(move || score_rows_into(b, rows, sc, vi, he, el));
                score_rest = sr;
                viol_rest = vr;
                head_rest = hr;
                elig_rest = er;
                start += len;
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch_one(mu: f64, sigma: f64, capacity: f32) -> ScoreBatch {
        let mut b = ScoreBatch::with_bins(8);
        b.capacity = capacity;
        b.theta = 0.05;
        b.lambda = 0.6;
        b.alpha = [0.45, 0.25, 0.15, 0.15];
        b.beta = [0.45, 0.2, 0.15, 0.2];
        b.push(
            &[mu; 8],
            &[sigma; 8],
            [0.8, 1.0, 0.5, 0.5],
            [0.7, 1.0, 0.0],
            1.0,
            0.5,
        );
        b
    }

    #[test]
    fn safe_variant_scores_in_unit_interval() {
        let b = batch_one(4.0, 0.3, 10.0);
        let out = NativeScorer.score(&b).unwrap();
        assert!(out.eligible[0]);
        assert!(out.violation[0] < 1e-4);
        assert!(out.score[0] > 0.0 && out.score[0] <= 1.0);
        // headroom = (10-4)/10 = 0.6
        assert!((out.headroom[0] - 0.6).abs() < 1e-5);
    }

    #[test]
    fn unsafe_variant_zeroed() {
        let b = batch_one(9.8, 1.0, 10.0); // mean just below cap, fat sigma
        let out = NativeScorer.score(&b).unwrap();
        assert!(!out.eligible[0]);
        assert!(out.violation[0] > 0.05);
        assert_eq!(out.score[0], 0.0);
    }

    #[test]
    fn score_matches_hand_computation() {
        let b = batch_one(4.0, 0.1, 10.0);
        let out = NativeScorer.score(&b).unwrap();
        // h = .45*.8+.25*1+.15*.5+.15*.5 = .36+.25+.075+.075 = .76
        // trust=1 -> h_cal = .76
        // f = .45*.7 + .2*.6 + .15*1.0 + .2*0 = .315+.12+.15 = .585
        // score = .6*.76 + .4*.585 = .456+.234 = .690
        assert!((out.score[0] - 0.690).abs() < 1e-4, "score {}", out.score[0]);
    }

    #[test]
    fn calibration_pulls_toward_history() {
        let mut b = batch_one(4.0, 0.1, 10.0);
        b.trust[0] = 0.5;
        b.hist[0] = 0.2;
        let out = NativeScorer.score(&b).unwrap();
        // h_cal = .5*.76 + .5*.2 = .48 ; score = .6*.48+.4*.585 = .522
        assert!((out.score[0] - 0.522).abs() < 1e-4, "score {}", out.score[0]);
    }

    #[test]
    fn lambda_extremes() {
        let mut b = batch_one(4.0, 0.1, 10.0);
        b.lambda = 1.0;
        let j = NativeScorer.score(&b).unwrap().score[0];
        assert!((j - 0.76).abs() < 1e-4, "pure job-side {j}");
        b.lambda = 0.0;
        let s = NativeScorer.score(&b).unwrap().score[0];
        assert!((s - 0.585).abs() < 1e-4, "pure system-side {s}");
    }

    #[test]
    fn batch_rows_independent() {
        let mut b = ScoreBatch::with_bins(4);
        b.capacity = 10.0;
        b.theta = 0.05;
        b.lambda = 0.5;
        b.alpha = [0.25; 4];
        b.beta = [0.25; 4];
        b.push(&[4.0; 4], &[0.2; 4], [1.0; 4], [1.0, 1.0, 1.0], 1.0, 0.0);
        b.push(&[9.9; 4], &[1.0; 4], [1.0; 4], [1.0, 1.0, 1.0], 1.0, 0.0);
        b.push(&[2.0; 4], &[0.1; 4], [0.0; 4], [0.0, 0.0, 0.0], 1.0, 0.0);
        let out = NativeScorer.score(&b).unwrap();
        assert!(out.eligible[0] && !out.eligible[1] && out.eligible[2]);
        assert!(out.score[0] > 0.5);
        assert_eq!(out.score[1], 0.0);
        // Row 2: all features zero -> only headroom contributes.
        let expected = 0.5 * (0.25 * out.headroom[2]);
        assert!((out.score[2] - expected).abs() < 1e-5);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut b = batch_one(4.0, 0.1, 10.0);
        b.mu.pop();
        assert!(NativeScorer.score(&b).is_err());
    }

    #[test]
    fn per_row_capacity_scores_each_window() {
        // Two identical rows, one scored against a 20 GiB window and one
        // against a 5 GiB window: the tight row must be ineligible while
        // the roomy row scores normally.
        let mut b = ScoreBatch::with_bins(8);
        b.capacity = 999.0; // must be ignored when row_capacity is set
        b.theta = 0.05;
        b.lambda = 0.6;
        b.alpha = [0.45, 0.25, 0.15, 0.15];
        b.beta = [0.45, 0.2, 0.15, 0.2];
        for _ in 0..2 {
            b.push(&[4.5; 8], &[0.3; 8], [0.8, 1.0, 0.5, 0.5], [0.7, 1.0, 0.0], 1.0, 0.5);
        }
        b.row_capacity = vec![20.0, 5.0];
        let out = NativeScorer.score(&b).unwrap();
        assert!(out.eligible[0]);
        assert!(!out.eligible[1], "4.5±0.3 GiB on a 5 GiB slice violates theta");
        assert_eq!(out.score[1], 0.0);
        // headroom row 0 = (20-4.5)/20
        assert!((out.headroom[0] - 15.5 / 20.0).abs() < 1e-5);

        // Mismatched row_capacity length is rejected.
        b.row_capacity = vec![20.0];
        assert!(NativeScorer.score(&b).is_err());

        // Empty row_capacity falls back to the uniform scalar.
        b.row_capacity = vec![];
        b.capacity = 20.0;
        let out = NativeScorer.score(&b).unwrap();
        assert!(out.eligible[0] && out.eligible[1]);
    }

    #[test]
    fn score_into_parallel_is_bit_identical_and_reuses_buffers() {
        // Large pseudo-random batch: the threaded path must agree with
        // the serial path on every lane, bit for bit.
        let mut b = ScoreBatch::with_bins(8);
        b.capacity = 12.0;
        b.theta = 0.05;
        b.lambda = 0.6;
        b.alpha = [0.45, 0.25, 0.15, 0.15];
        b.beta = [0.45, 0.2, 0.15, 0.2];
        let mut state = 0x9E37_79B9_7F4A_7C15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64
        };
        for _ in 0..1536 {
            let base = 2.0 + 12.0 * next();
            let mu: Vec<f64> = (0..8).map(|_| base + next() - 0.5).collect();
            let sigma: Vec<f64> = (0..8).map(|_| 0.05 + next()).collect();
            b.push(
                &mu,
                &sigma,
                [next(), next(), next(), next()],
                [next(), next(), next()],
                next(),
                next(),
            );
        }
        let serial = NativeScorer.score(&b).unwrap();
        let mut parallel = ScoreOutput::default();
        NativeScorer.score_into(&b, &mut parallel, 8).unwrap();
        assert_eq!(serial, parallel, "threaded scoring diverged from serial");
        // Persistent-pool fan-out: same chunking as the scoped-thread
        // path, so every lane must match bit for bit.
        let pool = crate::jasda::pool::WorkerPool::new(8);
        let mut pooled = ScoreOutput::default();
        NativeScorer.score_into_pooled(&b, &mut pooled, &pool).unwrap();
        assert_eq!(serial, pooled, "pooled scoring diverged from serial");
        // Buffer reuse: scoring a smaller batch into the same output
        // shrinks it and still matches.
        let mut small = ScoreBatch::with_bins(8);
        small.capacity = 12.0;
        small.theta = 0.05;
        small.lambda = 0.6;
        small.alpha = b.alpha;
        small.beta = b.beta;
        small.push(&[4.0; 8], &[0.3; 8], [0.8, 1.0, 0.5, 0.5], [0.7, 1.0, 0.0], 1.0, 0.5);
        NativeScorer.score_into(&small, &mut parallel, 8).unwrap();
        assert_eq!(parallel, NativeScorer.score(&small).unwrap());
        assert_eq!(parallel.score.len(), 1);
        // Batch reuse: clear() keeps policy scalars and capacity.
        small.clear();
        assert!(small.is_empty());
        assert_eq!(small.t, 8);
        assert_eq!(small.lambda, 0.6);
    }

    #[test]
    fn erf_f32_matches_f64_reference() {
        for x in [-3.0f32, -1.5, -0.2, 0.0, 0.7, 2.5] {
            let r = crate::trp::math::erf(x as f64);
            assert!((erf_f32(x) as f64 - r).abs() < 1e-6, "x={x}");
        }
    }

    #[test]
    fn monotone_in_violation() {
        // Increasing sigma increases violation, decreases nothing else.
        let outs: Vec<f32> = [0.1, 0.5, 1.0, 2.0]
            .iter()
            .map(|&s| NativeScorer.score(&batch_one(8.0, s, 10.0)).unwrap().violation[0])
            .collect();
        assert!(outs.windows(2).all(|w| w[0] <= w[1] + 1e-6), "{outs:?}");
    }
}
