//! The JASDA scheduling loop — paper Algorithm 1, one full interaction
//! cycle per engine iteration:
//!
//! 1. **Window announcement** (§3.1): pick up to **K** idle time–capacity
//!    windows via the configured [`WindowSelector`] policy
//!    (`announce_k`, or one per free slice in `announce_per_slice` mode;
//!    K = 1 is the paper's single-window prototype loop). Windows that
//!    draw no bids are skipped by index and do not count as
//!    announcements.
//! 2. **Job-side variant generation** (§3.2): every active job
//!    autonomously generates eligible, safe-by-construction variants
//!    for each announced window (or stays silent).
//! 3. **Bid submission** (§3.3): variants with declared utilities pool
//!    into the iteration's union bid set V, each tagged with the window
//!    it targets.
//! 4. **Scheduler clearing** (§3.4/§4.4): one batched scoring pass
//!    evaluates the normalized composite score (Eq. (4)) with calibration
//!    (Eq. (5)) and age fairness (§4.3) across all windows (per-row slice
//!    capacities); WIS then selects the optimal non-overlapping subset
//!    *per window*, and a cross-window reconciliation pass drops any
//!    selection that would hand one job two temporally overlapping
//!    reservations on different slices (atomicity, §4.1).
//! 5. **Commit and advance** (§3.5): selected variants become engine
//!    commitments; ex-post verification feeds back on completion.
//!
//! # Pipeline structure (§Perf iteration 2)
//!
//! One iteration is organized as an amortized-incremental pipeline over
//! scheduler-owned scratch buffers (`IterScratch`) — the steady state
//! allocates nothing on the candidate/pool/scoring paths:
//!
//! * **Announce** reads candidate windows straight off the cluster's
//!   per-slice gap indexes into a reused buffer; the repack trigger is a
//!   gap-index residue count instead of a per-slice `idle_gaps`
//!   re-enumeration.
//! * **Generate** consults a *bidder index* (jobs pre-screened by the
//!   memory-floor capacity-class precondition) and a per-iteration
//!   *plan cache* keyed by window shape `(c_k, speed, Δt)`, so identical
//!   window shapes never re-run chunk sizing or FMP discretization; the
//!   remaining plan misses fan out across worker threads.
//! * **Score** runs the one batched pass into a reused output, with the
//!   row space chunked across threads (rows are independent).
//! * **Clear** hands the union pool to the shared
//!   [`ClearingEngine`](crate::jasda::clearing::ClearingEngine) — the
//!   same batched-scoring + speculative-WIS + sequential-reconciliation
//!   core the [`coordinator`](crate::coordinator) leader drives, so both
//!   runtimes make identical decisions by construction.
//!
//! Every fan-out stage runs on a persistent [`WorkerPool`] spawned once
//! per scheduler (no per-iteration thread spawns) and is bit-identical
//! to its serial form (unit- and property-tested), so `jasda.parallel`
//! is purely a latency knob.

use crate::config::JasdaConfig;
use crate::jasda::calibration::Calibration;
use crate::jasda::clearing::{Accepted, ClearingEngine, RowCtx};
use crate::jasda::pool::{workers_for, WorkerPool};
use crate::jasda::scoring::{NativeScorer, ScorerBackend};
use crate::jasda::window::{announce_target, round_policy, WindowSelector};
use crate::job::variants::{plan_chunks, stamp_variants, PlannedChunk, Variant};
use crate::job::JobSet;
use crate::mig::{Cluster, Window};
use crate::sim::{Commitment, Rng, Scheduler, SubjobRecord};
use crate::types::{JobId, Time};
use std::collections::HashMap;

/// Internal counters exposed through [`Scheduler::stats`].
#[derive(Debug, Default, Clone)]
struct JasdaStats {
    iterations: u64,
    /// Windows that gathered at least one bid (silent windows excluded).
    windows_announced: u64,
    /// Windows announced that drew no bids and were skipped.
    windows_silent: u64,
    iterations_with_bids: u64,
    variants_submitted: u64,
    variants_eligible: u64,
    variants_selected: u64,
    /// Eligible pool variants filtered out before a window's WIS because
    /// their job already won an overlapping interval — or an overlapping
    /// work range — in an earlier window of the same iteration (counts
    /// variants, not jobs).
    cross_window_conflicts: u64,
    scoring_ns: u64,
    clearing_ns: u64,
    max_pool: usize,
    repack_iterations: u64,
    /// (job, window) generation calls answered from the per-iteration
    /// plan cache instead of a fresh plan.
    plan_cache_hits: u64,
    /// (job, window) generation calls skipped by the bidder index's
    /// memory-floor precondition.
    bidders_skipped: u64,
    /// Windows whose speculative WIS solution was discarded because an
    /// earlier window's acceptances touched their eligible pool.
    wis_replays: u64,
    /// Iterations whose round consulted the exact global solver
    /// (`jasda.clearing = "exact"` with K > 1 windows).
    exact_rounds: u64,
    /// Branch-and-bound nodes evaluated by the exact solver.
    exact_nodes: u64,
    /// Exact solves cut short by `jasda.clearing_budget_ms` (falling
    /// back to the best feasible solution found, at worst greedy).
    exact_budget_exhausted: u64,
    /// Rounds where the exact solution strictly beat the greedy
    /// incumbent's welfare.
    exact_improved: u64,
    /// Wall time spent in the exact solver.
    exact_ns: u64,
    /// Sum of accepted variants' composite scores over the run — the
    /// cleared-welfare series the clearing-policy benches compare
    /// (greedy vs exact uplift per K).
    award_score_sum: f64,
}

/// One bidder's entry in the per-iteration bidder index.
#[derive(Debug, Clone, Copy)]
struct BidderEntry {
    job: JobId,
    /// Lower bound on the job's mean memory from its work cursor on
    /// ([`crate::trp::Trp::min_mem_gb_from`]). A slice whose capacity is
    /// below this floor cannot receive an eligible variant (every FMP
    /// bin mean exceeds the capacity, so the violation probability is at
    /// least 0.5), letting bid collection skip the job for that window
    /// outright whenever `theta < 0.5`.
    mem_floor: f64,
}

/// Plan-cache key: (job, capacity bits, speed bits, Δt) — the window
/// shape of [`plan_chunks`]. Bit-exact float keys: shapes repeat only
/// when the slice profile values are identical.
type PlanKey = (JobId, u64, u64, u64);

/// Scheduler-owned scratch buffers, reused across iterations so the hot
/// loop performs no steady-state allocation on the candidate, pool,
/// scoring, or reconciliation paths.
#[derive(Default)]
struct IterScratch {
    /// Candidate windows (refilled from the cluster gap indexes).
    candidates: Vec<Window>,
    /// Windows announced this iteration.
    announced: Vec<Window>,
    /// Union bid pool.
    pool: Vec<Variant>,
    /// Contiguous `[start, end)` row range of each announced window's
    /// bids within `pool`.
    window_rows: Vec<(usize, usize)>,
    /// Bidder index, rebuilt each iteration (capacity retained).
    bidders: Vec<BidderEntry>,
    /// Per-iteration plan cache keyed by window shape.
    plans: HashMap<PlanKey, Vec<PlannedChunk>>,
    /// Plan-cache misses of the current window: (bidder slot, key).
    to_plan: Vec<(usize, PlanKey)>,
    /// Freshly computed plans aligned with `to_plan`.
    planned: Vec<Vec<PlannedChunk>>,
}

/// Bidders per worker below which plan fan-out is not worth a spawn.
const MIN_PLANS_PER_THREAD: usize = 8;

/// The JASDA scheduler.
pub struct JasdaScheduler {
    cfg: JasdaConfig,
    selector: WindowSelector,
    scorer: Box<dyn ScorerBackend>,
    calibration: Option<Calibration>,
    /// Persistent worker pool for every fan-out stage (plan misses,
    /// scoring rows, speculative WIS), spawned once from the resolved
    /// `cfg.parallel` budget (0 = autodetect).
    pool: WorkerPool,
    /// The shared K-window scoring/WIS/reconciliation core.
    engine: ClearingEngine,
    scratch: IterScratch,
    stats: JasdaStats,
}

impl JasdaScheduler {
    /// Build with the default native scoring backend.
    pub fn new(cfg: JasdaConfig) -> Self {
        Self::with_scorer(cfg, Box::new(NativeScorer))
    }

    /// Build with an explicit scoring backend (e.g. the PJRT artifact).
    pub fn with_scorer(cfg: JasdaConfig, scorer: Box<dyn ScorerBackend>) -> Self {
        cfg.validate().expect("invalid JASDA config");
        let pool = WorkerPool::from_config(cfg.parallel);
        JasdaScheduler {
            cfg,
            selector: WindowSelector::new(),
            scorer,
            calibration: None,
            pool,
            engine: ClearingEngine::new(),
            scratch: IterScratch::default(),
            stats: JasdaStats::default(),
        }
    }

    /// Access the policy config.
    pub fn config(&self) -> &JasdaConfig {
        &self.cfg
    }

    /// Windows announced (and cleared) by the most recent
    /// [`Scheduler::iterate`] call, in announcement order — empty when
    /// the last iteration announced nothing. Exposed for the
    /// decision-parity oracle in [`crate::coordinator::run_reference`].
    pub fn last_announced(&self) -> &[Window] {
        &self.scratch.announced
    }

    /// Current mean reliability across verified jobs (diagnostics).
    pub fn mean_rho(&self) -> f64 {
        self.calibration.as_ref().map_or(1.0, |c| c.mean_rho())
    }

    /// Per-job reliability ρ_J (1.0 until the job has verified history).
    pub fn rho(&self, job: JobId) -> f64 {
        self.calibration.as_ref().map_or(1.0, |c| c.trust(job).rho)
    }

    fn ensure_calibration(&mut self, n_jobs: usize) {
        if self.calibration.is_none() {
            self.calibration = Some(Calibration::new(
                n_jobs,
                self.cfg.kappa,
                self.cfg.gamma,
                self.cfg.alpha.as_array(),
            ));
        }
    }

    /// Steps 2–3 for one announced window: append every bidder's
    /// variants to the scratch pool (in bidder order — bit-identical to
    /// per-job `generate_variants`), resolving plans through the bidder
    /// index and the per-iteration plan cache, and fanning plan misses
    /// out across worker threads. Returns how many bids were added.
    fn collect_bids_for_window(&mut self, window: Window, jobs: &mut JobSet) -> usize {
        let cap_bits = window.capacity_gb.to_bits();
        let speed_bits = window.speed.to_bits();
        let delta_t = window.delta_t();
        // The memory-floor skip is exact only while an over-capacity
        // mean implies ineligibility, i.e. for theta below the 0.5 a
        // single over-capacity bin already guarantees.
        let mem_skip = self.cfg.theta < 0.5;

        // Phase 1: resolve plans — collect cache misses.
        self.scratch.to_plan.clear();
        let mut considered = 0u64;
        for (slot, b) in self.scratch.bidders.iter().enumerate() {
            if mem_skip && b.mem_floor > window.capacity_gb {
                continue;
            }
            considered += 1;
            let key = (b.job, cap_bits, speed_bits, delta_t);
            if !self.scratch.plans.contains_key(&key) {
                self.scratch.to_plan.push((slot, key));
            }
        }
        self.stats.plan_cache_hits += considered - self.scratch.to_plan.len() as u64;
        let misses = self.scratch.to_plan.len();
        if misses > 0 {
            self.scratch.planned.clear();
            self.scratch.planned.resize_with(misses, Vec::new);
            let workers = workers_for(self.pool.budget(), misses, MIN_PLANS_PER_THREAD);
            if workers <= 1 {
                for k in 0..misses {
                    let slot = self.scratch.to_plan[k].0;
                    let job = jobs.get(self.scratch.bidders[slot].job);
                    self.scratch.planned[k] = plan_chunks(
                        job,
                        &self.cfg,
                        window.capacity_gb,
                        window.speed,
                        delta_t,
                    );
                }
            } else {
                let cfg = &self.cfg;
                let bidders = &self.scratch.bidders;
                let to_plan = &self.scratch.to_plan;
                let jobs_ref = &*jobs;
                let chunk = (misses + workers - 1) / workers;
                self.pool.scope(|scope| {
                    let mut rest = self.scratch.planned.as_mut_slice();
                    let mut start = 0usize;
                    while start < misses {
                        let len = chunk.min(misses - start);
                        let (out_chunk, r) = rest.split_at_mut(len);
                        let keys = &to_plan[start..start + len];
                        scope.spawn(move || {
                            for (out, &(slot, _)) in out_chunk.iter_mut().zip(keys) {
                                let job = jobs_ref.get(bidders[slot].job);
                                *out = plan_chunks(
                                    job,
                                    cfg,
                                    window.capacity_gb,
                                    window.speed,
                                    delta_t,
                                );
                            }
                        });
                        rest = r;
                        start += len;
                    }
                });
            }
            for k in 0..misses {
                let key = self.scratch.to_plan[k].1;
                let plan = std::mem::take(&mut self.scratch.planned[k]);
                self.scratch.plans.insert(key, plan);
            }
        }

        // Phase 2: stamp plans into the pool in bidder order.
        let row0 = self.scratch.pool.len();
        for bi in 0..self.scratch.bidders.len() {
            let b = self.scratch.bidders[bi];
            if mem_skip && b.mem_floor > window.capacity_gb {
                self.stats.bidders_skipped += 1;
                continue;
            }
            let key = (b.job, cap_bits, speed_bits, delta_t);
            let plan = &self.scratch.plans[&key];
            if plan.is_empty() {
                continue;
            }
            stamp_variants(jobs.get(b.job), &window, &self.cfg, plan, &mut self.scratch.pool);
            jobs.get_mut(b.job).bids_submitted += 1;
        }
        self.scratch.pool.len() - row0
    }
}

impl Scheduler for JasdaScheduler {
    fn name(&self) -> &str {
        "jasda"
    }

    fn iterate(
        &mut self,
        now: Time,
        cluster: &Cluster,
        jobs: &mut JobSet,
        _rng: &mut Rng,
    ) -> Vec<Commitment> {
        self.stats.iterations += 1;
        self.ensure_calibration(jobs.len());

        let from = now + self.cfg.announce_lead;
        cluster.collect_windows(
            from,
            self.cfg.announce_horizon,
            self.cfg.tau_min,
            &mut self.scratch.candidates,
        );
        // Rolling repack (§3.5): the shared helper redirects to the
        // fragmentation-aware policy when too many unusable residues
        // have accumulated (see [`round_policy`]).
        let (policy, repack_redirected) = round_policy(&self.cfg, cluster, now);
        if repack_redirected {
            self.stats.repack_iterations += 1;
        }

        // Bidder index: who can bid this round, with the memory-floor
        // capacity class used to skip whole (job, window) pairs.
        self.scratch.bidders.clear();
        for j in jobs.bidders() {
            let mem_floor = j.trp.min_mem_gb_from(j.work_cursor());
            self.scratch.bidders.push(BidderEntry { job: j.id, mem_floor });
        }
        self.scratch.plans.clear();

        // Step 1–3: announce up to K windows, pooling each window's bids
        // as it is announced. The selector returns the pick's index, so
        // removal is a direct O(1) swap_remove (the policies' total
        // tie-broken orderings make selection order-independent). A
        // window that draws no bids at all (the "sparsity" failure mode
        // of §5.1(a)) is skipped and the next candidate is tried, so a
        // policy like earliest-start cannot livelock on a slice no
        // waiting job fits. Cost stays bounded by the candidate count.
        let k_target = announce_target(&self.cfg, &self.scratch.candidates);
        self.scratch.announced.clear();
        self.scratch.pool.clear();
        self.scratch.window_rows.clear();
        while self.scratch.announced.len() < k_target {
            let idx = match self.selector.select(
                policy,
                &self.scratch.candidates,
                cluster,
                now,
                self.cfg.announce_horizon,
            ) {
                Some(i) => i,
                None => break,
            };
            let window = self.scratch.candidates.swap_remove(idx);

            let row0 = self.scratch.pool.len();
            let added = self.collect_bids_for_window(window, jobs);
            if added == 0 {
                // Silent window: skip it; it is not a real announcement.
                self.stats.windows_silent += 1;
                continue;
            }
            self.stats.windows_announced += 1;
            self.scratch.window_rows.push((row0, self.scratch.pool.len()));
            if self.cfg.announce_per_slice {
                // One window per slice: further candidates on this slice
                // are out of this round.
                let slice = window.slice;
                self.scratch.candidates.retain(|c| c.slice != slice);
            }
            self.scratch.announced.push(window);
        }
        if self.scratch.announced.is_empty() {
            return vec![];
        }
        for (i, v) in self.scratch.pool.iter_mut().enumerate() {
            v.id = i as u32;
        }
        self.stats.iterations_with_bids += 1;
        self.stats.variants_submitted += self.scratch.pool.len() as u64;
        self.stats.max_pool = self.stats.max_pool.max(self.scratch.pool.len());

        // Step 4: one batched composite-scoring pass + per-window WIS +
        // cross-window reconciliation, delegated to the shared
        // [`ClearingEngine`] on the persistent worker pool. The closure
        // resolves each row's age/trust/history from scheduler-owned
        // state; acceptances arrive in commitment order.
        let cfg = &self.cfg;
        let calibration = self.calibration.as_ref();
        let jobs_ro: &JobSet = jobs;
        let mut commitments: Vec<Commitment> = Vec::new();
        let mut row_ctx = |v: &Variant| {
            let job = jobs_ro.get(v.job);
            let age = if cfg.age_priority { job.age_factor(now, cfg.age_scale) } else { 0.0 };
            let (trust, hist) = if cfg.calibration {
                let cal = calibration.expect("calibration initialized");
                (cal.trust_weight(v.job), cal.hist_avg(v.job))
            } else {
                (1.0, 0.0)
            };
            RowCtx { age, trust, hist }
        };
        let mut score_sum = 0.0f64;
        let mut on_accept = |acc: Accepted<'_>| {
            score_sum += acc.score;
            commitments.push(Commitment {
                job: acc.variant.job,
                slice: acc.variant.slice,
                interval: acc.variant.interval,
                work: acc.variant.work,
                declared_phi: acc.variant.declared.phi,
                score: acc.score,
                window_len: acc.window.delta_t(),
            });
        };
        let cstats = self.engine.clear(
            &self.cfg,
            &self.scratch.announced,
            &self.scratch.window_rows,
            &self.scratch.pool,
            &mut row_ctx,
            self.scorer.as_mut(),
            &self.pool,
            &mut on_accept,
        );
        self.stats.variants_eligible += cstats.variants_eligible;
        self.stats.variants_selected += cstats.variants_selected;
        self.stats.cross_window_conflicts += cstats.cross_window_conflicts;
        self.stats.wis_replays += cstats.wis_replays;
        self.stats.exact_rounds += cstats.exact_rounds;
        self.stats.exact_nodes += cstats.exact_nodes;
        self.stats.exact_budget_exhausted += cstats.exact_budget_exhausted;
        self.stats.exact_improved += cstats.exact_improved;
        self.stats.exact_ns += cstats.exact_ns;
        self.stats.award_score_sum += score_sum;
        self.stats.scoring_ns += cstats.scoring_ns;
        self.stats.clearing_ns += cstats.clearing_ns;

        // Step 5: commit.
        commitments
    }

    fn on_subjob_complete(&mut self, rec: &SubjobRecord) {
        if self.cfg.calibration {
            if let Some(cal) = self.calibration.as_mut() {
                cal.verify_record(rec, &self.cfg.alpha.as_array());
            }
        }
    }

    fn stats(&self) -> crate::util::Json {
        crate::util::Json::obj(vec![
            ("scorer", self.scorer.name().into()),
            ("iterations", self.stats.iterations.into()),
            ("windows_announced", self.stats.windows_announced.into()),
            ("windows_silent", self.stats.windows_silent.into()),
            ("iterations_with_bids", self.stats.iterations_with_bids.into()),
            ("variants_submitted", self.stats.variants_submitted.into()),
            ("variants_eligible", self.stats.variants_eligible.into()),
            ("variants_selected", self.stats.variants_selected.into()),
            ("cross_window_conflicts", self.stats.cross_window_conflicts.into()),
            ("scoring_ns", self.stats.scoring_ns.into()),
            ("clearing_ns", self.stats.clearing_ns.into()),
            ("max_pool", self.stats.max_pool.into()),
            ("repack_iterations", self.stats.repack_iterations.into()),
            ("plan_cache_hits", self.stats.plan_cache_hits.into()),
            ("bidders_skipped", self.stats.bidders_skipped.into()),
            ("wis_replays", self.stats.wis_replays.into()),
            ("exact_rounds", self.stats.exact_rounds.into()),
            ("exact_nodes", self.stats.exact_nodes.into()),
            ("exact_budget_exhausted", self.stats.exact_budget_exhausted.into()),
            ("exact_improved", self.stats.exact_improved.into()),
            ("exact_ns", self.stats.exact_ns.into()),
            ("award_score_sum", self.stats.award_score_sum.into()),
            ("threads", (self.pool.budget() as u64).into()),
            ("mean_rho", self.mean_rho().into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::job::Job;
    use crate::sim::SimEngine;
    use crate::trp::{Phase, Trp};

    fn jobs(n: u32, mem: f64, work: f64) -> Vec<Job> {
        (0..n)
            .map(|i| {
                let trp = Trp {
                    phases: vec![
                        Phase::new(work * 0.2, mem * 0.7, 0.2, 0.5),
                        Phase::new(work * 0.8, mem, 0.3, 0.1),
                    ],
                    duration_cv: 0.08,
                };
                Job::new(i, "test", (i as u64) * 200, trp, None, 1.0, work / 4.0, 0.0)
            })
            .collect()
    }

    fn cfg() -> SimConfig {
        let mut c = SimConfig::default();
        c.cluster.layout = "balanced".into();
        c.engine.iteration_period = 25;
        c.jasda.fmp_bins = 16;
        c
    }

    #[test]
    fn jasda_completes_workload() {
        let c = cfg();
        let sched = JasdaScheduler::new(c.jasda.clone());
        let out = SimEngine::new(c, Box::new(sched)).run(jobs(6, 6.0, 2000.0));
        assert_eq!(out.metrics.unfinished, 0, "summary: {}", out.metrics.summary());
        assert!(out.metrics.utilization > 0.0);
        let stats = &out.scheduler_stats;
        let g = |k: &str| stats.get(k).unwrap().as_u64().unwrap();
        assert!(g("variants_submitted") > 0);
        assert!(g("variants_selected") >= 6);
        assert!(g("variants_eligible") <= g("variants_submitted"));
    }

    #[test]
    fn jasda_deterministic() {
        let run = || {
            let c = cfg();
            let sched = JasdaScheduler::new(c.jasda.clone());
            SimEngine::new(c, Box::new(sched)).run(jobs(5, 6.0, 1500.0)).metrics
        };
        let (a, b) = (run(), run());
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.total_commits, b.total_commits);
    }

    #[test]
    fn multi_window_deterministic() {
        for per_slice in [false, true] {
            let run = || {
                let mut c = cfg();
                c.jasda.announce_k = 3;
                c.jasda.announce_per_slice = per_slice;
                let sched = JasdaScheduler::new(c.jasda.clone());
                SimEngine::new(c, Box::new(sched)).run(jobs(6, 6.0, 1800.0)).metrics
            };
            let (a, b) = (run(), run());
            assert_eq!(a.makespan, b.makespan, "per_slice={per_slice}");
            assert_eq!(a.total_commits, b.total_commits, "per_slice={per_slice}");
        }
    }

    #[test]
    fn multi_window_completes_and_reports() {
        let mut c = cfg();
        c.jasda.announce_per_slice = true;
        let sched = JasdaScheduler::new(c.jasda.clone());
        let out = SimEngine::new(c, Box::new(sched)).run(jobs(8, 6.0, 2000.0));
        assert_eq!(out.metrics.unfinished, 0, "summary: {}", out.metrics.summary());
        let g = |k: &str| out.scheduler_stats.get(k).unwrap().as_u64().unwrap();
        // With per-slice announcement on a 3-slice layout, contended
        // iterations must announce more windows than iterations-with-bids
        // would allow under K=1.
        assert!(g("windows_announced") > g("iterations_with_bids"));
    }

    #[test]
    fn memory_hungry_jobs_avoid_small_slices() {
        // 18 GiB jobs can only run on the 3g.20gb slice of `balanced`.
        let c = cfg();
        let sched = JasdaScheduler::new(c.jasda.clone());
        let out = SimEngine::new(c, Box::new(sched)).run(jobs(3, 17.0, 1200.0));
        assert_eq!(out.metrics.unfinished, 0);
        // All reservations must be on slice 0 (the 20 GiB one).
        for s in out.cluster.slices() {
            if s.capacity_gb() < 17.0 {
                assert!(
                    s.timeline.is_empty(),
                    "unsafe slice {} ({} GiB) received work",
                    s.id,
                    s.capacity_gb()
                );
            }
        }
    }

    #[test]
    fn memory_hungry_jobs_avoid_small_slices_multi_window() {
        // Same safety property with every slice announced per iteration.
        let mut c = cfg();
        c.jasda.announce_per_slice = true;
        let sched = JasdaScheduler::new(c.jasda.clone());
        let out = SimEngine::new(c, Box::new(sched)).run(jobs(3, 17.0, 1200.0));
        assert_eq!(out.metrics.unfinished, 0);
        for s in out.cluster.slices() {
            if s.capacity_gb() < 17.0 {
                assert!(
                    s.timeline.is_empty(),
                    "unsafe slice {} ({} GiB) received work under per-slice K",
                    s.id,
                    s.capacity_gb()
                );
            }
        }
    }

    #[test]
    fn age_priority_rescues_starved_class() {
        // Two heavy jobs + one light job contending on one small cluster;
        // with age priority the light job cannot be starved forever.
        let mut c = cfg();
        c.jasda.age_priority = true;
        c.jasda.age_scale = 2_000;
        let sched = JasdaScheduler::new(c.jasda.clone());
        let out = SimEngine::new(c, Box::new(sched)).run(jobs(8, 8.0, 3000.0));
        assert_eq!(out.metrics.unfinished, 0);
        assert!(out.metrics.max_starvation() < 1_000_000);
    }

    #[test]
    fn calibration_runs_and_reports_rho() {
        let c = cfg();
        let mut js = jobs(4, 6.0, 1500.0);
        js[1].misreport_bias = 0.8; // one liar
        let sched = JasdaScheduler::new(c.jasda.clone());
        let out = SimEngine::new(c, Box::new(sched)).run(js);
        assert_eq!(out.metrics.unfinished, 0);
        let rho = out.scheduler_stats.get("mean_rho").unwrap().as_f64().unwrap();
        assert!(rho > 0.0 && rho <= 1.0);
        assert!(rho < 1.0, "a misreporting job must dent mean reliability, got {rho}");
    }

    #[test]
    fn parallel_pipeline_matches_serial_end_to_end() {
        // The fan-out stages must not change a single decision: full-run
        // metrics are compared between a forced-serial scheduler and a
        // multi-threaded one, across announcement modes.
        for (k, per_slice) in [(1usize, false), (3, false), (1, true)] {
            let run = |threads: usize| {
                let mut c = cfg();
                c.jasda.announce_k = k;
                c.jasda.announce_per_slice = per_slice;
                c.jasda.parallel = threads;
                let sched = JasdaScheduler::new(c.jasda.clone());
                SimEngine::new(c, Box::new(sched)).run(jobs(8, 6.0, 2000.0)).metrics
            };
            let serial = run(1);
            let parallel = run(4);
            assert_eq!(serial.makespan, parallel.makespan, "K={k} per_slice={per_slice}");
            assert_eq!(
                serial.total_commits, parallel.total_commits,
                "K={k} per_slice={per_slice}"
            );
            assert_eq!(serial.mean_jct(), parallel.mean_jct(), "K={k} per_slice={per_slice}");
            assert_eq!(serial.unfinished, 0);
        }
    }

    #[test]
    fn bidder_index_skips_oversized_jobs_and_caches_plans() {
        // 17 GiB jobs on a balanced layout: the two 10 GiB slices must be
        // skipped by the memory-floor precondition, and per-slice
        // announcement over identical window shapes must hit the cache.
        let mut c = cfg();
        c.jasda.announce_per_slice = true;
        let sched = JasdaScheduler::new(c.jasda.clone());
        let out = SimEngine::new(c, Box::new(sched)).run(jobs(3, 17.0, 1200.0));
        assert_eq!(out.metrics.unfinished, 0);
        let g = |k: &str| out.scheduler_stats.get(k).unwrap().as_u64().unwrap();
        assert!(g("bidders_skipped") > 0, "memory floor must skip 10 GiB slices");
        let stats = &out.scheduler_stats;
        assert!(stats.get("plan_cache_hits").is_some());
        assert!(stats.get("wis_replays").is_some());
    }

    #[test]
    fn plan_cache_hits_on_identical_slices() {
        // seven_small: 7 identical 1g.5gb slices. With per-slice
        // announcement the 7 idle windows share one shape, so each
        // bidder plans once and stamps 7 times.
        let mut c = cfg();
        c.cluster.layout = "7x1g".into();
        c.jasda.announce_per_slice = true;
        let sched = JasdaScheduler::new(c.jasda.clone());
        let out = SimEngine::new(c, Box::new(sched)).run(jobs(6, 3.0, 1500.0));
        assert_eq!(out.metrics.unfinished, 0);
        let g = |k: &str| out.scheduler_stats.get(k).unwrap().as_u64().unwrap();
        assert!(g("plan_cache_hits") > 0, "identical slices must share plans");
    }

    #[test]
    fn no_bids_no_commitments() {
        let c = cfg();
        let mut sched = JasdaScheduler::new(c.jasda.clone());
        let layout = crate::mig::PartitionLayout::balanced();
        let cluster = Cluster::new(1, &layout);
        let mut empty = JobSet::new(vec![]);
        let mut rng = Rng::new(1);
        let commits = sched.iterate(0, &cluster, &mut empty, &mut rng);
        assert!(commits.is_empty());
    }
}
