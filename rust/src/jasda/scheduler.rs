//! The JASDA scheduling loop — paper Algorithm 1, one full interaction
//! cycle per engine iteration:
//!
//! 1. **Window announcement** (§3.1): pick up to **K** idle time–capacity
//!    windows via the configured [`WindowSelector`] policy
//!    (`announce_k`, or one per free slice in `announce_per_slice` mode;
//!    K = 1 is the paper's single-window prototype loop). Windows that
//!    draw no bids are skipped by index and do not count as
//!    announcements.
//! 2. **Job-side variant generation** (§3.2): every active job
//!    autonomously generates eligible, safe-by-construction variants
//!    for each announced window (or stays silent).
//! 3. **Bid submission** (§3.3): variants with declared utilities pool
//!    into the iteration's union bid set V, each tagged with the window
//!    it targets.
//! 4. **Scheduler clearing** (§3.4/§4.4): one batched scoring pass
//!    evaluates the normalized composite score (Eq. (4)) with calibration
//!    (Eq. (5)) and age fairness (§4.3) across all windows (per-row slice
//!    capacities); WIS then selects the optimal non-overlapping subset
//!    *per window*, and a cross-window reconciliation pass drops any
//!    selection that would hand one job two temporally overlapping
//!    reservations on different slices (atomicity, §4.1).
//! 5. **Commit and advance** (§3.5): selected variants become engine
//!    commitments; ex-post verification feeds back on completion.

use crate::config::JasdaConfig;
use crate::jasda::calibration::Calibration;
use crate::jasda::clearing::{select_best_compatible, WisItem};
use crate::jasda::scoring::{NativeScorer, ScoreBatch, ScorerBackend};
use crate::jasda::window::WindowSelector;
use crate::job::variants::{generate_variants, Variant};
use crate::job::JobSet;
use crate::mig::{Cluster, Window};
use crate::sim::{Commitment, Rng, Scheduler, SubjobRecord};
use crate::types::{Interval, JobId, SliceId, Time};

/// Internal counters exposed through [`Scheduler::stats`].
#[derive(Debug, Default, Clone)]
struct JasdaStats {
    iterations: u64,
    /// Windows that gathered at least one bid (silent windows excluded).
    windows_announced: u64,
    /// Windows announced that drew no bids and were skipped.
    windows_silent: u64,
    iterations_with_bids: u64,
    variants_submitted: u64,
    variants_eligible: u64,
    variants_selected: u64,
    /// Eligible pool variants filtered out before a window's WIS because
    /// their job already won an overlapping interval — or an overlapping
    /// work range — in an earlier window of the same iteration (counts
    /// variants, not jobs).
    cross_window_conflicts: u64,
    scoring_ns: u64,
    clearing_ns: u64,
    max_pool: usize,
    repack_iterations: u64,
}

/// The JASDA scheduler.
pub struct JasdaScheduler {
    cfg: JasdaConfig,
    selector: WindowSelector,
    scorer: Box<dyn ScorerBackend>,
    calibration: Option<Calibration>,
    stats: JasdaStats,
}

impl JasdaScheduler {
    /// Build with the default native scoring backend.
    pub fn new(cfg: JasdaConfig) -> Self {
        Self::with_scorer(cfg, Box::new(NativeScorer))
    }

    /// Build with an explicit scoring backend (e.g. the PJRT artifact).
    pub fn with_scorer(cfg: JasdaConfig, scorer: Box<dyn ScorerBackend>) -> Self {
        cfg.validate().expect("invalid JASDA config");
        JasdaScheduler {
            cfg,
            selector: WindowSelector::new(),
            scorer,
            calibration: None,
            stats: JasdaStats::default(),
        }
    }

    /// Access the policy config.
    pub fn config(&self) -> &JasdaConfig {
        &self.cfg
    }

    /// Current mean reliability across verified jobs (diagnostics).
    pub fn mean_rho(&self) -> f64 {
        self.calibration.as_ref().map_or(1.0, |c| c.mean_rho())
    }

    /// Per-job reliability ρ_J (1.0 until the job has verified history).
    pub fn rho(&self, job: JobId) -> f64 {
        self.calibration.as_ref().map_or(1.0, |c| c.trust(job).rho)
    }

    fn ensure_calibration(&mut self, n_jobs: usize) {
        if self.calibration.is_none() {
            self.calibration = Some(Calibration::new(
                n_jobs,
                self.cfg.kappa,
                self.cfg.gamma,
                self.cfg.alpha.as_array(),
            ));
        }
    }

    /// Steps 2–3: collect the iteration's bid pool for `window`.
    /// Pool-local ids are assigned later, over the union pool.
    fn collect_bids(&mut self, window: &Window, jobs: &mut JobSet) -> Vec<Variant> {
        let bidder_ids: Vec<JobId> = jobs.bidders().map(|j| j.id).collect();
        let mut pool = Vec::new();
        for id in bidder_ids {
            let vs = generate_variants(jobs.get(id), window, &self.cfg);
            if !vs.is_empty() {
                jobs.get_mut(id).bids_submitted += 1;
                pool.extend(vs);
            }
        }
        pool
    }

    /// How many windows this iteration announces: `announce_k`, or the
    /// number of distinct slices with a candidate in per-slice mode.
    fn announce_target(&self, candidates: &[Window]) -> usize {
        if self.cfg.announce_per_slice {
            let mut slices: Vec<SliceId> = candidates.iter().map(|w| w.slice).collect();
            slices.sort_unstable();
            slices.dedup();
            slices.len().max(1)
        } else {
            self.cfg.announce_k
        }
    }

    /// Step 4a: score the union pool with the configured backend.
    /// `window_rows[w]` is the contiguous `[start, end)` row range of
    /// window `w`'s bids in `pool` (bids are pooled window by window);
    /// with a single window the batch carries the uniform scalar capacity
    /// (bit-identical to the original single-window path), otherwise
    /// per-row capacities.
    fn score_pool(
        &mut self,
        windows: &[Window],
        pool: &[Variant],
        window_rows: &[(usize, usize)],
        jobs: &JobSet,
        now: Time,
    ) -> ScoreBatch {
        debug_assert_eq!(windows.len(), window_rows.len());
        let mut batch = ScoreBatch::with_bins(self.cfg.fmp_bins);
        batch.capacity = windows[0].capacity_gb as f32;
        batch.theta = self.cfg.theta as f32;
        batch.lambda = self.cfg.lambda as f32;
        let alpha = self.cfg.alpha.as_array();
        let beta = self.cfg.beta.as_array();
        batch.alpha = [alpha[0] as f32, alpha[1] as f32, alpha[2] as f32, alpha[3] as f32];
        batch.beta = [beta[0] as f32, beta[1] as f32, beta[2] as f32, beta[3] as f32];

        for v in pool {
            let job = jobs.get(v.job);
            let age = if self.cfg.age_priority {
                job.age_factor(now, self.cfg.age_scale)
            } else {
                0.0
            };
            let (trust, hist) = if self.cfg.calibration {
                let cal = self.calibration.as_ref().expect("calibration initialized");
                (cal.trust_weight(v.job), cal.hist_avg(v.job))
            } else {
                (1.0, 0.0)
            };
            let phi = [
                v.declared.phi[0],
                v.declared.phi[1],
                v.declared.phi[2],
                v.declared.phi[3],
            ];
            batch.push(
                &v.fmp.mu,
                &v.fmp.sigma,
                phi,
                [v.sys.util, v.sys.frag, age],
                trust,
                hist,
            );
        }
        if windows.len() > 1 {
            for (w, &(start, end)) in windows.iter().zip(window_rows) {
                batch
                    .row_capacity
                    .extend(std::iter::repeat(w.capacity_gb as f32).take(end - start));
            }
            debug_assert_eq!(batch.row_capacity.len(), pool.len());
        }
        batch
    }
}

impl Scheduler for JasdaScheduler {
    fn name(&self) -> &str {
        "jasda"
    }

    fn iterate(
        &mut self,
        now: Time,
        cluster: &Cluster,
        jobs: &mut JobSet,
        _rng: &mut Rng,
    ) -> Vec<Commitment> {
        self.stats.iterations += 1;
        self.ensure_calibration(jobs.len());

        let from = now + self.cfg.announce_lead;
        let mut candidates =
            cluster.candidate_windows(from, self.cfg.announce_horizon, self.cfg.tau_min);
        // Rolling repack (§3.5): the paper triggers a defragmentation
        // step "when residual gaps become too small for further
        // allocation". We count idle residues shorter than τ_min across
        // the announce horizon (they can never be allocated); when
        // several have accumulated, announcements are redirected to the
        // most fragmented slice so bids consolidate its gaps.
        let policy = if self.cfg.repack {
            let to = now.saturating_add(self.cfg.announce_horizon);
            let unusable: usize = cluster
                .slices()
                .iter()
                .map(|s| {
                    s.timeline
                        .idle_gaps(now, to, 1)
                        .iter()
                        .filter(|g| g.interval.len() < self.cfg.tau_min)
                        .count()
                })
                .sum();
            if unusable >= 3 {
                self.stats.repack_iterations += 1;
                crate::config::WindowPolicy::FragmentationAware
            } else {
                self.cfg.window_policy
            }
        } else {
            self.cfg.window_policy
        };

        // Step 1–3: announce up to K windows, pooling each window's bids
        // as it is announced. A window that draws no bids at all (the
        // "sparsity" failure mode of §5.1(a)) is removed by index — O(1)
        // via swap_remove, the policies' total tie-broken orderings make
        // selection order-independent — and the next candidate is tried,
        // so a policy like earliest-start cannot livelock on a slice no
        // waiting job fits. Cost stays bounded by the candidate count.
        let k_target = self.announce_target(&candidates);
        let mut announced: Vec<Window> = Vec::new();
        let mut pool: Vec<Variant> = Vec::new();
        // Contiguous [start, end) row range of each announced window's
        // bids within `pool`.
        let mut window_rows: Vec<(usize, usize)> = Vec::new();
        while announced.len() < k_target {
            let window = match self.selector.select(
                policy,
                &candidates,
                cluster,
                now,
                self.cfg.announce_horizon,
            ) {
                Some(w) => w,
                None => break,
            };
            let pos = candidates
                .iter()
                .position(|c| c.slice == window.slice && c.interval == window.interval)
                .expect("selected window originates from the candidate list");
            candidates.swap_remove(pos);

            let bids = self.collect_bids(&window, jobs);
            if bids.is_empty() {
                // Silent window: skip it; it is not a real announcement.
                self.stats.windows_silent += 1;
                continue;
            }
            self.stats.windows_announced += 1;
            let row0 = pool.len();
            pool.extend(bids);
            window_rows.push((row0, pool.len()));
            if self.cfg.announce_per_slice {
                // One window per slice: further candidates on this slice
                // are out of this round.
                let slice = window.slice;
                candidates.retain(|c| c.slice != slice);
            }
            announced.push(window);
        }
        if announced.is_empty() {
            return vec![];
        }
        for (i, v) in pool.iter_mut().enumerate() {
            v.id = i as u32;
        }
        self.stats.iterations_with_bids += 1;
        self.stats.variants_submitted += pool.len() as u64;
        self.stats.max_pool = self.stats.max_pool.max(pool.len());

        // Step 4a: one batched composite-scoring pass across all windows
        // (Eq. (4) + calibration + age; per-row capacities when K > 1).
        let t0 = std::time::Instant::now();
        let batch = self.score_pool(&announced, &pool, &window_rows, jobs, now);
        let out = self.scorer.score(&batch).expect("scoring backend failed");
        self.stats.scoring_ns += t0.elapsed().as_nanos() as u64;

        // Step 4b: optimal per-window clearing (WIS) with cross-window
        // reconciliation: within one decision round a job must never
        // hold two temporally overlapping reservations on different
        // slices (§4.1 atomicity), nor win the *same work chunk* twice —
        // every window's chains start at the job's unchanged work
        // cursor, so without the work-range check a job could commit
        // chunk [cursor, cursor+w) on two slices and the second
        // reservation would execute no work while still blocking its
        // slice. Windows clear in announcement order (= policy
        // preference order); conflicting variants are filtered *before*
        // this window's WIS, so the window still optimizes over
        // everything that can actually commit instead of silently
        // losing its winners. With one announced window the filter never
        // fires — K=1 stays bit-identical to the single-window path.
        let t1 = std::time::Instant::now();
        let mut commitments: Vec<Commitment> = Vec::new();
        // Per accepted variant: (job, execution interval, work range
        // [w0, w1) relative to the job's cursor).
        let mut accepted: Vec<(JobId, Interval, f64, f64)> = Vec::new();
        let mut items: Vec<WisItem> = Vec::new();
        let mut item_to_pool: Vec<usize> = Vec::new();
        for (widx, window) in announced.iter().enumerate() {
            items.clear();
            item_to_pool.clear();
            let wlen = window.delta_t().max(1) as f64;
            let (row0, row1) = window_rows[widx];
            for i in row0..row1 {
                let v = &pool[i];
                if !out.eligible[i] || out.score[i] <= 0.0 {
                    continue;
                }
                if !accepted.is_empty()
                    && accepted.iter().any(|&(job, iv, w0, w1)| {
                        job == v.job
                            && (iv.overlaps(&v.interval)
                                || (v.work_offset < w1 - 1e-9
                                    && w0 < v.work_offset + v.work - 1e-9))
                    })
                {
                    self.stats.cross_window_conflicts += 1;
                    continue;
                }
                // Optional duration weighting (EXPERIMENTS.md F6): under
                // the paper's plain sum objective, many short variants
                // dominate few long ones; weighting by window share makes
                // the objective score-weighted busy time.
                let w = if self.cfg.duration_weighted_clearing {
                    v.duration() as f64 / wlen
                } else {
                    1.0
                };
                items.push(WisItem { interval: v.interval, score: out.score[i] as f64 * w });
                item_to_pool.push(i);
            }
            self.stats.variants_eligible += items.len() as u64;
            let sol = select_best_compatible(&items);
            for &k in &sol.selected {
                let i = item_to_pool[k];
                let v = &pool[i];
                accepted.push((v.job, v.interval, v.work_offset, v.work_offset + v.work));
                self.stats.variants_selected += 1;
                commitments.push(Commitment {
                    job: v.job,
                    slice: v.slice,
                    interval: v.interval,
                    work: v.work,
                    declared_phi: v.declared.phi,
                    score: out.score[i] as f64,
                    window_len: window.delta_t(),
                });
            }
        }
        self.stats.clearing_ns += t1.elapsed().as_nanos() as u64;

        // Step 5: commit.
        commitments
    }

    fn on_subjob_complete(&mut self, rec: &SubjobRecord) {
        if self.cfg.calibration {
            if let Some(cal) = self.calibration.as_mut() {
                cal.verify_record(rec, &self.cfg.alpha.as_array());
            }
        }
    }

    fn stats(&self) -> crate::util::Json {
        crate::util::Json::obj(vec![
            ("scorer", self.scorer.name().into()),
            ("iterations", self.stats.iterations.into()),
            ("windows_announced", self.stats.windows_announced.into()),
            ("windows_silent", self.stats.windows_silent.into()),
            ("iterations_with_bids", self.stats.iterations_with_bids.into()),
            ("variants_submitted", self.stats.variants_submitted.into()),
            ("variants_eligible", self.stats.variants_eligible.into()),
            ("variants_selected", self.stats.variants_selected.into()),
            ("cross_window_conflicts", self.stats.cross_window_conflicts.into()),
            ("scoring_ns", self.stats.scoring_ns.into()),
            ("clearing_ns", self.stats.clearing_ns.into()),
            ("max_pool", self.stats.max_pool.into()),
            ("repack_iterations", self.stats.repack_iterations.into()),
            ("mean_rho", self.mean_rho().into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::job::Job;
    use crate::sim::SimEngine;
    use crate::trp::{Phase, Trp};

    fn jobs(n: u32, mem: f64, work: f64) -> Vec<Job> {
        (0..n)
            .map(|i| {
                let trp = Trp {
                    phases: vec![
                        Phase::new(work * 0.2, mem * 0.7, 0.2, 0.5),
                        Phase::new(work * 0.8, mem, 0.3, 0.1),
                    ],
                    duration_cv: 0.08,
                };
                Job::new(i, "test", (i as u64) * 200, trp, None, 1.0, work / 4.0, 0.0)
            })
            .collect()
    }

    fn cfg() -> SimConfig {
        let mut c = SimConfig::default();
        c.cluster.layout = "balanced".into();
        c.engine.iteration_period = 25;
        c.jasda.fmp_bins = 16;
        c
    }

    #[test]
    fn jasda_completes_workload() {
        let c = cfg();
        let sched = JasdaScheduler::new(c.jasda.clone());
        let out = SimEngine::new(c, Box::new(sched)).run(jobs(6, 6.0, 2000.0));
        assert_eq!(out.metrics.unfinished, 0, "summary: {}", out.metrics.summary());
        assert!(out.metrics.utilization > 0.0);
        let stats = &out.scheduler_stats;
        let g = |k: &str| stats.get(k).unwrap().as_u64().unwrap();
        assert!(g("variants_submitted") > 0);
        assert!(g("variants_selected") >= 6);
        assert!(g("variants_eligible") <= g("variants_submitted"));
    }

    #[test]
    fn jasda_deterministic() {
        let run = || {
            let c = cfg();
            let sched = JasdaScheduler::new(c.jasda.clone());
            SimEngine::new(c, Box::new(sched)).run(jobs(5, 6.0, 1500.0)).metrics
        };
        let (a, b) = (run(), run());
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.total_commits, b.total_commits);
    }

    #[test]
    fn multi_window_deterministic() {
        for per_slice in [false, true] {
            let run = || {
                let mut c = cfg();
                c.jasda.announce_k = 3;
                c.jasda.announce_per_slice = per_slice;
                let sched = JasdaScheduler::new(c.jasda.clone());
                SimEngine::new(c, Box::new(sched)).run(jobs(6, 6.0, 1800.0)).metrics
            };
            let (a, b) = (run(), run());
            assert_eq!(a.makespan, b.makespan, "per_slice={per_slice}");
            assert_eq!(a.total_commits, b.total_commits, "per_slice={per_slice}");
        }
    }

    #[test]
    fn multi_window_completes_and_reports() {
        let mut c = cfg();
        c.jasda.announce_per_slice = true;
        let sched = JasdaScheduler::new(c.jasda.clone());
        let out = SimEngine::new(c, Box::new(sched)).run(jobs(8, 6.0, 2000.0));
        assert_eq!(out.metrics.unfinished, 0, "summary: {}", out.metrics.summary());
        let g = |k: &str| out.scheduler_stats.get(k).unwrap().as_u64().unwrap();
        // With per-slice announcement on a 3-slice layout, contended
        // iterations must announce more windows than iterations-with-bids
        // would allow under K=1.
        assert!(g("windows_announced") > g("iterations_with_bids"));
    }

    #[test]
    fn memory_hungry_jobs_avoid_small_slices() {
        // 18 GiB jobs can only run on the 3g.20gb slice of `balanced`.
        let c = cfg();
        let sched = JasdaScheduler::new(c.jasda.clone());
        let out = SimEngine::new(c, Box::new(sched)).run(jobs(3, 17.0, 1200.0));
        assert_eq!(out.metrics.unfinished, 0);
        // All reservations must be on slice 0 (the 20 GiB one).
        for s in out.cluster.slices() {
            if s.capacity_gb() < 17.0 {
                assert!(
                    s.timeline.is_empty(),
                    "unsafe slice {} ({} GiB) received work",
                    s.id,
                    s.capacity_gb()
                );
            }
        }
    }

    #[test]
    fn memory_hungry_jobs_avoid_small_slices_multi_window() {
        // Same safety property with every slice announced per iteration.
        let mut c = cfg();
        c.jasda.announce_per_slice = true;
        let sched = JasdaScheduler::new(c.jasda.clone());
        let out = SimEngine::new(c, Box::new(sched)).run(jobs(3, 17.0, 1200.0));
        assert_eq!(out.metrics.unfinished, 0);
        for s in out.cluster.slices() {
            if s.capacity_gb() < 17.0 {
                assert!(
                    s.timeline.is_empty(),
                    "unsafe slice {} ({} GiB) received work under per-slice K",
                    s.id,
                    s.capacity_gb()
                );
            }
        }
    }

    #[test]
    fn age_priority_rescues_starved_class() {
        // Two heavy jobs + one light job contending on one small cluster;
        // with age priority the light job cannot be starved forever.
        let mut c = cfg();
        c.jasda.age_priority = true;
        c.jasda.age_scale = 2_000;
        let sched = JasdaScheduler::new(c.jasda.clone());
        let out = SimEngine::new(c, Box::new(sched)).run(jobs(8, 8.0, 3000.0));
        assert_eq!(out.metrics.unfinished, 0);
        assert!(out.metrics.max_starvation() < 1_000_000);
    }

    #[test]
    fn calibration_runs_and_reports_rho() {
        let c = cfg();
        let mut js = jobs(4, 6.0, 1500.0);
        js[1].misreport_bias = 0.8; // one liar
        let sched = JasdaScheduler::new(c.jasda.clone());
        let out = SimEngine::new(c, Box::new(sched)).run(js);
        assert_eq!(out.metrics.unfinished, 0);
        let rho = out.scheduler_stats.get("mean_rho").unwrap().as_f64().unwrap();
        assert!(rho > 0.0 && rho <= 1.0);
        assert!(rho < 1.0, "a misreporting job must dent mean reliability, got {rho}");
    }

    #[test]
    fn no_bids_no_commitments() {
        let c = cfg();
        let mut sched = JasdaScheduler::new(c.jasda.clone());
        let layout = crate::mig::PartitionLayout::balanced();
        let cluster = Cluster::new(1, &layout);
        let mut empty = JobSet::new(vec![]);
        let mut rng = Rng::new(1);
        let commits = sched.iterate(0, &cluster, &mut empty, &mut rng);
        assert!(commits.is_empty());
    }
}
