//! Per-window clearing: optimal Weighted Interval Scheduling (paper §4.4).
//!
//! `SelectBestCompatibleVariants` — given the pooled bid set V of one
//! announced window, select the maximum-total-score subset of pairwise
//! temporally non-overlapping variants. Classical DP after sorting by end
//! time, with binary-search predecessor lookup: `O(M log M)` for `M = |V|`
//! exactly as §4.6 claims.
//!
//! Intervals are half-open, so a variant ending at `t` is compatible with
//! one starting at `t` (back-to-back chains like the worked example's
//! `v_A1=[40,47), v_A2=[47,50)` are allowed).

use crate::types::Interval;

/// A scored interval entering the WIS instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WisItem {
    /// Execution interval `I(v)`.
    pub interval: Interval,
    /// Composite score `Score(v)` (must be ≥ 0; negatives are never
    /// selected anyway under a sum objective, so we reject them).
    pub score: f64,
}

/// Result of one clearing pass.
#[derive(Debug, Clone, PartialEq)]
pub struct WisSolution {
    /// Indices into the *input* slice, in increasing start order.
    pub selected: Vec<usize>,
    /// Total score of the selected set.
    pub total_score: f64,
}

/// Solve weighted interval scheduling over `items`.
///
/// Returns the optimal subset as indices into `items`. Deterministic
/// tie-breaking: when including or excluding an item yields the same
/// total, the item is *excluded* (later-ending bids don't displace earlier
/// structure without strict improvement).
pub fn select_best_compatible(items: &[WisItem]) -> WisSolution {
    let m = items.len();
    if m == 0 {
        return WisSolution { selected: vec![], total_score: 0.0 };
    }
    debug_assert!(items.iter().all(|it| it.score >= 0.0), "scores must be non-negative");

    // Order by end time (stable tie-break on start then input index).
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by(|&a, &b| {
        items[a]
            .interval
            .end
            .cmp(&items[b].interval.end)
            .then(items[a].interval.start.cmp(&items[b].interval.start))
            .then(a.cmp(&b))
    });
    let ends: Vec<u64> = order.iter().map(|&i| items[i].interval.end).collect();

    // p[k] = number of sorted items strictly before k that are compatible
    // with item k, i.e. the count of items with end <= start_k.
    // (half-open intervals: end == start is compatible).
    let p: Vec<usize> = order
        .iter()
        .map(|&i| ends.partition_point(|&e| e <= items[i].interval.start))
        .collect();

    // dp[k] = best total using the first k sorted items.
    let mut dp = vec![0.0f64; m + 1];
    for k in 1..=m {
        let item = &items[order[k - 1]];
        let include = dp[p[k - 1]] + item.score;
        dp[k] = if include > dp[k - 1] { include } else { dp[k - 1] };
    }

    // Backtrack.
    let mut selected = Vec::new();
    let mut k = m;
    while k > 0 {
        let item = &items[order[k - 1]];
        let include = dp[p[k - 1]] + item.score;
        if include > dp[k - 1] {
            selected.push(order[k - 1]);
            k = p[k - 1];
        } else {
            k -= 1;
        }
    }
    selected.reverse();
    selected.sort_by_key(|&i| items[i].interval.start);
    WisSolution { selected, total_score: dp[m] }
}

/// Exhaustive reference solver for verification (exponential; tests only).
#[cfg(test)]
pub fn brute_force(items: &[WisItem]) -> f64 {
    let m = items.len();
    assert!(m <= 20, "brute force is exponential");
    let mut best = 0.0f64;
    'subset: for mask in 0u32..(1 << m) {
        let mut total = 0.0;
        let mut chosen: Vec<&WisItem> = Vec::new();
        for i in 0..m {
            if mask & (1 << i) != 0 {
                for c in &chosen {
                    if c.interval.overlaps(&items[i].interval) {
                        continue 'subset;
                    }
                }
                chosen.push(&items[i]);
                total += items[i].score;
            }
        }
        if total > best {
            best = total;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(s: u64, e: u64, score: f64) -> WisItem {
        WisItem { interval: Interval::new(s, e), score }
    }

    #[test]
    fn empty_pool() {
        let sol = select_best_compatible(&[]);
        assert!(sol.selected.is_empty());
        assert_eq!(sol.total_score, 0.0);
    }

    #[test]
    fn single_item() {
        let sol = select_best_compatible(&[item(0, 10, 0.7)]);
        assert_eq!(sol.selected, vec![0]);
        assert!((sol.total_score - 0.7).abs() < 1e-12);
    }

    #[test]
    fn paper_worked_example_table3() {
        // Table 3: v_A1=[40,47) score .67, v_A2=[47,50) score .64,
        // v_B1=[40,50) score .72. Optimal = {v_A1, v_A2}, total 1.31.
        let pool = [item(40, 47, 0.67), item(47, 50, 0.64), item(40, 50, 0.72)];
        let sol = select_best_compatible(&pool);
        assert_eq!(sol.selected, vec![0, 1], "must pick the A-chain over B");
        assert!((sol.total_score - 1.31).abs() < 1e-12);
    }

    #[test]
    fn prefers_single_big_when_it_wins() {
        let pool = [item(40, 47, 0.3), item(47, 50, 0.3), item(40, 50, 0.72)];
        let sol = select_best_compatible(&pool);
        assert_eq!(sol.selected, vec![2]);
        assert!((sol.total_score - 0.72).abs() < 1e-12);
    }

    #[test]
    fn back_to_back_is_compatible() {
        let pool = [item(0, 10, 1.0), item(10, 20, 1.0), item(20, 30, 1.0)];
        let sol = select_best_compatible(&pool);
        assert_eq!(sol.selected, vec![0, 1, 2]);
        assert!((sol.total_score - 3.0).abs() < 1e-12);
    }

    #[test]
    fn identical_intervals_take_best() {
        let pool = [item(0, 10, 0.4), item(0, 10, 0.9), item(0, 10, 0.6)];
        let sol = select_best_compatible(&pool);
        assert_eq!(sol.selected, vec![1]);
    }

    #[test]
    fn selected_indices_point_into_input_and_are_start_sorted() {
        let pool = [item(50, 60, 0.5), item(0, 10, 0.5), item(20, 30, 0.5)];
        let sol = select_best_compatible(&pool);
        assert_eq!(sol.selected, vec![1, 2, 0]);
        let starts: Vec<u64> = sol.selected.iter().map(|&i| pool[i].interval.start).collect();
        assert!(starts.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn no_overlap_in_solution() {
        let pool = [
            item(0, 10, 0.9),
            item(5, 15, 0.9),
            item(10, 20, 0.9),
            item(15, 25, 0.9),
            item(20, 30, 0.9),
        ];
        let sol = select_best_compatible(&pool);
        for w in sol.selected.windows(2) {
            assert!(!pool[w[0]].interval.overlaps(&pool[w[1]].interval));
        }
        assert_eq!(sol.selected, vec![0, 2, 4]);
    }

    #[test]
    fn matches_brute_force_exhaustive_random() {
        // Deterministic pseudo-random pools checked against brute force.
        let mut state = 0x1234_5678_9abc_def0u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for trial in 0..200 {
            let n = 1 + (next() % 12) as usize;
            let items: Vec<WisItem> = (0..n)
                .map(|_| {
                    let s = next() % 80;
                    let len = 1 + next() % 30;
                    let score = (next() % 1000) as f64 / 1000.0;
                    item(s, s + len, score)
                })
                .collect();
            let sol = select_best_compatible(&items);
            let best = brute_force(&items);
            assert!(
                (sol.total_score - best).abs() < 1e-9,
                "trial {trial}: dp {} vs brute {best} on {items:?}",
                sol.total_score
            );
            // And the reported selection is consistent + feasible.
            let sum: f64 = sol.selected.iter().map(|&i| items[i].score).sum();
            assert!((sum - sol.total_score).abs() < 1e-9);
            for i in 0..sol.selected.len() {
                for j in (i + 1)..sol.selected.len() {
                    assert!(!items[sol.selected[i]]
                        .interval
                        .overlaps(&items[sol.selected[j]].interval));
                }
            }
        }
    }

    #[test]
    fn large_pool_scales() {
        // 100k items solved quickly — the O(M log M) claim in practice.
        let items: Vec<WisItem> = (0..100_000u64)
            .map(|i| {
                let s = (i * 7919) % 1_000_000;
                item(s, s + 50 + (i % 97), 0.1 + ((i % 89) as f64) / 100.0)
            })
            .collect();
        let t0 = std::time::Instant::now();
        let sol = select_best_compatible(&items);
        assert!(sol.total_score > 0.0);
        assert!(
            t0.elapsed().as_millis() < 2000,
            "100k-item WIS took {:?}",
            t0.elapsed()
        );
    }
}
