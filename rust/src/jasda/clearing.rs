//! Window clearing: optimal per-window Weighted Interval Scheduling
//! (paper §4.4) and the shared K-window [`ClearingEngine`].
//!
//! Two layers live here:
//!
//! * [`select_best_compatible`] — `SelectBestCompatibleVariants`: given
//!   the pooled bid set V of one announced window, select the
//!   maximum-total-score subset of pairwise temporally non-overlapping
//!   variants. Classical DP after sorting by end time, with
//!   binary-search predecessor lookup: `O(M log M)` for `M = |V|`
//!   exactly as §4.6 claims. Intervals are half-open, so a variant
//!   ending at `t` is compatible with one starting at `t` (back-to-back
//!   chains like the worked example's `v_A1=[40,47)`, `v_A2=[47,50)` are
//!   allowed).
//!
//! * [`ClearingEngine`] — the full K-window decision core shared by the
//!   in-process [`JasdaScheduler`](crate::jasda::JasdaScheduler) and the
//!   message-passing [`coordinator`](crate::coordinator) leader: one
//!   batched composite-scoring pass over the union bid pool (per-row
//!   slice capacities when K > 1), speculative per-window WIS fanned out
//!   on a persistent [`WorkerPool`], and the sequential cross-window
//!   reconciliation merge that keeps a job from winning two temporally
//!   overlapping reservations — or the same work chunk twice — in one
//!   decision round (§4.1 atomicity). Both runtimes feed the engine the
//!   same inputs, so "coordinator round" and "scheduler iteration" are
//!   decision-identical by construction (property-tested in
//!   `tests/properties.rs`).
//!
//! # Exact global clearing (`jasda.clearing = "exact"`)
//!
//! The reconciliation merge above is *greedy in announcement order*: a
//! job that wins an early window is filtered out of later overlapping
//! ones, which can leave welfare on the table as K grows. Under
//! `jasda.clearing = "exact"` the engine additionally solves the round's
//! job × window conflict graph *globally* with an in-tree, LP-free
//! branch-and-bound:
//!
//! * **Incumbent (lower bound)** — the greedy reconciliation result.
//!   It is always feasible, so the exact round can never award less
//!   welfare than greedy, and ties keep greedy's decisions verbatim.
//! * **Relaxation (upper bound)** — drop the cross-window constraints:
//!   each window's WIS over its non-excluded items is per-window
//!   optimal, so the sum of per-window WIS totals bounds every feasible
//!   completion of a node. The speculative per-window solutions the
//!   engine already computes are exactly the root node's columns.
//! * **Branching** — a node whose relaxed solution violates a
//!   cross-window rule on the pair (a, b) spawns two children, one
//!   excluding a and one excluding b; no feasible solution contains
//!   both, so the union of the children covers the node's feasible set.
//! * **Search** — best-first by bound (deterministic `(bound, seq)`
//!   ordering) in fixed-size waves whose children are evaluated on the
//!   [`WorkerPool`]; the wave size never depends on the pool budget, so
//!   the search trajectory is bit-identical at every `jasda.parallel`
//!   setting.
//!
//! The search runs under the `jasda.clearing_budget_ms` wall-clock
//! budget: when it expires (or the node cap trips) the engine commits
//! the best feasible solution found so far — at worst the greedy
//! incumbent — so the round-deadline semantics of the protocol runtime
//! are never violated. A zero budget, and any K = 1 round (a single
//! window has no cross-window constraints), skip the search entirely
//! and are decision-identical to `greedy` by construction. Whatever
//! mode wins, the engine emits exactly one final solution through
//! `on_accept`, so downstream layers (commitments, the cross-shard
//! reconciler) always consume the same global decision.

use crate::config::{ClearingMode, JasdaConfig};
use crate::jasda::pool::{workers_for, WorkerPool};
use crate::jasda::scoring::{ScoreBatch, ScoreOutput, ScorerBackend};
use crate::job::Variant;
use crate::mig::Window;
use crate::types::{Interval, JobId};

/// A scored interval entering the WIS instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WisItem {
    /// Execution interval `I(v)`.
    pub interval: Interval,
    /// Composite score `Score(v)` (must be ≥ 0; negatives are never
    /// selected anyway under a sum objective, so we reject them).
    pub score: f64,
}

/// Result of one clearing pass.
#[derive(Debug, Clone, PartialEq)]
pub struct WisSolution {
    /// Indices into the *input* slice, in increasing start order.
    pub selected: Vec<usize>,
    /// Total score of the selected set.
    pub total_score: f64,
}

/// Solve weighted interval scheduling over `items`.
///
/// Returns the optimal subset as indices into `items`. Deterministic
/// tie-breaking: when including or excluding an item yields the same
/// total, the item is *excluded* (later-ending bids don't displace earlier
/// structure without strict improvement).
pub fn select_best_compatible(items: &[WisItem]) -> WisSolution {
    let m = items.len();
    if m == 0 {
        return WisSolution { selected: vec![], total_score: 0.0 };
    }
    debug_assert!(items.iter().all(|it| it.score >= 0.0), "scores must be non-negative");

    // Order by end time (stable tie-break on start then input index).
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by(|&a, &b| {
        items[a]
            .interval
            .end
            .cmp(&items[b].interval.end)
            .then(items[a].interval.start.cmp(&items[b].interval.start))
            .then(a.cmp(&b))
    });
    let ends: Vec<u64> = order.iter().map(|&i| items[i].interval.end).collect();

    // p[k] = number of sorted items strictly before k that are compatible
    // with item k, i.e. the count of items with end <= start_k.
    // (half-open intervals: end == start is compatible).
    let p: Vec<usize> = order
        .iter()
        .map(|&i| ends.partition_point(|&e| e <= items[i].interval.start))
        .collect();

    // dp[k] = best total using the first k sorted items.
    let mut dp = vec![0.0f64; m + 1];
    for k in 1..=m {
        let item = &items[order[k - 1]];
        let include = dp[p[k - 1]] + item.score;
        dp[k] = if include > dp[k - 1] { include } else { dp[k - 1] };
    }

    // Backtrack.
    let mut selected = Vec::new();
    let mut k = m;
    while k > 0 {
        let item = &items[order[k - 1]];
        let include = dp[p[k - 1]] + item.score;
        if include > dp[k - 1] {
            selected.push(order[k - 1]);
            k = p[k - 1];
        } else {
            k -= 1;
        }
    }
    selected.reverse();
    selected.sort_by_key(|&i| items[i].interval.start);
    WisSolution { selected, total_score: dp[m] }
}

/// Eligible items across windows below which speculative parallel WIS
/// is not worth the fan-out.
const MIN_WIS_ITEMS_FOR_FANOUT: usize = 64;

/// Per-row scoring context the caller resolves from its own trust/age
/// state: the in-process scheduler reads its [`JobSet`](crate::job::JobSet)
/// and [`Calibration`](crate::jasda::Calibration); the coordinator leader
/// reads its private bookkeeping vectors. Everything else about a row
/// comes from the [`Variant`] itself.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RowCtx {
    /// Age factor `A_i(t) ∈ [0,1]` (§4.3); 0 when age priority is off.
    pub age: f64,
    /// Calibration weight `γ·ρ_J` (Eq. (5)); 1 when calibration is off.
    pub trust: f64,
    /// Historical anchor `HistAvg(J)`; 0 when calibration is off.
    pub hist: f64,
}

/// Counters from one [`ClearingEngine::clear`] round.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClearStats {
    /// Variants that survived eligibility gating into a window's WIS.
    pub variants_eligible: u64,
    /// Variants accepted across all windows.
    pub variants_selected: u64,
    /// Eligible pool variants filtered out before a window's WIS because
    /// their job already won an overlapping interval — or an overlapping
    /// work range — in an earlier window of the same round (counts
    /// variants, not jobs).
    pub cross_window_conflicts: u64,
    /// Windows whose speculative WIS solution was discarded because an
    /// earlier window's acceptances touched their eligible pool.
    pub wis_replays: u64,
    /// Wall time of the batched scoring pass.
    pub scoring_ns: u64,
    /// Wall time of the WIS + reconciliation pass.
    pub clearing_ns: u64,
    /// Rounds in which the exact global solver was consulted (0 or 1
    /// per `clear` call; K = 1 rounds never consult it).
    pub exact_rounds: u64,
    /// Branch-and-bound nodes evaluated by the exact solver.
    pub exact_nodes: u64,
    /// Rounds whose exact search was cut short by
    /// `jasda.clearing_budget_ms` (or the node cap) and fell back to
    /// the best feasible solution found so far.
    pub exact_budget_exhausted: u64,
    /// Rounds where the exact solution strictly improved on the greedy
    /// incumbent's welfare.
    pub exact_improved: u64,
    /// Wall time of the exact solve (0 under `clearing=greedy`).
    pub exact_ns: u64,
}

/// One accepted variant, handed to the caller's `on_accept` sink in
/// reconciliation (= commitment) order.
#[derive(Debug, Clone, Copy)]
pub struct Accepted<'a> {
    /// Row of the variant in the union pool.
    pub row: usize,
    /// The accepted variant.
    pub variant: &'a Variant,
    /// Composite score at selection time.
    pub score: f64,
    /// The announced window it was accepted into.
    pub window: &'a Window,
}

/// Conflict key of one (potential) award: `(job, interval, work range)`
/// — the tuple both reconciliation layers and the exact solver compare.
pub type AwardKey = (JobId, Interval, f64, f64);

/// The one cross-window conflict rule (§4.1), on award keys: same job
/// AND (temporal overlap OR work-range overlap). Every layer — the
/// engine's greedy merge, the exact solver's feasibility scan, and the
/// cross-shard reconciler via [`conflicts_with_accepted`] — routes
/// through this predicate, so they can never disagree.
#[inline]
pub fn keys_conflict(a: &AwardKey, b: &AwardKey) -> bool {
    a.0 == b.0 && (a.1.overlaps(&b.1) || (b.2 < a.3 - 1e-9 && a.2 < b.3 - 1e-9))
}

/// Conflict key of a variant.
#[inline]
pub fn variant_key(v: &Variant) -> AwardKey {
    (v.job, v.interval, v.work_offset, v.work_offset + v.work)
}

/// Cross-window reconciliation predicate (§4.1): true if `v`'s job
/// already won a temporally overlapping reservation — or an overlapping
/// work range `(w0, w1)` — earlier in this round. Public because the
/// coordinator's cross-*shard* reconciler applies the identical rule
/// between leader shards — one predicate, so the two layers can never
/// disagree on what a conflict is.
pub fn conflicts_with_accepted(accepted: &[AwardKey], v: &Variant) -> bool {
    let key = variant_key(v);
    accepted.iter().any(|a| keys_conflict(a, &key))
}

/// The shared K-window clearing core (steps 4a–4b of Algorithm 1,
/// generalized): batched scoring, speculative per-window WIS, sequential
/// cross-window reconciliation. Owns every scratch buffer, so the hot
/// path allocates nothing in the steady state wherever the engine is
/// embedded.
#[derive(Default)]
pub struct ClearingEngine {
    /// Reused scoring batch and output.
    batch: ScoreBatch,
    scored: ScoreOutput,
    /// Per-window WIS items and their pool-row mapping.
    items: Vec<Vec<WisItem>>,
    item_rows: Vec<Vec<usize>>,
    /// Speculative per-window WIS solutions.
    solutions: Vec<WisSolution>,
    /// Accepted (job, interval, work range) tuples for reconciliation.
    accepted: Vec<AwardKey>,
    /// Filtered WIS input for conflict replays.
    replay_items: Vec<WisItem>,
    replay_rows: Vec<usize>,
    /// Per-replay-item index back into the window's unfiltered item
    /// list, so the exact solver and the emission pass share one item
    /// coordinate space.
    replay_idx: Vec<usize>,
    /// The round's chosen solution as (window, item-in-window) picks.
    /// Populated by the greedy merge, possibly replaced by the exact
    /// solver, and emitted through `on_accept` exactly once at the end
    /// of `clear` — the single emission site is what makes it
    /// impossible for the exact path to double-commit a variant the
    /// greedy pass already accepted in the same round.
    pending: Vec<(usize, usize)>,
}

impl ClearingEngine {
    /// Create an engine with empty scratch buffers.
    pub fn new() -> Self {
        Self::default()
    }

    /// Clear one decision round: score the union bid `pool` across the
    /// announced `windows` (rows of window `w` are
    /// `window_rows[w].0..window_rows[w].1`), solve each window's WIS,
    /// and reconcile in announcement order. `row_ctx` supplies the
    /// caller-owned age/trust/history context per row; `on_accept`
    /// receives every accepted variant in commitment order.
    ///
    /// With a single announced window the batch carries the uniform
    /// scalar capacity and the reconciliation filter never fires — K = 1
    /// stays bit-identical to the paper's single-window loop. Results
    /// are bit-identical at any pool budget (the speculative WIS merge
    /// re-solves exactly like the sequential path on conflict).
    #[allow(clippy::too_many_arguments)]
    pub fn clear(
        &mut self,
        cfg: &JasdaConfig,
        windows: &[Window],
        window_rows: &[(usize, usize)],
        pool: &[Variant],
        row_ctx: &mut dyn FnMut(&Variant) -> RowCtx,
        scorer: &mut dyn ScorerBackend,
        workers: &WorkerPool,
        on_accept: &mut dyn FnMut(Accepted<'_>),
    ) -> ClearStats {
        debug_assert_eq!(windows.len(), window_rows.len());
        let mut stats = ClearStats::default();
        if windows.is_empty() || pool.is_empty() {
            return stats;
        }

        // Step 4a: one batched composite-scoring pass across all windows
        // (Eq. (4) + calibration + age; per-row capacities when K > 1),
        // into the reused output, row space chunked across the pool.
        let t0 = std::time::Instant::now();
        self.batch.clear();
        self.batch.t = cfg.fmp_bins;
        self.batch.capacity = windows[0].capacity_gb as f32;
        self.batch.theta = cfg.theta as f32;
        self.batch.lambda = cfg.lambda as f32;
        let alpha = cfg.alpha.as_array();
        let beta = cfg.beta.as_array();
        self.batch.alpha =
            [alpha[0] as f32, alpha[1] as f32, alpha[2] as f32, alpha[3] as f32];
        self.batch.beta = [beta[0] as f32, beta[1] as f32, beta[2] as f32, beta[3] as f32];
        for v in pool {
            let ctx = row_ctx(v);
            let phi =
                [v.declared.phi[0], v.declared.phi[1], v.declared.phi[2], v.declared.phi[3]];
            self.batch.push(
                &v.fmp.mu,
                &v.fmp.sigma,
                phi,
                [v.sys.util, v.sys.frag, ctx.age],
                ctx.trust,
                ctx.hist,
            );
        }
        if windows.len() > 1 {
            for (w, &(start, end)) in windows.iter().zip(window_rows) {
                self.batch
                    .row_capacity
                    .extend(std::iter::repeat(w.capacity_gb as f32).take(end - start));
            }
            debug_assert_eq!(self.batch.row_capacity.len(), pool.len());
        }
        scorer
            .score_into_pooled(&self.batch, &mut self.scored, workers)
            .expect("scoring backend failed");
        stats.scoring_ns = t0.elapsed().as_nanos() as u64;

        // Step 4b: optimal per-window clearing (WIS) with cross-window
        // reconciliation (§4.1 atomicity): within one decision round a
        // job must never hold two temporally overlapping reservations on
        // different slices, nor win the *same work chunk* twice — every
        // window's chains start at the job's unchanged work cursor, so
        // without the work-range check a job could commit chunk
        // [cursor, cursor+w) on two slices and the second reservation
        // would execute no work while still blocking its slice. Windows
        // clear in announcement order (= policy preference order).
        //
        // Parallel form: each window's WIS is solved speculatively over
        // its *unfiltered* eligible items; the merge then walks windows
        // sequentially in announcement order. A window none of whose
        // eligible items conflict with earlier acceptances has a
        // filtered pool identical to the unfiltered one, so its
        // speculative solution is exact; otherwise the solution is
        // discarded and re-solved on the filtered pool — exactly the
        // sequential algorithm.
        let t1 = std::time::Instant::now();
        let n_windows = windows.len();
        if self.items.len() < n_windows {
            self.items.resize_with(n_windows, Vec::new);
            self.item_rows.resize_with(n_windows, Vec::new);
        }
        let mut total_items = 0usize;
        for widx in 0..n_windows {
            self.items[widx].clear();
            self.item_rows[widx].clear();
            let window = windows[widx];
            let wlen = window.delta_t().max(1) as f64;
            let (row0, row1) = window_rows[widx];
            for i in row0..row1 {
                if !self.scored.eligible[i] || self.scored.score[i] <= 0.0 {
                    continue;
                }
                let v = &pool[i];
                // Optional duration weighting (EXPERIMENTS.md F6): under
                // the paper's plain sum objective, many short variants
                // dominate few long ones; weighting by window share makes
                // the objective score-weighted busy time.
                let w = if cfg.duration_weighted_clearing {
                    v.duration() as f64 / wlen
                } else {
                    1.0
                };
                self.items[widx].push(WisItem {
                    interval: v.interval,
                    score: self.scored.score[i] as f64 * w,
                });
                self.item_rows[widx].push(i);
            }
            total_items += self.items[widx].len();
        }

        // Speculative fan-out across windows.
        let speculate = workers.budget() > 1
            && n_windows >= 2
            && total_items >= MIN_WIS_ITEMS_FOR_FANOUT;
        if speculate {
            self.solutions.clear();
            self.solutions
                .resize_with(n_windows, || WisSolution { selected: vec![], total_score: 0.0 });
            let items = &self.items[..n_windows];
            let n_workers = workers_for(workers.budget(), n_windows, 1);
            let chunk = (n_windows + n_workers - 1) / n_workers;
            workers.scope(|scope| {
                let mut rest = self.solutions.as_mut_slice();
                let mut start = 0usize;
                while start < n_windows {
                    let len = chunk.min(n_windows - start);
                    let (sols, r) = rest.split_at_mut(len);
                    let window_items = &items[start..start + len];
                    scope.spawn(move || {
                        for (sol, wi) in sols.iter_mut().zip(window_items) {
                            *sol = select_best_compatible(wi);
                        }
                    });
                    rest = r;
                    start += len;
                }
            });
        }

        // Sequential greedy reconciliation merge in announcement order.
        // Under `clearing=greedy` this IS the round's decision; under
        // `clearing=exact` it is the incumbent the branch-and-bound must
        // strictly beat. Either way nothing is emitted from inside the
        // merge: picks land in `self.pending` and a single emission pass
        // at the end commits exactly one final solution (emitting from
        // the two reconciliation branches directly, as this loop once
        // did, would let a second global pass double-commit awards the
        // greedy pass had already handed out).
        self.accepted.clear();
        self.pending.clear();
        let mut greedy_welfare = 0.0f64;
        let mut fallback = WisSolution { selected: vec![], total_score: 0.0 };
        for widx in 0..n_windows {
            let mut n_conflicts = 0u64;
            if !self.accepted.is_empty() {
                for &i in &self.item_rows[widx] {
                    if conflicts_with_accepted(&self.accepted, &pool[i]) {
                        n_conflicts += 1;
                    }
                }
            }
            stats.cross_window_conflicts += n_conflicts;

            if n_conflicts == 0 {
                if !speculate {
                    fallback = select_best_compatible(&self.items[widx]);
                }
                let sol = if speculate { &self.solutions[widx] } else { &fallback };
                stats.variants_eligible += self.items[widx].len() as u64;
                greedy_welfare += sol.total_score;
                for &sel in &sol.selected {
                    let i = self.item_rows[widx][sel];
                    self.accepted.push(variant_key(&pool[i]));
                    self.pending.push((widx, sel));
                }
            } else {
                // Replay on the filtered pool — the sequential path.
                stats.wis_replays += 1;
                self.replay_items.clear();
                self.replay_rows.clear();
                self.replay_idx.clear();
                for k in 0..self.item_rows[widx].len() {
                    let i = self.item_rows[widx][k];
                    if conflicts_with_accepted(&self.accepted, &pool[i]) {
                        continue;
                    }
                    self.replay_items.push(self.items[widx][k]);
                    self.replay_rows.push(i);
                    self.replay_idx.push(k);
                }
                stats.variants_eligible += self.replay_items.len() as u64;
                let sol = select_best_compatible(&self.replay_items);
                greedy_welfare += sol.total_score;
                for &k in &sol.selected {
                    let i = self.replay_rows[k];
                    self.accepted.push(variant_key(&pool[i]));
                    self.pending.push((widx, self.replay_idx[k]));
                }
            }
        }

        // Exact global pass: branch-and-bound over the same per-window
        // item space, with the greedy result as incumbent. K = 1 has no
        // cross-window constraints (the single window's WIS is already
        // optimal) and a zero budget never starts the search — both are
        // decision-identical to greedy by construction.
        if cfg.clearing == ClearingMode::Exact && n_windows >= 2 {
            stats.exact_rounds = 1;
            let t2 = std::time::Instant::now();
            if cfg.clearing_budget_ms == 0 {
                stats.exact_budget_exhausted = 1;
            } else {
                let root_sols: Vec<WisSolution> = if speculate {
                    self.solutions[..n_windows].to_vec()
                } else {
                    self.items[..n_windows].iter().map(|it| select_best_compatible(it)).collect()
                };
                let keys: Vec<Vec<AwardKey>> = (0..n_windows)
                    .map(|w| self.item_rows[w].iter().map(|&i| variant_key(&pool[i])).collect())
                    .collect();
                let outcome = solve_exact(
                    &self.items[..n_windows],
                    &keys,
                    root_sols,
                    greedy_welfare,
                    std::time::Duration::from_millis(cfg.clearing_budget_ms),
                    workers,
                );
                stats.exact_nodes = outcome.nodes;
                if outcome.exhausted {
                    stats.exact_budget_exhausted = 1;
                }
                if let Some(sel) = outcome.improved {
                    // Adopt the strictly better global solution: rebuild
                    // the pending picks and the accepted record from
                    // scratch so the emission pass commits it — and only
                    // it — downstream.
                    stats.exact_improved = 1;
                    self.pending.clear();
                    self.accepted.clear();
                    for (w, items) in sel.iter().enumerate() {
                        for &k in items {
                            self.pending.push((w, k));
                            self.accepted.push(keys[w][k]);
                        }
                    }
                }
            }
            stats.exact_ns = t2.elapsed().as_nanos() as u64;
        }

        // Single emission site: commit the chosen solution, greedy or
        // exact, in window order then start order.
        for &(widx, k) in &self.pending {
            let i = self.item_rows[widx][k];
            stats.variants_selected += 1;
            on_accept(Accepted {
                row: i,
                variant: &pool[i],
                score: self.scored.score[i] as f64,
                window: &windows[widx],
            });
        }
        stats.clearing_ns = t1.elapsed().as_nanos() as u64;
        stats
    }
}

/// Incumbent replacements (and bound pruning) require strict float
/// improvement beyond this epsilon, so welfare ties keep the greedy
/// decisions verbatim and summation-order noise can't flip a round.
const EXACT_EPS: f64 = 1e-9;

/// Node-count safety cap for one exact solve; counts as budget
/// exhaustion. Bounds heap memory on adversarial conflict graphs the
/// wall-clock budget alone would let grow large.
const EXACT_MAX_NODES: u64 = 50_000;

/// Nodes expanded per best-first wave. Fixed — never derived from the
/// pool budget — so the search trajectory (and therefore the decision)
/// is bit-identical at every `jasda.parallel` setting; the pool only
/// changes how fast a wave's children are evaluated.
const EXACT_WAVE: usize = 8;

/// One open branch-and-bound node: a set of excluded (window, item)
/// pairs, the per-window WIS solutions under those exclusions, their
/// summed bound, and the first cross-window violation to branch on.
struct BbNode {
    bound: f64,
    /// Creation sequence number — the deterministic tie-break.
    seq: u64,
    excluded: Vec<(u32, u32)>,
    sols: Vec<WisSolution>,
    violation: ((u32, u32), (u32, u32)),
}

impl PartialEq for BbNode {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for BbNode {}
impl PartialOrd for BbNode {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for BbNode {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Max-heap: higher bound first; among equal bounds, the earlier
        // created node (lower seq) wins — fully deterministic order.
        self.bound.total_cmp(&other.bound).then(other.seq.cmp(&self.seq))
    }
}

/// Result of evaluating one child node (pure, pool-parallelizable).
struct ChildEval {
    bound: f64,
    excluded: Vec<(u32, u32)>,
    sols: Vec<WisSolution>,
    violation: Option<((u32, u32), (u32, u32))>,
}

/// What one exact solve produced.
struct ExactOutcome {
    /// Per-window selected item indices, only when strictly better than
    /// the greedy incumbent (ties keep greedy).
    improved: Option<Vec<Vec<usize>>>,
    /// Nodes evaluated (root + children).
    nodes: u64,
    /// Whether the wall-clock budget or node cap cut the search before
    /// the tree was exhausted (the result is then the best feasible
    /// solution found so far, at worst the greedy incumbent).
    exhausted: bool,
}

/// First cross-window conflict in a relaxed solution, scanning windows
/// in announcement order and selections in start order — deterministic,
/// and deliberately blind to *within*-window pairs (WIS already enforces
/// temporal compatibility there, and greedy applies the job-level rule
/// only across windows, so the exact solver must too or it would search
/// a smaller space than its own incumbent).
fn first_violation(
    sols: &[WisSolution],
    keys: &[Vec<AwardKey>],
) -> Option<((u32, u32), (u32, u32))> {
    let mut acc: Vec<(u32, u32)> = Vec::new();
    for (w, sol) in sols.iter().enumerate() {
        for &s in &sol.selected {
            let key = &keys[w][s];
            for &(aw, ai) in &acc {
                if keys_conflict(&keys[aw as usize][ai as usize], key) {
                    return Some(((aw, ai), (w as u32, s as u32)));
                }
            }
        }
        // Earlier windows only: append this window's picks after it is
        // fully scanned.
        acc.extend(sol.selected.iter().map(|&s| (w as u32, s as u32)));
    }
    None
}

/// WIS over one window's items minus the exclusions recorded for window
/// `w`, with the selection mapped back to unfiltered item indices.
fn wis_excluding(items: &[WisItem], excluded: &[(u32, u32)], w: u32) -> WisSolution {
    let mut filtered: Vec<WisItem> = Vec::with_capacity(items.len());
    let mut map: Vec<usize> = Vec::with_capacity(items.len());
    for (i, it) in items.iter().enumerate() {
        if excluded.iter().any(|&(ew, ei)| ew == w && ei as usize == i) {
            continue;
        }
        filtered.push(*it);
        map.push(i);
    }
    let sol = select_best_compatible(&filtered);
    WisSolution {
        selected: sol.selected.iter().map(|&k| map[k]).collect(),
        total_score: sol.total_score,
    }
}

/// Evaluate one child of `parent`: exclude one side of the parent's
/// violated pair, re-solve only that window's WIS, re-bound, re-scan.
fn eval_child(
    items: &[Vec<WisItem>],
    keys: &[Vec<AwardKey>],
    parent: &BbNode,
    side: usize,
) -> ChildEval {
    let (w, i) = if side == 0 { parent.violation.0 } else { parent.violation.1 };
    let mut excluded = parent.excluded.clone();
    excluded.push((w, i));
    let mut sols = parent.sols.clone();
    sols[w as usize] = wis_excluding(&items[w as usize], &excluded, w);
    let bound = sols.iter().map(|s| s.total_score).sum();
    let violation = first_violation(&sols, keys);
    ChildEval { bound, excluded, sols, violation }
}

/// Best-first branch-and-bound over the round's job × window conflict
/// graph (see the module docs for the bound structure). Returns a
/// strictly-better-than-greedy solution when one is proven (or found
/// before the budget ran out), `None` to keep the greedy incumbent.
fn solve_exact(
    items: &[Vec<WisItem>],
    keys: &[Vec<AwardKey>],
    root_sols: Vec<WisSolution>,
    incumbent: f64,
    budget: std::time::Duration,
    workers: &WorkerPool,
) -> ExactOutcome {
    let t0 = std::time::Instant::now();
    let mut nodes = 1u64; // the root
    let mut exhausted = false;
    let mut best_val = incumbent;
    let mut best_sel: Option<Vec<Vec<usize>>> = None;
    let mut seq = 0u64;
    let mut heap: std::collections::BinaryHeap<BbNode> = std::collections::BinaryHeap::new();

    let root_bound: f64 = root_sols.iter().map(|s| s.total_score).sum();
    if root_bound > best_val + EXACT_EPS {
        match first_violation(&root_sols, keys) {
            None => {
                // The unconstrained per-window optima are already
                // feasible — the global optimum, no search needed.
                best_val = root_bound;
                best_sel = Some(root_sols.iter().map(|s| s.selected.clone()).collect());
            }
            Some(violation) => {
                heap.push(BbNode {
                    bound: root_bound,
                    seq,
                    excluded: Vec::new(),
                    sols: root_sols,
                    violation,
                });
            }
        }
    }

    while !heap.is_empty() {
        if t0.elapsed() >= budget || nodes >= EXACT_MAX_NODES {
            exhausted = true;
            break;
        }
        // Pop one wave of the best open nodes. The heap is bound-ordered,
        // so the first pruned pop proves the whole frontier is pruned.
        let mut wave: Vec<BbNode> = Vec::with_capacity(EXACT_WAVE);
        while wave.len() < EXACT_WAVE {
            match heap.pop() {
                Some(n) if n.bound > best_val + EXACT_EPS => wave.push(n),
                Some(_) => {
                    heap.clear();
                    break;
                }
                None => break,
            }
        }
        if wave.is_empty() {
            break;
        }
        // Evaluate every child of the wave on the worker pool (pure
        // work, disjoint output slots — the same chunking contract as
        // the speculative WIS fan-out).
        let mut evals: Vec<Option<ChildEval>> = Vec::new();
        evals.resize_with(wave.len() * 2, || None);
        workers.scope(|scope| {
            let mut rest = evals.as_mut_slice();
            for parent in &wave {
                for side in 0..2 {
                    let (slot, r) = rest.split_at_mut(1);
                    rest = r;
                    scope.spawn(move || {
                        slot[0] = Some(eval_child(items, keys, parent, side));
                    });
                }
            }
        });
        // Merge sequentially in wave order — deterministic regardless of
        // which worker evaluated which child.
        for ev in evals.into_iter().flatten() {
            nodes += 1;
            if ev.bound <= best_val + EXACT_EPS {
                continue;
            }
            match ev.violation {
                None => {
                    best_val = ev.bound;
                    best_sel = Some(ev.sols.iter().map(|s| s.selected.clone()).collect());
                }
                Some(violation) => {
                    seq += 1;
                    heap.push(BbNode {
                        bound: ev.bound,
                        seq,
                        excluded: ev.excluded,
                        sols: ev.sols,
                        violation,
                    });
                }
            }
        }
    }
    ExactOutcome { improved: best_sel, nodes, exhausted }
}

/// Exhaustive reference solver for verification (exponential; tests only).
#[cfg(test)]
pub fn brute_force(items: &[WisItem]) -> f64 {
    let m = items.len();
    assert!(m <= 20, "brute force is exponential");
    let mut best = 0.0f64;
    'subset: for mask in 0u32..(1 << m) {
        let mut total = 0.0;
        let mut chosen: Vec<&WisItem> = Vec::new();
        for i in 0..m {
            if mask & (1 << i) != 0 {
                for c in &chosen {
                    if c.interval.overlaps(&items[i].interval) {
                        continue 'subset;
                    }
                }
                chosen.push(&items[i]);
                total += items[i].score;
            }
        }
        if total > best {
            best = total;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(s: u64, e: u64, score: f64) -> WisItem {
        WisItem { interval: Interval::new(s, e), score }
    }

    #[test]
    fn empty_pool() {
        let sol = select_best_compatible(&[]);
        assert!(sol.selected.is_empty());
        assert_eq!(sol.total_score, 0.0);
    }

    #[test]
    fn single_item() {
        let sol = select_best_compatible(&[item(0, 10, 0.7)]);
        assert_eq!(sol.selected, vec![0]);
        assert!((sol.total_score - 0.7).abs() < 1e-12);
    }

    #[test]
    fn paper_worked_example_table3() {
        // Table 3: v_A1=[40,47) score .67, v_A2=[47,50) score .64,
        // v_B1=[40,50) score .72. Optimal = {v_A1, v_A2}, total 1.31.
        let pool = [item(40, 47, 0.67), item(47, 50, 0.64), item(40, 50, 0.72)];
        let sol = select_best_compatible(&pool);
        assert_eq!(sol.selected, vec![0, 1], "must pick the A-chain over B");
        assert!((sol.total_score - 1.31).abs() < 1e-12);
    }

    #[test]
    fn prefers_single_big_when_it_wins() {
        let pool = [item(40, 47, 0.3), item(47, 50, 0.3), item(40, 50, 0.72)];
        let sol = select_best_compatible(&pool);
        assert_eq!(sol.selected, vec![2]);
        assert!((sol.total_score - 0.72).abs() < 1e-12);
    }

    #[test]
    fn back_to_back_is_compatible() {
        let pool = [item(0, 10, 1.0), item(10, 20, 1.0), item(20, 30, 1.0)];
        let sol = select_best_compatible(&pool);
        assert_eq!(sol.selected, vec![0, 1, 2]);
        assert!((sol.total_score - 3.0).abs() < 1e-12);
    }

    #[test]
    fn identical_intervals_take_best() {
        let pool = [item(0, 10, 0.4), item(0, 10, 0.9), item(0, 10, 0.6)];
        let sol = select_best_compatible(&pool);
        assert_eq!(sol.selected, vec![1]);
    }

    #[test]
    fn selected_indices_point_into_input_and_are_start_sorted() {
        let pool = [item(50, 60, 0.5), item(0, 10, 0.5), item(20, 30, 0.5)];
        let sol = select_best_compatible(&pool);
        assert_eq!(sol.selected, vec![1, 2, 0]);
        let starts: Vec<u64> = sol.selected.iter().map(|&i| pool[i].interval.start).collect();
        assert!(starts.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn no_overlap_in_solution() {
        let pool = [
            item(0, 10, 0.9),
            item(5, 15, 0.9),
            item(10, 20, 0.9),
            item(15, 25, 0.9),
            item(20, 30, 0.9),
        ];
        let sol = select_best_compatible(&pool);
        for w in sol.selected.windows(2) {
            assert!(!pool[w[0]].interval.overlaps(&pool[w[1]].interval));
        }
        assert_eq!(sol.selected, vec![0, 2, 4]);
    }

    #[test]
    fn matches_brute_force_exhaustive_random() {
        // Deterministic pseudo-random pools checked against brute force.
        let mut state = 0x1234_5678_9abc_def0u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for trial in 0..200 {
            let n = 1 + (next() % 12) as usize;
            let items: Vec<WisItem> = (0..n)
                .map(|_| {
                    let s = next() % 80;
                    let len = 1 + next() % 30;
                    let score = (next() % 1000) as f64 / 1000.0;
                    item(s, s + len, score)
                })
                .collect();
            let sol = select_best_compatible(&items);
            let best = brute_force(&items);
            assert!(
                (sol.total_score - best).abs() < 1e-9,
                "trial {trial}: dp {} vs brute {best} on {items:?}",
                sol.total_score
            );
            // And the reported selection is consistent + feasible.
            let sum: f64 = sol.selected.iter().map(|&i| items[i].score).sum();
            assert!((sum - sol.total_score).abs() < 1e-9);
            for i in 0..sol.selected.len() {
                for j in (i + 1)..sol.selected.len() {
                    assert!(!items[sol.selected[i]]
                        .interval
                        .overlaps(&items[sol.selected[j]].interval));
                }
            }
        }
    }

    #[test]
    fn large_pool_scales() {
        // 100k items solved quickly — the O(M log M) claim in practice.
        let items: Vec<WisItem> = (0..100_000u64)
            .map(|i| {
                let s = (i * 7919) % 1_000_000;
                item(s, s + 50 + (i % 97), 0.1 + ((i % 89) as f64) / 100.0)
            })
            .collect();
        let t0 = std::time::Instant::now();
        let sol = select_best_compatible(&items);
        assert!(sol.total_score > 0.0);
        assert!(
            t0.elapsed().as_millis() < 2000,
            "100k-item WIS took {:?}",
            t0.elapsed()
        );
    }
}
