//! Window clearing: optimal per-window Weighted Interval Scheduling
//! (paper §4.4) and the shared K-window [`ClearingEngine`].
//!
//! Two layers live here:
//!
//! * [`select_best_compatible`] — `SelectBestCompatibleVariants`: given
//!   the pooled bid set V of one announced window, select the
//!   maximum-total-score subset of pairwise temporally non-overlapping
//!   variants. Classical DP after sorting by end time, with
//!   binary-search predecessor lookup: `O(M log M)` for `M = |V|`
//!   exactly as §4.6 claims. Intervals are half-open, so a variant
//!   ending at `t` is compatible with one starting at `t` (back-to-back
//!   chains like the worked example's `v_A1=[40,47)`, `v_A2=[47,50)` are
//!   allowed).
//!
//! * [`ClearingEngine`] — the full K-window decision core shared by the
//!   in-process [`JasdaScheduler`](crate::jasda::JasdaScheduler) and the
//!   message-passing [`coordinator`](crate::coordinator) leader: one
//!   batched composite-scoring pass over the union bid pool (per-row
//!   slice capacities when K > 1), speculative per-window WIS fanned out
//!   on a persistent [`WorkerPool`], and the sequential cross-window
//!   reconciliation merge that keeps a job from winning two temporally
//!   overlapping reservations — or the same work chunk twice — in one
//!   decision round (§4.1 atomicity). Both runtimes feed the engine the
//!   same inputs, so "coordinator round" and "scheduler iteration" are
//!   decision-identical by construction (property-tested in
//!   `tests/properties.rs`).

use crate::config::JasdaConfig;
use crate::jasda::pool::{workers_for, WorkerPool};
use crate::jasda::scoring::{ScoreBatch, ScoreOutput, ScorerBackend};
use crate::job::Variant;
use crate::mig::Window;
use crate::types::{Interval, JobId};

/// A scored interval entering the WIS instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WisItem {
    /// Execution interval `I(v)`.
    pub interval: Interval,
    /// Composite score `Score(v)` (must be ≥ 0; negatives are never
    /// selected anyway under a sum objective, so we reject them).
    pub score: f64,
}

/// Result of one clearing pass.
#[derive(Debug, Clone, PartialEq)]
pub struct WisSolution {
    /// Indices into the *input* slice, in increasing start order.
    pub selected: Vec<usize>,
    /// Total score of the selected set.
    pub total_score: f64,
}

/// Solve weighted interval scheduling over `items`.
///
/// Returns the optimal subset as indices into `items`. Deterministic
/// tie-breaking: when including or excluding an item yields the same
/// total, the item is *excluded* (later-ending bids don't displace earlier
/// structure without strict improvement).
pub fn select_best_compatible(items: &[WisItem]) -> WisSolution {
    let m = items.len();
    if m == 0 {
        return WisSolution { selected: vec![], total_score: 0.0 };
    }
    debug_assert!(items.iter().all(|it| it.score >= 0.0), "scores must be non-negative");

    // Order by end time (stable tie-break on start then input index).
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by(|&a, &b| {
        items[a]
            .interval
            .end
            .cmp(&items[b].interval.end)
            .then(items[a].interval.start.cmp(&items[b].interval.start))
            .then(a.cmp(&b))
    });
    let ends: Vec<u64> = order.iter().map(|&i| items[i].interval.end).collect();

    // p[k] = number of sorted items strictly before k that are compatible
    // with item k, i.e. the count of items with end <= start_k.
    // (half-open intervals: end == start is compatible).
    let p: Vec<usize> = order
        .iter()
        .map(|&i| ends.partition_point(|&e| e <= items[i].interval.start))
        .collect();

    // dp[k] = best total using the first k sorted items.
    let mut dp = vec![0.0f64; m + 1];
    for k in 1..=m {
        let item = &items[order[k - 1]];
        let include = dp[p[k - 1]] + item.score;
        dp[k] = if include > dp[k - 1] { include } else { dp[k - 1] };
    }

    // Backtrack.
    let mut selected = Vec::new();
    let mut k = m;
    while k > 0 {
        let item = &items[order[k - 1]];
        let include = dp[p[k - 1]] + item.score;
        if include > dp[k - 1] {
            selected.push(order[k - 1]);
            k = p[k - 1];
        } else {
            k -= 1;
        }
    }
    selected.reverse();
    selected.sort_by_key(|&i| items[i].interval.start);
    WisSolution { selected, total_score: dp[m] }
}

/// Eligible items across windows below which speculative parallel WIS
/// is not worth the fan-out.
const MIN_WIS_ITEMS_FOR_FANOUT: usize = 64;

/// Per-row scoring context the caller resolves from its own trust/age
/// state: the in-process scheduler reads its [`JobSet`](crate::job::JobSet)
/// and [`Calibration`](crate::jasda::Calibration); the coordinator leader
/// reads its private bookkeeping vectors. Everything else about a row
/// comes from the [`Variant`] itself.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RowCtx {
    /// Age factor `A_i(t) ∈ [0,1]` (§4.3); 0 when age priority is off.
    pub age: f64,
    /// Calibration weight `γ·ρ_J` (Eq. (5)); 1 when calibration is off.
    pub trust: f64,
    /// Historical anchor `HistAvg(J)`; 0 when calibration is off.
    pub hist: f64,
}

/// Counters from one [`ClearingEngine::clear`] round.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClearStats {
    /// Variants that survived eligibility gating into a window's WIS.
    pub variants_eligible: u64,
    /// Variants accepted across all windows.
    pub variants_selected: u64,
    /// Eligible pool variants filtered out before a window's WIS because
    /// their job already won an overlapping interval — or an overlapping
    /// work range — in an earlier window of the same round (counts
    /// variants, not jobs).
    pub cross_window_conflicts: u64,
    /// Windows whose speculative WIS solution was discarded because an
    /// earlier window's acceptances touched their eligible pool.
    pub wis_replays: u64,
    /// Wall time of the batched scoring pass.
    pub scoring_ns: u64,
    /// Wall time of the WIS + reconciliation pass.
    pub clearing_ns: u64,
}

/// One accepted variant, handed to the caller's `on_accept` sink in
/// reconciliation (= commitment) order.
#[derive(Debug, Clone, Copy)]
pub struct Accepted<'a> {
    /// Row of the variant in the union pool.
    pub row: usize,
    /// The accepted variant.
    pub variant: &'a Variant,
    /// Composite score at selection time.
    pub score: f64,
    /// The announced window it was accepted into.
    pub window: &'a Window,
}

/// Cross-window reconciliation predicate (§4.1): true if `v`'s job
/// already won a temporally overlapping reservation — or an overlapping
/// work range `(w0, w1)` — earlier in this round. Public because the
/// coordinator's cross-*shard* reconciler applies the identical rule
/// between leader shards — one predicate, so the two layers can never
/// disagree on what a conflict is.
pub fn conflicts_with_accepted(accepted: &[(JobId, Interval, f64, f64)], v: &Variant) -> bool {
    accepted.iter().any(|&(job, iv, w0, w1)| {
        job == v.job
            && (iv.overlaps(&v.interval)
                || (v.work_offset < w1 - 1e-9 && w0 < v.work_offset + v.work - 1e-9))
    })
}

/// The shared K-window clearing core (steps 4a–4b of Algorithm 1,
/// generalized): batched scoring, speculative per-window WIS, sequential
/// cross-window reconciliation. Owns every scratch buffer, so the hot
/// path allocates nothing in the steady state wherever the engine is
/// embedded.
#[derive(Default)]
pub struct ClearingEngine {
    /// Reused scoring batch and output.
    batch: ScoreBatch,
    scored: ScoreOutput,
    /// Per-window WIS items and their pool-row mapping.
    items: Vec<Vec<WisItem>>,
    item_rows: Vec<Vec<usize>>,
    /// Speculative per-window WIS solutions.
    solutions: Vec<WisSolution>,
    /// Accepted (job, interval, work range) tuples for reconciliation.
    accepted: Vec<(JobId, Interval, f64, f64)>,
    /// Filtered WIS input for conflict replays.
    replay_items: Vec<WisItem>,
    replay_rows: Vec<usize>,
}

impl ClearingEngine {
    /// Create an engine with empty scratch buffers.
    pub fn new() -> Self {
        Self::default()
    }

    /// Clear one decision round: score the union bid `pool` across the
    /// announced `windows` (rows of window `w` are
    /// `window_rows[w].0..window_rows[w].1`), solve each window's WIS,
    /// and reconcile in announcement order. `row_ctx` supplies the
    /// caller-owned age/trust/history context per row; `on_accept`
    /// receives every accepted variant in commitment order.
    ///
    /// With a single announced window the batch carries the uniform
    /// scalar capacity and the reconciliation filter never fires — K = 1
    /// stays bit-identical to the paper's single-window loop. Results
    /// are bit-identical at any pool budget (the speculative WIS merge
    /// re-solves exactly like the sequential path on conflict).
    #[allow(clippy::too_many_arguments)]
    pub fn clear(
        &mut self,
        cfg: &JasdaConfig,
        windows: &[Window],
        window_rows: &[(usize, usize)],
        pool: &[Variant],
        row_ctx: &mut dyn FnMut(&Variant) -> RowCtx,
        scorer: &mut dyn ScorerBackend,
        workers: &WorkerPool,
        on_accept: &mut dyn FnMut(Accepted<'_>),
    ) -> ClearStats {
        debug_assert_eq!(windows.len(), window_rows.len());
        let mut stats = ClearStats::default();
        if windows.is_empty() || pool.is_empty() {
            return stats;
        }

        // Step 4a: one batched composite-scoring pass across all windows
        // (Eq. (4) + calibration + age; per-row capacities when K > 1),
        // into the reused output, row space chunked across the pool.
        let t0 = std::time::Instant::now();
        self.batch.clear();
        self.batch.t = cfg.fmp_bins;
        self.batch.capacity = windows[0].capacity_gb as f32;
        self.batch.theta = cfg.theta as f32;
        self.batch.lambda = cfg.lambda as f32;
        let alpha = cfg.alpha.as_array();
        let beta = cfg.beta.as_array();
        self.batch.alpha =
            [alpha[0] as f32, alpha[1] as f32, alpha[2] as f32, alpha[3] as f32];
        self.batch.beta = [beta[0] as f32, beta[1] as f32, beta[2] as f32, beta[3] as f32];
        for v in pool {
            let ctx = row_ctx(v);
            let phi =
                [v.declared.phi[0], v.declared.phi[1], v.declared.phi[2], v.declared.phi[3]];
            self.batch.push(
                &v.fmp.mu,
                &v.fmp.sigma,
                phi,
                [v.sys.util, v.sys.frag, ctx.age],
                ctx.trust,
                ctx.hist,
            );
        }
        if windows.len() > 1 {
            for (w, &(start, end)) in windows.iter().zip(window_rows) {
                self.batch
                    .row_capacity
                    .extend(std::iter::repeat(w.capacity_gb as f32).take(end - start));
            }
            debug_assert_eq!(self.batch.row_capacity.len(), pool.len());
        }
        scorer
            .score_into_pooled(&self.batch, &mut self.scored, workers)
            .expect("scoring backend failed");
        stats.scoring_ns = t0.elapsed().as_nanos() as u64;

        // Step 4b: optimal per-window clearing (WIS) with cross-window
        // reconciliation (§4.1 atomicity): within one decision round a
        // job must never hold two temporally overlapping reservations on
        // different slices, nor win the *same work chunk* twice — every
        // window's chains start at the job's unchanged work cursor, so
        // without the work-range check a job could commit chunk
        // [cursor, cursor+w) on two slices and the second reservation
        // would execute no work while still blocking its slice. Windows
        // clear in announcement order (= policy preference order).
        //
        // Parallel form: each window's WIS is solved speculatively over
        // its *unfiltered* eligible items; the merge then walks windows
        // sequentially in announcement order. A window none of whose
        // eligible items conflict with earlier acceptances has a
        // filtered pool identical to the unfiltered one, so its
        // speculative solution is exact; otherwise the solution is
        // discarded and re-solved on the filtered pool — exactly the
        // sequential algorithm.
        let t1 = std::time::Instant::now();
        let n_windows = windows.len();
        if self.items.len() < n_windows {
            self.items.resize_with(n_windows, Vec::new);
            self.item_rows.resize_with(n_windows, Vec::new);
        }
        let mut total_items = 0usize;
        for widx in 0..n_windows {
            self.items[widx].clear();
            self.item_rows[widx].clear();
            let window = windows[widx];
            let wlen = window.delta_t().max(1) as f64;
            let (row0, row1) = window_rows[widx];
            for i in row0..row1 {
                if !self.scored.eligible[i] || self.scored.score[i] <= 0.0 {
                    continue;
                }
                let v = &pool[i];
                // Optional duration weighting (EXPERIMENTS.md F6): under
                // the paper's plain sum objective, many short variants
                // dominate few long ones; weighting by window share makes
                // the objective score-weighted busy time.
                let w = if cfg.duration_weighted_clearing {
                    v.duration() as f64 / wlen
                } else {
                    1.0
                };
                self.items[widx].push(WisItem {
                    interval: v.interval,
                    score: self.scored.score[i] as f64 * w,
                });
                self.item_rows[widx].push(i);
            }
            total_items += self.items[widx].len();
        }

        // Speculative fan-out across windows.
        let speculate = workers.budget() > 1
            && n_windows >= 2
            && total_items >= MIN_WIS_ITEMS_FOR_FANOUT;
        if speculate {
            self.solutions.clear();
            self.solutions
                .resize_with(n_windows, || WisSolution { selected: vec![], total_score: 0.0 });
            let items = &self.items[..n_windows];
            let n_workers = workers_for(workers.budget(), n_windows, 1);
            let chunk = (n_windows + n_workers - 1) / n_workers;
            workers.scope(|scope| {
                let mut rest = self.solutions.as_mut_slice();
                let mut start = 0usize;
                while start < n_windows {
                    let len = chunk.min(n_windows - start);
                    let (sols, r) = rest.split_at_mut(len);
                    let window_items = &items[start..start + len];
                    scope.spawn(move || {
                        for (sol, wi) in sols.iter_mut().zip(window_items) {
                            *sol = select_best_compatible(wi);
                        }
                    });
                    rest = r;
                    start += len;
                }
            });
        }

        // Sequential reconciliation merge in announcement order.
        self.accepted.clear();
        let mut fallback = WisSolution { selected: vec![], total_score: 0.0 };
        for widx in 0..n_windows {
            let window = &windows[widx];
            let mut n_conflicts = 0u64;
            if !self.accepted.is_empty() {
                for &i in &self.item_rows[widx] {
                    if conflicts_with_accepted(&self.accepted, &pool[i]) {
                        n_conflicts += 1;
                    }
                }
            }
            stats.cross_window_conflicts += n_conflicts;

            if n_conflicts == 0 {
                if !speculate {
                    fallback = select_best_compatible(&self.items[widx]);
                }
                let sol = if speculate { &self.solutions[widx] } else { &fallback };
                stats.variants_eligible += self.items[widx].len() as u64;
                for &sel in &sol.selected {
                    let i = self.item_rows[widx][sel];
                    let v = &pool[i];
                    self.accepted.push((
                        v.job,
                        v.interval,
                        v.work_offset,
                        v.work_offset + v.work,
                    ));
                    stats.variants_selected += 1;
                    on_accept(Accepted {
                        row: i,
                        variant: v,
                        score: self.scored.score[i] as f64,
                        window,
                    });
                }
            } else {
                // Replay on the filtered pool — the sequential path.
                stats.wis_replays += 1;
                self.replay_items.clear();
                self.replay_rows.clear();
                for k in 0..self.item_rows[widx].len() {
                    let i = self.item_rows[widx][k];
                    if conflicts_with_accepted(&self.accepted, &pool[i]) {
                        continue;
                    }
                    self.replay_items.push(self.items[widx][k]);
                    self.replay_rows.push(i);
                }
                stats.variants_eligible += self.replay_items.len() as u64;
                let sol = select_best_compatible(&self.replay_items);
                for &k in &sol.selected {
                    let i = self.replay_rows[k];
                    let v = &pool[i];
                    self.accepted.push((
                        v.job,
                        v.interval,
                        v.work_offset,
                        v.work_offset + v.work,
                    ));
                    stats.variants_selected += 1;
                    on_accept(Accepted {
                        row: i,
                        variant: v,
                        score: self.scored.score[i] as f64,
                        window,
                    });
                }
            }
        }
        stats.clearing_ns = t1.elapsed().as_nanos() as u64;
        stats
    }
}

/// Exhaustive reference solver for verification (exponential; tests only).
#[cfg(test)]
pub fn brute_force(items: &[WisItem]) -> f64 {
    let m = items.len();
    assert!(m <= 20, "brute force is exponential");
    let mut best = 0.0f64;
    'subset: for mask in 0u32..(1 << m) {
        let mut total = 0.0;
        let mut chosen: Vec<&WisItem> = Vec::new();
        for i in 0..m {
            if mask & (1 << i) != 0 {
                for c in &chosen {
                    if c.interval.overlaps(&items[i].interval) {
                        continue 'subset;
                    }
                }
                chosen.push(&items[i]);
                total += items[i].score;
            }
        }
        if total > best {
            best = total;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(s: u64, e: u64, score: f64) -> WisItem {
        WisItem { interval: Interval::new(s, e), score }
    }

    #[test]
    fn empty_pool() {
        let sol = select_best_compatible(&[]);
        assert!(sol.selected.is_empty());
        assert_eq!(sol.total_score, 0.0);
    }

    #[test]
    fn single_item() {
        let sol = select_best_compatible(&[item(0, 10, 0.7)]);
        assert_eq!(sol.selected, vec![0]);
        assert!((sol.total_score - 0.7).abs() < 1e-12);
    }

    #[test]
    fn paper_worked_example_table3() {
        // Table 3: v_A1=[40,47) score .67, v_A2=[47,50) score .64,
        // v_B1=[40,50) score .72. Optimal = {v_A1, v_A2}, total 1.31.
        let pool = [item(40, 47, 0.67), item(47, 50, 0.64), item(40, 50, 0.72)];
        let sol = select_best_compatible(&pool);
        assert_eq!(sol.selected, vec![0, 1], "must pick the A-chain over B");
        assert!((sol.total_score - 1.31).abs() < 1e-12);
    }

    #[test]
    fn prefers_single_big_when_it_wins() {
        let pool = [item(40, 47, 0.3), item(47, 50, 0.3), item(40, 50, 0.72)];
        let sol = select_best_compatible(&pool);
        assert_eq!(sol.selected, vec![2]);
        assert!((sol.total_score - 0.72).abs() < 1e-12);
    }

    #[test]
    fn back_to_back_is_compatible() {
        let pool = [item(0, 10, 1.0), item(10, 20, 1.0), item(20, 30, 1.0)];
        let sol = select_best_compatible(&pool);
        assert_eq!(sol.selected, vec![0, 1, 2]);
        assert!((sol.total_score - 3.0).abs() < 1e-12);
    }

    #[test]
    fn identical_intervals_take_best() {
        let pool = [item(0, 10, 0.4), item(0, 10, 0.9), item(0, 10, 0.6)];
        let sol = select_best_compatible(&pool);
        assert_eq!(sol.selected, vec![1]);
    }

    #[test]
    fn selected_indices_point_into_input_and_are_start_sorted() {
        let pool = [item(50, 60, 0.5), item(0, 10, 0.5), item(20, 30, 0.5)];
        let sol = select_best_compatible(&pool);
        assert_eq!(sol.selected, vec![1, 2, 0]);
        let starts: Vec<u64> = sol.selected.iter().map(|&i| pool[i].interval.start).collect();
        assert!(starts.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn no_overlap_in_solution() {
        let pool = [
            item(0, 10, 0.9),
            item(5, 15, 0.9),
            item(10, 20, 0.9),
            item(15, 25, 0.9),
            item(20, 30, 0.9),
        ];
        let sol = select_best_compatible(&pool);
        for w in sol.selected.windows(2) {
            assert!(!pool[w[0]].interval.overlaps(&pool[w[1]].interval));
        }
        assert_eq!(sol.selected, vec![0, 2, 4]);
    }

    #[test]
    fn matches_brute_force_exhaustive_random() {
        // Deterministic pseudo-random pools checked against brute force.
        let mut state = 0x1234_5678_9abc_def0u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for trial in 0..200 {
            let n = 1 + (next() % 12) as usize;
            let items: Vec<WisItem> = (0..n)
                .map(|_| {
                    let s = next() % 80;
                    let len = 1 + next() % 30;
                    let score = (next() % 1000) as f64 / 1000.0;
                    item(s, s + len, score)
                })
                .collect();
            let sol = select_best_compatible(&items);
            let best = brute_force(&items);
            assert!(
                (sol.total_score - best).abs() < 1e-9,
                "trial {trial}: dp {} vs brute {best} on {items:?}",
                sol.total_score
            );
            // And the reported selection is consistent + feasible.
            let sum: f64 = sol.selected.iter().map(|&i| items[i].score).sum();
            assert!((sum - sol.total_score).abs() < 1e-9);
            for i in 0..sol.selected.len() {
                for j in (i + 1)..sol.selected.len() {
                    assert!(!items[sol.selected[i]]
                        .interval
                        .overlaps(&items[sol.selected[j]].interval));
                }
            }
        }
    }

    #[test]
    fn large_pool_scales() {
        // 100k items solved quickly — the O(M log M) claim in practice.
        let items: Vec<WisItem> = (0..100_000u64)
            .map(|i| {
                let s = (i * 7919) % 1_000_000;
                item(s, s + 50 + (i % 97), 0.1 + ((i % 89) as f64) / 100.0)
            })
            .collect();
        let t0 = std::time::Instant::now();
        let sol = select_best_compatible(&items);
        assert!(sol.total_score > 0.0);
        assert!(
            t0.elapsed().as_millis() < 2000,
            "100k-item WIS took {:?}",
            t0.elapsed()
        );
    }
}
