//! Window selection policies (paper §3.1 "Window Selection Policy" and
//! §5.1(c) "Adaptive window selection").
//!
//! Each iteration the scheduler announces up to **K** windows
//! (`announce_k`, default 1 = the paper's prototype; per-slice mode
//! announces one per free slice). The selector ranks one candidate at a
//! time in policy order and returns its *index*; the scheduler calls it
//! repeatedly, `swap_remove`-ing each pick (and, per-slice, the picked
//! slice's remaining candidates) between calls. Every policy's comparator is a
//! total order over candidates — ties break on start/length/slice — so
//! selection is independent of candidate-list order and K=1 reproduces
//! the single-window loop exactly. The paper's prototype announces the
//! earliest-starting idle window; the alternatives sketched in §5.1(c)
//! (slack-aware, fragmentation-aware) are implemented too and compared
//! by `benches/fig_window_policy`.

use crate::config::{JasdaConfig, WindowPolicy};
use crate::mig::{Cluster, Window};
use crate::types::{SliceId, Time};

/// How many windows one decision round announces: `announce_k`, or the
/// number of distinct slices with a candidate in per-slice mode. One
/// shared implementation so the in-process scheduler and the
/// coordinator leader can never disagree on the round's K.
pub fn announce_target(cfg: &JasdaConfig, candidates: &[Window]) -> usize {
    if cfg.announce_per_slice {
        let mut slices: Vec<SliceId> = candidates.iter().map(|w| w.slice).collect();
        slices.sort_unstable();
        slices.dedup();
        slices.len().max(1)
    } else {
        cfg.announce_k
    }
}

/// Which leader shard owns a slice: slices are striped round-robin
/// (`slice % shards`), so every stock layout spreads its slice mix
/// across shards instead of handing one shard all the big slices.
/// `shards <= 1` maps everything to shard 0 (the single leader).
pub fn shard_of(slice: SliceId, shards: usize) -> usize {
    (slice as usize) % shards.max(1)
}

/// The round's effective window policy, applying the rolling-repack
/// redirect (§3.5): the paper triggers a defragmentation step "when
/// residual gaps become too small for further allocation". We count
/// idle residues shorter than τ_min across the announce horizon (they
/// can never be allocated); when several have accumulated, announcements
/// are redirected to the most fragmented slice so bids consolidate its
/// gaps. The count comes straight off the per-slice gap indexes.
/// Returns the policy and whether the redirect fired — shared by the
/// scheduler and the coordinator leader for decision parity.
pub fn round_policy(cfg: &JasdaConfig, cluster: &Cluster, now: Time) -> (WindowPolicy, bool) {
    shard_round_policy(cfg, cluster, now, 0, 1)
}

/// [`round_policy`] restricted to the slices one leader shard owns
/// ([`shard_of`]): the repack redirect counts unusable residues over the
/// shard's own slices only, so one fragmented shard redirects its own
/// announcements without dragging its siblings along. With `shards == 1`
/// this is exactly the global [`round_policy`].
pub fn shard_round_policy(
    cfg: &JasdaConfig,
    cluster: &Cluster,
    now: Time,
    shard: usize,
    shards: usize,
) -> (WindowPolicy, bool) {
    if cfg.repack {
        let to = now.saturating_add(cfg.announce_horizon);
        let unusable: usize = cluster
            .slices()
            .iter()
            .filter(|s| shard_of(s.id, shards) == shard)
            .map(|s| s.timeline.count_unusable_residues(now, to, cfg.tau_min))
            .sum();
        if unusable >= 3 {
            return (WindowPolicy::FragmentationAware, true);
        }
    }
    (cfg.window_policy, false)
}

/// Stateful window selector (round-robin needs a cursor; the
/// fragmentation policy keeps a per-slice scratch buffer so selection
/// allocates nothing).
#[derive(Debug, Clone, Default)]
pub struct WindowSelector {
    rr_cursor: usize,
    /// Per-slice fragmentation cache for one `select` call
    /// (fragmentation-aware policy only; NaN = not yet computed).
    frag_scratch: Vec<f64>,
}

impl WindowSelector {
    /// Create a selector.
    pub fn new() -> Self {
        WindowSelector::default()
    }

    /// Pick the window to announce from `candidates` (must be non-empty to
    /// return Some). `now`/`horizon` give the fragmentation scoring span.
    ///
    /// Returns the *index* of the pick into `candidates`, so the caller
    /// can remove it with a direct `swap_remove` instead of re-scanning
    /// the list for the selected window. Every policy's comparator is a
    /// strict total order over distinct candidates, so the pick is
    /// independent of candidate-list order.
    pub fn select(
        &mut self,
        policy: WindowPolicy,
        candidates: &[Window],
        cluster: &Cluster,
        now: Time,
        horizon: u64,
    ) -> Option<usize> {
        if candidates.is_empty() {
            return None;
        }
        match policy {
            WindowPolicy::EarliestStart => candidates
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    a.interval
                        .start
                        .cmp(&b.interval.start)
                        .then(b.delta_t().cmp(&a.delta_t())) // tie: longer first
                        .then(a.slice.cmp(&b.slice))
                })
                .map(|(i, _)| i),
            WindowPolicy::LongestFirst => candidates
                .iter()
                .enumerate()
                .max_by(|(_, a), (_, b)| {
                    a.delta_t()
                        .cmp(&b.delta_t())
                        .then(b.interval.start.cmp(&a.interval.start))
                        .then(b.slice.cmp(&a.slice))
                })
                .map(|(i, _)| i),
            WindowPolicy::SlackAware => candidates
                .iter()
                .enumerate()
                .max_by(|(_, a), (_, b)| {
                    let sa = a.delta_t() as f64 * a.speed;
                    let sb = b.delta_t() as f64 * b.speed;
                    sa.total_cmp(&sb)
                        .then(b.interval.start.cmp(&a.interval.start))
                        .then(b.slice.cmp(&a.slice))
                })
                .map(|(i, _)| i),
            WindowPolicy::FragmentationAware => {
                // Per-slice fragmentation walks that slice's gap index;
                // evaluate it once per distinct slice instead of twice
                // per pairwise comparison, into a reused scratch buffer.
                let to = now.saturating_add(horizon);
                self.frag_scratch.clear();
                self.frag_scratch.resize(cluster.num_slices(), f64::NAN);
                for w in candidates {
                    let s = w.slice as usize;
                    if self.frag_scratch[s].is_nan() {
                        self.frag_scratch[s] =
                            cluster.slice(w.slice).timeline.fragmentation(now, to);
                    }
                }
                let frag = &self.frag_scratch;
                candidates
                    .iter()
                    .enumerate()
                    .max_by(|(_, a), (_, b)| {
                        frag[a.slice as usize]
                            .total_cmp(&frag[b.slice as usize])
                            .then(b.interval.start.cmp(&a.interval.start))
                            .then(b.slice.cmp(&a.slice))
                    })
                    .map(|(i, _)| i)
            }
            WindowPolicy::RoundRobin => {
                // Advance over slices until one with a candidate is found.
                let n_slices = cluster.num_slices();
                for step in 0..n_slices {
                    let slice = ((self.rr_cursor + step) % n_slices) as u32;
                    if let Some((i, _)) = candidates
                        .iter()
                        .enumerate()
                        .filter(|(_, w)| w.slice == slice)
                        .min_by_key(|(_, w)| w.interval.start)
                    {
                        self.rr_cursor = (slice as usize + 1) % n_slices;
                        return Some(i);
                    }
                }
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::{PartitionLayout, Reservation};
    use crate::types::Interval;

    fn w(slice: u32, start: u64, len: u64, speed: f64) -> Window {
        Window {
            slice,
            capacity_gb: 10.0,
            speed,
            interval: Interval::new(start, start + len),
        }
    }

    fn cluster() -> Cluster {
        Cluster::new(1, &PartitionLayout::seven_small())
    }

    #[test]
    fn empty_candidates_none() {
        let mut s = WindowSelector::new();
        assert!(s
            .select(WindowPolicy::EarliestStart, &[], &cluster(), 0, 1000)
            .is_none());
    }

    #[test]
    fn earliest_start_picks_min_start_then_longest() {
        let mut s = WindowSelector::new();
        let c = cluster();
        let cands = [w(0, 50, 10, 1.0), w(1, 20, 10, 1.0), w(2, 20, 40, 1.0)];
        let got = s.select(WindowPolicy::EarliestStart, &cands, &c, 0, 1000).unwrap();
        assert_eq!(cands[got].slice, 2, "tie on start=20 broken by longer window");
    }

    #[test]
    fn longest_first() {
        let mut s = WindowSelector::new();
        let c = cluster();
        let cands = [w(0, 0, 100, 1.0), w(1, 5, 300, 1.0), w(2, 10, 200, 1.0)];
        let got = s.select(WindowPolicy::LongestFirst, &cands, &c, 0, 1000).unwrap();
        assert_eq!(cands[got].slice, 1);
    }

    #[test]
    fn slack_aware_weights_speed() {
        let mut s = WindowSelector::new();
        let c = cluster();
        // 100 ticks at speed 1.0 beats 300 ticks at 1/7.
        let cands = [w(0, 0, 300, 1.0 / 7.0), w(1, 0, 100, 1.0)];
        let got = s.select(WindowPolicy::SlackAware, &cands, &c, 0, 1000).unwrap();
        assert_eq!(cands[got].slice, 1);
    }

    #[test]
    fn fragmentation_aware_prefers_shattered_slice() {
        let mut c = cluster();
        // Slice 0: two reservations -> fragmented idle. Slice 1: empty.
        c.slice_mut(0)
            .timeline
            .reserve(Reservation { job: 1, subjob_seq: 0, interval: Interval::new(100, 200) })
            .unwrap();
        c.slice_mut(0)
            .timeline
            .reserve(Reservation { job: 1, subjob_seq: 1, interval: Interval::new(400, 500) })
            .unwrap();
        let cands = [w(0, 0, 100, 1.0 / 7.0), w(1, 0, 1000, 1.0 / 7.0)];
        let mut s = WindowSelector::new();
        let got =
            s.select(WindowPolicy::FragmentationAware, &cands, &c, 0, 1000).unwrap();
        assert_eq!(cands[got].slice, 0);
    }

    #[test]
    fn round_robin_cycles() {
        let c = cluster();
        let cands =
            [w(0, 0, 100, 1.0), w(2, 0, 100, 1.0), w(5, 0, 100, 1.0)];
        let mut s = WindowSelector::new();
        let picks: Vec<u32> = (0..6)
            .map(|_| {
                let i = s.select(WindowPolicy::RoundRobin, &cands, &c, 0, 1000).unwrap();
                cands[i].slice
            })
            .collect();
        assert_eq!(picks, vec![0, 2, 5, 0, 2, 5]);
    }

    #[test]
    fn round_robin_earliest_within_slice() {
        let c = cluster();
        let cands = [w(0, 500, 100, 1.0), w(0, 100, 100, 1.0)];
        let mut s = WindowSelector::new();
        let got = s.select(WindowPolicy::RoundRobin, &cands, &c, 0, 1000).unwrap();
        assert_eq!(cands[got].interval.start, 100);
    }
}
