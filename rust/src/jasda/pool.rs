//! Persistent worker pool for the clearing pipeline's fan-out stages.
//!
//! §Perf iteration 2 parallelized the scheduler's generate/score/WIS
//! stages with [`std::thread::scope`], which spawns (and joins) fresh OS
//! threads on **every** iteration — the per-iteration spawn cost the
//! bench sweeps flagged as the remaining lever. [`WorkerPool`] replaces
//! that: a fixed set of worker threads is spawned **once per run** (one
//! pool per [`JasdaScheduler`](crate::jasda::JasdaScheduler) /
//! [`run_protocol`](crate::coordinator::run_protocol) leader) and every
//! fan-out stage feeds it task chunks through a channel.
//!
//! # Bit-identity
//!
//! [`WorkerPool::scope`] mirrors the `std::thread::scope` contract: tasks
//! may borrow from the enclosing frame, and `scope` does not return until
//! every spawned task has finished. Callers keep the exact chunking they
//! used with scoped threads (disjoint `split_at_mut` output slices, same
//! worker-count formula), so which OS thread executes a chunk can never
//! change a result — the pool is purely a latency knob, like
//! `jasda.parallel` itself. A pool built with a budget of 1 spawns no
//! threads at all and runs every task inline on the caller.
//!
//! # Panic behavior
//!
//! A panicking task does not kill its worker (the pool stays usable);
//! the panic is surfaced by making the owning `scope` call panic after
//! all of its tasks have drained, matching `std::thread::scope`'s
//! fail-fast observability without poisoning the pool.

use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A unit of work shipped to a worker thread. Lifetimes are erased on
/// submission; soundness is restored by [`WorkerPool::scope`]'s
/// wait-before-return barrier.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// Workers to use for `work` items given a concurrency budget and a
/// minimum batch per worker (always at least 1). Shared by every fan-out
/// stage so chunking — and therefore output — is identical whichever
/// mechanism (scoped threads or pool) executes the chunks.
pub fn workers_for(budget: usize, work: usize, min_per: usize) -> usize {
    budget.min(work / min_per.max(1)).max(1)
}

/// Completion tracking for one `scope` call.
struct ScopeSync {
    state: Mutex<ScopeState>,
    done: Condvar,
}

struct ScopeState {
    /// Tasks submitted but not yet finished.
    pending: usize,
    /// Whether any task panicked.
    panicked: bool,
}

/// A persistent pool of worker threads with a scoped-task API.
///
/// Construct once with the resolved `jasda.parallel` budget and reuse for
/// the lifetime of the scheduler/leader; [`Drop`] shuts the workers down.
pub struct WorkerPool {
    /// Resolved worker budget (≥ 1; 1 = fully serial, no threads).
    budget: usize,
    /// Work queue; `None` for a serial pool.
    tx: Option<mpsc::Sender<Task>>,
    workers: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("budget", &self.budget).finish()
    }
}

impl WorkerPool {
    /// Spawn a pool of `budget` workers (`budget <= 1` spawns none and
    /// runs tasks inline). `budget` is the number of chunks that can
    /// execute concurrently — the same quantity the scoped-thread code
    /// paths called their thread budget.
    pub fn new(budget: usize) -> Self {
        let budget = budget.max(1);
        if budget == 1 {
            return WorkerPool { budget, tx: None, workers: Vec::new() };
        }
        let (tx, rx) = mpsc::channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..budget)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    // Hold the lock only while dequeuing, never while
                    // running a task.
                    let task = match rx.lock().unwrap().recv() {
                        Ok(t) => t,
                        Err(_) => return, // pool dropped
                    };
                    task();
                })
            })
            .collect();
        WorkerPool { budget, tx: Some(tx), workers }
    }

    /// Resolve a `jasda.parallel` config value (0 = autodetect) to a
    /// concrete worker budget, without building a pool. The sharded
    /// coordinator splits this total across its per-shard pools.
    pub fn resolve_budget(parallel: usize) -> usize {
        if parallel > 0 {
            parallel
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }

    /// Resolve a `jasda.parallel` config value (0 = autodetect) and build
    /// the pool.
    pub fn from_config(parallel: usize) -> Self {
        Self::new(Self::resolve_budget(parallel))
    }

    /// The pool's concurrency budget (what the scoped-thread paths called
    /// their thread count).
    #[inline]
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Run `f` with a [`PoolScope`] on which borrowed tasks can be
    /// spawned; returns only after every spawned task has finished —
    /// the same structural guarantee as [`std::thread::scope`].
    ///
    /// Panics (after draining) if any task panicked; a panic in `f`
    /// itself also drains before propagating, so borrowed data is never
    /// left aliased by a still-running task.
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: for<'scope> FnOnce(&'scope PoolScope<'scope, 'env>) -> R,
    {
        let scope = PoolScope {
            pool: self,
            sync: Arc::new(ScopeSync {
                state: Mutex::new(ScopeState { pending: 0, panicked: false }),
                done: Condvar::new(),
            }),
            env: PhantomData,
        };
        let result = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        // Barrier: every spawned task must finish before any borrow of
        // 'env can end. This runs on the success AND the panic path.
        let mut st = scope.sync.state.lock().unwrap();
        while st.pending > 0 {
            st = scope.sync.done.wait(st).unwrap();
        }
        let task_panicked = st.panicked;
        drop(st);
        match result {
            Err(payload) => resume_unwind(payload),
            Ok(r) => {
                if task_panicked {
                    panic!("a WorkerPool task panicked");
                }
                r
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the channel makes every worker's `recv` fail and exit.
        drop(self.tx.take());
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Spawn handle passed to the closure of [`WorkerPool::scope`]. `'env`
/// is the lifetime of borrows the tasks may capture (invariant, exactly
/// like [`std::thread::Scope`]).
pub struct PoolScope<'pool, 'env> {
    pool: &'pool WorkerPool,
    sync: Arc<ScopeSync>,
    env: PhantomData<&'env mut &'env ()>,
}

impl<'pool, 'env> PoolScope<'pool, 'env> {
    /// Submit a task that may borrow from `'env`. On a serial pool the
    /// task runs inline, immediately.
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        let tx = match &self.pool.tx {
            None => {
                f();
                return;
            }
            Some(tx) => tx,
        };
        self.sync.state.lock().unwrap().pending += 1;
        let sync = Arc::clone(&self.sync);
        let task: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            let outcome = catch_unwind(AssertUnwindSafe(f));
            let mut st = sync.state.lock().unwrap();
            st.pending -= 1;
            if outcome.is_err() {
                st.panicked = true;
            }
            sync.done.notify_all();
        });
        // SAFETY: erasing 'env to 'static is sound because
        // `WorkerPool::scope` blocks until `pending == 0` before
        // returning (on both the normal and the unwind path), so the
        // task — and everything it borrows — cannot outlive 'env.
        let task: Task = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Task>(task)
        };
        if let Err(mpsc::SendError(task)) = tx.send(task) {
            // Unreachable in practice (the pool outlives its scopes);
            // run inline so the barrier still balances.
            task();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Chunked parallel sum through the pool equals the serial sum —
    /// the disjoint-output pattern every production call site uses.
    fn chunked_sum(pool: &WorkerPool, data: &[u64], workers: usize) -> u64 {
        let mut partial = vec![0u64; workers.max(1)];
        let chunk = (data.len() + workers.max(1) - 1) / workers.max(1);
        pool.scope(|s| {
            let mut rest = partial.as_mut_slice();
            let mut start = 0usize;
            while start < data.len() {
                let len = chunk.min(data.len() - start);
                let (out, r) = rest.split_at_mut(1);
                let slice = &data[start..start + len];
                s.spawn(move || out[0] = slice.iter().sum());
                rest = r;
                start += len;
            }
        });
        partial.iter().sum()
    }

    #[test]
    fn pool_matches_serial_sum() {
        let data: Vec<u64> = (0..10_000).map(|i| i * 7 + 3).collect();
        let serial: u64 = data.iter().sum();
        for budget in [1usize, 2, 4, 8] {
            let pool = WorkerPool::new(budget);
            for workers in [1usize, 2, 3, budget] {
                assert_eq!(chunked_sum(&pool, &data, workers), serial, "budget={budget}");
            }
        }
    }

    #[test]
    fn pool_is_reusable_across_scopes() {
        let pool = WorkerPool::new(3);
        let counter = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.scope(|s| {
                for _ in 0..4 {
                    s.spawn(|| {
                        counter.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
        }
        assert_eq!(counter.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.budget(), 1);
        let mut x = 0;
        pool.scope(|s| s.spawn(|| x += 1));
        assert_eq!(x, 1);
    }

    #[test]
    fn scope_returns_closure_value() {
        let pool = WorkerPool::new(2);
        let v = pool.scope(|s| {
            s.spawn(|| {});
            42
        });
        assert_eq!(v, 42);
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|s| {
                s.spawn(|| panic!("boom"));
            });
        }));
        assert!(r.is_err(), "scope must surface a task panic");
        // The pool is still usable afterwards.
        let data: Vec<u64> = (0..100).collect();
        assert_eq!(chunked_sum(&pool, &data, 2), data.iter().sum::<u64>());
    }

    #[test]
    fn from_config_resolves_autodetect() {
        assert!(WorkerPool::from_config(0).budget() >= 1);
        assert_eq!(WorkerPool::from_config(5).budget(), 5);
    }

    #[test]
    fn scope_waits_for_all_tasks() {
        // If scope returned early, the flags would still be false.
        let pool = WorkerPool::new(4);
        let flags: Vec<AtomicUsize> = (0..16).map(|_| AtomicUsize::new(0)).collect();
        pool.scope(|s| {
            for f in &flags {
                s.spawn(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    f.store(1, Ordering::SeqCst);
                });
            }
        });
        assert!(flags.iter().all(|f| f.load(Ordering::SeqCst) == 1));
    }
}
