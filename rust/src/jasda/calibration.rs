//! Ex-ante calibration, ex-post verification, and reliability feedback
//! (paper §4.2.1, Eqs. (5)–(8)).
//!
//! Per job the scheduler maintains:
//! * `HistAvg(J)` — an exponentially weighted moving average of *verified*
//!   job-side scores (scores recomputed from observed features), used as
//!   the smoothing anchor in Eq. (5);
//! * the expected per-variant error `E_v[ε(v)]` (Eq. (7)), a running mean
//!   of convex per-feature deviations (Eq. (6));
//! * the reliability coefficient `ρ_J = exp(−κ·E_v[ε(v)])` (Eq. (8)).
//!
//! The scheduler folds `ρ_J` into the calibration weight: the declared
//! utility enters the composite score as
//! `ĥ = (γ·ρ_J)·h̃ + (1 − γ·ρ_J)·HistAvg(J)` — the "feedback and
//! long-term stability" variant described at the end of §4.2.1.

use crate::sim::SubjobRecord;

/// Per-job trust state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobTrust {
    /// EWMA of verified job-side scores (HistAvg in Eq. (5)).
    pub hist_avg: f64,
    /// Running mean of per-variant errors ε(v) (Eq. (7)).
    pub mean_error: f64,
    /// Number of verified variants |V_J^verified|.
    pub verified: u64,
    /// Reliability ρ_J ∈ (0,1] (Eq. (8)).
    pub rho: f64,
}

impl Default for JobTrust {
    fn default() -> Self {
        // Neutral prior: no history, full trust, mid-scale anchor.
        JobTrust { hist_avg: 0.5, mean_error: 0.0, verified: 0, rho: 1.0 }
    }
}

/// Calibration engine shared by all of a scheduler's jobs.
#[derive(Debug, Clone)]
pub struct Calibration {
    /// Reliability sensitivity κ (Eq. (8)).
    kappa: f64,
    /// Ex-ante smoothing γ (Eq. (5)).
    gamma: f64,
    /// α-derived feature weights w_i for the convex error (Eq. (6));
    /// normalized to sum to 1.
    w: [f64; 4],
    /// EWMA rate for HistAvg (adaptability/stability trade-off the paper
    /// leaves open; 0.25 favors adaptation).
    ewma: f64,
    /// Keyed by [`crate::types::JobId`]: trace workloads may carry
    /// sparse, non-zero-based ids. Jobs without verified history read as
    /// the neutral default. HashMap keeps the per-variant hot-path
    /// lookups (trust_weight/hist_avg in score_pool) O(1); the one
    /// aggregate consumer, [`Calibration::mean_rho`], sorts before
    /// summing so diagnostics stay deterministic.
    per_job: std::collections::HashMap<u32, JobTrust>,
}

impl Calibration {
    /// Build with policy parameters `kappa`, `gamma` and job-side weights
    /// `alpha` (normalized into the error weights w_i). `_n_jobs` is kept
    /// for API stability; trust states materialize lazily per job id.
    pub fn new(_n_jobs: usize, kappa: f64, gamma: f64, alpha: [f64; 4]) -> Self {
        let s: f64 = alpha.iter().sum();
        let w = if s > 0.0 {
            [alpha[0] / s, alpha[1] / s, alpha[2] / s, alpha[3] / s]
        } else {
            [0.25; 4]
        };
        Calibration { kappa, gamma, w, ewma: 0.25, per_job: Default::default() }
    }

    /// Trust state of a job (the neutral prior until verified history).
    pub fn trust(&self, job: u32) -> JobTrust {
        self.per_job.get(&job).copied().unwrap_or_default()
    }

    /// Calibration weight `γ·ρ_J` the scoring pipeline applies to the
    /// declared utility (Eq. (5) with reliability feedback).
    pub fn trust_weight(&self, job: u32) -> f64 {
        self.gamma * self.trust(job).rho
    }

    /// Historical anchor HistAvg(J).
    pub fn hist_avg(&self, job: u32) -> f64 {
        self.trust(job).hist_avg
    }

    /// Per-variant error ε(v) = Σ w_i |φ_i − φ_i^observed| (Eqs. (6)–(7)
    /// inner term). Bounded in [0,1] by convexity.
    pub fn variant_error(&self, declared: &[f64; 4], observed: &[f64; 4]) -> f64 {
        declared
            .iter()
            .zip(observed)
            .zip(&self.w)
            .map(|((d, o), w)| w * (d - o).abs())
            .sum()
    }

    /// Ex-post verification of a completed subjob (Eqs. (6)–(8)): update
    /// the job's error statistics, reliability, and HistAvg.
    /// `h_observed` is the job-side score recomputed from observed
    /// features (the "verified score" anchoring HistAvg).
    pub fn verify(&mut self, job: u32, declared: &[f64; 4], observed: &[f64; 4], h_observed: f64) {
        let eps = self.variant_error(declared, observed);
        let t = self.per_job.entry(job).or_default();
        t.verified += 1;
        // Running mean of ε(v) — exactly Eq. (7).
        t.mean_error += (eps - t.mean_error) / t.verified as f64;
        // Eq. (8).
        t.rho = (-self.kappa * t.mean_error).exp();
        // HistAvg: EWMA of verified scores.
        t.hist_avg += self.ewma * (h_observed - t.hist_avg);
    }

    /// Convenience: verify from an engine [`SubjobRecord`], computing the
    /// observed job-side score with the given α weights.
    pub fn verify_record(&mut self, rec: &SubjobRecord, alpha: &[f64; 4]) {
        let declared = [
            rec.declared_phi[0],
            rec.declared_phi[1],
            rec.declared_phi[2],
            rec.declared_phi[3],
        ];
        let observed = [
            rec.observed_phi[0],
            rec.observed_phi[1],
            rec.observed_phi[2],
            rec.observed_phi[3],
        ];
        let h_obs: f64 = alpha.iter().zip(&observed).map(|(a, o)| a * o).sum();
        self.verify(rec.job, &declared, &observed, h_obs);
    }

    /// Mean reliability across jobs with history (diagnostics).
    pub fn mean_rho(&self) -> f64 {
        let mut with: Vec<f64> =
            self.per_job.values().filter(|t| t.verified > 0).map(|t| t.rho).collect();
        if with.is_empty() {
            return 1.0;
        }
        // HashMap iteration order is arbitrary; summing in sorted order
        // keeps the reported float bit-stable across runs.
        with.sort_by(f64::total_cmp);
        with.iter().sum::<f64>() / with.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cal() -> Calibration {
        Calibration::new(3, 4.0, 0.7, [0.45, 0.25, 0.15, 0.15])
    }

    #[test]
    fn error_weights_normalized() {
        let c = cal();
        let s: f64 = c.w.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
        // Degenerate alpha falls back to uniform.
        let c0 = Calibration::new(1, 1.0, 0.5, [0.0; 4]);
        assert_eq!(c0.w, [0.25; 4]);
    }

    #[test]
    fn variant_error_bounds() {
        let c = cal();
        assert_eq!(c.variant_error(&[0.5; 4], &[0.5; 4]), 0.0);
        let e = c.variant_error(&[1.0; 4], &[0.0; 4]);
        assert!((e - 1.0).abs() < 1e-12, "max error is 1 by convexity");
        let e = c.variant_error(&[0.8, 0.5, 0.5, 0.5], &[0.4, 0.5, 0.5, 0.5]);
        assert!((e - 0.45 * 0.4).abs() < 1e-12);
    }

    #[test]
    fn honest_job_keeps_full_trust() {
        let mut c = cal();
        for _ in 0..20 {
            c.verify(0, &[0.6, 1.0, 0.4, 0.5], &[0.6, 1.0, 0.4, 0.5], 0.55);
        }
        let t = c.trust(0);
        assert_eq!(t.verified, 20);
        assert_eq!(t.mean_error, 0.0);
        assert_eq!(t.rho, 1.0);
        assert!((c.trust_weight(0) - 0.7).abs() < 1e-12, "gamma*1");
        // HistAvg converges toward the verified score.
        assert!((t.hist_avg - 0.55).abs() < 0.01);
    }

    #[test]
    fn misreporter_loses_trust_monotonically() {
        let mut c = cal();
        let mut rhos = vec![c.trust(1).rho];
        for _ in 0..10 {
            // Declares 0.9 on features that realize at 0.4.
            c.verify(1, &[0.9, 1.0, 0.9, 0.5], &[0.4, 1.0, 0.4, 0.5], 0.35);
            rhos.push(c.trust(1).rho);
        }
        assert!(rhos.windows(2).all(|w| w[1] <= w[0] + 1e-12), "{rhos:?}");
        let t = c.trust(1);
        assert!(t.rho < 0.5, "rho should decay well below 1, got {}", t.rho);
        assert!(t.rho > 0.0, "rho stays in (0,1]");
        // Expected error = .45*.5 + .15*.5 = 0.30 -> rho = exp(-1.2)
        assert!((t.mean_error - 0.30).abs() < 1e-9);
        assert!((t.rho - (-4.0f64 * 0.30).exp()).abs() < 1e-9);
    }

    #[test]
    fn recovery_after_honesty() {
        let mut c = cal();
        for _ in 0..5 {
            c.verify(2, &[0.9, 0.5, 0.9, 0.5], &[0.1, 0.5, 0.1, 0.5], 0.1);
        }
        let low = c.trust(2).rho;
        for _ in 0..50 {
            c.verify(2, &[0.5, 0.5, 0.5, 0.5], &[0.5, 0.5, 0.5, 0.5], 0.5);
        }
        let recovered = c.trust(2).rho;
        assert!(recovered > low, "honest behavior must rebuild trust: {low} -> {recovered}");
    }

    #[test]
    fn sparse_job_ids_supported() {
        // Ids far beyond the constructed population must work (trace
        // workloads are not dense); unverified ids read the neutral prior.
        let mut c = cal();
        assert_eq!(c.trust(1_000_000).rho, 1.0);
        c.verify(1_000_000, &[0.9; 4], &[0.1; 4], 0.1);
        assert!(c.trust(1_000_000).rho < 1.0);
        assert_eq!(c.trust(999_999).rho, 1.0, "neighbor untouched");
    }

    #[test]
    fn mean_rho_ignores_unverified() {
        let mut c = cal();
        assert_eq!(c.mean_rho(), 1.0);
        c.verify(0, &[0.9; 4], &[0.1; 4], 0.1);
        let m = c.mean_rho();
        assert!(m < 1.0);
        assert!((m - c.trust(0).rho).abs() < 1e-12, "only job 0 has history");
    }

    #[test]
    fn verify_record_path() {
        use crate::types::Interval;
        let mut c = cal();
        let rec = SubjobRecord {
            job: 1,
            slice: 0,
            subjob_seq: 0,
            reserved: Interval::new(0, 100),
            realized_end: 90,
            planned_work: 50.0,
            realized_work: 50.0,
            declared_phi: [0.8, 1.0, 0.6, 0.5],
            observed_phi: [0.8, 1.0, 0.6, 0.5],
            committed_at: 0,
        };
        c.verify_record(&rec, &[0.45, 0.25, 0.15, 0.15]);
        assert_eq!(c.trust(1).verified, 1);
        assert_eq!(c.trust(1).rho, 1.0);
        let h_obs = 0.45 * 0.8 + 0.25 + 0.15 * 0.6 + 0.15 * 0.5;
        assert!((c.trust(1).hist_avg - (0.5 + 0.25 * (h_obs - 0.5))).abs() < 1e-12);
    }
}
