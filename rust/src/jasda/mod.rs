//! The JASDA core: the paper's contribution.
//!
//! * [`window`] — window announcement policies (§3.1, §5.1(c));
//! * [`scoring`] — the normalized composite scoring pipeline (§4.2) and
//!   the pluggable backend abstraction (native mirror vs PJRT artifact);
//!   batches may span several announced windows via per-row capacities;
//! * [`calibration`] — ex-ante calibration, ex-post verification, and
//!   reliability feedback (§4.2.1);
//! * [`clearing`] — optimal per-window WIS selection (§4.4);
//! * [`scheduler`] — the full interaction cycle (Algorithm 1),
//!   generalized to **K windows per iteration**: `announce_k` windows
//!   (or one per free slice with `announce_per_slice`) are announced and
//!   cleared each round, with one batched scoring pass over the union
//!   bid pool and a cross-window reconciliation step that keeps a job
//!   from holding overlapping reservations on different slices. The
//!   default K = 1 is bit-identical to the paper's single-window loop.
//!   Since §Perf iteration 2 the loop runs as an amortized-incremental
//!   pipeline: candidate windows come off the cluster's persistent gap
//!   indexes, variant generation reuses shape-keyed plans through a
//!   bidder index, and the generate/score/WIS stages fan out across
//!   worker threads (`jasda.parallel`) while the reconciliation merge
//!   stays sequential — outcomes are bit-identical at any thread count;
//! * [`pool`] — the persistent [`WorkerPool`](pool::WorkerPool) those
//!   fan-out stages run on (spawned once per scheduler/leader, no
//!   per-iteration thread spawns).
//!
//! The scoring + WIS + reconciliation core lives in
//! [`clearing::ClearingEngine`] and is shared with the message-passing
//! [`coordinator`](crate::coordinator) runtime, which drives the same
//! engine from protocol bids instead of in-process generation.

pub mod calibration;
pub mod clearing;
pub mod pool;
pub mod scheduler;
pub mod scoring;
pub mod window;

pub use calibration::{Calibration, JobTrust};
pub use clearing::{select_best_compatible, ClearingEngine, WisItem, WisSolution};
pub use pool::WorkerPool;
pub use scheduler::JasdaScheduler;
pub use scoring::{NativeScorer, ScoreBatch, ScoreOutput, ScorerBackend};
pub use window::WindowSelector;
