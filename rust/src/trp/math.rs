//! Small numerics used by the TRP/FMP layer.
//!
//! The normal CDF is implemented with the Abramowitz–Stegun 7.1.26 erf
//! approximation (|error| < 1.5e-7). The **same polynomial** is used in
//! the L1 Pallas kernel (`python/compile/kernels/scoring.py`) and the jnp
//! oracle (`ref.py`) so that the rust-native scorer, the PJRT-executed
//! scorer, and the python reference agree to ~1e-6 — tighter than any
//! scheduling decision threshold.

/// erf(x) via Abramowitz–Stegun 7.1.26 (max abs error 1.5e-7).
pub fn erf(x: f64) -> f64 {
    const A1: f64 = 0.254829592;
    const A2: f64 = -0.284496736;
    const A3: f64 = 1.421413741;
    const A4: f64 = -1.453152027;
    const A5: f64 = 1.061405429;
    const P: f64 = 0.3275911;

    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + P * x);
    let y = 1.0 - (((((A5 * t + A4) * t) + A3) * t + A2) * t + A1) * t * (-x * x).exp();
    sign * y
}

/// Standard normal CDF Φ(x).
#[inline]
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Standard normal quantile (inverse CDF), Acklam's algorithm
/// (relative error < 1.15e-9). Valid for p in (0, 1).
pub fn normal_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "quantile needs p in (0,1), got {p}");
    // Coefficients for the rational approximations.
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Numerically safe `ln(Φ(x))` for the log-space survival product used by
/// the FMP safety bound. For very negative x we use the asymptotic tail
/// expansion to avoid `ln(0)`.
pub fn log_normal_cdf(x: f64) -> f64 {
    if x > -8.0 {
        normal_cdf(x).max(1e-300).ln()
    } else {
        // ln Φ(x) ≈ -x²/2 - ln(-x) - ln(2π)/2 for x << 0.
        -0.5 * x * x - (-x).ln() - 0.5 * (2.0 * std::f64::consts::PI).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 1e-8, "A&S approx error at 0 is ~1e-9");
        assert!((erf(1.0) - 0.8427007929).abs() < 2e-7);
        assert!((erf(-1.0) + 0.8427007929).abs() < 2e-7);
        assert!((erf(3.0) - 0.9999779095).abs() < 2e-7);
    }

    #[test]
    fn normal_cdf_symmetry_and_known() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-8);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-4);
        for x in [-2.5, -1.0, 0.3, 1.7] {
            assert!((normal_cdf(x) + normal_cdf(-x) - 1.0).abs() < 1e-7);
        }
    }

    #[test]
    fn quantile_inverts_cdf() {
        for p in [0.001, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999] {
            let x = normal_quantile(p);
            assert!((normal_cdf(x) - p).abs() < 1e-6, "p={p} x={x}");
        }
        assert!((normal_quantile(0.5)).abs() < 1e-9);
        assert!((normal_quantile(0.975) - 1.959964).abs() < 1e-5);
    }

    #[test]
    #[should_panic]
    fn quantile_rejects_bounds() {
        normal_quantile(0.0);
    }

    #[test]
    fn log_cdf_matches_direct_and_handles_tail() {
        for x in [-6.0, -3.0, 0.0, 2.0] {
            assert!((log_normal_cdf(x) - normal_cdf(x).ln()).abs() < 1e-6, "x={x}");
        }
        // Deep tail stays finite and monotone.
        let a = log_normal_cdf(-20.0);
        let b = log_normal_cdf(-30.0);
        assert!(a.is_finite() && b.is_finite());
        assert!(b < a);
    }
}
