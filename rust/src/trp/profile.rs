//! Temporal Resource Profiles (TRP) and Functional Memory Profiles (FMP).
//!
//! A TRP (paper §3.2) is "a probabilistic model of [a job's] time-varying
//! resource demand over its execution … warm-up phases, steady-state
//! intervals, and transient bursts". An FMP is the TRP specialized to
//! device memory. We model a job's execution as a sequence of
//! [`Phase`]s over its total *work* (measured in full-GPU tick
//! equivalents); within each phase, memory at a given progress point is
//! Gaussian with a phase-specific mean trajectory and standard deviation.
//!
//! The two roles the paper assigns to TRPs are implemented here:
//!
//! 1. **Duration prediction** — [`Trp::predicted_duration`] derives the
//!    declared duration `Δt̃_i` of a variant from the work it covers, the
//!    slice speed, and a confidence quantile of the job's duration noise.
//! 2. **Probabilistic safety** — [`Fmp::violation_prob`] evaluates
//!    `Pr(max_t RAM(t) > c_k | FMP)` over the predicted interval, the
//!    safe-by-construction bound of §4.1(a).

use crate::sim::rng::Rng;
use crate::trp::math::{log_normal_cdf, normal_quantile};

/// One execution phase of a job (warm-up, steady, burst, …).
#[derive(Debug, Clone, PartialEq)]
pub struct Phase {
    /// Compute work in this phase (full-GPU tick equivalents).
    pub work: f64,
    /// Memory level (GiB) the phase ramps *to* and then holds.
    pub mem_gb: f64,
    /// Per-point Gaussian std of memory (GiB) within this phase.
    pub mem_std_gb: f64,
    /// Fraction of the phase spent ramping linearly from the previous
    /// phase's level to `mem_gb` (0 = step change, 1 = ramp whole phase).
    pub ramp_frac: f64,
}

impl Phase {
    /// Convenience constructor.
    pub fn new(work: f64, mem_gb: f64, mem_std_gb: f64, ramp_frac: f64) -> Self {
        Phase { work, mem_gb, mem_std_gb, ramp_frac }
    }
}

/// The job-level temporal resource profile.
#[derive(Debug, Clone, PartialEq)]
pub struct Trp {
    /// Execution phases in order. Total work = Σ phase.work.
    pub phases: Vec<Phase>,
    /// Coefficient of variation of realized duration around the nominal
    /// `work / speed` (duration noise; drives declared-vs-observed gaps).
    pub duration_cv: f64,
}

/// Discretized FMP over a work range: `bins` Gaussian memory snapshots.
///
/// This is exactly the `(M, T)` matrix the L1 Pallas scoring kernel
/// consumes: `mu[t]`, `sigma[t]` per time bin.
#[derive(Debug, Clone, PartialEq)]
pub struct Fmp {
    /// Mean memory per bin (GiB).
    pub mu: Vec<f64>,
    /// Std of memory per bin (GiB).
    pub sigma: Vec<f64>,
}

impl Trp {
    /// Total work of the job in full-GPU tick equivalents.
    pub fn total_work(&self) -> f64 {
        self.phases.iter().map(|p| p.work).sum()
    }

    /// Peak mean memory across phases (GiB) — a quick lower bound on the
    /// slice capacity the whole job would need if run monolithically.
    pub fn peak_mem_gb(&self) -> f64 {
        self.phases.iter().map(|p| p.mem_gb).fold(0.0, f64::max)
    }

    /// Minimum of the mean-memory trajectory (GiB) over all work points
    /// at or after `w0` (including the hold level past the final phase).
    ///
    /// This is the bidder-index precondition of the scheduler's bid
    /// collection: every FMP bin of a chunk starting at the work cursor
    /// samples the trajectory at some `w >= w0`, so if this minimum
    /// exceeds a slice's capacity, every bin mean does too and the
    /// violation probability is at least 0.5 — the job cannot produce an
    /// eligible variant for that slice under any `theta < 0.5`.
    pub fn min_mem_gb_from(&self, w0: f64) -> f64 {
        let mut min = f64::INFINITY;
        let mut prev_level = 0.0;
        let mut acc = 0.0;
        for p in &self.phases {
            if p.work == 0.0 {
                // A zero-work phase answers every query past its position
                // in `mem_stats_at`, so its level always bounds the
                // suffix minimum.
                min = min.min(p.mem_gb);
            } else if w0 < acc + p.work {
                // The phase overlaps [w0, inf): its trajectory ramps
                // linearly from prev_level to mem_gb over the first
                // ramp_frac, then holds. Over the suffix starting at
                // progress frac0, a lower bound is the value at frac0 or
                // the target level, whichever is smaller.
                let frac0 = ((w0 - acc) / p.work).clamp(0.0, 1.0);
                let at_frac0 = if p.ramp_frac > 0.0 && frac0 < p.ramp_frac {
                    prev_level + (p.mem_gb - prev_level) * (frac0 / p.ramp_frac)
                } else {
                    p.mem_gb
                };
                min = min.min(at_frac0).min(p.mem_gb);
            }
            acc += p.work;
            prev_level = p.mem_gb;
        }
        // Hold level past the end (also covers w0 beyond the total work).
        if let Some(p) = self.phases.last() {
            min = min.min(p.mem_gb);
        } else {
            min = 0.0;
        }
        min
    }

    /// Gaussian memory statistics `(mu, sigma)` at cumulative work `w`.
    ///
    /// Within a phase the mean ramps linearly from the previous phase's
    /// level over the first `ramp_frac` of the phase, then holds at
    /// `mem_gb`. Work beyond the total clamps to the final level.
    pub fn mem_stats_at(&self, w: f64) -> (f64, f64) {
        let mut prev_level = 0.0;
        let mut acc = 0.0;
        for p in &self.phases {
            if w <= acc + p.work || p.work == 0.0 {
                let frac = if p.work > 0.0 { ((w - acc) / p.work).clamp(0.0, 1.0) } else { 1.0 };
                let mu = if p.ramp_frac > 0.0 && frac < p.ramp_frac {
                    prev_level + (p.mem_gb - prev_level) * (frac / p.ramp_frac)
                } else {
                    p.mem_gb
                };
                return (mu, p.mem_std_gb);
            }
            acc += p.work;
            prev_level = p.mem_gb;
        }
        // Past the end: hold final level.
        match self.phases.last() {
            Some(p) => (p.mem_gb, p.mem_std_gb),
            None => (0.0, 0.0),
        }
    }

    /// Discretize the FMP over the work range `[w0, w1]` into `bins`
    /// snapshots (bin centers).
    pub fn fmp_bins(&self, w0: f64, w1: f64, bins: usize) -> Fmp {
        assert!(bins > 0, "fmp_bins needs at least one bin");
        let mut mu = Vec::with_capacity(bins);
        let mut sigma = Vec::with_capacity(bins);
        let span = (w1 - w0).max(0.0);
        for i in 0..bins {
            let w = w0 + span * ((i as f64 + 0.5) / bins as f64);
            let (m, s) = self.mem_stats_at(w);
            mu.push(m);
            sigma.push(s);
        }
        Fmp { mu, sigma }
    }

    /// Declared duration (ticks) for executing `work` on a slice of the
    /// given `speed`, at confidence `quantile` of the duration noise.
    ///
    /// Jobs declare conservative durations (e.g. the 0.9 quantile) so that
    /// the committed reservation usually covers the realized run; the
    /// margin is part of what ex-post verification measures.
    pub fn predicted_duration(&self, work: f64, speed: f64, quantile: f64) -> u64 {
        assert!(speed > 0.0);
        let nominal = work / speed;
        let z = if self.duration_cv > 0.0 && quantile > 0.0 && quantile < 1.0 {
            normal_quantile(quantile)
        } else {
            0.0
        };
        let d = nominal * (1.0 + z * self.duration_cv);
        d.max(1.0).round() as u64
    }

    /// Sample a realized duration (ticks) for `work` on `speed`, truncated
    /// below at half the nominal (a run can't be arbitrarily fast).
    pub fn sample_duration(&self, rng: &mut Rng, work: f64, speed: f64) -> u64 {
        let nominal = work / speed;
        let d = rng.normal_trunc_lo(nominal, nominal * self.duration_cv, nominal * 0.5);
        d.max(1.0).round() as u64
    }
}

impl Fmp {
    /// Number of bins.
    pub fn len(&self) -> usize {
        self.mu.len()
    }

    /// True if the profile has no bins.
    pub fn is_empty(&self) -> bool {
        self.mu.is_empty()
    }

    /// `Pr(max_t RAM(t) > c | FMP)` under per-bin independence:
    /// `1 − Π_t Φ((c − μ_t)/σ_t)`, evaluated in log space for stability.
    ///
    /// This is the eligibility bound of paper §4.1(a): a variant is
    /// *safe-by-construction* iff `violation_prob(c_k) ≤ θ`.
    pub fn violation_prob(&self, capacity_gb: f64) -> f64 {
        let mut log_surv = 0.0;
        for (&mu, &sig) in self.mu.iter().zip(&self.sigma) {
            if sig <= 0.0 {
                if mu > capacity_gb {
                    return 1.0;
                }
                continue;
            }
            let z = (capacity_gb - mu) / sig;
            log_surv += log_normal_cdf(z);
        }
        (1.0 - log_surv.exp()).clamp(0.0, 1.0)
    }

    /// Expected normalized memory headroom over the interval:
    /// `E[(c − RAM(t))/c]` clamped to `[0,1]` — the ψ_mem_headroom scoring
    /// feature of paper §4.2.
    pub fn mean_headroom(&self, capacity_gb: f64) -> f64 {
        if self.is_empty() || capacity_gb <= 0.0 {
            return 0.0;
        }
        let s: f64 =
            self.mu.iter().map(|&mu| ((capacity_gb - mu) / capacity_gb).clamp(0.0, 1.0)).sum();
        s / self.mu.len() as f64
    }

    /// Sample a realized memory trajectory and return its peak (GiB).
    pub fn sample_peak(&self, rng: &mut Rng) -> f64 {
        self.mu
            .iter()
            .zip(&self.sigma)
            .map(|(&mu, &sig)| rng.normal_ms(mu, sig).max(0.0))
            .fold(0.0, f64::max)
    }

    /// Sample the realized mean headroom given a capacity.
    pub fn sample_headroom(&self, rng: &mut Rng, capacity_gb: f64) -> f64 {
        if self.is_empty() || capacity_gb <= 0.0 {
            return 0.0;
        }
        let s: f64 = self
            .mu
            .iter()
            .zip(&self.sigma)
            .map(|(&mu, &sig)| {
                let m = rng.normal_ms(mu, sig).max(0.0);
                ((capacity_gb - m) / capacity_gb).clamp(0.0, 1.0)
            })
            .sum();
        s / self.mu.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn training_trp() -> Trp {
        Trp {
            phases: vec![
                Phase::new(1000.0, 8.0, 0.4, 0.5), // warm-up ramp to 8 GiB
                Phase::new(8000.0, 14.0, 0.8, 0.2), // steady at 14 GiB
                Phase::new(1000.0, 16.0, 1.5, 0.1), // bursty tail at 16 GiB
            ],
            duration_cv: 0.1,
        }
    }

    #[test]
    fn totals_and_peaks() {
        let t = training_trp();
        assert_eq!(t.total_work(), 10_000.0);
        assert_eq!(t.peak_mem_gb(), 16.0);
    }

    #[test]
    fn mem_stats_ramp_then_hold() {
        let t = training_trp();
        // Start of warm-up: ramping from 0 toward 8 over first half.
        let (m0, _) = t.mem_stats_at(0.0);
        assert!(m0 < 1.0, "start of ramp near 0, got {m0}");
        let (m_mid_ramp, _) = t.mem_stats_at(250.0); // frac 0.25 of ramp 0.5 -> half way
        assert!((m_mid_ramp - 4.0).abs() < 1e-9);
        let (m_hold, s_hold) = t.mem_stats_at(900.0);
        assert_eq!((m_hold, s_hold), (8.0, 0.4));
        // Steady phase holds 14 after its short ramp.
        let (m_steady, _) = t.mem_stats_at(5000.0);
        assert_eq!(m_steady, 14.0);
        // Past the end: final level.
        let (m_end, _) = t.mem_stats_at(99_999.0);
        assert_eq!(m_end, 16.0);
    }

    #[test]
    fn mem_stats_empty_trp() {
        let t = Trp { phases: vec![], duration_cv: 0.0 };
        assert_eq!(t.mem_stats_at(5.0), (0.0, 0.0));
        assert_eq!(t.total_work(), 0.0);
    }

    #[test]
    fn fmp_bins_sample_centers() {
        let t = training_trp();
        let fmp = t.fmp_bins(1000.0, 9000.0, 16);
        assert_eq!(fmp.len(), 16);
        // All bins are inside the steady phase (after its 20% ramp)
        // except the earliest ones.
        assert_eq!(*fmp.mu.last().unwrap(), 14.0);
        assert!(fmp.mu.iter().all(|&m| m > 0.0 && m <= 14.0));
    }

    #[test]
    fn min_mem_bounds_trajectory_suffix() {
        let t = training_trp();
        // Exhaustively compare against dense trajectory sampling.
        for w0 in [0.0, 250.0, 900.0, 1000.0, 4_000.0, 9_800.0, 10_000.0, 20_000.0] {
            let bound = t.min_mem_gb_from(w0);
            let mut sampled = f64::INFINITY;
            let mut w = w0;
            while w <= 12_000.0 {
                sampled = sampled.min(t.mem_stats_at(w).0);
                w += 1.0;
            }
            assert!(
                bound <= sampled + 1e-9,
                "w0={w0}: bound {bound} exceeds sampled min {sampled}"
            );
        }
        // From the steady state on, the bound clears the early ramp.
        assert!(t.min_mem_gb_from(2_000.0) >= 8.0);
        // Empty profile.
        assert_eq!(Trp { phases: vec![], duration_cv: 0.0 }.min_mem_gb_from(0.0), 0.0);
    }

    #[test]
    fn violation_prob_monotone_in_capacity() {
        let t = training_trp();
        let fmp = t.fmp_bins(2000.0, 8000.0, 32);
        let p_tight = fmp.violation_prob(14.5);
        let p_loose = fmp.violation_prob(20.0);
        assert!(p_tight > p_loose, "tight {p_tight} loose {p_loose}");
        assert!((0.0..=1.0).contains(&p_tight));
        assert!(p_loose < 1e-6, "20 GiB vs 14±0.8 should be safe, got {p_loose}");
        // Capacity below the mean is (almost) certain violation.
        assert!(fmp.violation_prob(10.0) > 0.999);
    }

    #[test]
    fn violation_prob_degenerate_sigma() {
        let fmp = Fmp { mu: vec![5.0, 6.0], sigma: vec![0.0, 0.0] };
        assert_eq!(fmp.violation_prob(6.5), 0.0);
        assert_eq!(fmp.violation_prob(5.5), 1.0);
    }

    #[test]
    fn headroom_in_unit_interval() {
        let t = training_trp();
        let fmp = t.fmp_bins(0.0, 10_000.0, 64);
        let h = fmp.mean_headroom(20.0);
        assert!((0.0..=1.0).contains(&h));
        // ~14 GiB mean usage on 20 GiB -> headroom around 0.3-0.5.
        assert!(h > 0.2 && h < 0.7, "h = {h}");
        assert!(fmp.mean_headroom(40.0) > h, "more capacity -> more headroom");
        assert_eq!(Fmp { mu: vec![], sigma: vec![] }.mean_headroom(10.0), 0.0);
    }

    #[test]
    fn predicted_duration_quantile_margin() {
        let t = training_trp();
        let nominal = t.predicted_duration(700.0, 1.0, 0.5);
        let conservative = t.predicted_duration(700.0, 1.0, 0.9);
        assert_eq!(nominal, 700);
        assert!(conservative > nominal, "0.9-quantile must add margin");
        // Slower slice -> proportionally longer.
        let slow = t.predicted_duration(700.0, 2.0 / 7.0, 0.5);
        assert_eq!(slow, 2450);
        // cv = 0 -> quantile irrelevant.
        let det = Trp { phases: t.phases.clone(), duration_cv: 0.0 };
        assert_eq!(det.predicted_duration(700.0, 1.0, 0.99), 700);
    }

    #[test]
    fn sample_duration_statistics() {
        let t = training_trp();
        let mut rng = Rng::new(42);
        let n = 5000;
        let mean: f64 =
            (0..n).map(|_| t.sample_duration(&mut rng, 1000.0, 1.0) as f64).sum::<f64>()
                / n as f64;
        assert!((mean - 1000.0).abs() < 15.0, "mean {mean}");
    }

    #[test]
    fn sample_peak_tracks_profile() {
        let t = training_trp();
        let fmp = t.fmp_bins(2000.0, 8000.0, 32);
        let mut rng = Rng::new(7);
        let peak = fmp.sample_peak(&mut rng);
        assert!(peak > 12.0 && peak < 20.0, "peak {peak}");
        let h = fmp.sample_headroom(&mut rng, 20.0);
        assert!((0.0..=1.0).contains(&h));
    }
}
