//! TRP/FMP: probabilistic temporal resource profiles (paper §3.2, §4.1).
//!
//! These descriptors originate in the SJA concept and are the basis of
//! JASDA's *safe-by-construction* eligibility: every variant a job bids
//! must satisfy `Pr(max_t RAM(t) > c_k | FMP) ≤ θ` over its predicted
//! execution interval.

pub mod math;
pub mod profile;

pub use profile::{Fmp, Phase, Trp};
