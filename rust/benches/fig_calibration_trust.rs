//! §4.2.1 calibration & ex-post verification — does the trust mechanism
//! neutralize strategic misreporting?
//!
//! Sweeps the misreporting fraction with calibration ON and OFF and
//! reports the liars' advantage (honest-to-liar slowdown ratio; > 1
//! means liars are better off) plus the mean reliability ρ after the run.

#[path = "common/mod.rs"]
mod common;

use jasda::jasda::JasdaScheduler;
use jasda::metrics::RunMetrics;
use jasda::report::Table;
use jasda::sim::SimEngine;
use jasda::workload::WorkloadGenerator;

fn slowdowns(m: &RunMetrics, liars: &[bool]) -> (f64, f64) {
    let (mut l, mut nl, mut h, mut nh) = (0.0, 0u32, 0.0, 0u32);
    for j in &m.jobs {
        if let Some(s) = j.slowdown() {
            if liars[j.job as usize] {
                l += s;
                nl += 1;
            } else {
                h += s;
                nh += 1;
            }
        }
    }
    (l / nl.max(1) as f64, h / nh.max(1) as f64)
}

fn main() {
    println!("Figure: calibration vs strategic misreporting (§4.2.1)\n");
    let mut table = Table::new(
        "misreport sweep (bias +80%)",
        &["liar_frac", "calibration", "liar_slow", "honest_slow", "advantage", "mean_rho"],
    );
    let mut advantages = Vec::new();
    for &frac in &[0.1, 0.3, 0.5] {
        for cal in [false, true] {
            let mut cfg = common::contended_cfg(51, 80);
            cfg.workload.misreport_fraction = frac;
            cfg.workload.misreport_bias = 0.8;
            cfg.jasda.calibration = cal;
            let jobs = WorkloadGenerator::new(cfg.workload.clone()).generate(cfg.seed);
            let liars: Vec<bool> = jobs.iter().map(|j| j.misreport_bias > 0.0).collect();
            let out = SimEngine::new(cfg.clone(), Box::new(JasdaScheduler::new(cfg.jasda.clone())))
                .run(jobs);
            let (liar, honest) = slowdowns(&out.metrics, &liars);
            // advantage > 1: honest jobs slowed more than liars.
            let adv = honest / liar.max(1e-9);
            advantages.push((frac, cal, adv));
            let rho = out
                .scheduler_stats
                .get("mean_rho")
                .and_then(|j| j.as_f64())
                .unwrap_or(f64::NAN);
            table.push_row(vec![
                format!("{frac:.1}"),
                if cal { "on" } else { "off" }.into(),
                format!("{liar:.2}"),
                format!("{honest:.2}"),
                format!("{adv:.3}"),
                format!("{rho:.3}"),
            ]);
        }
    }
    println!("{}", table.to_markdown());

    // Directional claim: calibration reduces the liars' advantage.
    let mut improved = 0;
    let mut cases = 0;
    for &frac in &[0.1, 0.3, 0.5] {
        let off = advantages.iter().find(|(f, c, _)| *f == frac && !c).unwrap().2;
        let on = advantages.iter().find(|(f, c, _)| *f == frac && *c).unwrap().2;
        cases += 1;
        if on <= off + 0.02 {
            improved += 1;
        }
        println!("liar_frac {frac}: advantage off={off:.3} on={on:.3}");
    }
    println!("calibration reduced (or held) liar advantage in {improved}/{cases} settings");
}
