//! Production-scale trace harness (ISSUE 10): run every scheduler over
//! one scenario-generated heavy-tailed multi-tenant trace twice — once
//! with the exact in-memory [`jasda::metrics::RunMetrics`] oracle and
//! once with the O(buckets) streaming layer — and emit the side-by-side
//! comparison rows into `BENCH_iteration.json` (override the path with
//! `BENCH_OUT`; set `BENCH_SMOKE=1` for a fast CI smoke run). The two
//! rows per scheduler must agree on counts/means and differ on
//! percentiles by at most the sketch's relative accuracy.

use jasda::baselines::{by_name, ALL_SCHEDULERS};
use jasda::config::SimConfig;
use jasda::metrics::streaming::{StreamingMetrics, DEFAULT_REL_ACCURACY};
use jasda::report::{comparison_headers, comparison_row, streaming_comparison_row, Table};
use jasda::sim::SimEngine;
use jasda::util::Json;
use jasda::workload::ScenarioGenerator;

/// The production-shaped scenario: heavy-tailed Pareto sizes, diurnal +
/// bursty arrivals, four fairness groups, SLO deadlines on ~a third of
/// jobs.
fn scenario_cfg(smoke: bool) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.seed = 81;
    cfg.cluster.num_gpus = 2;
    cfg.cluster.layout = "heterogeneous".into();
    // Bound pathological runs so the bench always terminates.
    cfg.engine.max_time = 80_000_000;
    let s = &mut cfg.jasda.scenario;
    s.jobs = if smoke { 400 } else { 8_000 };
    s.seed = 4242;
    s.tenants = 4;
    s.burst_prob = 0.05;
    s.metrics_window = 5_000;
    cfg
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").map_or(false, |v| v != "0" && !v.is_empty());
    let cfg = scenario_cfg(smoke);
    cfg.validate().expect("bench scenario config");
    let jobs = ScenarioGenerator::new(cfg.jasda.scenario.clone()).generate(cfg.seed);

    let schedulers: &[&str] = if smoke { &["jasda", "fcfs", "sjf"] } else { &ALL_SCHEDULERS };
    let mut table = Table::new(
        format!(
            "Production trace — {} jobs, {} tenants, seed {} (exact vs streaming)",
            jobs.len(),
            cfg.jasda.scenario.tenants,
            cfg.jasda.scenario.seed
        ),
        &comparison_headers(),
    );
    let mut rows: Vec<Json> = Vec::new();

    for &name in schedulers {
        let sched = by_name(name, &cfg.jasda).expect("known scheduler");
        let t0 = std::time::Instant::now();
        let exact = SimEngine::new(cfg.clone(), sched).run(jobs.clone());
        let exact_wall = t0.elapsed();
        table.push_row(comparison_row(&exact.metrics));

        let sched = by_name(name, &cfg.jasda).expect("known scheduler");
        let sm = StreamingMetrics::new(cfg.jasda.scenario.metrics_window, DEFAULT_REL_ACCURACY)
            .with_sink(Box::new(std::io::sink()));
        let t0 = std::time::Instant::now();
        let run = SimEngine::new(cfg.clone(), sched).with_streaming(sm).run(jobs.clone());
        let stream_wall = t0.elapsed();
        let sm = run.streaming.as_ref().expect("streaming path");
        let mut row = streaming_comparison_row(sm);
        row[0].push_str("+stream");
        table.push_row(row);

        let jct_delta = match (exact.metrics.jct_percentile(0.95), sm.jct_percentile(0.95)) {
            (Some(e), Some(s)) => (e - s).abs() / e.max(1.0),
            _ => 0.0,
        };
        println!(
            "{name:<12} exact {:>7.1?}  stream {:>7.1?}  buckets {:>4}  windows {:>5}  \
             p95_jct delta {:.4}",
            exact_wall,
            stream_wall,
            sm.total_buckets(),
            sm.lines_emitted(),
            jct_delta,
        );
        let exact_completed =
            exact.metrics.jobs.iter().filter(|j| j.completed.is_some()).count();
        rows.push(Json::obj(vec![
            ("scheduler", name.into()),
            ("jobs", jobs.len().into()),
            ("exact_completed", exact_completed.into()),
            ("stream_completed", sm.completed().into()),
            ("exact_unfinished", exact.metrics.unfinished.into()),
            ("stream_unfinished", sm.unfinished().into()),
            ("exact_util", exact.metrics.utilization.into()),
            ("stream_util", sm.utilization().into()),
            ("exact_p95_jct", exact.metrics.jct_percentile(0.95).unwrap_or(-1.0).into()),
            ("stream_p95_jct", sm.jct_percentile(0.95).unwrap_or(-1.0).into()),
            ("p95_jct_rel_delta", jct_delta.into()),
            ("stream_buckets", sm.total_buckets().into()),
            ("stream_windows_emitted", sm.lines_emitted().into()),
            ("exact_wall_ms", (exact_wall.as_nanos() as f64 / 1e6).into()),
            ("stream_wall_ms", (stream_wall.as_nanos() as f64 / 1e6).into()),
        ]));
    }

    println!();
    print!("{}", table.to_markdown());

    // Merge into the shared bench artifact rather than clobbering rows
    // other bench targets may already have written there.
    let path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_iteration.json".into());
    let production = Json::obj(vec![
        ("smoke", smoke.into()),
        ("rel_accuracy", DEFAULT_REL_ACCURACY.into()),
        ("rows", Json::Arr(rows)),
    ]);
    let merged = match std::fs::read_to_string(&path).ok().and_then(|s| Json::parse(&s).ok()) {
        Some(Json::Obj(mut m)) => {
            m.insert("production".into(), production);
            Json::Obj(m)
        }
        _ => Json::obj(vec![
            ("schema", "jasda.bench_iteration.v1".into()),
            ("smoke", smoke.into()),
            ("production", production),
        ]),
    };
    match std::fs::write(&path, merged.to_string_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
}
