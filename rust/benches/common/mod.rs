//! Shared workload/run helpers for the benchmark targets.
//!
//! Each bench regenerates one paper table or figure (see DESIGN.md §5);
//! they all run schedulers through the same engine on identical traces so
//! differences isolate scheduling policy.

#![allow(dead_code)]

use jasda::config::SimConfig;
use jasda::job::Job;
use jasda::metrics::RunMetrics;
use jasda::sim::{Scheduler, SimEngine};
use jasda::workload::WorkloadGenerator;

/// A moderately contended single-GPU scenario (offered load ~1.3x).
pub fn contended_cfg(seed: u64, num_jobs: usize) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.seed = seed;
    cfg.cluster.num_gpus = 1;
    cfg.cluster.layout = "heterogeneous".into();
    cfg.workload.num_jobs = num_jobs;
    cfg.workload.arrival_rate_per_sec = 0.35;
    cfg
}

/// A light scenario (offered load ~0.6x) where gaps dominate.
pub fn light_cfg(seed: u64, num_jobs: usize) -> SimConfig {
    let mut cfg = contended_cfg(seed, num_jobs);
    cfg.workload.arrival_rate_per_sec = 0.12;
    cfg
}

/// Generate the workload for a config.
pub fn workload(cfg: &SimConfig) -> Vec<Job> {
    WorkloadGenerator::new(cfg.workload.clone()).generate(cfg.seed)
}

/// Run one scheduler on a fixed trace.
pub fn run(cfg: &SimConfig, sched: Box<dyn Scheduler>, jobs: &[Job]) -> RunMetrics {
    SimEngine::new(cfg.clone(), sched).run(jobs.to_vec()).metrics
}

/// Format Option<f64> with 3 decimals or '-'.
pub fn fmt(x: Option<f64>) -> String {
    x.map_or("-".to_string(), |v| format!("{v:.3}"))
}

/// Format Option<f64> with 0 decimals or '-'.
pub fn fmt0(x: Option<f64>) -> String {
    x.map_or("-".to_string(), |v| format!("{v:.0}"))
}
