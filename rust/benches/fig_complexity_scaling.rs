//! §4.6 per-iteration complexity — the O(M log M) clearing claim.
//!
//! Generates WIS pools of increasing size M and times
//! `SelectBestCompatibleVariants`. The series should grow quasi-linearly
//! (doubling M should roughly double time, with a slowly growing log
//! factor), which we check numerically.

#[path = "common/mod.rs"]
mod common;

use jasda::jasda::clearing::{select_best_compatible, WisItem};
use jasda::report::Table;
use jasda::sim::Rng;
use jasda::types::Interval;
use jasda::util::bench::bench;

fn pool(m: usize, seed: u64) -> Vec<WisItem> {
    let mut rng = Rng::new(seed);
    (0..m)
        .map(|_| {
            let s = rng.below(1_000_000);
            let len = 1 + rng.below(5_000);
            WisItem { interval: Interval::new(s, s + len), score: rng.uniform() }
        })
        .collect()
}

fn main() {
    println!("Figure: clearing complexity — O(M log M) (paper §4.6)\n");
    let mut table = Table::new(
        "WIS clearing time vs pool size M",
        &["M", "median", "ns/variant", "ns/(M log2 M)"],
    );
    let mut per_mlogm = Vec::new();
    for &m in &[64usize, 256, 1024, 4096, 16384, 65536, 262144] {
        let items = pool(m, 7 + m as u64);
        let meas = bench(7, 5, || select_best_compatible(std::hint::black_box(&items)).total_score);
        let ns = meas.ns_per_iter();
        let norm = ns / (m as f64 * (m as f64).log2());
        per_mlogm.push(norm);
        table.push_row(vec![
            format!("{m}"),
            format!("{:.3} ms", ns / 1e6),
            format!("{:.1}", ns / m as f64),
            format!("{norm:.2}"),
        ]);
    }
    println!("{}", table.to_markdown());

    // O(M log M) check: the normalized column should be ~flat. Allow 4x
    // spread for cache effects across 4 orders of magnitude of M.
    let max = per_mlogm.iter().cloned().fold(f64::MIN, f64::max);
    let min = per_mlogm.iter().cloned().fold(f64::MAX, f64::min);
    println!("ns/(M log M) spread: {:.2}x (flat = perfectly M log M)", max / min);
    assert!(
        max / min < 12.0,
        "clearing deviates badly from M log M: spread {:.1}",
        max / min
    );
}
